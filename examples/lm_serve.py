"""Batched serving with continuous batching + KV cache.

    PYTHONPATH=src python examples/lm_serve.py [--arch recurrentgemma-2b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, smoke
from repro.launch.serve import Request, ServeEngine
from repro.models import build

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
ap.add_argument("--requests", type=int, default=6)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--max-new", type=int, default=12)
args = ap.parse_args()

cfg = smoke(args.arch)
lm = build(cfg)
params = lm.init_params(jax.random.PRNGKey(0))
engine = ServeEngine(cfg, params, batch=args.batch, max_seq=128,
                     temperature=0.8)

rng = np.random.default_rng(0)
t0 = time.time()
for rid in range(args.requests):
    plen = int(rng.integers(3, 10))
    prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
    engine.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

done = engine.run()
dt = time.time() - t0
total_tokens = sum(len(c.tokens) for c in done)
print(f"arch={args.arch} ({cfg.family}); {len(done)} completions, "
      f"{total_tokens} tokens in {dt:.1f}s "
      f"({total_tokens / dt:.1f} tok/s with batch={args.batch})")
for c in sorted(done, key=lambda c: c.rid)[:3]:
    print(f"  request {c.rid}: {c.tokens}")
