"""Large-scale Simplex-GP: houseelectric-style MVMs + one training epoch.

Demonstrates the paper's core claim at the largest size this host can
hold: lattice MVMs on 100k+ points in seconds, where the exact kernel
matrix (n^2 floats) would not even fit in memory.

    PYTHONPATH=src python examples/gp_large_scale.py [--n 100000]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import filtering
from repro.core.stencil import make_stencil
from repro.data.synthetic_uci import load
from repro.gp import GPParams, SimplexGP, SimplexGPConfig
from repro.gp.mll import mll_value_and_grad

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=100_000)
args = ap.parse_args()

ds = load("houseelectric", scale=args.n / 2_049_280)
x = jnp.asarray(ds.x_train)
y = jnp.asarray(ds.y_train)
n, d = x.shape
print(f"houseelectric stand-in: n={n:,} d={d}  "
      f"(dense K would be {n * n * 4 / 2**30:.0f} GiB)")

# --- one MVM ----------------------------------------------------------------
st = make_stencil("matern32", 1)
t0 = time.time()
mv, lat = filtering.mvm_operator(x, st)
v = y[:, None]
u = jax.block_until_ready(mv(v))
print(f"lattice build + first MVM: {time.time() - t0:.2f}s "
      f"(m={int(lat.m):,} lattice points, "
      f"m/L={int(lat.m) / (n * (d + 1)):.3f})")
t0 = time.time()
jax.block_until_ready(mv(v))
print(f"amortized MVM: {time.time() - t0:.3f}s")

# --- one full BBMM training step (CG solves + SLQ + gradients) --------------
model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=20,
                                  num_probes=4, max_lanczos_iters=10))
params = GPParams.init(d)
t0 = time.time()
res = mll_value_and_grad(model, params, x, y, jax.random.PRNGKey(0),
                         tol=1e-2)
print(f"one MLL step (20 CG iters, 4 probes): {time.time() - t0:.1f}s  "
      f"mll/n={float(res.mll) / n:+.4f}")
print("grad wrt log-lengthscales:",
      jax.numpy.round(res.grads.raw_lengthscale, 4))
