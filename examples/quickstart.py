"""Quickstart: Simplex-GP regression in ~40 lines (paper §5.3 workflow).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.gp import (GPParams, SimplexGP, SimplexGPConfig, fit, nll,
                      posterior, rmse)

# --- data: a smooth function of 4 inputs + noise ---------------------------
rng = np.random.default_rng(0)
n, d = 2000, 4
x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
f = jnp.sin(2 * x[:, 0]) + 0.5 * jnp.cos(x[:, 1] * x[:, 2]) + 0.3 * x[:, 3]
y = f + 0.1 * jnp.asarray(rng.normal(size=n), jnp.float32)
x_tr, y_tr = x[:1400], y[:1400]
x_val, y_val = x[1400:1700], f[1400:1700]
x_te, y_te = x[1700:], f[1700:]

# --- model: Matern-3/2 on the permutohedral lattice, order-1 blur ----------
model = SimplexGP(SimplexGPConfig(
    kernel="matern32",     # any stationary profile (paper §4.1)
    order=1,               # blur stencil radius r (Appendix A)
    grad_mode="autodiff",  # beyond-paper gradient mode (DESIGN.md §7)
    max_cg_iters=50,
))

# --- train: Adam(0.1) on the BBMM MLL, early stop on val RMSE (§5.4) -------
result = fit(model, x_tr, y_tr, x_val=x_val, y_val=y_val, epochs=25,
             lr=0.1, log_fn=print)

# --- predict ---------------------------------------------------------------
post = posterior(model, result.best_params, x_tr, y_tr, x_te,
                 key=jax.random.PRNGKey(0))
noise = model.constrained(result.best_params)[2]
print(f"\ntest RMSE {float(rmse(post, y_te)):.4f}   "
      f"test NLL {float(nll(post, noise, y_te)):.4f}")
ls, os_, nz = model.constrained(result.best_params)
print(f"learned ARD lengthscales: {np.asarray(ls).round(3)}")
print(f"outputscale {float(os_):.3f}   noise {float(nz):.4f}")
