"""Minimal serving walkthrough: freeze once, then run the engine.

Train once, freeze once, then serve query batches at O(d^2) per query —
no lattice build, no CG solve, cost independent of n (DESIGN.md §12).
The second half runs the same Predictor through the fault-tolerant
serving engine (DESIGN.md §13): queries against a hot-swappable
registry, warm background refreshes when new data lands, health/
staleness reporting. The final act makes the state DURABLE (§14):
persist the Predictor to a generation store, kill the process
mid-persist, and warm-boot a fresh engine from disk — no training, no
freeze, no data loss beyond the generation being written.

    PYTHONPATH=src python examples/serve_minimal.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.gp import (GPParams, SimplexGP, SimplexGPConfig, fit, freeze,
                      posterior)
from repro.gp.serve import predict
from repro.launch import EngineConfig, GPServeEngine, PredictorStore
from repro.runtime.faults import corrupt_checkpoint

# --- data: a smooth function of 4 inputs + noise ---------------------------
rng = np.random.default_rng(0)
n, d = 2000, 4
x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
f = jnp.sin(2 * x[:, 0]) + 0.5 * jnp.cos(x[:, 1] * x[:, 2]) + 0.3 * x[:, 3]
y = f + 0.1 * jnp.asarray(rng.normal(size=n), jnp.float32)
x_tr, y_tr = x[:1400], y[:1400]
x_val, y_val = x[1400:1700], f[1400:1700]

model = SimplexGP(SimplexGPConfig(kernel="matern32"))

# --- train (once) ----------------------------------------------------------
result = fit(model, x_tr, y_tr, x_val=x_val, y_val=y_val, epochs=10, lr=0.1)
params = result.best_params

# --- freeze (once): solves + one blur sweep -> immutable Predictor ---------
t0 = time.perf_counter()
pred = freeze(model, params, x_tr, y_tr, key=jax.random.PRNGKey(0),
              variance_rank=20)
print(f"freeze: {time.perf_counter() - t0:.2f}s  "
      f"(tables {pred.tables.shape}, {pred.tables.nbytes / 1024:.0f} KB, "
      f"hash index {pred.index.hcap} slots, "
      f"CG converged={bool(pred.cg_converged)} "
      f"in {int(pred.cg_iterations)} iters)")

# --- serve: batches pad to fixed buckets; first call per bucket compiles ---
queries = jnp.asarray(rng.normal(size=(200, d)), jnp.float32)
out = predict(pred, queries)  # warm-up / compile for the 256 bucket
t0 = time.perf_counter()
out = jax.block_until_ready(predict(pred, queries))
dt = time.perf_counter() - t0
print(f"serve: {dt * 1e3:.2f} ms / {queries.shape[0]} queries "
      f"({dt / queries.shape[0] * 1e6:.1f} us each)")

# miss_mass is the fidelity diagnostic: barycentric weight on lattice
# vertices the frozen model never saw. 0 = fully in-lattice; near 1 =
# the prediction is mostly prior. The engine below tracks it for you.
frac_clean = float(jnp.mean((out.miss_mass == 0).astype(jnp.float32)))
print(f"miss_mass: {frac_clean:.0%} of queries fully in-lattice, "
      f"mean mass {float(jnp.mean(out.miss_mass)):.3f}")

# --- sanity: the frozen path tracks the full posterior ---------------------
# The gap at the DEFAULT eval tolerance is dominated by CG stopping noise
# (both paths solve to rel. residual cg_tol_eval=1e-2, on marginally
# different lattices); with a converged-CG config it drops to ~1e-6 —
# see BENCH_serve.json's mean_parity column and tests/test_serve.py.
post = posterior(model, params, x_tr, y_tr, queries,
                 key=jax.random.PRNGKey(0), variance_rank=20)
clean = np.asarray(out.miss_mass) == 0
gap = np.abs(np.asarray(out.mean) - np.asarray(post.mean))[clean]
print(f"frozen vs posterior mean gap on in-lattice queries: "
      f"max {gap.max():.2e}  (~cg_tol_eval; see BENCH_serve.json "
      "mean_parity for the converged-CG figure)")

# --- the serving engine: hot swaps, warm refreshes, health -----------------
# In production you run the engine, not bare predict(): it validates
# every candidate before publishing, retries transient query faults,
# serves full-miss queries from the prior, and keeps the last-good
# Predictor serving if a refresh fails or wedges (launch/serve_gp.py).
with GPServeEngine(model, params, x_tr, y_tr, key=jax.random.PRNGKey(1),
                   config=EngineConfig(variance_rank=20)) as eng:
    res = eng.query(queries)
    print(f"engine: version {res.version} served {queries.shape[0]} "
          f"queries, {int(res.fallback.sum())} from the prior-fallback "
          f"lane, stale={res.stale}")

    # new observations arrive: a y-only refresh rides the warm lane
    # (cached lattice, reused hash index, CG warm-started from the old
    # alpha) and hot-swaps atomically — in-flight queries are untouched
    y_new = y_tr + 0.05 * jnp.sin(x_tr[:, 0])
    eng.submit_refresh(y=y_new)
    eng.refresh_now()  # or background=True for a worker thread
    h = eng.health()
    print(f"refresh: version {eng.version} in {h.last_refresh_s * 1e3:.0f} "
          f"ms (warm; CG {int(eng.predictor().cg_iterations)} iters), "
          f"status={h.status}, staleness={h.staleness:.3f}")

# --- durable state: save -> kill -> warm boot (DESIGN.md §14) --------------
# In production the frozen Predictor outlives the process: the engine
# persists every published version to a generation store (atomic
# tmp+rename, per-blob checksums), and a restarted engine boots from
# the newest generation that passes the full load gate — checksums,
# validate_predictor, and an in-lattice self-probe — skipping anything
# damaged. Here we persist two generations, vandalize the newest on
# disk (a stand-in for a torn write or a kill mid-persist: both leave
# either an ignored *.tmp orphan or a detectably damaged directory),
# and watch the warm boot fall back one generation instead of serving
# garbage or re-training.
with tempfile.TemporaryDirectory() as root:
    store = PredictorStore(root, keep_last=3)
    with GPServeEngine(model, params, x_tr, y_tr,
                       key=jax.random.PRNGKey(1),
                       config=EngineConfig(variance_rank=20),
                       store=store, model_name="demo") as eng:
        eng.query(queries)                    # cold boot: store was empty
        eng.submit_refresh(y=y_new)
        eng.refresh_now()                     # publish + persist gen 2
        eng.wait_persisted()                  # persistence is async
        print(f"persisted generations on disk: {store.generations('demo')}")

    # "kill": the process is gone; only the store survives. Damage the
    # newest generation the way a real crash or disk fault would.
    corrupt_checkpoint(store.path("demo", store.generations("demo")[-1]),
                       "bitflip")

    t0 = time.perf_counter()
    with GPServeEngine(model, params, x_tr, y_tr,
                       key=jax.random.PRNGKey(2),
                       config=EngineConfig(variance_rank=20),
                       store=store, model_name="demo") as eng2:
        res = eng2.query(queries)             # no fit, no freeze, no CG
        h = eng2.health()
        print(f"warm boot: {time.perf_counter() - t0:.2f}s to first answer "
              f"(mode={h.boot_mode}, generation={h.boot_generation}, "
              f"skipped {h.boot_skipped} damaged), version {res.version}")
