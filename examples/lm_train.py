"""End-to-end LM training driver: ~100M-parameter model, few hundred steps.

Uses the full production loop (launch/train.py): deterministic token
pipeline, prefetching, watchdog, atomic checkpoints with resume.

    PYTHONPATH=src python examples/lm_train.py [--steps 300]
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs import get as get_config
from repro.launch.train import TrainConfig, make_model_and_step, run
import repro.launch.train as train_mod

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt", type=str, default="/tmp/repro_lm_ckpt")
args = ap.parse_args()

# ~100M llama-style config: 8 layers, d=512, derived from llama3.2-3b
base = get_config("llama3.2-3b")
cfg100m = dataclasses.replace(
    base, num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
    d_ff=1536, vocab_size=32_000, vocab_pad_multiple=128,
    dtype=jnp.float32, remat=False, head_dim=64)
print(f"model: {cfg100m.num_params() / 1e6:.1f}M params")

# monkey-wire the reduced config through the launcher
_orig = train_mod.make_model_and_step


def patched(tc):
    from repro.models import build
    from repro.optim import Adam, schedules
    import jax
    lm = build(cfg100m)
    opt = Adam(learning_rate=schedules.warmup_cosine(
        tc.lr, tc.warmup, tc.steps), clip_global_norm=1.0)
    step, _ = lm.make_train_step(opt)
    return cfg100m, lm, opt, jax.jit(step)


train_mod.make_model_and_step = patched
tc = TrainConfig(arch="llama3.2-3b", smoke=False, steps=args.steps,
                 global_batch=8, seq_len=256, lr=3e-4, warmup=30,
                 ckpt_dir=args.ckpt, ckpt_every=100, log_every=10)
out = run(tc)
losses = [l for _, l in out["losses"]]
print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
      f"{args.steps} steps; {len(out['breaches'])} watchdog breaches")
