"""PILCO-style Monte-Carlo rollout on a frozen multi-output GP dynamics
model — the control workload differentiable frozen serving exists for
(ROADMAP item 4; DESIGN.md §15).

The loop, end to end:

  1. COLLECT  a few random-action episodes of the true dynamics (here a
     damped pendulum) give (state, action) -> next-state-delta pairs.
  2. FIT + FREEZE_MULTI  one Simplex-GP per state dimension, but frozen
     into ONE MultiPredictor: the k=2 output channels share the lattice
     index and a stacked (m+1, k*(1+r)) table, so serving both channels
     costs ONE embed + d+1 hash probes per query (gp/serve.py).
  3. ROLLOUT  P particles for H steps: each step queries the frozen
     model at [state, policy(state)], samples the next state from the
     predictive mean/variance (the Monte-Carlo counterpart of PILCO's
     moment matching), and accrues cost. The whole (P, H) trajectory
     cloud is one jitted ``lax.scan``. The LOVE low-rank variance is a
     CONSERVATIVE upper bound on the posterior variance (it only
     subtracts the explained mass the Lanczos subspace captured), so
     the sampled noise is tempered by ``LAM`` — the reparameterization,
     and therefore the gradient flow, is unchanged.
  4. IMPROVE  the expected cost is differentiated END TO END with
     ``jax.grad`` — through the sampling, through the frozen slice
     (the custom JVP of ``filtering.slice_only``: barycentric weights
     are piecewise-linear in the query, so the tangent is one extra
     contraction, no probes), into the policy parameters. A few plain
     gradient steps visibly drop the cost.

Validity gating: gradients of the frozen surface are exact FOR THE
SURROGATE everywhere, but only approximate the GP posterior's where
``miss_mass == 0`` (inside the frozen lattice). The rollout tracks the
worst per-step miss and reports it — a policy that drags particles off
the training manifold announces itself here rather than silently
following a kinked extrapolation.

    PYTHONPATH=src python examples/rollout_pilco.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.gp import GPParams, SimplexGP, SimplexGPConfig, freeze_multi
from repro.gp.serve import predict_multi

# --- the true system: a damped pendulum, angle th / velocity om ------------
DT = 0.1


def true_step(state, action):
    th, om = state[..., 0], state[..., 1]
    om2 = om + DT * (-9.8 * jnp.sin(th) - 0.2 * om + action)
    th2 = th + DT * om2
    return jnp.stack([th2, om2], axis=-1)


# --- 1. collect off-policy transitions -------------------------------------
rng = np.random.default_rng(0)
n = 1500
states = jnp.asarray(
    np.stack([rng.uniform(-np.pi, np.pi, n), rng.uniform(-7, 7, n)], 1),
    jnp.float32)
actions = jnp.asarray(rng.uniform(-2, 2, n), jnp.float32)
deltas = true_step(states, actions) - states  # (n, 2): the GP targets

x_train = jnp.concatenate([states, actions[:, None]], axis=1)  # (n, 3)
y_train = deltas  # (n, k=2)

# --- 2. freeze a stacked 2-output dynamics model ---------------------------
model = SimplexGP(SimplexGPConfig(kernel="matern32"))
# anisotropic lengthscales sized to the state box: the lattice cell is
# ~1.3 lengthscales wide, so these keep the 1500 training points dense
# per cell (few coverage holes -> near-zero rollout miss_mass) while the
# smooth pendulum deltas stay well fit
params = GPParams.init(3, lengthscale=jnp.asarray([1.0, 2.0, 1.2]),
                       noise=1e-2)

t0 = time.perf_counter()
mp = freeze_multi(model, params, x_train, y_train,
                  key=jax.random.PRNGKey(0), variance_rank=24)
print(f"freeze_multi: {time.perf_counter() - t0:.2f}s — "
      f"{mp.n_outputs} channels in one {mp.tables.shape} table, "
      f"CG converged={np.asarray(mp.cg_converged).tolist()}")

# --- 3 + 4. differentiable MC rollout + policy gradient --------------------
P, H = 256, 100  # particles, horizon
LAM = 0.1  # variance tempering: LOVE var is conservative (see docstring)
TARGET = jnp.asarray([0.0, 0.0])  # damp a big swing down to rest


def wrap(th):
    """Wrap the angle into the trained [-pi, pi) chart. ``round`` is
    piecewise-constant, so d wrap/d th == 1 — gradients pass through."""
    return th - 2 * jnp.pi * jnp.round(th / (2 * jnp.pi))


def policy(w, s):
    """Tiny affine-tanh controller; w is what we optimize."""
    feats = jnp.stack([jnp.sin(s[..., 0]), jnp.cos(s[..., 0]),
                       s[..., 1]], axis=-1)
    return 2.0 * jnp.tanh(feats @ w[:3] + w[3])


def rollout_cost(w, key):
    """Expected cost of the particle cloud under the FROZEN model.

    Every step serves all P particles x k channels from one probe
    batch; the sampling reparameterization keeps the whole thing
    differentiable, so jax.grad(rollout_cost) is the policy gradient
    PILCO computes by moment-matching — here by Monte Carlo.
    """
    s0 = jnp.zeros((P, 2)).at[:, 0].set(2.5)  # released from a big swing
    eps = jax.random.normal(key, (H, P, 2))

    def step(s, e):
        a = policy(w, s)
        q = jnp.stack([wrap(s[:, 0]), s[:, 1], a], axis=1)  # (P, 3)
        res = predict_multi(mp, q)
        s2 = s + res.mean + LAM * jnp.sqrt(res.var) * e  # reparam sample
        err = jnp.stack([jnp.cos(s2[:, 0]) - jnp.cos(TARGET[0]),
                         jnp.sin(s2[:, 0]) - jnp.sin(TARGET[0]),
                         0.3 * (s2[:, 1] - TARGET[1])], axis=1)
        cost = jnp.mean(jnp.sum(err ** 2, axis=1))
        return s2, (cost, jnp.max(res.miss_mass))

    _, (costs, miss) = jax.lax.scan(step, s0, eps)
    return jnp.mean(costs), jnp.max(miss)


grad_fn = jax.jit(jax.value_and_grad(rollout_cost, has_aux=True))

w = jnp.zeros(4)
key = jax.random.PRNGKey(1)
t0 = time.perf_counter()
for it in range(15):
    key, sub = jax.random.split(key)
    (cost, worst_miss), g = grad_fn(w, sub)
    w = w - 0.5 * g
    if it % 3 == 0 or it == 14:
        print(f"iter {it:2d}  E[cost]={float(cost):.4f}  "
              f"worst step miss={float(worst_miss):.3f}  "
              f"|grad|={float(jnp.linalg.norm(g)):.3f}")
evals = 15 * P * H * mp.n_outputs
dt = time.perf_counter() - t0
print(f"policy search: {dt:.2f}s — {evals / dt:,.0f} "
      "state-evals/s THROUGH the gradient (fwd+bwd each step)")
