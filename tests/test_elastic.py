"""Elastic sharded GP training (DESIGN.md §16).

Three layers of defense, mirroring test_multidevice.py:
  * in-process (always runs, 1 real device): cache mesh-keying, the
    degenerate size-1-data-axis one-psum pin, fit's transient-retry and
    watchdog-breach semantics, the in-process ElasticGPTrainer loop,
    and a hypothesis property for the replicated checkpoint round-trip;
  * subprocess snippets (marker ``elastic``): checkpoint round-trip
    across REAL mesh sizes (8 -> 4 -> 1 -> 8, params bit-identical) and
    the cross-mesh LatticeCache staleness regression (8 -> 4 resume must
    miss and rebuild);
  * subprocess worker lives (marker ``elastic``): a scripted kill on the
    full mesh resumed on half the devices — true device loss, losing at
    most ``ckpt_every`` epochs.

The ``elastic`` CI lane runs the subprocess tests under varying base
device counts (``ELASTIC_BASE_DEVICES``).
"""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from _hyp_compat import given, settings, st
from repro.core import lattice as lat_mod
from repro.core.filtering import LatticeCache
from repro.core.stencil import make_stencil
from repro.gp import SimplexGP, SimplexGPConfig
from repro.gp import train as train_mod
from repro.gp.models import GPParams
from repro.launch.elastic_gp import (ElasticGPTrainer, make_problem,
                                     params_digest)
from repro.runtime import elastic
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.faults import FaultEvent, FaultInjector, is_injected
from repro.runtime.straggler import StepWatchdog
from repro.sharding import simplex as sx

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
BASE_DEVICES = int(os.environ.get("ELASTIC_BASE_DEVICES", "8"))

CFG = SimplexGPConfig(kernel="matern32", max_cg_iters=40, num_probes=2)


# -- mesh fingerprints and cache keys (in-process) ---------------------------

def test_mesh_fingerprint_distinguishes_layouts():
    assert sx.mesh_fingerprint(None) == ""
    m1 = Mesh(np.array(jax.devices()[:1]), ("data",))
    fp = sx.mesh_fingerprint(m1)
    assert fp and fp != sx.mesh_fingerprint(None)
    # same devices, same axis -> same fingerprint (stable key)
    assert fp == sx.mesh_fingerprint(Mesh(np.array(jax.devices()[:1]),
                                          ("data",)))


def test_cache_misses_on_mesh_change(rng):
    """A lattice built for one consumer mesh must never serve another
    (DESIGN.md §16): mesh=None and a 1-device mesh are distinct keys."""
    st_ = make_stencil("rbf", 1)
    x = jnp.asarray(rng.normal(size=(64, 2)), jnp.float32)
    ls = jnp.ones((2,), jnp.float32)
    cache = LatticeCache()
    tag = cache.point_set_tag(x)
    m1 = Mesh(np.array(jax.devices()[:1]), ("data",))
    l_none = cache.get(tag, x, spacing=st_.spacing, r=st_.r, cap=None,
                       ls=ls)
    l_mesh = cache.get(tag, x, spacing=st_.spacing, r=st_.r, cap=None,
                       ls=ls, mesh=m1)
    assert l_mesh is not l_none
    assert cache.misses == 2 and cache.hits == 0
    assert cache.get(tag, x, spacing=st_.spacing, r=st_.r, cap=None,
                     ls=ls, mesh=m1) is l_mesh
    assert cache.hits == 1


def test_one_psum_on_size1_data_axis(rng):
    """Degenerate mesh: the one-psum contract holds when the data axis
    has shrunk all the way to a single device (elastic floor)."""
    st_ = make_stencil("matern32", 1)
    z = jnp.asarray(rng.normal(size=(37, 3)), jnp.float32)  # uneven too
    v = jnp.asarray(rng.normal(size=(37, 2)), jnp.float32)
    lat = lat_mod.build_lattice(z, spacing=st_.spacing, r=st_.r)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    w = jnp.asarray(st_.weights, jnp.float32)
    counts = sx.collective_counts(
        lambda vv: sx.sharded_lattice_mvm(lat, vv, w, mesh=mesh), v)
    assert counts["psum"] == 1
    assert all(c == 0 for p, c in counts.items() if p != "psum")


# -- checkpoint round-trip property (in-process) -----------------------------

@settings(max_examples=8, deadline=None)
@given(d=st.integers(1, 6), seed=st.integers(0, 1000))
def test_ckpt_roundtrip_replicated_property(d, seed):
    """GP loop state is replicated: restore via resume_gp onto any mesh
    must be bit-identical to what was saved, for any param shape/seed.

    NOTE: no pytest fixtures here — @given properties run many examples
    per test call, so state is built inside the example.
    """
    key = jax.random.PRNGKey(seed)
    params = GPParams.init(d)
    params = jax.tree.map(
        lambda a, k=key: a + 0.1 * jax.random.normal(k, a.shape, a.dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
    tree = {"params": params, "key": key}
    with tempfile.TemporaryDirectory() as td:
        m = CheckpointManager(td, keep_last=1)
        m.save(0, tree, metric=0.0, extra={"epoch": 0})
        m.wait()
        tmpl = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        out, step, extra, mesh = elastic.resume_gp(m, tmpl)
    assert step == 0 and extra["epoch"] == 0
    assert mesh.shape["data"] == jax.device_count()
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


# -- fit step-failure semantics (in-process) ---------------------------------

def _tiny_problem():
    return make_problem(0, 96, 2, 24)


def test_fit_retries_transient_step_fault(tmp_path):
    """A transient in-step exception surfaces as a retried event in
    FitReport — and the retried trajectory is bit-identical to an
    un-faulted run (the step is pure; the retry replays it)."""
    x, y, xv, yv = _tiny_problem()
    model = SimplexGP(CFG)
    inj = FaultInjector([FaultEvent(site="fit_step", kind="exception",
                                    at=2, note="transient")])
    faulted = train_mod.fit(model, x, y, x_val=xv, y_val=yv, epochs=4,
                            faults=inj)
    assert len(faulted.report.retries) == 1
    assert faulted.report.retries[0]["epoch"] == 1
    assert faulted.report.completed_epochs == 4
    assert faulted.report.interrupted is None
    # bit-compat vs a clean run: the injector must be armed (same guarded
    # step program) but with nothing scheduled
    clean = train_mod.fit(model, x, y, x_val=xv, y_val=yv, epochs=4,
                          faults=FaultInjector())
    assert params_digest(faulted.params) == params_digest(clean.params)


def test_fit_exhausted_retries_raise(tmp_path):
    """A PERSISTENT in-step failure (count > step_retries) aborts: retry
    absorbs transients, not hard faults."""
    x, y, xv, yv = _tiny_problem()
    model = SimplexGP(CFG)
    inj = FaultInjector([FaultEvent(site="fit_step", kind="exception",
                                    at=1, count=5, note="persistent")])
    with pytest.raises(Exception) as ei:
        train_mod.fit(model, x, y, x_val=xv, y_val=yv, epochs=3,
                      faults=inj, step_retries=2)
    assert is_injected(ei.value)


def test_fit_watchdog_breach_checkpoints_and_aborts(tmp_path):
    """A wedged step trips the watchdog: fit records the breach, writes
    an immediate checkpoint of the slow-but-valid epoch, and (with
    watchdog_abort) returns early so a supervisor can re-shard."""
    x, y, xv, yv = _tiny_problem()
    model = SimplexGP(CFG)
    # warm the 2-step window first so compile time doesn't set the median
    inj = FaultInjector([FaultEvent(site="fit_step", kind="slow", at=5,
                                    seconds=1.0, note="wedge")])
    wd = StepWatchdog(window=2, multiplier=2.0, min_deadline=0.3)
    res = train_mod.fit(model, x, y, x_val=xv, y_val=yv, epochs=8,
                        ckpt_dir=str(tmp_path), ckpt_every=100,
                        faults=inj, watchdog=wd, watchdog_abort=True)
    assert res.report.interrupted == "watchdog_breach"
    assert len(res.report.watchdog_breaches) == 1
    breach_epoch = res.report.watchdog_breaches[0]["epoch"]
    assert res.history[-1]["epoch"] == breach_epoch
    # the breach epoch is durable DESPITE ckpt_every=100
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_valid_step() == breach_epoch
    # and a resumed fit continues from it to completion
    cont = train_mod.fit(model, x, y, x_val=xv, y_val=yv, epochs=8,
                         ckpt_dir=str(tmp_path), ckpt_every=100,
                         resume=True)
    assert cont.report.resumed_from_epoch == breach_epoch
    assert cont.history[-1]["epoch"] == 7


def test_elastic_trainer_crash_resume(tmp_path):
    """The in-process supervisor: an injected crash falls back to the
    last durable checkpoint and the run still completes."""
    x, y, xv, yv = _tiny_problem()
    model = SimplexGP(CFG)
    inj = FaultInjector([FaultEvent(site="fit", kind="exception", at=4,
                                    note="crash")])
    t = ElasticGPTrainer(model, x, y, x_val=xv, y_val=yv,
                         ckpt_dir=str(tmp_path), epochs=6, ckpt_every=2,
                         faults=inj)
    rep = t.run()
    assert rep.restarts == 1
    assert rep.events[0]["kind"] == "crash"
    assert rep.result.history[-1]["epoch"] == 5
    # the crash cost at most ckpt_every epochs of progress
    assert rep.result.report.resumed_from_epoch >= 3 - 2


# -- subprocess: REAL mesh sizes ---------------------------------------------

ROUNDTRIP = textwrap.dedent("""
    import json, tempfile
    import jax, numpy as np
    from repro.gp.models import GPParams
    from repro.runtime.checkpoint import CheckpointManager
    from repro.runtime import elastic
    from repro.launch.elastic_gp import params_digest

    devs = jax.devices()
    tree = {"params": GPParams.init(3), "key": jax.random.PRNGKey(7)}
    d0 = params_digest(tree)
    tmpl = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        tree)
    out = {"devices": jax.device_count(), "chain": []}
    with tempfile.TemporaryDirectory() as td:
        m = CheckpointManager(td, keep_last=8)
        m.save(0, tree, metric=0.0, extra={}); m.wait()
        step = 0
        for k in (len(devs) // 2, 1, len(devs)):
            # restore onto a k-device mesh, then re-save FROM that mesh:
            # the next restore exercises a save-on-k/restore-on-k' pair
            t2, s, _, mesh = elastic.resume_gp(m, tmpl, devices=devs[:k])
            out["chain"].append({"k": k, "from_step": s,
                                 "bit_identical": params_digest(t2) == d0,
                                 "axis": int(mesh.shape["data"])})
            step += 1
            m.save(step, t2, metric=0.0, extra={}); m.wait()
    print(json.dumps(out))
""")


CACHE_STALENESS = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.core.filtering import LatticeCache
    from repro.gp import GPParams, SimplexGP, SimplexGPConfig

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(120, 2)), jnp.float32)
    model = SimplexGP(SimplexGPConfig(kernel="matern32"))
    params = GPParams.init(2)
    devs = jax.devices()
    m8 = Mesh(np.array(devs), ("data",))
    m4 = Mesh(np.array(devs[: len(devs) // 2]), ("data",))
    cache = LatticeCache()
    # "training on the full mesh": the operator builds through the cache
    model.operator(params, x, cache=cache, mesh=m8)
    after_full = (cache.misses, cache.hits)
    # "resume on half the mesh": MUST miss (a lattice keyed to the old
    # layout is stale) and rebuild
    model.operator(params, x, cache=cache, mesh=m4)
    after_shrink = (cache.misses, cache.hits)
    # steady state on the new mesh: hits
    model.operator(params, x, cache=cache, mesh=m4)
    print(json.dumps({"devices": jax.device_count(),
                      "after_full": after_full,
                      "after_shrink": after_shrink,
                      "final": (cache.misses, cache.hits)}))
""")


@pytest.mark.elastic
@pytest.mark.multidevice
def test_ckpt_roundtrip_8_4_1_8_subprocess(multidevice_run):
    """Checkpoint round-trip across real mesh sizes: 8 -> 4 -> 1 -> 8,
    params bit-identical after every re-shard."""
    data = multidevice_run(ROUNDTRIP)
    assert data["devices"] == 8
    assert [c["k"] for c in data["chain"]] == [4, 1, 8]
    for c in data["chain"]:
        assert c["bit_identical"], c
        assert c["axis"] == c["k"]


@pytest.mark.elastic
@pytest.mark.multidevice
def test_cache_staleness_8_to_4_subprocess(multidevice_run):
    """Resuming 8 -> 4 devices must never serve the 8-device lattice:
    the cache misses and rebuilds, then serves the new entry."""
    data = multidevice_run(CACHE_STALENESS)
    assert data["devices"] == 8
    assert tuple(data["after_full"]) == (1, 0)
    assert tuple(data["after_shrink"]) == (2, 0)  # miss: stale layout
    assert tuple(data["final"]) == (2, 1)  # steady state on new mesh


# -- subprocess: true device loss (worker lives) -----------------------------

def _run_life(spec: dict, devices: int, timeout: int = 600):
    """One elastic_gp worker life under ``devices`` virtual CPUs."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count={devices}").strip()
    extra = env.get("PYTHONPATH")
    env["PYTHONPATH"] = SRC + (os.pathsep + extra if extra else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.elastic_gp", "--worker",
         json.dumps(spec)],
        env=env, capture_output=True, text=True, timeout=timeout)
    report = None
    if proc.returncode == 0:
        report = json.loads(proc.stdout.strip().splitlines()[-1])
    else:
        assert proc.returncode == 17, proc.stderr[-3000:]
    return proc.returncode, report


@pytest.mark.elastic
def test_kill_on_full_mesh_resume_on_half(tmp_path):
    """True device loss: a life killed at a scripted epoch on the full
    mesh resumes on HALF the devices, losing <= ckpt_every epochs —
    across a data size the smaller mesh does not divide evenly."""
    full, half = BASE_DEVICES, max(1, BASE_DEVICES // 2)
    spec = {"ckpt_dir": str(tmp_path), "seed": 1, "n": 90, "d": 2,
            "n_val": 24, "epochs": 8, "ckpt_every": 2,
            "max_cg_iters": 30, "num_probes": 2}
    # dies at epoch 5: epochs 0..4 completed, checkpoints at 1/3 -> the
    # resume restores 3 and loses exactly 1 completed epoch (<= 2)
    code, _ = _run_life(
        dict(spec, faults=[{"site": "fit", "kind": "kill", "at": 6}]),
        devices=full)
    assert code == 17
    code, rep = _run_life(spec, devices=half)
    assert code == 0
    assert rep["devices"] == half and rep["visible_devices"] == half
    assert rep["resumed_from_epoch"] == 3
    lost = 4 - rep["resumed_from_epoch"]
    assert 0 <= lost <= spec["ckpt_every"]
    assert rep["last_epoch"] == 7 and rep["interrupted"] is None
    assert np.isfinite(rep["final_mll"])
