"""Simplex-GP MVM vs the dense oracle (paper §3.1/§4.2; Fig 4 regime)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import filtering, kernels_math as km
from repro.core.lattice import build_lattice
from repro.core.stencil import make_stencil


def _data(rng, n, d, c=2):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    return x, v


def cosine_err(a, b):
    return 1.0 - float(jnp.vdot(a, b)
                       / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))


@pytest.mark.parametrize("d", [2, 4, 8])
@pytest.mark.parametrize("kernel", ["rbf", "matern32"])
def test_forward_matches_dense_oracle(rng, d, kernel):
    """Fig-4 regime: cosine error 1e-3..1e-1 at r=1.

    RBF is exactly separable across lattice directions, so it stays tight
    at high d; Matern is not, and its error grows with d (the paper's own
    Fig 4 spans up to ~1e-1)."""
    x, v = _data(rng, 500, d)
    st = make_stencil(kernel, 1)
    mv, lat = filtering.mvm_operator(x, st)
    ref = km.dense_mvm(km.get_profile(kernel), x, v)
    limit = 6e-2 if (kernel == "rbf" or d <= 4) else 2e-1
    assert cosine_err(mv(v), ref) < limit
    assert not bool(lat.overflow)


def test_order_tradeoff_not_monotone_claim(rng):
    """Fig 4's observation: higher r does not always reduce the error
    (blur truncation interacts with spacing) — but errors stay in the
    same decade."""
    x, v = _data(rng, 400, 3)
    errs = []
    for r in (1, 2, 3):
        st = make_stencil("rbf", r)
        mv, _ = filtering.mvm_operator(x, st)
        errs.append(cosine_err(mv(v), km.dense_mvm(km.RBF, x, v)))
    assert max(errs) < 10 * min(errs)
    assert max(errs) < 1e-1


def test_symmetrized_operator_is_symmetric(rng):
    x, _ = _data(rng, 300, 3)
    st = make_stencil("matern32", 1)
    mv, _ = filtering.mvm_operator(x, st, symmetrize=True)
    u = jnp.asarray(np.random.default_rng(1).normal(size=(300, 1)),
                    jnp.float32)
    w = jnp.asarray(np.random.default_rng(2).normal(size=(300, 1)),
                    jnp.float32)
    lhs = float(jnp.vdot(w, mv(u)))
    rhs = float(jnp.vdot(u, mv(w)))
    assert abs(lhs - rhs) < 1e-3 * max(abs(lhs), 1.0)


def test_transpose_operator(rng):
    """filter_mvm_t is the exact adjoint of filter_mvm (unsymmetrized)."""
    x, _ = _data(rng, 250, 4)
    st = make_stencil("rbf", 1)
    lat = build_lattice(x, spacing=st.spacing, r=1)
    w = jnp.asarray(st.weights, jnp.float32)
    u = jnp.asarray(np.random.default_rng(3).normal(size=(250, 2)),
                    jnp.float32)
    v = jnp.asarray(np.random.default_rng(4).normal(size=(250, 2)),
                    jnp.float32)
    fu = filtering.filter_mvm(lat, u, w, symmetrize=False)
    ftv = filtering.filter_mvm_t(lat, v, w, symmetrize=False)
    np.testing.assert_allclose(float(jnp.vdot(v, fu)),
                               float(jnp.vdot(u, ftv)), rtol=1e-4)


@pytest.mark.parametrize("kernel,r", [("rbf", 1), ("matern32", 2)])
def test_custom_vjp_dv_is_transpose(rng, kernel, r):
    """dL/dv through the custom VJP == F^T g exactly."""
    x, v = _data(rng, 200, 3)
    g = jnp.asarray(rng.normal(size=v.shape), jnp.float32)
    st = make_stencil(kernel, r)
    spec = filtering.spec_for(st)
    w = jnp.asarray(st.weights, jnp.float32)
    dw = jnp.asarray(st.dweights, jnp.float32)
    _, vjp = jax.vjp(lambda vv: filtering.lattice_filter(x, vv, w, dw,
                                                         spec), v)
    (dv,) = vjp(g)
    lat = build_lattice(x, spacing=st.spacing, r=r)
    want = filtering.filter_mvm_t(lat, g, w, symmetrize=spec.symmetrize)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("kernel", ["rbf", "matern32"])
def test_paper_gradient_direction(rng, kernel):
    """§4.2 input-space gradient aligns with the dense-oracle gradient."""
    n, d, c = 300, 3, 2
    x, v = _data(rng, n, d, c)
    g = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    st = make_stencil(kernel, 2)
    spec = filtering.spec_for(st)
    w = jnp.asarray(st.weights, jnp.float32)
    dw = jnp.asarray(st.dweights, jnp.float32)
    dz = jax.grad(lambda z: jnp.vdot(
        g, filtering.lattice_filter(z, v, w, dw, spec)))(x)
    dz_ref = km.dense_grad_x(km.get_profile(kernel), x, v, g)
    cos = float(jnp.vdot(dz, dz_ref)
                / (jnp.linalg.norm(dz) * jnp.linalg.norm(dz_ref)))
    assert cos > 0.9


def test_autodiff_through_barycentric_weights(rng):
    """Beyond-paper grad mode: autodiff through the lattice operator runs
    and produces finite, nonzero gradients."""
    x, v = _data(rng, 200, 3)
    st = make_stencil("rbf", 1)
    w = jnp.asarray(st.weights, jnp.float32)

    def f(z):
        lat = build_lattice(z, spacing=st.spacing, r=1)
        return jnp.sum(filtering.filter_mvm(lat, v, w) ** 2)

    dz = jax.grad(f)(x)
    assert bool(jnp.all(jnp.isfinite(dz)))
    assert float(jnp.linalg.norm(dz)) > 0


def test_pallas_blur_path_matches_default(rng):
    x, v = _data(rng, 200, 3)
    st = make_stencil("rbf", 1)
    lat = build_lattice(x, spacing=st.spacing, r=1)
    w = jnp.asarray(st.weights, jnp.float32)
    a = filtering.filter_mvm(lat, v, w, backend="xla")
    b = filtering.filter_mvm(lat, v, w, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("backend", ["xla", "fused_xla",
                                     "per_direction_pallas"])
def test_backend_tiers_agree(rng, backend):
    """Every dispatch tier computes the same operator (f32 noise apart)."""
    x, v = _data(rng, 250, 4)
    st = make_stencil("matern32", 1)
    lat = build_lattice(x, spacing=st.spacing, r=1)
    w = jnp.asarray(st.weights, jnp.float32)
    want = filtering.filter_mvm(lat, v, w, backend="xla")
    got = filtering.filter_mvm(lat, v, w, backend=backend,
                               taps=tuple(st.weights))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_filter_mvm_traced_weights_under_jit(rng):
    """Regression: traced weights under jit must not crash any backend
    resolution — the seed's use_pallas path called float() on tracers."""
    x, v = _data(rng, 150, 3)
    st = make_stencil("rbf", 1)
    lat = build_lattice(x, spacing=st.spacing, r=1)
    w = jnp.asarray(st.weights, jnp.float32)

    # auto: falls back to a taps-free tier instead of crashing
    got = jax.jit(lambda ww, vv: filtering.filter_mvm(lat, vv, ww))(w, v)
    want = filtering.filter_mvm(lat, v, w, backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)

    # concrete taps via FilterSpec keep the Pallas tiers jit-compatible
    got2 = jax.jit(lambda ww, vv: filtering.filter_mvm(
        lat, vv, ww, use_pallas=True, taps=tuple(st.weights)))(w, v)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want),
                               rtol=1e-4, atol=1e-5)

    # a Pallas tier with ONLY traced weights is a loud error, not a crash
    with pytest.raises(ValueError, match="concrete stencil taps"):
        jax.jit(lambda ww, vv: filtering.filter_mvm(
            lat, vv, ww, backend="per_direction_pallas"))(w, v)


def test_lattice_filter_with_matches_rebuild(rng):
    """Shared-lattice entry point == rebuild-per-call: values AND §4.2
    grads (acceptance: max abs err <= 1e-6; in fact bit-identical, since
    the build is deterministic)."""
    x, v = _data(rng, 250, 3)
    g = jnp.asarray(rng.normal(size=v.shape), jnp.float32)
    st = make_stencil("matern32", 1)
    spec = filtering.spec_for(st)
    w = jnp.asarray(st.weights, jnp.float32)
    dw = jnp.asarray(st.dweights, jnp.float32)
    lat = build_lattice(x, spacing=st.spacing, r=st.r)

    a = filtering.lattice_filter(x, v, w, dw, spec)
    b = filtering.lattice_filter_with(lat, x, v, w, dw, spec)
    assert float(jnp.max(jnp.abs(a - b))) <= 1e-6

    f_re = lambda z, vv: jnp.vdot(g, filtering.lattice_filter(
        z, vv, w, dw, spec))
    f_sh = lambda z, vv: jnp.vdot(g, filtering.lattice_filter_with(
        lat, z, vv, w, dw, spec))
    dz_re, dv_re = jax.grad(f_re, argnums=(0, 1))(x, v)
    dz_sh, dv_sh = jax.grad(f_sh, argnums=(0, 1))(x, v)
    np.testing.assert_allclose(np.asarray(dz_sh), np.asarray(dz_re),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dv_sh), np.asarray(dv_re),
                               rtol=0, atol=1e-6)


def test_lattice_filter_with_jit_traced_lattice(rng):
    """The prebuilt-lattice VJP works with the lattice as a traced pytree
    (the in-jit training-step usage) and performs zero builds."""
    from repro.core.lattice import build_count

    x, v = _data(rng, 150, 3)
    g = jnp.asarray(rng.normal(size=v.shape), jnp.float32)
    st = make_stencil("rbf", 1)
    spec = filtering.spec_for(st)
    w = jnp.asarray(st.weights, jnp.float32)
    dw = jnp.asarray(st.dweights, jnp.float32)
    lat = build_lattice(x, spacing=st.spacing, r=st.r)

    @jax.jit
    def grad_z(lt, z, vv):
        return jax.grad(lambda zz: jnp.vdot(g, filtering.lattice_filter_with(
            lt, zz, vv, w, dw, spec)))(z)

    c0 = build_count()
    dz = grad_z(lat, x, v)
    assert build_count() - c0 == 0
    want = jax.grad(lambda zz: jnp.vdot(g, filtering.lattice_filter(
        zz, v, w, dw, spec)))(x)
    np.testing.assert_allclose(np.asarray(dz), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_lattice_cache_reuses_builds(rng):
    """Same concrete (point set, lengthscale, spacing, r, cap) -> one build;
    any key change -> fresh build; traced inputs bypass the memo."""
    from repro.core.lattice import build_count

    x, _ = _data(rng, 120, 3)
    st = make_stencil("rbf", 1)
    cache = filtering.LatticeCache()
    tag = cache.point_set_tag(x)
    ls = jnp.ones((3,), jnp.float32)

    c0 = build_count()
    l1 = cache.get(tag, x, spacing=st.spacing, r=st.r, cap=None, ls=ls)
    l2 = cache.get(tag, x, spacing=st.spacing, r=st.r, cap=None, ls=ls)
    assert l1 is l2
    assert build_count() - c0 == 1
    assert cache.hits == 1 and cache.misses == 1

    # lengthscale moved -> rebuild
    l3 = cache.get(tag, x, spacing=st.spacing, r=st.r, cap=None,
                   ls=2.0 * ls)
    assert l3 is not l1
    assert build_count() - c0 == 2

    # traced lengthscale -> bypass (fresh build, nothing cached)
    jax.jit(lambda s: cache.get(tag, x, spacing=st.spacing, r=st.r,
                                cap=None, ls=s).weights)(ls)
    assert cache.misses == 2  # unchanged by the traced call

    # traced points -> tag is None -> bypass (no crash under jit)
    jax.jit(lambda xx: cache.get(cache.point_set_tag(xx), xx,
                                 spacing=st.spacing, r=st.r, cap=None,
                                 ls=ls).weights)(x)
    assert cache.misses == 2

    # row order matters: the lattice's seg_ids/splat plan are
    # order-dependent, so a permuted point set must NOT hit the cache
    perm = x[::-1]
    assert cache.point_set_tag(perm) != tag
    l4 = cache.get(cache.point_set_tag(perm), perm, spacing=st.spacing,
                   r=st.r, cap=None, ls=ls)
    assert l4 is not l1


def test_lattice_cache_keys_on_sharding_layout(rng):
    """Regression (PR 3): the cache fingerprint includes the device/
    sharding layout, so a lattice built from a mesh-sharded ``z`` never
    aliases the unsharded build of the same bytes (the built arrays
    inherit z's placement — serving the wrong one silently resharded
    every MVM)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    x, _ = _data(rng, 64, 2)
    st = make_stencil("rbf", 1)
    cache = filtering.LatticeCache()
    tag = cache.point_set_tag(x)
    ls = jnp.ones((2,), jnp.float32)

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    x_sharded = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    assert cache.point_set_tag(x_sharded) == tag  # same bytes, same tag
    assert (cache.layout_key(x_sharded) != cache.layout_key(x))

    l1 = cache.get(tag, x, spacing=st.spacing, r=st.r, cap=None, ls=ls)
    l2 = cache.get(tag, x_sharded, spacing=st.spacing, r=st.r, cap=None,
                   ls=ls)
    assert l2 is not l1  # layout differs -> distinct cache entries
    assert cache.misses == 2 and cache.hits == 0
    # and each layout still hits its own entry
    assert cache.get(tag, x, spacing=st.spacing, r=st.r, cap=None,
                     ls=ls) is l1
    assert cache.get(tag, x_sharded, spacing=st.spacing, r=st.r, cap=None,
                     ls=ls) is l2
    assert cache.hits == 2


def test_mvm_operator_auto_cap_and_backends(rng):
    """auto_cap right-sizes the table; fused backend matches the default."""
    from repro.core.lattice import default_capacity, suggest_capacity

    x, v = _data(rng, 300, 4, c=1)
    st = make_stencil("matern32", 1)
    mv, lat = filtering.mvm_operator(x, st, auto_cap=True)
    assert not bool(lat.overflow)
    assert lat.cap < default_capacity(300, 4)
    assert lat.cap >= int(lat.m)
    assert suggest_capacity(300, 4, st.spacing) <= default_capacity(300, 4)
    mv_ref, lat_ref = filtering.mvm_operator(x, st, backend="xla")
    np.testing.assert_allclose(np.asarray(mv(v)), np.asarray(mv_ref(v)),
                               rtol=1e-4, atol=1e-5)


def test_grow_and_retry_recovers_from_overflow(rng):
    """build_lattice_auto grows past an undersized initial capacity."""
    from repro.core.lattice import build_lattice_auto

    x = jnp.asarray(rng.normal(size=(400, 3)) * 4.0, jnp.float32)
    lat = build_lattice_auto(x, spacing=0.5, r=1, cap=16)
    assert not bool(lat.overflow)
    assert lat.cap >= int(lat.m)


def test_suggest_capacity_vmem_aware_rounding():
    """Regression: the power-of-two rounding must not silently pick a cap
    that defeats ``fits_vmem`` when the unrounded occupancy guess fits —
    that spill cost the fused-MVM tier for no occupancy benefit."""
    from repro.core.lattice import default_capacity, suggest_capacity
    from repro.kernels.blur.ops import fits_vmem, max_cap_for_vmem

    # find a size where the raw guess fits the fused VMEM plan but its
    # power-of-two round-up does not (exists: the plan is linear in cap)
    found = None
    for n in range(20000, 70000, 500):
        for d in (4, 8):
            guess = max(1024, int(n * (d + 1) / 8.0))
            pow2 = min(1 << (guess - 1).bit_length(), default_capacity(n, d))
            if fits_vmem(n, d, 1, guess + 1, 1) and \
                    not fits_vmem(n, d, 1, pow2 + 1, 1):
                found = (n, d, guess, pow2)
                break
        if found:
            break
    assert found is not None, "no spill-prone size in scan range"
    n, d, guess, pow2 = found

    cap = suggest_capacity(n, d, 1.0, r=1, c=1)
    assert cap < pow2  # the naive round-up was rejected
    assert cap >= guess  # never below the occupancy guess
    assert fits_vmem(n, d, 1, cap + 1, 1)  # and the clamp actually fits
    # the clamp target is exactly the largest fitting capacity
    assert fits_vmem(n, d, 1, max_cap_for_vmem(n, d, 1, 1) + 1, 1)
    assert not fits_vmem(n, d, 1, max_cap_for_vmem(n, d, 1, 1) + 2, 1)
    # opting out restores the plain power-of-two suggestion
    assert suggest_capacity(n, d, 1.0, r=1, c=1, vmem_aware=False) == pow2
    # a guess that itself spills is returned un-clamped (occupancy first)
    big_n = 200000
    cap_big = suggest_capacity(big_n, 8, 1.0, r=1, c=1)
    assert cap_big == min(1 << (max(1024, int(big_n * 9 / 8.0))
                                - 1).bit_length(),
                          default_capacity(big_n, 8))


def test_estimate_m_exact_on_full_sample(rng):
    """With sample >= n the estimator degenerates to an exact count."""
    from repro.core.lattice import build_lattice_auto, estimate_m

    z = jnp.asarray(rng.normal(size=(400, 3)), jnp.float32)
    lat = build_lattice_auto(z, spacing=1.0, r=1)
    assert estimate_m(z, 1.0, sample=400) == int(lat.m)


def test_estimate_m_multiscale_no_severe_underestimate(rng):
    """Regression for the 2-point estimator's multi-scale failure: tight
    clusters saturate small subsamples, so the single average slope
    underestimated m and the resulting cap paid a grow-and-retry
    rebuild. The 3-point fit's monotonicity check (convex log-log growth
    -> trust the tail slope) must keep the estimate near the true m."""
    from repro.core.lattice import build_lattice_auto, estimate_m

    n, d = 4000, 3
    n_bg = n // 10  # sparse background carries most distinct vertices
    z = np.concatenate([rng.normal(size=(n - n_bg, d)) * 0.05,
                        rng.normal(size=(n_bg, d)) * 20.0])
    z = jnp.asarray(z[rng.permutation(n)], jnp.float32)
    m = int(build_lattice_auto(z, spacing=1.0, r=1).m)
    assert estimate_m(z, 1.0, sample=512) >= 0.55 * m
    assert estimate_m(z, 1.0, sample=1024) >= 0.8 * m
    # ... without wrecking the uniform case with overestimates
    z2 = jnp.asarray(rng.normal(size=(n, d)) * 3.0, jnp.float32)
    m2 = int(build_lattice_auto(z2, spacing=1.0, r=1).m)
    assert estimate_m(z2, 1.0, sample=512) <= 3.0 * m2


def test_suggest_capacity_data_aware_tightens(rng):
    """The subsample-insert estimate right-sizes the cap on clustered
    data (where the constant-occupancy guess over-allocates heavily) and
    still covers the true m; the blind guess is unchanged without z."""
    from repro.core.lattice import (build_lattice_auto, default_capacity,
                                    suggest_capacity)

    n, d = 2000, 4
    # tightly clustered: very few occupied lattice points
    z = jnp.asarray(rng.normal(size=(n, d)) * 0.05, jnp.float32)
    lat = build_lattice_auto(z, spacing=1.0, r=1)
    m = int(lat.m)
    cap_blind = suggest_capacity(n, d, 1.0)
    cap_data = suggest_capacity(n, d, 1.0, z=z)
    assert m <= cap_data <= cap_blind
    assert cap_data < cap_blind  # actually tighter on this data
    assert cap_data <= default_capacity(n, d)
    # auto build (which now threads z through) lands on the tight cap
    assert lat.cap == cap_data
    assert not bool(lat.overflow)


def test_suggest_capacity_data_aware_underestimate_recovers(rng):
    """A low estimate is harmless: build_lattice_auto's grow-and-retry
    catches the overflow. (Sparse data where a small subsample badly
    under-predicts fresh-vertex growth.)"""
    from repro.core.lattice import build_lattice_auto

    z = jnp.asarray(rng.normal(size=(3000, 4)) * 3.0, jnp.float32)
    lat = build_lattice_auto(z, spacing=0.5, r=1)
    assert not bool(lat.overflow)
    assert lat.cap >= int(lat.m)
