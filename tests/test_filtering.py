"""Simplex-GP MVM vs the dense oracle (paper §3.1/§4.2; Fig 4 regime)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import filtering, kernels_math as km
from repro.core.lattice import build_lattice
from repro.core.stencil import make_stencil


def _data(rng, n, d, c=2):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    return x, v


def cosine_err(a, b):
    return 1.0 - float(jnp.vdot(a, b)
                       / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))


@pytest.mark.parametrize("d", [2, 4, 8])
@pytest.mark.parametrize("kernel", ["rbf", "matern32"])
def test_forward_matches_dense_oracle(rng, d, kernel):
    """Fig-4 regime: cosine error 1e-3..1e-1 at r=1.

    RBF is exactly separable across lattice directions, so it stays tight
    at high d; Matern is not, and its error grows with d (the paper's own
    Fig 4 spans up to ~1e-1)."""
    x, v = _data(rng, 500, d)
    st = make_stencil(kernel, 1)
    mv, lat = filtering.mvm_operator(x, st)
    ref = km.dense_mvm(km.get_profile(kernel), x, v)
    limit = 6e-2 if (kernel == "rbf" or d <= 4) else 2e-1
    assert cosine_err(mv(v), ref) < limit
    assert not bool(lat.overflow)


def test_order_tradeoff_not_monotone_claim(rng):
    """Fig 4's observation: higher r does not always reduce the error
    (blur truncation interacts with spacing) — but errors stay in the
    same decade."""
    x, v = _data(rng, 400, 3)
    errs = []
    for r in (1, 2, 3):
        st = make_stencil("rbf", r)
        mv, _ = filtering.mvm_operator(x, st)
        errs.append(cosine_err(mv(v), km.dense_mvm(km.RBF, x, v)))
    assert max(errs) < 10 * min(errs)
    assert max(errs) < 1e-1


def test_symmetrized_operator_is_symmetric(rng):
    x, _ = _data(rng, 300, 3)
    st = make_stencil("matern32", 1)
    mv, _ = filtering.mvm_operator(x, st, symmetrize=True)
    u = jnp.asarray(np.random.default_rng(1).normal(size=(300, 1)),
                    jnp.float32)
    w = jnp.asarray(np.random.default_rng(2).normal(size=(300, 1)),
                    jnp.float32)
    lhs = float(jnp.vdot(w, mv(u)))
    rhs = float(jnp.vdot(u, mv(w)))
    assert abs(lhs - rhs) < 1e-3 * max(abs(lhs), 1.0)


def test_transpose_operator(rng):
    """filter_mvm_t is the exact adjoint of filter_mvm (unsymmetrized)."""
    x, _ = _data(rng, 250, 4)
    st = make_stencil("rbf", 1)
    lat = build_lattice(x, spacing=st.spacing, r=1)
    w = jnp.asarray(st.weights, jnp.float32)
    u = jnp.asarray(np.random.default_rng(3).normal(size=(250, 2)),
                    jnp.float32)
    v = jnp.asarray(np.random.default_rng(4).normal(size=(250, 2)),
                    jnp.float32)
    fu = filtering.filter_mvm(lat, u, w, symmetrize=False)
    ftv = filtering.filter_mvm_t(lat, v, w, symmetrize=False)
    np.testing.assert_allclose(float(jnp.vdot(v, fu)),
                               float(jnp.vdot(u, ftv)), rtol=1e-4)


@pytest.mark.parametrize("kernel,r", [("rbf", 1), ("matern32", 2)])
def test_custom_vjp_dv_is_transpose(rng, kernel, r):
    """dL/dv through the custom VJP == F^T g exactly."""
    x, v = _data(rng, 200, 3)
    g = jnp.asarray(rng.normal(size=v.shape), jnp.float32)
    st = make_stencil(kernel, r)
    spec = filtering.spec_for(st)
    w = jnp.asarray(st.weights, jnp.float32)
    dw = jnp.asarray(st.dweights, jnp.float32)
    _, vjp = jax.vjp(lambda vv: filtering.lattice_filter(x, vv, w, dw,
                                                         spec), v)
    (dv,) = vjp(g)
    lat = build_lattice(x, spacing=st.spacing, r=r)
    want = filtering.filter_mvm_t(lat, g, w, symmetrize=spec.symmetrize)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("kernel", ["rbf", "matern32"])
def test_paper_gradient_direction(rng, kernel):
    """§4.2 input-space gradient aligns with the dense-oracle gradient."""
    n, d, c = 300, 3, 2
    x, v = _data(rng, n, d, c)
    g = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    st = make_stencil(kernel, 2)
    spec = filtering.spec_for(st)
    w = jnp.asarray(st.weights, jnp.float32)
    dw = jnp.asarray(st.dweights, jnp.float32)
    dz = jax.grad(lambda z: jnp.vdot(
        g, filtering.lattice_filter(z, v, w, dw, spec)))(x)
    dz_ref = km.dense_grad_x(km.get_profile(kernel), x, v, g)
    cos = float(jnp.vdot(dz, dz_ref)
                / (jnp.linalg.norm(dz) * jnp.linalg.norm(dz_ref)))
    assert cos > 0.9


def test_autodiff_through_barycentric_weights(rng):
    """Beyond-paper grad mode: autodiff through the lattice operator runs
    and produces finite, nonzero gradients."""
    x, v = _data(rng, 200, 3)
    st = make_stencil("rbf", 1)
    w = jnp.asarray(st.weights, jnp.float32)

    def f(z):
        lat = build_lattice(z, spacing=st.spacing, r=1)
        return jnp.sum(filtering.filter_mvm(lat, v, w) ** 2)

    dz = jax.grad(f)(x)
    assert bool(jnp.all(jnp.isfinite(dz)))
    assert float(jnp.linalg.norm(dz)) > 0


def test_pallas_blur_path_matches_default(rng):
    x, v = _data(rng, 200, 3)
    st = make_stencil("rbf", 1)
    lat = build_lattice(x, spacing=st.spacing, r=1)
    w = jnp.asarray(st.weights, jnp.float32)
    a = filtering.filter_mvm(lat, v, w, use_pallas=False)
    b = filtering.filter_mvm(lat, v, w, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)
