"""Property tests for permutohedral-lattice invariants (hypothesis-style).

Uses tests/_hyp_compat (real hypothesis when installed, deterministic
replay otherwise). Four families from the build's contract:

  * barycentric weights are a valid simplex point (nonneg, sum to 1) and
    the vertex keys live on the lattice plane (coords sum to 0 mod d+1);
  * the dedup/build is permutation-invariant over input rows: the deduped
    point SET and the filtering OPERATOR commute with row permutations;
  * the 16-bit key packing round-trips exactly within its documented
    range (the last coordinate is recovered from the zero-sum constraint);
  * adversarial inputs raise the overflow/pack_overflow FLAGS instead of
    silently corrupting the table.
"""
import sys
import os

sys.path.insert(0, os.path.dirname(__file__))

import jax.numpy as jnp
import numpy as np

from _hyp_compat import given, settings, st
from repro.core import lattice as lat_mod
from repro.core.stencil import make_stencil


def _points(seed, n, d, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(scale * rng.normal(size=(n, d)), jnp.float32)


@settings(max_examples=15)
@given(d=st.integers(1, 6), seed=st.integers(0, 10_000),
       scale=st.floats(0.05, 20.0))
def test_barycentric_weights_are_simplex_point(d, seed, scale):
    z = _points(seed, 40, d, scale)
    keys, w = lat_mod.simplex_embed(z, spacing=1.0)
    w = np.asarray(w)
    assert np.all(w >= -1e-4), w.min()
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-4)
    # vertex keys live on the lattice plane: coords sum to zero
    sums = np.asarray(keys).sum(axis=-1)
    assert np.all(sums == 0), np.unique(sums)


@settings(max_examples=10)
@given(d=st.integers(1, 5), seed=st.integers(0, 10_000))
def test_build_is_permutation_invariant(d, seed):
    """Permuting input rows permutes the operator: the deduped point set
    is identical and F(P v) == P F(v) (the lattice has no row-order
    dependence beyond the per-point bookkeeping)."""
    rng = np.random.default_rng(seed)
    n = 48
    z = _points(seed, n, d)
    perm = jnp.asarray(rng.permutation(n))
    st_ = make_stencil("matern32", 1)
    lat = lat_mod.build_lattice(z, spacing=st_.spacing, r=st_.r)
    lat_p = lat_mod.build_lattice(z[perm], spacing=st_.spacing, r=st_.r)

    assert int(lat.m) == int(lat_p.m)
    coords = np.asarray(lat.coords)[np.asarray(lat.valid)]
    coords_p = np.asarray(lat_p.coords)[np.asarray(lat_p.valid)]
    as_set = lambda c: set(map(tuple, c.tolist()))
    assert as_set(coords) == as_set(coords_p)

    v = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    w = jnp.asarray(st_.weights, jnp.float32)
    from repro.kernels.blur.ops import lattice_mvm
    out = lattice_mvm(lat, v, w, backend="xla")
    out_p = lattice_mvm(lat_p, v[perm], w, backend="xla")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out)[perm],
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=20)
@given(d=st.integers(1, 8), seed=st.integers(0, 10_000),
       magnitude=st.integers(1, lat_mod._PACK_LIMIT))
def test_unpack_key_cols_roundtrip(d, seed, magnitude):
    """_unpack_key_cols is the exact inverse of _pack_key_cols within the
    +/- 2^15 - 2 range, for any coordinate count (odd and even packing)."""
    rng = np.random.default_rng(seed)
    c = d + 1
    rest = rng.integers(-magnitude, magnitude + 1, size=(32, d))
    keys = np.concatenate([rest, -rest.sum(axis=1, keepdims=True)], axis=1)
    packed = jnp.stack(lat_mod._pack_key_cols(jnp.asarray(keys, jnp.int32)),
                       axis=1)
    back = lat_mod._unpack_key_cols(packed, c)
    np.testing.assert_array_equal(np.asarray(back), keys)


@settings(max_examples=10)
@given(seed=st.integers(0, 10_000), d=st.integers(1, 4))
def test_capacity_overflow_flag_fires(seed, d):
    """More unique lattice points than cap -> overflow set, pack_overflow
    clear, and the table stays structurally sound (dump row exists,
    seg_ids in range) instead of silently corrupting."""
    z = _points(seed, 64, d, scale=30.0)  # spread -> many unique points
    lat = lat_mod.build_lattice(z, spacing=0.5, r=1, cap=4)
    assert bool(lat.overflow)
    assert not bool(lat.pack_overflow)
    seg = np.asarray(lat.seg_ids)
    assert seg.min() >= 0 and seg.max() <= lat.cap
    assert lat.coords.shape == (lat.cap + 1, d + 1)


@settings(max_examples=10)
@given(seed=st.integers(0, 10_000), scale=st.floats(3e4, 3e5))
def test_pack_overflow_flag_fires(seed, scale):
    """Coordinates beyond +/- 2^15 set pack_overflow AND overflow (results
    invalid; growing cap cannot fix it) — the grow-and-retry contract's
    hard stop."""
    z = _points(seed, 16, 2, scale=scale)
    lat = lat_mod.build_lattice(z, spacing=0.5, r=1)
    assert bool(lat.pack_overflow)
    assert bool(lat.overflow)
    # build_lattice_auto must NOT grow its way out of a pack overflow
    lat_auto = lat_mod.build_lattice_auto(z, spacing=0.5, r=1, cap=8)
    assert bool(lat_auto.pack_overflow)
    assert lat_auto.cap <= lat_mod.default_capacity(16, 2)
