"""Stencil discretization tests (paper §4.1, Eq. 9)."""
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core.kernels_math import PROFILES, get_profile
from repro.core.stencil import _coverage_curves, make_stencil, solve_spacing


@pytest.mark.parametrize("name", sorted(PROFILES))
@pytest.mark.parametrize("r", [1, 2, 3])
def test_coverage_balance_at_solution(name, r):
    """Eq. 9: spatial and spectral coverage cross at the solved spacing."""
    profile = get_profile(name)
    s = solve_spacing(profile, r)
    lhs, rhs = _coverage_curves(profile, r)
    assert abs(lhs(s) - rhs(s)) < 1e-6
    # monotonicity around the crossing
    assert lhs(s * 1.1) > lhs(s * 0.9)
    assert rhs(s * 1.1) < rhs(s * 0.9)


@pytest.mark.parametrize("name", sorted(PROFILES))
def test_stencil_structure(name):
    st_ = make_stencil(name, r=2)
    w = np.asarray(st_.weights)
    assert w.shape == (5,)
    assert abs(w[2] - 1.0) < 1e-12  # center tap k(0) = 1
    assert np.all(w[:2] == w[:-3:-1])  # symmetric
    assert np.all(np.diff(w[2:]) <= 0)  # decaying

def test_spacing_shrinks_with_order():
    """More taps -> finer spacing (same coverage split over more points)."""
    s1 = make_stencil("rbf", 1).spacing
    s3 = make_stencil("rbf", 3).spacing
    assert s3 < s1


def test_rbf_derivative_stencil_is_minus_half_forward():
    """For RBF, k' = -k/2 exactly, so dweights == weights, dscale == -1/2."""
    st_ = make_stencil("rbf", 2)
    np.testing.assert_allclose(st_.dweights, st_.weights, rtol=1e-12)
    assert abs(st_.dscale + 0.5) < 1e-12


def test_matern12_gradient_disabled():
    """Matern-1/2 has a cusp at 0: derivative stencil must be disabled."""
    st_ = make_stencil("matern12", 1)
    assert st_.dscale == 0.0


@settings(max_examples=10, deadline=None)
@given(r=st.integers(1, 4),
       name=st.sampled_from(sorted(PROFILES)))
def test_property_weights_bounded(name, r):
    st_ = make_stencil(name, r)
    w = np.asarray(st_.weights)
    assert w.shape == (2 * r + 1,)
    assert np.all(w > 0) and np.all(w <= 1.0 + 1e-12)
    assert st_.spacing > 0
