"""End-to-end Simplex-GP inference tests (paper §5 behaviours)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels_math as km
from repro.core.exact import ExactGP
from repro.gp import (GPParams, SimplexGP, SimplexGPConfig, cross_mvm, fit,
                      mll_value_and_grad, nll, posterior, rmse)
from repro.gp.models import softplus


def _problem(rng, n=600, d=3, noise=0.1):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    f = jnp.sin(2 * x[:, 0]) + 0.5 * jnp.cos(x[:, 1] * (x[:, 2]
                                                        if d > 2 else 1.0))
    y = f + noise * jnp.asarray(rng.normal(size=n), jnp.float32)
    return x, y, f


def test_mll_value_close_to_exact(rng):
    x, y, _ = _problem(rng, n=500)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=80,
                                      num_probes=10, max_lanczos_iters=40))
    params = GPParams.init(3, noise=0.2)
    res = mll_value_and_grad(model, params, x, y, jax.random.PRNGKey(0),
                             tol=1e-3)
    eg = ExactGP(km.MATERN32)
    ls, os_, nz = model.constrained(params)
    want = float(eg.mll(x, y, lengthscale=ls, outputscale=os_, noise=nz))
    # lattice operator approximates K; SLQ adds noise — same decade check
    assert abs(float(res.mll) - want) < 0.45 * abs(want) + 50.0


@pytest.mark.parametrize("grad_mode", ["paper", "autodiff"])
def test_training_improves_validation_rmse(rng, grad_mode):
    x, y, _ = _problem(rng, n=700)
    xv, yv, fv = _problem(np.random.default_rng(7), n=150)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=40,
                                      num_probes=6, grad_mode=grad_mode,
                                      max_lanczos_iters=20))
    res = fit(model, x, y, x_val=xv, y_val=fv, epochs=10, lr=0.1,
              patience=10)
    first = res.history[0]["val_rmse"]
    assert res.best_val_rmse < first  # learning happened


def test_posterior_beats_prior(rng):
    x, y, _ = _problem(rng, n=600)
    xs, ys, fs = _problem(np.random.default_rng(3), n=120)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=60))
    params = GPParams.init(3, noise=0.1, lengthscale=1.0)
    post = posterior(model, params, x, y, xs, key=jax.random.PRNGKey(1))
    pr = float(rmse(post, fs))
    assert pr < float(jnp.std(fs))  # better than predicting the mean
    assert bool(jnp.all(post.var > 0))
    assert np.isfinite(float(nll(post, model.constrained(params)[2], fs)))


def test_cross_mvm_matches_dense(rng):
    x = jnp.asarray(rng.normal(size=(300, 3)), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(80, 3)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(300, 2)), jnp.float32)
    model = SimplexGP(SimplexGPConfig(kernel="rbf"))
    params = GPParams.init(3)
    got = cross_mvm(model, params, x, xs, v)
    ls, os_, _ = model.constrained(params)
    want = km.gram(km.RBF, xs, x, ls, os_) @ v
    cos = float(jnp.vdot(got, want)
                / (jnp.linalg.norm(got) * jnp.linalg.norm(want)))
    assert cos > 0.93


def test_one_lattice_build_per_step_and_posterior(rng):
    """DESIGN.md §9 contract: a jitted training step traces exactly ONE
    lattice build (seed: 3 — operator + two surrogate quad forms), and a
    posterior performs exactly ONE (seed: 3 — operator + two cross_mvm).

    The rebuild-per-call pipeline now traces TWO builds per step (operator
    + the single batched surrogate quad form — the multi-RHS batching of
    DESIGN.md §10 merged the two surrogate terms into one filtering even
    without lattice sharing); its posterior still builds 3 (operator + two
    cross_mvm joint builds)."""
    from repro.core.lattice import build_count

    x, y, _ = _problem(rng, n=300)
    xs, _, _ = _problem(np.random.default_rng(5), n=60)
    params = GPParams.init(3)

    shared = SimplexGP(SimplexGPConfig(max_cg_iters=20, num_probes=4,
                                       max_lanczos_iters=10))
    legacy = SimplexGP(SimplexGPConfig(max_cg_iters=20, num_probes=4,
                                       max_lanczos_iters=10,
                                       shared_lattice=False,
                                       logdet_estimator="slq"))
    for model, want_step, want_post in [(shared, 1, 1), (legacy, 2, 3)]:
        step = jax.jit(lambda p, k, m=model: mll_value_and_grad(
            m, p, x, y, k))
        c0 = build_count()
        jax.block_until_ready(step(params, jax.random.PRNGKey(0)))
        assert build_count() - c0 == want_step

        c0 = build_count()
        post = posterior(model, params, x, y, xs,
                         key=jax.random.PRNGKey(1), variance_rank=8)
        jax.block_until_ready(post.mean)
        assert build_count() - c0 == want_post


def test_shared_lattice_matches_legacy_pipeline(rng):
    """Shared-lattice step == rebuild-per-call step: identical surrogate
    gradients (same lattice values by determinism) and MLL within
    stochastic-estimator noise (different log-det estimators)."""
    x, y, _ = _problem(rng, n=400)
    params = GPParams.init(3, noise=0.2)
    kw = dict(kernel="matern32", max_cg_iters=80, num_probes=8,
              max_lanczos_iters=40)
    shared = SimplexGP(SimplexGPConfig(**kw))
    legacy = SimplexGP(SimplexGPConfig(shared_lattice=False,
                                       logdet_estimator="slq", **kw))
    key = jax.random.PRNGKey(2)
    res_s = mll_value_and_grad(shared, params, x, y, key, tol=1e-4)
    res_l = mll_value_and_grad(legacy, params, x, y, key, tol=1e-4)
    for gs, gl in zip(jax.tree.leaves(res_s.grads),
                      jax.tree.leaves(res_l.grads)):
        np.testing.assert_allclose(np.asarray(gs), np.asarray(gl),
                                   rtol=1e-5, atol=1e-6)
    # same CG solves -> same data-fit term; log-det estimators differ only
    # by probe sets/depth, so values agree to estimator noise
    assert abs(float(res_s.mll) - float(res_l.mll)) < \
        0.05 * abs(float(res_l.mll)) + 20.0


def test_posterior_shared_joint_lattice_close_to_legacy(rng):
    """Single-joint-lattice posterior tracks the rebuild-per-call one (the
    K_XX approximations differ slightly — the joint lattice is denser)."""
    x, y, _ = _problem(rng, n=400)
    xs, _, fs = _problem(np.random.default_rng(9), n=80)
    params = GPParams.init(3, noise=0.1)
    kw = dict(kernel="matern32", max_cg_iters=60)
    shared = SimplexGP(SimplexGPConfig(**kw))
    legacy = SimplexGP(SimplexGPConfig(shared_lattice=False, **kw))
    ps = posterior(shared, params, x, y, xs, key=jax.random.PRNGKey(4))
    pl = posterior(legacy, params, x, y, xs, key=jax.random.PRNGKey(4))
    scale = float(jnp.std(pl.mean)) + 1e-6
    assert float(jnp.max(jnp.abs(ps.mean - pl.mean))) < 0.35 * scale
    assert bool(jnp.all(ps.var > 0))
    # both beat predicting the mean on held-out structure
    assert float(rmse(ps, fs)) < float(jnp.std(fs))


def test_rrcg_training_step_runs(rng):
    x, y, _ = _problem(rng, n=300)
    model = SimplexGP(SimplexGPConfig(kernel="rbf", max_cg_iters=40,
                                      num_probes=4, max_lanczos_iters=15))
    params = GPParams.init(3)
    res = mll_value_and_grad(model, params, x, y, jax.random.PRNGKey(5),
                             use_rrcg=True)
    assert np.isfinite(float(res.mll))
    for leaf in jax.tree.leaves(res.grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_precond_rank_config(rng):
    x, y, _ = _problem(rng, n=250)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=30,
                                      precond_rank=20, num_probes=4,
                                      max_lanczos_iters=10))
    params = GPParams.init(3)
    res = mll_value_and_grad(model, params, x, y, jax.random.PRNGKey(0))
    assert np.isfinite(float(res.mll))
