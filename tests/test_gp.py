"""End-to-end Simplex-GP inference tests (paper §5 behaviours)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels_math as km
from repro.core.exact import ExactGP
from repro.gp import (GPParams, SimplexGP, SimplexGPConfig, cross_mvm, fit,
                      mll_value_and_grad, nll, posterior, rmse)
from repro.gp.models import softplus


def _problem(rng, n=600, d=3, noise=0.1):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    f = jnp.sin(2 * x[:, 0]) + 0.5 * jnp.cos(x[:, 1] * (x[:, 2]
                                                        if d > 2 else 1.0))
    y = f + noise * jnp.asarray(rng.normal(size=n), jnp.float32)
    return x, y, f


def test_mll_value_close_to_exact(rng):
    x, y, _ = _problem(rng, n=500)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=80,
                                      num_probes=10, max_lanczos_iters=40))
    params = GPParams.init(3, noise=0.2)
    res = mll_value_and_grad(model, params, x, y, jax.random.PRNGKey(0),
                             tol=1e-3)
    eg = ExactGP(km.MATERN32)
    ls, os_, nz = model.constrained(params)
    want = float(eg.mll(x, y, lengthscale=ls, outputscale=os_, noise=nz))
    # lattice operator approximates K; SLQ adds noise — same decade check
    assert abs(float(res.mll) - want) < 0.45 * abs(want) + 50.0


@pytest.mark.parametrize("grad_mode", ["paper", "autodiff"])
def test_training_improves_validation_rmse(rng, grad_mode):
    x, y, _ = _problem(rng, n=700)
    xv, yv, fv = _problem(np.random.default_rng(7), n=150)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=40,
                                      num_probes=6, grad_mode=grad_mode,
                                      max_lanczos_iters=20))
    res = fit(model, x, y, x_val=xv, y_val=fv, epochs=10, lr=0.1,
              patience=10)
    first = res.history[0]["val_rmse"]
    assert res.best_val_rmse < first  # learning happened


def test_posterior_beats_prior(rng):
    x, y, _ = _problem(rng, n=600)
    xs, ys, fs = _problem(np.random.default_rng(3), n=120)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=60))
    params = GPParams.init(3, noise=0.1, lengthscale=1.0)
    post = posterior(model, params, x, y, xs, key=jax.random.PRNGKey(1))
    pr = float(rmse(post, fs))
    assert pr < float(jnp.std(fs))  # better than predicting the mean
    assert bool(jnp.all(post.var > 0))
    assert np.isfinite(float(nll(post, model.constrained(params)[2], fs)))


def test_cross_mvm_matches_dense(rng):
    x = jnp.asarray(rng.normal(size=(300, 3)), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(80, 3)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(300, 2)), jnp.float32)
    model = SimplexGP(SimplexGPConfig(kernel="rbf"))
    params = GPParams.init(3)
    got = cross_mvm(model, params, x, xs, v)
    ls, os_, _ = model.constrained(params)
    want = km.gram(km.RBF, xs, x, ls, os_) @ v
    cos = float(jnp.vdot(got, want)
                / (jnp.linalg.norm(got) * jnp.linalg.norm(want)))
    assert cos > 0.93


def test_rrcg_training_step_runs(rng):
    x, y, _ = _problem(rng, n=300)
    model = SimplexGP(SimplexGPConfig(kernel="rbf", max_cg_iters=40,
                                      num_probes=4, max_lanczos_iters=15))
    params = GPParams.init(3)
    res = mll_value_and_grad(model, params, x, y, jax.random.PRNGKey(5),
                             use_rrcg=True)
    assert np.isfinite(float(res.mll))
    for leaf in jax.tree.leaves(res.grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_precond_rank_config(rng):
    x, y, _ = _problem(rng, n=250)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=30,
                                      precond_rank=20, num_probes=4,
                                      max_lanczos_iters=10))
    params = GPParams.init(3)
    res = mll_value_and_grad(model, params, x, y, jax.random.PRNGKey(0))
    assert np.isfinite(float(res.mll))
