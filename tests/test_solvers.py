"""Krylov solver tests: CG, preconditioning, SLQ, RR-CG."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core import kernels_math as km
from repro.solvers import (cg, cg_while, expected_iters, lanczos,
                           pivoted_cholesky, precond_logdet, rrcg,
                           slq_logdet, slq_logdet_from_cg, woodbury_precond)


def _spd(rng, n, cond=100.0):
    a = rng.normal(size=(n, n))
    m = a @ a.T / n + np.eye(n) / cond
    return jnp.asarray(m, jnp.float32)


def test_cg_solves_to_tolerance(rng):
    a = _spd(rng, 200)
    b = jnp.asarray(rng.normal(size=(200, 3)), jnp.float32)
    x, info = cg(lambda v: a @ v, b, tol=1e-6, max_iters=300)
    rel = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
    # f32 CG: the recurrence residual hits 1e-6 but the TRUE residual
    # stagnates around eps * sqrt(kappa) ~ 1e-5; allow that headroom.
    assert rel < 3e-5
    assert bool(info.converged.all())


def test_cg_min_iters_at_paper_tolerance(rng):
    """Appendix A train tolerance 1.0 must still do work (>= min_iters)."""
    a = _spd(rng, 150)
    b = jnp.asarray(rng.normal(size=(150, 1)), jnp.float32)
    x, info = cg(lambda v: a @ v, b, tol=1.0, max_iters=100, min_iters=10)
    assert int(info.iterations) >= 10
    assert float(jnp.linalg.norm(x)) > 0


def test_cg_while_matches_scan_cg_cold(rng):
    """The early-exit solver runs the identical update recurrence, so a
    cold start must reproduce the scan-based ``cg`` solution bit-for-bit
    (same converged mask, same solution, fewer wasted iterations)."""
    a = _spd(rng, 200)
    b = jnp.asarray(rng.normal(size=(200, 3)), jnp.float32)
    xs, info_s = cg(lambda v: a @ v, b, tol=1e-5, max_iters=300)
    xw, info_w = cg_while(lambda v: a @ v, b, tol=1e-5, max_iters=300)
    np.testing.assert_array_equal(np.asarray(xw), np.asarray(xs))
    assert bool(info_w.converged.all())
    assert int(info_w.iterations) <= int(info_s.iterations)


def test_cg_while_warm_start_cuts_iterations(rng):
    """Warm-starting from the true solution exits without iterating;
    warm-starting from a nearby solve takes fewer iterations than cold
    and reaches the same answer. This is the refresh path's economics
    (gp/serve.refreeze)."""
    a = _spd(rng, 200)
    b = jnp.asarray(rng.normal(size=(200, 1)), jnp.float32)
    x_cold, info_cold = cg_while(lambda v: a @ v, b, tol=1e-5, max_iters=300)
    # a seed already within tolerance starts inactive: zero iterations.
    # (tol is looser than the cold solve's because the TRUE residual of
    # x_cold sits slightly above the recurrence residual it stopped on.)
    x_same, info_same = cg_while(lambda v: a @ v, b, tol=1e-4,
                                 max_iters=300, x0=x_cold)
    assert int(info_same.iterations) == 0
    np.testing.assert_array_equal(np.asarray(x_same), np.asarray(x_cold))
    # perturbed rhs: warm start from the old solution converges in fewer
    # iterations than the cold solve of the new system
    b2 = b + 0.01 * jnp.asarray(rng.normal(size=b.shape), jnp.float32)
    _, info_cold2 = cg_while(lambda v: a @ v, b2, tol=1e-5, max_iters=300)
    x_warm, info_warm = cg_while(lambda v: a @ v, b2, tol=1e-5,
                                 max_iters=300, x0=x_cold)
    assert bool(info_warm.converged.all())
    assert int(info_warm.iterations) < int(info_cold2.iterations)
    rel = float(jnp.linalg.norm(a @ x_warm - b2) / jnp.linalg.norm(b2))
    assert rel < 3e-5


def test_preconditioner_reduces_iterations(rng):
    x0 = jnp.asarray(rng.normal(size=(400, 4)), jnp.float32)
    k = km.gram(km.RBF, x0, x0)
    s2 = jnp.float32(0.05)
    mv = lambda v: k @ v + s2 * v
    b = jnp.asarray(rng.normal(size=(400, 1)), jnp.float32)
    pc = pivoted_cholesky(lambda i: km.gram(km.RBF, x0[i][None], x0)[0],
                          jnp.ones(400, jnp.float32), 40)
    pre = woodbury_precond(pc.l, s2)
    _, plain = cg(mv, b, tol=1e-4, max_iters=300)
    _, prec = cg(mv, b, precond=pre, tol=1e-4, max_iters=300)
    assert int(prec.iterations) < int(plain.iterations)


def test_pivoted_cholesky_approximates_kernel(rng):
    x0 = jnp.asarray(rng.normal(size=(200, 3)), jnp.float32)
    k = km.gram(km.RBF, x0, x0)
    pc = pivoted_cholesky(lambda i: k[i], jnp.ones(200, jnp.float32), 60)
    approx = pc.l @ pc.l.T
    rel = float(jnp.linalg.norm(approx - k) / jnp.linalg.norm(k))
    assert rel < 0.1
    assert float(pc.error) >= 0


def test_woodbury_matches_direct(rng):
    l = jnp.asarray(rng.normal(size=(100, 10)), jnp.float32)
    s2 = jnp.float32(0.3)
    p = l @ l.T + s2 * jnp.eye(100)
    b = jnp.asarray(rng.normal(size=(100, 2)), jnp.float32)
    got = woodbury_precond(l, s2)(b)
    want = jnp.linalg.solve(p, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-4)
    ld = float(precond_logdet(l, s2, 100))
    want_ld = float(jnp.linalg.slogdet(p)[1])
    assert abs(ld - want_ld) < 1e-2 * abs(want_ld)


def test_slq_logdet(rng):
    a = _spd(rng, 250)
    ld = slq_logdet(lambda v: a @ v, 250, key=jax.random.PRNGKey(0),
                    num_probes=30, num_iters=60)
    want = float(jnp.linalg.slogdet(a)[1])
    assert abs(float(ld) - want) < 0.1 * abs(want)


def test_slq_logdet_from_cg_matches_dense(rng):
    """BBMM's free log-det: SLQ on the tridiagonals mBCG collects during
    Rademacher-probe solves matches dense slogdet on a small SPD matrix."""
    n, p = 250, 30
    a = _spd(rng, n)
    probes = jnp.asarray(np.sign(rng.normal(size=(n, p))), jnp.float32)
    _, info = cg(lambda v: a @ v, probes, tol=1e-7, max_iters=120)
    ld = slq_logdet_from_cg(info.alphas, info.betas, info.valid,
                            jnp.full((p,), float(n), jnp.float32))
    want = float(jnp.linalg.slogdet(a)[1])
    assert abs(float(ld) - want) < 0.1 * abs(want)


def test_slq_logdet_from_cg_agrees_with_separate_slq(rng):
    """The two estimators target the same quantity; with matched probes and
    depth they land within stochastic-estimator noise of each other."""
    n, p = 200, 25
    a = _spd(rng, n)
    key = jax.random.PRNGKey(3)
    probes = jax.random.rademacher(key, (n, p), dtype=jnp.float32)
    _, info = cg(lambda v: a @ v, probes, tol=1e-7, max_iters=100)
    ld_cg = float(slq_logdet_from_cg(info.alphas, info.betas, info.valid,
                                     jnp.full((p,), float(n), jnp.float32)))
    ld_slq = float(slq_logdet(lambda v: a @ v, n, key=key, num_probes=p,
                              num_iters=60))
    denom = max(abs(ld_slq), 1.0)
    assert abs(ld_cg - ld_slq) < 0.15 * denom + 5.0


def test_lanczos_extreme_eigenvalues(rng):
    a = _spd(rng, 150)
    evals = np.linalg.eigvalsh(np.asarray(a))
    q0 = jnp.asarray(rng.normal(size=(150, 1)), jnp.float32)
    res = lanczos(lambda v: a @ v, q0, 50)
    t = (np.diag(np.asarray(res.alphas[:, 0]))
         + np.diag(np.asarray(res.betas[:-1, 0]), 1)
         + np.diag(np.asarray(res.betas[:-1, 0]), -1))
    ritz = np.linalg.eigvalsh(t)
    assert abs(ritz.max() - evals.max()) < 1e-2 * evals.max()


def test_rrcg_unbiased(rng):
    a = _spd(rng, 120)
    b = jnp.asarray(rng.normal(size=(120, 1)), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(2), 48)
    sols = jnp.stack([rrcg(lambda v: a @ v, b, key=k, min_iters=20,
                           max_iters=120).x for k in keys])
    mean = jnp.mean(sols, axis=0)
    want = jnp.linalg.solve(a, b)
    rel = float(jnp.linalg.norm(mean - want) / jnp.linalg.norm(want))
    assert rel < 0.05


def test_rrcg_expected_iters_between_bounds():
    e = expected_iters(20, 200, q=0.95)
    assert 20 < e < 60  # ~ min + 1/(1-q)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 120), seed=st.integers(0, 999))
def test_property_cg_residual_decreases(n, seed):
    rng = np.random.default_rng(seed)
    a = _spd(rng, n)
    b = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
    x, info = cg(lambda v: a @ v, b, tol=1e-5, max_iters=2 * n)
    assert float(info.residual_norms[0]) < 1e-3


def test_mbcg_issues_one_batched_mvm_per_iteration(rng):
    """Multi-RHS operator contract: mBCG with k probe columns advances the
    whole [y | Z] block through ONE (n, 1+k)-channel lattice MVM per
    iteration — never one MVM per column. Pinned at trace level with the
    kernels/blur/ops instrumentation (build_count-style): the CG scan body
    traces exactly one lattice_mvm call whose channel width is the full
    block, and the operator build itself traces exactly one more (the
    initial residual is b, so there is no extra setup MVM)."""
    from repro.core import filtering
    from repro.core.stencil import make_stencil
    from repro.kernels.blur.ops import mvm_cols, mvm_count

    n, d, k = 96, 2, 7
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, 1 + k)), jnp.float32)
    stn = make_stencil("matern32", 1)
    matvec, _ = filtering.mvm_operator(x, stn)
    op = lambda v: matvec(v) + 0.5 * v

    c0, w0 = mvm_count(), mvm_cols()
    _, info = cg(op, b, tol=1e-2, max_iters=25)
    calls = mvm_count() - c0
    cols = mvm_cols() - w0
    assert calls == 1, calls  # one traced MVM in the scan body
    assert cols == 1 + k, cols  # ... carrying the WHOLE block
    assert int(info.iterations) > 1  # and it actually iterated


def test_lanczos_block_rides_one_mvm_per_iteration(rng):
    """Same contract for the Lanczos/LOVE side: a (n, k) start block is
    tridiagonalized with one batched MVM per iteration."""
    from repro.core import filtering
    from repro.core.stencil import make_stencil
    from repro.kernels.blur.ops import mvm_cols, mvm_count

    n, d, k = 80, 2, 5
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    q0 = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    stn = make_stencil("rbf", 1)
    matvec, _ = filtering.mvm_operator(x, stn)

    c0, w0 = mvm_count(), mvm_cols()
    res = lanczos(lambda v: matvec(v) + 0.1 * v, q0, 10)
    assert mvm_count() - c0 == 1
    assert mvm_cols() - w0 == k
    assert bool(jnp.all(jnp.isfinite(res.alphas)))
