"""Paper baselines vs the dense oracle (Table 1/2 methods)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels_math as km
from repro.core.exact import ExactGP, chunked_mvm
from repro.core.sgpr import SGPR, select_inducing
from repro.core.ski_grid import kiss_gp_operator, kron_matvec
from repro.core.skip import skip_operator


def _xy(rng, n=500, d=3):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    return x, v


def test_chunked_mvm_exact(rng):
    x, v = _xy(rng)
    ref = km.dense_mvm(km.MATERN32, x, v)
    got = chunked_mvm(km.MATERN32, x, v, block=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_kron_matvec(rng):
    a = jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(5, 5)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(20, 2)), jnp.float32)
    got = kron_matvec([a, b], v)
    want = jnp.kron(a, b) @ v
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("d", [1, 2, 3])
def test_kiss_gp_accuracy(rng, d):
    """Cubic-grid SKI: accurate for small d (the regime it exists for)."""
    x, v = _xy(rng, n=400, d=d)
    op = kiss_gp_operator(km.RBF, x, grid_size=40)
    ref = km.dense_mvm(km.RBF, x, v)
    rel = float(jnp.linalg.norm(op.mvm(v) - ref) / jnp.linalg.norm(ref))
    assert rel < 5e-3


def test_kiss_grid_grows_exponentially():
    """Fig 1's point: the KISS grid is g^d while the lattice is ~n(d+1)."""
    rng = np.random.default_rng(0)
    x3, _ = _xy(rng, n=100, d=3)
    op3 = kiss_gp_operator(km.RBF, x3, grid_size=10)
    assert op3.total == 10 ** 3
    from repro.core.lattice import build_lattice
    lat = build_lattice(x3, spacing=1.0, r=1)
    assert int(lat.m) <= 100 * 4  # n (d+1)


def test_skip_rank_limited(rng):
    """SKIP's low-rank Hadamard approximation degrades vs rank (the
    paper's criticism); higher rank must do better."""
    x, v = _xy(rng, n=400, d=4)
    ref = km.dense_mvm(km.RBF, x, v)
    errs = []
    for rank in (8, 32):
        op = skip_operator(km.RBF, x, grid_size=48, rank=rank)
        errs.append(float(jnp.linalg.norm(op.mvm(v) - ref)
                          / jnp.linalg.norm(ref)))
    assert errs[1] < errs[0]
    assert errs[1] < 0.2


def test_sgpr_bound_and_posterior(rng):
    x, _ = _xy(rng, n=400, d=3)
    y = jnp.sin(x[:, 0]) + 0.05 * jnp.asarray(rng.normal(size=400),
                                              jnp.float32)
    eg = ExactGP(km.RBF)
    exact = float(eg.mll(x, y, lengthscale=1.0, outputscale=1.0,
                         noise=0.05))
    sg = SGPR(km.RBF, select_inducing(jax.random.PRNGKey(0), x, 200))
    bound = float(sg.mll(x, y, lengthscale=1.0, outputscale=1.0,
                         noise=0.05))
    assert bound <= exact + 1e-3  # ELBO is a lower bound
    xs = jnp.asarray(rng.normal(size=(50, 3)), jnp.float32)
    mean, var = sg.posterior(x, y, xs, lengthscale=1.0, outputscale=1.0,
                             noise=0.05)
    ref = eg.posterior(x, y, xs, lengthscale=1.0, outputscale=1.0,
                       noise=0.05)
    rel = float(jnp.linalg.norm(mean - ref.mean)
                / jnp.linalg.norm(ref.mean))
    assert rel < 0.05
    assert bool(jnp.all(var > 0))
