"""Per-architecture smoke tests: reduced config, forward + train step +
decode == forward consistency (assignment requirement f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get as get_config, smoke
from repro.models import build, transformer, whisper
from repro.optim import Adam


def _batch(rng, cfg, b=2, s=8):
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": tok,
             "labels": jnp.roll(tok, -1, axis=1),
             "loss_mask": jnp.ones((b, s), jnp.float32)}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        nv = cfg.num_vision_tokens
        st = s + nv
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, nv, cfg.d_model)), jnp.float32)
        batch["positions_3d"] = jnp.broadcast_to(
            jnp.arange(st, dtype=jnp.int32), (3, b, st))
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, st)), jnp.int32)
        batch["loss_mask"] = jnp.ones((b, st), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCH_IDS)
def test_full_config_matches_assignment(name):
    cfg = get_config(name)
    assert cfg.name == name
    floor = 2e7 if name == "whisper-tiny" else 1e8
    assert cfg.num_params() > floor  # full config is the real thing


@pytest.mark.parametrize("name", ARCH_IDS)
def test_smoke_forward_and_train_step(rng, name):
    cfg = smoke(name)
    lm = build(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    batch = _batch(rng, cfg)
    loss, metrics = lm.loss_fn(params, batch)
    assert np.isfinite(float(loss))
    opt = Adam(learning_rate=1e-2)
    step, _ = lm.make_train_step(opt)
    p2, _, m2 = jax.jit(step)(params, opt.init(params), batch)
    assert np.isfinite(float(m2["loss"]))
    # params actually moved
    moved = any(float(jnp.max(jnp.abs(a - b))) > 0
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("name", ARCH_IDS)
def test_decode_matches_forward(rng, name):
    """Teacher-forced one-token decode reproduces full-forward logits."""
    cfg = smoke(name)
    lm = build(cfg)
    params = lm.init_params(jax.random.PRNGKey(1))
    b, s = 2, 8
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.is_encdec:
        frames = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
        full = whisper.forward(cfg, params, frames, tok)
        enc = whisper.encode(cfg, params, frames)
        state = lm.init_decode_state(b, s, params=params, enc_out=enc)
    else:
        full = transformer.forward(cfg, params, tok).logits
        state = lm.init_decode_state(b, s)
    logits = None
    for t in range(s):
        logits, state = lm.serve_step(params, state, tok[:, t:t + 1],
                                      jnp.full((b,), t, jnp.int32))
    rel = float(jnp.linalg.norm(logits[:, 0] - full[:, -1])
                / jnp.linalg.norm(full[:, -1]))
    assert rel < 1e-4, rel


def test_lattice_attention_variant(rng):
    """Beyond-paper: permutohedral kernel attention as a drop-in layer."""
    cfg = dataclasses.replace(smoke("llama3.2-3b"),
                              attention_kind="lattice", num_layers=1)
    lm = build(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    batch = _batch(rng, cfg, b=1, s=16)
    loss, _ = lm.loss_fn(params, batch)
    assert np.isfinite(float(loss))


def test_lattice_attention_approximates_kernel_attention(rng):
    """The lattice layer approximates exact (normalized) RBF attention."""
    from repro.core import kernels_math as km
    from repro.models.lattice_attention import _kernel_attend
    from repro.core.stencil import make_stencil
    zk = jnp.asarray(rng.normal(size=(100, 3)), jnp.float32)
    zq = jnp.asarray(rng.normal(size=(40, 3)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(100, 8)), jnp.float32)
    got = _kernel_attend(zq, zk, v, make_stencil("rbf", 1))
    kqk = km.gram(km.RBF, zq, zk)
    want = (kqk @ v) / jnp.maximum(kqk.sum(1, keepdims=True), 1e-6)
    cos = float(jnp.vdot(got, want)
                / (jnp.linalg.norm(got) * jnp.linalg.norm(want)))
    assert cos > 0.93


def test_rwkv_chunk_invariance(rng):
    """Chunked-parallel time mix must not depend on the chunk size."""
    cfg1 = dataclasses.replace(smoke("rwkv6-7b"), ssm_chunk=4)
    cfg2 = dataclasses.replace(smoke("rwkv6-7b"), ssm_chunk=16)
    lm1, lm2 = build(cfg1), build(cfg2)
    params = lm1.init_params(jax.random.PRNGKey(0))
    tok = jnp.asarray(rng.integers(0, cfg1.vocab_size, (2, 16)), jnp.int32)
    l1 = transformer.forward(cfg1, params, tok).logits
    l2 = transformer.forward(cfg2, params, tok).logits
    rel = float(jnp.linalg.norm(l1 - l2) / jnp.linalg.norm(l2))
    assert rel < 1e-4


def test_griffin_window_masks_history(rng):
    """Local attention: token far beyond the window cannot see history."""
    cfg = smoke("recurrentgemma-2b")
    from repro.models import attention as attn_mod
    params = attn_mod.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 64, cfg.d_model)), jnp.float32)
    pos = jnp.arange(64, dtype=jnp.int32)[None]
    w = 8
    out = attn_mod.windowed_attention(params, x, pos, cfg, w)
    # perturb x[0, 0]; outputs beyond 2w must be unchanged
    x2 = x.at[0, 0].add(10.0)
    out2 = attn_mod.windowed_attention(params, x2, pos, cfg, w)
    diff = jnp.abs(out2 - out).max(axis=-1)[0]
    assert float(diff[:w].max()) > 0  # nearby tokens see it
    assert float(diff[2 * w:].max()) < 1e-4  # beyond the window: nothing


def test_microbatch_equivalence(rng):
    cfg = smoke("llama3.2-3b")
    lm = build(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    batch = _batch(rng, cfg, b=4, s=16)
    opt = Adam(learning_rate=0.0)
    s1, _ = lm.make_train_step(opt, microbatches=1)
    s2, _ = lm.make_train_step(opt, microbatches=2)
    _, _, m1 = s1(params, opt.init(params), batch)
    _, _, m2 = s2(params, opt.init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
