"""Partition specs + sharded-execution equivalence (subprocess w/ 8 devs)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get as get_config
from repro.launch.mesh import make_production_mesh  # noqa: F401 (import ok)
from repro.models import build
from repro.sharding import partition

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class _FakeMesh:
    """Shape-only stand-in so spec construction needs no real devices."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)

    @property
    def devices(self):  # pragma: no cover
        raise AssertionError("spec building must not touch devices")


MESH = _FakeMesh({"data": 16, "model": 16})
MESH_MP = _FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("name", ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["single", "multi"])
def test_param_specs_cover_tree(name, mesh):
    cfg = get_config(name)
    lm = build(cfg)
    params = lm.abstract_params()
    specs = partition.param_specs(cfg, mesh, params)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape)
        # every sharded dim divides the axis size
        for dim, part in zip(leaf.shape, tuple(spec)):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, (name, leaf.shape, spec)


@pytest.mark.parametrize("name", ["glm4-9b", "deepseek-v2-236b",
                                  "rwkv6-7b", "recurrentgemma-2b"])
def test_decode_state_specs_cover_tree(name):
    cfg = get_config(name)
    lm = build(cfg)
    state = lm.abstract_decode_state(128, 1024)
    specs = partition.decode_state_specs(cfg, MESH, state)
    assert len(jax.tree.leaves(state)) == len(
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))


def test_fsdp_shards_large_leaves():
    cfg = get_config("llama3.2-3b")
    lm = build(cfg)
    params = lm.abstract_params()
    specs = partition.param_specs(cfg, MESH, params)
    embed_spec = specs["embed"]
    # vocab-parallel + FSDP on the remaining dim
    assert "model" in str(embed_spec) and "data" in str(embed_spec)
    no_fsdp = partition.param_specs(cfg, MESH, params, fsdp=False)
    assert "data" not in str(no_fsdp["embed"])


def test_batch_specs_long_context_seq_shards():
    cfg = get_config("rwkv6-7b")
    lm = build(cfg)
    batch = lm.input_specs("train_4k")
    specs = partition.batch_specs(cfg, MESH, batch)
    assert tuple(specs["tokens"])[0] in (("data",), "data")
    # batch=1 long context: sequence sharded instead
    import jax.numpy as jnp
    tiny = {"tokens": jax.ShapeDtypeStruct((1, 4096), jnp.int32)}
    specs2 = partition.batch_specs(cfg, MESH, tiny)
    t = tuple(specs2["tokens"])
    assert t[0] is None and t[1] == "data"


SHARDED_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smoke
    from repro.models import build
    from repro.optim import Adam
    from repro.sharding import partition
    from repro.sharding.constraints import activation_mesh

    cfg = smoke("llama3.2-3b")
    lm = build(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 4, 16
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1),
             "loss_mask": jnp.ones((b, s), jnp.float32)}
    loss_plain = float(lm.loss_fn(params, batch)[0])

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    pspecs = partition.param_specs(cfg, mesh, params)
    psh = partition.named(mesh, pspecs)
    bspecs = partition.batch_specs(cfg, mesh, batch)
    bsh = jax.tree.map(lambda sp: jax.NamedSharding(mesh, sp), bspecs,
                       is_leaf=lambda x: isinstance(x,
                           jax.sharding.PartitionSpec))
    params_s = jax.tree.map(jax.device_put, params, psh)
    batch_s = jax.tree.map(jax.device_put, batch, bsh)
    with mesh, activation_mesh(mesh):
        loss_sharded = float(jax.jit(
            lambda p, bb: lm.loss_fn(p, bb)[0],
            in_shardings=(psh, bsh))(params_s, batch_s))
    print(json.dumps({"plain": loss_plain, "sharded": loss_sharded}))
""")


@pytest.mark.slow
def test_sharded_equals_unsharded_loss():
    """The 8-fake-device sharded loss equals the single-device loss."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SHARDED_EQUIV], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(data["plain"] - data["sharded"]) < 1e-3 * max(
        1.0, abs(data["plain"]))
