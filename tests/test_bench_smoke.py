"""Tier-1 smoke of the training/prediction pipeline the benchmarks measure.

One jitted training step and one posterior at tiny size, under the
policy-chosen ("auto") fused backend, asserting the DESIGN.md §9 contract:
exactly one lattice build each, finite outputs, no table overflow. A
pipeline regression (extra rebuilds, broken fused dispatch, NaNs from the
CG-reused log-det) fails here instead of only showing up in
``benchmarks/fig_train_step.py``.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lattice import build_count
from repro.gp import (GPParams, SimplexGP, SimplexGPConfig,
                      mll_value_and_grad, posterior)

# the benchmarks package lives at the repo root (not under src/)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.mark.bench_smoke
def test_training_step_smoke(rng):
    n, d = 96, 2
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.normal(size=n), jnp.float32)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=15,
                                      num_probes=3, backend="auto"))
    params = GPParams.init(d)

    step = jax.jit(lambda p, k: mll_value_and_grad(model, p, x, y, k))
    c0 = build_count()
    res = jax.block_until_ready(step(params, jax.random.PRNGKey(0)))
    assert build_count() - c0 == 1  # one lattice build per training step
    assert np.isfinite(float(res.mll))
    assert not bool(res.overflow)
    for leaf in jax.tree.leaves(res.grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.bench_smoke
def test_posterior_smoke(rng):
    n, ns, d = 96, 24, 2
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.normal(size=n), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(ns, d)), jnp.float32)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=15,
                                      backend="auto"))
    params = GPParams.init(d)

    c0 = build_count()
    post = posterior(model, params, x, y, xs, key=jax.random.PRNGKey(1),
                     variance_rank=6)
    jax.block_until_ready(post.mean)
    assert build_count() - c0 == 1  # one lattice build per posterior
    assert post.mean.shape == (ns,)
    assert bool(jnp.all(jnp.isfinite(post.mean)))
    assert bool(jnp.all(post.var > 0))
    assert not bool(post.overflow)


@pytest.mark.bench_smoke
def test_build_bench_smoke(rng):
    """benchmarks/fig_build.py's measurement path at tiny size: both build
    backends run, the row carries every field BENCH_build.json reports,
    and the structural invariants (m, occupancy, finite timings) hold. A
    broken backend fails here instead of only in the benchmark run."""
    from benchmarks.fig_build import measure_build

    x = jnp.asarray(rng.normal(size=(160, 3)) * 0.5, jnp.float32)
    row = measure_build(x, with_phases=True)
    assert row["n"] == 160 and row["d"] == 3
    assert 0 < row["m"] <= row["cap"]
    assert 0 < row["occupancy"] <= 0.5
    for backend in ("sort", "hash_xla"):
        assert row[backend]["cold_s"] > 0
        assert row[backend]["compile_s"] > 0
        assert row[backend]["compile_s"] >= row[backend]["cold_s"]
    assert row["cold_speedup"] > 0 and row["compile_speedup"] > 0
    ph = row["phases"]
    assert ph["embed_s"] > 0
    assert set(ph["sort"]) == {"dedup_s", "neighbor_s"}
    assert set(ph["hash"]) == {"dedup_s", "neighbor_s", "plan_s"}


@pytest.mark.bench_smoke
def test_serve_bench_smoke(rng):
    """benchmarks/fig_serve.py's measurement path at tiny size: freeze +
    predict + the posterior baseline all run, the row carries every field
    BENCH_serve.json reports, and the fidelity invariants hold (tight-tol
    parity, zero in-lattice miss, off-lattice miss in [0, 1])."""
    from benchmarks.fig_serve import measure_serve

    n, d, bq = 200, 3, 32
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.normal(size=n), jnp.float32)
    xs_out = jnp.asarray(rng.normal(size=(bq, d)) * 2.0, jnp.float32)
    row = measure_serve(x, y, x[:bq], xs_out, variance_rank=6)
    assert row["n"] == n and row["bq"] == bq
    assert row["freeze_s"] > 0 and row["serve_s"] > 0
    assert row["posterior_s"] > 0 and row["speedup"] > 1
    assert row["mean_parity"] <= 1e-4  # tiny-size band; 1e-5 at bench size
    assert row["miss_in_lattice"] == 0.0
    off = row["offlattice"]
    assert 0.0 <= off["mean_miss"] <= 1.0 and 0.0 <= off["max_miss"] <= 1.0


@pytest.mark.bench_smoke
def test_rollout_bench_smoke():
    """benchmarks/fig_rollout.py's measurement paths at tiny size: the
    k=2 freeze_multi + jitted MC rollout runs and reports positive
    throughput with a valid miss bound, the FD gradcheck meets the same
    1e-4 band the trend check enforces, and the query-gradient jaxpr is
    collective-free (the measure asserts it)."""
    from benchmarks.fig_rollout import (measure_grad_collectives,
                                        measure_gradcheck, measure_rollout)

    row = measure_rollout(200, 32, 10, variance_rank=4, iters=1)
    assert row["k"] == 2 and row["m"] > 0
    assert row["evals_per_s"] > 0 and row["grad_evals_per_s"] > 0
    assert 0.0 <= row["worst_miss"] <= 1.0
    gc = measure_gradcheck(dims=(2,), n=200, variance_rank=4)
    assert gc["max_rel_err"] <= 1e-4
    assert gc["dims"]["2"]["pairs"] > 0
    counts = measure_grad_collectives(n=150, variance_rank=4)
    assert all(v == 0 for v in counts.values())


@pytest.mark.bench_smoke
def test_trend_check_runs_clean():
    """The CI trend gate parses every committed artifact and exits 0 (its
    fail-soft contract); a malformed BENCH_*.json fails here in tier-1
    instead of only annotating a CI run."""
    from benchmarks.trend_check import main

    assert main([]) == 0
