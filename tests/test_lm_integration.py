"""LM integration: short training runs learn; serving engine completes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import smoke
from repro.launch.serve import Request, ServeEngine
from repro.launch.train import TrainConfig, run
from repro.models import build


@pytest.mark.slow
def test_train_loss_decreases():
    tc = TrainConfig(arch="llama3.2-3b", smoke=True, steps=40,
                     global_batch=4, seq_len=32, lr=3e-3, warmup=5,
                     ckpt_dir=None, log_every=5)
    out = run(tc, log=lambda *_: None)
    losses = [l for _, l in out["losses"]]
    assert losses[-1] < losses[0] - 0.2, losses
    assert not out["breaches"]


def test_serve_engine_continuous_batching(rng):
    cfg = smoke("llama3.2-3b")
    lm = build(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=2, max_seq=64)
    for rid in range(5):  # more requests than slots -> refill path
        prompt = np.asarray(rng.integers(0, cfg.vocab_size, 4), np.int32)
        eng.submit(Request(rid=rid, prompt=prompt, max_new=6))
    done = eng.run(max_steps=500)
    assert sorted(c.rid for c in done) == [0, 1, 2, 3, 4]
    for c in done:
        assert len(c.tokens) == 6
        assert all(0 <= t < cfg.vocab_size for t in c.tokens)


def test_serve_engine_reports_stranded_work_on_step_exhaustion(rng):
    """An exhausted step budget must not silently drop work: the run
    report flags exhaustion, carries the in-flight partials and the
    still-queued requests, warns — and a follow-up run() resumes the
    stranded state to completion."""
    import warnings

    cfg = smoke("llama3.2-3b")
    lm = build(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=2, max_seq=64)
    for rid in range(4):
        prompt = np.asarray(rng.integers(0, cfg.vocab_size, 6), np.int32)
        eng.submit(Request(rid=rid, prompt=prompt, max_new=8))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        report = eng.run(max_steps=3)  # < prefill length: nothing finishes
    assert report.exhausted
    assert report.unfinished == len(report.in_flight) + len(report.queued)
    assert len(report.in_flight) == 2 and len(report.queued) == 2
    assert len(report) == 0  # a RunReport IS the done list
    assert any("step budget" in str(w.message) for w in caught)

    # stranded state stays on the engine: a second run finishes the lot
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a clean drain must not warn
        report2 = eng.run(max_steps=500)
    assert not report2.exhausted and report2.unfinished == 0
    assert sorted(c.rid for c in report2) == [0, 1, 2, 3]
    for c in report2:
        assert len(c.tokens) == 8


def test_serve_engine_greedy_matches_stepwise(rng):
    """Engine greedy decode == manual serve_step loop."""
    cfg = smoke("minitron-4b")
    lm = build(cfg)
    params = lm.init_params(jax.random.PRNGKey(1))
    prompt = np.asarray(rng.integers(0, cfg.vocab_size, 5), np.int32)
    eng = ServeEngine(cfg, params, batch=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new=4))
    done = eng.run()
    # manual loop
    state = lm.init_decode_state(1, 32)
    toks = list(prompt)
    logits = None
    for t, tok in enumerate(toks):
        logits, state = lm.serve_step(
            params, state, jnp.asarray([[tok]], jnp.int32),
            jnp.asarray([t], jnp.int32))
    out = []
    cur = int(jnp.argmax(logits[0, 0]))
    out.append(cur)
    for i in range(3):
        logits, state = lm.serve_step(
            params, state, jnp.asarray([[cur]], jnp.int32),
            jnp.asarray([len(toks) + i], jnp.int32))
        cur = int(jnp.argmax(logits[0, 0]))
        out.append(cur)
    assert done[0].tokens == out
