"""Exact-GP parity golden tests: Simplex-GP vs core/exact.py.

Small-n problems (n <= 256, d in {2, 3, 5}) drawn IN-MODEL from the exact
GP prior, so the solves are well-conditioned and the gap measured is the
lattice approximation itself, not out-of-model misfit. Two layers:

  * absolute parity vs the Cholesky oracle within paper-consistent
    tolerances (the r=1 stencil's MVM error is 1e-3..1e-1, Fig. 4; the
    GP-level quantities inherit that — these bounds are calibrated, not
    tight, and catch catastrophic divergence);
  * CROSS-BACKEND agreement to ~f32 noise: every policy tier in
    kernels/blur/ops.py (fused_xla, per_direction_pallas, xla) must
    produce the SAME numbers — a backend cannot silently diverge behind
    the policy switch.

The per-problem exact reference and per-backend Simplex results are
computed once per dimension (module cache) so the 3 x 3 grid stays fast.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels_math as km
from repro.core.exact import ExactGP
from repro.gp import (GPParams, SimplexGP, SimplexGPConfig,
                      mll_value_and_grad, posterior)

BACKENDS = ("fused_xla", "per_direction_pallas", "xla")
DIMS = (2, 3, 5)
KERNEL, PROFILE = "matern32", km.MATERN32
N, NS = 192, 48
NOISE, LENGTHSCALE = 0.5, 1.0


@functools.lru_cache(maxsize=None)
def _problem(d: int):
    """In-model draw: f ~ GP(0, K) on the joint [X; X*] set."""
    rng = np.random.default_rng(1000 + d)
    x = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(NS, d)), jnp.float32)
    params = GPParams.init(d, lengthscale=LENGTHSCALE, noise=NOISE)
    model = SimplexGP(SimplexGPConfig(kernel=KERNEL))
    ls, os_, nz = model.constrained(params)
    xj = jnp.concatenate([x, xs])
    kj = km.gram(PROFILE, xj, xj, ls, os_) + 1e-5 * jnp.eye(N + NS)
    fj = jnp.linalg.cholesky(kj) @ jnp.asarray(
        rng.normal(size=N + NS), jnp.float32)
    y = fj[:N] + jnp.sqrt(nz) * jnp.asarray(rng.normal(size=N), jnp.float32)
    return x, y, xs, fj[N:], params


@functools.lru_cache(maxsize=None)
def _exact(d: int):
    x, y, xs, _, params = _problem(d)
    model = SimplexGP(SimplexGPConfig(kernel=KERNEL))
    ls, os_, nz = model.constrained(params)
    eg = ExactGP(PROFILE)
    mll = float(eg.mll(x, y, lengthscale=ls, outputscale=os_, noise=nz))
    post = eg.posterior(x, y, xs, lengthscale=ls, outputscale=os_, noise=nz)
    return mll, post, float(nz)


@functools.lru_cache(maxsize=None)
def _simplex(d: int, backend: str):
    x, y, xs, _, params = _problem(d)
    # cg_tol_eval tightened so cross-backend comparisons measure the
    # operator, not where CG happened to stop (default eval tol is 1e-2)
    model = SimplexGP(SimplexGPConfig(kernel=KERNEL, backend=backend,
                                      max_cg_iters=120, num_probes=8,
                                      cg_tol_eval=1e-4))
    res = mll_value_and_grad(model, params, x, y, jax.random.PRNGKey(0),
                             tol=1e-4)
    post = posterior(model, params, x, y, xs, key=jax.random.PRNGKey(1),
                     variance_rank=30)
    return float(res.mll), post


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("d", DIMS)
def test_mll_parity(d, backend):
    mll_exact, _, _ = _exact(d)
    mll, _ = _simplex(d, backend)
    # calibrated: observed rel error <= 0.08 across the grid (SLQ noise +
    # lattice approximation); 0.2 is the catastrophic-divergence fence
    assert abs(mll - mll_exact) <= 0.2 * abs(mll_exact), (mll, mll_exact)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("d", DIMS)
def test_posterior_mean_parity(d, backend):
    _, ep, _ = _exact(d)
    _, post = _simplex(d, backend)
    _, _, _, ftruth, _ = _problem(d)
    cos = float(jnp.vdot(post.mean, ep.mean)
                / (jnp.linalg.norm(post.mean) * jnp.linalg.norm(ep.mean)))
    assert cos > 0.90, cos
    # downstream-metric parity (paper Table 2 style): the Simplex mean
    # predicts held-out truth nearly as well as the exact mean
    rmse_s = float(jnp.sqrt(jnp.mean((post.mean - ftruth) ** 2)))
    rmse_e = float(jnp.sqrt(jnp.mean((ep.mean - ftruth) ** 2)))
    assert rmse_s <= 1.8 * rmse_e + 0.05, (rmse_s, rmse_e)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("d", DIMS)
def test_posterior_variance_parity(d, backend):
    _, ep, nz = _exact(d)
    _, post = _simplex(d, backend)
    # predictive variance (latent + noise): the noise floor keeps the
    # ratio meaningful where the exact latent variance underflows
    ratio = (np.asarray(post.var) + nz) / (np.asarray(ep.var) + nz)
    assert np.all(np.isfinite(ratio))
    assert float(ratio.min()) > 0.4, float(ratio.min())
    assert float(ratio.max()) < 2.5, float(ratio.max())


@pytest.mark.parametrize("d", DIMS)
def test_backends_cannot_silently_diverge(d):
    """All policy tiers produce the SAME numbers (f32-noise tight)."""
    ref_mll, ref_post = _simplex(d, BACKENDS[0])
    for backend in BACKENDS[1:]:
        mll, post = _simplex(d, backend)
        assert abs(mll - ref_mll) <= 1e-3 * max(1.0, abs(ref_mll)), backend
        mdiff = float(jnp.linalg.norm(post.mean - ref_post.mean)
                      / jnp.maximum(jnp.linalg.norm(ref_post.mean), 1e-30))
        vdiff = float(jnp.max(jnp.abs(post.var - ref_post.var)))
        assert mdiff <= 1e-3, (backend, mdiff)
        assert vdiff <= 1e-3, (backend, vdiff)
