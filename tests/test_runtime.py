"""Fault-tolerance substrates: checkpoint, watchdog, elastic, compression."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import Adam
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.compression import (compress, decompress,
                                       init_residuals)
from repro.runtime.straggler import StepTimer, StepWatchdog


def _tree(rng):
    return {"layers": {"w": jnp.asarray(rng.normal(size=(8, 4, 4)),
                                        jnp.float32)},
            "embed": jnp.asarray(rng.normal(size=(16, 4)), jnp.bfloat16)}


def test_checkpoint_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, async_write=False)
    tree = _tree(rng)
    mgr.save(10, tree, metric=0.5)
    assert mgr.latest_step() == 10
    got = mgr.restore(10, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_async_and_retention(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep_last=2, keep_best=1,
                            async_write=True)
    tree = _tree(rng)
    metrics = [5.0, 1.0, 4.0, 3.0, 2.0]
    for i, m in enumerate(metrics):
        mgr.save(i, tree, metric=m)
    mgr.wait()
    steps = mgr.steps()
    assert 1 in steps  # best metric kept
    assert steps[-2:] == [3, 4]  # last two kept
    assert len(steps) <= 3


def test_checkpoint_atomic_no_tmp_left(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save(1, _tree(rng))
    assert not list(tmp_path.glob("*.tmp"))


def test_checkpoint_shape_mismatch_raises(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save(1, _tree(rng))
    bad = {"layers": {"w": jax.ShapeDtypeStruct((8, 5, 4), jnp.float32)},
           "embed": jax.ShapeDtypeStruct((16, 4), jnp.bfloat16)}
    with pytest.raises(ValueError):
        mgr.restore(1, bad)


def test_watchdog_fires_on_slow_step():
    fired = []
    wd = StepWatchdog(multiplier=2.0, min_deadline=0.05,
                      on_breach=lambda s, d: fired.append(s))
    for i in range(5):  # establish a fast baseline
        with StepTimer(wd, i):
            time.sleep(0.01)
    with StepTimer(wd, 99):
        time.sleep(0.2)  # >> deadline
    assert fired == [99]
    assert wd.breaches[0][0] == 99


def test_watchdog_quiet_on_normal_steps():
    fired = []
    wd = StepWatchdog(multiplier=10.0, min_deadline=1.0,
                      on_breach=lambda s, d: fired.append(s))
    for i in range(10):
        with StepTimer(wd, i):
            time.sleep(0.005)
    assert fired == []


def test_compression_roundtrip(rng):
    x = jnp.asarray(rng.normal(size=(1000,)) * 3.0, jnp.float32)
    c = compress(x)
    xr = decompress(c, x.shape, x.dtype)
    rel = float(jnp.linalg.norm(x - xr) / jnp.linalg.norm(x))
    assert rel < 0.02
    assert c.q.dtype == jnp.int8


def test_error_feedback_residual_bounded(rng):
    x = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    r = jnp.zeros_like(x)
    norms = []
    for _ in range(5):
        c = compress(x + r)
        xr = decompress(c, x.shape, x.dtype)
        r = (x + r) - xr
        norms.append(float(jnp.linalg.norm(r)))
    assert norms[-1] < 0.05 * float(jnp.linalg.norm(x))


def test_elastic_mesh_shapes():
    from repro.runtime.elastic import choose_mesh_shape
    dp, accum = choose_mesh_shape(512, model_parallel=16,
                                  global_batch=256, prev_dp=32)
    assert dp == 32 and accum == 1
    # lose a pod's worth of devices: dp shrinks, accumulation covers it
    dp2, accum2 = choose_mesh_shape(256, model_parallel=16,
                                    global_batch=256, prev_dp=32)
    assert dp2 == 16 and accum2 == 2


def test_train_launcher_resume(tmp_path, rng):
    """Kill-and-restart: the loop resumes from the saved step."""
    from repro.launch.train import TrainConfig, run
    tc = TrainConfig(arch="whisper-tiny", smoke=True, steps=6,
                     global_batch=2, seq_len=16,
                     ckpt_dir=str(tmp_path), ckpt_every=3,
                     log_every=100)
    out1 = run(tc, log=lambda *_: None)
    # second run starts from step 6 checkpoint and does nothing more
    out2 = run(tc, log=lambda *_: None)
    assert out2["losses"] == [] or out2["losses"][0][0] >= 5
