"""Data pipeline: determinism, sharding, standardization, prefetch."""
import time

import numpy as np
import pytest

from repro.data.pipeline import Prefetcher
from repro.data.synthetic_uci import SPECS, all_names, load
from repro.data.tokens import TokenStream


def test_uci_shapes_and_standardization():
    for name in all_names():
        ds = load(name, scale=0.01 if SPECS[name]["n"] > 1e5 else 0.05)
        assert ds.d == SPECS[name]["d"]
        assert abs(float(ds.y_train.mean())) < 0.05
        assert abs(float(ds.y_train.std()) - 1.0) < 0.05
        assert ds.x_val.shape[0] > 0 and ds.x_test.shape[0] > 0


def test_uci_deterministic():
    a = load("protein", scale=0.02, seed=3)
    b = load("protein", scale=0.02, seed=3)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    c = load("protein", scale=0.02, seed=4)
    assert not np.array_equal(a.x_train, c.x_train)


def test_uci_sparsity_ordering():
    """Table 3's geometry: gridded precipitation is far sparser on the
    lattice than heavy-tailed elevators."""
    import jax.numpy as jnp
    from repro.core.lattice import build_lattice
    ratios = {}
    for name in ("precipitation", "elevators"):
        ds = load(name, scale=0.01 if name == "precipitation" else 0.05)
        x = jnp.asarray(ds.x_train)
        lat = build_lattice(x, spacing=1.0, r=1)
        ratios[name] = float(lat.m) / (x.shape[0] * (x.shape[1] + 1))
    assert ratios["precipitation"] < 0.3 * ratios["elevators"]


def test_token_stream_determinism_and_sharding():
    ts = TokenStream(vocab_size=5000, seq_len=32, global_batch=8)
    a = ts.batch(3)
    b = ts.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    # shards partition the global batch
    parts = [ts.batch(3, shard=i, num_shards=4)["tokens"]
             for i in range(4)]
    assert sum(p.shape[0] for p in parts) == 8
    stacked = np.concatenate(parts)
    assert {tuple(r) for r in stacked} == {tuple(r)
                                           for r in a["tokens"]}


def test_token_stream_skew():
    ts = TokenStream(vocab_size=10_000, seq_len=64, global_batch=16)
    toks = ts.batch(0)["tokens"]
    # zipf-ish: low ids dominate
    assert (toks < 100).mean() > 0.3
    assert toks.max() < 10_000


def test_prefetcher_order_and_skip():
    pf = Prefetcher(lambda s: {"step": s}, start_step=0, depth=2)
    pf.skip(1)
    time.sleep(0.05)
    got = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert got == [0, 2, 3, 4]


def test_prefetcher_propagates_errors():
    def boom(step):
        raise RuntimeError("source failed")

    pf = Prefetcher(boom, start_step=0)
    with pytest.raises(RuntimeError):
        next(pf)
    pf.close()
