"""Fault-tolerant serving runtime (launch/serve_gp.py, DESIGN.md §13).

Pins the degradation contract, failure mode by failure mode: a corrupt
or non-converged candidate is refused by the ``validate_predictor`` gate
and the last-good Predictor keeps serving; a wedged refresh is abandoned
at its deadline and can never publish late; a capacity-overflow refusal
recovers by re-freezing with grown cap; transient query faults are
retried inside the per-request budget while persistent ones are refused
(never answered with garbage); full-miss queries ride the explicit
prior-fallback lane; and the warm refresh path (cached lattice + reused
hash index + warm-started CG) is pinned to cold-freeze parity. The
``bench_smoke`` test replays benchmarks/fig_soak.py's scripted fault
schedule at tiny size so the whole soak harness runs in tier-1.
"""
import dataclasses
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import filtering
from repro.gp import (GPParams, SimplexGP, SimplexGPConfig, freeze,
                      refreeze, validate_predictor)
from repro.gp.serve import predict
from repro.launch.serve_gp import (EngineConfig, GPServeEngine,
                                   RefreshRejected, ServeUnavailable)
from repro.runtime.faults import FaultInjector, InjectedFault

# the benchmarks package lives at the repo root (not under src/)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

TIGHT = SimplexGPConfig(kernel="matern32", cg_tol_eval=3e-7,
                        max_cg_iters=400)
STALL = dataclasses.replace(TIGHT, cg_tol_eval=1e-12, max_cg_iters=2)


def _data(rng, n=240, d=3):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = (jnp.sin(2 * x[:, 0]) + 0.4 * x[:, 1] * x[:, 2]
         + 0.05 * jnp.asarray(rng.normal(size=n), jnp.float32))
    return x, y


def _engine(rng, n=240, d=3, faults=None, background=False, **cfg_kw):
    x, y = _data(rng, n, d)
    model = SimplexGP(TIGHT)
    params = GPParams.init(d, noise=0.3)
    cfg = EngineConfig(variance_rank=6, **cfg_kw)
    eng = GPServeEngine(model, params, x, y, key=jax.random.PRNGKey(0),
                        config=cfg, faults=faults, background=background)
    return eng, x, y


# -- freeze diagnostics + validation gate (satellite: CGInfo no longer
# -- dropped on the freeze floor) -------------------------------------------

def test_freeze_records_cg_diagnostics(rng):
    x, y = _data(rng)
    params = GPParams.init(3, noise=0.3)
    pred = freeze(SimplexGP(TIGHT), params, x, y,
                  key=jax.random.PRNGKey(0), variance_rank=6)
    assert bool(pred.cg_converged)
    assert float(pred.cg_residual) <= TIGHT.cg_tol_eval
    assert int(pred.cg_iterations) > 0

    stalled = freeze(SimplexGP(STALL), params, x, y,
                     key=jax.random.PRNGKey(0), variance_rank=6)
    assert not bool(stalled.cg_converged)
    rep = validate_predictor(stalled)
    assert not rep.ok and any("not converged" in f for f in rep.failures)
    # ...unless convergence is explicitly waived (offline experimentation)
    assert validate_predictor(stalled, require_converged=False).ok

    with pytest.raises(RuntimeError, match="did not converge"):
        freeze(SimplexGP(STALL), params, x, y, key=jax.random.PRNGKey(0),
               variance_rank=6, on_nonconverged="raise")


def test_validate_predictor_reports_each_corruption(rng):
    x, y = _data(rng)
    pred = freeze(SimplexGP(TIGHT), GPParams.init(3, noise=0.3), x, y,
                  key=jax.random.PRNGKey(0), variance_rank=6)
    assert validate_predictor(pred).ok

    bad_nan = dataclasses.replace(
        pred, tables=pred.tables.at[0, 0].set(jnp.nan))
    rep = validate_predictor(bad_nan)
    assert not rep.ok and any("non-finite" in f for f in rep.failures)

    bad_alpha = dataclasses.replace(
        pred, alpha=pred.alpha.at[0].set(jnp.inf))
    rep = validate_predictor(bad_alpha)
    assert not rep.ok and any("alpha" in f for f in rep.failures)

    bad_rows = dataclasses.replace(pred, tables=pred.tables[:-2])
    rep = validate_predictor(bad_rows)
    assert not rep.ok and any("rows" in f for f in rep.failures)

    bad_miss = dataclasses.replace(
        pred, tables=pred.tables.at[-1, 0].set(1.0))
    rep = validate_predictor(bad_miss)
    assert not rep.ok and any("miss row" in f for f in rep.failures)

    # every failure is reported, not just the first
    multi = dataclasses.replace(
        bad_nan, cg_converged=jnp.asarray(False))
    assert len(validate_predictor(multi).failures) >= 2


# -- warm refreeze: parity + index reuse (satellite + tentpole core) --------

def test_warm_refreeze_matches_cold_freeze(rng):
    """The warm path (cached lattice + reused index + warm-started CG)
    must agree with a cold freeze of the same data to 1e-5 — both solves
    converged under the tight config, so the comparison isolates the
    reuse machinery from CG stopping noise — while doing fewer CG
    iterations."""
    x, y = _data(rng, n=300)
    model = SimplexGP(TIGHT)
    params = GPParams.init(3, noise=0.3)
    key = jax.random.PRNGKey(0)
    cache = filtering.LatticeCache()
    old = freeze(model, params, x, y, key=key, variance_rank=6, cache=cache)

    y2 = y + 0.05 * jnp.sin(x[:, 0])
    cold = freeze(model, params, x, y2, key=key, variance_rank=6,
                  cache=filtering.LatticeCache())
    warm = refreeze(model, params, x, y2, key=key, old=old, cache=cache)

    assert warm.index is old.index  # same cached lattice: reuse verified
    assert bool(warm.cg_converged) and bool(cold.cg_converged)
    assert int(warm.cg_iterations) < int(cold.cg_iterations)

    xs = jnp.concatenate([x[:48], x[:16] + 0.3], axis=0)
    sw, sc = predict(warm, xs), predict(cold, xs)
    np.testing.assert_allclose(np.asarray(sw.mean), np.asarray(sc.mean),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(sw.var), np.asarray(sc.var),
                               atol=1e-5)


def test_refreeze_rebuilds_index_for_a_different_lattice(rng):
    """Index reuse is verification-gated, not assumed: when the old
    Predictor's lattice was built under a different cap (hash placement
    numbers slots differently), the stale index must be REBUILT — row
    permutations between numberings would otherwise serve silently
    permuted tables."""
    x, y = _data(rng, n=300)
    model = SimplexGP(TIGHT)
    params = GPParams.init(3, noise=0.3)
    key = jax.random.PRNGKey(0)
    # old: worst-case cap (cache path); new: auto cap (no cache)
    old = freeze(model, params, x, y, key=key, variance_rank=6,
                 cache=filtering.LatticeCache())
    y2 = y + 0.05 * jnp.sin(x[:, 0])
    warm = refreeze(model, params, x, y2, key=key, old=old, cache=None)
    cold = freeze(model, params, x, y2, key=key, variance_rank=6)
    assert warm.index is not old.index
    np.testing.assert_allclose(np.asarray(predict(warm, x[:48]).mean),
                               np.asarray(predict(cold, x[:48]).mean),
                               atol=1e-5)

    # x changed: reuse is structurally impossible, index must rebuild
    x3 = x + 0.05
    moved = refreeze(model, params, x3, y2, key=key, old=old,
                     cache=filtering.LatticeCache())
    assert moved.index is not old.index
    assert bool(jnp.all(jnp.isfinite(predict(moved, x3[:16]).mean)))


# -- engine: serving + degradation lanes ------------------------------------

def test_engine_serves_and_reports_health(rng):
    eng, x, y = _engine(rng)
    res = eng.query(x[:32])
    assert res.version == 1 and not res.stale
    assert not bool(res.fallback.any())
    assert bool(jnp.all(jnp.isfinite(res.mean)))
    h = eng.health()
    assert h.status == "ok" and h.version == 1
    assert h.queries_served == 1 and h.queries_refused == 0
    assert h.n_train == x.shape[0]
    assert h.last_refresh_s is not None and h.last_refresh_s > 0
    # an empty batch is well-formed and must not poison the staleness window
    empty = eng.query(jnp.zeros((0, x.shape[1]), jnp.float32))
    assert empty.mean.shape == (0,) and empty.var.shape == (0,)
    assert math.isfinite(eng.health().staleness)
    eng.close()


def test_engine_warm_refresh_publishes_new_version(rng):
    eng, x, y = _engine(rng)
    gen = eng.submit_refresh(y=y + 0.05)
    assert eng.refresh_now()
    assert eng.version == 2
    res = eng.query(x[:16])
    assert res.version == 2 and not res.stale
    h = eng.health()
    assert h.refreshes_ok == 1 and h.status == "ok"
    # the published predictor reused the cached lattice's index and was
    # warm-started: same treedef, so bucket compiles survived the swap
    assert eng.predictor(2).index is eng.predictor(1).index
    eng.close()


def test_nan_candidate_refused_last_good_keeps_serving(rng):
    fi = FaultInjector()
    eng, x, y = _engine(rng, faults=fi)
    fi.arm(site="freeze", kind="nan_tables")
    eng.submit_refresh(y=y + 0.05)
    assert not eng.refresh_now()
    h = eng.health()
    assert h.refreshes_rejected == 1 and h.version == 1
    assert h.status == "degraded"  # newer data exists but is not serving
    assert "non-finite" in h.last_failure
    res = eng.query(x[:16])  # last-good still serves, flagged stale
    assert res.version == 1 and res.stale
    assert bool(jnp.all(jnp.isfinite(res.mean)))

    # inf poisoning takes the same gate
    fi.arm(site="freeze", kind="inf_tables")
    eng.submit_refresh(y=y + 0.1)
    assert not eng.refresh_now()
    assert eng.health().refreshes_rejected == 2

    # a clean refresh recovers: version bumps, health returns to ok
    eng.submit_refresh(y=y + 0.1)
    assert eng.refresh_now()
    assert eng.version == 2
    assert eng.health().status == "ok"
    assert not eng.query(x[:16]).stale
    eng.close()


def test_cg_stall_refused_by_convergence_gate(rng):
    fi = FaultInjector()
    eng, x, y = _engine(rng, faults=fi)
    fi.arm(site="freeze", kind="cg_stall")
    eng.submit_refresh(y=y + 0.05)
    assert not eng.refresh_now()
    h = eng.health()
    assert h.refreshes_rejected == 1 and h.version == 1
    assert "not converged" in h.last_failure
    eng.close()


def test_overflow_recovers_with_grown_cap(rng):
    fi = FaultInjector()
    eng, x, y = _engine(rng, faults=fi)
    fi.arm(site="freeze", kind="overflow", cap=8)
    eng.submit_refresh(y=y + 0.05)
    assert eng.refresh_now()  # refused at cap 8, recovered by regrowth
    h = eng.health()
    assert h.overflow_recoveries >= 1
    assert h.refreshes_ok == 1 and h.version == 2
    assert bool(jnp.all(jnp.isfinite(eng.query(x[:16]).mean)))
    eng.close()


def test_wedged_refresh_abandoned_and_never_publishes_late(rng):
    fi = FaultInjector()
    eng, x, y = _engine(rng, faults=fi, refresh_min_deadline_s=0.2,
                        refresh_max_deadline_s=0.2)
    fi.arm(site="freeze", kind="slow", seconds=1.0)
    eng.submit_refresh(y=y + 0.05)
    t0 = time.perf_counter()
    assert not eng.refresh_now()  # abandoned at the 0.2 s deadline
    assert time.perf_counter() - t0 < 0.9  # did NOT wait out the sleep
    h = eng.health()
    assert h.refreshes_wedged == 1 and h.version == 1
    assert "wedged" in h.last_failure
    res = eng.query(x[:16])
    assert res.version == 1 and res.stale

    # the abandoned attempt finishes its sleep + freeze eventually; its
    # candidate must never publish
    time.sleep(1.6)
    assert eng.version == 1
    # the engine itself is not stuck: the next clean refresh publishes
    eng.submit_refresh(y=y + 0.05)
    assert eng.refresh_now()
    assert eng.version == 2
    eng.close()


def test_transient_query_fault_retried_persistent_refused(rng):
    fi = FaultInjector()
    eng, x, y = _engine(rng, faults=fi, max_retries=2)
    fi.arm(site="query", kind="exception")  # transient: next probe only
    res = eng.query(x[:16])
    assert bool(jnp.all(jnp.isfinite(res.mean)))
    h = eng.health()
    assert h.queries_retried == 1 and h.queries_refused == 0

    fi.arm(site="query", kind="exception", count=3)  # > max_retries
    with pytest.raises(ServeUnavailable):
        eng.query(x[:16])
    h = eng.health()
    assert h.queries_refused == 1
    # the engine recovers: the fault schedule is exhausted
    assert bool(jnp.all(jnp.isfinite(eng.query(x[:16]).mean)))
    eng.close()


def test_fallback_lane_and_staleness_alert(rng):
    eng, x, y = _engine(rng, staleness_window=4, staleness_alert=0.5)
    far = x[:8] + 100.0  # every simplex vertex misses the frozen lattice
    res = eng.query(far)
    assert bool(res.fallback.all())
    np.testing.assert_allclose(np.asarray(res.mean), 0.0, atol=0.0)
    np.testing.assert_allclose(np.asarray(res.var),
                               float(eng.predictor().outputscale),
                               atol=1e-6)
    res = eng.query(far)
    h = eng.health()
    assert h.fallback_queries == 16
    assert h.staleness > 0.5 and h.staleness_alert
    assert h.status == "degraded"  # the lattice no longer covers traffic
    # in-lattice traffic drains the rolling window back below the alert
    for _ in range(4):
        eng.query(x[:16])
    assert not eng.health().staleness_alert
    assert eng.health().status == "ok"
    eng.close()


def test_background_worker_refreshes_and_coalesces(rng):
    eng, x, y = _engine(rng, background=True)
    # two quick submissions: the worker serves the NEWEST generation
    eng.submit_refresh(y=y + 0.01)
    gen = eng.submit_refresh(y=y + 0.02)
    assert eng.wait_refreshed(gen, timeout_s=60.0)
    assert not eng.query(x[:16]).stale
    assert eng.health().refreshes_ok >= 1
    eng.close()


def test_refresh_worker_exception_reports_failure(rng):
    fi = FaultInjector()
    eng, x, y = _engine(rng, faults=fi, background=True)
    fi.arm(site="refresh", kind="exception", note="worker crash")
    gen = eng.submit_refresh(y=y + 0.05)
    assert not eng.wait_refreshed(gen, timeout_s=60.0)
    h = eng.health()
    assert h.refreshes_failed == 1 and h.version == 1
    assert "injected exception" in h.last_failure
    eng.close()


def test_initial_freeze_must_validate(rng):
    x, y = _data(rng)
    with pytest.raises(RefreshRejected, match="not converged"):
        GPServeEngine(SimplexGP(STALL), GPParams.init(3, noise=0.3), x, y,
                      key=jax.random.PRNGKey(0),
                      config=EngineConfig(variance_rank=6))


# -- the soak harness itself, at tier-1 scale -------------------------------

@pytest.mark.bench_smoke
def test_soak_smoke_zero_invalid_responses(rng):
    """benchmarks/fig_soak.py's full scripted fault schedule (worker
    crash, CG stall, NaN tables, capacity overflow, wedged freeze,
    transient + persistent query faults) against a live engine at tiny
    size: every scripted fault fires, every refused candidate stays
    unpublished, and not one served response is invalid."""
    from benchmarks.fig_soak import measure_soak

    x, y = _data(rng, n=240, d=3)
    xs_out = jnp.asarray(rng.normal(size=(64, 3)) * 2.0, jnp.float32)
    row = measure_soak(x, y, xs_out, variance_rank=4, bq=48, batches=18,
                       refresh_every=3, query_transient_at=5,
                       query_persistent_at=12)
    r, t = row["refresh"], row["traffic"]
    assert t["invalid_responses"] == 0
    assert t["availability"] >= 0.9
    assert t["served"] > 0 and t["refused"] >= 1 and t["retried"] >= 1
    assert r["ok"] >= 2 and r["rejected"] == 2 and r["wedged"] == 1
    assert r["overflow_recoveries"] >= 1
    assert r["warm_speedup"] > 1.0
    assert r["warm_iters"] < r["cold_iters"]
    fired = {(f["site"], f["kind"]) for f in row["faults"]}
    assert {("refresh", "exception"), ("freeze", "cg_stall"),
            ("freeze", "nan_tables"), ("freeze", "overflow"),
            ("freeze", "slow"), ("query", "exception")} <= fired
    assert row["final_status"] == "ok"
