"""Differentiable frozen serving (gp/serve.predict_grad, DESIGN.md §15).

The gradcheck suite behind the PR's query-space-gradient contract:

  * ANALYTIC == NUMERIC: the served mean is piecewise-LINEAR and the
    LOVE variance piecewise-QUADRATIC in x*, so central differences are
    EXACT (up to f32 roundoff) whenever both probe points stay in the
    query's simplex cell — the FD check filters to same-cell pairs via
    the embed keys and then demands 1e-4, far below what a smooth-model
    gradcheck could ask of f32.
  * ANALYTIC == AUTODIFF: ``predict_grad`` (fused forward pass, no
    autodiff) matches ``jax.jacfwd`` of the serving core to f32 noise,
    and reverse-mode ``jax.grad`` works through the ``slice_only``
    custom JVP.
  * SURROGATE ~= MODEL: against the DENSE exact-GP analytic gradient
    (``gp.predict.exact_mean_grad``) on a target much smoother than the
    lattice cell, the frozen gradient is globally unbiased (unit scale
    fit) and pointwise aligned — the fences catch sign/scale/indexing
    bugs while allowing the O(cell) interpolation scatter.
  * MULTI-OUTPUT: ``freeze_multi`` is bit-exact against k independent
    ``freeze()`` calls (channels solve sequentially on the shared
    lattice), and the k-channel serving path pays ONE embed per batch.
  * BOUNDARIES: the positional tie-break (``lattice.descending_rank``)
    makes cell-boundary subgradients deterministic; ``grad_ok`` gates
    off-lattice queries.
  * ZERO-COLLECTIVE: query-space gradients under the replicated-table
    mesh contract stay collective-free (sharding/simplex.py).

CI runs this file as its own lane: ``pytest -m gradcheck``.
"""
import functools
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp_compat import given, settings, st
from repro.core import filtering
from repro.core import lattice as L
from repro.core.kernels_math import PROFILES
from repro.gp import (GPParams, SimplexGP, SimplexGPConfig, exact_mean_grad,
                      freeze, freeze_multi)
from repro.gp.serve import (_predict_core, _predict_multi_core, predict,
                            predict_grad, predict_multi, predict_multi_grad)
from repro.sharding.simplex import collective_counts, data_mesh

pytestmark = pytest.mark.gradcheck

TIGHT = SimplexGPConfig(kernel="matern32", cg_tol_eval=3e-7,
                        max_cg_iters=400)
# in-cell FD step: large enough that the f32 roundoff of the two
# evaluations is ~1e-6 of the secant, small enough that most probe pairs
# stay inside one simplex cell (cell size ~ spacing * ls ~ 1.3)
FD_EPS = 2.5e-2


def _data(seed, n, d):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = (jnp.sin(2 * x[:, 0]) + 0.4 * x[:, 1] * x[:, d - 1]
         + 0.05 * jnp.asarray(rng.normal(size=n), jnp.float32))
    return x, y


@functools.lru_cache(maxsize=None)
def _frozen(d, n=500, rank=10):
    """One tight-config freeze per dimension, shared across the suite."""
    x, y = _data(0, n, d)
    model = SimplexGP(TIGHT)
    params = GPParams.init(d, noise=0.3)
    pred = freeze(model, params, x, y, key=jax.random.PRNGKey(0),
                  variance_rank=rank)
    return model, params, x, y, pred


def _same_cell(pred, model, xa, xb):
    """True per row iff xa and xb embed into the SAME simplex cell."""
    sp = model.stencil.spacing
    ka, _ = L.simplex_embed(xa / pred.lengthscale[None, :], sp)
    kb, _ = L.simplex_embed(xb / pred.lengthscale[None, :], sp)
    return np.asarray(jnp.all(ka == kb, axis=(1, 2)))


# -- analytic vs central differences (exact in-cell) -------------------------


@pytest.mark.parametrize("d", [2, 3, 5])
def test_fd_gradcheck_interior(d):
    """d(mean, var)/dx* == central differences to 1e-4 relative (scale
    floored at 1: mean/var are O(1) here) at strictly-interior queries —
    per coordinate, for d in {2, 3, 5}. Piecewise linear/quadratic means
    the in-cell secant IS the derivative; the tolerance is pure f32
    roundoff headroom."""
    model, _, x, _, pred = _frozen(d)
    xs = x[:80]
    g = predict_grad(pred, xs)
    ok = np.asarray(g.grad_ok)
    assert ok.sum() >= 40  # queries at train points are in-lattice
    used = 0
    for j in range(d):
        e = jnp.zeros(d, xs.dtype).at[j].set(FD_EPS)
        xp, xm = xs + e, xs - e
        keep = _same_cell(pred, model, xp, xm) & ok
        rp, rm = predict(pred, xp), predict(pred, xm)
        fdm = np.asarray((rp.mean - rm.mean) / (2 * FD_EPS))[keep]
        fdv = np.asarray((rp.var - rm.var) / (2 * FD_EPS))[keep]
        am = np.asarray(g.dmean[:, j])[keep]
        av = np.asarray(g.dvar[:, j])[keep]
        scale_m = np.maximum(np.abs(am), 1.0)
        scale_v = np.maximum(np.abs(av), 1.0)
        assert np.all(np.abs(fdm - am) / scale_m <= 1e-4), (d, j)
        assert np.all(np.abs(fdv - av) / scale_v <= 1e-4), (d, j)
        used += int(keep.sum())
    # the same-cell filter must not hollow the check out
    assert used >= 40 * d, used


def test_matches_dense_exact_gp_gradient():
    """Against the dense exact-GP analytic gradient oracle on a target
    much smoother than the lattice cell: globally unbiased (least-squares
    scale fit within 5% of 1) and pointwise aligned where the oracle
    gradient is strong (median relative error <= 0.2, median cosine
    >= 0.99). A missing 1/ls, transposed Jacobian, or sign flip fails
    all three fences; the allowed scatter is the documented O(cell)
    piecewise-linearization error (DESIGN.md §15)."""
    d, n = 2, 800
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(-4, 4, size=(n, d)), jnp.float32)
    y = jnp.sin(x[:, 0] * (2 * np.pi / 6.0)) \
        + 0.5 * jnp.cos(x[:, 1] * (2 * np.pi / 6.0))
    model = SimplexGP(TIGHT)
    params = GPParams.init(d, lengthscale=0.5, noise=0.01)
    pred = freeze(model, params, x, y, key=jax.random.PRNGKey(0),
                  variance_rank=10)
    xs = jnp.asarray(rng.uniform(-2.5, 2.5, size=(256, d)), jnp.float32)
    g = predict_grad(pred, xs)
    ls, os_, noise = model.constrained(params)
    oracle = exact_mean_grad(PROFILES["matern32"], x, y, xs,
                             lengthscale=ls, outputscale=os_, noise=noise)
    ok = np.asarray(g.grad_ok)
    gd, go = np.asarray(g.dmean)[ok], np.asarray(oracle)[ok]
    assert gd.shape[0] >= 200

    scale = float(np.sum(gd * go) / np.sum(go * go))
    assert 0.95 <= scale <= 1.05, scale

    mag = np.linalg.norm(go, axis=1)
    strong = mag >= np.median(mag)
    rel = np.linalg.norm(gd - go, axis=1)[strong] / mag[strong]
    assert np.median(rel) <= 0.2, np.median(rel)
    cos = np.sum(gd * go, axis=1) / (np.linalg.norm(gd, axis=1) * mag
                                     + 1e-12)
    assert np.median(cos[strong]) >= 0.99, np.median(cos[strong])


# -- analytic vs autodiff ----------------------------------------------------


def test_predict_grad_matches_jacfwd():
    """The fused analytic pass equals jax.jacfwd of the serving core —
    same custom JVP, no retrace, to f32 noise."""
    _, _, x, _, pred = _frozen(3)
    xs = x[:32]
    g = predict_grad(pred, xs)

    def core(q):
        mean, var, _ = _predict_core(pred, q[None, :], backend="slice_xla")
        return jnp.stack([mean[0], var[0]])

    jac = jax.vmap(jax.jacfwd(core))(xs)  # (b, 2, d)
    np.testing.assert_allclose(np.asarray(g.dmean), np.asarray(jac[:, 0]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(g.dvar), np.asarray(jac[:, 1]),
                               atol=1e-6)


def test_reverse_mode_grad_through_predict():
    """jax.grad works through the frozen slice (the custom JVP is built
    from transposable XLA ops) and agrees with the analytic dmean."""
    _, _, x, _, pred = _frozen(3)
    xs = x[:16]

    def loss(q):
        mean, _, _ = _predict_core(pred, q, backend="slice_xla")
        return jnp.sum(mean)

    gr = jax.grad(loss)(xs)
    g = predict_grad(pred, xs)
    np.testing.assert_allclose(np.asarray(gr), np.asarray(g.dmean),
                               atol=1e-6)


def test_tangent_xla_pallas_interpret_parity():
    """The fused Pallas tangent tier computes the same (out, out_dot,
    miss) as the XLA reference tier."""
    _, _, x, _, pred = _frozen(3)
    zq = x[:64] / pred.lengthscale[None, :]
    zdot = jnp.asarray(np.random.default_rng(3).normal(size=zq.shape),
                       jnp.float32)
    ox, dx_, mx = filtering.slice_only_tangent(
        pred.index, pred.tables, zq, zdot, spacing=pred.spacing,
        backend="slice_xla")
    op, dp, mp_ = filtering.slice_only_tangent(
        pred.index, pred.tables, zq, zdot, spacing=pred.spacing,
        backend="slice_pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(op), np.asarray(ox), atol=1e-6)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dx_), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(mp_), np.asarray(mx))


# -- multi-output freeze/serve -----------------------------------------------


def _multi_setup(k=3, n=300, d=3, rank=6, cap=4096):
    x, _ = _data(0, n, d)
    rng = np.random.default_rng(5)
    ys = jnp.asarray(rng.normal(size=(n, k)), jnp.float32) \
        + jnp.sin(x[:, :1] * jnp.arange(1, k + 1)[None, :])
    model = SimplexGP(TIGHT)
    params = GPParams.init(d, noise=0.3)
    key = jax.random.PRNGKey(7)
    return model, params, x, ys, key, cap, rank


def test_freeze_multi_bit_exact_vs_k_freezes():
    """One freeze_multi == k independent freeze() calls, bit for bit:
    same shared lattice, per-channel tables and alpha EXACTLY equal (the
    channels solve sequentially so CG stopping is identical — the
    documented reason freeze_multi does not batch the solves)."""
    model, params, x, ys, key, cap, rank = _multi_setup()
    k = ys.shape[1]
    mp = freeze_multi(model, params, x, ys, key=key, variance_rank=rank,
                      cap=cap)
    chan_keys = jax.random.split(key, k)
    r1 = mp.tables.shape[1] // k
    for j in range(k):
        pj = freeze(model, params, x, ys[:, j], key=chan_keys[j],
                    variance_rank=rank, cap=cap)
        np.testing.assert_array_equal(
            np.asarray(mp.tables[:, j * r1:(j + 1) * r1]),
            np.asarray(pj.tables))
        np.testing.assert_array_equal(np.asarray(mp.alpha[:, j]),
                                      np.asarray(pj.alpha))


def test_predict_multi_parity_and_grads():
    """predict_multi channel j == predict of the j-th single-channel
    Predictor (1-ulp fence: identical math, one reshape apart), and
    predict_multi_grad stacks per-channel predict_grad."""
    model, params, x, ys, key, cap, rank = _multi_setup()
    k = ys.shape[1]
    mp = freeze_multi(model, params, x, ys, key=key, variance_rank=rank,
                      cap=cap)
    xs = x[:48]
    mr = predict_multi(mp, xs)
    mg = predict_multi_grad(mp, xs)
    chan_keys = jax.random.split(key, k)
    for j in range(k):
        pj = freeze(model, params, x, ys[:, j], key=chan_keys[j],
                    variance_rank=rank, cap=cap)
        sr = predict(pj, xs)
        sg = predict_grad(pj, xs)
        np.testing.assert_allclose(np.asarray(mr.mean[:, j]),
                                   np.asarray(sr.mean), atol=1e-6)
        np.testing.assert_allclose(np.asarray(mr.var[:, j]),
                                   np.asarray(sr.var), atol=1e-6)
        np.testing.assert_allclose(np.asarray(mg.dmean[:, j]),
                                   np.asarray(sg.dmean), atol=1e-6)
        np.testing.assert_allclose(np.asarray(mg.dvar[:, j]),
                                   np.asarray(sg.dvar), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(mr.miss_mass),
                                  np.asarray(mg.miss_mass))


def test_multi_channel_serving_embeds_once():
    """The satellite op-count pin: tracing the k-channel serving core
    runs simplex_embed exactly ONCE per query batch — the channels share
    the embed/rank scratch and differ only in table columns."""
    model, params, x, ys, key, cap, rank = _multi_setup()
    mp = freeze_multi(model, params, x, ys, key=key, variance_rank=rank,
                      cap=cap)
    xs = x[:16]
    before = L.embed_count()
    jax.make_jaxpr(
        lambda q: _predict_multi_core(mp, q, backend="slice_xla"))(xs)
    assert L.embed_count() - before == 1
    # and the gradient pass too: one ranked embed serves primal + Jacobian
    before = L.embed_count()
    jax.make_jaxpr(
        lambda q: filtering.slice_only_grad(mp.index, mp.tables,
                                            q, spacing=mp.spacing))(xs)
    assert L.embed_count() - before == 1


# -- boundary / tie-break semantics ------------------------------------------


def test_boundary_tiebreak_deterministic():
    """On a simplex boundary the subgradient is the POSITIONAL tie-break
    of descending_rank: at a lattice vertex (full tie, z=0) the rank is
    arange(d+1), repeated and jitted evaluation is bit-identical, and the
    reported gradient is the one-sided derivative of that cell."""
    for d in (2, 3, 5):
        z0 = jnp.zeros((1, d), jnp.float32)
        _, _, rank = L.simplex_embed_ranked(z0, 1.0)
        np.testing.assert_array_equal(np.asarray(rank[0]),
                                      np.arange(d + 1))
    # tied differentials break by coordinate position (lower index first)
    diff = jnp.asarray([[0.5, 0.5, 0.5, 0.5]], jnp.float32)
    np.testing.assert_array_equal(np.asarray(L.descending_rank(diff)[0]),
                                  np.arange(4))
    _, _, x, _, pred = _frozen(3)
    # exact boundary query in x-space: a lattice vertex maps to z = 0
    xb = jnp.zeros((1, 3), jnp.float32)
    g1 = predict_grad(pred, xb)
    g2 = predict_grad(pred, xb)
    np.testing.assert_array_equal(np.asarray(g1.dmean), np.asarray(g2.dmean))
    g3 = jax.jit(lambda q: predict_grad(pred, q).dmean)(xb)
    np.testing.assert_array_equal(np.asarray(g1.dmean), np.asarray(g3))


# -- hypothesis-style properties ---------------------------------------------


@settings(max_examples=10)
@given(d=st.integers(2, 6), seed=st.integers(0, 10_000),
       scale=st.floats(0.1, 5.0))
def test_weight_jacobian_rows_sum_to_zero(d, seed, scale):
    """Barycentric weights sum to 1 identically, so every Jacobian row
    (summed over the d+1 vertices) is zero — for any cell, any rank
    pattern, any spacing regime the embed reaches."""
    rng = np.random.default_rng(seed)
    z = jnp.asarray(scale * rng.normal(size=(32, d)), jnp.float32)
    _, _, rank = L.simplex_embed_ranked(z, 1.0)
    jac = L.embed_weight_jacobian(rank, 1.0)  # (n, d+1, d)
    np.testing.assert_allclose(np.asarray(jac.sum(axis=1)), 0.0,
                               atol=2e-6 * scale)


@settings(max_examples=8)
@given(seed=st.integers(0, 10_000))
def test_gradient_locally_constant_within_cell(seed):
    """dmean is the slope of a piecewise-linear surface: CONSTANT within
    a cell — two queries in the same cell report it bit-close. dvar is
    the slope of a piecewise-QUADRATIC surface: affine within the cell,
    so it may drift proportionally to the in-cell shift (here 1e-3 with
    O(1) curvature) but no further."""
    model, _, x, _, pred = _frozen(3)
    rng = np.random.default_rng(seed)
    base = x[rng.integers(0, x.shape[0], size=24)]
    shift = base + jnp.asarray(1e-3 * rng.normal(size=base.shape),
                               jnp.float32)
    keep = _same_cell(pred, model, base, shift)
    ga, gb = predict_grad(pred, base), predict_grad(pred, shift)
    np.testing.assert_allclose(np.asarray(ga.dmean)[keep],
                               np.asarray(gb.dmean)[keep], atol=1e-5)
    np.testing.assert_allclose(np.asarray(ga.dvar)[keep],
                               np.asarray(gb.dvar)[keep], atol=1e-2)


@settings(max_examples=8)
@given(seed=st.integers(0, 10_000))
def test_gradients_permutation_invariant(seed):
    """Serving gradients are embarrassingly parallel: permuting the
    query batch permutes (mean, dmean, dvar, grad_ok) bit for bit."""
    _, _, x, _, pred = _frozen(3)
    xs = x[:64]
    perm = jnp.asarray(np.random.default_rng(seed).permutation(64))
    g = predict_grad(pred, xs)
    gp = predict_grad(pred, xs[perm])
    np.testing.assert_array_equal(np.asarray(g.dmean[perm]),
                                  np.asarray(gp.dmean))
    np.testing.assert_array_equal(np.asarray(g.dvar[perm]),
                                  np.asarray(gp.dvar))
    np.testing.assert_array_equal(np.asarray(g.grad_ok[perm]),
                                  np.asarray(gp.grad_ok))


@settings(max_examples=8)
@given(seed=st.integers(0, 10_000), shift=st.floats(50.0, 500.0))
def test_off_lattice_gradients_are_flagged(seed, shift):
    """grad_ok is exactly the miss_mass == 0 gate: off-lattice queries
    (which fall back toward the prior, a kinked surface) always report
    grad_ok=False; in-lattice train-point queries always pass."""
    _, _, x, _, pred = _frozen(3)
    far = x[:16] + jnp.float32(shift)
    g = predict_grad(pred, jnp.concatenate([x[16:32], far], axis=0))
    ok = np.asarray(g.grad_ok)
    miss = np.asarray(g.miss_mass)
    np.testing.assert_array_equal(ok, miss <= 0.0)
    assert not ok[16:].any()
    assert ok[:16].all()


# -- sharding: gradients stay zero-collective --------------------------------


def test_query_gradients_zero_collective():
    """The DESIGN.md §15 contract: d/dx* under the replicated-table mesh
    adds NO collectives — the table cotangent is partial-evaluated away
    (grad is taken w.r.t. the sharded queries only), so the gradient
    jaxpr is as collective-free as the forward serving jaxpr."""
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    _, _, x, _, pred = _frozen(3)
    mesh = data_mesh(1)

    def grad_core(p, q):
        f = lambda qq: jnp.sum(
            _predict_core(p, qq, backend="slice_xla")[0])
        return jax.grad(f)(q)

    fn = shard_map(grad_core, mesh=mesh, in_specs=(P(), P("data")),
                   out_specs=P("data"), check_rep=False)
    counts = collective_counts(fn, pred, jnp.zeros((64, 3), jnp.float32))
    assert all(v == 0 for v in counts.values()), counts
    # the fused analytic pass is likewise collective-free
    fn2 = shard_map(lambda p, q: predict_grad(p, q).dmean, mesh=mesh,
                    in_specs=(P(), P("data")), out_specs=P("data"),
                    check_rep=False)
    counts2 = collective_counts(fn2, pred,
                                jnp.zeros((64, 3), jnp.float32))
    assert all(v == 0 for v in counts2.values()), counts2
