"""Frozen-lattice serving (gp/serve.py + kernels/slice, DESIGN.md §12).

Pins the serving contract: (1) the frozen Predictor reproduces the
shared-lattice ``posterior`` on in-lattice queries once both CG solves
are converged (tight tolerance isolates the frozen math from CG stopping
noise); (2) off-lattice queries are fenced by the slice-miss diagnostic
— zero miss implies parity, full miss implies the prior; (3) serving is
embarrassingly parallel: permuting a batch permutes outputs bit-for-bit,
buckets don't change results, and the replicated-table mesh path is
collective-free and bit-identical.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import filtering
from repro.core import lattice as L
from repro.gp import (GPParams, SimplexGP, SimplexGPConfig, freeze,
                      posterior)
from repro.gp.serve import _predict_core, bucket_size, predict
from repro.sharding.simplex import collective_counts, data_mesh

TIGHT = SimplexGPConfig(kernel="matern32", cg_tol_eval=3e-7,
                        max_cg_iters=400)


def _data(rng, n, d):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = (jnp.sin(2 * x[:, 0]) + 0.4 * x[:, 1] * x[:, 2]
         + 0.05 * jnp.asarray(rng.normal(size=n), jnp.float32))
    return x, y


def _frozen(rng, n=500, d=3, cfg=TIGHT, rank=10):
    x, y = _data(rng, n, d)
    model = SimplexGP(cfg)
    # realistic noise level: keeps K_hat's condition number moderate, so
    # the two converged CG solves (train vs joint lattice, f32) agree to
    # well under the 1e-5 parity fence instead of sitting right on it
    params = GPParams.init(d, noise=0.3)
    key = jax.random.PRNGKey(0)
    pred = freeze(model, params, x, y, key=key, variance_rank=rank)
    return model, params, x, y, key, pred


def test_in_lattice_parity_vs_posterior(rng):
    """Mean <= 1e-5 and variance <= 1e-5 against the shared-lattice
    posterior on queries AT train points (their simplices are fully
    inside the frozen lattice, so the two paths compute the same
    quantity up to f32 noise)."""
    model, params, x, y, key, pred = _frozen(rng)
    xs = x[:64]
    sr = predict(pred, xs)
    post = posterior(model, params, x, y, xs, key=key, variance_rank=10)
    assert float(jnp.max(sr.miss_mass)) == 0.0
    np.testing.assert_allclose(np.asarray(sr.mean), np.asarray(post.mean),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(sr.var), np.asarray(post.var),
                               atol=1e-5)


def test_off_lattice_fenced_by_miss_mass(rng):
    """The slice-miss diagnostic fences off-lattice behavior: fully
    off-lattice queries report miss 1 and fall back to the prior (zero
    mean, prior variance); zero-miss queries match the posterior to the
    in-lattice tolerance; everything in between stays bounded."""
    model, params, x, y, key, pred = _frozen(rng)
    os_ = float(pred.outputscale)

    far = x[:16] + 100.0
    sf = predict(pred, far)
    # all d+1 vertices miss: mass is the full weight sum (1 up to f32
    # normalization, clipped to the [0, 1] contract at the source)
    assert float(jnp.min(sf.miss_mass)) >= 1.0 - 1e-6
    assert float(jnp.max(sf.miss_mass)) <= 1.0
    np.testing.assert_allclose(np.asarray(sf.mean), 0.0, atol=0.0)
    np.testing.assert_allclose(np.asarray(sf.var), os_, atol=1e-6)

    near = x[:96] + 0.3
    sn = predict(pred, near)
    miss = np.asarray(sn.miss_mass)
    assert np.all((0.0 <= miss) & (miss <= 1.0))
    assert np.all(np.isfinite(np.asarray(sn.mean)))
    assert np.all((np.asarray(sn.var) > 0) & (np.asarray(sn.var) <= os_))
    # zero-miss queries add no lattice points, so a posterior over JUST
    # them runs on the same point set as the frozen lattice and must
    # agree; any miss > 0 query in the batch would refine the joint blur
    # graph and legitimately shift every prediction — exactly the hazard
    # the miss diagnostic exists to flag
    sel = miss == 0.0
    assert np.any(sel)
    xin = near[np.nonzero(sel)[0]]
    sin = predict(pred, xin)
    post = posterior(model, params, x, y, xin, key=key, variance_rank=10)
    np.testing.assert_allclose(np.asarray(sin.mean),
                               np.asarray(post.mean), atol=1e-5)


def test_permuting_queries_permutes_outputs(rng):
    """Serving is per-query independent: predict(xs[perm]) must equal
    predict(xs)[perm] BIT-FOR-BIT (same bucket, no cross-query state)."""
    _, _, x, _, _, pred = _frozen(rng, n=300)
    xs = jnp.asarray(rng.normal(size=(48, 3)), jnp.float32)
    base = predict(pred, xs)
    for seed in range(3):
        perm = np.random.default_rng(seed).permutation(48)
        out = predict(pred, xs[perm])
        assert bool(jnp.all(out.mean == base.mean[perm]))
        assert bool(jnp.all(out.var == base.var[perm]))
        assert bool(jnp.all(out.miss_mass == base.miss_mass[perm]))


def test_buckets_do_not_change_results(rng):
    """Different batch sizes land in different padding buckets; results
    for a given query must not depend on which bucket served it."""
    _, _, x, _, _, pred = _frozen(rng, n=300)
    xs = jnp.asarray(rng.normal(size=(70, 3)), jnp.float32)
    full = predict(pred, xs)  # bucket 256
    for b in (1, 7, 64, 65):  # buckets 64, 64, 64, 256
        part = predict(pred, xs[:b])
        assert part.mean.shape == (b,)
        assert bool(jnp.all(part.mean == full.mean[:b]))
        assert bool(jnp.all(part.var == full.var[:b]))
    assert bucket_size(1, (64, 256)) == 64
    assert bucket_size(65, (64, 256)) == 256
    assert bucket_size(300, (64, 256)) == 512  # pow2 growth past largest
    assert bucket_size(60, (64, 256), multiple=8) == 64
    assert bucket_size(65, (64,), multiple=3) == 129


def test_slice_pallas_interpret_matches_xla(rng):
    """The fused Pallas query kernel (interpret mode off-TPU) agrees with
    the XLA lookup+slice reference."""
    _, _, x, _, _, pred = _frozen(rng, n=300)
    zq = jnp.asarray(rng.normal(size=(40, 3)), jnp.float32)
    o_x, m_x = filtering.slice_only(pred.index, pred.tables, zq,
                                    spacing=pred.spacing,
                                    backend="slice_xla")
    o_p, m_p = filtering.slice_only(pred.index, pred.tables, zq,
                                    spacing=pred.spacing,
                                    backend="slice_pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_x), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(m_p), np.asarray(m_x))


def test_replicated_mesh_serving_zero_collectives(rng):
    """The DESIGN.md §12 serving contract: frozen tables replicated,
    queries sharded, ZERO collectives on the jaxpr, and results identical
    to single-device serving."""
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    _, _, x, _, _, pred = _frozen(rng, n=300)
    mesh = data_mesh(1)
    xs = x[:64]
    single = predict(pred, xs)
    sharded = predict(pred, xs, mesh=mesh)
    assert bool(jnp.all(single.mean == sharded.mean))
    assert bool(jnp.all(single.var == sharded.var))

    fn = shard_map(functools.partial(_predict_core, backend="slice_xla"),
                   mesh=mesh, in_specs=(P(), P("data")),
                   out_specs=P("data"), check_rep=False)
    counts = collective_counts(fn, pred, jnp.zeros((64, 3), jnp.float32))
    assert all(v == 0 for v in counts.values()), counts


def test_predictor_is_a_jit_safe_pytree(rng):
    """The Predictor round-trips through jit (serving runs inside jitted
    endpoints) and through tree flatten/unflatten (checkpointing)."""
    _, _, x, _, _, pred = _frozen(rng, n=300)
    leaves, treedef = jax.tree.flatten(pred)
    pred2 = jax.tree.unflatten(treedef, leaves)
    out = jax.jit(lambda p, q: _predict_core(p, q, backend="slice_xla"))(
        pred2, x[:16])
    assert out[0].shape == (16,)


def test_freeze_respects_cache_and_cap(rng):
    """freeze goes through LatticeCache when given one (no duplicate
    builds for the same point set) and honors an explicit cap."""
    x, y = _data(rng, 300, 3)
    model = SimplexGP(TIGHT)
    params = GPParams.init(3)
    key = jax.random.PRNGKey(0)
    cache = filtering.LatticeCache()
    c0 = L.build_count()
    freeze(model, params, x, y, key=key, variance_rank=6, cap=2048,
           cache=cache)
    freeze(model, params, x, y, key=key, variance_rank=6, cap=2048,
           cache=cache)
    assert cache.hits == 1 and cache.misses == 1
    assert L.build_count() - c0 == 1


def test_freeze_raises_on_overflowed_lattice(rng):
    """An under-capacity freeze must refuse to serve corrupt tables."""
    x, y = _data(rng, 400, 3)
    model = SimplexGP(TIGHT)
    with pytest.raises(RuntimeError, match="overflow"):
        freeze(model, GPParams.init(3), x, y, key=jax.random.PRNGKey(0),
               variance_rank=6, cap=8)
