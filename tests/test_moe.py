"""MoE dispatch correctness: routing, capacity, EP data path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as moe_mod
from repro.models.config import ModelConfig


def _cfg(**kw):
    base = dict(name="t", family="moe", num_layers=1, d_model=32,
                num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=128,
                moe=True, num_experts=8, moe_top_k=2, moe_d_ff=16,
                capacity_factor=8.0, dtype=jnp.float32)
    base.update(kw)
    return ModelConfig(**base)


def _dense_reference(params, x, cfg):
    """Loop-based oracle: every token through its top-k experts."""
    b, s, d = x.shape
    logits = x.reshape(-1, d) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe_top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    xf = x.reshape(-1, d)
    out = np.zeros((b * s, d), np.float32)
    for t in range(b * s):
        for j in range(cfg.moe_top_k):
            e = int(top_e[t, j])
            h = jax.nn.silu(xf[t] @ params["wi_gate"][e]) * (
                xf[t] @ params["wi_up"][e])
            out[t] += float(top_p[t, j]) * np.asarray(h @ params["wo"][e])
    return out.reshape(b, s, d)


def test_moe_matches_dense_reference(rng):
    cfg = _cfg()
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 12, 32)), jnp.float32)
    got = moe_mod.moe_apply(params, x, cfg)
    want = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got.y), want, rtol=1e-4,
                               atol=1e-4)
    assert float(got.aux_loss) > 0


def test_capacity_drops_overflow(rng):
    """With capacity_factor ~0, (almost) everything drops -> y ~ 0
    (shared experts disabled)."""
    cfg = _cfg(capacity_factor=1e-6)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 64, 32)), jnp.float32)
    got = moe_mod.moe_apply(params, x, cfg)
    full = moe_mod.moe_apply(
        params, x, _cfg(capacity_factor=8.0))
    assert float(jnp.linalg.norm(got.y)) < float(jnp.linalg.norm(full.y))


def test_shared_experts_added(rng):
    cfg = _cfg(num_shared_experts=1)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 8, 32)), jnp.float32)
    got = moe_mod.moe_apply(params, x, cfg)
    # removing the shared contribution recovers the routed-only output
    routed = moe_mod.moe_apply({k: v for k, v in params.items()
                                if k != "shared"},
                               x, _cfg(num_shared_experts=0))
    from repro.models import modules as nn
    shared = nn.mlp_apply(params["shared"], x.reshape(-1, 32),
                          "swiglu").reshape(1, 8, 32)
    np.testing.assert_allclose(np.asarray(got.y),
                               np.asarray(routed.y + shared), rtol=1e-4,
                               atol=1e-5)


def test_aux_loss_prefers_balance(rng):
    """Uniform routing yields smaller aux loss than collapsed routing."""
    cfg = _cfg(router_aux_coef=1.0)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 64, 32)), jnp.float32)
    balanced = moe_mod.moe_apply(params, x, cfg)
    # collapse the router onto one expert
    collapsed = dict(params)
    collapsed["router"] = params["router"].at[:, 0].add(100.0)
    worse = moe_mod.moe_apply(collapsed, x, cfg)
    assert float(worse.aux_loss) > float(balanced.aux_loss)


def test_capacity_alignment():
    cfg = _cfg()
    c = moe_mod.capacity(cfg, 4096)
    assert c % 8 == 0 and c >= 8
