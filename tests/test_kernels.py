"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels_math as km
from repro.core import lattice as L
from repro.core.stencil import make_stencil
from repro.kernels.blur.ops import blur_pallas
from repro.kernels.blur.ref import blur_ref
from repro.kernels.exact_mvm.ops import exact_mvm
from repro.kernels.exact_mvm.ref import exact_mvm_ref
from repro.kernels.flash_attention.ops import (blockwise_attention_xla,
                                               flash_attention)
from repro.kernels.flash_attention.ref import attention_ref


# ---------------------------------------------------------------------------
# exact_mvm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d,c", [(64, 2, 1), (300, 5, 2), (512, 3, 1),
                                   (777, 11, 4)])
@pytest.mark.parametrize("profile", ["rbf", "matern32", "matern52"])
def test_exact_mvm_sweep(rng, n, d, c, profile):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    got = exact_mvm(profile, x, v)
    want = exact_mvm_ref(km.get_profile(profile), x, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_exact_mvm_outputscale(rng):
    x = jnp.asarray(rng.normal(size=(128, 3)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(128, 1)), jnp.float32)
    got = exact_mvm("rbf", x, v, outputscale=2.5)
    want = 2.5 * exact_mvm_ref(km.RBF, x, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# blur
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,r,c", [(1, 1, 1), (2, 1, 1), (4, 2, 3),
                                   (7, 1, 2), (3, 3, 5)])
def test_blur_sweep(rng, d, r, c):
    x = jnp.asarray(rng.normal(size=(256, d)), jnp.float32)
    st = make_stencil("rbf", r=r)
    lat = L.build_lattice(x, spacing=st.spacing, r=r)
    vals = jnp.asarray(rng.normal(size=(lat.cap + 1, c)),
                       jnp.float32).at[lat.cap].set(0.0)
    w = jnp.asarray(st.weights, jnp.float32)
    for rev in (False, True):
        # default off-TPU dispatch (XLA) and the explicit interpreted kernel
        for interp in (None, True):
            got = blur_pallas(lat, vals, tuple(st.weights), reverse=rev,
                              interpret=interp)
            want = blur_ref(vals, lat.nbr, w, reverse=rev)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-6)


def test_blur_blocked_streaming_matches_resident(rng):
    """Grid-blocked fallback (source streamed in tiles) == resident kernel,
    including tiles where every gather misses the resident source block."""
    from repro.kernels.blur.kernel import (blur_direction_blocked_pallas,
                                           blur_direction_pallas)
    from repro.kernels.blur.ref import blur_direction_ref

    x = jnp.asarray(rng.normal(size=(300, 3)), jnp.float32)
    st = make_stencil("rbf", r=2)
    lat = L.build_lattice(x, spacing=st.spacing, r=2)
    vals = jnp.asarray(rng.normal(size=(lat.cap + 1, 2)),
                       jnp.float32).at[lat.cap].set(0.0)
    w = jnp.asarray(st.weights, jnp.float32)
    for a in (0, 3):
        want = blur_direction_ref(vals, lat.nbr[a], w, lat.cap)
        res = blur_direction_pallas(vals, lat.nbr[a], tuple(st.weights),
                                    block_p=256, interpret=True)
        blk = blur_direction_blocked_pallas(vals, lat.nbr[a],
                                            tuple(st.weights),
                                            block_p=256, interpret=True)
        np.testing.assert_allclose(np.asarray(res), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(blk), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fused splat -> blur -> slice kernel
# ---------------------------------------------------------------------------


def _fused_case(rng, n, d, r, c, kernel="matern32"):
    from repro.core.stencil import make_stencil as mk
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    st = mk(kernel, r)
    lat = L.build_lattice(x, spacing=st.spacing, r=r)
    return lat, v, st


@pytest.mark.parametrize("d,r", [(2, 1), (2, 2), (5, 1), (5, 2), (9, 1),
                                 (9, 2)])
@pytest.mark.parametrize("symmetrize", [True, False])
def test_fused_kernel_parity(rng, d, r, symmetrize):
    """Fused Pallas kernel == the op-for-op reference across d, r, sym."""
    from repro.kernels.blur.fused import fused_filter_pallas
    from repro.kernels.blur.ref import filter_ref

    lat, v, st = _fused_case(rng, 220, d, r, c=2)
    w = jnp.asarray(st.weights, jnp.float32)
    got = fused_filter_pallas(lat, v, tuple(st.weights),
                              symmetrize=symmetrize, interpret=True)
    want = filter_ref(lat, v, w, symmetrize=symmetrize, splat_algo="hs")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_kernel_vs_legacy_path(rng):
    """Fused backend == the legacy segment_sum/scan path to f32 noise."""
    from repro.core import filtering

    lat, v, st = _fused_case(rng, 300, 4, 1, c=3)
    w = jnp.asarray(st.weights, jnp.float32)
    legacy = filtering.filter_mvm(lat, v, w, backend="xla")
    fused = filtering.filter_mvm(lat, v, w, backend="fused_xla",
                                 taps=tuple(st.weights))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(legacy),
                               rtol=1e-4, atol=1e-5)


def test_fused_kernel_dump_row_and_padding(rng):
    """Edge cases: overflowed (dump-routed) contributions must vanish, and
    odd table sizes (non-power-of-two scan/block lengths) stay exact."""
    from repro.kernels.blur.fused import fused_filter_pallas
    from repro.kernels.blur.ref import filter_ref

    # tiny cap forces overflow -> some contributions land on the dump row
    x = jnp.asarray(rng.normal(size=(97, 3)) * 3.0, jnp.float32)
    v = jnp.asarray(rng.normal(size=(97, 1)), jnp.float32)
    st = make_stencil("rbf", 1)
    lat = L.build_lattice(x, spacing=st.spacing, r=1, cap=33)
    assert bool(lat.overflow)  # the edge case under test
    w = jnp.asarray(st.weights, jnp.float32)
    got = fused_filter_pallas(lat, v, tuple(st.weights), interpret=True)
    want = filter_ref(lat, v, w, splat_algo="hs")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # dump row never leaks: a no-overflow rebuild agrees with the legacy
    # splat on every VALID slot even though the sorted order differs
    lat2 = L.build_lattice(x, spacing=st.spacing, r=1)
    table = L.splat_sorted(lat2, v)
    np.testing.assert_allclose(np.asarray(table[lat2.cap]), 0.0)
    np.testing.assert_allclose(np.asarray(table), np.asarray(L.splat(lat2, v)),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("symmetrize", [True, False])
def test_fused_kernel_self_adjoint(rng, symmetrize):
    """<F u, v> == <u, F^T v>; with symmetrize the operator is self-adjoint
    so F^T == F."""
    from repro.kernels.blur.fused import fused_filter_pallas

    lat, u, st = _fused_case(rng, 180, 3, 1, c=2)
    v = jnp.asarray(rng.normal(size=u.shape), jnp.float32)
    taps = tuple(st.weights)
    fu = fused_filter_pallas(lat, u, taps, symmetrize=symmetrize,
                             interpret=True)
    ftv = fused_filter_pallas(lat, v, taps, symmetrize=symmetrize,
                              transpose=True, interpret=True)
    lhs = float(jnp.vdot(v, fu))
    rhs = float(jnp.vdot(u, ftv))
    assert abs(lhs - rhs) < 1e-4 * max(abs(lhs), 1.0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

CASES = [
    (1, 4, 4, 256, 256, 64, True),   # MHA causal
    (2, 8, 2, 256, 256, 64, True),   # GQA group 4
    (1, 6, 6, 128, 384, 32, True),   # decode offset
    (2, 4, 1, 256, 256, 64, False),  # MQA, bidirectional
    (1, 2, 2, 100, 300, 48, True),   # ragged shapes
    (1, 4, 2, 1, 333, 64, True),     # single-token decode
]


@pytest.mark.parametrize("b,hq,hkv,sq,sk,hd,causal", CASES)
def test_flash_pallas_sweep(rng, b, hq, hkv, sq, sk, hd, causal):
    q = jnp.asarray(rng.normal(size=(b, hq, sq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, sk, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, sk, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, use_pallas=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("b,hq,hkv,sq,sk,hd,causal", CASES[:4])
def test_blockwise_xla_sweep(rng, b, hq, hkv, sq, sk, hd, causal):
    q = jnp.asarray(rng.normal(size=(b, hq, sq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, sk, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, sk, hd)), jnp.float32)
    got = blockwise_attention_xla(q, k, v, causal=causal, block_q=64,
                                  block_k=128)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_blockwise_mla_vdim(rng):
    """MLA: v head dim differs from qk head dim."""
    q = jnp.asarray(rng.normal(size=(2, 4, 128, 48)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 4, 128, 48)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 4, 128, 32)), jnp.float32)
    got = blockwise_attention_xla(q, k, v, causal=True, block_q=64,
                                  block_k=64)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_flash_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, use_pallas=True)
    want = attention_ref(q, k, v, causal=True)
    rel = float(jnp.linalg.norm((got - want).astype(jnp.float32))
                / jnp.linalg.norm(want.astype(jnp.float32)))
    assert rel < 2e-2
