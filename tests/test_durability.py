"""Durable state (DESIGN.md §14): checkpoints, persistence, crash recovery.

Pins the durability contract layer by layer: the checkpoint integrity
gate detects every scripted on-disk corruption kind (truncation, bit
flip, missing blob, stale manifest) and never restores past it;
Predictor save/load round-trips bit-exactly (property-tested over
d/n/rank and over raw blob dtype/shape edge cases) and every corrupted
save is refused at load; ``fit`` resumes bit-compatibly from its newest
valid checkpoint after an injected crash and survives injected
divergence (NaN params, loss spikes) by rolling back instead of
aborting; the serving engine warm-boots from its ``PredictorStore``,
falls back generation by generation past damage, and persists every
published Predictor off the query path. The ``recovery`` marker lane
replays the benchmarks/fig_recovery.py kill/restart schedule with real
subprocesses (an injected kill is ``os._exit`` — it needs a victim).
"""
import os
import pathlib
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp_compat import given, settings, st
from repro.gp import (GPParams, SimplexGP, SimplexGPConfig, fit, freeze,
                      load_predictor, save_predictor, self_probe,
                      PredictorLoadError)
from repro.gp.serve import predict
from repro.launch.serve_gp import (EngineConfig, GPServeEngine,
                                   PredictorStore)
from repro.optim import Adam
from repro.runtime.checkpoint import (CheckpointCorruptError,
                                      CheckpointManager, load_blobs,
                                      save_blobs)
from repro.runtime.faults import (CORRUPTION_KINDS, FaultInjector,
                                  InjectedFault, corrupt_checkpoint)
from repro.solvers import cg_while

# the benchmarks package lives at the repo root (not under src/)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

CFG = SimplexGPConfig(kernel="matern32", max_cg_iters=40, num_probes=4,
                      max_lanczos_iters=10)


def _data(rng, n=300, d=2):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = (jnp.sin(2 * x[:, 0])
         + 0.1 * jnp.asarray(rng.normal(size=n), jnp.float32))
    return x, y


def _val(rng, d=2, n=60):
    xv = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    return xv, jnp.sin(2 * xv[:, 0])


# -- checkpoint integrity gate (satellite 1) ---------------------------------

@pytest.mark.parametrize("kind", CORRUPTION_KINDS)
def test_checkpoint_corruption_detected(tmp_path, rng, kind):
    mgr = CheckpointManager(tmp_path, async_write=False)
    tree = {"w": jnp.asarray(rng.normal(size=(32, 4)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    mgr.save(1, tree)
    corrupt_checkpoint(tmp_path / "step_00000001", kind)
    with pytest.raises(CheckpointCorruptError):
        mgr.verify(1)
    tmpl = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        tree)
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(1, tmpl)


def test_latest_valid_step_skips_corrupt_newest(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep_last=5, async_write=False)
    tree = {"w": jnp.asarray(rng.normal(size=(16,)), jnp.float32)}
    for step in (1, 2, 3):
        mgr.save(step, tree)
    corrupt_checkpoint(tmp_path / "step_00000003", "bitflip")
    assert mgr.latest_valid_step() == 2
    corrupt_checkpoint(tmp_path / "step_00000002", "missing_blob")
    assert mgr.latest_valid_step() == 1
    corrupt_checkpoint(tmp_path / "step_00000001", "truncate")
    assert mgr.latest_valid_step() is None


def test_checkpoint_corruption_error_names_the_blob(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save(1, {"alpha": jnp.zeros((64,), jnp.float32)})
    corrupt_checkpoint(tmp_path / "step_00000001", "truncate")
    with pytest.raises(CheckpointCorruptError, match="alpha"):
        mgr.verify(1)


def test_checkpoint_async_wait_then_verify(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep_last=2, async_write=True)
    tree = {"w": jnp.asarray(rng.normal(size=(128, 8)), jnp.float32)}
    for step in range(4):
        mgr.save(step, tree, metric=float(step))
    mgr.wait()
    steps = mgr.steps()
    assert len(steps) <= 3  # keep_last=2 (+ keep_best default)
    for step in steps:
        mgr.verify(step)  # every retained generation is fully intact


# -- serialization round-trips (satellite 3) ---------------------------------

@settings(max_examples=8)
@given(dtype=st.sampled_from(["float32", "int32", "uint32", "bool"]),
       rank=st.integers(0, 3), seed=st.integers(0, 1000))
def test_blob_roundtrip_property(dtype, rank, seed):
    """Raw blob layer: any shape/dtype leaf survives save+load exactly.

    NOTE: no pytest fixtures here — @given properties (and the
    _hyp_compat shim) require zero-fixture signatures, so temp dirs come
    from tempfile."""
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(1, 5)) for _ in range(rank))
    arr = (rng.normal(size=shape) * 100).astype(dtype)
    with tempfile.TemporaryDirectory() as td:
        directory = pathlib.Path(td)
        leaves = save_blobs(directory, {"leaf/with/path": arr})
        got = load_blobs(directory, leaves)["leaf/with/path"]
    assert got.dtype == arr.dtype and got.shape == arr.shape
    np.testing.assert_array_equal(got, arr)


@settings(max_examples=4, deadline=None)
@given(d=st.integers(1, 3), n=st.integers(48, 96),
       rank=st.integers(1, 4), seed=st.integers(0, 100))
def test_predictor_roundtrip_property(d, n, rank, seed):
    """Predictor save/load is bit-exact and the load passes the full gate
    across d / n / variance-rank — shapes, static fields, index."""
    rng = np.random.default_rng(seed)
    x, y = _data(rng, n=n, d=d)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=60))
    pred = freeze(model, GPParams.init(d, noise=0.2), x, y,
                  key=jax.random.PRNGKey(seed), variance_rank=rank)
    with tempfile.TemporaryDirectory() as td:
        path = pathlib.Path(td) / "p"
        save_predictor(pred, path)
        # full gate: integrity + validate + self-probe
        got = load_predictor(path)
    assert got.n_train == pred.n_train
    assert got.buckets == pred.buckets
    assert got.spacing == pred.spacing and got.backend == pred.backend
    np.testing.assert_array_equal(np.asarray(got.tables),
                                  np.asarray(pred.tables))
    np.testing.assert_array_equal(np.asarray(got.alpha),
                                  np.asarray(pred.alpha))
    np.testing.assert_array_equal(np.asarray(got.index.tkeys),
                                  np.asarray(pred.index.tkeys))
    xs = jnp.asarray(rng.normal(size=(16, d)), jnp.float32)
    a, b = predict(pred, xs), predict(got, xs)
    np.testing.assert_array_equal(np.asarray(a.mean), np.asarray(b.mean))
    np.testing.assert_array_equal(np.asarray(a.var), np.asarray(b.var))


@settings(max_examples=6)
@given(d=st.integers(1, 5), seed=st.integers(0, 1000))
def test_training_state_roundtrip_property(d, seed):
    """The exact tree ``fit`` checkpoints (params+opt_state+key) survives
    a save/restore round-trip bit-exactly for any input dimension."""
    params = GPParams.init(d, noise=0.1 + 0.01 * (seed % 7))
    opt = Adam(learning_rate=0.1)
    tree = {"params": params, "opt_state": opt.init(params),
            "best_params": params, "key": jax.random.PRNGKey(seed)}
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, async_write=False)
        mgr.save(7, tree, extra={"epoch": 7, "d": d})
        got = mgr.restore(7, jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree))
        assert mgr.manifest(7)["extra"]["epoch"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("kind", CORRUPTION_KINDS)
def test_predictor_corruption_detected_at_load(tmp_path, rng, kind):
    """Every scripted corruption kind is refused by the load gate —
    a damaged Predictor is never eligible to serve."""
    x, y = _data(rng, n=200, d=2)
    model = SimplexGP(CFG)
    pred = freeze(model, GPParams.init(2, noise=0.2), x, y,
                  key=jax.random.PRNGKey(0), variance_rank=4)
    path = tmp_path / "pred"
    save_predictor(pred, path)
    corrupt_checkpoint(path, kind)
    with pytest.raises(PredictorLoadError):
        load_predictor(path)


def test_self_probe_catches_torn_index(tmp_path, rng):
    """A key table torn against its hash layout passes every value check
    (finite, in-range, row map still a bijection) but must fail the
    behavioral self-probe: keys swapped across probe neighborhoods are
    no longer reachable from their own home buckets, which is exactly
    what a tkeys blob mixed in from another generation looks like."""
    import dataclasses as dc
    x, y = _data(rng, n=200, d=2)
    pred = freeze(SimplexGP(CFG), GPParams.init(2, noise=0.2), x, y,
                  key=jax.random.PRNGKey(0), variance_rank=4)
    self_probe(pred)  # healthy predictor passes
    ros = np.asarray(pred.index.row_of_slot)
    occ = np.nonzero(ros < pred.index.m)[0]
    tk = np.asarray(pred.index.tkeys).copy()
    a, b = occ[0], occ[-1]  # far apart -> different probe chains
    tk[[a, b]] = tk[[b, a]]
    torn = dc.replace(pred, index=dc.replace(
        pred.index, tkeys=jnp.asarray(tk)))
    with pytest.raises(PredictorLoadError, match="own rows"):
        self_probe(torn)

    # a duplicated dense row (restore-gone-wrong) trips the bijection check
    ros2 = ros.copy()
    ros2[occ[0]] = ros2[occ[1]]
    dup = dc.replace(pred, index=dc.replace(
        pred.index, row_of_slot=jnp.asarray(ros2)))
    with pytest.raises(PredictorLoadError, match="bijection"):
        self_probe(dup)


# -- resumable training (tentpole a) -----------------------------------------

def test_fit_resume_bitcompat_after_crash(tmp_path, rng):
    """The acceptance criterion: crash mid-run, resume from the newest
    checkpoint, and the combined trajectory matches an uninterrupted run
    epoch for epoch (same rng stream — the key is checkpointed)."""
    x, y = _data(rng)
    xv, yv = _val(np.random.default_rng(7))
    model = SimplexGP(CFG)
    ref = fit(model, x, y, x_val=xv, y_val=yv, epochs=8, patience=20)

    fi = FaultInjector()
    fi.arm(site="fit", kind="exception", at=5)  # crash in epoch 4
    with pytest.raises(InjectedFault):
        fit(model, x, y, x_val=xv, y_val=yv, epochs=8, patience=20,
            ckpt_dir=tmp_path, ckpt_every=2, faults=fi)
    res = fit(model, x, y, x_val=xv, y_val=yv, epochs=8, patience=20,
              ckpt_dir=tmp_path, ckpt_every=2)
    assert res.report.resumed_from_epoch == 3  # ckpt at epochs 1, 3
    ref_by_epoch = {h["epoch"]: h for h in ref.history}
    assert [h["epoch"] for h in res.history] == [4, 5, 6, 7]
    for h in res.history:
        want = ref_by_epoch[h["epoch"]]
        assert abs(h["mll"] - want["mll"]) <= 1e-3 * max(
            1.0, abs(want["mll"]))
        assert abs(h["val_rmse"] - want["val_rmse"]) <= 1e-4


def test_fit_resume_skips_corrupt_checkpoint(tmp_path, rng):
    x, y = _data(rng)
    xv, yv = _val(np.random.default_rng(7))
    model = SimplexGP(CFG)
    fit(model, x, y, x_val=xv, y_val=yv, epochs=6, patience=20,
        ckpt_dir=tmp_path, ckpt_every=2)
    steps = sorted(int(p.name[5:]) for p in tmp_path.glob("step_*")
                   if not p.name.endswith(".tmp"))
    corrupt_checkpoint(tmp_path / f"step_{steps[-1]:08d}", "bitflip")
    res = fit(model, x, y, x_val=xv, y_val=yv, epochs=8, patience=20,
              ckpt_dir=tmp_path, ckpt_every=2)
    # resumed from the newest VALID step, not the corrupted newest
    assert res.report.resumed_from_epoch == steps[-2]


def test_fit_rollback_on_injected_nan(rng):
    x, y = _data(rng)
    xv, yv = _val(np.random.default_rng(7))
    fi = FaultInjector()
    fi.arm(site="fit", kind="nan_params", at=4)
    res = fit(SimplexGP(CFG), x, y, x_val=xv, y_val=yv, epochs=8,
              patience=30, faults=fi)
    reasons = [e["reason"] for e in res.report.rollbacks]
    assert any("non-finite" in r for r in reasons)
    assert all(np.isfinite(h["mll"]) for h in res.history)
    # escalation recorded: reduced lr, raised jitter
    assert res.report.rollbacks[0]["lr_scale"] == 0.5
    assert res.report.rollbacks[0]["jitter_raw"] > 0


def test_fit_rollback_on_loss_spike(rng):
    """An injected loss spike is survived by rollback, not an abort, and
    training continues to a healthy final state."""
    x, y = _data(rng)
    xv, yv = _val(np.random.default_rng(7))
    fi = FaultInjector()
    fi.arm(site="fit", kind="spike_params", at=10)
    res = fit(SimplexGP(CFG), x, y, x_val=xv, y_val=yv, epochs=14,
              patience=30, spike_window=4, spike_sigma=6.0, faults=fi)
    assert len(res.report.rollbacks) >= 1
    assert "spike" in res.report.rollbacks[0]["reason"]
    assert res.history[-1]["val_rmse"] < 0.5  # recovered, kept training


def test_fit_rollback_budget_exhaustion_raises(rng):
    x, y = _data(rng)
    xv, yv = _val(np.random.default_rng(7))
    fi = FaultInjector()
    fi.arm(site="fit", kind="nan_params", at=2, count=10)  # persistent
    with pytest.raises(RuntimeError, match="divergence guard exhausted"):
        fit(SimplexGP(CFG), x, y, x_val=xv, y_val=yv, epochs=8,
            patience=30, max_rollbacks=2, faults=fi)


# -- warm-boot serving (tentpole c) ------------------------------------------

def _store_engine(rng, store, **kw):
    x, y = _data(rng, n=240, d=3)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=60))
    params = GPParams.init(3, noise=0.2)
    cfg = EngineConfig(variance_rank=4, refresh_min_deadline_s=30.0)
    eng = GPServeEngine(model, params, x, y, key=jax.random.PRNGKey(0),
                        config=cfg, store=store, model_name="m", **kw)
    return eng, x, y


def test_engine_persists_and_warm_boots(tmp_path, rng):
    store = PredictorStore(tmp_path, keep_last=2)
    eng, x, y = _store_engine(rng, store)
    assert eng.health().boot_mode == "cold"
    assert eng.wait_persisted(timeout_s=60)  # boot predictor durable
    eng.submit_refresh(y=y + 0.01)
    assert eng.refresh_now()
    assert eng.wait_persisted(timeout_s=60)
    gens = store.generations("m")
    assert len(gens) == 2
    eng.close()

    eng2, x2, _ = _store_engine(np.random.default_rng(0), store)
    h = eng2.health()
    assert h.boot_mode == "warm" and h.boot_generation == gens[-1]
    assert h.boot_skipped == 0
    res = eng2.query(x2[:16])
    assert np.isfinite(np.asarray(res.mean)).all()
    eng2.close()


def test_engine_generation_fallback_past_corruption(tmp_path, rng):
    store = PredictorStore(tmp_path, keep_last=3)
    eng, x, y = _store_engine(rng, store)
    eng.wait_persisted(timeout_s=60)
    eng.submit_refresh(y=y + 0.01)
    assert eng.refresh_now() and eng.wait_persisted(timeout_s=60)
    eng.close()
    gens = store.generations("m")
    corrupt_checkpoint(store.path("m", gens[-1]), "truncate")

    eng2, x2, _ = _store_engine(np.random.default_rng(0), store)
    h = eng2.health()
    assert h.boot_mode == "warm"
    assert h.boot_generation == gens[-2]  # fell back exactly one
    assert h.boot_skipped == 1
    res = eng2.query(x2[:16])
    assert np.isfinite(np.asarray(res.mean)).all()
    eng2.close()


def test_engine_cold_boot_when_store_all_corrupt(tmp_path, rng):
    store = PredictorStore(tmp_path, keep_last=3)
    eng, _, _ = _store_engine(rng, store)
    eng.wait_persisted(timeout_s=60)
    eng.close()
    for g in store.generations("m"):
        corrupt_checkpoint(store.path("m", g), "missing_blob")
    eng2, x2, _ = _store_engine(np.random.default_rng(0), store)
    h = eng2.health()
    assert h.boot_mode == "cold"
    assert h.boot_skipped >= 1  # the rejected generations are on record
    res = eng2.query(x2[:16])
    assert np.isfinite(np.asarray(res.mean)).all()
    eng2.close()


def test_store_retention_keeps_last_k_plus_best(tmp_path, rng):
    store = PredictorStore(tmp_path, keep_last=2, keep_best=1)
    x, y = _data(rng, n=200, d=2)
    pred = freeze(SimplexGP(CFG), GPParams.init(2, noise=0.2), x, y,
                  key=jax.random.PRNGKey(0), variance_rank=4)
    metrics = [5.0, 1.0, 4.0, 3.0, 2.0]
    for i, m in enumerate(metrics):
        store.save("m", pred, gen=i + 1, metric=m)
    gens = store.generations("m")
    assert 2 in gens  # best metric (1.0) survives retention
    assert gens[-2:] == [4, 5]  # newest two kept
    assert len(gens) <= 3


# -- CG warm-start hygiene (powers warm boot + refreeze) ---------------------

def _spd_problem(rng, n=48, k=3):
    a = rng.normal(size=(n, n)).astype(np.float32)
    A = jnp.asarray(a @ a.T + n * np.eye(n, dtype=np.float32))
    b = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    return (lambda v: A @ v), b


def test_cg_nonfinite_seed_sanitized(rng):
    matvec, b = _spd_problem(rng)
    x_ref, _ = cg_while(matvec, b, tol=1e-6, max_iters=200)
    bad = jnp.full_like(b, jnp.nan).at[:, 0].set(b[:, 0])
    x, info = cg_while(matvec, b, tol=1e-6, max_iters=200, x0=bad)
    assert bool(jnp.all(jnp.isfinite(x)))
    assert bool(jnp.all(info.converged))
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_ref),
                               rtol=1e-3, atol=1e-4)


def test_cg_regressive_seed_reset_to_cold(rng):
    """A seed WORSE than zero (stale checkpoint under new hyperparams)
    must not slow convergence below the cold start."""
    matvec, b = _spd_problem(rng)
    _, cold = cg_while(matvec, b, tol=1e-6, max_iters=200)
    awful = 1e6 * jnp.ones_like(b)
    x, info = cg_while(matvec, b, tol=1e-6, max_iters=200, x0=awful)
    assert bool(jnp.all(info.converged))
    assert int(info.iterations) <= int(cold.iterations)


def test_cg_perfect_seed_costs_zero_iterations(rng):
    matvec, b = _spd_problem(rng)
    x_ref, _ = cg_while(matvec, b, tol=1e-6, max_iters=200)
    _, info = cg_while(matvec, b, tol=1e-4, max_iters=200, x0=x_ref)
    assert int(info.iterations) == 0


# -- crash-recovery smoke (tentpole d; CI lane) ------------------------------

@pytest.mark.recovery
def test_kill_restart_recovery_smoke(tmp_path):
    """Real-subprocess kill/restart cycles through one shared store:
    the scaled-down benchmarks/fig_recovery.py schedule (one corruption
    kind). Asserts the §14 acceptance invariants end to end."""
    from benchmarks.fig_recovery import run_recovery
    payload = run_recovery(tmp_path, corruption_kinds=("bitflip",),
                           queries=2, timeout_s=280.0)
    s = payload["summary"]
    assert not s["errors"], s["errors"]
    assert s["kills"] == 2
    assert s["max_generations_lost"] <= 1
    assert s["invalid_responses"] == 0
    assert s["all_corruptions_detected"]
    assert s["warm_boots"] >= 1
