"""Hash-build vs sort-build equivalence suite (DESIGN.md §11).

The sort build is the bit-exact lex-ordered oracle; the hash build must
produce an operator-equivalent lattice: identical deduplicated point SET
and exact m, per-row slot->coordinate mapping, a neighbor graph that
matches through the slot permutation, MVM parity <= 1e-6 across
backends, permutation invariance, and identical overflow/pack_overflow
semantics — including collision-heavy key sets and >90% occupancy.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import lattice as L
from repro.core.stencil import make_stencil
from repro.kernels.blur.ops import lattice_mvm
from repro.kernels.hash import ops as hash_ops
from repro.kernels.hash import ref as hash_ref


def _points(rng, n, d, scale=1.0):
    return jnp.asarray(rng.normal(size=(n, d)) * scale, jnp.float32)


def _pair(x, *, spacing=1.0, r=1, cap=None):
    lat_s = L.build_lattice(x, spacing=spacing, r=r, cap=cap,
                            backend="sort")
    lat_h = L.build_lattice(x, spacing=spacing, r=r, cap=cap,
                            backend="hash_xla")
    return lat_s, lat_h


def _coord_set(lat):
    return set(map(tuple,
                   np.asarray(lat.coords)[np.asarray(lat.valid)].tolist()))


def _assert_structural_equiv(lat_s, lat_h):
    """Same dedup result and neighbor graph, up to slot permutation."""
    assert int(lat_s.m) == int(lat_h.m)
    assert bool(lat_s.overflow) == bool(lat_h.overflow)
    assert bool(lat_s.pack_overflow) == bool(lat_h.pack_overflow)
    assert _coord_set(lat_s) == _coord_set(lat_h)
    # every (input, vertex) row resolves to the same coordinates
    a = np.asarray(lat_s.coords)[np.asarray(lat_s.seg_ids)]
    b = np.asarray(lat_h.coords)[np.asarray(lat_h.seg_ids)]
    np.testing.assert_array_equal(a, b)
    # neighbor tables match through the coordinate-keyed slot permutation
    cap = lat_s.cap
    cs, ch = np.asarray(lat_s.coords), np.asarray(lat_h.coords)
    vs, vh = np.asarray(lat_s.valid), np.asarray(lat_h.valid)
    sort_slot = {tuple(cs[i]): i for i in np.flatnonzero(vs)}
    hv = np.flatnonzero(vh)
    h2s = np.full(cap + 1, cap, np.int64)
    for i in hv:
        h2s[i] = sort_slot[tuple(ch[i])]
    nb_s, nb_h = np.asarray(lat_s.nbr), np.asarray(lat_h.nbr)
    for a_ in range(lat_s.d + 1):
        lhs = np.where(nb_h[a_, hv] == cap, cap, h2s[nb_h[a_, hv]])
        np.testing.assert_array_equal(lhs, nb_s[a_, h2s[hv]],
                                      err_msg=f"direction {a_}")


@pytest.mark.parametrize("d", [1, 2, 4, 8])
def test_hash_build_matches_sort_oracle(rng, d):
    x = _points(rng, 300, d)
    lat_s, lat_h = _pair(x)
    assert lat_s.build_backend == "sort"
    assert lat_h.build_backend == "hash_xla"
    assert not bool(lat_h.overflow)
    _assert_structural_equiv(lat_s, lat_h)


@pytest.mark.parametrize("d,r", [(2, 1), (3, 2), (6, 1)])
def test_hash_neighbor_table_radii(rng, d, r):
    """Neighbor equivalence holds for r > 1 stencils too."""
    x = _points(rng, 200, d)
    lat_s, lat_h = _pair(x, r=r)
    _assert_structural_equiv(lat_s, lat_h)


@pytest.mark.parametrize("backend", ["xla", "fused_xla"])
def test_operator_parity_across_builds(rng, backend):
    """MVM parity <= 1e-6 between hash- and sort-built lattices (the
    fused_xla case exercises the hash build's single-column splat plan)."""
    x = _points(rng, 256, 4)
    v = jnp.asarray(rng.normal(size=(256, 3)), jnp.float32)
    st = make_stencil("matern32", 1)
    w = jnp.asarray(st.weights, jnp.float32)
    lat_s, lat_h = _pair(x, spacing=st.spacing, r=st.r)
    out_s = lattice_mvm(lat_s, v, w, backend=backend)
    out_h = lattice_mvm(lat_h, v, w, backend=backend)
    scale = float(jnp.abs(out_s).max())
    assert float(jnp.abs(out_s - out_h).max()) <= 1e-6 * max(scale, 1.0)


def test_splat_plan_consistency(rng):
    """The hash build's sorted splat plan computes the same linear map as
    the scatter splat (up to f32 scan noise)."""
    x = _points(rng, 400, 5)
    v = jnp.asarray(rng.normal(size=(400, 2)), jnp.float32)
    _, lat_h = _pair(x)
    s_ref = L.splat(lat_h, v)
    s_plan = L.splat_sorted(lat_h, v)
    np.testing.assert_allclose(np.asarray(s_plan), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)


def test_hash_build_permutation_invariance(rng):
    """Permuting input rows permutes the operator (slot assignment may
    differ — only the operator must commute with the permutation)."""
    n, d = 96, 3
    x = _points(rng, n, d)
    perm = jnp.asarray(rng.permutation(n))
    st = make_stencil("matern32", 1)
    lat = L.build_lattice(x, spacing=st.spacing, r=st.r, backend="hash_xla")
    lat_p = L.build_lattice(x[perm], spacing=st.spacing, r=st.r,
                            backend="hash_xla")
    assert int(lat.m) == int(lat_p.m)
    assert _coord_set(lat) == _coord_set(lat_p)
    v = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    w = jnp.asarray(st.weights, jnp.float32)
    out = lattice_mvm(lat, v, w, backend="xla")
    out_p = lattice_mvm(lat_p, v[perm], w, backend="xla")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out)[perm],
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# Adversarial key sets, occupancy, and overflow semantics.
# ---------------------------------------------------------------------------


def test_collision_heavy_single_bucket_ref():
    """Keys engineered into ONE home bucket (max linear-probe clustering):
    insert places every distinct key, lookup finds each, absent keys miss."""
    hcap = 256
    rng = np.random.default_rng(0)
    pool = jnp.asarray(rng.integers(0, 1 << 20, size=(4096, 2)), jnp.int32)
    h = np.asarray(hash_ref.initial_slots(pool, hcap))
    bucket = np.bincount(h, minlength=hcap).argmax()
    cand = np.flatnonzero(h == bucket)[:24]  # all share home slot
    keys = pool[jnp.asarray(cand)]
    dup = jnp.concatenate([keys, keys[::-1], keys[:7]], axis=0)
    owner, slot, ok = hash_ops.hash_insert(dup, hcap, backend="hash_xla")
    assert bool(jnp.all(ok))
    occ = int(jnp.sum(owner < dup.shape[0]))
    assert occ == len(cand)  # every distinct key placed exactly once
    tk = hash_ops.table_keys(owner, dup)
    found = hash_ops.hash_lookup(tk, keys, jnp.ones(len(cand), bool), hcap,
                                 backend="hash_xla")
    assert bool(jnp.all(found >= 0))
    got = np.asarray(tk)[np.asarray(found)]
    np.testing.assert_array_equal(got, np.asarray(keys))
    absent = keys + jnp.int32(1 << 21)
    missed = hash_ops.hash_lookup(tk, absent, jnp.ones(len(cand), bool),
                                  hcap, backend="hash_xla")
    assert bool(jnp.all(missed == -1))


def test_collision_chain_longer_than_epoch_budget():
    """Regression: claims serialize one-per-epoch on a shared cluster
    frontier, so a single-bucket chain needs ~chain-length epochs. A
    fixed probes/inner_rounds epoch budget spuriously reported overflow
    for chains past ~hcap/16 on a mostly-empty table; the fix iterates
    while any row is alive and fails a row only after it ADVANCED through
    every slot."""
    hcap = 1024
    rng_ = np.random.default_rng(1)
    pool = jnp.asarray(rng_.integers(0, 1 << 30, size=(400_000, 1)),
                       jnp.int32)
    h = np.asarray(hash_ref.initial_slots(pool, hcap))
    bucket = np.bincount(h, minlength=hcap).argmax()
    cand = np.flatnonzero(h == bucket)
    assert len(cand) >= 200  # chain far beyond the old ~72-epoch budget
    keys = pool[jnp.asarray(cand[:200])]
    owner, slot, ok = hash_ops.hash_insert(keys, hcap, backend="hash_xla")
    assert bool(jnp.all(ok))  # 200 distinct keys, 1024 slots: all place
    assert int(jnp.sum(owner < keys.shape[0])) == 200
    tk = hash_ops.table_keys(owner, keys)
    np.testing.assert_array_equal(np.asarray(tk)[np.asarray(slot)],
                                  np.asarray(keys))


def test_duplicate_heavy_degenerate_points(rng):
    """All points identical: one simplex worth of lattice points, massive
    duplication per key."""
    x = jnp.tile(_points(rng, 1, 6), (500, 1))
    lat_s, lat_h = _pair(x)
    assert int(lat_h.m) == int(lat_s.m) <= 7
    _assert_structural_equiv(lat_s, lat_h)


def test_overflow_flags_above_90pct_occupancy(rng):
    """Near-full tables: results stay exact just under cap; one unique
    point past cap flips overflow (uncorrupted seg_ids) — identically to
    the sort oracle."""
    x = _points(rng, 128, 3, scale=5.0)
    m = int(L.build_lattice(x, spacing=0.5, r=1, backend="sort").m)
    snug = int(np.floor(m / 0.95))  # ~95% of capacity used
    assert m / snug > 0.9
    lat_s, lat_h = _pair(x, spacing=0.5, cap=snug)
    assert not bool(lat_h.overflow)
    _assert_structural_equiv(lat_s, lat_h)

    lat_s2, lat_h2 = _pair(x, spacing=0.5, cap=m - 1)
    assert bool(lat_s2.overflow) and bool(lat_h2.overflow)
    assert not bool(lat_h2.pack_overflow)
    seg = np.asarray(lat_h2.seg_ids)
    assert seg.min() >= 0 and seg.max() <= lat_h2.cap


def test_pack_overflow_semantics_match(rng):
    """|coord| > 2^15 sets pack_overflow AND overflow on the hash path,
    and build_lattice_auto refuses to grow its way out — the sort
    contract, verbatim."""
    far = _points(rng, 64, 2, scale=3e4)
    lat = L.build_lattice(far, spacing=0.5, r=1, backend="hash_xla")
    assert bool(lat.pack_overflow) and bool(lat.overflow)
    lat_auto = L.build_lattice_auto(far, spacing=0.5, r=1, cap=16,
                                    backend="hash_xla")
    assert bool(lat_auto.pack_overflow)
    assert lat_auto.cap <= 64  # no useless growth


def test_build_lattice_auto_hash_grows(rng):
    """Grow-and-retry clears a capacity overflow under the hash backend."""
    x = _points(rng, 128, 3, scale=3.0)
    lat = L.build_lattice_auto(x, spacing=0.5, r=1, cap=16,
                               backend="hash_xla")
    assert not bool(lat.overflow)
    assert int(lat.m) <= lat.cap


# ---------------------------------------------------------------------------
# Pallas kernels (interpreter off-TPU) vs the XLA reference.
# ---------------------------------------------------------------------------


def test_pallas_insert_lookup_interpret_parity(rng):
    """The Pallas kernels implement the same table semantics: identical
    placed-key sets and per-row resolution (slot NUMBERING may differ:
    sequential first-come claims vs epoch min-id claims)."""
    hcap = 256  # 90 distinct keys -> occupancy 0.35, all must place
    keys = jnp.asarray(rng.integers(0, 1 << 15, size=(90, 3)), jnp.int32)
    keys = jnp.concatenate([keys, keys[:30]], axis=0)  # duplicates

    ow_x, sl_x, ok_x = hash_ops.hash_insert(keys, hcap, backend="hash_xla")
    ow_p, sl_p, ok_p = hash_ops.hash_insert(keys, hcap,
                                            backend="hash_pallas",
                                            interpret=True)
    assert bool(jnp.all(ok_x)) and bool(jnp.all(ok_p))
    tk_x = hash_ops.table_keys(ow_x, keys)
    tk_p = hash_ops.table_keys(ow_p, keys)
    placed = lambda tk, ow: set(
        map(tuple, np.asarray(tk)[np.asarray(ow) < keys.shape[0]].tolist()))
    assert placed(tk_x, ow_x) == placed(tk_p, ow_p)
    # each row resolves to its own key under both kernels
    np.testing.assert_array_equal(np.asarray(tk_p)[np.asarray(sl_p)],
                                  np.asarray(keys))

    # lookup: same hits/misses, and hits resolve to the right keys
    queries = jnp.concatenate([keys[:40], keys[:40] + jnp.int32(1 << 16)])
    active = jnp.ones((queries.shape[0],), bool)
    res_x = hash_ops.hash_lookup(tk_x, queries, active, hcap,
                                 backend="hash_xla")
    res_p = hash_ops.hash_lookup(tk_p, queries, active, hcap,
                                 backend="hash_pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(res_x) >= 0,
                                  np.asarray(res_p) >= 0)
    hits = np.asarray(res_p) >= 0
    np.testing.assert_array_equal(
        np.asarray(tk_p)[np.asarray(res_p)[hits]],
        np.asarray(queries)[hits])
    # inactive queries short-circuit to -1
    res_inact = hash_ops.hash_lookup(tk_x, queries, jnp.zeros_like(active),
                                     hcap, backend="hash_xla")
    assert bool(jnp.all(res_inact == -1))


def test_insert_full_table_reports_failure():
    """More distinct keys than slots: ok=False for the overflow rows, and
    the table itself stays uncorrupted (every placed slot holds a real
    key)."""
    hcap = 16
    keys = jnp.arange(64, dtype=jnp.int32)[:, None] * jnp.int32(7919)
    owner, slot, ok = hash_ops.hash_insert(keys, hcap, backend="hash_xla")
    assert not bool(jnp.all(ok))
    assert int(jnp.sum(owner < keys.shape[0])) == hcap  # full
    tk = hash_ops.table_keys(owner, keys)
    occ = np.asarray(owner) < keys.shape[0]
    placed = np.asarray(tk)[occ]
    all_keys = {int(k) for k in np.asarray(keys)[:, 0]}
    assert {int(k) for k in placed[:, 0]}.issubset(all_keys)


# ---------------------------------------------------------------------------
# Policy / cache / GP integration.
# ---------------------------------------------------------------------------


def test_build_backend_policy():
    assert hash_ops.resolve_build_backend("sort") == "sort"
    assert hash_ops.resolve_build_backend("hash_xla") == "hash_xla"
    resolved = hash_ops.resolve_build_backend("auto", hcap=1024, npk=2)
    if jax.default_backend() == "tpu":
        assert resolved == "hash_pallas"
    else:
        assert resolved == "hash_xla"
    with pytest.raises(ValueError):
        hash_ops.resolve_build_backend("bogus")


def test_hash_capacity_invariants():
    for cap in (1, 7, 8, 1000, 4096):
        hcap = hash_ops.hash_capacity(cap)
        assert hcap >= 2 * cap  # occupancy <= 0.5 whenever m <= cap
        assert hcap & (hcap - 1) == 0  # power of two


def test_lattice_cache_keys_on_build_backend(rng):
    """Sort- and hash-built lattices for the SAME geometry must never
    alias in the cache (their slot numbering differs)."""
    from repro.core.filtering import LatticeCache
    x = _points(rng, 64, 3)
    cache = LatticeCache()
    tag = cache.point_set_tag(x)
    kw = dict(spacing=1.0, r=1, cap=256, ls=jnp.ones(3))
    lat_h = cache.get(tag, x, build_backend="hash_xla", **kw)
    lat_s = cache.get(tag, x, build_backend="sort", **kw)
    assert lat_h is not lat_s
    assert cache.misses == 2
    assert cache.get(tag, x, build_backend="hash_xla", **kw) is lat_h
    assert cache.get(tag, x, build_backend="sort", **kw) is lat_s
    assert cache.hits == 2
    # "auto" keys on its RESOLUTION: on this host it must HIT the
    # explicit hash entry, not build a duplicate lattice
    resolved = hash_ops.resolve_build_backend("auto", hcap=512, npk=2)
    lat_auto = cache.get(tag, x, build_backend="auto", **kw)
    if resolved == "hash_xla":
        assert lat_auto is lat_h
        assert cache.hits == 3 and cache.misses == 2


def test_gp_pipeline_parity_across_build_backends(rng):
    """End to end: MLL value/grads and posterior agree between build
    backends to f32 solver noise."""
    from repro.gp import (GPParams, SimplexGP, SimplexGPConfig,
                          mll_value_and_grad, posterior)
    n, ns, d = 96, 24, 2
    x = _points(rng, n, d)
    y = jnp.asarray(rng.normal(size=n), jnp.float32)
    xs = _points(rng, ns, d)
    params = GPParams.init(d)
    key = jax.random.PRNGKey(0)
    res, post = {}, {}
    for bk in ("sort", "hash_xla"):
        model = SimplexGP(SimplexGPConfig(kernel="matern32",
                                          max_cg_iters=200,
                                          cg_tol_eval=1e-4, num_probes=4,
                                          build_backend=bk))
        # tight tolerances: an UNCONVERGED CG iterate is path-sensitive,
        # so at the paper's loose tolerances f32-level operator noise
        # between equivalent builds legitimately shifts solve outputs
        res[bk] = mll_value_and_grad(model, params, x, y, key, tol=1e-6)
        post[bk] = posterior(model, params, x, y, xs, key=key,
                             variance_rank=8)
    assert np.isclose(float(res["sort"].mll), float(res["hash_xla"].mll),
                      rtol=2e-3, atol=1e-2)
    for g_s, g_h in zip(jax.tree.leaves(res["sort"].grads),
                        jax.tree.leaves(res["hash_xla"].grads)):
        np.testing.assert_allclose(np.asarray(g_s), np.asarray(g_h),
                                   rtol=5e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(post["sort"].mean),
                               np.asarray(post["hash_xla"].mean),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(post["sort"].var),
                               np.asarray(post["hash_xla"].var),
                               rtol=1e-3, atol=1e-4)


def test_hash_build_jaxpr_is_sort_free(rng):
    """Acceptance regression (ISSUE 5): the hash build path — embed,
    dedup insert, neighbor lookup, AND the splat plan — contains ZERO
    ``lax.sort`` primitives, asserted recursively on the jaxpr. The
    embed's vertex ranking is a pairwise comparison count and the plan
    is the counting/partition construction; only the "sort" oracle
    backend may sort."""
    from repro.sharding.simplex import count_primitive

    x = _points(rng, 200, 4)
    jaxpr = jax.make_jaxpr(
        lambda z: L._build_lattice_hash_impl(z, spacing=1.0, r=1, cap=512,
                                             backend="hash_xla"))(x)
    assert count_primitive(jaxpr, "sort") == 0
    # the oracle still sorts (sanity check that the counter works at all)
    jaxpr_sort = jax.make_jaxpr(
        lambda z: L._build_lattice_impl(z, spacing=1.0, r=1, cap=512))(x)
    assert count_primitive(jaxpr_sort, "sort") > 0


@pytest.mark.parametrize("shape", [(97, 3), (400, 5), (64, 1)])
def test_counting_plan_matches_stable_sort(rng, shape):
    """The sort-free splat plan is BIT-IDENTICAL to the stable single-key
    sort it replaced (ascending slot, original row order within a slot),
    including non-multiple-of-block sizes and the dump slot."""
    n, d = shape
    x = _points(rng, n, d, scale=0.4)  # clustered: heavy duplication
    lat = L.build_lattice(x, spacing=1.0, r=1, backend="hash_xla")
    big = n * (d + 1)
    ss, sp = jax.lax.sort((lat.seg_ids, jnp.arange(big, dtype=jnp.int32)),
                          num_keys=1)
    cs, cp = L._splat_plan_counting(lat.seg_ids, big=big, cap=lat.cap)
    np.testing.assert_array_equal(np.asarray(ss), np.asarray(cs))
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(cp))


def test_counting_plan_degenerate_single_slot():
    """Every contribution in ONE slot (the worst case for any
    rank-by-counting scheme) still yields the identity-stable plan."""
    big, cap = 1000, 64
    seg = jnp.full((big,), 7, jnp.int32)
    cs, cp = L._splat_plan_counting(seg, big=big, cap=cap)
    np.testing.assert_array_equal(np.asarray(cs), np.full(big, 7))
    np.testing.assert_array_equal(np.asarray(cp), np.arange(big))
