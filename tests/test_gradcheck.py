"""Finite-difference gradcheck for the §4.2 custom VJP.

Central differences pin down what the existing algebraic tests
(tests/test_filtering.py) cannot: that the implemented cotangents agree
with NUMERICAL derivatives, not merely with each other.

  * w.r.t. values v: ``lattice_filter`` is linear in v, so central
    differences of the lattice function itself are exact to f32 roundoff
    — a tight check of the transpose-filter cotangent.
  * w.r.t. lengthscale: the §4.2 gradient is, by construction, an
    approximation of the EXACT kernel MVM's gradient (it deliberately
    ignores the integer rounding), so the oracle is central differences
    of the DENSE quad form a^T K(ls) b — directional agreement within
    the lattice approximation error, same calibration as the paper's
    cosine-similarity claims.
  * ``lattice_filter_with`` (the prebuilt-lattice twin): identical
    cotangents to ``lattice_filter``, and its lattice cotangent is the
    symbolic-zero float0 path (integer leaves carry float0, inexact
    leaves carry zeros).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import filtering, kernels_math as km
from repro.core.lattice import build_lattice
from repro.core.stencil import make_stencil


def _data(rng, n, d, c=2):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    return x, v


def _central_diff(f, x0, direction, eps):
    return (f(x0 + eps * direction) - f(x0 - eps * direction)) / (2 * eps)


@pytest.mark.parametrize("entry", ["rebuild", "prebuilt"])
def test_gradcheck_wrt_values(rng, entry):
    """dL/dv vs central differences: exact (the filter is linear in v)."""
    n, d, c = 120, 3, 2
    x, v = _data(rng, n, d, c)
    s = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    st = make_stencil("matern32", 1)
    spec = filtering.spec_for(st)
    w = jnp.asarray(st.weights, jnp.float32)
    dw = jnp.asarray(st.dweights, jnp.float32)
    if entry == "rebuild":
        f = lambda vv: jnp.vdot(s, filtering.lattice_filter(x, vv, w, dw,
                                                            spec))
    else:
        lat = build_lattice(x, spacing=st.spacing, r=st.r)
        f = lambda vv: jnp.vdot(s, filtering.lattice_filter_with(
            lat, x, vv, w, dw, spec))
    grad = jax.grad(f)(v)
    rng2 = np.random.default_rng(7)
    for _ in range(4):
        direction = jnp.asarray(rng2.normal(size=v.shape), jnp.float32)
        direction = direction / jnp.linalg.norm(direction)
        fd = float(_central_diff(f, v, direction, eps=1e-2))
        an = float(jnp.vdot(grad, direction))
        assert abs(fd - an) <= 1e-3 * max(1.0, abs(an)), (fd, an)


@pytest.mark.parametrize("kernel", ["rbf", "matern32"])
def test_gradcheck_wrt_lengthscale_vs_dense_fd(rng, kernel):
    """d(a^T K(ls) b)/d(ls) — §4.2 analytic vs central differences of the
    DENSE oracle quad form, per-ARD-dimension."""
    n, d, c = 240, 3, 1
    x, v = _data(rng, n, d, c)
    a = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    st = make_stencil(kernel, 2)
    spec = filtering.spec_for(st)
    w = jnp.asarray(st.weights, jnp.float32)
    dw = jnp.asarray(st.dweights, jnp.float32)
    profile = km.get_profile(kernel)
    ls0 = jnp.asarray([1.1, 0.9, 1.3], jnp.float32)

    def lattice_quad(ls):
        return jnp.vdot(a, filtering.lattice_filter(x / ls[None, :], v, w,
                                                    dw, spec))

    def dense_quad(ls):
        # float64 numpy oracle: K(ls) b without any lattice
        xs = np.asarray(x, np.float64) / np.asarray(ls, np.float64)[None, :]
        tau = np.sqrt(np.maximum(
            ((xs[:, None, :] - xs[None, :, :]) ** 2).sum(-1), 0.0))
        kmat = np.asarray(profile.k(jnp.asarray(tau)), np.float64)
        return float(np.vdot(np.asarray(a, np.float64)[:, 0],
                             kmat @ np.asarray(v, np.float64)[:, 0]))

    grad = jax.grad(lattice_quad)(ls0)
    fd = np.array([
        _central_diff(lambda l: dense_quad(jnp.asarray(l, jnp.float32)),
                      np.asarray(ls0, np.float64), e, eps=1e-3)
        for e in np.eye(3)])
    grad = np.asarray(grad, np.float64)
    cos = float(grad @ fd / (np.linalg.norm(grad) * np.linalg.norm(fd)))
    assert cos > 0.9, (cos, grad, fd)
    # magnitudes agree to the lattice approximation level, not just sign
    assert np.linalg.norm(grad - fd) <= 0.5 * np.linalg.norm(fd), (grad, fd)


def test_prebuilt_matches_rebuild_gradients(rng):
    """lattice_filter_with reproduces lattice_filter's (z, v) cotangents
    exactly when handed the same lattice."""
    n, d, c = 100, 2, 2
    x, v = _data(rng, n, d, c)
    s = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    st = make_stencil("matern32", 1)
    spec = filtering.spec_for(st)
    w = jnp.asarray(st.weights, jnp.float32)
    dw = jnp.asarray(st.dweights, jnp.float32)
    lat = build_lattice(x, spacing=st.spacing, r=st.r)

    g1 = jax.grad(lambda z, vv: jnp.vdot(
        s, filtering.lattice_filter(z, vv, w, dw, spec)), argnums=(0, 1))(
            x, v)
    g2 = jax.grad(lambda z, vv: jnp.vdot(
        s, filtering.lattice_filter_with(lat, z, vv, w, dw, spec)),
        argnums=(0, 1))(x, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_float0_lattice_cotangent(rng):
    """The prebuilt lattice's cotangent is symbolically zero: float0 for
    integer/bool leaves, zero arrays for inexact leaves — so jit/grad
    compose over the shared-lattice path without touching the rounding."""
    n, d = 60, 2
    x, v = _data(rng, n, d, 1)
    st = make_stencil("rbf", 1)
    spec = filtering.spec_for(st)
    w = jnp.asarray(st.weights, jnp.float32)
    dw = jnp.asarray(st.dweights, jnp.float32)
    lat = build_lattice(x, spacing=st.spacing, r=st.r)

    out, vjp = jax.vjp(
        lambda lt, z, vv: filtering.lattice_filter_with(lt, z, vv, w, dw,
                                                        spec), lat, x, v)
    dlat, dz, dv = vjp(jnp.ones_like(out))
    leaves = jax.tree.leaves(dlat)
    assert leaves, "lattice cotangent should not be empty"
    for leaf in leaves:
        if np.asarray(leaf).dtype == jax.dtypes.float0:
            continue  # symbolic zero for integer leaves — the float0 path
        assert jnp.issubdtype(jnp.result_type(leaf), jnp.inexact)
        assert not np.any(np.asarray(leaf))
    # the real cotangents flow unharmed next to the float0 ones
    assert float(jnp.linalg.norm(dz)) > 0
    assert float(jnp.linalg.norm(dv)) > 0
