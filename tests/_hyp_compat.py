"""Hypothesis compatibility shim: property tests run everywhere.

The container image does not ship ``hypothesis``; importing it at module
scope made three tier-1 files fail at COLLECTION, killing the whole suite.
This shim re-exports the real library when present and otherwise provides a
minimal stand-in that replays each property over a fixed number of
deterministic pseudo-random examples — weaker than real shrinking/search,
but the invariants still get exercised on every host.

Usage (instead of ``from hypothesis import given, settings, strategies``):

    from _hyp_compat import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    st = _Strategies()

    _DEFAULT_EXAMPLES = 10

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # NOTE: the wrapper must expose a ZERO-argument signature (no
            # functools.wraps/__wrapped__), or pytest would try to resolve
            # the property's parameters as fixtures.
            def wrapper():
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
                rng = np.random.default_rng(0xA11CE)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
