"""Permutohedral lattice geometry invariants (paper §3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core import lattice as L

DIMS = [1, 2, 3, 5, 8, 11]


def _points(rng, n, d, scale=1.0):
    return jnp.asarray(rng.normal(size=(n, d)) * scale, jnp.float32)


@pytest.mark.parametrize("d", DIMS)
def test_elevation_is_isometry(rng, d):
    """The scaled triangular elevation preserves distances (x alpha)."""
    x = _points(rng, 64, d)
    spacing = 1.3
    el = L.elevate(x, spacing)
    # rows sum to ~0 (lies in H_d)
    np.testing.assert_allclose(np.asarray(jnp.sum(el, axis=1)), 0.0,
                               atol=2e-3 * d)
    alpha = L.step_scale(d, spacing)
    d_in = np.linalg.norm(np.asarray(x[:1] - x[1:2]))
    d_el = np.linalg.norm(np.asarray(el[:1] - el[1:2]))
    assert abs(d_el / d_in - alpha) < 1e-3 * alpha


@pytest.mark.parametrize("d", DIMS)
def test_simplex_embed_invariants(rng, d):
    x = _points(rng, 256, d)
    keys, w = L.simplex_embed(x, spacing=1.0)
    w = np.asarray(w)
    keys = np.asarray(keys)
    # barycentric weights: sum to 1, in [0, 1]
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-4)
    assert w.min() > -1e-4 and w.max() < 1 + 1e-4
    # every vertex key lies on the lattice plane sum == 0
    assert (keys.sum(-1) == 0).all()
    # vertices have distinct remainders 0..d (mod d+1) — permutohedral
    rem = np.sort(keys[..., 0] % (d + 1), axis=1)
    np.testing.assert_array_equal(rem, np.arange(d + 1)[None, :]
                                  .repeat(keys.shape[0], 0))
    # barycentric reconstruction: sum_k w_k key_k ~= elevated point
    el = np.asarray(L.elevate(x, 1.0))
    recon = np.einsum("nkj,nk->nj", keys.astype(np.float64), w)
    np.testing.assert_allclose(recon, el, atol=5e-2 * max(d, 2))


@pytest.mark.parametrize("d", [2, 4, 7])
def test_dedup_matches_numpy_unique(rng, d):
    x = _points(rng, 300, d)
    lat = L.build_lattice(x, spacing=1.0, r=1)
    keys, _ = L.simplex_embed(x, spacing=1.0)
    uniq = np.unique(np.asarray(keys).reshape(-1, d + 1), axis=0)
    assert int(lat.m) == uniq.shape[0]
    assert not bool(lat.overflow)
    got = np.asarray(lat.coords)[np.asarray(lat.valid)]
    got = got[np.lexsort(got.T[::-1])]
    np.testing.assert_array_equal(got, uniq)


@pytest.mark.parametrize("d,r", [(2, 1), (3, 2), (6, 1)])
def test_neighbor_table_offsets(rng, d, r):
    x = _points(rng, 200, d)
    lat = L.build_lattice(x, spacing=1.0, r=r)
    coords = np.asarray(lat.coords)
    valid = np.asarray(lat.valid)
    nbr = np.asarray(lat.nbr)  # (d+1, cap+1, 2r)
    eye = np.eye(d + 1, dtype=np.int64)
    steps = np.concatenate([np.arange(-r, 0), np.arange(1, r + 1)])
    coord_set = {tuple(c) for c in coords[valid]}
    for a in range(d + 1):
        dirv = (d + 1) * eye[a] - 1
        for p in np.flatnonzero(valid)[:50]:
            for si, s in enumerate(steps):
                want = tuple(coords[p] + s * dirv)
                j = nbr[a, p, si]
                if j == lat.cap:  # miss: must really be absent
                    assert want not in coord_set
                else:
                    assert tuple(coords[j]) == want


def test_overflow_flag(rng):
    x = _points(rng, 128, 3, scale=5.0)
    lat = L.build_lattice(x, spacing=0.5, r=1, cap=8)
    assert bool(lat.overflow)


def test_capacity_default():
    assert L.default_capacity(100, 7) == 800


@settings(max_examples=25, deadline=None)
@given(d=st.integers(1, 6), seed=st.integers(0, 2 ** 16),
       scale=st.floats(0.1, 10.0))
def test_property_weights_and_plane(d, seed, scale):
    """Hypothesis: invariants hold for arbitrary dims/scales/seeds."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32, d)) * scale, jnp.float32)
    keys, w = L.simplex_embed(x, spacing=1.0)
    w = np.asarray(w)
    assert np.all(np.abs(w.sum(1) - 1.0) < 1e-3)
    assert w.min() > -1e-3
    assert (np.asarray(keys).sum(-1) == 0).all()


@settings(max_examples=10, deadline=None)
@given(d=st.integers(1, 5), seed=st.integers(0, 999))
def test_property_splat_slice_mass(d, seed):
    """splat^T preserves total mass: sum(splat(v)) == sum(v)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(50, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(50, 2)), jnp.float32)
    lat = L.build_lattice(x, spacing=1.0, r=1)
    splatted = L.splat(lat, v)
    np.testing.assert_allclose(np.asarray(jnp.sum(splatted, axis=0)),
                               np.asarray(jnp.sum(v, axis=0)), rtol=2e-4,
                               atol=1e-4)


@pytest.mark.parametrize("d", DIMS)
def test_pack_unpack_roundtrip(rng, d):
    """C2 fast build path: the packed sort keys are lossless, so coords can
    be reconstructed from them after the dedup sort (no payload columns)."""
    keys = jnp.asarray(rng.integers(-500, 500, size=(200, d)), jnp.int32)
    keys = jnp.concatenate([keys, -jnp.sum(keys, axis=1, keepdims=True)],
                           axis=1)  # zero-sum like real lattice coords
    packed = jnp.stack(L._pack_key_cols(keys), axis=1)
    got = L._unpack_key_cols(packed, d + 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(keys))


def test_build_count_increments(rng):
    x = _points(rng, 40, 3)
    c0 = L.build_count()
    L.build_lattice(x, spacing=1.0, r=1)
    L.build_lattice(x, spacing=1.0, r=1)
    assert L.build_count() - c0 == 2


def test_pack_overflow_flag_distinct_from_capacity(rng):
    """Coordinate-range overflow sets BOTH flags (results invalid) and is
    reported separately, since growing cap cannot fix it; a plain capacity
    overflow leaves pack_overflow clear."""
    x = _points(rng, 64, 2, scale=5.0)
    lat = L.build_lattice(x, spacing=0.5, r=1, cap=8)
    assert bool(lat.overflow) and not bool(lat.pack_overflow)

    far = _points(rng, 64, 2, scale=3e4)  # coords blow past +/-2^15
    lat2 = L.build_lattice(far, spacing=0.5, r=1)
    assert bool(lat2.pack_overflow) and bool(lat2.overflow)
    # build_lattice_auto must not burn retries growing an unfixable table
    lat3 = L.build_lattice_auto(far, spacing=0.5, r=1, cap=16)
    assert bool(lat3.pack_overflow)
    assert lat3.cap <= 64  # no useless growth
