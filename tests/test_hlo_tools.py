"""HLO analysis tools: collective parsing + trip-count-aware costs."""
import os
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils import hlo, hlo_cost


def test_shape_bytes():
    assert hlo.shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert hlo.shape_bytes("bf16[2,2]{1,0}") == 8
    assert hlo.shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert hlo.shape_bytes("pred[]") == 1


def test_collective_stats_parsing():
    text = """
HloModule m
ENTRY %main {
  %p = f32[64,64] parameter(0)
  %ag = f32[64,256] all-gather(%p), dimensions={1}
  %ar = f32[64,64] all-reduce(%p), to_apply=%add
  %rs = f32[16,64] reduce-scatter(%p), dimensions={0}
}
"""
    st = hlo.collective_stats(text)
    assert st.by_kind["all-gather"][0] == 1
    assert st.by_kind["all-gather"][1] == 64 * 256 * 4
    assert st.total_bytes == (64 * 256 + 64 * 64 + 16 * 64) * 4


def test_trip_count_scaling_on_scan():
    """The analyzer multiplies scanned-body flops by the trip count."""
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out, _ = jax.lax.scan(body, x, w)
        return out.sum()

    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((24, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(xs, ws).compile()
    cost = hlo_cost.analyze(compiled.as_text())
    # 24 iterations x 2*64^3 flops
    want = 24 * 2 * 64 ** 3
    assert 0.8 * want <= cost.flops <= 1.5 * want
    assert any(v == 24 for v in cost.trip_counts.values())


def test_dot_flops_vs_xla_costs_nonloop():
    """Without loops our dot counting matches XLA's cost analysis."""
    def f(a, b):
        return (a @ b).sum()

    aa = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    bb = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    compiled = jax.jit(f).lower(aa, bb).compile()
    cost = hlo_cost.analyze(compiled.as_text())
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0]
    assert abs(cost.flops - float(ca["flops"])) < 0.2 * float(ca["flops"])
