"""Sharded lattice MVM ≡ single-device, pinned on 8 virtual CPU devices.

Two layers of defense (DESIGN.md §10):
  * in-process (always runs, 1 real device): the one-psum-per-MVM contract
    is a property of the traced program, so it is asserted on the jaxpr
    with a 1-device mesh — the trace is identical for any axis size;
  * subprocess (marker ``multidevice``, still tier-1): numerical
    equivalence of the sharded path against the single-device fused path
    on a REAL 8-device mesh, plus the end-to-end sharded GP step/posterior.
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lattice as lat_mod
from repro.core.stencil import make_stencil
from repro.kernels.blur.ops import lattice_mvm
from repro.sharding import simplex as sx


def _problem(rng, n, d, c):
    z = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    return z, v


def test_one_psum_per_mvm_jaxpr(rng):
    """Exactly one psum — and no other collective — per sharded MVM,
    including the symmetrized and transposed variants."""
    st = make_stencil("matern32", 1)
    z, v = _problem(rng, 64, 3, 3)
    lat = lat_mod.build_lattice(z, spacing=st.spacing, r=st.r)
    mesh = sx.data_mesh()
    w = jnp.asarray(st.weights, jnp.float32)
    for sym in (False, True):
        for tr in (False, True):
            counts = sx.collective_counts(
                lambda vv: sx.sharded_lattice_mvm(
                    lat, vv, w, mesh=mesh, symmetrize=sym, transpose=tr), v)
            assert counts["psum"] == 1, (sym, tr, counts)
            for prim, cnt in counts.items():
                if prim != "psum":
                    assert cnt == 0, (sym, tr, counts)


def test_sharded_matches_single_device_one_dev_mesh(rng):
    """1-device-mesh smoke of the sharded path (full 8-dev run below)."""
    st = make_stencil("rbf", 1)
    z, v = _problem(rng, 80, 2, 2)
    lat = lat_mod.build_lattice(z, spacing=st.spacing, r=st.r)
    w = jnp.asarray(st.weights, jnp.float32)
    ref = lattice_mvm(lat, v, w, backend="fused_xla")
    got = sx.sharded_lattice_mvm(lat, v, w, mesh=sx.data_mesh())
    err = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert err <= 1e-5


def test_shard_rows_any_n():
    """The divisibility cliff is gone: any n shards via ghost padding."""

    class _Mesh:
        shape = {"data": 8}

    m = _Mesh()
    assert sx.shard_rows(16, m, "data") == (2, 0)
    assert sx.shard_rows(7, m, "data") == (1, 1)  # n < axis size
    assert sx.shard_rows(17, m, "data") == (3, 7)
    assert sx.check_shardable(17, m, "data") == 3  # legacy alias: no raise


@pytest.mark.parametrize("n", [7, 80, 81, 3])
def test_padded_sharded_mvm_matches_fused(rng, n):
    """Ghost-row padding: indivisible n (including n < axis size on the
    8-dev subprocess run below; here the 1-dev mesh pins the pad==0
    no-op) matches the single-device fused operator."""
    st = make_stencil("rbf", 1)
    z, v = _problem(rng, n, 2, 2)
    lat = lat_mod.build_lattice(z, spacing=st.spacing, r=st.r)
    w = jnp.asarray(st.weights, jnp.float32)
    ref = lattice_mvm(lat, v, w, backend="fused_xla")
    got = sx.sharded_lattice_mvm(lat, v, w, mesh=sx.data_mesh())
    assert got.shape == ref.shape
    err = float(jnp.linalg.norm(got - ref)
                / max(float(jnp.linalg.norm(ref)), 1e-30))
    assert err <= 1e-5


def test_padded_sharded_mvm_one_psum(rng):
    """Padding happens outside shard_map: the one-psum contract (and the
    no-other-collective contract) hold for indivisible n too."""
    st = make_stencil("matern32", 1)
    z, v = _problem(rng, 37, 3, 2)
    lat = lat_mod.build_lattice(z, spacing=st.spacing, r=st.r)
    mesh = sx.data_mesh()
    w = jnp.asarray(st.weights, jnp.float32)
    counts = sx.collective_counts(
        lambda vv: sx.sharded_lattice_mvm(lat, vv, w, mesh=mesh), v)
    assert counts["psum"] == 1
    assert all(c == 0 for p, c in counts.items() if p != "psum")


SHARDED_MVM = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import lattice as lat_mod
    from repro.core.stencil import make_stencil
    from repro.kernels.blur.ops import lattice_mvm
    from repro.sharding import simplex as sx

    rng = np.random.default_rng(0)
    n, d, c = 1024, 3, 4
    st = make_stencil("matern32", 1)
    z = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    lat = lat_mod.build_lattice_auto(z, spacing=st.spacing, r=st.r)
    w = jnp.asarray(st.weights, jnp.float32)
    mesh = sx.data_mesh()

    ref = lattice_mvm(lat, v, w, backend="fused_xla")
    fn = jax.jit(lambda vv: sx.sharded_lattice_mvm(lat, vv, w, mesh=mesh))
    got = jax.block_until_ready(fn(v))
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    counts = sx.collective_counts(
        lambda vv: sx.sharded_lattice_mvm(lat, vv, w, mesh=mesh), v)
    print(json.dumps({"devices": jax.device_count(), "rel_err": rel,
                      "psums": counts["psum"],
                      "other": sum(v for k, v in counts.items()
                                   if k != "psum")}))
""")


@pytest.mark.multidevice
def test_sharded_mvm_8dev_matches_fused(multidevice_run):
    data = multidevice_run(SHARDED_MVM)
    assert data["devices"] == 8
    assert data["rel_err"] <= 1e-5
    assert data["psums"] == 1
    assert data["other"] == 0


PADDED_MVM = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import lattice as lat_mod
    from repro.core.stencil import make_stencil
    from repro.kernels.blur.ops import lattice_mvm
    from repro.sharding import simplex as sx

    rng = np.random.default_rng(1)
    st = make_stencil("matern32", 1)
    mesh = sx.data_mesh()
    out = {"devices": jax.device_count(), "cases": {}}
    # 1003 = 8*125+3 (real ghost rows); 5 < 8 (whole devices all-ghost)
    for n in (1003, 5):
        d, c = 3, 2
        z = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
        lat = lat_mod.build_lattice_auto(z, spacing=st.spacing, r=st.r)
        w = jnp.asarray(st.weights, jnp.float32)
        ref = lattice_mvm(lat, v, w, backend="fused_xla")
        got = jax.jit(lambda vv: sx.sharded_lattice_mvm(
            lat, vv, w, mesh=mesh))(v)
        counts = sx.collective_counts(
            lambda vv: sx.sharded_lattice_mvm(lat, vv, w, mesh=mesh), v)
        out["cases"][str(n)] = {
            "shape_ok": got.shape == ref.shape,
            "rel_err": float(jnp.linalg.norm(got - ref)
                             / jnp.linalg.norm(ref)),
            "psums": counts["psum"],
            "other": sum(cc for kk, cc in counts.items() if kk != "psum")}
    print(json.dumps(out))
""")


@pytest.mark.multidevice
def test_padded_sharded_mvm_8dev(multidevice_run):
    """Uneven-shard regression: n % 8 != 0 and n < 8 both serve the exact
    operator on a REAL 8-device mesh with exactly one psum."""
    data = multidevice_run(PADDED_MVM)
    assert data["devices"] == 8
    for n, row in data["cases"].items():
        assert row["shape_ok"], n
        assert row["rel_err"] <= 1e-5, (n, row)
        assert row["psums"] == 1 and row["other"] == 0, (n, row)


SHARDED_GP = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.gp import (GPParams, SimplexGP, SimplexGPConfig,
                          mll_value_and_grad, posterior)
    from repro.sharding import simplex as sx

    rng = np.random.default_rng(0)
    n, d, ns = 512, 3, 64
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(np.sin(2 * np.asarray(x[:, 0]))
                    + 0.1 * rng.normal(size=n), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(ns, d)), jnp.float32)
    model = SimplexGP(SimplexGPConfig(kernel="matern32", max_cg_iters=200,
                                      num_probes=4, cg_tol_eval=1e-4))
    params = GPParams.init(d)
    mesh = sx.data_mesh()
    key = jax.random.PRNGKey(0)

    r0 = mll_value_and_grad(model, params, x, y, key)
    r1 = mll_value_and_grad(model, params, x, y, key, mesh=mesh)
    p0 = posterior(model, params, x, y, xs, key=key, variance_rank=8)
    p1 = posterior(model, params, x, y, xs, key=key, variance_rank=8,
                   mesh=mesh)
    mdenom = float(jnp.linalg.norm(p0.mean)) or 1.0
    print(json.dumps({
        "devices": jax.device_count(),
        "mll_rel": abs(float(r1.mll) - float(r0.mll))
                   / max(1.0, abs(float(r0.mll))),
        "mean_rel": float(jnp.linalg.norm(p1.mean - p0.mean)) / mdenom,
        "var_max": float(jnp.max(jnp.abs(p1.var - p0.var))),
        "grads_finite": all(bool(jnp.all(jnp.isfinite(g)))
                            for g in jax.tree.leaves(r1.grads)),
    }))
""")


@pytest.mark.multidevice
def test_sharded_gp_step_and_posterior_8dev(multidevice_run):
    """The whole GP stack (mBCG MLL + LOVE posterior) under a sharded
    operator reproduces the single-device numbers on 8 devices."""
    data = multidevice_run(SHARDED_GP)
    assert data["devices"] == 8
    # An UNCONVERGED CG iterate is path-sensitive: at the loose default
    # eval tolerance, f32 summation-order noise (sharding or build-path
    # slot ordering) steers CG through visibly different iterates, so the
    # old ~1e-2 fence measured solver luck, not sharding correctness. At
    # eval tol 1e-4 with iteration headroom both sides converge and the
    # sharded posterior mean matches to ~1e-4 (measured 9.6e-5); the MLL
    # keeps the paper's train tolerance and stays a ~1% stochastic match.
    assert data["mll_rel"] <= 2e-2
    assert data["mean_rel"] <= 1e-3
    assert data["var_max"] <= 5e-3
    assert data["grads_finite"]
