import json
import os
import subprocess
import sys

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — tests must see the 1 real CPU device.
# Multi-device tests (marker ``multidevice``) run their sharded half in a
# subprocess whose environment carries MULTIDEVICE_XLA_FLAGS; the
# ``multidevice_run`` fixture below is the lane's entry point. That keeps
# the 8 virtual CPU devices OUT of this process (XLA reads the flag once,
# at backend init) while the lane still runs inside tier-1 on any host.

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
MULTIDEVICE_DEVICES = 8
MULTIDEVICE_XLA_FLAGS = (
    f"--xla_force_host_platform_device_count={MULTIDEVICE_DEVICES}")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def multidevice_run():
    """Run a python snippet under 8 virtual CPU devices; return its JSON.

    The snippet must print a single JSON object as its last stdout line.
    Existing XLA_FLAGS are preserved (the device-count flag is appended).
    """

    def run(code: str, timeout: int = 600) -> dict:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " "
                            + MULTIDEVICE_XLA_FLAGS).strip()
        extra = env.get("PYTHONPATH")
        env["PYTHONPATH"] = SRC + (os.pathsep + extra if extra else "")
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=timeout)
        assert out.returncode == 0, out.stderr[-3000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    return run


def pytest_addoption(parser):
    parser.addoption("--slow", action="store_true", default=False,
                     help="run slow integration tests")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--slow"):
        return
    skip = pytest.mark.skip(reason="slow; use --slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
