import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — tests must see the 1 real CPU device.
# Sharded-execution tests spawn subprocesses with their own flags.


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption("--slow", action="store_true", default=False,
                     help="run slow integration tests")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--slow"):
        return
    skip = pytest.mark.skip(reason="slow; use --slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
