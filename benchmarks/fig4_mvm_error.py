"""Fig 4: Simplex-GP MVM cosine error vs blur-stencil order r.

Reproduces the paper's observation: errors sit at the 1e-3..1e-1 level
and increasing r does NOT monotonically reduce them (blur truncation vs
spacing trade-off).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit
from repro.core import filtering, kernels_math as km
from repro.core.stencil import make_stencil
from repro.data.synthetic_uci import all_names, load

DATASETS = {"precipitation": 0.002, "keggdirected": 0.02, "protein": 0.02,
            "elevators": 0.05}


def cosine_err(a, b):
    return 1.0 - float(jnp.vdot(a, b)
                       / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))


def main():
    for name, scale in DATASETS.items():
        ds = load(name, scale=scale * SCALE)
        n = min(ds.x_train.shape[0], 2000)
        x = jnp.asarray(ds.x_train[:n])
        v = jnp.asarray(np.random.default_rng(0).normal(
            size=(n, 1)), jnp.float32)
        ref = km.dense_mvm(km.MATERN32, x, v)
        for r in (1, 2, 3):
            st = make_stencil("matern32", r)
            mv, lat = filtering.mvm_operator(x, st)
            err = cosine_err(mv(v), ref)
            emit(f"fig4/{name}/r{r}", None,
                 f"cosine_err={err:.3e} n={n} d={x.shape[1]} "
                 f"m={int(lat.m)}")


if __name__ == "__main__":
    main()
