"""Shared benchmark helpers: timing + CSV emission + JSON artifacts."""
from __future__ import annotations

import json
import os
import pathlib
import time

import jax

# machine-readable benchmark artifacts land next to the repo root so the
# perf trajectory can be tracked across PRs (BENCH_*.json)
ARTIFACT_DIR = pathlib.Path(os.environ.get(
    "BENCH_ARTIFACT_DIR", pathlib.Path(__file__).resolve().parents[1]))

# default subsample so `python -m benchmarks.run` finishes on 1 CPU core;
# crank BENCH_SCALE up for larger runs.
SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (blocks on device results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float | None, derived: str):
    us = f"{seconds * 1e6:.1f}" if seconds is not None else ""
    print(f"{name},{us},{derived}")


def write_json(name: str, payload: dict) -> pathlib.Path:
    """Write a BENCH_*.json artifact (adds host metadata)."""
    out = dict(payload)
    out.setdefault("host", {})
    out["host"].update({
        "jax_backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "device_count": jax.device_count(),
        "bench_scale": SCALE,
    })
    path = ARTIFACT_DIR / name
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {path}")
    return path
