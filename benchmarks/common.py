"""Shared benchmark helpers: timing + CSV emission."""
from __future__ import annotations

import os
import time

import jax

# default subsample so `python -m benchmarks.run` finishes on 1 CPU core;
# crank BENCH_SCALE up for larger runs.
SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (blocks on device results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float | None, derived: str):
    us = f"{seconds * 1e6:.1f}" if seconds is not None else ""
    print(f"{name},{us},{derived}")
