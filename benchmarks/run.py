"""Benchmark orchestrator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Default sizes finish on one
CPU core; BENCH_SCALE=10 approaches the paper's regimes.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig4 table3 # subset
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (fig4_mvm_error, fig6_mvm_speed, fig_build,
                        fig_elastic, fig_recovery, fig_rollout,
                        fig_scaling, fig_serve, fig_soak, fig_train_step,
                        roofline_report, table2_uci, table3_sparsity,
                        table4_cg)

MODULES = {
    "fig4": fig4_mvm_error,
    "table3": table3_sparsity,
    "fig6": fig6_mvm_speed,
    "fig_build": fig_build,
    "fig_train": fig_train_step,
    "fig_scaling": fig_scaling,
    "fig_serve": fig_serve,
    "fig_rollout": fig_rollout,
    "fig_soak": fig_soak,
    "fig_recovery": fig_recovery,
    "fig_elastic": fig_elastic,
    "table4": table4_cg,
    "table2": table2_uci,
    "roofline": roofline_report,
}


def main() -> None:
    wanted = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")
    for key in wanted:
        mod = MODULES[key]
        t0 = time.time()
        try:
            mod.main()
            print(f"{key}/TOTAL,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception as e:  # keep the suite going
            traceback.print_exc()
            print(f"{key}/TOTAL,,ERROR {e}")
    # machine-readable artifacts written by the modules (BENCH_*.json)
    from benchmarks.common import ARTIFACT_DIR
    arts = sorted(p.name for p in ARTIFACT_DIR.glob("BENCH_*.json"))
    if arts:
        print(f"# artifacts in {ARTIFACT_DIR}: {', '.join(arts)}")


if __name__ == "__main__":
    main()
