"""Table 2: test RMSE / NLL across methods on the (synthetic) UCI suite.

Methods: Exact GP (subsampled, the Wang et al. 2019 role), SGPR (m=512),
SKIP, Simplex-GP. The paper's claims checked here:
  * Simplex-GP beats SKIP on RMSE,
  * Simplex-GP is competitive with SGPR and close to Exact.
Datasets are subsampled for the CPU host (BENCH_SCALE scales them up).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit
from repro.core import kernels_math as km
from repro.core.exact import ExactGP
from repro.core.sgpr import SGPR, select_inducing
from repro.core.skip import skip_operator
from repro.gp import (GPParams, SimplexGP, SimplexGPConfig, fit, nll,
                      posterior, rmse)
from repro.gp.models import softplus
from repro.data.synthetic_uci import load
from repro.optim import Adam
from repro.solvers import cg

DATASETS = {"precipitation": 0.004, "keggdirected": 0.05, "protein": 0.05,
            "elevators": 0.15}
EPOCHS = 8


def _fit_exact(ds, n_max=800):
    eg = ExactGP(km.MATERN32)
    x = jnp.asarray(ds.x_train[:n_max])
    y = jnp.asarray(ds.y_train[:n_max])
    p = GPParams.init(x.shape[1], noise=0.1)
    opt = Adam(learning_rate=0.1)
    s = opt.init(p)

    @jax.jit
    def step(p, s):
        def neg(p):
            ls, os_, nz = (softplus(p.raw_lengthscale),
                           softplus(p.raw_outputscale),
                           softplus(p.raw_noise) + 1e-4)
            return -eg.mll(x, y, lengthscale=ls, outputscale=os_, noise=nz)
        return opt.update(jax.grad(neg)(p), s, p)

    for _ in range(EPOCHS):
        p, s = step(p, s)
    ls, os_, nz = (softplus(p.raw_lengthscale),
                   softplus(p.raw_outputscale),
                   softplus(p.raw_noise) + 1e-4)
    post = eg.posterior(x, y, jnp.asarray(ds.x_test), lengthscale=ls,
                        outputscale=os_, noise=nz)
    ytest = jnp.asarray(ds.y_test)
    r = float(jnp.sqrt(jnp.mean((post.mean - ytest) ** 2)))
    s2 = post.var + nz
    n = float(jnp.mean(0.5 * jnp.log(2 * jnp.pi * s2)
                       + 0.5 * (ytest - post.mean) ** 2 / s2))
    return r, n


def _fit_sgpr(ds, m=512):
    x = jnp.asarray(ds.x_train)
    y = jnp.asarray(ds.y_train)
    sg = SGPR(km.MATERN32, select_inducing(jax.random.PRNGKey(0), x,
                                           min(m, x.shape[0] // 2)))
    p = GPParams.init(x.shape[1], noise=0.1)
    opt = Adam(learning_rate=0.1)
    s = opt.init(p)

    @jax.jit
    def step(p, s):
        def neg(p):
            ls, os_, nz = (softplus(p.raw_lengthscale),
                           softplus(p.raw_outputscale),
                           softplus(p.raw_noise) + 1e-4)
            return -sg.mll(x, y, lengthscale=ls, outputscale=os_, noise=nz)
        return opt.update(jax.grad(neg)(p), s, p)

    for _ in range(EPOCHS):
        p, s = step(p, s)
    ls, os_, nz = (softplus(p.raw_lengthscale),
                   softplus(p.raw_outputscale),
                   softplus(p.raw_noise) + 1e-4)
    mean, var = sg.posterior(x, y, jnp.asarray(ds.x_test), lengthscale=ls,
                             outputscale=os_, noise=nz)
    ytest = jnp.asarray(ds.y_test)
    r = float(jnp.sqrt(jnp.mean((mean - ytest) ** 2)))
    s2 = var + nz
    n = float(jnp.mean(0.5 * jnp.log(2 * jnp.pi * s2)
                       + 0.5 * (ytest - mean) ** 2 / s2))
    return r, n


def _fit_skip(ds, rank=24):
    """SKIP posterior mean via CG on (R R^T + s2 I); fixed unit ls."""
    x = jnp.asarray(ds.x_train)
    y = jnp.asarray(ds.y_train)
    op = skip_operator(km.MATERN32, x, grid_size=48, rank=rank)
    s2 = jnp.float32(0.1)
    sol, _ = cg(lambda v: op.mvm(v) + s2 * v, y[:, None], tol=1e-3,
                max_iters=200)
    xt = jnp.asarray(ds.x_test)
    kxs = km.gram(km.MATERN32, xt, x)
    mean = kxs @ sol[:, 0]
    ytest = jnp.asarray(ds.y_test)
    r = float(jnp.sqrt(jnp.mean((mean - ytest) ** 2)))
    return r, float("nan")


def _fit_simplex(ds):
    model = SimplexGP(SimplexGPConfig(kernel="matern32", order=1,
                                      max_cg_iters=40, num_probes=6,
                                      grad_mode="autodiff",
                                      max_lanczos_iters=20))
    res = fit(model, jnp.asarray(ds.x_train), jnp.asarray(ds.y_train),
              x_val=jnp.asarray(ds.x_val), y_val=jnp.asarray(ds.y_val),
              epochs=EPOCHS, lr=0.1, patience=EPOCHS)
    post = posterior(model, res.best_params, jnp.asarray(ds.x_train),
                     jnp.asarray(ds.y_train), jnp.asarray(ds.x_test),
                     key=jax.random.PRNGKey(1))
    ytest = jnp.asarray(ds.y_test)
    r = float(rmse(post, ytest))
    n = float(nll(post, model.constrained(res.best_params)[2], ytest))
    return r, n


def main():
    for name, frac in DATASETS.items():
        ds = load(name, scale=frac * SCALE)
        rows = {}
        for label, fitter in [("exact", _fit_exact), ("sgpr", _fit_sgpr),
                              ("skip", _fit_skip),
                              ("simplexgp", _fit_simplex)]:
            t0 = time.time()
            try:
                r, n = fitter(ds)
                rows[label] = r
                emit(f"table2/{name}/{label}", time.time() - t0,
                     f"rmse={r:.3f} nll={n:.3f} n={ds.n} d={ds.d}")
            except Exception as e:  # pragma: no cover
                emit(f"table2/{name}/{label}", None, f"ERROR {e}")
        if {"simplexgp", "skip"} <= rows.keys():
            emit(f"table2/{name}/claim", None,
                 f"simplex_beats_skip={rows['simplexgp'] < rows['skip']}")


if __name__ == "__main__":
    main()
