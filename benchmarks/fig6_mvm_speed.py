"""Fig 6: Simplex-GP MVM wall time vs exact MVM, across n — per backend.

The paper's claim: lattice MVMs overtake exact MVMs as n grows (10x at
n ~ 1e6 on GPU). On this CPU host the crossover appears at smaller n; the
benchmark reports both times and the speedup so the TREND is the check.
Amortization matters: the lattice build is done once per hyperparameter
setting, so per-MVM cost excludes the build (reported separately), exactly
like the paper's CG-loop usage.

Beyond the paper figure this also races the backend tiers of the fused
lattice-MVM rework (kernels/blur/ops.py):

  * per_direction — the pre-fusion path (segment_sum splat + one blur
    dispatch per direction + slice), jitted, on the same lattice;
  * fused — the policy-chosen fused backend (single fused kernel/program
    with the scatter-free sorted-segment splat).

Both run on ONE auto-capped lattice so the comparison isolates the fused
rework, and the fused output is checked against the op-for-op reference
(kernels/blur/ref.py). Results land in BENCH_mvm.json (per-backend µs/MVM,
build seconds, m) so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit, timeit, write_json
from repro.core import filtering
from repro.core import kernels_math as km
from repro.core.exact import chunked_mvm
from repro.core.lattice import build_lattice_auto
from repro.core.stencil import make_stencil
from repro.kernels.blur import ref as blur_ref
from repro.kernels.blur.ops import choose_backend

SIZES = [1000, 4000, 16000, 64000]
D = 8
# exact O(n^2 d) MVMs get prohibitive on CPU well before the paper's n;
# the lattice backends are what must scale, so cap the oracle column.
EXACT_MAX_N = 16000


def main():
    rng = np.random.default_rng(0)
    st = make_stencil("matern32", 1)
    taps = tuple(st.weights)
    w = jnp.asarray(st.weights, jnp.float32)
    rows = []
    for n in [int(s * SCALE) for s in SIZES]:
        x = jnp.asarray(rng.normal(size=(n, D)) * 0.3, jnp.float32)
        v = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
        v = v / jnp.linalg.norm(v)  # unit RHS: abs err is scale-honest

        t0 = time.perf_counter()
        lat = build_lattice_auto(x, spacing=st.spacing, r=st.r)
        jax.block_until_ready(lat.nbr)
        build_s = time.perf_counter() - t0
        m = int(lat.m)

        fused_backend = choose_backend(n=n, d=D, r=st.r, cap1=lat.cap + 1,
                                       c=1)
        per_dir = jax.jit(lambda lt, vv: filtering.filter_mvm(
            lt, vv, w, backend="xla"))
        fused = jax.jit(lambda lt, vv: filtering.filter_mvm(
            lt, vv, w, backend=fused_backend, taps=taps))

        per_dir_s = timeit(per_dir, lat, v)
        fused_s = timeit(fused, lat, v)

        # correctness: fused vs the op-for-op reference oracle
        algo = "hs" if fused_backend == "fused_pallas" else "scan"
        ref_out = blur_ref.filter_ref(lat, v, w, splat_algo=algo)
        err = float(jnp.max(jnp.abs(fused(lat, v) - ref_out)))

        exact_s = None
        if n <= EXACT_MAX_N * SCALE:
            exact_s = timeit(
                jax.jit(lambda xx, vv: chunked_mvm(km.MATERN32, xx, vv,
                                                   block=1024)), x, v)

        speedup = per_dir_s / fused_s
        emit(f"fig6/n{n}", fused_s,
             f"per_direction_s={per_dir_s:.4f} fused_s={fused_s:.4f} "
             f"fused_speedup={speedup:.2f}x "
             + (f"exact_s={exact_s:.4f} " if exact_s is not None else "")
             + f"build_s={build_s:.2f} m={m} cap={lat.cap} "
             f"backend={fused_backend} max_abs_err={err:.2e}")
        rows.append({
            "n": n, "d": D, "r": st.r, "m": m, "cap": lat.cap,
            "build_s": round(build_s, 4),
            "max_abs_err_fused_vs_ref": err,
            "backends": {
                "per_direction": {"us_per_mvm": per_dir_s * 1e6,
                                  "backend": "xla"},
                "fused": {"us_per_mvm": fused_s * 1e6,
                          "backend": fused_backend},
                **({"exact": {"us_per_mvm": exact_s * 1e6}}
                   if exact_s is not None else {}),
            },
            "fused_speedup": speedup,
        })
    write_json("BENCH_mvm.json", {"figure": "fig6_mvm_speed",
                                  "kernel": "matern32", "sizes": rows})


if __name__ == "__main__":
    main()
