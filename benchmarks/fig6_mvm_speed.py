"""Fig 6: Simplex-GP MVM wall time vs exact MVM, across n.

The paper's claim: lattice MVMs overtake exact MVMs as n grows (10x at
n ~ 1e6 on GPU). On this CPU host the crossover appears at smaller n; the
benchmark reports both times and the speedup so the TREND is the check.
Amortization matters: the lattice build is done once per hyperparameter
setting, so per-MVM cost excludes the build (reported separately), exactly
like the paper's CG-loop usage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit, timeit
from repro.core import filtering
from repro.core.exact import chunked_mvm
from repro.core import kernels_math as km
from repro.core.stencil import make_stencil

SIZES = [1000, 4000, 16000, 64000]
D = 8


def main():
    rng = np.random.default_rng(0)
    st = make_stencil("matern32", 1)
    for n in [int(s * SCALE) for s in SIZES]:
        x = jnp.asarray(rng.normal(size=(n, D)) * 0.3, jnp.float32)
        v = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)

        import time
        t0 = time.perf_counter()
        mv, lat = filtering.mvm_operator(x, st)
        jax.block_until_ready(mv(v))
        build_s = time.perf_counter() - t0

        lattice_s = timeit(mv, v)
        exact_s = timeit(
            jax.jit(lambda xx, vv: chunked_mvm(km.MATERN32, xx, vv,
                                               block=1024)), x, v)
        emit(f"fig6/n{n}", lattice_s,
             f"exact_s={exact_s:.4f} lattice_s={lattice_s:.4f} "
             f"speedup={exact_s / lattice_s:.2f}x build_s={build_s:.2f} "
             f"m={int(lat.m)}")


if __name__ == "__main__":
    main()
