"""Table 4: training-step cost at CG tolerance 1e-2 / 1e-4 vs RR-CG.

The paper's point: tol 1e-4 stabilizes training but costs ~5-8x; RR-CG
recovers most of the speed while remaining unbiased. On the static-shape
TPU formulation we report BOTH wall seconds (this host) and the effective
MVM count a dynamic backend would execute (solvers/rrcg.py docstring).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import SCALE, emit
from repro.data.synthetic_uci import load
from repro.gp import GPParams, SimplexGP, SimplexGPConfig
from repro.gp.mll import mll_value_and_grad
from repro.solvers import expected_iters

DATASETS = {"precipitation": 0.004, "protein": 0.05, "elevators": 0.15}


def one_step_seconds(model, params, x, y, *, tol, use_rrcg=False):
    key = jax.random.PRNGKey(0)
    fn = jax.jit(lambda p, k: mll_value_and_grad(
        model, p, x, y, k, tol=tol, use_rrcg=use_rrcg).mll)
    fn(params, key).block_until_ready()  # compile
    t0 = time.perf_counter()
    fn(params, jax.random.PRNGKey(1)).block_until_ready()
    return time.perf_counter() - t0


def main():
    for name, frac in DATASETS.items():
        ds = load(name, scale=frac * SCALE)
        x = jnp.asarray(ds.x_train)
        y = jnp.asarray(ds.y_train)
        params = GPParams.init(x.shape[1])
        for label, iters, tol, rr in [
                ("cg_1e-2", 30, 1e-2, False),
                ("cg_1e-4", 150, 1e-4, False),
                ("rrcg", 150, 1e-8, True)]:
            # "auto" resolves to the fused lattice-MVM backend for this
            # host (kernels/blur/ops.py policy) — every CG iteration of the
            # step rides the fused path.
            model = SimplexGP(SimplexGPConfig(
                kernel="matern32", max_cg_iters=iters, num_probes=4,
                max_lanczos_iters=10, backend="auto"))
            s = one_step_seconds(model, params, x, y, tol=tol,
                                 use_rrcg=rr)
            eff = (expected_iters(iters // 4, iters)
                   if rr else iters)
            emit(f"table4/{name}/{label}", s,
                 f"effective_mvm_iters={eff:.0f} n={x.shape[0]}")


if __name__ == "__main__":
    main()
