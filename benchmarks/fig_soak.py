"""Serving soak: sustained traffic through a scripted fault schedule.

The fault-tolerance acceptance test for the serving runtime (DESIGN.md
§13): run a live ``GPServeEngine`` (background refresh worker) under
mixed query traffic while ``runtime/faults.FaultInjector`` replays a
deterministic failure schedule — a refresh-worker crash, a forced CG
stall, NaN-poisoned candidate tables, a capacity-overflow freeze, a
wedged (deadline-tripping) freeze, plus transient and persistent
query-path faults — and prove two things end to end:

  zero invalid responses   every response the engine actually served is
                           finite with nonnegative variance (stale-but-
                           validated Predictors only; the validation gate
                           plus the last-line finiteness check hold under
                           every scripted failure);
  graceful degradation     faulted refreshes are refused/abandoned while
                           the last-good Predictor keeps serving, and the
                           engine recovers (clean refreshes publish,
                           health returns to "ok").

It also measures the refresh economics the engine's warm path exists
for: ``cold_s`` (freeze from scratch — lattice build + CG from zero) vs
``warm_s`` (y-only refresh — cached lattice, reused hash index, CG
warm-started from the old alpha), both jit-warm, plus the CG iteration
counts behind the speedup. Results land in BENCH_soak.json; the tier-1
``bench_smoke`` test replays a scaled-down schedule so a broken
degradation path fails CI.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit, write_json
from repro.core import filtering
from repro.gp import GPParams, SimplexGP, SimplexGPConfig, freeze, refreeze
from repro.launch.serve_gp import (EngineConfig, GPServeEngine,
                                   ServeUnavailable)
from repro.runtime.faults import FaultInjector

N, D = 2000, 6
BQ = 256  # queries per batch
RANK = 8
BATCHES = 60
REFRESH_EVERY = 6


def arm_default_schedule(fi: FaultInjector, *, slow_seconds: float,
                         overflow_cap: int = 8, query_transient_at: int = 10,
                         query_persistent_at: int = 31,
                         max_retries: int = 2) -> None:
    """The scripted schedule the soak stats assume.

    Refresh attempt 1 is left clean (it is the warm-refresh latency
    measurement); attempts 2-6 each exercise one failure mode. ``at``
    counts (site, kind) PROBES: the attempt-2 exception fires before any
    freeze-site probe runs, so freeze-site probe k corresponds to
    refresh attempt k+1 from attempt 3 on. The wedge is scheduled LAST
    because its abandoned attempt thread keeps consuming freeze-site
    probes after the deadline — ordering every other event before it
    keeps the schedule deterministic.
    """
    fi.arm(site="refresh", kind="exception", at=2, note="worker crash")
    fi.arm(site="freeze", kind="cg_stall", at=2,
           note="forced CG non-convergence")
    fi.arm(site="freeze", kind="nan_tables", at=3, note="poisoned tables")
    fi.arm(site="freeze", kind="overflow", at=4, cap=overflow_cap,
           note="undersized lattice cap")
    fi.arm(site="freeze", kind="slow", at=5, seconds=slow_seconds,
           note="wedged freeze")
    fi.arm(site="query", kind="exception", at=query_transient_at,
           note="transient query fault")
    fi.arm(site="query", kind="exception", at=query_persistent_at,
           count=max_retries + 1, note="persistent query fault")


def _make_batch(rng, x, xs_out, far_scale, bq):
    """Mixed traffic: ~80% in-lattice, ~15% off-lattice, ~5% full-miss."""
    n_in = int(bq * 0.8)
    n_off = int(bq * 0.15)
    n_far = bq - n_in - n_off
    d = x.shape[1]
    rows = [np.asarray(x)[rng.integers(0, x.shape[0], n_in)],
            np.asarray(xs_out)[rng.integers(0, xs_out.shape[0], n_off)],
            rng.normal(size=(n_far, d)).astype(np.float32) * far_scale]
    return jnp.asarray(np.concatenate(rows, axis=0))


def measure_soak(x, y, xs_out, *, variance_rank: int = RANK, bq: int = BQ,
                 batches: int = BATCHES, refresh_every: int = REFRESH_EVERY,
                 target_refreshes: int | None = None, pace_s: float = 0.0,
                 far_scale: float = 100.0, query_transient_at: int = 10,
                 query_persistent_at: int = 31, overflow_cap: int = 8,
                 seed: int = 0) -> dict:
    """Run the soak; returns the (JSON-able) result row.

    ``target_refreshes`` defaults to 7: the warm measurement, the five
    scripted refresh faults, and at least one clean recovery refresh.
    The traffic loop keeps serving batches until both the batch budget
    and the refresh schedule are exhausted, so refreshes always run
    UNDER live traffic (that is the soak).
    """
    rng = np.random.default_rng(seed)
    n, d = x.shape
    key = jax.random.PRNGKey(seed)
    params = GPParams.init(d)
    model = SimplexGP(SimplexGPConfig(kernel="matern32"))
    if target_refreshes is None:
        target_refreshes = max(7, batches // refresh_every)

    # --- cold-freeze baseline (jit-warm: first call pays compilation) ------
    freeze(model, params, x, y, key=key, variance_rank=variance_rank,
           cache=filtering.LatticeCache())
    t0 = time.perf_counter()
    pred_cold = freeze(model, params, x, y, key=key,
                       variance_rank=variance_rank,
                       cache=filtering.LatticeCache())
    jax.block_until_ready(pred_cold.tables)
    cold_s = time.perf_counter() - t0
    cold_iters = int(pred_cold.cg_iterations)
    # warm the WARM-refresh jit path too (warm-started CG traces a
    # different program than the cold solve) so the engine's refresh
    # deadline — derived from cold_s below — never charges a refresh for
    # one-time compilation
    refreeze(model, params, x, y, key=key, old=pred_cold,
             cache=filtering.LatticeCache(), variance_rank=variance_rank)
    # ... and the cg_stall fault's config variant (different static CG
    # bounds retrace the solver); without this, the injected-stall attempt
    # pays compilation and can trip the wedge deadline instead of the
    # validation gate — a different (real) failure than the one scripted
    stall_model = SimplexGP(dataclasses.replace(
        model.config, cg_tol_eval=1e-12, max_cg_iters=2))
    refreeze(stall_model, params, x, y, key=key, old=pred_cold,
             cache=filtering.LatticeCache(), variance_rank=variance_rank)

    # --- engine + schedule --------------------------------------------------
    # constant refresh deadline derived from the measured cold freeze; the
    # scripted wedge sleeps past it, a healthy freeze stays well inside it
    deadline_s = max(4.0 * cold_s, 3.0)
    cfg = EngineConfig(variance_rank=variance_rank,
                       refresh_min_deadline_s=deadline_s,
                       refresh_max_deadline_s=deadline_s)
    # the overflow-recovery lane builds at the forced cap and then the
    # grown cap — two more one-time build shapes to compile outside the
    # deadline (the capacity overflow itself still fires on cue)
    for c in (overflow_cap, overflow_cap * cfg.cap_growth):
        try:
            refreeze(model, params, x, y, key=key, old=pred_cold,
                     cache=filtering.LatticeCache(), cap=c,
                     variance_rank=variance_rank)
        except RuntimeError:
            pass
    fi = FaultInjector()
    eng = GPServeEngine(model, params, x, y, key=jax.random.PRNGKey(seed + 1),
                        config=cfg, faults=fi, background=True)

    # warm-refresh measurement (attempt 1, clean): y drifts, x unchanged —
    # cached lattice + reused index + warm-started CG
    def drift_y(t):
        return y + 0.02 * t * jnp.sin(x[:, 0]) + jnp.asarray(
            0.01 * rng.normal(size=n), jnp.float32)

    gen = eng.submit_refresh(y=drift_y(1))
    assert eng.wait_refreshed(gen, timeout_s=60 + 10 * deadline_s)
    warm_s = eng.health().last_refresh_s
    warm_iters = int(eng.predictor().cg_iterations)
    submitted = 1

    arm_default_schedule(fi, slow_seconds=1.5 * deadline_s + 0.2,
                         overflow_cap=overflow_cap,
                         query_transient_at=query_transient_at,
                         query_persistent_at=query_persistent_at,
                         max_retries=cfg.max_retries)

    # --- traffic loop -------------------------------------------------------
    latencies, refused, invalid, stale_batches = [], 0, 0, 0
    versions_served: set[int] = set()
    alerts = 0
    b = 0
    hard_cap = batches * 200  # loop backstop; never binds in practice
    while b < hard_cap:
        pending = eng.health().pending_refresh
        if b >= batches and submitted >= target_refreshes and not pending:
            break
        if b % refresh_every == 0 and submitted < target_refreshes \
                and not pending:
            # y-only refreshes: the warm lane this engine exists for. An
            # x-change refresh would retrace the frozen kernels for the
            # new table shapes — a real (one-time) cost the deadline
            # would misread as a wedge; tests cover that path inline.
            submitted += 1
            eng.submit_refresh(y=drift_y(submitted))
        xs = _make_batch(rng, x, xs_out, far_scale, bq)
        t1 = time.perf_counter()
        try:
            res = eng.query(xs)
        except ServeUnavailable:
            refused += 1
            b += 1
            continue
        latencies.append(time.perf_counter() - t1)
        mean = np.asarray(res.mean)
        var = np.asarray(res.var)
        if not (np.isfinite(mean).all() and np.isfinite(var).all()
                and (var >= 0).all()):
            invalid += 1
        versions_served.add(res.version)
        stale_batches += int(res.stale)
        alerts += int(eng.health().staleness_alert)
        b += 1
        if pace_s:
            time.sleep(pace_s)

    h = eng.health()
    eng.close()
    elapsed = float(np.sum(latencies))
    lat_ms = np.asarray(latencies) * 1e3
    return {
        "n": n, "d": d, "bq": bq, "variance_rank": variance_rank,
        "refresh": {
            "cold_s": round(cold_s, 4),
            "warm_s": round(warm_s, 4),
            "warm_speedup": round(cold_s / warm_s, 2),
            "cold_iters": cold_iters,
            "warm_iters": warm_iters,
            "deadline_s": round(deadline_s, 3),
            "submitted": submitted,
            "ok": h.refreshes_ok,
            "failed": h.refreshes_failed,
            "rejected": h.refreshes_rejected,
            "wedged": h.refreshes_wedged,
            "overflow_recoveries": h.overflow_recoveries,
        },
        "traffic": {
            "batches": int(b),
            "served": h.queries_served,
            "retried": h.queries_retried,
            "refused": h.queries_refused,
            "fallback_queries": h.fallback_queries,
            "availability": round(
                h.queries_served / max(1, h.queries_served
                                       + h.queries_refused), 5),
            "invalid_responses": invalid,
            "qps": round(bq * len(latencies) / max(elapsed, 1e-9), 0),
            "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
            "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
            "stale_batches": stale_batches,
            "staleness_alerts": alerts,
            "staleness_final": round(h.staleness, 4),
            "versions_served": sorted(versions_served),
        },
        "final_status": h.status,
        "faults": fi.summary(),
    }


def main():
    rng = np.random.default_rng(0)
    n = int(N * SCALE)
    x = jnp.asarray(rng.normal(size=(n, D)), jnp.float32)
    y = (jnp.sin(2 * x[:, 0]) + 0.4 * x[:, 1] * x[:, 2]
         + 0.05 * jnp.asarray(rng.normal(size=n), jnp.float32))
    xs_out = jnp.asarray(rng.normal(size=(BQ, D)) * 2.0, jnp.float32)
    row = measure_soak(x, y, xs_out, pace_s=0.01)
    r, t = row["refresh"], row["traffic"]
    emit(f"fig_soak/n{n}_d{D}", None,
         f"batches={t['batches']} avail={t['availability']} "
         f"invalid={t['invalid_responses']} "
         f"refresh ok/fail/rej/wedge={r['ok']}/{r['failed']}"
         f"/{r['rejected']}/{r['wedged']} "
         f"cold={r['cold_s']}s warm={r['warm_s']}s "
         f"({r['warm_speedup']}x, CG {r['cold_iters']}->{r['warm_iters']}) "
         f"p99={t['p99_ms']}ms status={row['final_status']}")
    write_json("BENCH_soak.json", {"figure": "fig_soak", "soak": row})


if __name__ == "__main__":
    main()
