"""Elastic training soak: scripted kill/shrink/regrow vs an uninterrupted run.

The elastic-training acceptance test (DESIGN.md §16). Every life is a REAL
training process (``python -m repro.launch.elastic_gp --worker``) whose
device count the driver sets via ``--xla_force_host_platform_device_count``
— killing a life and restarting it on fewer devices is exactly what losing
half the mesh looks like from the checkpoint layer's point of view. All
lives of a scenario share one checkpoint directory; ``fit(resume=True)``
picks up the newest valid generation.

Three scenarios:

  baseline    one uninterrupted life on 8 devices — the reference
              trajectory (final MLL, final-params digest);
  bitcompat   kill at a scripted epoch on 8 devices, restart on the SAME
              8 devices: the finished run must be bit-identical to the
              baseline (PR 7's resume guarantee, now under a mesh) and
              lose <= ckpt_every epochs to the kill;
  elastic     kill on 8 -> resume on 4 (shrink, uneven 300/4-per-device
              rows exercised on the 8-dev lives via ghost padding) ->
              kill on 4 -> regrow to 8 with a transient in-step exception
              (absorbed as a retry) and a wedged step (StepWatchdog
              breach: checkpoint + early return) -> final life completes.
              Each event loses <= ckpt_every epochs; the final MLL lands
              within a tolerance fence of the baseline (f32 reduction
              order differs across mesh sizes, so bitwise equality is
              only promised for same-mesh resume).

``trend_check`` ENFORCES the summary invariants: zero scripted faults
unfired, max steps lost <= ckpt_every, same-mesh bit-compat, regrow
success, MLL within the fence.

    PYTHONPATH=src python -m benchmarks.fig_elastic
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

N, D, N_VAL = 300, 2, 64  # 300 % 8 != 0: every 8-device life pads rows
EPOCHS = 24
CKPT_EVERY = 4
KILL_EXIT = 17  # runtime/faults.kill_if_armed's scripted exit code
MLL_FENCE_REL = 0.05


def _run_life(spec: dict, *, devices: int,
              timeout_s: float = 900.0) -> tuple[int, dict | None, float]:
    """One worker life under ``devices`` virtual CPUs; returns
    (exit_code, report|None, wall_s)."""
    env = dict(os.environ)
    root = pathlib.Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root), env.get("PYTHONPATH", "")])
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count={devices}").strip()
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.elastic_gp", "--worker",
         json.dumps(spec)],
        capture_output=True, text=True, timeout=timeout_s, env=env,
        cwd=str(root))
    wall = time.perf_counter() - t0
    report = None
    if proc.returncode == 0:
        lines = [ln for ln in proc.stdout.strip().splitlines() if ln]
        if not lines:
            raise RuntimeError(f"worker exited 0 with no report:\n"
                               f"{proc.stderr[-2000:]}")
        report = json.loads(lines[-1])
    elif proc.returncode != KILL_EXIT:
        raise RuntimeError(
            f"worker died with unexpected exit {proc.returncode}:\n"
            f"{proc.stderr[-2000:]}")
    return proc.returncode, report, wall


def _resume_point(ckpt_dir: pathlib.Path) -> int | None:
    """The epoch the NEXT life of this scenario will resume from (its
    newest valid checkpoint). Needed for the steps-lost arithmetic of a
    KILLED life: ``os._exit`` means the victim never prints a report, so
    the driver reads the same ``latest_valid_step`` the successor's
    ``fit(resume=True)`` will."""
    from repro.runtime.checkpoint import CheckpointManager
    return CheckpointManager(str(ckpt_dir)).latest_valid_step()


def run_elastic(root: str | pathlib.Path, *, epochs: int = EPOCHS,
                ckpt_every: int = CKPT_EVERY, seed: int = 0,
                timeout_s: float = 900.0) -> dict:
    """The full scripted kill/shrink/regrow schedule; returns the
    BENCH_elastic payload (also usable at reduced ``epochs`` by the
    tier-1 ``elastic`` test lane). Requires ``epochs >= 20`` so every
    scripted event lands inside the run."""
    assert epochs >= 20, "schedule needs >= 20 epochs"
    root = pathlib.Path(root)
    base = {"seed": seed, "n": N, "d": D, "n_val": N_VAL,
            "epochs": epochs, "ckpt_every": ckpt_every}
    lives = []
    errors = []

    def life(name: str, scenario_dir: str, spec: dict, *, devices: int,
             expect_kill: bool = False) -> dict:
        ckpt_dir = root / scenario_dir
        ckpt_dir.mkdir(parents=True, exist_ok=True)
        code, report, wall = _run_life(
            dict(base, ckpt_dir=str(ckpt_dir), **spec), devices=devices,
            timeout_s=timeout_s)
        row = {"name": name, "devices": devices, "exit_code": code,
               "killed": code == KILL_EXIT, "wall_s": round(wall, 3),
               "report": report}
        if expect_kill != (code == KILL_EXIT):
            errors.append(f"{name}: expected killed={expect_kill}, "
                          f"got exit {code}")
        lives.append(row)
        return row

    # -- scenario A: uninterrupted reference on 8 devices -------------------
    a = life("baseline", "a", {}, devices=8)

    # -- scenario B: same-mesh kill + resume must be bit-compatible ---------
    # kill fires on the 15th epoch iteration (epoch 14): epochs 0..13
    # completed, cadence checkpoints at 3/7/11 -> resume loses 13-11 = 2
    b_kill_epoch = 14
    life("b_kill", "b",
         {"faults": [{"site": "fit", "kind": "kill",
                      "at": b_kill_epoch + 1, "note": "device loss"}]},
         devices=8, expect_kill=True)
    b2 = life("b_resume_same_mesh", "b", {}, devices=8)

    # -- scenario C: shrink 8 -> 4, then regrow 4 -> 8 ----------------------
    # C1 dies at epoch 10 (epochs 0..9 done, checkpoints 3/7 -> lose 2)
    c1_kill_epoch = 10
    life("c_kill_on_8", "c",
         {"faults": [{"site": "fit", "kind": "kill",
                      "at": c1_kill_epoch + 1, "note": "device loss"}]},
         devices=8, expect_kill=True)
    # C2 resumes on 4 devices from epoch 7, dies at its 7th epoch
    # iteration (epoch 14): 8..13 done, cadence checkpoint 11 -> lose 2.
    # Probe the resume point BEFORE each killed life: the victim cannot
    # report it (os._exit), the checkpoint dir can.
    c2_resume = _resume_point(root / "c")
    life("c_shrink_to_4", "c",
         {"faults": [{"site": "fit", "kind": "kill", "at": 7,
                      "note": "device loss"}]},
         devices=4, expect_kill=True)
    c3_resume = _resume_point(root / "c")
    # C3 regrows to 8. In-step executions of this life: #1/#2 warm the
    # watchdog window (#1 carries jit compile, which fattens the median
    # — deliberate, it keeps the retry epoch under the deadline), #3
    # raises (transient -> retried as #4, same epoch), #5 sleeps 12s ->
    # breach -> checkpoint + early return. 12s because the deadline is
    # 2x the window median (compile-heavy, a few seconds here): the
    # wedge must clear it on any plausible host.
    c3 = life("c_regrow_to_8_faulty", "c",
              {"faults": [
                  {"site": "fit_step", "kind": "exception", "at": 3,
                   "note": "transient step failure"},
                  {"site": "fit_step", "kind": "slow", "at": 5,
                   "seconds": 12.0, "note": "wedged collective"}],
               "watchdog": {"window": 4, "multiplier": 2.0,
                            "min_deadline": 1.0}},
              devices=8)
    c4 = life("c_finish_on_8", "c", {}, devices=8)

    # -- summary invariants (trend_check ENFORCES these) --------------------
    def _resumed(row):
        return (row["report"] or {}).get("resumed_from_epoch")

    # steps lost per event = last epoch completed before the event minus
    # the epoch the next life resumed from (kill positions are scripted,
    # so the completed count is known; breach epochs come from the report)
    losses = {
        "b_kill": (b_kill_epoch - 1) - _resumed(b2),
        "c_kill_on_8": (c1_kill_epoch - 1) - c2_resume,
        "c_kill_on_4": (c2_resume + 7 - 1) - c3_resume,
        "c_watchdog_breach": (c3["report"]["last_epoch"] or 0)
        - _resumed(c4),
    }
    scripted = 5  # 3 kills + 1 transient exception + 1 wedge
    fired = (sum(1 for lf in lives if lf["killed"])
             + len(c3["report"]["fired"]))
    bitcompat = (
        a["report"]["params_digest"] == b2["report"]["params_digest"]
        and a["report"]["final_mll"] == b2["report"]["final_mll"])
    regrow_ok = (c4["report"] is not None and c4["report"]["devices"] == 8
                 and c4["report"]["last_epoch"] == epochs - 1
                 and c4["report"]["interrupted"] is None)
    mll_rel = (abs(c4["report"]["final_mll"] - a["report"]["final_mll"])
               / max(1.0, abs(a["report"]["final_mll"])))
    if len(c3["report"]["retries"]) != 1:
        errors.append(f"expected 1 transient retry in c3, got "
                      f"{c3['report']['retries']}")
    if c3["report"]["interrupted"] != "watchdog_breach":
        errors.append(f"c3 should end on a watchdog breach, got "
                      f"{c3['report']['interrupted']!r}")

    payload = {
        "figure": "fig_elastic",
        "n": N, "d": D, "epochs": epochs, "ckpt_every": ckpt_every,
        "lives": lives,
        "steps_lost": losses,
        "summary": {
            "lives": len(lives),
            "kills": sum(1 for lf in lives if lf["killed"]),
            "scripted_faults": scripted,
            "fired_faults": fired,
            "all_faults_fired": fired >= scripted,
            "max_steps_lost": max(losses.values()),
            "ckpt_every": ckpt_every,
            "same_mesh_bitcompat": bool(bitcompat),
            "regrow_ok": bool(regrow_ok),
            "mesh_sizes": sorted({lf["devices"] for lf in lives}),
            "final_mll_baseline": a["report"]["final_mll"],
            "final_mll_elastic": c4["report"]["final_mll"],
            "mll_rel_err": round(mll_rel, 6),
            "mll_fence": MLL_FENCE_REL,
            "errors": errors,
        },
    }
    return payload


def main():
    from benchmarks.common import emit, write_json
    with tempfile.TemporaryDirectory(prefix="elastic_ckpt_") as td:
        payload = run_elastic(td)
    s = payload["summary"]
    emit(f"fig_elastic/n{N}_d{D}_e{payload['epochs']}", None,
         f"lives={s['lives']} kills={s['kills']} "
         f"faults={s['fired_faults']}/{s['scripted_faults']} "
         f"lost<={s['max_steps_lost']}(ckpt_every={s['ckpt_every']}) "
         f"bitcompat={s['same_mesh_bitcompat']} regrow={s['regrow_ok']} "
         f"mll_rel={s['mll_rel_err']} errors={len(s['errors'])}")
    write_json("BENCH_elastic.json", payload)
    if (s["errors"] or not s["all_faults_fired"]
            or s["max_steps_lost"] > s["ckpt_every"]
            or not s["same_mesh_bitcompat"] or not s["regrow_ok"]
            or s["mll_rel_err"] > s["mll_fence"]):
        raise SystemExit("fig_elastic: elastic invariant violated: "
                         + json.dumps(s))


if __name__ == "__main__":
    main()
