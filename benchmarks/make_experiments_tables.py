"""Generate the §Dry-run and §Roofline markdown tables from results/.

    PYTHONPATH=src python -m benchmarks.make_experiments_tables > tables.md
"""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"

ARCH_ORDER = ["glm4-9b", "llama3.2-3b", "minitron-4b", "phi3-medium-14b",
              "moonshot-v1-16b-a3b", "deepseek-v2-236b", "qwen2-vl-7b",
              "whisper-tiny", "rwkv6-7b", "recurrentgemma-2b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(mesh: str):
    cells = {}
    for f in RESULTS.glob("dryrun_*.json"):
        d = json.loads(f.read_text())
        if d.get("mesh") != mesh:
            continue
        key = (d.get("arch", d.get("cell", "?")), d.get("shape", "-"))
        cells[key] = d
    return cells


def dryrun_table(mesh: str):
    cells = _load(mesh)
    print(f"\n### Dry-run — {mesh} mesh "
          f"({'512' if mesh == 'multi' else '256'} chips)\n")
    print("| arch | shape | status | compile | params+opt+state GiB/dev |"
          " temp GiB/dev | HLO GFLOP/dev | coll GiB/dev | top collectives |")
    print("|---|---|---|---|---|---|---|---|---|")
    keys = [(a, s) for a in ARCH_ORDER for s in SHAPE_ORDER]
    keys += [(k, s) for (k, s) in cells if k not in ARCH_ORDER]
    for key in keys:
        d = cells.get(key)
        if d is None:
            continue
        a, s = key
        st = str(d.get("status", "?"))
        if st.startswith("SKIP"):
            print(f"| {a} | {s} | SKIP(full-attn) | | | | | | |")
            continue
        if st != "OK":
            print(f"| {a} | {s} | FAIL | | | | | | {st[:60]} |")
            continue
        mem = d.get("memory", {})
        ta = d.get("trip_aware", {})
        by = ta.get("by_kind", {})
        top = ", ".join(f"{k.split('-')[-1]} {v/2**30:.1f}G"
                        for k, v in sorted(by.items(),
                                           key=lambda kv: -kv[1])[:2])
        print(f"| {a} | {s} | OK | {d.get('seconds_compile', '')}s "
              f"| {mem.get('argument_size_in_bytes', 0)/2**30:.2f} "
              f"| {mem.get('temp_size_in_bytes', 0)/2**30:.2f} "
              f"| {ta.get('flops', 0)/1e9:.0f} "
              f"| {ta.get('collective_bytes', 0)/2**30:.2f} | {top} |")


def roofline_table(mesh: str = "single"):
    cells = _load(mesh)
    print(f"\n### Roofline — {mesh} mesh, TPU v5e targets "
          "(197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)\n")
    print("| cell | t_compute s | t_memory s | t_collective s | bound |"
          " useful | MFU bound |")
    print("|---|---|---|---|---|---|---|")
    keys = [(a, s) for a in ARCH_ORDER for s in SHAPE_ORDER]
    keys += [(k, s) for (k, s) in cells if k not in ARCH_ORDER]
    for key in keys:
        d = cells.get(key)
        if d is None or "roofline" not in d:
            if d is not None and str(d.get("status", "")).startswith("SKIP"):
                print(f"| {key[0]} × {key[1]} | — | — | — | "
                      "SKIP(full-attn) | | |")
            continue
        r = d["roofline"]
        print(f"| {key[0]} × {key[1]} | {r['t_compute']:.4f} "
              f"| {r['t_memory']:.4f} | {r['t_collective']:.4f} "
              f"| {r['bottleneck']} | {r['useful']:.2f} "
              f"| {r['mfu_bound']:.3f} |")


if __name__ == "__main__":
    for mesh in ("single", "multi"):
        dryrun_table(mesh)
    roofline_table("single")
