"""Trend check over the committed BENCH_*.json artifacts (CI gate).

The benchmark artifacts are committed alongside the code so the perf
trajectory is reviewable per PR; this check keeps them honest without
making CI flaky: it validates the SCALE-FREE invariants each artifact
claims (speedup floors, parity/error ceilings, availability/validity of
the serving soak, structural fields) inside tolerance bands. Absolute
times are deliberately not compared — CI hosts differ wildly from the
machines the artifacts were measured on; ratios and error bounds are
host-portable.

Two tiers:

  ENFORCED   the serving-path artifacts (serve, build, soak) — their
             invariants are acceptance criteria (zero invalid soak
             responses, the 20x serving speedup floor, hash-build
             sanity), so a violation prints a GitHub ``::error::``
             annotation and the process exits nonzero.
  ADVISORY   the research-figure artifacts (mvm, train) — violations
             print ``::warning::`` and do not fail the run (their bands
             inform; the tier-1 tests enforce their code paths).

A malformed/unreadable artifact always exits nonzero — that means the
artifact pipeline itself broke. ``--strict`` escalates advisory
warnings to failures. With healthy artifacts the exit code is 0 (the
tier-1 ``test_trend_check_runs_clean`` pins that contract).

    PYTHONPATH=src python -m benchmarks.trend_check [--strict]
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

# check(payload) yields violation strings; bands are deliberately
# generous: they catch order-of-magnitude breaks and sign flips, not
# single-digit-percent noise.


def _check_build(p):
    for row in p["sizes"]:
        tag = f"n{row['n']}_d{row['d']}"
        if row["cold_speedup"] < 0.8:
            yield (f"fig_build {tag}: hash cold build slower than sort "
                   f"(cold_speedup={row['cold_speedup']} < 0.8)")
        if not 0 < row["m"] <= row["cap"]:
            yield f"fig_build {tag}: m={row['m']} outside (0, cap]"
        if row["occupancy"] > 0.5:
            yield (f"fig_build {tag}: hash occupancy {row['occupancy']} "
                   "> 0.5 — probe costs degrade")


def _check_serve(p):
    for row in p["sizes"]:
        tag = f"n{row['n']}_d{row['d']}"
        if row["n"] >= 4000 and row["speedup"] < 20:
            yield (f"fig_serve {tag}: serving speedup {row['speedup']}x "
                   "below the 20x acceptance floor")
        if row.get("mean_parity", 0) > 1e-5:
            yield (f"fig_serve {tag}: in-lattice mean parity "
                   f"{row['mean_parity']:.2e} > 1e-5")
        if row.get("miss_in_lattice", 0) > 0:
            yield (f"fig_serve {tag}: in-lattice queries report nonzero "
                   f"slice miss ({row['miss_in_lattice']})")
        off = row.get("offlattice", {})
        if not 0 <= off.get("mean_miss", 0) <= 1:
            yield f"fig_serve {tag}: off-lattice miss mass outside [0, 1]"


def _check_soak(p):
    """The DESIGN.md §13 acceptance invariants of the fault-schedule soak."""
    row = p["soak"]
    r, t = row["refresh"], row["traffic"]
    tag = f"n{row['n']}_d{row['d']}"
    if t["invalid_responses"] != 0:
        yield (f"fig_soak {tag}: {t['invalid_responses']} invalid "
               "response(s) served — the zero-invalid guarantee broke")
    if t["availability"] < 0.98:
        yield (f"fig_soak {tag}: availability {t['availability']} < 0.98 "
               "under the scripted fault schedule")
    if r["ok"] < 1:
        yield f"fig_soak {tag}: no refresh ever published (ok={r['ok']})"
    if r["warm_speedup"] < 1.0:
        yield (f"fig_soak {tag}: warm refresh no faster than cold "
               f"(speedup={r['warm_speedup']})")
    if r["wedged"] < 1 or r["rejected"] < 1:
        yield (f"fig_soak {tag}: scripted degradation not exercised "
               f"(wedged={r['wedged']}, rejected={r['rejected']})")
    fired = {(f["site"], f["kind"]) for f in row["faults"]}
    missing = {("refresh", "exception"), ("freeze", "cg_stall"),
               ("freeze", "nan_tables"), ("freeze", "overflow"),
               ("freeze", "slow")} - fired
    if missing:
        yield (f"fig_soak {tag}: scheduled fault(s) never fired: "
               f"{sorted(missing)}")
    if row["final_status"] != "ok":
        yield (f"fig_soak {tag}: engine did not recover to 'ok' "
               f"(final_status={row['final_status']})")


def _check_mvm(p):
    for row in p.get("sizes", []):
        for k, v in row.items():
            if k.endswith("err") and isinstance(v, (int, float)) and v > 1e-4:
                yield (f"fig6 n{row.get('n')}: backend divergence "
                       f"{k}={v:.2e} > 1e-4")


def _check_train(p):
    for row in p.get("sizes", []):
        shared = row.get("shared", {})
        for k in ("builds_per_step", "builds_per_posterior"):
            if shared.get(k, 1) > 1:
                yield (f"fig_train n{row.get('n')}: shared-lattice {k}="
                       f"{shared[k]} > 1 — the §9 contract broke")


def _check_recovery(p):
    """The DESIGN.md §14 durability acceptance invariants."""
    s = p["summary"]
    if s["max_generations_lost"] > 1:
        yield (f"fig_recovery: {s['max_generations_lost']} generations "
               "lost across a kill — the atomic-persist bound (<= 1) broke")
    if s["invalid_responses"] != 0:
        yield (f"fig_recovery: {s['invalid_responses']} invalid "
               "response(s) served after restart")
    if not s["all_corruptions_detected"]:
        yield (f"fig_recovery: only {s['corruptions_detected']}/"
               f"{s['corruptions']} disk corruptions detected at boot — "
               "a damaged generation could have served")
    if s["kills"] < 2:
        yield (f"fig_recovery: kill sites not exercised "
               f"(kills={s['kills']} < 2)")
    if s["warm_boots"] < 1:
        yield "fig_recovery: no restart ever warm-booted from the store"
    if s["errors"]:
        yield f"fig_recovery: cycle errors: {s['errors']}"


def _check_rollout(p):
    """The DESIGN.md §15 serving-gradient acceptance invariants."""
    gc = p["gradcheck"]
    if gc["max_rel_err"] > 1e-4:
        yield (f"fig_rollout: gradcheck max rel-err "
               f"{gc['max_rel_err']:.2e} > 1e-4 — the analytic "
               "d(mean,var)/dx* no longer matches in-cell central "
               "differences")
    for d, row in gc["dims"].items():
        if row["pairs"] < 32:
            yield (f"fig_rollout: gradcheck d={d} kept only "
                   f"{row['pairs']} same-cell FD pairs — the check is "
                   "hollowed out")
    if any(v != 0 for v in p["grad_collectives"].values()):
        yield (f"fig_rollout: query-space gradient jaxpr has "
               f"collectives: {p['grad_collectives']} — the "
               "zero-collective gradient contract broke")
    if not 0 <= p["rollout"]["worst_miss"] <= 1:
        yield (f"fig_rollout: worst_miss {p['rollout']['worst_miss']} "
               "outside [0, 1]")


def _check_elastic(p):
    """The DESIGN.md §16 elastic-training acceptance invariants."""
    s = p["summary"]
    if not s["all_faults_fired"]:
        yield (f"fig_elastic: only {s['fired_faults']}/"
               f"{s['scripted_faults']} scripted faults fired — the "
               "schedule was not exercised")
    if s["max_steps_lost"] > s["ckpt_every"]:
        yield (f"fig_elastic: {s['max_steps_lost']} epochs lost to one "
               f"event > ckpt_every={s['ckpt_every']} — the durable-"
               "progress bound broke")
    if not s["same_mesh_bitcompat"]:
        yield ("fig_elastic: same-mesh kill+resume is no longer "
               "bit-compatible with the uninterrupted run (PR 7 resume "
               "guarantee broke under a mesh)")
    if not s["regrow_ok"]:
        yield ("fig_elastic: mesh regrow 4 -> 8 did not complete the "
               "run")
    if s["mll_rel_err"] > s["mll_fence"]:
        yield (f"fig_elastic: final MLL drifted {s['mll_rel_err']} "
               f"(rel) > fence {s['mll_fence']} across mesh resizes")
    if s["kills"] < 3:
        yield (f"fig_elastic: kill/shrink/regrow schedule not exercised "
               f"(kills={s['kills']} < 3)")
    if len(s["mesh_sizes"]) < 2:
        yield (f"fig_elastic: only one mesh size exercised "
               f"({s['mesh_sizes']})")
    if s["errors"]:
        yield f"fig_elastic: life errors: {s['errors']}"


def _check_rollout_throughput(p):
    row = p["rollout"]
    if row["evals_per_s"] < 1e4:
        yield (f"fig_rollout: {row['evals_per_s']:.0f} state-evals/s "
               "below the 1e4 CPU floor for the 100-step MC rollout")
    if row["grad_evals_per_s"] <= 0:
        yield "fig_rollout: gradient rollout produced no throughput"


ENFORCED = [
    ("BENCH_build.json", _check_build),
    ("BENCH_serve.json", _check_serve),
    ("BENCH_soak.json", _check_soak),
    ("BENCH_recovery.json", _check_recovery),
    ("BENCH_rollout.json", _check_rollout),
    ("BENCH_elastic.json", _check_elastic),
]

ADVISORY = [
    ("BENCH_mvm.json", _check_mvm),
    ("BENCH_train.json", _check_train),
    ("BENCH_rollout.json", _check_rollout_throughput),
]


def main(argv=None) -> int:
    strict = "--strict" in (argv if argv is not None else sys.argv[1:])
    errors, warnings, malformed = [], [], []
    for tier, out in ((ENFORCED, errors), (ADVISORY, warnings)):
        for name, check in tier:
            path = ROOT / name
            if not path.exists():
                # artifacts are optional until their benchmark has run once
                print(f"trend_check: {name} not committed yet — skipped")
                continue
            try:
                payload = json.loads(path.read_text())
                out.extend(check(payload))
            except (json.JSONDecodeError, KeyError, TypeError) as e:
                malformed.append(f"{name}: {type(e).__name__}: {e}")
    for w in warnings:
        print(f"::warning title=benchmark trend::{w}")
    for e in errors:
        print(f"::error title=benchmark invariant::{e}")
    for m in malformed:
        print(f"::error title=malformed benchmark artifact::{m}")
    print(f"trend_check: {len(errors)} error(s), {len(warnings)} "
          f"warning(s), {len(malformed)} malformed artifact(s)")
    if errors or malformed or (strict and warnings):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
