"""Fail-soft trend check over the committed BENCH_*.json artifacts (CI).

The benchmark artifacts are committed alongside the code so the perf
trajectory is reviewable per PR; this check keeps them honest without
making CI flaky: it validates the SCALE-FREE invariants each artifact
claims (speedup floors, parity/error ceilings, structural fields) inside
tolerance bands. Absolute times are deliberately not compared — CI hosts
differ wildly from the machines the artifacts were measured on; ratios
and error bounds are host-portable.

Fail-soft contract: band violations print GitHub ``::warning::``
annotations and the process still exits 0 — the trend gate informs, the
tier-1 tests enforce. Only a malformed/unreadable artifact (or
``--strict``) exits nonzero, because that means the artifact pipeline
itself broke.

    PYTHONPATH=src python -m benchmarks.trend_check [--strict]
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

# (artifact, description, check) — check(payload) yields warning strings.
# Bands are deliberately generous: they catch order-of-magnitude breaks
# and sign flips, not single-digit-percent noise.


def _check_build(p):
    for row in p["sizes"]:
        tag = f"n{row['n']}_d{row['d']}"
        if row["cold_speedup"] < 0.8:
            yield (f"fig_build {tag}: hash cold build slower than sort "
                   f"(cold_speedup={row['cold_speedup']} < 0.8)")
        if not 0 < row["m"] <= row["cap"]:
            yield f"fig_build {tag}: m={row['m']} outside (0, cap]"
        if row["occupancy"] > 0.5:
            yield (f"fig_build {tag}: hash occupancy {row['occupancy']} "
                   "> 0.5 — probe costs degrade")


def _check_serve(p):
    for row in p["sizes"]:
        tag = f"n{row['n']}_d{row['d']}"
        if row["n"] >= 4000 and row["speedup"] < 20:
            yield (f"fig_serve {tag}: serving speedup {row['speedup']}x "
                   "below the 20x acceptance floor")
        if row.get("mean_parity", 0) > 1e-5:
            yield (f"fig_serve {tag}: in-lattice mean parity "
                   f"{row['mean_parity']:.2e} > 1e-5")
        if row.get("miss_in_lattice", 0) > 0:
            yield (f"fig_serve {tag}: in-lattice queries report nonzero "
                   f"slice miss ({row['miss_in_lattice']})")
        off = row.get("offlattice", {})
        if not 0 <= off.get("mean_miss", 0) <= 1:
            yield f"fig_serve {tag}: off-lattice miss mass outside [0, 1]"


def _check_mvm(p):
    for row in p.get("sizes", []):
        for k, v in row.items():
            if k.endswith("err") and isinstance(v, (int, float)) and v > 1e-4:
                yield (f"fig6 n{row.get('n')}: backend divergence "
                       f"{k}={v:.2e} > 1e-4")


def _check_train(p):
    for row in p.get("sizes", []):
        shared = row.get("shared", {})
        for k in ("builds_per_step", "builds_per_posterior"):
            if shared.get(k, 1) > 1:
                yield (f"fig_train n{row.get('n')}: shared-lattice {k}="
                       f"{shared[k]} > 1 — the §9 contract broke")


CHECKS = [
    ("BENCH_build.json", _check_build),
    ("BENCH_serve.json", _check_serve),
    ("BENCH_mvm.json", _check_mvm),
    ("BENCH_train.json", _check_train),
]


def main(argv=None) -> int:
    strict = "--strict" in (argv if argv is not None else sys.argv[1:])
    warnings, malformed = [], []
    for name, check in CHECKS:
        path = ROOT / name
        if not path.exists():
            # artifacts are optional until their benchmark has run once
            print(f"trend_check: {name} not committed yet — skipped")
            continue
        try:
            payload = json.loads(path.read_text())
            warnings.extend(check(payload))
        except (json.JSONDecodeError, KeyError, TypeError) as e:
            malformed.append(f"{name}: {type(e).__name__}: {e}")
    for w in warnings:
        print(f"::warning title=benchmark trend::{w}")
    for m in malformed:
        print(f"::error title=malformed benchmark artifact::{m}")
    print(f"trend_check: {len(warnings)} warning(s), "
          f"{len(malformed)} malformed artifact(s)")
    if malformed or (strict and warnings):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
