"""Table 3: lattice points generated m vs worst case L = n (d+1).

The paper's sparsity ratios m/L (houseelectric 0.04, precipitation 0.003,
keggdirected 0.12, protein 0.03, elevators 0.69) are driven by input
geometry; the synthetic stand-ins are tuned to land in the same regimes,
so the ORDERING and decade of the ratios is the claim checked here.
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.common import SCALE, emit
from repro.core.lattice import build_lattice
from repro.data.synthetic_uci import SPECS, all_names, load

# per-dataset subsample fractions sized for a CPU-core run
FRACTIONS = {"houseelectric": 0.02, "precipitation": 0.05,
             "keggdirected": 1.0, "protein": 1.0, "elevators": 1.0}

PAPER_RATIOS = {"houseelectric": 0.04, "precipitation": 0.003,
                "keggdirected": 0.12, "protein": 0.03, "elevators": 0.69}


def main():
    for name in all_names():
        ds = load(name, scale=FRACTIONS[name] * SCALE)
        x = jnp.asarray(ds.x_train)
        n, d = x.shape
        t0 = time.time()
        lat = build_lattice(x, spacing=1.0, r=1)
        dt = time.time() - t0
        m = int(lat.m)
        ratio = m / (n * (d + 1))
        emit(f"table3/{name}", dt,
             f"n={n} d={d} m={m} ratio={ratio:.4f} "
             f"paper_ratio={PAPER_RATIOS[name]}")


if __name__ == "__main__":
    main()
