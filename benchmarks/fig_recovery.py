"""Crash-recovery soak: scripted kills and disk corruption vs warm boot.

The durability acceptance test (DESIGN.md §14). Each cycle runs a REAL
serving process (a subprocess: an injected kill is ``os._exit``, so the
victim must not be the benchmark itself) against one shared on-disk
``PredictorStore``, then the driver inspects the store and restarts:

  clean cycle       cold boot, persist, refresh, persist, clean exit —
                    establishes durable generations;
  kill cycles       a ``FaultInjector`` kill armed at the persistence
                    sites: ``persist_before_publish`` (process dies with
                    the tmp dir written but never renamed — the store
                    must be byte-identical to before) and
                    ``persist_after_publish`` (dies right after the
                    atomic rename — the new generation must be durable);
  corruption cycles the driver damages the newest generation on disk
                    (``runtime/faults.corrupt_checkpoint``: truncate,
                    bitflip, missing blob, stale manifest) before the
                    restart — warm boot must DETECT it, fall back one
                    generation, keep serving, and persist a fresh good
                    generation over it.

Measured per restart: recovery time (engine construction + first valid
query, plus driver wall clock including interpreter/jax startup),
boot mode/generation, generations lost (published in memory but not
durable — the atomic-persist design bounds this at <= 1), and invalid
responses after restart (must be 0). Results land in
BENCH_recovery.json; ``trend_check`` ENFORCES the invariants and the
tier-1 ``recovery`` lane replays a scaled-down schedule.

    PYTHONPATH=src python -m benchmarks.fig_recovery
    PYTHONPATH=src python -m benchmarks.fig_recovery --worker <store> <spec>
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

N, D = 400, 3
MODEL_NAME = "m"
KILL_EXIT = 17  # runtime/faults.kill_if_armed's scripted exit code


# -- worker (the process that gets killed) -----------------------------------

def _dataset(seed: int, n: int, d: int):
    import numpy as np
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = (jnp.sin(2 * x[:, 0]) + 0.4 * x[:, 1]
         + 0.05 * jnp.asarray(rng.normal(size=n), jnp.float32))
    return rng, x, y


def worker(store_dir: str, spec: dict) -> dict:
    """One serving-process life; returns the (JSON-able) cycle report.

    ``spec``: seed, n, d, queries (batches to serve after boot),
    kill (None | "persist_before_publish" | "persist_after_publish"),
    refresh (bool: submit one y-drift refresh and wait for its persist —
    the wait never returns when a kill is armed).
    """
    t_entry = time.perf_counter()
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.gp import GPParams, SimplexGP, SimplexGPConfig
    from repro.launch.serve_gp import (EngineConfig, GPServeEngine,
                                       PredictorStore)
    from repro.runtime.faults import FaultInjector

    seed = int(spec.get("seed", 0))
    n = int(spec.get("n", N))
    d = int(spec.get("d", D))
    rng, x, y = _dataset(seed, n, d)
    model = SimplexGP(SimplexGPConfig(kernel="matern32"))
    params = GPParams.init(d, noise=0.1)

    fi = FaultInjector()
    if spec.get("kill"):
        fi.arm(site=spec["kill"], kind="kill", note="scripted crash")
    store = PredictorStore(store_dir, keep_last=3, keep_best=1)
    cfg = EngineConfig(variance_rank=4, refresh_min_deadline_s=30.0)
    eng = GPServeEngine(model, params, x, y,
                        key=jax.random.PRNGKey(seed + 1), config=cfg,
                        store=store, model_name=MODEL_NAME, faults=fi)
    boot_s = time.perf_counter() - t_entry
    h0 = eng.health()

    # first valid query = the moment the restarted process is SERVING
    xs = jnp.asarray(np.asarray(x)[rng.integers(0, n, 32)])
    res = eng.query(xs)
    first_query_s = time.perf_counter() - t_entry
    invalid = 0
    for _ in range(int(spec.get("queries", 5))):
        xs = jnp.asarray(np.asarray(x)[rng.integers(0, n, 32)])
        res = eng.query(xs)
        m, v = np.asarray(res.mean), np.asarray(res.var)
        if not (np.isfinite(m).all() and np.isfinite(v).all()
                and (v >= 0).all()):
            invalid += 1

    versions_published = eng.version
    if spec.get("refresh", True):
        eng.submit_refresh(y=y + 0.02 * jnp.sin(x[:, 0]))
        eng.refresh_now()
        versions_published = eng.version
        # with a kill armed at a persistence site the process dies INSIDE
        # this wait (the persist thread hits the site) — nothing below runs
        eng.wait_persisted(timeout_s=120.0)

    h = eng.health()
    eng.close()
    return {
        "boot_mode": h0.boot_mode,
        "boot_generation": h0.boot_generation,
        "boot_skipped": h0.boot_skipped,
        "boot_s": round(boot_s, 3),
        "first_query_s": round(first_query_s, 3),
        "invalid_responses": invalid,
        "versions_published": versions_published,
        "persists_ok": h.persists_ok,
        "persists_failed": h.persists_failed,
        "durable_gens": store.generations(MODEL_NAME),
    }


# -- driver ------------------------------------------------------------------

def _run_worker(store_dir: pathlib.Path, spec: dict, *,
                timeout_s: float = 300.0) -> tuple[int, dict | None, float]:
    """Launch one worker life; returns (exit_code, report|None, wall_s)."""
    env = dict(os.environ)
    root = pathlib.Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root), env.get("PYTHONPATH", "")])
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig_recovery", "--worker",
         str(store_dir), json.dumps(spec)],
        capture_output=True, text=True, timeout=timeout_s, env=env,
        cwd=str(root))
    wall = time.perf_counter() - t0
    report = None
    if proc.returncode == 0:
        lines = [ln for ln in proc.stdout.strip().splitlines() if ln]
        if not lines:
            raise RuntimeError(f"worker exited 0 with no report:\n"
                               f"{proc.stderr[-2000:]}")
        report = json.loads(lines[-1])
    elif proc.returncode != KILL_EXIT:
        raise RuntimeError(
            f"worker died with unexpected exit {proc.returncode}:\n"
            f"{proc.stderr[-2000:]}")
    return proc.returncode, report, wall


def run_recovery(store_root: str | pathlib.Path, *,
                 corruption_kinds: tuple[str, ...] | None = None,
                 queries: int = 5, seed: int = 0,
                 timeout_s: float = 300.0) -> dict:
    """The full scripted kill/restart/corruption schedule; returns the
    BENCH_recovery payload (also usable at reduced scale by the tier-1
    ``recovery`` test lane)."""
    from repro.launch.serve_gp import PredictorStore
    from repro.runtime.faults import CORRUPTION_KINDS, corrupt_checkpoint

    if corruption_kinds is None:
        corruption_kinds = CORRUPTION_KINDS
    store_dir = pathlib.Path(store_root)
    store = PredictorStore(store_dir)
    base = {"seed": seed, "n": N, "d": D, "queries": queries}

    cycles = []

    def cycle(name: str, spec: dict, *, corrupt: str | None = None,
              expect_kill: bool = False) -> dict:
        gens_before = store.generations(MODEL_NAME)
        corrupted_gen = None
        if corrupt is not None:
            corrupted_gen = gens_before[-1]
            corrupt_checkpoint(store.path(MODEL_NAME, corrupted_gen),
                               corrupt)
        code, report, wall = _run_worker(store_dir, dict(base, **spec),
                                         timeout_s=timeout_s)
        gens_after = store.generations(MODEL_NAME)
        row = {"name": name, "spec": spec, "exit_code": code,
               "killed": code == KILL_EXIT,
               "corruption": corrupt, "corrupted_gen": corrupted_gen,
               "gens_before": gens_before, "gens_after": gens_after,
               "wall_s": round(wall, 3), "report": report}
        if expect_kill != (code == KILL_EXIT):
            row["error"] = (f"expected killed={expect_kill}, "
                            f"got exit {code}")
        cycles.append(row)
        return row

    # 1. clean cold start: establishes durable generations
    cycle("cold_clean", {"kill": None, "refresh": True})
    # 2. kill BEFORE the atomic rename: store must be unchanged
    cycle("kill_before_publish", {"kill": "persist_before_publish",
                                  "refresh": True}, expect_kill=True)
    # 3. restart: warm boot; at most ONE generation (the unpersisted
    #    refresh of cycle 2) may be lost
    cycle("recover_after_kill_before", {"kill": None, "refresh": True})
    # 4. kill AFTER the atomic rename: the new generation must be durable
    cycle("kill_after_publish", {"kill": "persist_after_publish",
                                 "refresh": True}, expect_kill=True)
    # 5. restart: warm boot serves the generation persisted mid-kill
    cycle("recover_after_kill_after", {"kill": None, "refresh": True})
    # 6+. corruption cycles: damage the newest generation, restart —
    #     detection + one-generation fallback + re-persist a good one
    for kind in corruption_kinds:
        cycle(f"corrupt_{kind}", {"kill": None, "refresh": True},
              corrupt=kind)

    # -- summary invariants (trend_check ENFORCES these) --------------------
    # a killed life published exactly ONE in-memory refresh beyond its
    # boot generation; it is lost iff no new generation reached disk
    # before the kill (kill-before-publish: lost=1; after: lost=0)
    lost_max = 0
    for c in cycles:
        if c["killed"]:
            new_gens = set(c["gens_after"]) - set(c["gens_before"])
            lost_max = max(lost_max, 0 if new_gens else 1)
    restarts = [c for c in cycles[1:] if c["report"] is not None]
    # "detected" = the damaged generation was rejected at boot (skipped
    # >= 1 counts cold boots too — the store may run dry of valid gens)
    # and was never the one served
    corruption_rows = [c for c in cycles if c["corruption"]]
    all_detected = all(
        c["report"] is not None and c["report"]["boot_skipped"] >= 1
        and c["report"]["boot_generation"] != c["corrupted_gen"]
        for c in corruption_rows)
    recovery_s = [c["report"]["first_query_s"] for c in restarts]
    payload = {
        "figure": "fig_recovery",
        "n": N, "d": D, "model": MODEL_NAME,
        "cycles": cycles,
        "summary": {
            "cycles": len(cycles),
            "kills": sum(c["killed"] for c in cycles),
            "corruptions": len(corruption_rows),
            "corruptions_detected": sum(
                1 for c in corruption_rows
                if c["report"] and c["report"]["boot_skipped"] >= 1),
            "all_corruptions_detected": bool(all_detected),
            "warm_boots": sum(1 for c in restarts
                              if c["report"]["boot_mode"] == "warm"),
            "max_generations_lost": lost_max,
            "invalid_responses": sum(c["report"]["invalid_responses"]
                                     for c in restarts),
            "mean_recovery_s": round(sum(recovery_s)
                                     / max(len(recovery_s), 1), 3),
            "max_recovery_s": round(max(recovery_s, default=0.0), 3),
            "errors": [c["error"] for c in cycles if "error" in c],
        },
    }
    return payload


def main():
    from benchmarks.common import emit, write_json
    with tempfile.TemporaryDirectory(prefix="recovery_store_") as td:
        payload = run_recovery(td)
    s = payload["summary"]
    emit(f"fig_recovery/n{N}_d{D}", None,
         f"cycles={s['cycles']} kills={s['kills']} "
         f"corruptions={s['corruptions']}/{s['corruptions_detected']}det "
         f"lost<={s['max_generations_lost']} "
         f"invalid={s['invalid_responses']} "
         f"warm_boots={s['warm_boots']} "
         f"recovery mean={s['mean_recovery_s']}s "
         f"max={s['max_recovery_s']}s errors={len(s['errors'])}")
    write_json("BENCH_recovery.json", payload)
    if s["errors"] or s["invalid_responses"] or not \
            s["all_corruptions_detected"] or s["max_generations_lost"] > 1:
        raise SystemExit("fig_recovery: durability invariant violated: "
                         + json.dumps(s))


if __name__ == "__main__":
    if len(sys.argv) >= 4 and sys.argv[1] == "--worker":
        out = worker(sys.argv[2], json.loads(sys.argv[3]))
        print(json.dumps(out))  # last line: the report the driver parses
    else:
        main()
