"""Multi-device scaling: sharded lattice MVM + batched multi-RHS solves.

The measurement half runs in a SUBPROCESS with
``--xla_force_host_platform_device_count=8`` (XLA reads the flag once at
backend init, and the rest of the benchmark suite must keep the 1 real
device), mirroring the tier-1 ``multidevice`` pytest lane. It reports,
per size:

  * single-device fused MVM time vs the 8-virtual-device sharded MVM
    time, and their relative error (contract: <= 1e-5);
  * the collective count of one sharded MVM from its jaxpr (contract:
    exactly ONE psum, nothing else — DESIGN.md §10);
  * the multi-RHS mBCG contract: a [y | Z] block with k probes traces
    ONE batched lattice MVM per CG iteration (``ops.mvm_count`` /
    ``mvm_cols`` instrumentation), and the batched block solve is raced
    against the k+1 per-column solves it replaces.

On a CPU host the 8 "devices" share the physical cores, so sharded wall
time measures overhead, not speedup — the artifact records it honestly
as ``sharded_overhead_x`` next to the error/collective contracts that
ARE hardware-independent. Results land in BENCH_scaling.json.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_WORKER_ENV = "REPRO_SCALING_WORKER"
_DEVICES = 8


def _worker() -> None:
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import SCALE
    from repro.core import filtering, lattice as lat_mod
    from repro.core.stencil import make_stencil
    from repro.kernels.blur.ops import lattice_mvm, mvm_cols, mvm_count
    from repro.sharding import simplex as sx
    from repro.solvers.cg import cg

    def timeit(fn, *args, iters=3):
        jax.block_until_ready(fn(*args))
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[len(ts) // 2]

    d, c, k = 3, 8, 8
    sizes = [int(n * max(SCALE, 0.1)) // _DEVICES * _DEVICES
             for n in (4096, 16384)]
    st = make_stencil("matern32", 1)
    mesh = sx.data_mesh()
    results = []
    for n in sizes:
        rng = np.random.default_rng(0)
        z = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
        lat = lat_mod.build_lattice_auto(z, spacing=st.spacing, r=st.r)
        w = jnp.asarray(st.weights, jnp.float32)

        single = jax.jit(lambda vv: lattice_mvm(lat, vv, w,
                                                backend="fused_xla"))
        sharded = jax.jit(lambda vv: sx.sharded_lattice_mvm(lat, vv, w,
                                                            mesh=mesh))
        t_single = timeit(single, v)
        t_sharded = timeit(sharded, v)
        rel = float(jnp.linalg.norm(sharded(v) - single(v))
                    / jnp.linalg.norm(single(v)))
        counts = sx.collective_counts(
            lambda vv: sx.sharded_lattice_mvm(lat, vv, w, mesh=mesh), v)

        # multi-RHS mBCG contract + batched-vs-per-column race
        matvec, _ = filtering.mvm_operator(z, st, cap=lat.cap)
        op = lambda vv: matvec(vv) + 0.1 * vv
        b = jnp.asarray(rng.normal(size=(n, 1 + k)), jnp.float32)
        c0, w0 = mvm_count(), mvm_cols()
        cg(op, b, tol=1e-2, max_iters=20)
        traced_mvms, traced_cols = mvm_count() - c0, mvm_cols() - w0
        t_block = timeit(lambda bb: cg(op, bb, tol=1e-2, max_iters=20)[0], b)
        t_cols = timeit(lambda bb: [
            cg(op, bb[:, i:i + 1], tol=1e-2, max_iters=20)[0]
            for i in range(1 + k)], b)

        results.append(dict(
            n=n, d=d, c=c, cap=lat.cap, m=int(lat.m),
            single_mvm_s=t_single, sharded_mvm_s=t_sharded,
            sharded_overhead_x=t_sharded / t_single,
            sharded_rel_err=rel, psums_per_mvm=counts["psum"],
            other_collectives=sum(v_ for k_, v_ in counts.items()
                                  if k_ != "psum"),
            mbcg_probes=k, mbcg_traced_mvms=traced_mvms,
            mbcg_traced_cols=traced_cols,
            cg_block_s=t_block, cg_per_column_s=t_cols,
            batched_speedup_x=t_cols / t_block,
        ))
    print(json.dumps({"devices": jax.device_count(), "results": results}))


def main() -> None:
    if os.environ.get(_WORKER_ENV) == "1":
        _worker()
        return
    from benchmarks.common import emit, write_json

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={_DEVICES}"
                        ).strip()
    env[_WORKER_ENV] = "1"
    out = subprocess.run([sys.executable, "-m", "benchmarks.fig_scaling"],
                         env=env, capture_output=True, text=True)
    if out.returncode != 0:
        raise RuntimeError(f"scaling worker failed:\n{out.stderr[-3000:]}")
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    payload["figure"] = "fig_scaling"
    payload["contract"] = ("one psum per sharded MVM; sharded == fused to "
                           "<=1e-5; one batched lattice MVM per mBCG "
                           "iteration for the whole [y|Z] block")
    for row in payload["results"]:
        emit(f"fig_scaling/mvm_single/n{row['n']}", row["single_mvm_s"],
             f"err{row['sharded_rel_err']:.1e}")
        emit(f"fig_scaling/mvm_sharded8/n{row['n']}", row["sharded_mvm_s"],
             f"psums{row['psums_per_mvm']}")
        emit(f"fig_scaling/cg_block/n{row['n']}", row["cg_block_s"],
             f"{row['batched_speedup_x']:.1f}x_vs_per_col")
    write_json("BENCH_scaling.json", payload)


if __name__ == "__main__":
    main()
