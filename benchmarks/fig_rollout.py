"""PILCO-style MC rollout throughput + serving gradcheck (DESIGN.md §15).

The control-workload benchmark behind differentiable frozen serving:
freeze a k=2-output pendulum dynamics model once (``freeze_multi`` — one
lattice, stacked tables), then push a P-particle, H-step Monte-Carlo
rollout through it as one jitted ``lax.scan``. Measured columns:

  rollout_s         one full (P, H) forward rollout (all channels)
  evals_per_s       particle state evaluations per second, P*H/rollout_s
                    (>= 1e4 on one CPU is the acceptance floor; in
                    practice ~1e6)
  grad_rollout_s    value_and_grad of the expected rollout cost w.r.t.
                    policy params — the end-to-end policy gradient
                    through the ``slice_only`` custom JVP
  worst_miss        max per-step miss_mass over the rollout (validity)

plus two correctness columns the trend check ENFORCES:

  gradcheck         worst central-difference relative error of
                    ``predict_grad``'s d(mean, var)/dx* over d in
                    {2, 3, 5} at same-cell interior probe pairs (the
                    served surface is piecewise linear/quadratic, so the
                    in-cell secant is the derivative up to f32 roundoff;
                    <= 1e-4 is the acceptance band)
  grad_collectives  collective-primitive counts on the jaxpr of the
                    query-space gradient under the replicated-table mesh
                    — all zero by the DESIGN.md §15 contract, asserted
                    here so a committed artifact can never claim
                    otherwise.

Results land in BENCH_rollout.json; tier-1 runs ``measure_rollout`` and
``measure_gradcheck`` at tiny size via the ``bench_smoke`` marker.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit, timeit, write_json
from repro.core import lattice as L
from repro.gp import (GPParams, SimplexGP, SimplexGPConfig, freeze,
                      freeze_multi)
from repro.gp.serve import _predict_core, predict, predict_grad, predict_multi
from repro.sharding.simplex import collective_counts, data_mesh

TIGHT = SimplexGPConfig(kernel="matern32", cg_tol_eval=3e-7,
                        max_cg_iters=400)
DT = 0.1
FD_EPS = 2.5e-2


def _pendulum_data(n):
    """(state, action) -> next-state-delta pairs of a damped pendulum."""
    rng = np.random.default_rng(0)
    th = rng.uniform(-np.pi, np.pi, n)
    om = rng.uniform(-7, 7, n)
    a = rng.uniform(-2, 2, n)
    om2 = om + DT * (-9.8 * np.sin(th) - 0.2 * om + a)
    th2 = th + DT * om2
    x = jnp.asarray(np.stack([th, om, a], 1), jnp.float32)
    y = jnp.asarray(np.stack([th2 - th, om2 - om], 1), jnp.float32)
    return x, y


def measure_rollout(n: int, particles: int, horizon: int, *,
                    variance_rank: int = 16, iters: int = 3) -> dict:
    """Freeze the k=2 dynamics model and race the MC rollout through it."""
    x, y = _pendulum_data(n)
    model = SimplexGP(SimplexGPConfig(kernel="matern32"))
    # anisotropic lengthscales sized to the state box (examples/
    # rollout_pilco.py): dense-per-cell coverage, near-zero rollout miss
    params = GPParams.init(3, lengthscale=jnp.asarray([1.0, 2.0, 1.2]),
                           noise=1e-2)

    t0 = time.perf_counter()
    mp = freeze_multi(model, params, x, y, key=jax.random.PRNGKey(0),
                      variance_rank=variance_rank)
    jax.block_until_ready(mp.tables)
    freeze_s = time.perf_counter() - t0

    def rollout(w, key):
        s0 = jnp.zeros((particles, 2), jnp.float32).at[:, 0].set(2.5)
        eps = jax.random.normal(key, (horizon, particles, 2))

        def step(s, e):
            a = 2.0 * jnp.tanh(s @ w[:2] + w[2])
            # wrap the angle into the trained chart (round has zero
            # tangent, so d wrap/d th == 1 — examples/rollout_pilco.py)
            th = s[:, 0] - 2 * jnp.pi * jnp.round(s[:, 0] / (2 * jnp.pi))
            q = jnp.stack([th, s[:, 1], a], axis=1)
            res = predict_multi(mp, q)
            s2 = s + res.mean + 0.1 * jnp.sqrt(res.var) * e
            cost = jnp.mean(jnp.sum(s2 ** 2, axis=1))
            return s2, (cost, jnp.max(res.miss_mass))

        _, (costs, miss) = jax.lax.scan(step, s0, eps)
        return jnp.mean(costs), jnp.max(miss)

    w0 = jnp.zeros(3)
    key = jax.random.PRNGKey(1)
    fwd = jax.jit(rollout)
    rollout_s = timeit(fwd, w0, key, iters=iters)
    _, worst_miss = fwd(w0, key)

    grad_fn = jax.jit(jax.value_and_grad(rollout, has_aux=True))
    grad_rollout_s = timeit(grad_fn, w0, key, iters=iters)

    evals = particles * horizon
    return {
        "n": n, "d_in": 3, "k": int(mp.n_outputs),
        "particles": particles, "horizon": horizon,
        "variance_rank": variance_rank,
        "m": int(mp.index.m),
        "freeze_s": round(freeze_s, 3),
        "rollout_s": round(rollout_s, 5),
        "evals_per_s": round(evals / rollout_s, 0),
        "grad_rollout_s": round(grad_rollout_s, 5),
        "grad_evals_per_s": round(evals / grad_rollout_s, 0),
        "worst_miss": round(float(worst_miss), 4),
    }


def measure_gradcheck(dims=(2, 3, 5), n: int = 400, *,
                      variance_rank: int = 8) -> dict:
    """Worst FD relative error of predict_grad per dimension (the number
    the trend check enforces at 1e-4). Probe pairs that cross a simplex
    cell boundary are excluded — there the surface is kinked by design
    and the secant measures the kink, not the gradient."""
    out = {"eps": FD_EPS, "dims": {}}
    worst_all = 0.0
    for d in dims:
        rng = np.random.default_rng(d)
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        y = (jnp.sin(2 * x[:, 0]) + 0.4 * x[:, 1] * x[:, d - 1])
        model = SimplexGP(TIGHT)
        params = GPParams.init(d, noise=0.3)
        pred = freeze(model, params, x, y, key=jax.random.PRNGKey(0),
                      variance_rank=variance_rank)
        xs = x[:64]
        g = predict_grad(pred, xs)
        sp = model.stencil.spacing
        worst = 0.0
        used = 0
        for j in range(d):
            e = jnp.zeros(d, xs.dtype).at[j].set(FD_EPS)
            xp, xm = xs + e, xs - e
            kp, _ = L.simplex_embed(xp / pred.lengthscale[None, :], sp)
            km = L.simplex_embed(xm / pred.lengthscale[None, :], sp)[0]
            keep = (np.asarray(jnp.all(kp == km, axis=(1, 2)))
                    & np.asarray(g.grad_ok))
            rp, rm = predict(pred, xp), predict(pred, xm)
            fdm = np.asarray((rp.mean - rm.mean) / (2 * FD_EPS))[keep]
            fdv = np.asarray((rp.var - rm.var) / (2 * FD_EPS))[keep]
            am = np.asarray(g.dmean[:, j])[keep]
            av = np.asarray(g.dvar[:, j])[keep]
            rel_m = np.abs(fdm - am) / np.maximum(np.abs(am), 1.0)
            rel_v = np.abs(fdv - av) / np.maximum(np.abs(av), 1.0)
            if keep.sum():
                worst = max(worst, float(rel_m.max()), float(rel_v.max()))
            used += int(keep.sum())
        out["dims"][str(d)] = {"worst_rel_err": worst, "pairs": used}
        worst_all = max(worst_all, worst)
    out["max_rel_err"] = worst_all
    return out


def measure_grad_collectives(n: int = 300, *, variance_rank: int = 6) -> dict:
    """Collective counts on the query-gradient jaxpr under the
    replicated-table mesh — asserted all-zero before the artifact is
    written (DESIGN.md §15 zero-collective gradient contract)."""
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    x, y = _pendulum_data(n)
    model = SimplexGP(SimplexGPConfig(kernel="matern32"))
    params = GPParams.init(3, lengthscale=jnp.asarray([1.0, 2.0, 1.2]),
                           noise=1e-2)
    pred = freeze(model, params, x, y[:, 0], key=jax.random.PRNGKey(0),
                  variance_rank=variance_rank)
    mesh = data_mesh(1)

    def grad_core(p, q):
        f = lambda qq: jnp.sum(_predict_core(p, qq, backend="slice_xla")[0])
        return jax.grad(f)(q)

    fn = shard_map(grad_core, mesh=mesh, in_specs=(P(), P("data")),
                   out_specs=P("data"), check_rep=False)
    counts = collective_counts(fn, pred, jnp.zeros((64, 3), jnp.float32))
    assert all(v == 0 for v in counts.values()), (
        f"query-space gradient is not collective-free: {counts}")
    return dict(counts)


def main() -> dict:
    n = int(2000 * SCALE)
    particles = int(256 * SCALE)
    row = measure_rollout(n, particles, 100)
    emit(f"rollout_n{n}_p{particles}_h100", row["rollout_s"],
         f"evals_per_s={row['evals_per_s']:.0f}")
    emit(f"rollout_grad_n{n}_p{particles}_h100", row["grad_rollout_s"],
         f"grad_evals_per_s={row['grad_evals_per_s']:.0f}")

    gc = measure_gradcheck()
    emit("gradcheck_d235", None, f"max_rel_err={gc['max_rel_err']:.2e}")
    counts = measure_grad_collectives()
    emit("grad_collectives", None,
         f"total={sum(counts.values())}")

    payload = {"rollout": row, "gradcheck": gc,
               "grad_collectives": counts}
    write_json("BENCH_rollout.json", payload)
    return payload


if __name__ == "__main__":
    main()
