"""Training-step / posterior cost: shared-lattice pipeline vs the seed path.

PR 1 fused the per-iteration MVM, which left the lattice *build* as the
dominant per-step cost: the seed pipeline builds the SAME lattice 3x per
training step (operator + two surrogate quad forms) and 3x per posterior
(operator + two cross-MVM joint builds). The shared-lattice pipeline
(DESIGN.md §9) performs exactly ONE build each and reuses the mBCG
tridiagonals for the log-det instead of a separate Lanczos pass.

This benchmark races both pipelines on the same data — the "legacy" config
(``shared_lattice=False, logdet_estimator="slq"``) IS the pre-change
measurement, recorded in the same artifact — and reports:

  * builds/step and builds/posterior (counted at trace level via
    ``lattice.build_count``: each traced build is one construction in the
    compiled program);
  * median step / posterior wall seconds;
  * MLL value under the CG-reused log-det vs the separate-SLQ one, as
    multi-seed means/stds at a converged CG tolerance — both are stochastic
    trace estimators over different probe draws, so the check is that the
    means agree within the probe-sampling noise (|z| modest), not that any
    single seed matches. (At the paper's train tolerance 1.0 the CG
    tridiagonals stop at the 10-iteration floor, which adds truncation bias
    — the standard GPyTorch/BBMM trade-off; the grads are unaffected, and
    model selection runs on validation RMSE per §5.4.);
  * n / d / m / cap so table growth is visible across PRs.

Results land in BENCH_train.json.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit, timeit, write_json
from repro.core.lattice import build_count, build_lattice_auto
from repro.gp import (GPParams, SimplexGP, SimplexGPConfig,
                      mll_value_and_grad, posterior)

SIZES = [1000, 4000]
D = 8
NS_FRACTION = 0.2  # test set size relative to n
NUM_PROBES = 8
MAX_CG = 40
MAX_LANCZOS = 40
VARIANCE_RANK = 15


def _configs():
    kw = dict(kernel="matern32", max_cg_iters=MAX_CG, num_probes=NUM_PROBES,
              max_lanczos_iters=MAX_LANCZOS, backend="auto")
    return {
        "legacy": SimplexGP(SimplexGPConfig(shared_lattice=False,
                                            logdet_estimator="slq", **kw)),
        "shared": SimplexGP(SimplexGPConfig(shared_lattice=True,
                                            logdet_estimator="cg", **kw)),
    }


def _measure(model, params, x, y, xs, *, step_cap, post_cap, key):
    """(builds/step, step_s, mll, builds/posterior, posterior_s)."""
    step = jax.jit(lambda p, k: mll_value_and_grad(model, p, x, y, k,
                                                   cap=step_cap))
    c0 = build_count()
    res = jax.block_until_ready(step(params, key))  # trace + compile
    builds_step = build_count() - c0
    step_s = timeit(step, params, key)
    mll = float(res.mll)

    post = jax.jit(lambda p, k: posterior(model, p, x, y, xs, key=k,
                                          variance_rank=VARIANCE_RANK,
                                          cap=post_cap))
    c0 = build_count()
    jax.block_until_ready(post(params, key).mean)
    builds_post = build_count() - c0
    post_s = timeit(post, params, key)
    return builds_step, step_s, mll, builds_post, post_s


def _mll_agreement(models, params, x, y, *, seeds: int = 6,
                   tol: float = 1e-4, depth: int = 100) -> dict:
    """Multi-seed means of both MLL estimators at matched converged depth.

    The timed configs truncate Krylov depth differently (CG stops at the
    training tolerance, SLQ at max_lanczos_iters), which would mix
    truncation bias into the comparison — so the agreement check re-runs
    both with ``depth`` iterations available and a tight tolerance, leaving
    probe sampling as the only difference. ``z_score`` = |mean_cg -
    mean_slq| / pooled std-error; both estimators are unbiased trace
    estimates over independent probe draws, so modest |z| means agreement
    within stochastic-estimator noise.
    """
    deep = {name: SimplexGP(dataclasses.replace(
        model.config, max_cg_iters=depth, max_lanczos_iters=depth))
        for name, model in models.items()}
    vals = {name: [] for name in deep}
    for name, model in deep.items():
        for s in range(seeds):
            res = mll_value_and_grad(model, params, x, y,
                                     jax.random.PRNGKey(s), tol=tol)
            vals[name].append(float(res.mll))
    mean = {k: float(np.mean(v)) for k, v in vals.items()}
    std = {k: float(np.std(v)) for k, v in vals.items()}
    pooled_se = max(np.sqrt(sum(s ** 2 for s in std.values()) / seeds),
                    1e-9)
    # A residual |z| ~ 2 at larger n is the known f32 effect: CG runs
    # without reorthogonalization, so its recovered tridiagonals develop
    # ghost eigenvalues at depth, slightly biasing the quadrature relative
    # to the fully reorthogonalized Lanczos — the standard BBMM trade-off.
    # rel_diff is the honest scale of that effect on the MLL itself.
    return {"mll_mean": {k: round(v, 3) for k, v in mean.items()},
            "mll_std": {k: round(v, 3) for k, v in std.items()},
            "seeds": seeds, "cg_tol": tol,
            "rel_diff": round(abs(mean["shared"] - mean["legacy"])
                              / max(abs(mean["legacy"]), 1.0), 4),
            "z_score": round(abs(mean["shared"] - mean["legacy"])
                             / pooled_se, 3)}


def main():
    rng = np.random.default_rng(0)
    models = _configs()
    rows = []
    for n in [int(s * SCALE) for s in SIZES]:
        ns = max(int(n * NS_FRACTION), 10)
        x = jnp.asarray(rng.normal(size=(n, D)) * 0.3, jnp.float32)
        y = jnp.asarray(np.sin(2 * np.asarray(x[:, 0]))
                        + 0.1 * rng.normal(size=n), jnp.float32)
        xs = jnp.asarray(rng.normal(size=(ns, D)) * 0.3, jnp.float32)
        params = GPParams.init(D)
        key = jax.random.PRNGKey(0)

        # right-size static caps outside jit (the fast-build entry): the
        # legacy config keeps the seed's worst-case default (cap=None)
        st = models["shared"].stencil
        ls = models["shared"].constrained(params)[0]
        lat0 = build_lattice_auto(x / ls[None, :], spacing=st.spacing,
                                  r=st.r)
        latj = build_lattice_auto(jnp.concatenate([x, xs]) / ls[None, :],
                                  spacing=st.spacing, r=st.r)
        m = int(lat0.m)
        caps = {"legacy": (None, None),
                "shared": (lat0.cap, latj.cap)}

        row = {"n": n, "ns": ns, "d": D, "m": m,
               "cap_shared": lat0.cap,
               "cap_worst": n * (D + 1)}
        for name, model in models.items():
            step_cap, post_cap = caps[name]
            bs, ss, mll, bp, ps = _measure(model, params, x, y, xs,
                                           step_cap=step_cap,
                                           post_cap=post_cap, key=key)
            row[name] = {"builds_per_step": bs, "step_s": round(ss, 4),
                         "mll": mll, "builds_per_posterior": bp,
                         "posterior_s": round(ps, 4)}
        row["step_speedup"] = round(row["legacy"]["step_s"]
                                    / row["shared"]["step_s"], 2)
        row["posterior_speedup"] = round(row["legacy"]["posterior_s"]
                                         / row["shared"]["posterior_s"], 2)
        row["mll_agreement"] = _mll_agreement(models, params, x, y)
        emit(f"fig_train/n{n}", row["shared"]["step_s"],
             f"legacy_step_s={row['legacy']['step_s']:.3f} "
             f"shared_step_s={row['shared']['step_s']:.3f} "
             f"step_speedup={row['step_speedup']}x "
             f"builds {row['legacy']['builds_per_step']}->"
             f"{row['shared']['builds_per_step']}/step "
             f"{row['legacy']['builds_per_posterior']}->"
             f"{row['shared']['builds_per_posterior']}/posterior "
             f"posterior_speedup={row['posterior_speedup']}x "
             f"mll_rel_diff={row['mll_agreement']['rel_diff']} "
             f"mll_z={row['mll_agreement']['z_score']}")
        rows.append(row)
    write_json("BENCH_train.json", {"figure": "fig_train_step",
                                    "kernel": "matern32", "sizes": rows})


if __name__ == "__main__":
    main()
