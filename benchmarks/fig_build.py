"""Cold/warm lattice-build latency: hash vs sort build backends.

After PR 1-3 the per-iteration MVM is µs-scale and builds are amortized
to one per step, so the COLD build — every cache miss, every joint
[X; X*] posterior build, i.e. exactly the serving path — is the dominant
latency. This benchmark races the two build paths (DESIGN.md §11):

  sort       two O(N log N) lexicographic `lax.sort` passes (dedup +
             neighbor merge-sort) — the PR 2 baseline;
  hash_xla   open-addressing hash table (kernels/hash): epoch scatter-min
             insert for dedup, gather-only probe lookup for neighbors.

Terminology (matches the serving cost model, DESIGN.md §9/§11): builds
run eagerly through jitted impls compiled ONCE per (n, d, r, cap) shape,
so a LatticeCache miss — every new point set, every posterior's joint
[X; X*] — pays the compiled program's EXECUTION time, not a recompile.
Reported per (n, d):

  compile_s   one-time trace+compile+first-run (fresh jit caches);
              amortized over the process lifetime.
  cold_s      the per-cache-miss build: compiled program on fresh data.
              This is the number every serving-path miss pays and the
              headline the hash path attacks.

plus a per-phase breakdown (embed / dedup / neighbor / plan) of cold_s
so the artifact shows WHERE the hash path wins. Results land in
BENCH_build.json; the tier-1 ``bench_smoke`` test runs ``measure_build``
at tiny size so a broken backend fails CI rather than the benchmark.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit, timeit, write_json
from repro.core import lattice as L
from repro.core.stencil import make_stencil
from repro.kernels.hash import ops as hash_ops

SIZES = [1000, 4000, 16000]
DIMS = [4, 8]
BACKENDS = ("sort", "hash_xla")


def _phase_fns(x, spacing: float, r: int, cap: int):
    """Jitted per-phase closures shared by both backends' breakdowns."""
    n, d = x.shape
    big = n * (d + 1)
    hcap = hash_ops.hash_capacity(cap)

    @jax.jit
    def embed(z):
        keys, w = L.simplex_embed(z, spacing)
        return jnp.stack(L._pack_key_cols(keys.reshape(big, d + 1)), axis=1)

    @jax.jit
    def dedup_sort(packed):
        cols = [packed[:, j] for j in range(packed.shape[1])]
        return L._lex_sort(cols, [jnp.arange(big, dtype=jnp.int32)])

    @jax.jit
    def dedup_hash(packed):
        return hash_ops.hash_insert(packed, hcap, backend="hash_xla")

    nbr_sort = jax.jit(functools.partial(L._neighbor_table, d=d, r=r,
                                         cap=cap))

    @jax.jit
    def nbr_hash(tkeys, q_packed, src_valid):
        return hash_ops.hash_lookup(tkeys, q_packed, src_valid, hcap,
                                    backend="hash_xla")

    @jax.jit
    def plan_hash(seg_ids):
        # shared with the build impl so the phase times the construction
        # the build actually runs (sort-free counting/partition plan)
        return L._splat_plan_counting(seg_ids, big=big, cap=cap)

    return embed, dedup_sort, dedup_hash, nbr_sort, nbr_hash, plan_hash


def _phases(x, spacing: float, r: int, cap: int) -> dict:
    """Warm per-phase seconds for both backends at this size."""
    n, d = x.shape
    hcap = hash_ops.hash_capacity(cap)
    embed, dedup_sort, dedup_hash, nbr_sort, nbr_hash, plan_hash = \
        _phase_fns(x, spacing, r, cap)
    packed = jax.block_until_ready(embed(x))
    lat = L.build_lattice(x, spacing=spacing, r=r, cap=cap, backend="sort")
    lath = L.build_lattice(x, spacing=spacing, r=r, cap=cap,
                           backend="hash_xla")
    owner, _, _ = hash_ops.hash_insert(packed, hcap, backend="hash_xla")
    tkeys = hash_ops.table_keys(owner, packed)
    q_packed, src_valid = L._neighbor_queries(lath.coords, lath.valid,
                                              d=d, r=r, cap=cap)

    return {
        "embed_s": timeit(embed, x),
        "sort": {"dedup_s": timeit(dedup_sort, packed),
                 "neighbor_s": timeit(nbr_sort, lat.coords, lat.valid)},
        "hash": {"dedup_s": timeit(dedup_hash, packed),
                 "neighbor_s": timeit(nbr_hash, tkeys, q_packed, src_valid),
                 "plan_s": timeit(plan_hash, lath.seg_ids)},
    }


def measure_build(x, *, r: int = 1, spacing: float | None = None,
                  with_phases: bool = True) -> dict:
    """Race all build backends on one point set; returns a result row."""
    n, d = x.shape
    if spacing is None:
        spacing = make_stencil("matern32", r).spacing
    # right-size the static cap once (the realistic serving configuration)
    lat0 = L.build_lattice_auto(x, spacing=spacing, r=r, backend="sort")
    cap, m = lat0.cap, int(lat0.m)
    row = {"n": n, "d": d, "m": m, "cap": cap,
           "hcap": hash_ops.hash_capacity(cap),
           "occupancy": round(m / hash_ops.hash_capacity(cap), 4)}
    for backend in BACKENDS:
        build = lambda: L.build_lattice(x, spacing=spacing, r=r, cap=cap,
                                        backend=backend)
        jax.clear_caches()  # one-time cost: trace + compile + first run
        import time
        t0 = time.perf_counter()
        jax.block_until_ready(build().coords)
        compile_s = time.perf_counter() - t0
        # per-cache-miss cost: the compiled program (jit does not cache on
        # data values, so this is exactly what a fresh point set pays);
        # extra iterations since a single-digit-ms median over 3 samples
        # right after a compile is visibly noisy
        cold = timeit(lambda: build().coords, iters=5)
        row[backend] = {"compile_s": round(compile_s, 4),
                        "cold_s": round(cold, 5)}
    row["cold_speedup"] = round(row["sort"]["cold_s"]
                                / row["hash_xla"]["cold_s"], 2)
    row["compile_speedup"] = round(row["sort"]["compile_s"]
                                   / row["hash_xla"]["compile_s"], 2)
    if with_phases:
        row["phases"] = {k: (v if not isinstance(v, dict) else
                             {kk: round(vv, 5) for kk, vv in v.items()})
                         for k, v in _phases(x, spacing, r, cap).items()}
        row["phases"]["embed_s"] = round(row["phases"]["embed_s"], 5)
    return row


def main():
    rng = np.random.default_rng(0)
    rows = []
    for n in [int(s * SCALE) for s in SIZES]:
        for d in DIMS:
            # unit-scale data: thousands of occupied lattice points at
            # n=16k (clustered 0.3-scale data dedups to m in the hundreds,
            # which under-stresses the dedup phase this figure measures)
            x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
            row = measure_build(x)
            emit(f"fig_build/n{n}_d{d}", row["hash_xla"]["cold_s"],
                 f"m={row['m']} cap={row['cap']} "
                 f"sort_cold={row['sort']['cold_s']:.3f}s "
                 f"hash_cold={row['hash_xla']['cold_s']:.3f}s "
                 f"cold_speedup={row['cold_speedup']}x "
                 f"compile_speedup={row['compile_speedup']}x")
            rows.append(row)
    write_json("BENCH_build.json", {"figure": "fig_build",
                                    "backends": list(BACKENDS),
                                    "sizes": rows})


if __name__ == "__main__":
    main()
