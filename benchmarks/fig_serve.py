"""Frozen-lattice serving latency vs the shared-lattice posterior path.

The serving question (ROADMAP north star): what does ONE query batch cost
once the model is trained? The ``posterior`` path pays a joint [X; X*]
lattice build + CG solve + Lanczos per batch; the frozen ``Predictor``
(gp/serve.py, DESIGN.md §12) pays embed + hash lookup + slice against
precomputed tables — cost independent of n. This benchmark measures both
on the same host and data:

  freeze_s       one-time freeze cost (solves + one blur sweep + index)
  posterior_s    per-batch latency of the jitted shared-lattice posterior
  serve_s        per-batch latency of ``predict`` (warm bucket)
  speedup        posterior_s / serve_s — the headline (>= 20x acceptance
                 floor at n=4000, d=8; in practice orders of magnitude)

plus the fidelity columns: mean/var parity between the two paths on
in-lattice queries under a TIGHT-tolerance config (both CG solves
converged, so the comparison isolates the frozen math from CG stopping
noise — at the default eval tolerance 1e-2 the two solves legitimately
differ by O(tol)), and the slice-miss diagnostic on off-lattice queries.
Results land in BENCH_serve.json; the tier-1 ``bench_smoke`` test runs
``measure_serve`` at tiny size so a broken serving path fails CI.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit, timeit, write_json
from repro.gp import (GPParams, SimplexGP, SimplexGPConfig, freeze,
                      posterior)
from repro.gp.serve import predict

SIZES = [(1000, 4), (4000, 8)]  # (n, d); 4000/8 is the acceptance config
BQ = 512  # queries per serving batch
RANK = 16  # LOVE variance rank for both paths

# tight-tolerance config for the parity columns: both paths' CG converged
# to the f32 floor, so parity measures the frozen math itself
TIGHT = dict(cg_tol_eval=3e-7, max_cg_iters=400)


def measure_serve(x, y, xs_in, xs_out, *, variance_rank: int = RANK,
                  with_parity: bool = True) -> dict:
    """Race one serving batch through both paths; returns a result row."""
    n, d = x.shape
    bq = xs_in.shape[0]
    key = jax.random.PRNGKey(0)
    params = GPParams.init(d)
    model = SimplexGP(SimplexGPConfig(kernel="matern32"))

    # --- latency at the DEFAULT eval config (what serving replaces) -------
    @jax.jit
    def post_fn(xs):
        p = posterior(model, params, x, y, xs, key=key,
                      variance_rank=variance_rank)
        return p.mean, p.var
    posterior_s = timeit(post_fn, xs_in)

    t0 = time.perf_counter()
    pred = freeze(model, params, x, y, key=key,
                  variance_rank=variance_rank)
    jax.block_until_ready(pred.tables)
    freeze_s = time.perf_counter() - t0
    serve_s = timeit(lambda: predict(pred, xs_in).mean)

    row = {
        "n": n, "d": d, "bq": bq, "m": pred.index.m,
        "variance_rank": variance_rank,
        "freeze_s": round(freeze_s, 4),
        "posterior_s": round(posterior_s, 5),
        "serve_s": round(serve_s, 6),
        "speedup": round(posterior_s / serve_s, 1),
        "per_query_us": round(serve_s / bq * 1e6, 2),
        "qps": round(bq / serve_s, 0),
        "table_kb": round(pred.tables.nbytes / 1024, 1),
    }

    # --- fidelity: in-lattice parity under the tight config ---------------
    if with_parity:
        tight = SimplexGP(SimplexGPConfig(kernel="matern32", **TIGHT))
        pred_t = freeze(tight, params, x, y, key=key,
                        variance_rank=variance_rank)
        sr = predict(pred_t, xs_in)
        pt = posterior(tight, params, x, y, xs_in, key=key,
                       variance_rank=variance_rank)
        row["mean_parity"] = float(jnp.max(jnp.abs(sr.mean - pt.mean)))
        row["var_parity"] = float(jnp.max(jnp.abs(sr.var - pt.var)))
        row["miss_in_lattice"] = float(jnp.max(sr.miss_mass))

    # --- miss diagnostic on off-lattice queries ---------------------------
    so = predict(pred, xs_out)
    row["offlattice"] = {
        "miss_frac": float(jnp.mean((so.miss_mass > 0).astype(jnp.float32))),
        "mean_miss": float(jnp.mean(so.miss_mass)),
        "max_miss": float(jnp.max(so.miss_mass)),
    }
    return row


def main():
    rng = np.random.default_rng(0)
    rows = []
    for n, d in SIZES:
        n = int(n * SCALE)
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        y = (jnp.sin(2 * x[:, 0]) + 0.4 * x[:, 1] * x[:, 2]
             + 0.05 * jnp.asarray(rng.normal(size=n), jnp.float32))
        # in-lattice queries: train points (simplices fully in the lattice);
        # off-lattice: fresh draws from a wider distribution
        xs_in = x[:BQ]
        xs_out = jnp.asarray(rng.normal(size=(BQ, d)) * 2.0, jnp.float32)
        row = measure_serve(x, y, xs_in, xs_out)
        emit(f"fig_serve/n{n}_d{d}", row["serve_s"],
             f"posterior={row['posterior_s']:.3f}s "
             f"serve={row['serve_s'] * 1e3:.2f}ms "
             f"speedup={row['speedup']}x "
             f"per_query={row['per_query_us']}us "
             f"mean_parity={row['mean_parity']:.1e} "
             f"miss_frac={row['offlattice']['miss_frac']:.2f}")
        rows.append(row)
    write_json("BENCH_serve.json", {"figure": "fig_serve", "bq": BQ,
                                    "sizes": rows})


if __name__ == "__main__":
    main()
