"""§Roofline report: aggregate results/dryrun_*.json into the table.

Reads every dry-run artifact (launch/dryrun.py writes one JSON per cell)
and prints the three roofline terms + bottleneck + useful-compute fraction
per (arch x shape x mesh). Used to generate EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit
from repro.utils.roofline import format_table

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"


def load_rows(mesh: str = "single"):
    rows = []
    skips = []
    for f in sorted(RESULTS.glob("dryrun_*.json")):
        data = json.loads(f.read_text())
        if data.get("mesh") != mesh:
            continue
        status = str(data.get("status", ""))
        name = f"{data.get('arch', data.get('cell'))} x {data['shape']}" \
            if "shape" in data else str(data.get("cell"))
        if status.startswith("SKIP"):
            skips.append((name, status))
            continue
        if status != "OK" or "roofline" not in data:
            skips.append((name, status or "missing"))
            continue
        row = dict(data["roofline"])
        row["name"] = name
        rows.append(row)
    return rows, skips


def main():
    for mesh in ("single", "multi"):
        rows, skips = load_rows(mesh)
        if not rows:
            emit(f"roofline/{mesh}", None, "no dry-run artifacts found")
            continue
        print(f"# roofline ({mesh}-pod mesh)")
        print(format_table(rows))
        for name, status in skips:
            print(f"{name:42s} {status}")
        for r in rows:
            emit(f"roofline/{mesh}/{r['name'].replace(' ', '')}", None,
                 f"bound={r['bottleneck']} step={r['step_time']:.4f}s "
                 f"mfu_bound={r['mfu_bound']:.3f}")


if __name__ == "__main__":
    main()
