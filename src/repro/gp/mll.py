"""BBMM marginal log-likelihood: value + unbiased stochastic gradients.

Paper Eq. 4 with the Gardner et al. (2018a) estimator:

  value:  -1/2 y^T u - 1/2 logdet(K_hat) - n/2 log 2pi,
          u = K_hat^{-1} y via CG (Appendix A tolerances),
          logdet via SLQ on the Lanczos tridiagonals mBCG already collected
          during the probe solves (BBMM's "log-det for free"; the separate
          Lanczos pass survives as ``logdet_estimator="slq"`` and for
          preconditioned runs, where the CG tridiagonals describe the
          preconditioned operator rather than K_hat).

  grads:  dMLL/dtheta = 1/2 u^T (dK/dtheta) u - 1/2 E_z[w^T (dK/dtheta) z],
          w = K_hat^{-1} z, z Rademacher probes — realized by differentiating
          the *surrogate* S = 1/2 u^T K(theta) u - 1/(2p) sum_i w_i^T K(theta) z_i
          with u, w, z treated as constants. K(theta) applications go through
          the §4.2 custom VJP, so every gradient is itself a lattice
          filtering call — the paper's headline trick.

One lattice build per step (DESIGN.md §9): the operator built for the
solves is threaded into the surrogate ``quad_form`` via
``lattice_filter_with``, so the whole step — solves, log-det, and all
gradients — runs on a single build (down from 3+ in the seed). The
data-fit and trace surrogate terms are batched into ONE (1+p)-column
quad form (quad_form is bilinear), so the step's gradient costs a single
batched filtering + its single batched §4.2 backward filtering. Set
``SimplexGPConfig.shared_lattice=False`` for the seed's rebuild-per-call
behavior (the benchmark baseline). Optional RR-CG (Table 4) replaces the
y-solve with the unbiased randomized-truncation estimator.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.filtering import LatticeCache
from repro.gp.models import GPParams, SimplexGP
from repro.solvers.cg import cg as cg_solve
from repro.solvers.lanczos import slq_logdet, slq_logdet_from_cg
from repro.solvers.pivoted_cholesky import pivoted_cholesky, woodbury_precond
from repro.solvers.rrcg import rrcg as rrcg_solve

Array = jax.Array


class MLLResult(NamedTuple):
    mll: Array  # () the MLL value (per Eq. 4, up to reported constant)
    grads: GPParams  # d(-MLL)/d(raw params) — ready for a minimizer
    cg_iters: Array  # () iterations the solve used
    cg_residual: Array  # () final relative residual of the y-solve
    overflow: Array  # () bool: lattice table overflowed (grow cap, retry)
    pack_overflow: Array  # () bool: coord range overflow — growth can't fix


def _solve_block(model: SimplexGP, params: GPParams, x: Array, y: Array,
                 probes: Array, *, tol: float, rr_key: Array | None,
                 cap: int | None, cache: LatticeCache | None, mesh=None):
    """u = K^{-1} y and W = K^{-1} Z with one operator build.

    The whole ``[y | Z]`` block goes through ONE mBCG run whose matvec is
    a single (n, 1+p)-channel lattice MVM per iteration — the multi-RHS
    operator contract (never one MVM per probe).
    """
    cfg = model.config
    op = model.operator(params, x, cap=cap, cache=cache, mesh=mesh)

    precond = None
    if cfg.precond_rank > 0:
        diag = op.outputscale + op.noise
        row_fn = lambda i: model.exact_row(params, x, i)
        pc = pivoted_cholesky(row_fn, jnp.full(x.shape[0], diag,
                                                      x.dtype),
                                     cfg.precond_rank)
        precond = woodbury_precond(pc.l, op.noise)

    b = jnp.concatenate([y[:, None], probes], axis=1)
    solves, info = cg_solve(op.mvm, b, precond=precond, tol=tol,
                             max_iters=cfg.max_cg_iters)
    if rr_key is not None:
        rr = rrcg_solve(op.mvm, y[:, None], key=rr_key,
                           precond=precond,
                           min_iters=max(cfg.max_cg_iters // 4, 10),
                           max_iters=cfg.max_cg_iters)
        solves = solves.at[:, 0].set(rr.x[:, 0])
    return op, solves, info, precond


def mll_value_and_grad(model: SimplexGP, params: GPParams, x: Array,
                       y: Array, key: Array, *, tol: float | None = None,
                       use_rrcg: bool = False, cap: int | None = None,
                       cache: LatticeCache | None = None,
                       mesh=None) -> MLLResult:
    """One training-step MLL evaluation (value + surrogate gradients).

    ``cap`` overrides the worst-case lattice capacity (thread a right-sized
    cap chosen outside jit — see gp/train.py); ``cache`` memoizes
    eager-mode lattice builds across calls with unchanged hyperparameters.
    ``mesh`` shards every solve-phase MVM over its "data" axis (DESIGN.md
    §10; n must divide the axis size).
    """
    cfg = model.config
    n = x.shape[0]
    tol = cfg.cg_tol_train if tol is None else tol

    pk, lk, rk = jax.random.split(key, 3)
    probes = jax.random.rademacher(pk, (n, cfg.num_probes),
                                   dtype=x.dtype)

    sg_params = jax.tree.map(jax.lax.stop_gradient, params)
    op, solves, info, precond = _solve_block(
        model, sg_params, x, y, probes, tol=tol,
        rr_key=rk if use_rrcg else None, cap=cap, cache=cache, mesh=mesh)
    u = jax.lax.stop_gradient(solves[:, 0])
    w = jax.lax.stop_gradient(solves[:, 1:])

    # ---- value ------------------------------------------------------------
    # The probe columns of the mBCG run ARE Lanczos processes on K_hat
    # started at z_i/||z_i||, so their tridiagonals give the SLQ log-det with
    # zero extra MVMs. (With a preconditioner they tridiagonalize P^{-1}K
    # instead — fall back to the separate pass.)
    if cfg.logdet_estimator == "cg" and precond is None:
        probe_norms2 = jnp.full((cfg.num_probes,), float(n), x.dtype)
        logdet = slq_logdet_from_cg(info.alphas[:, 1:], info.betas[:, 1:],
                                    info.valid[:, 1:], probe_norms2)
    else:
        logdet = slq_logdet(op.mvm, n, key=lk,
                            num_probes=cfg.num_probes,
                            num_iters=cfg.max_lanczos_iters,
                            dtype=x.dtype)
    mll = (-0.5 * jnp.dot(y, u) - 0.5 * logdet
           - 0.5 * n * math.log(2.0 * math.pi))

    # ---- gradients via the surrogate --------------------------------------
    # Shared-lattice path: the surrogate quad form filters on the operator's
    # lattice (numerically identical params — sg_params is a stop_gradient
    # of the same values), so the step performs exactly one build.
    #
    # Multi-RHS: quad_form is bilinear, so the data-fit and trace terms
    # batch into ONE (1+p)-column call — the §4.2 backward then also runs
    # as a single batched filtering instead of one per term:
    #   S = 1/2 u^T K u - 1/(2p) sum_i w_i^T K z_i = sum(A * K_hat B),
    #   A = [1/2 u | -1/(2p) W],  B = [u | Z].
    shared = (op.lattice if cfg.shared_lattice and cfg.grad_mode == "paper"
              else None)
    a_blk = jnp.concatenate([0.5 * u[:, None],
                             (-0.5 / cfg.num_probes) * w], axis=1)
    b_blk = jnp.concatenate([u[:, None], probes], axis=1)

    def neg_surrogate(p: GPParams) -> Array:
        return -model.quad_form(p, x, a_blk, b_blk, lat=shared)

    grads = jax.grad(neg_surrogate)(params)
    return MLLResult(mll=mll, grads=grads, cg_iters=info.iterations,
                     cg_residual=info.residual_norms[0],
                     overflow=op.lattice.overflow,
                     pack_overflow=op.lattice.pack_overflow)
