"""Full-batch GP hyperparameter training (paper §5.3, Appendix A).

Adam(lr=0.1) on the BBMM MLL; CG tolerance 1.0 during training and 1e-2 at
eval; early stopping on *validation RMSE* (§5.4: the MLL is non-monotone at
high CG tolerance, so the best model is selected by held-out RMSE). Optional
RR-CG solves reproduce Table 4's stability/runtime trade-off.

Lattice sizing (DESIGN.md §9): the jitted step needs a STATIC table
capacity, but the worst case n(d+1) over-allocates ~3-50x on real data
(paper Table 3) and every per-lattice-point array — the neighbor table
above all — scales with it. So ``fit`` right-sizes the cap OUTSIDE jit
with ``build_lattice_auto`` under the initial hyperparameters (plus
headroom for lengthscale drift), threads it into the jitted step/eval as a
static argument, and watches the step's overflow flag: if training moves
the lengthscale enough to overflow the table, the cap grows and the step
re-jits — the grow-and-retry contract, amortized over the whole run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.lattice import build_lattice_auto, default_capacity
from repro.gp import mll as mll_mod
from repro.gp import predict as predict_mod
from repro.gp.models import GPParams, SimplexGP
from repro.optim import Adam

Array = jax.Array

CAP_GROWTH = 4  # multiplier applied when a step/eval overflows its table


@dataclasses.dataclass
class TrainResult:
    params: GPParams
    best_params: GPParams
    history: list[dict]
    best_val_rmse: float


def _auto_cap(model: SimplexGP, params: GPParams, x: Array, *,
              headroom: int = 2) -> int:
    """Right-size a static lattice capacity for ``x`` under ``params``.

    One eager auto build (grow-and-retry on the overflow flag), then
    ``headroom``x margin so moderate lengthscale shrink during training
    does not immediately overflow the table.
    """
    st = model.stencil
    ls = model.constrained(params)[0]
    lat = build_lattice_auto(x / ls[None, :], spacing=st.spacing, r=st.r,
                             backend=model.config.build_backend)
    worst = default_capacity(*x.shape)
    return min(max(lat.cap * headroom, 1024), worst)


def fit(model: SimplexGP, x: Array, y: Array, *, x_val: Array, y_val: Array,
        epochs: int = 100, lr: float = 0.1, seed: int = 0,
        use_rrcg: bool = False, patience: int = 15,
        auto_cap: bool = True, mesh=None,
        log_fn: Callable[[str], None] | None = None) -> TrainResult:
    """``mesh`` runs every solve/posterior MVM data-parallel over the
    mesh's "data" axis (DESIGN.md §10); n and n + n_val must divide the
    axis size. The lattice build and the surrogate gradients stay
    single-device — the per-iteration MVMs are where the time goes."""
    d = x.shape[1]
    params = GPParams.init(d)
    opt = Adam(learning_rate=lr)
    opt_state = opt.init(params)
    key = jax.random.PRNGKey(seed)

    worst = default_capacity(*x.shape)
    worst_joint = default_capacity(x.shape[0] + x_val.shape[0], d)
    if auto_cap and model.config.shared_lattice:
        cap = _auto_cap(model, params, x)
        cap_val = _auto_cap(model, params, jnp.concatenate([x, x_val]))
    else:
        cap, cap_val = worst, worst_joint

    def make_step(cap):
        @jax.jit
        def step(params, opt_state, key):
            res = mll_mod.mll_value_and_grad(model, params, x, y, key,
                                             use_rrcg=use_rrcg, cap=cap,
                                             mesh=mesh)
            new_params, new_state = opt.update(res.grads, opt_state, params)
            return (new_params, new_state, res.mll, res.cg_iters,
                    res.overflow, res.pack_overflow)
        return step

    def make_val(cap_val):
        @jax.jit
        def val_rmse(params, key):
            post = predict_mod.posterior(model, params, x, y, x_val,
                                         key=key, variance_rank=10,
                                         cap=cap_val, mesh=mesh)
            return (predict_mod.rmse(post, y_val), post.overflow,
                    post.pack_overflow)
        return val_rmse

    def _check_pack(povf):
        # coordinate-range overflow corrupts results and no capacity can
        # fix it — fail loudly rather than train on a broken lattice
        if bool(povf):
            raise RuntimeError(
                "lattice coordinate range overflow (|coord| > 2^15): the "
                "lengthscale/input scaling is degenerate (z = x / ls far "
                "too spread). Rescale inputs or bound the lengthscale.")

    step = make_step(cap)
    val_rmse = make_val(cap_val)

    best = (jnp.inf, params)
    history = []
    stall = 0
    for epoch in range(epochs):
        key, k1, k2 = jax.random.split(key, 3)
        t0 = time.perf_counter()
        while True:
            new_params, new_state, mll, iters, ovf, povf = step(
                params, opt_state, k1)
            _check_pack(povf)
            if not bool(ovf) or cap >= worst:
                break
            cap = min(cap * CAP_GROWTH, worst)  # stale grads: grow & redo
            step = make_step(cap)
        params, opt_state = new_params, new_state
        dt = time.perf_counter() - t0
        while True:
            rmse_v, ovf, povf = val_rmse(params, k2)
            _check_pack(povf)
            if not bool(ovf) or cap_val >= worst_joint:
                break
            cap_val = min(cap_val * CAP_GROWTH, worst_joint)
            val_rmse = make_val(cap_val)
        rmse = float(rmse_v)
        history.append(dict(epoch=epoch, mll=float(mll), val_rmse=rmse,
                            cg_iters=int(iters), seconds=dt, cap=cap))
        if log_fn:
            log_fn(f"epoch {epoch:3d}  mll/n {float(mll)/x.shape[0]:+.4f}  "
                   f"val_rmse {rmse:.4f}  cg_iters {int(iters)}  {dt:.2f}s")
        if rmse < float(best[0]) - 1e-5:
            best = (rmse, params)
            stall = 0
        else:
            stall += 1
            if stall >= patience:
                break
    return TrainResult(params=params, best_params=best[1], history=history,
                       best_val_rmse=float(best[0]))
