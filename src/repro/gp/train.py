"""Full-batch GP hyperparameter training (paper §5.3, Appendix A).

Adam(lr=0.1) on the BBMM MLL; CG tolerance 1.0 during training and 1e-2 at
eval; early stopping on *validation RMSE* (§5.4: the MLL is non-monotone at
high CG tolerance, so the best model is selected by held-out RMSE). Optional
RR-CG solves reproduce Table 4's stability/runtime trade-off.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.gp import mll as mll_mod
from repro.gp import predict as predict_mod
from repro.gp.models import GPParams, SimplexGP
from repro.optim import Adam

Array = jax.Array


@dataclasses.dataclass
class TrainResult:
    params: GPParams
    best_params: GPParams
    history: list[dict]
    best_val_rmse: float


def fit(model: SimplexGP, x: Array, y: Array, *, x_val: Array, y_val: Array,
        epochs: int = 100, lr: float = 0.1, seed: int = 0,
        use_rrcg: bool = False, patience: int = 15,
        log_fn: Callable[[str], None] | None = None) -> TrainResult:
    d = x.shape[1]
    params = GPParams.init(d)
    opt = Adam(learning_rate=lr)
    opt_state = opt.init(params)
    key = jax.random.PRNGKey(seed)

    @jax.jit
    def step(params, opt_state, key):
        res = mll_mod.mll_value_and_grad(model, params, x, y, key,
                                         use_rrcg=use_rrcg)
        new_params, new_state = opt.update(res.grads, opt_state, params)
        return new_params, new_state, res.mll, res.cg_iters

    @jax.jit
    def val_rmse(params, key):
        post = predict_mod.posterior(model, params, x, y, x_val, key=key,
                                     variance_rank=10)
        return predict_mod.rmse(post, y_val)

    best = (jnp.inf, params)
    history = []
    stall = 0
    for epoch in range(epochs):
        key, k1, k2 = jax.random.split(key, 3)
        t0 = time.perf_counter()
        params, opt_state, mll, iters = step(params, opt_state, k1)
        dt = time.perf_counter() - t0
        rmse = float(val_rmse(params, k2))
        history.append(dict(epoch=epoch, mll=float(mll), val_rmse=rmse,
                            cg_iters=int(iters), seconds=dt))
        if log_fn:
            log_fn(f"epoch {epoch:3d}  mll/n {float(mll)/x.shape[0]:+.4f}  "
                   f"val_rmse {rmse:.4f}  cg_iters {int(iters)}  {dt:.2f}s")
        if rmse < float(best[0]) - 1e-5:
            best = (rmse, params)
            stall = 0
        else:
            stall += 1
            if stall >= patience:
                break
    return TrainResult(params=params, best_params=best[1], history=history,
                       best_val_rmse=float(best[0]))
