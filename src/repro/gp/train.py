"""Full-batch GP hyperparameter training (paper §5.3, Appendix A).

Adam(lr=0.1) on the BBMM MLL; CG tolerance 1.0 during training and 1e-2 at
eval; early stopping on *validation RMSE* (§5.4: the MLL is non-monotone at
high CG tolerance, so the best model is selected by held-out RMSE). Optional
RR-CG solves reproduce Table 4's stability/runtime trade-off.

Lattice sizing (DESIGN.md §9): the jitted step needs a STATIC table
capacity, but the worst case n(d+1) over-allocates ~3-50x on real data
(paper Table 3) and every per-lattice-point array — the neighbor table
above all — scales with it. So ``fit`` right-sizes the cap OUTSIDE jit
with ``build_lattice_auto`` under the initial hyperparameters (plus
headroom for lengthscale drift), threads it into the jitted step/eval as a
static argument, and watches the step's overflow flag: if training moves
the lengthscale enough to overflow the table, the cap grows and the step
re-jits — the grow-and-retry contract, amortized over the whole run.

Durability (DESIGN.md §14): training state is the expensive asset of an
MVM-based run, so ``fit`` periodically checkpoints the FULL loop state —
``(params, opt_state, best_params, rng key)`` as host-gathered logical
arrays via ``runtime/checkpoint.py`` (so a restore re-shards onto any
mesh, per ``runtime/elastic.py``), plus the non-array loop state (epoch,
caps, early-stop bookkeeping, the divergence window) in the manifest.
A crashed run re-invoked with the same ``ckpt_dir`` resumes from the
newest VALID checkpoint bit-compatibly: the rng key is saved post-split,
so the resumed trajectory is the uninterrupted one.

The same snapshot powers the DIVERGENCE GUARD: a non-finite loss/grad or
a loss spike outside the windowed band rolls the loop back to the last
good state (in-memory; the disk checkpoint is the crash-durable copy)
with escalated noise jitter and a backed-off learning rate — bounded by
``max_rollbacks``, every event recorded in the ``FitReport``.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.lattice import build_lattice_auto, default_capacity
from repro.gp import mll as mll_mod
from repro.gp import predict as predict_mod
from repro.gp.models import GPParams, SimplexGP
from repro.optim import Adam
from repro.runtime import faults as faults_mod
from repro.runtime.checkpoint import CheckpointManager

Array = jax.Array

CAP_GROWTH = 4  # multiplier applied when a step/eval overflows its table


@dataclasses.dataclass
class FitReport:
    """Durability/robustness log of one ``fit`` run (DESIGN.md §14)."""

    resumed_from_epoch: int | None = None  # checkpointed epoch restored at start
    checkpoint_dir: str | None = None
    checkpoints_written: int = 0
    rollbacks: list = dataclasses.field(default_factory=list)
    # each rollback entry: {epoch, reason, restored_epoch, lr_scale,
    #                       jitter_raw} — the full escalation trail
    completed_epochs: int = 0
    retries: list = dataclasses.field(default_factory=list)
    # each retry entry: {epoch, error, remaining} — a transient in-step
    # failure that was absorbed by re-running the step (DESIGN.md §16)
    watchdog_breaches: list = dataclasses.field(default_factory=list)
    # each breach entry: {epoch, deadline, seconds} — a slow/hung step
    # that tripped the StepWatchdog; fit checkpoints immediately after
    interrupted: str | None = None  # why the loop stopped early, if it did


@dataclasses.dataclass
class TrainResult:
    params: GPParams
    best_params: GPParams
    history: list[dict]
    best_val_rmse: float
    report: FitReport = dataclasses.field(default_factory=FitReport)


def _auto_cap(model: SimplexGP, params: GPParams, x: Array, *,
              headroom: int = 2) -> int:
    """Right-size a static lattice capacity for ``x`` under ``params``.

    One eager auto build (grow-and-retry on the overflow flag), then
    ``headroom``x margin so moderate lengthscale shrink during training
    does not immediately overflow the table.
    """
    st = model.stencil
    ls = model.constrained(params)[0]
    lat = build_lattice_auto(x / ls[None, :], spacing=st.spacing, r=st.r,
                             backend=model.config.build_backend)
    worst = default_capacity(*x.shape)
    return min(max(lat.cap * headroom, 1024), worst)


@dataclasses.dataclass
class _LoopState:
    """Everything the loop needs to continue from — the checkpoint unit."""

    params: GPParams
    opt_state: object
    best_params: GPParams
    key: Array
    epoch: int  # last COMPLETED epoch (-1 = none)
    cap: int
    cap_val: int
    best_val_rmse: float
    stall: int
    lr_scale: float
    jitter_raw: float
    window: list  # recent accepted losses (-mll) for the spike band
    rollbacks: list  # rollback log entries (survive resume)

    def arrays(self) -> dict:
        return {"params": self.params, "opt_state": self.opt_state,
                "best_params": self.best_params, "key": self.key}

    def extra(self) -> dict:
        return {"epoch": self.epoch, "cap": self.cap,
                "cap_val": self.cap_val,
                "best_val_rmse": self.best_val_rmse, "stall": self.stall,
                "lr_scale": self.lr_scale, "jitter_raw": self.jitter_raw,
                "window": list(self.window),
                "rollbacks": list(self.rollbacks)}


def fit(model: SimplexGP, x: Array, y: Array, *, x_val: Array, y_val: Array,
        epochs: int = 100, lr: float = 0.1, seed: int = 0,
        use_rrcg: bool = False, patience: int = 15,
        auto_cap: bool = True, mesh=None,
        log_fn: Callable[[str], None] | None = None,
        ckpt_dir: str | None = None, ckpt_every: int = 10,
        keep_last: int = 3, resume: bool = True,
        max_rollbacks: int = 3, spike_window: int = 8,
        spike_sigma: float = 10.0, lr_backoff: float = 0.5,
        jitter_raw0: float = 0.1, faults=None,
        step_retries: int = 2, watchdog=None,
        watchdog_abort: bool = False) -> TrainResult:
    """``mesh`` runs every solve/posterior MVM data-parallel over the
    mesh's "data" axis (DESIGN.md §10); n and n + n_val must divide the
    axis size. The lattice build and the surrogate gradients stay
    single-device — the per-iteration MVMs are where the time goes.

    Durability knobs: ``ckpt_dir`` enables crash-durable checkpoints
    every ``ckpt_every`` epochs (atomic, async, ``keep_last`` retained
    plus keep-best by validation RMSE); re-invoking ``fit`` with the same
    ``ckpt_dir`` and ``resume=True`` continues from the newest VALID
    checkpoint (corrupt generations are skipped) with the identical rng
    trajectory. The divergence guard rolls back to the last good state
    when the loss/grads go non-finite or the loss spikes more than
    ``spike_sigma`` standard deviations above the ``spike_window``-epoch
    band, escalating a raw-noise jitter (+``jitter_raw0`` · 2^k) and
    backing off the learning rate (×``lr_backoff``) each time; after
    ``max_rollbacks`` rollbacks it raises rather than looping. ``faults``
    (a ``runtime/faults.FaultInjector``) arms the scripted crash/
    divergence probes the recovery tests replay.

    Elastic/failure semantics (DESIGN.md §16): a transient exception
    raised INSIDE the jitted step (the ``"fit_step"`` fault site, or any
    error ``runtime/faults.is_injected`` recognizes) is absorbed by
    re-running the step — up to ``step_retries`` consecutive times per
    epoch, each recorded in ``FitReport.retries`` — because the step is
    a pure function of ``(params, opt_state, key)``: nothing was mutated
    when it raised, so the retry replays the identical computation. A
    ``watchdog`` (``runtime/straggler.StepWatchdog``) times every epoch;
    a breach is recorded in ``FitReport.watchdog_breaches`` and forces
    an immediate checkpoint (the epoch's result is still valid — slow is
    not wrong), and with ``watchdog_abort=True`` the loop then returns
    early with ``FitReport.interrupted = "watchdog_breach"`` so an
    elastic supervisor (launch/elastic_gp.py) can re-shard onto a
    surviving mesh and resume from that checkpoint.
    """
    d = x.shape[1]
    worst = default_capacity(*x.shape)
    worst_joint = default_capacity(x.shape[0] + x_val.shape[0], d)

    manager = None
    if ckpt_dir is not None:
        manager = CheckpointManager(ckpt_dir, keep_last=keep_last,
                                    keep_best=1)

    report = FitReport(checkpoint_dir=ckpt_dir)

    # -- initial or resumed loop state --------------------------------------
    def _fresh_state() -> _LoopState:
        params = GPParams.init(d)
        if auto_cap and model.config.shared_lattice:
            cap = _auto_cap(model, params, x)
            cap_val = _auto_cap(model, params, jnp.concatenate([x, x_val]))
        else:
            cap, cap_val = worst, worst_joint
        return _LoopState(params=params,
                          opt_state=Adam(learning_rate=lr).init(params),
                          best_params=params,
                          key=jax.random.PRNGKey(seed), epoch=-1,
                          cap=cap, cap_val=cap_val,
                          best_val_rmse=float("inf"), stall=0,
                          lr_scale=1.0, jitter_raw=0.0, window=[],
                          rollbacks=[])

    st = _fresh_state()
    if manager is not None and resume:
        step0 = manager.latest_valid_step()
        if step0 is not None:
            tmpl = st.arrays()
            tree = manager.restore(step0, jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tmpl))
            extra = manager.manifest(step0)["extra"]
            st = _LoopState(params=tree["params"],
                            opt_state=tree["opt_state"],
                            best_params=tree["best_params"],
                            key=tree["key"], epoch=int(extra["epoch"]),
                            cap=int(extra["cap"]),
                            cap_val=int(extra["cap_val"]),
                            best_val_rmse=float(extra["best_val_rmse"]),
                            stall=int(extra["stall"]),
                            lr_scale=float(extra["lr_scale"]),
                            jitter_raw=float(extra["jitter_raw"]),
                            window=list(extra.get("window", [])),
                            rollbacks=list(extra.get("rollbacks", [])))
            report.resumed_from_epoch = st.epoch
            if log_fn:
                log_fn(f"resume: restored epoch {st.epoch} from {ckpt_dir}")

    def make_opt(lr_scale: float) -> Adam:
        return Adam(learning_rate=lr * lr_scale)

    opt = make_opt(st.lr_scale)

    def make_step(cap, opt):
        # the in-step fault probe is only traced in when an injector is
        # armed: the production step (faults=None) compiles the identical
        # program it always did, so the PR 7 bit-compatibility guarantee
        # is untouched. The guarded variant takes a host-planned fault
        # code as an operand and returns the callback's poison flag as an
        # EXTRA OUTPUT (outputs cannot be dead-code-eliminated), leaving
        # mll/params untouched — guarded and unguarded trajectories stay
        # bit-identical. The callback only sleeps/echoes, never raises:
        # raising from one device thread of a sharded program deadlocks
        # the others in the collective (faults.exec_step_fault).
        guarded = faults is not None
        @jax.jit
        def step(params, opt_state, key, fault_code=None):
            res = mll_mod.mll_value_and_grad(model, params, x, y, key,
                                             use_rrcg=use_rrcg, cap=cap,
                                             mesh=mesh)
            grads_ok = jnp.all(jnp.asarray(
                [jnp.all(jnp.isfinite(g))
                 for g in jax.tree.leaves(res.grads)]))
            new_params, new_state = opt.update(res.grads, opt_state, params)
            out = (new_params, new_state, res.mll, res.cg_iters,
                   res.overflow, res.pack_overflow, grads_ok)
            if guarded:
                probe = jax.pure_callback(
                    faults_mod.exec_step_fault,
                    jax.ShapeDtypeStruct((), jnp.float32), fault_code)
                out = out + (probe,)
            return out
        return step

    def make_val(cap_val):
        @jax.jit
        def val_rmse(params, key):
            post = predict_mod.posterior(model, params, x, y, x_val,
                                         key=key, variance_rank=10,
                                         cap=cap_val, mesh=mesh)
            return (predict_mod.rmse(post, y_val), post.overflow,
                    post.pack_overflow)
        return val_rmse

    def _check_pack(povf):
        # coordinate-range overflow corrupts results and no capacity can
        # fix it — fail loudly rather than train on a broken lattice
        if bool(povf):
            raise RuntimeError(
                "lattice coordinate range overflow (|coord| > 2^15): the "
                "lengthscale/input scaling is degenerate (z = x / ls far "
                "too spread). Rescale inputs or bound the lengthscale.")

    step = make_step(st.cap, opt)
    val_rmse = make_val(st.cap_val)

    # in-memory rollback anchor: a cheap host copy of the last GOOD state
    # (the disk checkpoint is the crash-durable copy of the same thing)
    good = jax.tree.map(jnp.asarray, st.arrays())
    good_meta = st.extra()

    def _spike(loss: float) -> bool:
        w = st.window
        if len(w) < spike_window or not math.isfinite(loss):
            return False
        mean = sum(w) / len(w)
        var = sum((v - mean) ** 2 for v in w) / len(w)
        band = spike_sigma * max(math.sqrt(var),
                                 0.02 * abs(mean) + 1e-3)
        return loss > mean + band

    def _rollback(epoch: int, reason: str):
        nonlocal opt, step, good, good_meta
        if len(st.rollbacks) >= max_rollbacks:
            raise RuntimeError(
                f"fit: divergence guard exhausted after {max_rollbacks} "
                f"rollback(s); last reason: {reason}")
        restored = jax.tree.map(jnp.asarray, good)
        st.params = restored["params"]
        st.opt_state = restored["opt_state"]
        st.best_params = restored["best_params"]
        st.key = restored["key"]
        st.epoch = int(good_meta["epoch"])
        st.best_val_rmse = float(good_meta["best_val_rmse"])
        st.stall = int(good_meta["stall"])
        st.window = []  # post-restore losses rejoin a fresh band
        st.lr_scale *= lr_backoff
        st.jitter_raw = jitter_raw0 * (2 ** len(st.rollbacks))
        # escalated jitter: a larger noise floor conditions K_hat better;
        # raw-space additive keeps the bump monotone under softplus
        st.params = dataclasses.replace(
            st.params, raw_noise=st.params.raw_noise + st.jitter_raw)
        entry = dict(epoch=epoch, reason=reason,
                     restored_epoch=st.epoch, lr_scale=st.lr_scale,
                     jitter_raw=st.jitter_raw)
        st.rollbacks.append(entry)
        report.rollbacks.append(entry)
        opt = make_opt(st.lr_scale)
        step = make_step(st.cap, opt)
        if log_fn:
            log_fn(f"rollback #{len(st.rollbacks)} at epoch {epoch} "
                   f"({reason}): restored epoch {st.epoch}, "
                   f"lr x{st.lr_scale:g}, jitter +{st.jitter_raw:g}")

    def _checkpoint(metric: float | None):
        if manager is None:
            return
        manager.save(st.epoch, st.arrays(), metric=metric,
                     extra=st.extra())
        report.checkpoints_written += 1

    report.rollbacks.extend(st.rollbacks)
    history = []
    epoch = st.epoch + 1
    while epoch < epochs:
        if faults is not None:
            faults.kill_if_armed("fit")  # scripted device loss (os._exit)
            faults.maybe_raise("fit")  # scripted crash (recovery tests)
            if faults.take("fit", "nan_params") is not None:
                st.params = dataclasses.replace(
                    st.params, raw_lengthscale=st.params.raw_lengthscale
                    .at[0].set(jnp.nan))
            if faults.take("fit", "spike_params") is not None:
                # near-zero noise: K_hat goes ill-conditioned and the
                # data-fit term y^T K^-1 y explodes — a reliable, finite
                # loss spike (unlike outputscale, whose logdet blow-up
                # the truncated SLQ estimate underreports)
                st.params = dataclasses.replace(
                    st.params, raw_noise=st.params.raw_noise - 18.0)
        st.key, k1, k2 = jax.random.split(st.key, 3)
        t0 = time.perf_counter()
        pre_breaches = 0 if watchdog is None else len(watchdog.breaches)
        if watchdog is not None:
            watchdog.start_step(epoch)
        retries_left = step_retries
        while True:
            try:
                if faults is not None:
                    # consume the in-step schedule ONCE per dispatch (a
                    # retry is a new dispatch) and hand the decision to
                    # the compiled step as an operand; block so the
                    # injected sleep/poison has materialized before the
                    # flag is inspected, then raise the scripted fault
                    # HERE on the host — the callback itself never raises
                    code = faults.plan_step("fit_step")
                    out = jax.block_until_ready(
                        step(st.params, st.opt_state, k1, code))
                    *out, probe = out
                    if float(probe) != 0.0:
                        raise faults_mod.InjectedFault(
                            "injected exception at 'fit_step'")
                else:
                    out = step(st.params, st.opt_state, k1)
                new_params, new_state, mll, iters, ovf, povf, gok = out
            except Exception as err:  # noqa: BLE001 — non-injected re-raised
                if (retries_left > 0 and faults is not None
                        and faults_mod.is_injected(err)):
                    # the step's outputs are discarded on the poison path
                    # and nothing host-side was mutated — re-running it is
                    # safe and (fault aside) replays the identical
                    # computation
                    retries_left -= 1
                    entry = dict(epoch=epoch,
                                 error=str(err).splitlines()[0][:200],
                                 remaining=retries_left)
                    report.retries.append(entry)
                    if log_fn:
                        log_fn(f"transient step failure at epoch {epoch}: "
                               f"retrying ({retries_left} retr"
                               f"{'y' if retries_left == 1 else 'ies'} left)")
                    continue
                raise
            _check_pack(povf)
            if not bool(ovf) or st.cap >= worst:
                break
            st.cap = min(st.cap * CAP_GROWTH, worst)  # stale grads: regrow
            step = make_step(st.cap, opt)
        breached = False
        if watchdog is not None:
            step_seconds = time.perf_counter() - t0
            watchdog.end_step(step_seconds)
            breached = len(watchdog.breaches) > pre_breaches
            if breached:
                report.watchdog_breaches.append(dict(
                    epoch=epoch, deadline=watchdog.breaches[-1][1],
                    seconds=step_seconds))
                if log_fn:
                    log_fn(f"watchdog breach at epoch {epoch}: step took "
                           f"{step_seconds:.2f}s (deadline "
                           f"{watchdog.breaches[-1][1]:.2f}s)")

        # -- divergence guard (DESIGN.md §14) -------------------------------
        loss = float(-mll) if bool(jnp.isfinite(mll)) else float("nan")
        if not (bool(jnp.isfinite(mll)) and bool(gok)):
            _rollback(epoch, "non-finite loss/grads")
            epoch = st.epoch + 1
            continue
        if _spike(loss):
            _rollback(epoch, f"loss spike ({loss:.4g} outside the "
                             f"{len(st.window)}-epoch band)")
            epoch = st.epoch + 1
            continue

        st.params, st.opt_state = new_params, new_state
        dt = time.perf_counter() - t0
        while True:
            rmse_v, ovf, povf = val_rmse(st.params, k2)
            _check_pack(povf)
            if not bool(ovf) or st.cap_val >= worst_joint:
                break
            st.cap_val = min(st.cap_val * CAP_GROWTH, worst_joint)
            val_rmse = make_val(st.cap_val)
        rmse = float(rmse_v)
        st.window = (st.window + [loss])[-spike_window:]
        history.append(dict(epoch=epoch, mll=float(mll), val_rmse=rmse,
                            cg_iters=int(iters), seconds=dt, cap=st.cap))
        if log_fn:
            log_fn(f"epoch {epoch:3d}  mll/n {float(mll)/x.shape[0]:+.4f}  "
                   f"val_rmse {rmse:.4f}  cg_iters {int(iters)}  {dt:.2f}s")
        if rmse < st.best_val_rmse - 1e-5:
            st.best_val_rmse = rmse
            st.best_params = st.params
            st.stall = 0
        else:
            st.stall += 1
        st.epoch = epoch
        report.completed_epochs += 1

        # the just-completed epoch is the new rollback anchor (host copy,
        # detached from the loop's live references)
        good = jax.tree.map(jnp.asarray, st.arrays())
        good_meta = st.extra()
        if (epoch + 1) % max(ckpt_every, 1) == 0 or breached:
            # a breach forces an immediate checkpoint: the slow epoch's
            # result is valid (slow is not wrong), and if the mesh is
            # about to shrink this is the state the resume picks up
            _checkpoint(rmse)
        if breached and watchdog_abort:
            report.interrupted = "watchdog_breach"
            break
        if st.stall >= patience:
            break
        epoch += 1

    if manager is not None and history:
        _checkpoint(history[-1]["val_rmse"])  # final state always durable
        manager.wait()
    return TrainResult(params=st.params, best_params=st.best_params,
                       history=history, best_val_rmse=st.best_val_rmse,
                       report=report)
