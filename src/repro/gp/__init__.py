"""MVM-based GP inference built on the Simplex-GP operator."""
from repro.gp.models import GPParams, SimplexGP, SimplexGPConfig
from repro.gp.mll import MLLResult, mll_value_and_grad
from repro.gp.predict import Posterior, cross_mvm, nll, posterior, rmse
# NOTE: serve.predict is deliberately NOT re-exported here — the package
# attribute ``repro.gp.predict`` must stay the submodule above, not a
# function shadowing it. Serving call sites use
# ``from repro.gp.serve import predict``.
from repro.gp.serve import (Predictor, PredictorLoadError, ServeResult,
                            ValidationReport, freeze, load_predictor,
                            refreeze, save_predictor, self_probe,
                            validate_predictor)
from repro.gp.train import FitReport, TrainResult, fit

__all__ = ["GPParams", "SimplexGP", "SimplexGPConfig", "MLLResult",
           "mll_value_and_grad", "Posterior", "cross_mvm", "nll",
           "posterior", "rmse", "FitReport", "TrainResult", "fit",
           "Predictor", "PredictorLoadError", "ServeResult",
           "ValidationReport", "freeze", "load_predictor", "refreeze",
           "save_predictor", "self_probe", "validate_predictor"]
