"""MVM-based GP inference built on the Simplex-GP operator."""
from repro.gp.models import GPParams, SimplexGP, SimplexGPConfig
from repro.gp.mll import MLLResult, mll_value_and_grad
from repro.gp.predict import (Posterior, cross_mvm, exact_mean_grad, nll,
                              posterior, rmse)
# NOTE: serve.predict / serve.predict_grad etc. are deliberately NOT
# re-exported here — the package attribute ``repro.gp.predict`` must stay
# the submodule above, not a function shadowing it. Serving call sites use
# ``from repro.gp.serve import predict, predict_grad, ...``.
from repro.gp.serve import (MultiPredictor, MultiServeResult, Predictor,
                            PredictorLoadError, ServeGradResult, ServeResult,
                            ValidationReport, freeze, freeze_multi,
                            load_predictor, refreeze, save_predictor,
                            self_probe, validate_predictor)
from repro.gp.train import FitReport, TrainResult, fit

__all__ = ["GPParams", "SimplexGP", "SimplexGPConfig", "MLLResult",
           "mll_value_and_grad", "Posterior", "cross_mvm",
           "exact_mean_grad", "nll", "posterior", "rmse", "FitReport",
           "TrainResult", "fit", "MultiPredictor", "MultiServeResult",
           "Predictor", "PredictorLoadError", "ServeGradResult",
           "ServeResult", "ValidationReport", "freeze", "freeze_multi",
           "load_predictor", "refreeze", "save_predictor", "self_probe",
           "validate_predictor"]
