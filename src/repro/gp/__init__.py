"""MVM-based GP inference built on the Simplex-GP operator."""
from repro.gp.models import GPParams, SimplexGP, SimplexGPConfig
from repro.gp.mll import MLLResult, mll_value_and_grad
from repro.gp.predict import Posterior, cross_mvm, nll, posterior, rmse
from repro.gp.train import TrainResult, fit

__all__ = ["GPParams", "SimplexGP", "SimplexGPConfig", "MLLResult",
           "mll_value_and_grad", "Posterior", "cross_mvm", "nll",
           "posterior", "rmse", "TrainResult", "fit"]
