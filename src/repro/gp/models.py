"""GP model definitions: hyperparameters + the Simplex-GP operator factory.

``GPParams`` holds raw (unconstrained) hyperparameters; softplus transforms
keep lengthscale/outputscale/noise positive, with the paper's minimum-noise
floor (Appendix A: {1e-4, 1e-1}). ``SimplexGP.operator`` builds the lattice
ONCE per hyperparameter setting and returns the K_hat MVM closure used by
all CG/Lanczos iterations of that step — the paper's amortization — and
that same ``Lattice`` is shared with the surrogate ``quad_form`` calls via
``lat=`` (DESIGN.md §9), so a whole training step costs ONE build. Both
``operator`` and ``quad_form`` also take prebuilt/right-sized lattices from
outside jit (``lat=``/``cap=``) and an eager-mode ``LatticeCache``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import filtering, kernels_math as km
from repro.core.lattice import Lattice, build_lattice, default_capacity
from repro.core.stencil import Stencil, make_stencil

Array = jax.Array


def softplus(x: Array) -> Array:
    return jax.nn.softplus(x)


def inv_softplus(y) -> Array:
    y = jnp.asarray(y, jnp.float32)
    return y + jnp.log(-jnp.expm1(-y))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GPParams:
    raw_lengthscale: Array  # (d,) ARD
    raw_outputscale: Array  # ()
    raw_noise: Array  # ()

    @staticmethod
    def init(d: int, *, lengthscale: float = 1.0, outputscale: float = 1.0,
             noise: float = 0.1) -> "GPParams":
        return GPParams(
            raw_lengthscale=jnp.full((d,), inv_softplus(lengthscale)),
            raw_outputscale=inv_softplus(outputscale),
            raw_noise=inv_softplus(noise),
        )


@dataclasses.dataclass(frozen=True)
class SimplexGPConfig:
    """Static configuration (Appendix A defaults)."""

    kernel: str = "matern32"  # {rbf, matern12, matern32, matern52}
    order: int = 1  # blur stencil order r
    min_noise: float = 1e-4
    symmetrize: bool = True
    cap_factor: float = 1.0  # capacity = cap_factor * n * (d+1)
    cg_tol_train: float = 1.0
    cg_tol_eval: float = 1e-2
    max_cg_iters: int = 100
    # lattice-MVM backend tier (kernels/blur/ops.py policy; DESIGN.md §8):
    # "auto" picks fused_pallas/per_direction_pallas on TPU by VMEM fit and
    # the fused single-jit XLA path elsewhere.
    backend: str = "auto"
    # lattice BUILD path (kernels/hash/ops.py policy; DESIGN.md §11):
    # "auto" resolves to the open-addressing hash build (hash_pallas on
    # TPU when the key table fits VMEM, hash_xla elsewhere); "sort" keeps
    # the original lexicographic-sort build as the bit-exact oracle.
    build_backend: str = "auto"
    precond_rank: int = 0  # 0 = no preconditioner (lattice MVMs are cheap)
    num_probes: int = 8
    max_lanczos_iters: int = 50
    # "paper": §4.2 derivative-stencil custom VJP (faithful reproduction).
    # "autodiff": differentiate through the barycentric weights of the
    #   actual lattice operator (beyond-paper; self-consistent with the
    #   approximate model the solves come from — see DESIGN.md §7).
    grad_mode: str = "paper"
    # One lattice build per training step / posterior (DESIGN.md §9): the
    # solve operator, the surrogate quad forms, and the prediction cross-
    # MVMs all share a single Lattice. False restores the seed's
    # rebuild-per-call behavior (the benchmark baseline). Note "autodiff"
    # grad mode must rebuild inside the differentiated quad form regardless
    # (its gradient flows through the barycentric construction itself).
    shared_lattice: bool = True
    # log-det estimator for the MLL value: "cg" reuses the Lanczos
    # tridiagonals mBCG already collected during the probe solves (BBMM's
    # free log-det; zero extra MVMs), "slq" runs the separate Lanczos pass.
    # Preconditioned runs fall back to "slq" (the CG tridiagonals then
    # describe the preconditioned operator, not K_hat).
    logdet_estimator: str = "cg"
    # frozen-lattice serving (gp/serve.py; DESIGN.md §12): the query-path
    # backend (kernels/slice/ops.py policy — "auto" fuses lookup + slice
    # into one Pallas kernel on TPU when the frozen state fits VMEM) and
    # the fixed padding-bucket sizes jit compiles per (not per batch
    # shape).
    serve_backend: str = "auto"
    serve_buckets: tuple[int, ...] = (64, 256, 1024, 4096)


class Operator(NamedTuple):
    """K_hat = outputscale * F(z) + noise * I as closures over one lattice."""

    mvm: Callable[[Array], Array]  # (n, k) -> (n, k), full K_hat
    kxx_mvm: Callable[[Array], Array]  # kernel part only (no noise)
    lattice: Lattice
    noise: Array
    outputscale: Array
    lengthscale: Array


@dataclasses.dataclass(frozen=True)
class SimplexGP:
    config: SimplexGPConfig

    @property
    def stencil(self) -> Stencil:
        return make_stencil(self.config.kernel, self.config.order)

    @property
    def profile(self) -> km.KernelProfile:
        return km.get_profile(self.config.kernel)

    def constrained(self, params: GPParams):
        ls = softplus(params.raw_lengthscale)
        os_ = softplus(params.raw_outputscale)
        noise = softplus(params.raw_noise) + self.config.min_noise
        return ls, os_, noise

    def capacity(self, n: int, d: int) -> int:
        return int(self.config.cap_factor * default_capacity(n, d))

    def operator(self, params: GPParams, x: Array, *,
                 lat: Lattice | None = None, cap: int | None = None,
                 cache: "filtering.LatticeCache | None" = None,
                 mesh=None, axis_name: str = "data") -> Operator:
        """Build lattice once; return the K_hat MVM for CG loops.

        The MVM obeys the multi-RHS block contract: (n, k) in, (n, k)
        out, one lattice filtering per call — mBCG's ``[y | Z]`` block
        and LOVE's Krylov starts all ride a single MVM per iteration.

        NOT differentiable (stop-gradient semantics by construction —
        params enter only through concrete values). Use ``quad_form``
        for gradient paths.

        ``lat`` skips the build entirely (a prebuilt lattice for these
        ``x`` under these params — e.g. an auto-sized one constructed
        outside jit, or a shared joint lattice). ``cap`` overrides the
        worst-case ``default_capacity`` table size, so jit-side code can
        inherit a right-sized cap chosen outside jit (build_lattice_auto).
        ``cache`` memoizes eager-mode builds across calls. ``mesh`` runs
        every MVM data-parallel over its ``axis_name`` axis (DESIGN.md
        §10: sharded splat/slice, replicated blur, one psum per MVM).
        """
        cfg = self.config
        st = self.stencil
        ls, os_, noise = self.constrained(params)
        z = x / ls[None, :]
        if lat is None:
            cap = self.capacity(*x.shape) if cap is None else cap
            if cache is not None:
                lat = cache.get(cache.point_set_tag(x), z,
                                spacing=st.spacing, r=st.r, cap=cap, ls=ls,
                                build_backend=cfg.build_backend, mesh=mesh)
            else:
                lat = build_lattice(z, spacing=st.spacing, r=st.r, cap=cap,
                                    backend=cfg.build_backend)
        w = jnp.asarray(st.weights, x.dtype)
        taps = tuple(st.weights)

        def kxx(v: Array) -> Array:
            return os_ * filtering.filter_mvm(lat, v, w,
                                              symmetrize=cfg.symmetrize,
                                              backend=cfg.backend,
                                              taps=taps, mesh=mesh,
                                              axis_name=axis_name)

        def mvm(v: Array) -> Array:
            return kxx(v) + noise * v

        return Operator(mvm=mvm, kxx_mvm=kxx, lattice=lat, noise=noise,
                        outputscale=os_, lengthscale=ls)

    def quad_form(self, params: GPParams, x: Array, a: Array,
                  b: Array, *, lat: Lattice | None = None) -> Array:
        """Differentiable ``sum(a * (K_hat(theta) b))`` (for MLL surrogates).

        Uses ``lattice_filter``'s §4.2 custom VJP, so gradients w.r.t.
        lengthscale flow through z = x / ls without differentiating the
        integer lattice construction. Passing ``lat`` (a lattice already
        built for these x under numerically identical params — e.g.
        ``operator(...).lattice``) skips the per-call rebuild via
        ``lattice_filter_with``; values and §4.2 gradients are identical.
        Only honored in "paper" grad mode — "autodiff" differentiates
        through the barycentric weights of the build itself, so it must
        construct the lattice inside the traced computation.
        """
        cfg = self.config
        st = self.stencil
        ls, os_, noise = self.constrained(params)
        z = x / ls[None, :]
        w = jnp.asarray(st.weights, x.dtype)
        if cfg.grad_mode == "paper":
            dw = jnp.asarray(st.dweights, x.dtype)
            cap = lat.cap if lat is not None else self.capacity(*x.shape)
            spec = filtering.spec_for(st, cap=cap,
                                      symmetrize=cfg.symmetrize,
                                      backend=cfg.backend,
                                      build_backend=cfg.build_backend)
            if lat is not None:
                kb = os_ * filtering.lattice_filter_with(lat, z, b, w, dw,
                                                         spec)
            else:
                kb = os_ * filtering.lattice_filter(z, b, w, dw, spec)
        else:  # autodiff through the barycentric interpolation (a.e. exact)
            lat = build_lattice(z, spacing=st.spacing, r=st.r,
                                cap=self.capacity(*x.shape),
                                backend=cfg.build_backend)
            # Pallas kernels have no VJP; keep autodiff on the fused XLA
            # tier even when the config would pick a Pallas backend.
            bk = cfg.backend if cfg.backend in ("fused_xla", "xla") \
                else "fused_xla"
            kb = os_ * filtering.filter_mvm(lat, b, w,
                                            symmetrize=cfg.symmetrize,
                                            backend=bk,
                                            taps=tuple(st.weights))
        return jnp.sum(a * kb) + noise * jnp.sum(a * b)

    def exact_row(self, params: GPParams, x: Array, i: Array) -> Array:
        """Exact kernel row K_hat[i, :] (for the pivoted-Cholesky precond)."""
        ls, os_, noise = self.constrained(params)
        row = km.gram(self.profile, x[i][None, :], x, ls, os_)[0]
        return row.at[i].add(noise)
