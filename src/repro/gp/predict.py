"""Posterior prediction for Simplex-GP (paper Eqs. 2-3, MVM-based).

Mean: mu_* = K_{*,X} u with u = K_hat^{-1} y (CG at eval tolerance 1e-2).
K_{*,X} u is ONE lattice filtering over the joint point set [X; X_*] with
the training rows carrying u and test rows carrying 0 — cross-covariance
times a vector is just another bilateral filter (paper §3.1).

Variance: LOVE-style low-rank approximation. Run k Lanczos iterations on
K_hat from a y-seeded start to get K_hat^{-1} ~= Q T^{-1} Q^T on the Krylov
subspace; then var_* ~= k_*(0) - (K_{*,X} Q) T^{-1} (K_{*,X} Q)^T, where
K_{*,X} Q is k more joint filterings (batched into one call with k channels).
This mirrors GPyTorch's fast predictive variances the paper evaluates NLL
with; accuracy grows with k.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import filtering
from repro.core.lattice import build_lattice
from repro.gp.models import GPParams, SimplexGP
from repro.solvers.cg import cg as cg_solve
from repro.solvers.lanczos import lanczos as lanczos_run

Array = jax.Array


class Posterior(NamedTuple):
    mean: Array  # (n*,)
    var: Array  # (n*,) latent-f variance (add noise for predictive y)


def cross_mvm(model: SimplexGP, params: GPParams, x: Array, xs: Array,
              v: Array) -> Array:
    """K_{*,X} v via one joint-lattice filtering. v: (n, c) -> (n*, c)."""
    cfg = model.config
    st = model.stencil
    ls, os_, _ = model.constrained(params)
    n, ns = x.shape[0], xs.shape[0]
    zj = jnp.concatenate([x, xs], axis=0) / ls[None, :]
    lat = build_lattice(zj, spacing=st.spacing, r=st.r,
                        cap=model.capacity(n + ns, x.shape[1]))
    w = jnp.asarray(st.weights, x.dtype)
    vj = jnp.concatenate([v, jnp.zeros((ns, v.shape[1]), v.dtype)], axis=0)
    out = filtering.filter_mvm(lat, vj, w, symmetrize=cfg.symmetrize,
                               backend=cfg.backend, taps=tuple(st.weights))
    return os_ * out[n:]


def posterior(model: SimplexGP, params: GPParams, x: Array, y: Array,
              xs: Array, *, key: Array, variance_rank: int = 30) -> Posterior:
    cfg = model.config
    op = model.operator(params, x)

    # mean
    u, _ = cg_solve(op.mvm, y[:, None], tol=cfg.cg_tol_eval,
                     max_iters=cfg.max_cg_iters)
    mean = cross_mvm(model, params, x, xs, u)[:, 0]

    # variance via Lanczos on K_hat (LOVE-style)
    q0 = y[:, None] + 1e-3 * jax.random.normal(key, (x.shape[0], 1), x.dtype)
    lres = lanczos_run(op.mvm, q0, variance_rank)
    q = lres.q[:, :, 0].T  # (n, k)
    tdense = (jnp.diag(jnp.where(lres.valid[:, 0], lres.alphas[:, 0], 1.0))
              + jnp.diag(lres.betas[:-1, 0] * lres.valid[:-1, 0]
                         * lres.valid[1:, 0], 1)
              + jnp.diag(lres.betas[:-1, 0] * lres.valid[:-1, 0]
                         * lres.valid[1:, 0], -1))
    ksq = cross_mvm(model, params, x, xs, q)  # (n*, k)
    sol = jnp.linalg.solve(tdense + 1e-6 * jnp.eye(tdense.shape[0], dtype=x.dtype),
                           ksq.T)  # (k, n*)
    prior_var = op.outputscale  # k(0) = outputscale for unit profiles
    var = prior_var - jnp.sum(ksq * sol.T, axis=1)
    return Posterior(mean=mean, var=jnp.clip(var, 1e-6, prior_var))


def nll(post: Posterior, noise: Array, y_true: Array) -> Array:
    """Mean predictive negative log-likelihood (Table 2's NLL column)."""
    s2 = post.var + noise
    return jnp.mean(0.5 * jnp.log(2.0 * jnp.pi * s2)
                    + 0.5 * (y_true - post.mean) ** 2 / s2)


def rmse(post: Posterior, y_true: Array) -> Array:
    return jnp.sqrt(jnp.mean((post.mean - y_true) ** 2))
