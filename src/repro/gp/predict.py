"""Posterior prediction for Simplex-GP (paper Eqs. 2-3, MVM-based).

Mean: mu_* = K_{*,X} u with u = K_hat^{-1} y (CG at eval tolerance 1e-2).
K_{*,X} u is ONE lattice filtering over the joint point set [X; X_*] with
the training rows carrying u and test rows carrying 0 — cross-covariance
times a vector is just another bilateral filter (paper §3.1).

Variance: LOVE-style low-rank approximation. Run k Lanczos iterations on
K_hat from a y-seeded start to get K_hat^{-1} ~= Q T^{-1} Q^T on the Krylov
subspace; then var_* ~= k_*(0) - (K_{*,X} Q) T^{-1} (K_{*,X} Q)^T. This
mirrors GPyTorch's fast predictive variances the paper evaluates NLL with;
accuracy grows with k.

One lattice build per posterior (DESIGN.md §9): the joint lattice over
[X; X_*] serves BOTH the K_hat MVMs of the solve/Lanczos phases (restrict
the joint filtering to the training rows) and the cross-covariance rows,
and ``u`` and the LOVE basis ``Q`` are batched into a single (1+k)-channel
cross filtering. The seed built three lattices per posterior (train
operator + one per cross_mvm call); ``shared_lattice=False`` restores that
as the benchmark baseline. Restricting the joint filtering to train rows is
a slightly *denser* K_XX approximation than the train-only lattice (extra
lattice points from X_* refine the blur graph) and keeps the solve
consistent with the cross-covariance — both use the same W K_UU W^T.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import filtering
from repro.core.filtering import LatticeCache
from repro.core.lattice import Lattice, build_lattice
from repro.gp.models import GPParams, SimplexGP
from repro.solvers.cg import cg as cg_solve
from repro.solvers.lanczos import lanczos as lanczos_run

Array = jax.Array


class Posterior(NamedTuple):
    mean: Array  # (n*,)
    var: Array  # (n*,) latent-f variance (add noise for predictive y)
    overflow: Array | bool = False  # lattice table overflow flag
    pack_overflow: Array | bool = False  # coord range overflow (can't grow)


def _joint_lattice(model: SimplexGP, params: GPParams, x: Array, xs: Array,
                   *, cap: int | None,
                   cache: LatticeCache | None, mesh=None) -> Lattice:
    """Build (or fetch) the one lattice over the joint point set [x; xs]."""
    st = model.stencil
    ls, _, _ = model.constrained(params)
    zj = jnp.concatenate([x, xs], axis=0) / ls[None, :]
    n, ns = x.shape[0], xs.shape[0]
    cap = model.capacity(n + ns, x.shape[1]) if cap is None else cap
    if cache is not None:
        return cache.get(cache.point_set_tag(x, xs), zj,
                         spacing=st.spacing, r=st.r, cap=cap, ls=ls,
                         build_backend=model.config.build_backend, mesh=mesh)
    return build_lattice(zj, spacing=st.spacing, r=st.r, cap=cap,
                         backend=model.config.build_backend)


def _joint_filter(model: SimplexGP, lat: Lattice, v: Array,
                  dtype, mesh=None) -> Array:
    """One filtering of (n+ns, c) values on the joint lattice (no scales)."""
    cfg = model.config
    st = model.stencil
    w = jnp.asarray(st.weights, dtype)
    return filtering.filter_mvm(lat, v, w, symmetrize=cfg.symmetrize,
                                backend=cfg.backend, taps=tuple(st.weights),
                                mesh=mesh)


def cross_mvm(model: SimplexGP, params: GPParams, x: Array, xs: Array,
              v: Array, *, lat: Lattice | None = None,
              cache: LatticeCache | None = None, mesh=None) -> Array:
    """K_{*,X} v via one joint-lattice filtering. v: (n, c) -> (n*, c).

    Multi-RHS by construction: a (n, c) block of cross-covariance RHS
    costs the same single filtering as one column. ``lat`` reuses a
    prebuilt joint lattice over [x; xs] (e.g. the one ``posterior``
    shares across its solve and cross-MVMs); ``mesh`` shards the joint
    filtering data-parallel (n + n* must divide the "data" axis).
    """
    _, os_, _ = model.constrained(params)
    n, ns = x.shape[0], xs.shape[0]
    if lat is None:
        lat = _joint_lattice(model, params, x, xs, cap=None, cache=cache,
                             mesh=mesh)
    vj = jnp.concatenate([v, jnp.zeros((ns, v.shape[1]), v.dtype)], axis=0)
    out = _joint_filter(model, lat, vj, x.dtype, mesh=mesh)
    return os_ * out[n:]


def posterior(model: SimplexGP, params: GPParams, x: Array, y: Array,
              xs: Array, *, key: Array, variance_rank: int = 30,
              cap: int | None = None,
              cache: LatticeCache | None = None, mesh=None) -> Posterior:
    """Predictive mean and LOVE variance at ``xs``.

    ``cap`` overrides the joint lattice's worst-case capacity (thread a
    right-sized one chosen outside jit); ``cache`` memoizes eager builds.
    ``mesh`` shards every joint-lattice filtering — the solve MVMs, the
    LOVE Lanczos MVMs, and the batched [u | Q] cross filtering — over its
    "data" axis, one psum each (DESIGN.md §10).
    """
    cfg = model.config
    n, ns = x.shape[0], xs.shape[0]
    if not cfg.shared_lattice:
        return _posterior_rebuild(model, params, x, y, xs, key=key,
                                  variance_rank=variance_rank)

    ls, os_, noise = model.constrained(params)
    lat = _joint_lattice(model, params, x, xs, cap=cap, cache=cache,
                         mesh=mesh)

    # K_hat MVM on the training block, through the shared joint lattice.
    def mvm(v: Array) -> Array:
        vj = jnp.concatenate([v, jnp.zeros((ns, v.shape[1]), v.dtype)],
                             axis=0)
        return (os_ * _joint_filter(model, lat, vj, x.dtype, mesh=mesh)[:n]
                + noise * v)

    # mean solve
    u, _ = cg_solve(mvm, y[:, None], tol=cfg.cg_tol_eval,
                     max_iters=cfg.max_cg_iters)

    # variance via Lanczos on K_hat (LOVE-style)
    q0 = y[:, None] + 1e-3 * jax.random.normal(key, (n, 1), x.dtype)
    lres = lanczos_run(mvm, q0, variance_rank)
    q = lres.q[:, :, 0].T  # (n, k)
    tdense = (jnp.diag(jnp.where(lres.valid[:, 0], lres.alphas[:, 0], 1.0))
              + jnp.diag(lres.betas[:-1, 0] * lres.valid[:-1, 0]
                         * lres.valid[1:, 0], 1)
              + jnp.diag(lres.betas[:-1, 0] * lres.valid[:-1, 0]
                         * lres.valid[1:, 0], -1))

    # ONE batched cross filtering for [u | Q]: (1 + k) channels at once.
    ksall = cross_mvm(model, params, x, xs, jnp.concatenate([u, q], axis=1),
                      lat=lat, mesh=mesh)
    mean = ksall[:, 0]
    ksq = ksall[:, 1:]  # (n*, k)
    sol = jnp.linalg.solve(tdense + 1e-6 * jnp.eye(tdense.shape[0], dtype=x.dtype),
                           ksq.T)  # (k, n*)
    prior_var = os_  # k(0) = outputscale for unit profiles
    var = prior_var - jnp.sum(ksq * sol.T, axis=1)
    return Posterior(mean=mean, var=jnp.clip(var, 1e-6, prior_var),
                     overflow=lat.overflow, pack_overflow=lat.pack_overflow)


def _posterior_rebuild(model: SimplexGP, params: GPParams, x: Array,
                       y: Array, xs: Array, *, key: Array,
                       variance_rank: int) -> Posterior:
    """Seed-compatible path: train-lattice operator + per-call joint builds
    (3 lattice constructions per posterior). Kept as the benchmark baseline
    and for A/B parity checks against the shared-lattice path."""
    cfg = model.config
    op = model.operator(params, x)

    u, _ = cg_solve(op.mvm, y[:, None], tol=cfg.cg_tol_eval,
                     max_iters=cfg.max_cg_iters)
    mean = cross_mvm(model, params, x, xs, u)[:, 0]

    q0 = y[:, None] + 1e-3 * jax.random.normal(key, (x.shape[0], 1), x.dtype)
    lres = lanczos_run(op.mvm, q0, variance_rank)
    q = lres.q[:, :, 0].T  # (n, k)
    tdense = (jnp.diag(jnp.where(lres.valid[:, 0], lres.alphas[:, 0], 1.0))
              + jnp.diag(lres.betas[:-1, 0] * lres.valid[:-1, 0]
                         * lres.valid[1:, 0], 1)
              + jnp.diag(lres.betas[:-1, 0] * lres.valid[:-1, 0]
                         * lres.valid[1:, 0], -1))
    ksq = cross_mvm(model, params, x, xs, q)  # (n*, k)
    sol = jnp.linalg.solve(tdense + 1e-6 * jnp.eye(tdense.shape[0], dtype=x.dtype),
                           ksq.T)  # (k, n*)
    prior_var = op.outputscale  # k(0) = outputscale for unit profiles
    var = prior_var - jnp.sum(ksq * sol.T, axis=1)
    return Posterior(mean=mean, var=jnp.clip(var, 1e-6, prior_var),
                     overflow=op.lattice.overflow,
                     pack_overflow=op.lattice.pack_overflow)


def exact_mean_grad(profile, x: Array, y: Array, xs: Array, *,
                    lengthscale, outputscale, noise) -> Array:
    """Analytic d(mean)/dx* of the DENSE exact GP — the gradient oracle.

    The closed form the frozen serving gradients (gp/serve.predict_grad,
    DESIGN.md §15) are validated against on in-model draws:

      d mu(x*)/dx* = os * sum_i alpha_i k'(tau_i) * 2 (x* - x_i) / ls^2

    with ``k' = profile.dk_dsq`` (dk/d tau^2, the same derivative profile
    the paper's Eq. 11 hyperparameter gradients use — core/kernels_math)
    and ``alpha = (K + noise I)^{-1} y`` from the same jittered system
    ``core/exact.ExactGP`` solves. O(n* n d): test/benchmark-scale only.
    """
    from repro.core import kernels_math as km
    d = x.shape[1]
    ls = jnp.broadcast_to(jnp.asarray(lengthscale, x.dtype), (d,))
    khat = km.gram(profile, x, x, ls, outputscale) \
        + (noise + 1e-6) * jnp.eye(x.shape[0], dtype=x.dtype)
    alpha = jnp.linalg.solve(khat, y)
    zs, z = xs / ls[None, :], x / ls[None, :]
    tau = jnp.sqrt(km.pairwise_sqdist(zs, z) + 1e-30)  # (n*, n)
    kp = outputscale * profile.dk_dsq(tau)  # dk/d(tau^2) per pair
    dsq = 2.0 * (zs[:, None, :] - z[None, :, :]) / ls[None, None, :]
    return jnp.einsum("sn,n,snd->sd", kp, alpha, dsq)


def nll(post: Posterior, noise: Array, y_true: Array) -> Array:
    """Mean predictive negative log-likelihood (Table 2's NLL column)."""
    s2 = post.var + noise
    return jnp.mean(0.5 * jnp.log(2.0 * jnp.pi * s2)
                    + 0.5 * (y_true - post.mean) ** 2 / s2)


def rmse(post: Posterior, y_true: Array) -> Array:
    return jnp.sqrt(jnp.mean((post.mean - y_true) ** 2))
