"""Frozen-lattice serving (DESIGN.md §12): precomputed Simplex-GP predictor.

``gp/predict.posterior`` pays a joint-lattice build plus CG/Lanczos solves
for EVERY query batch — fine for benchmarking, fatal for serving. But SKI
prediction reduces to interpolating precomputed grid quantities (KISS-GP,
Wilson & Nickisch 2015; Yadav et al. 2021 decouple query cost from n
entirely), and on the permutohedral lattice the analogue is exact:

  mean(x*)  = k_{*,X} alpha            with alpha = K_hat^{-1} y
            = w(x*)^T  [B W^T alpha]   — slice of a PRECOMPUTED table
  var(x*)   = k(0) - || w(x*)^T [B W^T R] ||^2
            with R = Q (T + eps I)^{-1/2} the LOVE root from k Lanczos
            iterations (the same T/Q ``posterior`` uses; the inverse
            square root via the k x k eigendecomposition)

so ``freeze`` solves ONCE at train time, splats [alpha | R] onto the
train lattice, runs the 2(d+1) blur sweeps ONCE (batched over the 1 + k
channels), and keeps only the blurred value tables — compacted to the
m + 1 occupied rows — plus the hash index for vertex lookup. Per query,
``predict`` is embed (O(d^2), sort-free) + d+1 hash probes + a batched
multi-channel barycentric slice: no build, no solve, no collective, cost
independent of n. Queries landing outside the frozen lattice lose the
mass of their absent vertices (standard slicing semantics) and report it
as the ``miss_mass`` fidelity diagnostic.

Serving mechanics: ``predict`` pads each batch to a fixed bucket size
(``SimplexGPConfig.serve_buckets``) so jit compiles once per bucket
rather than once per batch shape, donates the padded query buffer, and
optionally fans queries over a device mesh with the frozen tables
REPLICATED — zero collectives, linear throughput scaling
(sharding/simplex.py's serving contract).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import pathlib
import shutil
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filtering
from repro.core import lattice as lat_mod
from repro.core.filtering import LatticeCache
from repro.core.lattice import LatticeIndex
from repro.gp.models import GPParams, SimplexGP
from repro.runtime.checkpoint import (CheckpointCorruptError, load_blobs,
                                      read_manifest, save_blobs)
from repro.solvers.cg import cg_while as cg_solve
from repro.solvers.lanczos import lanczos as lanczos_run

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Predictor:
    """Immutable frozen-model state: everything a query needs, nothing else.

    ``tables`` column 0 is the mean channel (os * blurred splat of alpha);
    columns 1..k are the LOVE variance channels (os * blurred splat of the
    root R), so var = outputscale - sum_j table_j(x*)^2. A pytree — safe
    to pass through jit, replicate across a mesh, or checkpoint.

    Beyond the query tables, a Predictor carries what the serving RUNTIME
    (DESIGN.md §13) needs: the raw ``alpha`` solution so the next refresh
    can warm-start its CG solve, and the solve diagnostics
    (``cg_converged``/``cg_residual``/``cg_iterations``) so the
    ``validate_predictor`` publication gate can refuse a candidate whose
    solve silently failed. All are DATA fields — re-freezing never changes
    the treedef, so bucket compilations survive hot swaps whenever the
    array shapes (n, m, k) are unchanged.
    """

    index: LatticeIndex  # hash index over the frozen train lattice
    tables: Array  # (m+1, 1+k) f32 blurred [mean | LOVE root] channels
    lengthscale: Array  # (d,)
    outputscale: Array  # ()
    noise: Array  # () — for predictive-y variance (latent var + noise)
    alpha: Array  # (n,) K_hat^{-1} y — the warm-start seed for refreeze
    cg_converged: Array  # () bool: alpha solve hit tolerance
    cg_residual: Array  # () final relative residual of the alpha solve
    cg_iterations: Array  # () int32 iterations the alpha solve used
    spacing: float = dataclasses.field(metadata=dict(static=True))
    backend: str = dataclasses.field(default="auto",
                                     metadata=dict(static=True))
    buckets: tuple[int, ...] = dataclasses.field(
        default=(64, 256, 1024, 4096), metadata=dict(static=True))
    n_train: int = dataclasses.field(default=0, metadata=dict(static=True))


class ServeResult(NamedTuple):
    mean: Array  # (b,)
    var: Array  # (b,) latent-f variance (add pred.noise for predictive y)
    miss_mass: Array  # (b,) in [0, 1]: barycentric mass on absent vertices


class ServeGradResult(NamedTuple):
    """``predict_grad`` output: predictions + analytic query-space gradients.

    ``grad_ok`` gates validity (DESIGN.md §15): a query with miss_mass > 0
    has vertices clamped to the zero row, so its surrogate surface is
    kinked by the frozen lattice's support boundary — the gradients are
    still the exact gradients OF THE SERVED SURROGATE, but no longer
    approximate the GP posterior's. Callers must gate on ``grad_ok``
    rather than consume silently-degraded gradients.
    """

    mean: Array  # (b,) [or (b, k) from predict_multi_grad]
    var: Array  # (b,) latent-f variance
    dmean: Array  # (b, d) [or (b, k, d)] d mean / d x*
    dvar: Array  # (b, d) [or (b, k, d)] d var / d x*
    miss_mass: Array  # (b,) in [0, 1]
    grad_ok: Array  # (b,) bool: miss_mass == 0 -> gradients trustworthy


class MultiServeResult(NamedTuple):
    mean: Array  # (b, k)
    var: Array  # (b, k) latent-f variance per output channel
    miss_mass: Array  # (b,) shared across channels (one embed, one probe)


@functools.partial(jax.jit, static_argnames=("model", "variance_rank"))
def _freeze_tables(model: SimplexGP, params: GPParams, lat, x: Array,
                   y: Array, key: Array, variance_rank: int,
                   x0: Array | None = None):
    """alpha + LOVE-root solves and the one batched splat->blur sweep.

    Returns ``(tables, alpha, cg_info)`` — the solve diagnostics ride out
    so ``freeze`` can record them on the Predictor (the publication gate
    refuses silently-failed solves). ``x0`` warm-starts the alpha CG from
    a previous Predictor's solution; the early-exit solver then pays only
    the iterations the data CHANGE needs, not a cold solve.
    """
    cfg = model.config
    st = model.stencil
    n = x.shape[0]
    _, os_, _ = model.constrained(params)
    op = model.operator(params, x, lat=lat)

    u, cg_info = cg_solve(op.mvm, y[:, None], tol=cfg.cg_tol_eval,
                          max_iters=cfg.max_cg_iters, x0=x0)

    # LOVE basis — the same y-seeded Lanczos run ``posterior`` does
    q0 = y[:, None] + 1e-3 * jax.random.normal(key, (n, 1), x.dtype)
    lres = lanczos_run(op.mvm, q0, variance_rank)
    q = lres.q[:, :, 0].T  # (n, k)
    tdense = (jnp.diag(jnp.where(lres.valid[:, 0], lres.alphas[:, 0], 1.0))
              + jnp.diag(lres.betas[:-1, 0] * lres.valid[:-1, 0]
                         * lres.valid[1:, 0], 1)
              + jnp.diag(lres.betas[:-1, 0] * lres.valid[:-1, 0]
                         * lres.valid[1:, 0], -1))
    # (T + eps I)^{-1/2} via the k x k eigendecomposition: identical
    # quadratic form to posterior's (T + eps I)^{-1} solve
    e, vecs = jnp.linalg.eigh(
        tdense + 1e-6 * jnp.eye(tdense.shape[0], dtype=x.dtype))
    root = q @ (vecs * jnp.where(e > 1e-10,
                                 jax.lax.rsqrt(jnp.maximum(e, 1e-10)),
                                 0.0)[None, :])

    # ONE batched splat + 2(d+1) blur sweeps for all 1 + k channels
    chans = jnp.concatenate([u, root], axis=1)
    w = jnp.asarray(st.weights, x.dtype)
    table = lat_mod.splat_sorted(lat, chans)
    blurred = lat_mod.blur(lat, table, w)
    if cfg.symmetrize:
        blurred = 0.5 * (blurred + lat_mod.blur(lat, table, w, reverse=True))
    return os_ * blurred, u[:, 0], cg_info  # (cap+1, 1+k), (n,), info


def _freeze_lattice(model: SimplexGP, params: GPParams, x: Array, *,
                    cap: int | None, cache: LatticeCache | None):
    """The one train-lattice build every freeze flavor shares.

    ``freeze`` and ``freeze_multi`` MUST run the identical build branch:
    the multi-output bit-exact-parity contract (DESIGN.md §15) holds
    because each channel of ``freeze_multi`` reuses this lattice, which
    is byte-identical to what k independent ``freeze`` calls would build
    from the same (x, params, cap) — only built once.
    """
    cfg = model.config
    st = model.stencil
    ls, _, _ = model.constrained(params)
    z = x / ls[None, :]
    if cap is None and cache is None:
        lat = lat_mod.build_lattice_auto(z, spacing=st.spacing, r=st.r,
                                         backend=cfg.build_backend)
    elif cache is not None:
        n, d = x.shape
        cap_val = model.capacity(n, d) if cap is None else cap
        lat = cache.get(cache.point_set_tag(x), z, spacing=st.spacing,
                        r=st.r, cap=cap_val, ls=ls,
                        build_backend=cfg.build_backend)
    else:
        lat = lat_mod.build_lattice(z, spacing=st.spacing, r=st.r, cap=cap,
                                    backend=cfg.build_backend)
    if bool(lat.pack_overflow):
        raise RuntimeError("freeze: lattice coordinate range overflow "
                           "(|coord| > 2^15) — rescale inputs or bound "
                           "the lengthscale")
    if bool(lat.overflow):
        raise RuntimeError("freeze: lattice capacity overflow — pass a "
                           "larger cap (or let build_lattice_auto size it)")
    return lat


def freeze(model: SimplexGP, params: GPParams, x: Array, y: Array, *,
           key: Array, variance_rank: int = 30, cap: int | None = None,
           cache: LatticeCache | None = None,
           warm_start: Array | None = None,
           reuse_index: LatticeIndex | None = None,
           on_nonconverged: str = "flag") -> Predictor:
    """Freeze a trained model into an immutable serving ``Predictor``.

    One-time cost (amortized over every future query): a train-lattice
    build (auto-sized unless ``cap`` given; ``cache`` memoizes it), the
    alpha/LOVE solves, one batched blur sweep, and the hash-index build.
    Eager-only: the dense tables are sized by the CONCRETE occupied count
    m, which is what keeps them small enough to stay VMEM-resident.

    Refresh hooks (used by ``refreeze``/the serving engine): ``warm_start``
    seeds the alpha CG with a previous solution (valid for ANY seed — CG
    converges regardless; a good seed from an old Predictor just makes it
    exit early). ``reuse_index`` skips the eager hash-index rebuild when
    the lattice is unchanged (a y-only refresh); it is VERIFIED against
    the freshly built lattice's occupied slots and silently rebuilt on
    mismatch — never trusted. ``on_nonconverged``: "flag" records the
    failed solve in the diagnostics (the ``validate_predictor`` gate
    refuses it at publication time); "raise" fails fast here.
    """
    lat = _freeze_lattice(model, params, x, cap=cap, cache=cache)
    ls, os_, noise = model.constrained(params)
    cfg = model.config
    st = model.stencil
    x0 = None
    if warm_start is not None and warm_start.shape[0] == x.shape[0]:
        x0 = jnp.asarray(warm_start, x.dtype)[:, None]
    blurred, alpha, cg_info = _freeze_tables(model, params, lat, x, y, key,
                                             variance_rank, x0)
    converged = bool(jnp.all(cg_info.converged))
    if not converged and on_nonconverged == "raise":
        raise RuntimeError(
            "freeze: alpha CG solve did not converge "
            f"(relative residual {float(jnp.max(cg_info.residual_norms)):.2e}"
            f" > tol {cfg.cg_tol_eval} after "
            f"{int(cg_info.iterations)} iterations)")
    index = _verified_index(lat, reuse_index)
    tables = lat_mod.compact_table(index, blurred)
    return Predictor(index=index, tables=tables, lengthscale=ls,
                     outputscale=os_, noise=noise, alpha=alpha,
                     cg_converged=jnp.asarray(converged),
                     cg_residual=jnp.max(cg_info.residual_norms),
                     cg_iterations=cg_info.iterations,
                     spacing=st.spacing,
                     backend=cfg.serve_backend,
                     buckets=tuple(cfg.serve_buckets),
                     n_train=x.shape[0])


def _verified_index(lat, reuse_index: LatticeIndex | None) -> LatticeIndex:
    """``reuse_index`` if it provably indexes ``lat``, else a fresh build.

    Reuse is only sound if BOTH maps still hold against the freshly built
    lattice: (a) ``slots`` (dense row -> lattice slot, what
    ``compact_table`` gathers with) must land on exactly the occupied
    slots, and (b) each dense row's PACKED COORDINATES in the index's
    probe table must equal the new lattice's coordinates at that slot.
    Slot ids alone are NOT enough: the hash build numbers slots by
    placement order, so two builds of different capacity can occupy the
    identical slot-id set 0..m-1 with different coord->slot assignments —
    an id-level check would pass and silently serve permuted rows. The
    key-level check makes a stale index impossible to reuse; on any
    mismatch a fresh index is built (never an error — reuse is an
    optimization, not a contract).
    """
    if reuse_index is None:
        return lat_mod.lattice_index(lat)
    occupied = np.nonzero(np.asarray(lat.valid))[0]
    slots = np.asarray(reuse_index.slots)
    if (reuse_index.m != occupied.shape[0]
            or not np.array_equal(np.sort(slots), occupied)):
        return lat_mod.lattice_index(lat)
    # (b) packed keys of the new lattice at the index's slots, per dense row
    coords = jnp.asarray(np.asarray(lat.coords)[slots])
    packed_new = np.stack(
        [np.asarray(c) for c in lat_mod._pack_key_cols(coords)], axis=1)
    ros = np.asarray(reuse_index.row_of_slot)
    tkeys = np.asarray(reuse_index.tkeys)
    occ = ros < reuse_index.m
    if int(occ.sum()) != reuse_index.m:
        return lat_mod.lattice_index(lat)
    packed_idx = np.zeros_like(packed_new)
    packed_idx[ros[occ]] = tkeys[occ]
    if not np.array_equal(packed_idx, packed_new):
        return lat_mod.lattice_index(lat)
    return reuse_index


def refreeze(model: SimplexGP, params: GPParams, x: Array, y: Array, *,
             key: Array, old: Predictor, cache: LatticeCache | None = None,
             variance_rank: int | None = None, cap: int | None = None,
             on_nonconverged: str = "flag") -> Predictor:
    """Warm-started re-freeze for a data refresh (DESIGN.md §13).

    The incremental path ROADMAP item 1 calls for: seed the alpha CG from
    ``old.alpha`` (early-exit solver — a y-perturbation refresh pays a
    few iterations, not a cold solve) and offer ``old.index`` for reuse
    (verified inside ``freeze``; a y-only update leaves the lattice — and
    hence the index — unchanged, skipping the eager hash-index rebuild).
    Produces the SAME Predictor a cold ``freeze`` on (x, y) would, up to
    CG stopping noise — pinned to 1e-5 by tests/test_serve_engine.py.

    Pass the engine's ``cache`` so an unchanged (x, lengthscale) hits the
    memoized lattice instead of rebuilding. ``variance_rank`` defaults to
    the old Predictor's rank (table shapes stay stable -> no bucket
    recompiles after the hot swap).
    """
    if variance_rank is None:
        variance_rank = old.tables.shape[1] - 1
    warm = old.alpha if old.n_train == x.shape[0] else None
    return freeze(model, params, x, y, key=key, variance_rank=variance_rank,
                  cap=cap, cache=cache, warm_start=warm,
                  reuse_index=old.index, on_nonconverged=on_nonconverged)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MultiPredictor:
    """Stacked multi-output frozen state: k channels, ONE lattice index.

    The dynamics-model layout (DESIGN.md §15): all k outputs share the
    input space, hyperparameters, and hence the lattice geometry, so one
    hash index serves every channel and the per-channel value tables are
    stacked column-wise into a single ``(m+1, k*(1+r))`` buffer —
    channel j occupies the contiguous block ``[j*(1+r), (j+1)*(1+r))``,
    column 0 of the block is its mean channel and the remaining r its
    LOVE root. One embed + d+1 probes + one batched contraction serve
    ALL k outputs per query (``predict_multi``). Per-channel solve
    diagnostics ride along as (k,) vectors for the publication gate.
    """

    index: LatticeIndex
    tables: Array  # (m+1, k*(1+r)) stacked per-channel [mean | root] blocks
    lengthscale: Array  # (d,) shared across channels
    outputscale: Array  # () shared
    noise: Array  # () shared
    alpha: Array  # (n, k) per-channel K_hat^{-1} y_j — refresh warm starts
    cg_converged: Array  # (k,) bool per channel
    cg_residual: Array  # (k,)
    cg_iterations: Array  # (k,) int32
    spacing: float = dataclasses.field(metadata=dict(static=True))
    backend: str = dataclasses.field(default="auto",
                                     metadata=dict(static=True))
    buckets: tuple[int, ...] = dataclasses.field(
        default=(64, 256, 1024, 4096), metadata=dict(static=True))
    n_train: int = dataclasses.field(default=0, metadata=dict(static=True))
    n_outputs: int = dataclasses.field(default=1,
                                       metadata=dict(static=True))


def freeze_multi(model: SimplexGP, params: GPParams, x: Array, ys: Array, *,
                 key: Array, variance_rank: int = 30, cap: int | None = None,
                 cache: LatticeCache | None = None,
                 warm_start: Array | None = None,
                 reuse_index: LatticeIndex | None = None,
                 on_nonconverged: str = "flag") -> MultiPredictor:
    """Freeze k output channels over ONE shared lattice (DESIGN.md §15).

    ``ys`` is (n, k) — e.g. the per-state-dimension targets of a dynamics
    model. All channels share (x, hyperparameters), so the lattice build,
    overflow checks, and hash index are paid ONCE; each channel then runs
    the same ``_freeze_tables`` solve an independent ``freeze`` would
    (channel j is seeded with ``jax.random.split(key, k)[j]``), and the
    compacted tables are stacked column-wise. The per-channel tables are
    therefore BIT-EXACT equal to k independent ``freeze(model, params,
    x, ys[:, j], key=split[j], cap=cap)`` calls — pinned by
    tests/test_serve_grad.py. Batching the k CG solves into one block
    solve would couple their stopping decisions and break that parity,
    which is why the channels solve sequentially.

    ``warm_start`` takes a previous MultiPredictor's (n, k) ``alpha``.
    ``on_nonconverged="raise"`` fails if ANY channel's solve missed
    tolerance; the default flags it in ``cg_converged`` for the gate.
    """
    if ys.ndim != 2:
        raise ValueError(f"freeze_multi wants ys of shape (n, k); got "
                         f"{ys.shape} — use freeze() for a single output")
    k_out = ys.shape[1]
    cfg = model.config
    st = model.stencil
    ls, os_, noise = model.constrained(params)
    lat = _freeze_lattice(model, params, x, cap=cap, cache=cache)
    chan_keys = jax.random.split(key, k_out)

    warm = None
    if warm_start is not None and warm_start.shape == (x.shape[0], k_out):
        warm = jnp.asarray(warm_start, x.dtype)
    blurred_list, alphas, infos = [], [], []
    for j in range(k_out):
        x0 = warm[:, j][:, None] if warm is not None else None
        blurred, alpha, cg_info = _freeze_tables(
            model, params, lat, x, ys[:, j], chan_keys[j], variance_rank,
            x0)
        blurred_list.append(blurred)
        alphas.append(alpha)
        infos.append(cg_info)
    converged = [bool(jnp.all(i.converged)) for i in infos]
    if not all(converged) and on_nonconverged == "raise":
        bad = [j for j, c in enumerate(converged) if not c]
        raise RuntimeError(
            f"freeze_multi: alpha CG did not converge for channel(s) {bad} "
            f"(tol {cfg.cg_tol_eval})")
    index = _verified_index(lat, reuse_index)
    tables = jnp.concatenate(
        [lat_mod.compact_table(index, b) for b in blurred_list], axis=1)
    return MultiPredictor(
        index=index, tables=tables, lengthscale=ls, outputscale=os_,
        noise=noise, alpha=jnp.stack(alphas, axis=1),
        cg_converged=jnp.asarray(converged),
        cg_residual=jnp.stack([jnp.max(i.residual_norms) for i in infos]),
        cg_iterations=jnp.stack([i.iterations for i in infos]),
        spacing=st.spacing, backend=cfg.serve_backend,
        buckets=tuple(cfg.serve_buckets), n_train=x.shape[0],
        n_outputs=k_out)


class ValidationReport(NamedTuple):
    ok: bool
    failures: tuple[str, ...]


def validate_predictor(pred: Predictor, *,
                       require_converged: bool = True) -> ValidationReport:
    """The publication gate: is this Predictor safe to serve?

    Runs host-side on the CANDIDATE before it is swapped in (never on the
    query path), so every failure mode it catches is refused before any
    query can observe it: non-finite tables/alpha (NaN-poisoned solve or
    buffer), a non-converged alpha solve, an index whose shapes/row map
    cannot be consistent with the tables, non-positive hyperparameters,
    and a corrupted zero miss row. Returns every failure, not just the
    first — the serving engine surfaces the list in its health status.
    """
    fails: list[str] = []
    tables = np.asarray(pred.tables)
    if not bool(np.isfinite(tables).all()):
        fails.append("tables contain non-finite values")
    if not bool(np.isfinite(np.asarray(pred.alpha)).all()):
        fails.append("alpha solution contains non-finite values")
    if require_converged and not bool(pred.cg_converged):
        fails.append(
            f"alpha CG solve not converged (relative residual "
            f"{float(pred.cg_residual):.2e} after "
            f"{int(pred.cg_iterations)} iterations)")
    if tables.shape[0] != pred.index.m + 1:
        fails.append(f"tables have {tables.shape[0]} rows, index expects "
                     f"m+1={pred.index.m + 1}")
    row_of_slot = np.asarray(pred.index.row_of_slot)
    if row_of_slot.shape != (pred.index.hcap,) or (
            row_of_slot.size and (row_of_slot.min() < 0
                                  or row_of_slot.max() > pred.index.m)):
        fails.append("index row_of_slot outside [0, m]")
    if tables.shape[0] > 0 and not bool((tables[-1] == 0).all()):
        fails.append("zero miss row is non-zero")
    ls = np.asarray(pred.lengthscale)
    if not (bool(np.isfinite(ls).all()) and bool((ls > 0).all())):
        fails.append("lengthscale not finite-positive")
    for name in ("outputscale", "noise"):
        v = float(getattr(pred, name))
        if not (math.isfinite(v) and v > 0):
            fails.append(f"{name} not finite-positive ({v})")
    if not pred.spacing > 0:
        fails.append(f"spacing not positive ({pred.spacing})")
    return ValidationReport(ok=not fails, failures=tuple(fails))


def _predict_core(pred: Predictor, xs: Array, *, backend: str,
                  interpret: bool | None = None):
    zq = xs / pred.lengthscale[None, :]
    out, miss = filtering.slice_only(pred.index, pred.tables, zq,
                                     spacing=pred.spacing, backend=backend,
                                     interpret=interpret)
    mean = out[:, 0]
    var = pred.outputscale - jnp.sum(out[:, 1:] ** 2, axis=1)
    var = jnp.clip(var, 1e-6, pred.outputscale)
    return mean, var, miss


# NOTE on buffer donation: the padded query buffer is freshly allocated
# per call and dead after the embed, but XLA input-output aliasing (what
# donate_argnums provides) needs a same-shape/dtype OUTPUT to alias onto —
# and the serving outputs are three (b,) vectors, never (b, d). Donating
# would only emit "donated buffers were not usable" warnings on every
# bucket compile, so the buffer is left to XLA's ordinary liveness
# analysis, which already reuses it after the embed.
@functools.partial(jax.jit, static_argnames=("backend",))
def _predict_padded(pred: Predictor, xs: Array, backend: str):
    return _predict_core(pred, xs, backend=backend)


def bucket_size(b: int, buckets: tuple[int, ...], multiple: int = 1) -> int:
    """Smallest serving bucket >= b (power-of-two growth past the largest),
    rounded up to ``multiple`` (mesh divisibility)."""
    nb = 0
    for s in sorted(buckets):
        if b <= s:
            nb = s
            break
    if nb == 0:
        biggest = max(buckets)
        nb = biggest * (1 << max(0, math.ceil(math.log2(b / biggest))))
    return -(-nb // multiple) * multiple


# jitted replicated-serving closures, keyed per (core, mesh, axis, backend)
# so repeated batches reuse one compilation instead of re-wrapping shard_map
_SHARDED_CACHE: dict = {}


def _sharded_predict_fn(mesh, axis_name: str, backend: str, core=None):
    core = _predict_core if core is None else core
    key = (core, mesh, axis_name, backend)
    fn = _SHARDED_CACHE.get(key)
    if fn is None:
        from repro.sharding.simplex import replicated_table_serve
        fn = replicated_table_serve(
            functools.partial(core, backend=backend), mesh, axis_name)
        _SHARDED_CACHE[key] = fn
    return fn


def predict(pred: Predictor, xs: Array, *, backend: str | None = None,
            mesh=None, axis_name: str = "data") -> ServeResult:
    """Serve one query batch from the frozen predictor.

    The batch is padded to a fixed bucket (``pred.buckets``) so jit
    compiles once per bucket, not once per batch shape; the padded buffer
    is freshly materialized per call and dies after the embed (see the
    donation note above ``_predict_padded``). Padding rows are served
    like any query (all identical, so their probes converge) and sliced
    away before returning. ``mesh`` fans the batch over its ``axis_name``
    axis with the frozen tables replicated — zero collectives, so
    throughput scales linearly in devices (DESIGN.md §12).
    """
    b, d = xs.shape
    backend = pred.backend if backend is None else backend
    ndev = int(mesh.shape[axis_name]) if mesh is not None else 1
    nb = bucket_size(b, pred.buckets, multiple=ndev)
    xs_pad = jnp.zeros((nb, d), xs.dtype).at[:b].set(xs)
    if mesh is None:
        mean, var, miss = _predict_padded(pred, xs_pad, backend)
    else:
        mean, var, miss = _sharded_predict_fn(mesh, axis_name,
                                              backend)(pred, xs_pad)
    return ServeResult(mean=mean[:b], var=var[:b], miss_mass=miss[:b])


# -- Multi-output serving (DESIGN.md §15) ------------------------------------


def _predict_multi_core(mp: MultiPredictor, xs: Array, *, backend: str,
                        interpret: bool | None = None):
    """One embed + probe + batched contraction for ALL k channels.

    The hoisted multi-channel path: the embed/rank scratch is computed
    once per query batch inside the single ``slice_only`` call, not once
    per output (pinned by the ``lattice.embed_count`` test) — the k
    channels differ only in which table columns the one gathered row set
    contracts against.
    """
    zq = xs / mp.lengthscale[None, :]
    out, miss = filtering.slice_only(mp.index, mp.tables, zq,
                                     spacing=mp.spacing, backend=backend,
                                     interpret=interpret)
    out = out.reshape(xs.shape[0], mp.n_outputs, -1)
    mean = out[:, :, 0]
    var = mp.outputscale - jnp.sum(out[:, :, 1:] ** 2, axis=2)
    var = jnp.clip(var, 1e-6, mp.outputscale)
    return mean, var, miss


@functools.partial(jax.jit, static_argnames=("backend",))
def _predict_multi_padded(mp: MultiPredictor, xs: Array, backend: str):
    return _predict_multi_core(mp, xs, backend=backend)


def predict_multi(mp: MultiPredictor, xs: Array, *,
                  backend: str | None = None, mesh=None,
                  axis_name: str = "data") -> MultiServeResult:
    """Serve all k output channels of one query batch from one probe.

    Same bucketing/mesh contract as ``predict``; returns (b, k) mean and
    latent variance plus the shared per-query ``miss_mass`` (the channels
    share the lattice, so they miss together). Differentiable in ``xs``
    (the ``slice_only`` custom JVP) — a PILCO-style rollout can
    ``jax.grad`` straight through it; see also ``predict_multi_grad`` for
    the one-pass analytic Jacobian.
    """
    b, d = xs.shape
    backend = mp.backend if backend is None else backend
    ndev = int(mesh.shape[axis_name]) if mesh is not None else 1
    nb = bucket_size(b, mp.buckets, multiple=ndev)
    xs_pad = jnp.zeros((nb, d), xs.dtype).at[:b].set(xs)
    if mesh is None:
        mean, var, miss = _predict_multi_padded(mp, xs_pad, backend)
    else:
        mean, var, miss = _sharded_predict_fn(
            mesh, axis_name, backend, core=_predict_multi_core)(mp, xs_pad)
    return MultiServeResult(mean=mean[:b], var=var[:b], miss_mass=miss[:b])


# -- Analytic query-space gradients (DESIGN.md §15) --------------------------


def _grad_blocks(index: LatticeIndex, tables: Array, xs: Array, ls: Array,
                 os_: Array, spacing: float, k_out: int):
    """Shared analytic d(mean, var)/dx* core for 1 and k output channels.

    One ``slice_only_grad`` pass (embed + d+1 probes + one gather + d+1
    contractions) yields the primal AND the full query-space Jacobian of
    every table channel; the chain rule through zq = x/ls and the
    variance's quadratic form are applied here. Where the variance clip
    is active (var_raw outside [1e-6, outputscale] — off-model queries)
    the reported dvar is 0, the true subgradient of the clipped surface.
    """
    zq = xs / ls[None, :]
    out, jac, miss = filtering.slice_only_grad(index, tables, zq,
                                               spacing=spacing)
    b = xs.shape[0]
    out = out.reshape(b, k_out, -1)
    jac = (jac / ls[None, None, :]).reshape(b, k_out, out.shape[2],
                                            ls.shape[0])
    mean = out[:, :, 0]
    dmean = jac[:, :, 0, :]
    roots = out[:, :, 1:]
    var_raw = os_ - jnp.sum(roots ** 2, axis=2)
    dvar = -2.0 * jnp.einsum("bkr,bkrj->bkj", roots, jac[:, :, 1:, :])
    clipped = (var_raw < 1e-6) | (var_raw > os_)
    var = jnp.clip(var_raw, 1e-6, os_)
    dvar = jnp.where(clipped[:, :, None], 0.0, dvar)
    return mean, var, dmean, dvar, miss


@jax.jit
def _predict_grad_padded(pred: Predictor, xs: Array):
    mean, var, dmean, dvar, miss = _grad_blocks(
        pred.index, pred.tables, xs, pred.lengthscale, pred.outputscale,
        pred.spacing, 1)
    return mean[:, 0], var[:, 0], dmean[:, 0], dvar[:, 0], miss


@jax.jit
def _predict_multi_grad_padded(mp: MultiPredictor, xs: Array):
    return _grad_blocks(mp.index, mp.tables, xs, mp.lengthscale,
                        mp.outputscale, mp.spacing, mp.n_outputs)


def predict_grad(pred: Predictor, xs: Array) -> ServeGradResult:
    """Predictions + analytic d(mean, var)/dx* in one fused pass.

    The forward-only fast path for gradient consumers (BO acquisition
    ascent, rollout sensitivity): one embed, d+1 probes, one table
    gather — the Jacobian contraction reuses the primal's gathered rows,
    so the pair costs O(d^2 (1+r)) per query with NO extra probes and no
    autodiff retrace. Equals ``jax.jacfwd`` of ``predict`` exactly
    (tests/test_serve_grad.py); strictly-interior queries (miss 0, away
    from cell boundaries) match central differences to f32 exactness
    because mean is piecewise-linear and var piecewise-quadratic in x*.
    Gate on ``grad_ok`` — see ``ServeGradResult``.
    """
    b, d = xs.shape
    nb = bucket_size(b, pred.buckets)
    xs_pad = jnp.zeros((nb, d), xs.dtype).at[:b].set(xs)
    mean, var, dmean, dvar, miss = _predict_grad_padded(pred, xs_pad)
    return ServeGradResult(mean=mean[:b], var=var[:b], dmean=dmean[:b],
                           dvar=dvar[:b], miss_mass=miss[:b],
                           grad_ok=miss[:b] <= 0.0)


def predict_multi_grad(mp: MultiPredictor, xs: Array) -> ServeGradResult:
    """``predict_grad`` over all k channels of a ``MultiPredictor``.

    Returns (b, k) mean/var and (b, k, d) dmean/dvar from ONE
    embed/probe/gather — the per-state-dimension Jacobian a dynamics
    rollout consumes at each step.
    """
    b, d = xs.shape
    nb = bucket_size(b, mp.buckets)
    xs_pad = jnp.zeros((nb, d), xs.dtype).at[:b].set(xs)
    mean, var, dmean, dvar, miss = _predict_multi_grad_padded(mp, xs_pad)
    return ServeGradResult(mean=mean[:b], var=var[:b], dmean=dmean[:b],
                           dvar=dvar[:b], miss_mass=miss[:b],
                           grad_ok=miss[:b] <= 0.0)


# -- Predictor persistence (DESIGN.md §14) -----------------------------------

PREDICTOR_FORMAT = "simplex-gp-predictor"
PREDICTOR_SCHEMA = 1


class PredictorLoadError(CheckpointCorruptError):
    """A saved Predictor failed integrity or validation at load.

    Subclasses ``CheckpointCorruptError`` so generation-fallback code can
    treat "corrupt training checkpoint" and "corrupt Predictor" with one
    except clause. A Predictor that raises this was NEVER eligible to
    serve — the load gate runs before any registry/publish step.
    """


def _predictor_arrays(pred: Predictor) -> dict[str, np.ndarray]:
    return {
        "tables": np.asarray(pred.tables),
        "lengthscale": np.asarray(pred.lengthscale),
        "outputscale": np.asarray(pred.outputscale),
        "noise": np.asarray(pred.noise),
        "alpha": np.asarray(pred.alpha),
        "cg_converged": np.asarray(pred.cg_converged),
        "cg_residual": np.asarray(pred.cg_residual),
        "cg_iterations": np.asarray(pred.cg_iterations),
        "index/tkeys": np.asarray(pred.index.tkeys),
        "index/row_of_slot": np.asarray(pred.index.row_of_slot),
        "index/slots": np.asarray(pred.index.slots),
    }


def save_predictor(pred: Predictor, path: str | pathlib.Path, *,
                   extra: dict | None = None, faults=None) -> pathlib.Path:
    """Atomically persist a Predictor to directory ``path``.

    Layout mirrors runtime/checkpoint.py: one .npy blob per array leaf
    plus a versioned ``manifest.json`` recording per-blob byte size and
    CRC32 alongside the static fields (spacing/backend/buckets/n_train
    and the index geometry). Writes land in ``<path>.tmp`` and publish
    via ``os.replace`` — the atomicity boundary: a crash mid-write
    leaves at most a dead ``.tmp`` (never a half-valid Predictor), a
    crash after the rename leaves a fully valid one. ``faults`` (a
    runtime/faults.FaultInjector) arms the kill-before/after-publish
    crash sites the recovery harness exercises.
    """
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.parent / (path.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    manifest = {
        "format": PREDICTOR_FORMAT,
        "schema": PREDICTOR_SCHEMA,
        "static": {
            "spacing": pred.spacing,
            "backend": pred.backend,
            "buckets": list(pred.buckets),
            "n_train": pred.n_train,
            "index": {"d": pred.index.d, "hcap": pred.index.hcap,
                      "m": pred.index.m},
        },
        "extra": extra or {},
        "leaves": save_blobs(tmp, _predictor_arrays(pred)),
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if faults is not None:
        faults.kill_if_armed("persist_before_publish")
    if path.exists():
        shutil.rmtree(path)
    os.replace(tmp, path)  # atomic publish
    if faults is not None:
        faults.kill_if_armed("persist_after_publish")
    return path


def self_probe(pred: Predictor, *, sample: int = 16,
               check_end_to_end: bool = True) -> None:
    """In-lattice self-probe: prove the loaded Predictor can actually
    serve its own lattice before it becomes eligible to serve anyone.

    ``validate_predictor`` checks VALUES (finiteness, ranges, shapes);
    this checks BEHAVIOR, with no training data needed:

      1. row-map bijection — the occupied entries of ``row_of_slot``
         must hit every dense row 0..m-1 exactly once (a permuted or
         duplicated row map passes the range check but serves the wrong
         vertices);
      2. hash round-trip — a sample of the index's own stored keys,
         looked up through the REAL probe path (kernels/hash), must land
         back on their own rows (catches a tkeys/row_of_slot pair that
         was torn from two different generations);
      3. end-to-end slice — ``predict`` on a tiny synthetic batch must
         return finite mean/var with ``miss_mass`` in [0, 1] (catches a
         static-field/blob mismatch that only explodes inside the jitted
         slice kernel).

    Raises ``PredictorLoadError`` on any failure; returns None when the
    Predictor is fit to serve.
    """
    from repro.kernels.hash import ops as hash_ops

    idx = pred.index
    ros = np.asarray(idx.row_of_slot)
    occ = np.nonzero(ros < idx.m)[0]
    rows = ros[occ]
    if occ.shape[0] != idx.m or not np.array_equal(np.sort(rows),
                                                   np.arange(idx.m)):
        raise PredictorLoadError(
            "self-probe: index row_of_slot is not a bijection onto "
            f"dense rows 0..{idx.m - 1} ({occ.shape[0]} occupied slots)")
    take = occ[:: max(1, occ.shape[0] // max(sample, 1))][:sample]
    tkeys = jnp.asarray(idx.tkeys)
    queries = tkeys[jnp.asarray(take)]
    found = np.asarray(hash_ops.hash_lookup(
        tkeys, queries, jnp.ones((take.shape[0],), bool), idx.hcap,
        backend="hash_xla"))
    if (found < 0).any() or not np.array_equal(
            ros[np.maximum(found, 0)], ros[take]):
        raise PredictorLoadError(
            "self-probe: the index's own keys do not look up to their "
            "own rows — tkeys/row_of_slot are inconsistent")
    gathered = np.asarray(pred.tables)[ros[take]]
    if not np.isfinite(gathered).all():
        raise PredictorLoadError(
            "self-probe: probed table rows contain non-finite values")
    if check_end_to_end:
        d = int(pred.lengthscale.shape[0])
        zs = np.zeros((2, d), np.float32)
        zs[1] = 0.37  # off-origin: exercises nontrivial barycentric ranks
        try:
            res = predict(pred, jnp.asarray(zs))
            mean = np.asarray(res.mean)
            var = np.asarray(res.var)
            miss = np.asarray(res.miss_mass)
        except Exception as e:
            raise PredictorLoadError(
                f"self-probe: end-to-end predict failed "
                f"({type(e).__name__}: {e})") from e
        if not (np.isfinite(mean).all() and np.isfinite(var).all()):
            raise PredictorLoadError(
                "self-probe: end-to-end predict returned non-finite "
                "mean/var")
        if not ((miss >= 0) & (miss <= 1)).all():
            raise PredictorLoadError(
                f"self-probe: miss_mass outside [0, 1] ({miss})")


def load_predictor(path: str | pathlib.Path, *, validate: bool = True,
                   require_converged: bool = True) -> Predictor:
    """Load a persisted Predictor; gate it before it can ever serve.

    The load path enforces the §14 validation-before-serve rule in three
    layers, all BEFORE the Predictor is returned to any registry:
    blob integrity (existence / recorded size / CRC32 / parse — a
    truncated or bit-flipped file raises here), the existing
    ``validate_predictor`` value gate, and the ``self_probe`` behavior
    gate. Any failure raises ``PredictorLoadError`` — a corrupted file
    is rejected, never served. ``validate=False`` skips the two gates
    (integrity checks always run) for diagnostic tooling only.
    """
    path = pathlib.Path(path)
    try:
        man = read_manifest(path / "manifest.json",
                            expect_format=PREDICTOR_FORMAT)
        if man.get("schema", 0) > PREDICTOR_SCHEMA:
            raise CheckpointCorruptError(
                f"{path}: predictor schema {man.get('schema')} is newer "
                f"than this reader ({PREDICTOR_SCHEMA})")
        static = man.get("static")
        if not isinstance(static, dict) or not isinstance(
                static.get("index"), dict):
            raise CheckpointCorruptError(
                f"{path}: manifest missing the static-field table")
        flat = load_blobs(path, man["leaves"])
        missing = set(_REQUIRED_LEAVES) - set(flat)
        if missing:
            raise CheckpointCorruptError(
                f"{path}: manifest lists no blob for {sorted(missing)}")
    except PredictorLoadError:
        raise
    except CheckpointCorruptError as e:
        raise PredictorLoadError(str(e)) from e

    try:
        idx_static = static["index"]
        index = LatticeIndex(
            tkeys=jnp.asarray(flat["index/tkeys"]),
            row_of_slot=jnp.asarray(flat["index/row_of_slot"]),
            slots=jnp.asarray(flat["index/slots"]),
            d=int(idx_static["d"]), hcap=int(idx_static["hcap"]),
            m=int(idx_static["m"]))
        pred = Predictor(
            index=index,
            tables=jnp.asarray(flat["tables"]),
            lengthscale=jnp.asarray(flat["lengthscale"]),
            outputscale=jnp.asarray(flat["outputscale"]),
            noise=jnp.asarray(flat["noise"]),
            alpha=jnp.asarray(flat["alpha"]),
            cg_converged=jnp.asarray(flat["cg_converged"]),
            cg_residual=jnp.asarray(flat["cg_residual"]),
            cg_iterations=jnp.asarray(flat["cg_iterations"]),
            spacing=float(static["spacing"]),
            backend=str(static["backend"]),
            buckets=tuple(int(b) for b in static["buckets"]),
            n_train=int(static["n_train"]))
    except (KeyError, TypeError, ValueError) as e:
        raise PredictorLoadError(
            f"{path}: manifest/blob structure unusable "
            f"({type(e).__name__}: {e})") from e

    if validate:
        rep = validate_predictor(pred, require_converged=require_converged)
        if not rep.ok:
            raise PredictorLoadError(
                f"{path}: loaded predictor failed validation: "
                + "; ".join(rep.failures))
        self_probe(pred)
    return pred


_REQUIRED_LEAVES = tuple(sorted((
    "tables", "lengthscale", "outputscale", "noise", "alpha",
    "cg_converged", "cg_residual", "cg_iterations",
    "index/tkeys", "index/row_of_slot", "index/slots")))
