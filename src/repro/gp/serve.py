"""Frozen-lattice serving (DESIGN.md §12): precomputed Simplex-GP predictor.

``gp/predict.posterior`` pays a joint-lattice build plus CG/Lanczos solves
for EVERY query batch — fine for benchmarking, fatal for serving. But SKI
prediction reduces to interpolating precomputed grid quantities (KISS-GP,
Wilson & Nickisch 2015; Yadav et al. 2021 decouple query cost from n
entirely), and on the permutohedral lattice the analogue is exact:

  mean(x*)  = k_{*,X} alpha            with alpha = K_hat^{-1} y
            = w(x*)^T  [B W^T alpha]   — slice of a PRECOMPUTED table
  var(x*)   = k(0) - || w(x*)^T [B W^T R] ||^2
            with R = Q (T + eps I)^{-1/2} the LOVE root from k Lanczos
            iterations (the same T/Q ``posterior`` uses; the inverse
            square root via the k x k eigendecomposition)

so ``freeze`` solves ONCE at train time, splats [alpha | R] onto the
train lattice, runs the 2(d+1) blur sweeps ONCE (batched over the 1 + k
channels), and keeps only the blurred value tables — compacted to the
m + 1 occupied rows — plus the hash index for vertex lookup. Per query,
``predict`` is embed (O(d^2), sort-free) + d+1 hash probes + a batched
multi-channel barycentric slice: no build, no solve, no collective, cost
independent of n. Queries landing outside the frozen lattice lose the
mass of their absent vertices (standard slicing semantics) and report it
as the ``miss_mass`` fidelity diagnostic.

Serving mechanics: ``predict`` pads each batch to a fixed bucket size
(``SimplexGPConfig.serve_buckets``) so jit compiles once per bucket
rather than once per batch shape, donates the padded query buffer, and
optionally fans queries over a device mesh with the frozen tables
REPLICATED — zero collectives, linear throughput scaling
(sharding/simplex.py's serving contract).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import filtering
from repro.core import lattice as lat_mod
from repro.core.filtering import LatticeCache
from repro.core.lattice import LatticeIndex
from repro.gp.models import GPParams, SimplexGP
from repro.solvers.cg import cg as cg_solve
from repro.solvers.lanczos import lanczos as lanczos_run

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Predictor:
    """Immutable frozen-model state: everything a query needs, nothing else.

    ``tables`` column 0 is the mean channel (os * blurred splat of alpha);
    columns 1..k are the LOVE variance channels (os * blurred splat of the
    root R), so var = outputscale - sum_j table_j(x*)^2. A pytree — safe
    to pass through jit, replicate across a mesh, or checkpoint.
    """

    index: LatticeIndex  # hash index over the frozen train lattice
    tables: Array  # (m+1, 1+k) f32 blurred [mean | LOVE root] channels
    lengthscale: Array  # (d,)
    outputscale: Array  # ()
    noise: Array  # () — for predictive-y variance (latent var + noise)
    spacing: float = dataclasses.field(metadata=dict(static=True))
    backend: str = dataclasses.field(default="auto",
                                     metadata=dict(static=True))
    buckets: tuple[int, ...] = dataclasses.field(
        default=(64, 256, 1024, 4096), metadata=dict(static=True))
    n_train: int = dataclasses.field(default=0, metadata=dict(static=True))


class ServeResult(NamedTuple):
    mean: Array  # (b,)
    var: Array  # (b,) latent-f variance (add pred.noise for predictive y)
    miss_mass: Array  # (b,) in [0, 1]: barycentric mass on absent vertices


@functools.partial(jax.jit, static_argnames=("model", "variance_rank"))
def _freeze_tables(model: SimplexGP, params: GPParams, lat, x: Array,
                   y: Array, key: Array, variance_rank: int) -> Array:
    """alpha + LOVE-root solves and the one batched splat->blur sweep."""
    cfg = model.config
    st = model.stencil
    n = x.shape[0]
    _, os_, _ = model.constrained(params)
    op = model.operator(params, x, lat=lat)

    u, _ = cg_solve(op.mvm, y[:, None], tol=cfg.cg_tol_eval,
                    max_iters=cfg.max_cg_iters)

    # LOVE basis — the same y-seeded Lanczos run ``posterior`` does
    q0 = y[:, None] + 1e-3 * jax.random.normal(key, (n, 1), x.dtype)
    lres = lanczos_run(op.mvm, q0, variance_rank)
    q = lres.q[:, :, 0].T  # (n, k)
    tdense = (jnp.diag(jnp.where(lres.valid[:, 0], lres.alphas[:, 0], 1.0))
              + jnp.diag(lres.betas[:-1, 0] * lres.valid[:-1, 0]
                         * lres.valid[1:, 0], 1)
              + jnp.diag(lres.betas[:-1, 0] * lres.valid[:-1, 0]
                         * lres.valid[1:, 0], -1))
    # (T + eps I)^{-1/2} via the k x k eigendecomposition: identical
    # quadratic form to posterior's (T + eps I)^{-1} solve
    e, vecs = jnp.linalg.eigh(
        tdense + 1e-6 * jnp.eye(tdense.shape[0], dtype=x.dtype))
    root = q @ (vecs * jnp.where(e > 1e-10,
                                 jax.lax.rsqrt(jnp.maximum(e, 1e-10)),
                                 0.0)[None, :])

    # ONE batched splat + 2(d+1) blur sweeps for all 1 + k channels
    chans = jnp.concatenate([u, root], axis=1)
    w = jnp.asarray(st.weights, x.dtype)
    table = lat_mod.splat_sorted(lat, chans)
    blurred = lat_mod.blur(lat, table, w)
    if cfg.symmetrize:
        blurred = 0.5 * (blurred + lat_mod.blur(lat, table, w, reverse=True))
    return os_ * blurred  # (cap+1, 1+k)


def freeze(model: SimplexGP, params: GPParams, x: Array, y: Array, *,
           key: Array, variance_rank: int = 30, cap: int | None = None,
           cache: LatticeCache | None = None) -> Predictor:
    """Freeze a trained model into an immutable serving ``Predictor``.

    One-time cost (amortized over every future query): a train-lattice
    build (auto-sized unless ``cap`` given; ``cache`` memoizes it), the
    alpha/LOVE solves, one batched blur sweep, and the hash-index build.
    Eager-only: the dense tables are sized by the CONCRETE occupied count
    m, which is what keeps them small enough to stay VMEM-resident.
    """
    cfg = model.config
    st = model.stencil
    ls, os_, noise = model.constrained(params)
    z = x / ls[None, :]
    if cap is None and cache is None:
        lat = lat_mod.build_lattice_auto(z, spacing=st.spacing, r=st.r,
                                         backend=cfg.build_backend)
    elif cache is not None:
        n, d = x.shape
        cap_val = model.capacity(n, d) if cap is None else cap
        lat = cache.get(cache.point_set_tag(x), z, spacing=st.spacing,
                        r=st.r, cap=cap_val, ls=ls,
                        build_backend=cfg.build_backend)
    else:
        lat = lat_mod.build_lattice(z, spacing=st.spacing, r=st.r, cap=cap,
                                    backend=cfg.build_backend)
    if bool(lat.pack_overflow):
        raise RuntimeError("freeze: lattice coordinate range overflow "
                           "(|coord| > 2^15) — rescale inputs or bound "
                           "the lengthscale")
    if bool(lat.overflow):
        raise RuntimeError("freeze: lattice capacity overflow — pass a "
                           "larger cap (or let build_lattice_auto size it)")

    blurred = _freeze_tables(model, params, lat, x, y, key, variance_rank)
    index = lat_mod.lattice_index(lat)
    tables = lat_mod.compact_table(index, blurred)
    return Predictor(index=index, tables=tables, lengthscale=ls,
                     outputscale=os_, noise=noise, spacing=st.spacing,
                     backend=cfg.serve_backend,
                     buckets=tuple(cfg.serve_buckets),
                     n_train=x.shape[0])


def _predict_core(pred: Predictor, xs: Array, *, backend: str,
                  interpret: bool | None = None):
    zq = xs / pred.lengthscale[None, :]
    out, miss = filtering.slice_only(pred.index, pred.tables, zq,
                                     spacing=pred.spacing, backend=backend,
                                     interpret=interpret)
    mean = out[:, 0]
    var = pred.outputscale - jnp.sum(out[:, 1:] ** 2, axis=1)
    var = jnp.clip(var, 1e-6, pred.outputscale)
    return mean, var, miss


# NOTE on buffer donation: the padded query buffer is freshly allocated
# per call and dead after the embed, but XLA input-output aliasing (what
# donate_argnums provides) needs a same-shape/dtype OUTPUT to alias onto —
# and the serving outputs are three (b,) vectors, never (b, d). Donating
# would only emit "donated buffers were not usable" warnings on every
# bucket compile, so the buffer is left to XLA's ordinary liveness
# analysis, which already reuses it after the embed.
@functools.partial(jax.jit, static_argnames=("backend",))
def _predict_padded(pred: Predictor, xs: Array, backend: str):
    return _predict_core(pred, xs, backend=backend)


def bucket_size(b: int, buckets: tuple[int, ...], multiple: int = 1) -> int:
    """Smallest serving bucket >= b (power-of-two growth past the largest),
    rounded up to ``multiple`` (mesh divisibility)."""
    nb = 0
    for s in sorted(buckets):
        if b <= s:
            nb = s
            break
    if nb == 0:
        biggest = max(buckets)
        nb = biggest * (1 << max(0, math.ceil(math.log2(b / biggest))))
    return -(-nb // multiple) * multiple


# jitted replicated-serving closures, keyed per (mesh, axis, backend) so
# repeated batches reuse one compilation instead of re-wrapping shard_map
_SHARDED_CACHE: dict = {}


def _sharded_predict_fn(mesh, axis_name: str, backend: str):
    key = (mesh, axis_name, backend)
    fn = _SHARDED_CACHE.get(key)
    if fn is None:
        from repro.sharding.simplex import replicated_table_serve
        fn = replicated_table_serve(
            functools.partial(_predict_core, backend=backend), mesh,
            axis_name)
        _SHARDED_CACHE[key] = fn
    return fn


def predict(pred: Predictor, xs: Array, *, backend: str | None = None,
            mesh=None, axis_name: str = "data") -> ServeResult:
    """Serve one query batch from the frozen predictor.

    The batch is padded to a fixed bucket (``pred.buckets``) so jit
    compiles once per bucket, not once per batch shape; the padded buffer
    is freshly materialized per call and dies after the embed (see the
    donation note above ``_predict_padded``). Padding rows are served
    like any query (all identical, so their probes converge) and sliced
    away before returning. ``mesh`` fans the batch over its ``axis_name``
    axis with the frozen tables replicated — zero collectives, so
    throughput scales linearly in devices (DESIGN.md §12).
    """
    b, d = xs.shape
    backend = pred.backend if backend is None else backend
    ndev = int(mesh.shape[axis_name]) if mesh is not None else 1
    nb = bucket_size(b, pred.buckets, multiple=ndev)
    xs_pad = jnp.zeros((nb, d), xs.dtype).at[:b].set(xs)
    if mesh is None:
        mean, var, miss = _predict_padded(pred, xs_pad, backend)
    else:
        mean, var, miss = _sharded_predict_fn(mesh, axis_name,
                                              backend)(pred, xs_pad)
    return ServeResult(mean=mean[:b], var=var[:b], miss_mass=miss[:b])
