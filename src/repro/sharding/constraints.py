"""Activation sharding constraints usable from pure model code.

Model functions stay mesh-agnostic: they call ``constrain(x, "batch",
None, "vocab")`` with LOGICAL axis names; the launcher installs a mesh +
logical->physical mapping around tracing (``with activation_mesh(mesh):``)
and the call becomes a with_sharding_constraint. With no mesh installed
(CPU smoke tests) it is a no-op, so the same model code runs everywhere.

Logical axes:
  batch   -> ("pod", "data")  [or ("data",)]
  seq     -> "data" when sequence-sharding (long-context batch=1 cells)
  model   -> "model" (TP: heads / ff / vocab shards)

A constraint is applied only when the dimension divides the physical axis
— the same divisibility guard as partition.py.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current() -> tuple[Mesh | None, dict]:
    return (getattr(_state, "mesh", None),
            getattr(_state, "logical", {}))


@contextlib.contextmanager
def activation_mesh(mesh: Mesh | None, *, seq_shard: bool = False):
    """Install `mesh` for constrain() during tracing/execution."""
    if mesh is None:
        yield
        return
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    logical = {
        "batch": dp,
        "seq": ("data",) if seq_shard else None,
        # Megatron-style sequence parallelism: the residual stream between
        # layers is seq-sharded over the TP axis (memory: scan carries
        # shrink 16x; GSPMD inserts the SP all-gather before attention).
        "seq_tp": ("model",),
        "model": ("model",),
    }
    prev = _current()
    _state.mesh, _state.logical = mesh, logical
    try:
        yield
    finally:
        _state.mesh, _state.logical = prev


def constrain(x: Any, *axes: str | None) -> Any:
    """with_sharding_constraint by logical axis names (no-op without mesh).
    """
    mesh, logical = _current()
    if mesh is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    spec = []
    for dim, name in zip(x.shape, axes):
        if name is None:
            spec.append(None)
            continue
        phys = logical.get(name)
        if phys is None:
            spec.append(None)
            continue
        size = 1
        for a in phys:
            size *= mesh.shape[a]
        spec.append(tuple(phys) if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
