from repro.sharding import partition, simplex

__all__ = ["partition", "simplex"]
