from repro.sharding import partition

__all__ = ["partition"]
