"""Data-parallel Simplex-GP: the sharded lattice MVM (DESIGN.md §10).

The splat→blur→slice operator decomposes cleanly over devices because the
two big per-*point* objects (inputs and values) and the one per-*lattice-
point* object (the deduped value table) have wildly different sizes: the
table is m ≲ n(d+1) rows but in practice a small fraction of it (paper
Table 3), so it is cheap to REPLICATE, while the n data rows are what
actually scale — so they are SHARDED:

  splat   local segment-sum of the device's (n/dev)(d+1) contributions
          into a full-size (cap+1, c) table, then ONE ``psum`` — the only
          collective of the whole MVM;
  blur    the 2(d+1) directional sweeps run replicated on the summed
          table (identical work per device; no communication);
  slice   purely local — each device gathers table rows for its own
          points via its shard of ``seg_ids``/barycentric weights.

The per-point lattice arrays (``seg_ids``, ``weights``) carry *global*
slot ids in [0, cap], so sharding them by point rows needs no re-indexing.
The lattice is built once, globally (the build is already amortized to one
per step — DESIGN.md §9); this module distributes the per-iteration MVMs,
which is where CG/mBCG/LOVE spend their time.

One-psum-per-MVM is a hard contract: ``count_primitive`` below lets tests
and benchmarks assert it on the jaxpr (``symmetrize`` reuses the same
summed table for both sweep orders, so it adds no collective).

Build-backend interplay (DESIGN.md §11): the sharded MVM is agnostic to
which build path produced the ``Lattice`` — ``seg_ids`` carry *global*
slot ids and the blur graph is a dense gather table under every backend
(sort's lex numbering vs the hash build's placement numbering are related
by a pure slot permutation, which the replicated table absorbs). What
must NOT happen is mixing lattices across paths for the same point set:
consumers holding slot-indexed state (the replicated ``nbr`` table, LOVE
caches) would silently mix numberings — ``LatticeCache`` therefore keys
on the build backend alongside the device/sharding layout.

Everything is plain XLA inside ``shard_map`` — on CPU hosts with
``--xla_force_host_platform_device_count=8`` the sharded path is
bit-compatible modulo f32 summation order with the single-device
``fused_xla`` tier, which is exactly what tests/test_multidevice.py pins.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:  # graduated API (jax >= 0.5)
    from jax import shard_map
except ImportError:  # this image's jax 0.4.x only has the experimental path
    from jax.experimental.shard_map import shard_map

from repro.core.lattice import Lattice

Array = jax.Array


def data_mesh(num_devices: int | None = None,
              axis_name: str = "data") -> Mesh:
    """1-D device mesh over (a prefix of) the available devices."""
    devs = jax.devices()
    if num_devices is not None:
        devs = devs[:num_devices]
    return Mesh(np.array(devs), (axis_name,))


def shard_rows(n: int, mesh: Mesh, axis_name: str) -> tuple[int, int]:
    """(rows per device, ghost rows) for n points on the axis — ANY n.

    There is deliberately no divisibility requirement (the old hard error
    was the "divisibility cliff"): ``sharded_lattice_mvm`` pads the three
    per-point arrays with ``ghost`` zero-weight rows so every device gets
    an equal shard. Ghost rows carry barycentric weight 0 and splat into
    the trash row ``cap`` (which the blur zeroes anyway), so the masked
    segment-sum is bit-equivalent to the unpadded operator on the real
    rows — including n < axis size, where some devices hold only ghosts.
    """
    ndev = int(mesh.shape[axis_name])
    ghost = (-n) % ndev
    return (n + ghost) // ndev, ghost


def check_shardable(n: int, mesh: Mesh, axis_name: str) -> int:
    """Points-per-device under ghost padding (kept for API compatibility).

    Historically raised on indivisible n; since the elastic-training work
    any n shards (zero-weight ghost rows make up the remainder), so this
    now just reports the padded per-device row count.
    """
    return shard_rows(n, mesh, axis_name)[0]


def sharded_lattice_mvm(lat: Lattice, v: Array, weights: Array | None = None,
                        *, mesh: Mesh, axis_name: str = "data",
                        taps: tuple[float, ...] | None = None,
                        symmetrize: bool = True,
                        transpose: bool = False) -> Array:
    """W B W^T v with rows of ``v`` sharded over ``mesh``; one psum total.

    Semantically identical to the single-device ``kernels.blur.ops``
    backends (same linear operator; summation order differs only across
    device boundaries, so results agree to f32 accumulation noise).
    ``weights`` may be traced (the sharded path is pure XLA).

    Any n shards: when n does not divide the axis size, the per-point
    arrays are padded with GHOST rows — zero values, zero barycentric
    weight, seg_id = the trash row ``cap``. A ghost contributes exactly
    0.0 to the segment-sum of a row the blur zeroes regardless, so the
    real rows' results are bit-identical to the pad-free layout (and for
    divisible n no padding code runs at all). Padding happens outside
    ``shard_map``, so the one-psum contract is untouched.
    """
    if weights is None:
        if taps is None:
            raise ValueError("sharded_lattice_mvm needs weights= or taps=")
        weights = jnp.asarray(taps, v.dtype)
    n, c = v.shape
    if n != lat.n:
        raise ValueError(f"v has {n} rows but the lattice was built for "
                         f"{lat.n} points")
    _, ghost = shard_rows(n, mesh, axis_name)
    d1 = lat.d + 1
    r = lat.r
    cap = lat.cap
    # (n, d+1) layout so the per-point leading axis is the sharded one.
    seg = lat.seg_ids.reshape(lat.n, d1)
    bary = lat.weights
    if ghost:
        v = jnp.concatenate(
            [v, jnp.zeros((ghost, c), v.dtype)], axis=0)
        seg = jnp.concatenate(
            [seg, jnp.full((ghost, d1), cap, seg.dtype)], axis=0)
        bary = jnp.concatenate(
            [bary, jnp.zeros((ghost, d1), bary.dtype)], axis=0)

    def local_mvm(v_loc, seg_loc, bw_loc, nbr, w):
        nl = v_loc.shape[0]
        seg_flat = seg_loc.reshape(nl * d1)
        # --- splat (local) + the ONE collective --------------------------
        contrib = (bw_loc[:, :, None] * v_loc[:, None, :]).reshape(
            nl * d1, c)
        table = jax.ops.segment_sum(contrib, seg_flat, num_segments=cap + 1)
        table = jax.lax.psum(table, axis_name)
        table = table.at[cap].set(0.0)

        # --- blur (replicated on the summed table) -----------------------
        w_off = jnp.concatenate([w[:r], w[r + 1:]])  # (2r,) off-center taps

        def blur_dir(vals, a):
            out = vals * w[r] + jnp.einsum("prc,r->pc", vals[nbr[a]], w_off)
            return out.at[cap].set(0.0), None

        order = jnp.arange(d1)
        fwd = order[::-1] if transpose else order
        blurred, _ = jax.lax.scan(blur_dir, table, fwd)
        if symmetrize:  # 0.5 (F + F^T): same summed table, opposite sweep
            blurred_r, _ = jax.lax.scan(blur_dir, table, fwd[::-1])
            blurred = 0.5 * (blurred + blurred_r)

        # --- slice (local) ----------------------------------------------
        per_vertex = blurred[seg_flat].reshape(nl, d1, c)
        return jnp.einsum("nkc,nk->nc", per_vertex, bw_loc)

    fn = shard_map(
        local_mvm, mesh=mesh,
        in_specs=(P(axis_name, None), P(axis_name, None),
                  P(axis_name, None), P(), P()),
        out_specs=P(axis_name, None))
    out = fn(v, seg, bary, lat.nbr, weights.astype(v.dtype))
    return out[:n] if ghost else out


def mesh_fingerprint(mesh) -> str:
    """Hashable identity of a device mesh for cache keys (DESIGN.md §16).

    Two meshes are interchangeable for a consumer holding mesh-dependent
    compiled/sharded state ONLY if they have the same axis layout over the
    same physical devices — so the fingerprint is (axis names/sizes, the
    flattened device ids). ``None`` (no mesh — single-device execution)
    fingerprints as "". ``LatticeCache`` folds this into its key so a
    lattice produced for one mesh layout is NEVER served to an MVM running
    on a different one after an elastic resize (8→4 must rebuild).
    """
    if mesh is None:
        return ""
    shape = tuple((str(name), int(size))
                  for name, size in mesh.shape.items())
    devs = tuple(int(d.id) for d in np.asarray(mesh.devices).reshape(-1))
    return f"{shape}|{devs}"


# NOTE: there is deliberately no sharded twin of ``filtering.mvm_operator``
# here — pass ``mesh=`` to it (or to ``SimplexGP.operator``) and its matvec
# dispatches to ``sharded_lattice_mvm`` while keeping the cache/auto-cap
# machinery of DESIGN.md §9.


# ---------------------------------------------------------------------------
# Replicated-table serving contract (DESIGN.md §12).
# ---------------------------------------------------------------------------
# The frozen serving path inverts the training MVM's sharding economics:
# training shards the n data rows and replicates the small value table with
# ONE psum per MVM, but a frozen-predictor query touches no shared
# accumulator at all — every query is an independent hash-probe + gather +
# contraction against immutable tables. So the serving contract is:
#
#   frozen state (hash index + value tables + hyperparameters) REPLICATED,
#   query rows SHARDED over the data axis, outputs sharded the same way,
#   ZERO collectives (assert with ``collective_counts``).
#
# Throughput therefore scales linearly in devices for batches that divide
# the axis (gp/serve.predict pads its buckets to the axis size). Keeping
# the tables replicated is cheap for the same reason the blur table is:
# they hold m + 1 <= cap + 1 rows, a small fraction of n(d+1) in practice.


# Hot-swap contract (DESIGN.md §13): the serving engine may PUBLISH a new
# frozen state while traffic is in flight. That is safe under this
# replicated contract because (a) a Predictor is an immutable pytree — a
# query batch that grabbed the old reference keeps serving the old
# version end to end (no torn reads: nothing is mutated in place), and
# (b) the swap itself is a host-side reference assignment AFTER the
# candidate has been fully materialized on every device via
# ``replicate_pytree`` and validated (serve.validate_predictor) — devices
# never observe a half-transferred table. Per-bucket compilations key on
# array shapes, not identities, so a swap whose (n, m, k) are unchanged
# (the y-only refresh path) reuses every compiled bucket.


def replicate_pytree(tree, mesh: Mesh):
    """Place every array leaf of ``tree`` fully replicated on ``mesh``.

    The publish step of the hot-swap contract above: a candidate frozen
    state is replicated here BEFORE the registry swap, so the first
    post-swap query pays no lazy per-device transfer (and a transfer
    failure surfaces at publish time — refusable — instead of on the
    query path)."""
    sharding = jax.sharding.NamedSharding(mesh, P())

    def place(leaf):
        return jax.device_put(leaf, sharding) \
            if isinstance(leaf, jax.Array) else leaf

    return jax.tree.map(place, tree)


def replicated_table_serve(fn, mesh: Mesh, axis_name: str = "data"):
    """Wrap ``fn(frozen_state, queries) -> per-query outputs`` for
    replicated-table serving: returns a JITTED callable with the frozen
    state replicated, query rows sharded over ``axis_name``, and every
    output sharded the same way. ``fn`` must be embarrassingly parallel
    over query rows (no cross-query reductions) — which is exactly what
    the frozen slice path is."""
    # check_rep=False: the body's probe while_loop has no replication rule
    # in this jax version; replication is by construction here (the frozen
    # state is P() everywhere and nothing reduces across queries).
    sharded = shard_map(fn, mesh=mesh, in_specs=(P(), P(axis_name)),
                        out_specs=P(axis_name), check_rep=False)
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# Collective-count inspection (the one-psum contract).
# ---------------------------------------------------------------------------

COLLECTIVE_PRIMITIVES = ("psum", "all_gather", "all_to_all", "ppermute",
                         "psum_scatter")

# inside shard_map bodies jax names the reduction primitive "psum2"
# (the positional-semantics variant); count it as a psum — it IS the
# cross-device all-reduce. "pbroadcast" is replication bookkeeping with
# no communication and is deliberately not counted.
_PRIMITIVE_ALIASES = {"psum2": "psum"}


def count_primitive(jaxpr, name: str) -> int:
    """Occurrences of primitive ``name`` in a (closed) jaxpr, recursively
    descending into sub-jaxprs (scan/while bodies, shard_map, pjit)."""
    core_jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    total = 0
    for eqn in core_jaxpr.eqns:
        if _PRIMITIVE_ALIASES.get(eqn.primitive.name,
                                  eqn.primitive.name) == name:
            total += 1
        for v in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(
                    v, is_leaf=lambda x: isinstance(
                        x, (jax.core.Jaxpr, jax.core.ClosedJaxpr))):
                if isinstance(sub, (jax.core.Jaxpr, jax.core.ClosedJaxpr)):
                    total += count_primitive(sub, name)
    return total


def collective_counts(fn, *args) -> dict[str, int]:
    """{primitive: count} over ``COLLECTIVE_PRIMITIVES`` for ``fn(*args)``."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return {p: count_primitive(jaxpr, p) for p in COLLECTIVE_PRIMITIVES}


# ---------------------------------------------------------------------------
# Gradient contract of replicated-table serving (DESIGN.md §15).
# ---------------------------------------------------------------------------
# The zero-collective serving contract EXTENDS to query-space gradients:
# d(mean, var)/d(x*) of the frozen slice is, per query, the same local
# probe + gather + contraction against analytic weight derivatives — no
# cross-query term exists, so differentiating w.r.t. the SHARDED queries
# introduces no communication. The only way a collective could appear is
# a cotangent w.r.t. the REPLICATED frozen state (summing per-device
# table cotangents needs a psum); serving gradients never request that —
# the tables are frozen constants, so ``jax.grad(..., argnums=queries)``
# partial-evaluates the table cotangent away. ``assert_zero_collectives``
# pins this on the gradient jaxpr (tests/test_serve_grad.py and
# benchmarks/fig_rollout.py both assert it).


def assert_zero_collectives(fn, *args, what: str = "serving") -> None:
    """Raise if ``fn(*args)`` would execute ANY collective primitive.

    Traces (never runs) ``fn`` and counts ``COLLECTIVE_PRIMITIVES`` in
    the jaxpr, recursively. Use on serving entry points and on their
    gradient functions to enforce the zero-collective contracts above.
    """
    counts = {p: c for p, c in collective_counts(fn, *args).items() if c}
    if counts:
        raise AssertionError(
            f"zero-collective {what} contract violated: found {counts}")
