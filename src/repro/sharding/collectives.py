"""Explicit collective patterns: overlap-friendly TP matmul + DP psum.

jit+GSPMD already inserts collectives from the partition specs; these
shard_map building blocks exist for the cases where *schedule* matters and
we want it under our control rather than the partitioner's:

  * ``collective_matmul_ag`` — all-gather-matmul overlap: instead of one
    blocking all-gather of the (seq-sharded) activations followed by a
    full matmul, rotate shards around the TP ring with ppermute and
    matmul each chunk as it arrives — comm hides behind compute when
    t_chunk_matmul >= t_permute (the standard TPU "collective matmul").
  * ``psum_scatter_matmul`` — the row-parallel dual: matmul chunk-wise
    and reduce-scatter via ring accumulation.
  * ``dp_psum_compressed`` — DP gradient all-reduce with the int8
    error-feedback codec (runtime/compression.py).

These are opt-in (launch/train.py ``--overlap tp_ring``); the dry-run
baselines use plain GSPMD so §Perf can compare the two schedules.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array


def collective_matmul_ag(x_shard: Array, w_shard: Array,
                         axis_name: str) -> Array:
    """(x all-gathered over axis) @ w, overlapped via a ppermute ring.

    x_shard: (m/k, d) this device's sequence shard (k = axis size).
    w_shard: (d, f/k) this device's column shard.
    Returns (m, f/k): the full-sequence activation for the local columns.

    Ring schedule: at step t we matmul the shard that originated t hops
    away while simultaneously permuting the buffer to the next neighbor —
    XLA's latency-hiding scheduler overlaps the two because there is no
    data dependence between ppermute(t) and matmul(t).
    """
    k = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % k) for i in range(k)]
    m_loc = x_shard.shape[0]
    out = jnp.zeros((k * m_loc, w_shard.shape[1]), x_shard.dtype)
    out = jax.lax.pvary(out, (axis_name,))  # carry is device-varying

    def body(t, carry):
        buf, out = carry
        # which device's shard is currently in `buf`
        src = (idx - t) % k
        piece = buf @ w_shard
        out = jax.lax.dynamic_update_slice(out, piece,
                                           (src * m_loc, 0))
        buf = jax.lax.ppermute(buf, axis_name, perm)
        return buf, out

    buf, out = jax.lax.fori_loop(0, k, body, (x_shard, out))
    return out


def psum_scatter_matmul(x_full: Array, w_shard: Array,
                        axis_name: str) -> Array:
    """Row-parallel matmul with ring reduce-scatter of the output.

    x_full: (m, d/k) local columns of the activations.
    w_shard: (d/k, f) this device's row shard.
    Returns (m/k, f): this device's scatter shard of x @ w (summed).
    """
    k = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    partial = x_full @ w_shard  # (m, f) partial sum (needs cross-device +)
    m_loc = partial.shape[0] // k
    # downward ring: device i+1 -> i; the accumulator visiting device i at
    # step t carries chunk (i + t + 1) mod k, so after k-1 hops device i
    # holds the fully-summed chunk i.
    perm = [(i, (i - 1) % k) for i in range(k)]

    def chunk(j):
        return jax.lax.dynamic_slice(
            partial, (j * m_loc, 0), (m_loc, partial.shape[1]))

    def body(t, acc):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        return acc + chunk((idx + t + 1) % k)

    acc = jax.lax.fori_loop(1, k, body, chunk((idx + 1) % k))
    return acc


def dp_psum_compressed(grads, residuals, axis_name: str):
    from repro.runtime.compression import compressed_psum
    return compressed_psum(grads, residuals, axis_name)
