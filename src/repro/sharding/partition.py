"""Partition specs for every architecture family (DP / TP / EP / SP).

Axis semantics on the production mesh (launch/mesh.py):
  ("pod", "data")  — data parallelism (gradient all-reduce spans pods)
  "model"          — Megatron-style tensor parallelism + expert parallelism

Rules (applied only when the dimension divides the mesh axis — for
non-dividing dims, e.g. llama's 24 heads on model=16, the *flat* fused dim
is sharded instead when it divides; otherwise the leaf is replicated and
GSPMD inserts the reshard):

  embed (V, D)                 -> (model, None)      vocab-parallel
  head  (D, V)                 -> (None, model)
  attn wq/wk/wv (D, H*hd)      -> (None, model)      column-parallel
  attn wo (H*hd, D)            -> (model, None)      row-parallel (psum)
  mlp wi* (D, F) / wo (F, D)   -> (None, model) / (model, None)
  MoE expert stacks (E, ., .)  -> (model, None, None) expert-parallel
  MLA b-projections            -> column-parallel on the head dim
  RWKV projections             -> column/row like attention
  RG-LRU w_gate/w_in/w_a/w_x   -> column-parallel on the LRU width
  norms / biases / tiny LoRAs  -> replicated

Batches shard the global batch over ("pod","data"); when global_batch is
not divisible (long_500k, batch=1) the *sequence* dimension is sharded
over "data" instead (context/sequence parallelism).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

Array = jax.Array


def mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _div(mesh: Mesh, axis, size: int):
    """axis if size divides the axis extent, else None (replicate)."""
    return axis if size % mesh_axis_size(mesh, axis) == 0 else None


def _col(mesh, in_dim, out_dim):
    return P(None, _div(mesh, "model", out_dim))


def _row(mesh, in_dim, out_dim):
    return P(_div(mesh, "model", in_dim), None)


FSDP_MIN_ELEMENTS = 1 << 20  # leaves below this stay DP-replicated


def _apply_fsdp(spec: P, shape, mesh: Mesh, *, skip_dims=(0,)) -> P:
    """ZeRO/FSDP: shard the largest still-replicated dim over "data".

    Parameters + optimizer moments then scale with the full mesh instead
    of only the TP axis (deepseek-236B: 150 GiB/dev -> ~9 GiB/dev). GSPMD
    inserts the per-layer all-gather (classic FSDP schedule). The leading
    stacked-layer dim is never sharded (it is scanned over)."""
    n = 1
    for d in shape:
        n *= d
    if n < FSDP_MIN_ELEMENTS:
        return spec
    used = {a for part in spec if part is not None
            for a in (part if isinstance(part, tuple) else (part,))}
    if "data" in used:
        return spec
    dsize = mesh_axis_size(mesh, "data")
    cands = [i for i in range(len(shape))
             if spec[i] is None and i not in skip_dims
             and shape[i] % dsize == 0]
    if not cands:
        return spec
    best = max(cands, key=lambda i: shape[i])
    parts = list(spec) + [None] * (len(shape) - len(spec))
    parts[best] = "data"
    return P(*parts)


def param_specs(cfg: ModelConfig, mesh: Mesh, params: Any,
                *, fsdp: bool = True) -> Any:
    """PartitionSpec tree mirroring the params tree (works on abstract)."""

    def leaf_spec(path, leaf) -> P:
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        joined = "/".join(names)
        shape = leaf.shape
        # stacked layers add a leading L axis; compute the "local" shape
        stacked = any(n in ("layers", "periods", "dense_layers",
                            "enc_layers", "dec_layers") for n in names)
        ls = shape[1:] if stacked else shape
        pad = (None,) if stacked else ()

        def mk(*spec):
            return P(*(pad + spec))

        if name == "embed":
            return P(_div(mesh, "model", shape[0]), None)
        if name == "head":
            return P(None, _div(mesh, "model", shape[1]))
        # --- MoE expert stacks -------------------------------------------------
        # Experts shard over "data" (EP inside the DP group, DeepSeek
        # deployment style) and the CONTRACTING dim over "model" (TP), so
        # expert weights are fully sharded in place — no FSDP re-gather
        # per scan step (that cost 100+ GiB/step on the 236B cells).
        if "mlp" in names and name in ("wi_gate", "wi_up") \
                and len(ls) == 3:
            return mk(_div(mesh, "data", ls[0]),
                      _div(mesh, "model", ls[1]), None)
        if "mlp" in names and name == "wo" and len(ls) == 3:
            return mk(_div(mesh, "data", ls[0]), None,
                      _div(mesh, "model", ls[2]))
        if name == "router":
            return mk(None, None)
        # --- column/row parallel projections --------------------------------
        col_names = {"wq", "wk", "wv", "wi", "wi_gate", "wi_up", "wq_b",
                     "wk_b", "wv_b", "w_gate", "w_in", "w_a", "w_x",
                     "wd_a"}
        row_names = {"wo", "w_out", "wv_cmix"}
        if name in col_names and len(ls) == 2:
            return mk(None, _div(mesh, "model", ls[1]))
        if name in row_names and len(ls) == 2:
            return mk(_div(mesh, "model", ls[0]), None)
        if "cmix" in names and name == "wv" and len(ls) == 2:
            return mk(_div(mesh, "model", ls[0]), None)
        if name == "conv_w":
            return mk(None, _div(mesh, "model", ls[1]))
        if name in ("conv_b", "lam"):
            return mk(_div(mesh, "model", ls[0]))
        if name == "u" and len(ls) == 2:  # rwkv bonus (h, hk)
            return mk(_div(mesh, "model", ls[0]), None)
        if name in ("gn_w", "gn_b", "w0"):
            return mk(_div(mesh, "model", ls[0]))
        # everything else (norms, biases, LoRA factors, mu's): replicated
        return mk(*([None] * len(ls)))

    def leaf_spec_fsdp(path, leaf) -> P:
        spec = leaf_spec(path, leaf)
        if not fsdp:
            return spec
        names = [p.key for p in path if hasattr(p, "key")]
        stacked = any(n in ("layers", "periods", "dense_layers",
                            "enc_layers", "dec_layers") for n in names)
        parts = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
        return _apply_fsdp(P(*parts), leaf.shape, mesh,
                           skip_dims=(0,) if stacked else ())

    return jax.tree_util.tree_map_with_path(leaf_spec_fsdp, params)


# ---------------------------------------------------------------------------
# batch + decode-state specs
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: dict,
                *, seq_shard: bool | None = None) -> dict:
    """Specs for a train/prefill batch dict of ShapeDtypeStructs.

    seq_shard: shard the sequence dim over "data" when the batch dim
    does not divide DP (long-context, batch=1). Auto-detected if None.
    """
    dp = dp_axes(mesh)
    dp_size = mesh_axis_size(mesh, dp)
    b = batch["tokens"].shape[0]
    if seq_shard is None:
        seq_shard = (b % dp_size) != 0
    bspec = None if seq_shard else dp
    sspec = ("data" if seq_shard else None)

    def spec_of(key, leaf):
        nd = len(leaf.shape)
        if key == "positions_3d":  # (3, b, s)
            return P(None, bspec, sspec)
        if key in ("tokens", "labels", "loss_mask"):  # (b, s)
            s = leaf.shape[1] if nd > 1 else None
            if nd == 1:
                return P(bspec)
            return P(bspec, sspec if _div(mesh, "data", s) else None)
        if key == "frames":  # (b, F, d)
            return P(bspec, sspec, None)
        if key == "vision_embeds":  # (b, nv, d)
            return P(bspec, None, None)
        if key == "position":  # (b,)
            return P(bspec)
        raise ValueError(f"no batch spec rule for {key}")

    return {k: spec_of(k, v) if k != "state" else
            decode_state_specs(cfg, mesh, v) for k, v in batch.items()}


def decode_state_specs(cfg: ModelConfig, mesh: Mesh, state: Any) -> Any:
    """Mirror the decode-state tree with specs.

    Convention: leaves are either stacked (L, b, ...) or per-layer
    (b, ...); the batch dim is sharded over DP when divisible, KV heads /
    RWKV heads / LRU width over "model" when divisible.
    """
    dp = dp_axes(mesh)
    dp_size = mesh_axis_size(mesh, dp)

    def leaf_spec(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        joined = "/".join(names)
        shape = leaf.shape
        # find the batch dim: first dim not equal to a leading stack axis
        # heuristic: stacked leaves have ndim >= 3 and dim1 == batch
        # encode rule by leaf name instead:
        name = names[-1] if names else ""
        stacked = len(shape) >= 2
        # KVCache: k/v (L, b, hkv, S, hd) or (b, hkv, S, hd); pos (L, b, S)
        msize = mesh_axis_size(mesh, "model")

        def bspec_at(i, model_dim=None, seq_dim=None):
            """Shard batch at i over DP; model_dim over TP when it
            divides, else seq_dim over TP (sequence-sharded KV cache —
            the GQA archs here have kv_heads < 16)."""
            spec = [None] * len(shape)
            if shape[i] % dp_size == 0:
                spec[i] = dp
            if model_dim is not None and shape[model_dim] % msize == 0:
                spec[model_dim] = "model"
            elif seq_dim is not None and shape[seq_dim] % msize == 0:
                spec[seq_dim] = "model"
            return P(*spec)

        if name in ("k", "v"):
            return bspec_at(len(shape) - 4, model_dim=len(shape) - 3,
                            seq_dim=len(shape) - 2)
        if name == "pos":
            return bspec_at(len(shape) - 2)
        if name in ("c_kv", "k_rope"):  # MLA (L, b, S, r)
            return bspec_at(len(shape) - 3, seq_dim=len(shape) - 2)
        if name == "s":  # rwkv state (L, b, h, K, V)
            return bspec_at(len(shape) - 4, model_dim=len(shape) - 3)
        if name in ("shift_t", "shift_c"):  # (L, b, d)
            return bspec_at(len(shape) - 2, model_dim=len(shape) - 1)
        if name == "h":  # rg-lru hidden (L?, b, w)
            return bspec_at(len(shape) - 2, model_dim=len(shape) - 1)
        if name == "conv":  # (L?, b, cw-1, w)
            return bspec_at(len(shape) - 3, model_dim=len(shape) - 1)
        if name in ("cross_k", "cross_v"):  # (L, b, hkv, F, hd)
            return bspec_at(len(shape) - 4, model_dim=len(shape) - 3)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf_spec, state)


def named(mesh: Mesh, tree_specs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_spec_tree: Any, mesh: Mesh) -> Any:
    """Adam state mirrors params (mu/nu same layout; step replicated)."""
    from repro.optim.adam import AdamState
    return AdamState(step=P(), mu=param_spec_tree, nu=param_spec_tree)
