"""Stationary kernel profiles (paper §4, Rasmussen & Williams 2005).

A *profile* is the radial function ``k(tau)`` of a stationary kernel
``K(x, x') = outputscale * k(||x - x'||)`` evaluated on lengthscale-normalized
inputs.  Simplex-GP (paper §4.1) discretizes the profile onto the lattice, and
the gradient trick (paper §4.2, Eq. 11-13) additionally needs ``k'``, the
derivative of the kernel *with respect to the squared distance*.

Profiles are expressed as plain functions of ``tau`` (distance, not squared)
so the same object serves the stencil builder (which samples ``k(i * s)``),
the dense oracles, and the exact-MVM Pallas kernel.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

SQRT3 = math.sqrt(3.0)
SQRT5 = math.sqrt(5.0)


@dataclasses.dataclass(frozen=True)
class KernelProfile:
    """A stationary kernel's radial profile and its squared-distance derivative.

    Attributes:
      name: identifier used by configs / benchmarks.
      k: ``tau -> k(tau)`` with ``k(0) == 1`` (unit outputscale).
      dk_dsq: ``tau -> dk/d(tau^2)`` — the ``k'`` of paper Eq. 11. Defined as a
        function of ``tau`` (not ``tau^2``) because both the stencil builder
        and the dense oracle naturally have ``tau`` in hand.
    """

    name: str
    k: Callable[[Array], Array]
    dk_dsq: Callable[[Array], Array]

    def __call__(self, tau: Array) -> Array:
        return self.k(tau)


def _rbf(tau: Array) -> Array:
    return jnp.exp(-0.5 * tau * tau)


def _rbf_dsq(tau: Array) -> Array:
    # k(t2) = exp(-t2/2)  =>  dk/dt2 = -1/2 exp(-t2/2)
    return -0.5 * jnp.exp(-0.5 * tau * tau)


def _matern12(tau: Array) -> Array:
    return jnp.exp(-jnp.abs(tau))


def _matern12_dsq(tau: Array) -> Array:
    # k = exp(-sqrt(t2)); dk/dt2 = -exp(-tau)/(2 tau); singular at 0 — clamp.
    safe = jnp.maximum(jnp.abs(tau), 1e-12)
    return -jnp.exp(-safe) / (2.0 * safe)


def _matern32(tau: Array) -> Array:
    a = SQRT3 * jnp.abs(tau)
    return (1.0 + a) * jnp.exp(-a)


def _matern32_dsq(tau: Array) -> Array:
    # k = (1 + a) e^{-a}, a = sqrt(3) tau. dk/dt2 = dk/da * da/dt2
    # dk/da = -a e^{-a};  da/dt2 = sqrt(3)/(2 tau)  =>  dk/dt2 = -3/2 e^{-a}
    a = SQRT3 * jnp.abs(tau)
    return -1.5 * jnp.exp(-a)


def _matern52(tau: Array) -> Array:
    a = SQRT5 * jnp.abs(tau)
    return (1.0 + a + a * a / 3.0) * jnp.exp(-a)


def _matern52_dsq(tau: Array) -> Array:
    # k(a) = (1 + a + a^2/3) e^{-a}; dk/da = -(a + a^2) e^{-a} / ... compute:
    # dk/da = (1 + 2a/3) e^{-a} - (1 + a + a^2/3) e^{-a} = -(a/3)(1 + a) e^{-a}
    # dk/dt2 = dk/da * sqrt(5)/(2 tau) = -(5/6)(1 + a) e^{-a}
    a = SQRT5 * jnp.abs(tau)
    return -(5.0 / 6.0) * (1.0 + a) * jnp.exp(-a)


RBF = KernelProfile("rbf", _rbf, _rbf_dsq)
MATERN12 = KernelProfile("matern12", _matern12, _matern12_dsq)
MATERN32 = KernelProfile("matern32", _matern32, _matern32_dsq)
MATERN52 = KernelProfile("matern52", _matern52, _matern52_dsq)

PROFILES: dict[str, KernelProfile] = {
    p.name: p for p in (RBF, MATERN12, MATERN32, MATERN52)
}


def get_profile(name: str) -> KernelProfile:
    try:
        return PROFILES[name]
    except KeyError:
        raise ValueError(f"unknown kernel profile {name!r}; have {sorted(PROFILES)}")


# ---------------------------------------------------------------------------
# Dense oracles. These are the ground truth every approximation in this
# repository (lattice filter, SKI grid, SKIP, Pallas exact_mvm) is tested
# against. O(n^2 d) — small-n only.
# ---------------------------------------------------------------------------


def pairwise_sqdist(x1: Array, x2: Array) -> Array:
    """Squared Euclidean distances, (n1, d) x (n2, d) -> (n1, n2)."""
    n1 = jnp.sum(x1 * x1, axis=-1)[:, None]
    n2 = jnp.sum(x2 * x2, axis=-1)[None, :]
    sq = n1 + n2 - 2.0 * (x1 @ x2.T)
    return jnp.maximum(sq, 0.0)


def gram(profile: KernelProfile, x1: Array, x2: Array,
         lengthscale: Array | float = 1.0,
         outputscale: Array | float = 1.0) -> Array:
    """Dense kernel matrix with ARD lengthscales (oracle)."""
    ls = jnp.asarray(lengthscale)
    z1 = x1 / ls
    z2 = x2 / ls
    tau = jnp.sqrt(pairwise_sqdist(z1, z2) + 1e-30)
    return outputscale * profile.k(tau)


def dense_mvm(profile: KernelProfile, x: Array, v: Array,
              lengthscale: Array | float = 1.0,
              outputscale: Array | float = 1.0) -> Array:
    """Oracle MVM ``v -> K v`` (paper Eq. 1/10)."""
    return gram(profile, x, x, lengthscale, outputscale) @ v


def dense_grad_x(profile: KernelProfile, x: Array, v: Array, g: Array,
                 lengthscale: Array | float = 1.0) -> Array:
    """Oracle for the paper's Eq. 11: d/dx_n of L where dL/du = g, u = K v.

    Computed directly from the analytic identity (not autodiff) so that the
    lattice implementation of Eq. 12/13 has an exact target modulo the
    filtering approximation.
    """
    ls = jnp.asarray(lengthscale)
    z = x / ls
    tau = jnp.sqrt(pairwise_sqdist(z, z) + 1e-30)
    kp = profile.dk_dsq(tau)  # (n, n)
    gv = g @ v.T  # (n, n): sum_c g_ic v_jc
    m = kp * gv
    sym = m + m.T
    # dL/dz_n = 2 sum_j sym_nj (z_n - z_j)  [Eq. 11 collapsed]
    row = jnp.sum(sym, axis=1, keepdims=True)
    dz = 2.0 * (z * row - sym @ z)
    return dz / ls  # chain back to x
