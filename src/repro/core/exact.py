"""Exact GP baseline (paper Table 2 "Exact GP" column; §5.1's KeOps role).

Two pieces:
  * ``chunked_mvm`` — a memory-nimble exact MVM that never materializes
    K_XX (rows are produced block-by-block inside a ``lax.map``). This is
    the same role KeOps plays in the paper: O(n^2 d) compute, O(n b) memory.
    The Pallas version (kernels/exact_mvm) is the TPU-tiled equivalent; this
    is its pure-jnp reference and the CPU fallback.
  * ``ExactGP`` — Cholesky-based exact inference for small n (tests, and the
    Table 2 exact column via subsampling, like Wang et al. 2019 report).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kernels_math as km
from repro.core.kernels_math import KernelProfile

Array = jax.Array


def chunked_mvm(profile: KernelProfile, x: Array, v: Array, *,
                lengthscale: Array | float = 1.0,
                outputscale: Array | float = 1.0,
                block: int = 1024) -> Array:
    """v -> K_XX v without materializing K (KeOps-analogue)."""
    n, d = x.shape
    ls = jnp.asarray(lengthscale)
    z = x / ls
    pad = (-n) % block
    zp = jnp.pad(z, ((0, pad), (0, 0)))
    blocks = zp.reshape(-1, block, d)

    def one_block(zb):
        tau = jnp.sqrt(km.pairwise_sqdist(zb, z) + 1e-30)
        return profile.k(tau) @ v  # (block, c)

    out = jax.lax.map(one_block, blocks).reshape(-1, v.shape[1])[:n]
    return outputscale * out


class ExactPosterior(NamedTuple):
    mean: Array
    var: Array


@dataclasses.dataclass(frozen=True)
class ExactGP:
    """Small-n Cholesky GP — the oracle for every approximation here."""

    profile: KernelProfile

    def _khat(self, x, lengthscale, outputscale, noise):
        k = km.gram(self.profile, x, x, lengthscale, outputscale)
        return k + (noise + 1e-6) * jnp.eye(x.shape[0], dtype=x.dtype)

    def mll(self, x: Array, y: Array, *, lengthscale, outputscale,
            noise) -> Array:
        n = x.shape[0]
        khat = self._khat(x, lengthscale, outputscale, noise)
        chol = jnp.linalg.cholesky(khat)
        alpha = jax.scipy.linalg.cho_solve((chol, True), y[:, None])[:, 0]
        logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
        return (-0.5 * jnp.dot(y, alpha) - 0.5 * logdet
                - 0.5 * n * jnp.log(2.0 * jnp.pi))

    def posterior(self, x: Array, y: Array, xs: Array, *, lengthscale,
                  outputscale, noise) -> ExactPosterior:
        khat = self._khat(x, lengthscale, outputscale, noise)
        chol = jnp.linalg.cholesky(khat)
        kxs = km.gram(self.profile, x, xs, lengthscale, outputscale)
        alpha = jax.scipy.linalg.cho_solve((chol, True), y[:, None])[:, 0]
        mean = kxs.T @ alpha
        vs = jax.scipy.linalg.solve_triangular(chol, kxs, lower=True)
        var = outputscale - jnp.sum(vs * vs, axis=0)
        return ExactPosterior(mean=mean, var=jnp.maximum(var, 1e-8))
