"""Discretizing generic stationary kernels onto the lattice (paper §4.1).

Given a kernel profile ``k(tau)`` and a stencil order ``r`` (m = 2r+1 taps),
the free parameter is the tap spacing ``s``. The paper's criterion (Eq. 9):
pick ``s`` so that the fraction of the kernel's mass covered in the spatial
domain, ``int_{-sm/2}^{sm/2} k / int k``, equals the fraction of its spectrum
inside the Nyquist band, ``int_{-pi/s}^{pi/s} F[k] / int F[k]``. The LHS is
monotonically increasing in ``s`` and the RHS monotonically decreasing, so
the crossing is found by bisection. Like the paper we use the discrete FFT
and numerical integration rather than analytic transforms, so any new
profile works unmodified.

This is a tiny host-side precompute (the stencil does NOT depend on the
lengthscale — normalization happens by scaling the inputs), so it runs in
float64 numpy and is cached per (profile, r).
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import numpy as np

from repro.core.kernels_math import KernelProfile

# Sampling setup for the numerical transforms. T must cover the slowest
# tail we support (Matern-1/2 ~ e^-tau: 1e-16 mass beyond tau=40).
_T = 64.0
_N = 1 << 17


@dataclasses.dataclass(frozen=True)
class Stencil:
    """Discretized blur for one stationary kernel at one order r.

    The blur composes the 1-D stencil multiplicatively across the d+1
    lattice directions, so any stencil must be normalized like a kernel
    (center tap == 1) with scalar amplitude carried OUTSIDE the filter.
    For the §4.2 derivative kernel k' (center k'(0) != 1) we therefore store
    the normalized profile ``dweights = k'(|i|s)/k'(0)`` plus ``dscale =
    k'(0)``; the backward pass multiplies the filter output by ``dscale``.
    (For RBF, k' = -0.5 k, so dweights == weights and dscale == -0.5 — the
    derivative filter is exactly -0.5 x the forward filter.)
    """

    name: str
    r: int
    spacing: float  # s*, the Eq. 9 crossing
    weights: tuple[float, ...]  # (2r+1,) k(|i| s), center == k(0) == 1
    dweights: tuple[float, ...]  # (2r+1,) k'(|i| s) / k'(0), center == 1
    dscale: float  # k'(0), the amplitude of the derivative kernel

    @property
    def order(self) -> int:
        return self.r


def _coverage_curves(profile: KernelProfile, r: int):
    """Precompute LHS(s) and RHS(s) of Eq. 9 on a dense grid of tau/omega."""
    tau = np.linspace(0.0, _T, _N, dtype=np.float64)
    with jax.ensure_compile_time_eval():  # host-side even if called under jit
        k = np.asarray(profile.k(tau), dtype=np.float64)
    dtau = tau[1] - tau[0]

    # spatial cumulative mass: C_k(t) = int_0^t k  (k even => symmetric)
    ck = np.concatenate([[0.0], np.cumsum((k[1:] + k[:-1]) * 0.5 * dtau)])
    ck_total = ck[-1]

    # spectrum via DFT of the even extension; real and (numerically) >= 0.
    full = np.concatenate([k, k[-2:0:-1]])  # even periodic extension
    spec = np.fft.rfft(full).real * dtau
    freqs = np.fft.rfftfreq(full.size, d=dtau)  # cycles / tau
    omega = 2.0 * math.pi * freqs
    spec = np.maximum(spec, 0.0)
    domega = omega[1] - omega[0]
    cs = np.concatenate([[0.0], np.cumsum((spec[1:] + spec[:-1]) * 0.5 * domega)])
    cs_total = cs[-1]

    def lhs(s: float) -> float:
        t = min(s * (2 * r + 1) / 2.0, _T)
        return float(np.interp(t, tau, ck) / ck_total)

    def rhs(s: float) -> float:
        w = min(math.pi / s, omega[-1])
        return float(np.interp(w, omega, cs) / cs_total)

    return lhs, rhs


def solve_spacing(profile: KernelProfile, r: int, *, tol: float = 1e-9) -> float:
    """Bisection for the Eq. 9 balance point s*."""
    lhs, rhs = _coverage_curves(profile, r)
    lo, hi = 1e-4, _T / max(r, 1)
    flo = lhs(lo) - rhs(lo)
    fhi = lhs(hi) - rhs(hi)
    if flo > 0 or fhi < 0:  # pragma: no cover - defensive
        raise RuntimeError(
            f"coverage criterion not bracketed for {profile.name} r={r}: "
            f"f(lo)={flo:.3g} f(hi)={fhi:.3g}")
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if lhs(mid) - rhs(mid) < 0:
            lo = mid
        else:
            hi = mid
        if hi - lo < tol:
            break
    return 0.5 * (lo + hi)


@functools.lru_cache(maxsize=None)
def _make_stencil_cached(profile_name: str, r: int) -> Stencil:
    from repro.core.kernels_math import get_profile

    profile = get_profile(profile_name)
    s = solve_spacing(profile, r)
    taps = np.arange(-r, r + 1, dtype=np.float64)
    tau = np.abs(taps) * s
    with jax.ensure_compile_time_eval():
        w = np.asarray(profile.k(tau), dtype=np.float64)
        dw = np.asarray(profile.dk_dsq(tau), dtype=np.float64)
        dscale = float(profile.dk_dsq(np.zeros(())))
    if (not np.all(np.isfinite(dw)) or not np.isfinite(dscale)
            or dscale == 0 or abs(dscale) > 1e6):  # cusp at 0 (Matern-1/2)
        # e.g. Matern-1/2 has a cusp at 0; its squared-distance derivative is
        # singular there. Input-space gradients are then unavailable; the
        # paper's kernel family {RBF, Matern-3/2} is unaffected.
        dw = np.zeros_like(dw)
        dscale = 0.0
    else:
        dw = dw / dscale  # normalize center tap to 1 (see class docstring)
    return Stencil(name=profile_name, r=r, spacing=float(s),
                   weights=tuple(float(x) for x in w),
                   dweights=tuple(float(x) for x in dw),
                   dscale=dscale)


def make_stencil(profile: KernelProfile | str, r: int = 1) -> Stencil:
    name = profile if isinstance(profile, str) else profile.name
    return _make_stencil_cached(name, r)
