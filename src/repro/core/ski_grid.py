"""KISS-GP: dense rectilinear-grid SKI baseline (Wilson & Nickisch 2015).

This is the method the paper generalizes (§2.1). Inducing points lie on a
cubic grid; interpolation is 4-point cubic convolution (Keys) per dimension,
so each input touches 4^d grid points — the 2^d-neighbor exponential blowup
(Fig. 1) that Simplex-GP removes. K_UU has Kronecker structure over the
grid axes (valid for kernels that factor across dimensions, e.g. RBF; for
Matern we use the per-dimension *product* form, as standard for
Kronecker-SKI).

Usable only for small d (the paper's point); tests compare it against the
dense oracle and against Simplex-GP on d <= 4.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.kernels_math import KernelProfile

Array = jax.Array


def cubic_weights(u: Array) -> Array:
    """Keys cubic-convolution weights (a = -1/2) for offsets [-1,0,1,2].

    u: (...,) fractional position in [0, 1). Returns (..., 4); rows sum to 1.
    """
    u2 = u * u
    u3 = u2 * u
    w0 = 0.5 * (-u3 + 2.0 * u2 - u)
    w1 = 0.5 * (3.0 * u3 - 5.0 * u2 + 2.0)
    w2 = 0.5 * (-3.0 * u3 + 4.0 * u2 + u)
    w3 = 0.5 * (u3 - u2)
    return jnp.stack([w0, w1, w2, w3], axis=-1)


@dataclasses.dataclass(frozen=True)
class Grid:
    lo: Array  # (d,)
    h: Array  # (d,) spacing
    sizes: tuple[int, ...]  # static per-dim grid sizes

    @property
    def total(self) -> int:
        out = 1
        for g in self.sizes:
            out *= g
        return out


def make_grid(x: Array, sizes: Sequence[int], margin: float = 0.1) -> Grid:
    lo = jnp.min(x, axis=0)
    hi = jnp.max(x, axis=0)
    span = jnp.maximum(hi - lo, 1e-6)
    lo = lo - margin * span
    hi = hi + margin * span
    sizes = tuple(int(g) for g in sizes)
    h = (hi - lo) / (jnp.asarray([g - 1 for g in sizes], x.dtype))
    return Grid(lo=lo, h=h, sizes=sizes)


def interp_indices_weights(grid: Grid, x: Array) -> tuple[Array, Array]:
    """Cubic interpolation onto the grid.

    Returns:
      idx: (n, 4**d) int32 raveled grid indices.
      w:   (n, 4**d) float weights (rows sum to 1).
    """
    n, d = x.shape
    t = (x - grid.lo[None]) / grid.h[None]  # grid coords
    sizes = jnp.asarray(grid.sizes)
    # keep the 4-point stencil in range: base in [1, g-3]
    base = jnp.clip(jnp.floor(t).astype(jnp.int32), 1, sizes[None] - 3)
    u = t - base.astype(x.dtype)
    w4 = cubic_weights(u)  # (n, d, 4)
    offs = jnp.arange(-1, 3, dtype=jnp.int32)  # (4,)
    idx4 = base[:, :, None] + offs[None, None, :]  # (n, d, 4)

    combos = list(itertools.product(range(4), repeat=d))  # 4^d static
    combo_arr = jnp.asarray(combos, jnp.int32)  # (4^d, d)
    # gather per-dim picks: (n, 4^d, d)
    picked_idx = jnp.take_along_axis(
        idx4[:, None, :, :].repeat(len(combos), axis=1),
        combo_arr[None, :, :, None], axis=3)[..., 0]
    picked_w = jnp.take_along_axis(
        w4[:, None, :, :].repeat(len(combos), axis=1),
        combo_arr[None, :, :, None], axis=3)[..., 0]
    w = jnp.prod(picked_w, axis=2)  # (n, 4^d)
    # ravel multi-index
    strides = []
    s = 1
    for g in reversed(grid.sizes):
        strides.append(s)
        s *= g
    strides = jnp.asarray(list(reversed(strides)), jnp.int32)  # (d,)
    idx = jnp.sum(picked_idx * strides[None, None, :], axis=2)
    return idx, w.astype(x.dtype)


def kron_factors(profile: KernelProfile, grid: Grid,
                 dtype=jnp.float32) -> list[Array]:
    """Per-dimension dense (g, g) kernel matrices (inputs pre-normalized)."""
    mats = []
    for a, g in enumerate(grid.sizes):
        pts = grid.lo[a] + grid.h[a] * jnp.arange(g, dtype=dtype)
        tau = jnp.abs(pts[:, None] - pts[None, :])
        mats.append(profile.k(tau).astype(dtype))
    return mats


def kron_matvec(factors: list[Array], v: Array) -> Array:
    """(K_1 kron ... kron K_d) v for v of length prod(g_i), batched cols.

    v: (m, c). Sequentially contracts each axis: O(sum_i g_i * m) per col.
    """
    sizes = [f.shape[0] for f in factors]
    c = v.shape[1]
    t = v.reshape(*sizes, c)
    for a, f in enumerate(factors):
        t = jnp.moveaxis(jnp.tensordot(f, t, axes=([1], [a])), 0, a)
    return t.reshape(-1, c)


@dataclasses.dataclass(frozen=True)
class KissGPOperator:
    """W K_UU W^T as an MVM closure — KISS-GP's SKI decomposition."""

    idx: Array  # (n, 4^d)
    w: Array  # (n, 4^d)
    factors: tuple[Array, ...]
    total: int

    def mvm(self, v: Array) -> Array:
        n, q = self.idx.shape
        c = v.shape[1]
        contrib = (self.w[:, :, None] * v[:, None, :]).reshape(n * q, c)
        splat = jax.ops.segment_sum(contrib, self.idx.reshape(-1),
                                    num_segments=self.total)
        blurred = kron_matvec(list(self.factors), splat)
        gathered = blurred[self.idx.reshape(-1)].reshape(n, q, c)
        return jnp.einsum("nqc,nq->nc", gathered, self.w)


def kiss_gp_operator(profile: KernelProfile, x: Array,
                     grid_size: int | Sequence[int]) -> KissGPOperator:
    """Build the KISS-GP operator for lengthscale-normalized inputs x."""
    n, d = x.shape
    sizes = [grid_size] * d if isinstance(grid_size, int) else list(grid_size)
    grid = make_grid(x, sizes)
    idx, w = interp_indices_weights(grid, x)
    factors = tuple(kron_factors(profile, grid, x.dtype))
    return KissGPOperator(idx=idx, w=w, factors=factors, total=grid.total)
