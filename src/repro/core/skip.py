"""SKIP: product-kernel low-rank SKI baseline (Gardner et al. 2018b).

The paper's main scalable-SKI competitor (Tables 1-2). SKIP writes a
product kernel K = K^(1) o K^(2) o ... o K^(d) (Hadamard across dimensions),
approximates each 1-D factor by 1-D SKI (W_j K_j W_j^T), root-decomposes
each factor to rank r, and merges factors pairwise in a binary tree,
re-compressing to rank r after every Hadamard product.

Root algebra used below: if A = R_A R_A^T and B = R_B R_B^T then
A o B = R R^T with R = row-wise Khatri-Rao of (R_A, R_B) — rank r^2 —
which we re-compress to rank r by the exact top-r eigenbasis of R^T R
(optimal in Frobenius norm; deterministic, unlike the randomized Lanczos
of the reference implementation, and cheap since r^2 x r^2 Grams are tiny).

The final operator is K ~= R R^T with R (n, r): MVMs cost O(n r) — the
paper's Table 1 "O(r n d)" counts the tree build. Memory is the paper's
criticism: the tree holds O(log d) roots of size (n, r^2) transiently —
this is exactly the "~20*d copies of the dataset" scaling quoted in §1.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.kernels_math import KernelProfile
from repro.core.ski_grid import (cubic_weights, kron_matvec, make_grid)

Array = jax.Array


def _ski_1d_root(profile: KernelProfile, x1: Array, grid_size: int,
                 rank: int) -> Array:
    """Rank-r root of the 1-D SKI factor W K W^T for one dimension.

    x1: (n,) one coordinate of the (normalized) inputs. Returns (n, r).
    """
    n = x1.shape[0]
    grid = make_grid(x1[:, None], [grid_size])
    pts = grid.lo[0] + grid.h[0] * jnp.arange(grid_size, dtype=x1.dtype)
    tau = jnp.abs(pts[:, None] - pts[None, :])
    k = profile.k(tau)
    evals, evecs = jnp.linalg.eigh(k)  # ascending
    top = jnp.sqrt(jnp.maximum(evals[-rank:], 0.0))
    root_u = evecs[:, -rank:] * top[None, :]  # (g, r)

    # cubic interpolation of the 1-D grid root to the inputs
    t = (x1 - grid.lo[0]) / grid.h[0]
    base = jnp.clip(jnp.floor(t).astype(jnp.int32), 1, grid_size - 3)
    u = t - base.astype(x1.dtype)
    w4 = cubic_weights(u)  # (n, 4)
    idx4 = base[:, None] + jnp.arange(-1, 3, dtype=jnp.int32)[None, :]
    gathered = root_u[idx4]  # (n, 4, r)
    return jnp.einsum("nqr,nq->nr", gathered, w4)


def _hadamard_merge(ra: Array, rb: Array, rank: int) -> Array:
    """Root of (R_A R_A^T) o (R_B R_B^T), re-compressed to `rank` columns."""
    n, a = ra.shape
    b = rb.shape[1]
    big = (ra[:, :, None] * rb[:, None, :]).reshape(n, a * b)
    if a * b <= rank:
        return big
    gram = big.T @ big  # (ab, ab)
    evals, evecs = jnp.linalg.eigh(gram)
    basis = evecs[:, -rank:]  # top-r column basis of big
    return big @ basis


@dataclasses.dataclass(frozen=True)
class SkipOperator:
    """K ~= R R^T (+ explicit diagonal correction option)."""

    root: Array  # (n, r)

    def mvm(self, v: Array) -> Array:
        return self.root @ (self.root.T @ v)

    def diag(self) -> Array:
        return jnp.sum(self.root * self.root, axis=1)


def skip_operator(profile: KernelProfile, x: Array, *, grid_size: int = 100,
                  rank: int = 32) -> SkipOperator:
    """Build the SKIP root by pairwise tree merging over dimensions.

    x: (n, d) lengthscale-normalized inputs.
    """
    n, d = x.shape
    roots = [_ski_1d_root(profile, x[:, j], grid_size, rank)
             for j in range(d)]
    while len(roots) > 1:
        merged = []
        for i in range(0, len(roots) - 1, 2):
            merged.append(_hadamard_merge(roots[i], roots[i + 1], rank))
        if len(roots) % 2 == 1:
            merged.append(roots[-1])
        roots = merged
    return SkipOperator(root=roots[0])
