"""Permutohedral lattice (paper §3.2), TPU-native static-shape formulation.

The reference CUDA implementation (Adams et al. 2010) builds a dynamic hash
table at splat time and probes it at blur time. TPUs have neither dynamic
allocation nor atomics, so this module re-derives the lattice with
static-shape primitives (see DESIGN.md §2):

  * every input emits the ``d+1`` vertex keys of its enclosing simplex;
  * keys are deduplicated into a fixed-capacity table, and blur neighbors
    are resolved ONCE at build time into a dense ``(d+1, cap, 2r)`` int32
    gather table — by one of two interchangeable build paths:
      - ``sort``: exact lexicographic ``lax.sort`` dedup + a merge-sort
        neighbor lookup (deterministic lex slot order; the oracle path);
      - ``hash`` (the default; DESIGN.md §11): a static-capacity
        open-addressing hash table (kernels/hash) — epoch-based
        scatter-min insert for dedup, gather-only probe lookup for
        neighbors — the CUDA design recovered without atomics, 2-5x
        faster per build on the host backend (BENCH_build.json);
  * splat is a ``segment_sum``, blur is ``gather + stencil reduction``,
    slice is ``take + barycentric contraction``.

Both paths produce operator-equivalent ``Lattice`` structures (same
deduplicated point set, seg structure, neighbor graph, and overflow
semantics) differing only in slot numbering. All shapes depend only on
``(n, d, r, cap)`` so the whole build is jittable.
A build is only required when the *integer* lattice geometry changes — i.e.
when the lengthscale/spacing moves enough to change the rounding of inputs
to simplex vertices — which in practice means once per hyperparameter
setting. Training/prediction share ONE build per step through
``filtering.lattice_filter_with`` / ``filtering.LatticeCache`` (DESIGN.md
§9); ``build_count()`` below exposes a call counter so benchmarks and smoke
tests can assert the builds-per-step contract.

Geometry facts used below (verified in tests/test_lattice.py):
  * the elevation basis E (paper Eq. 7 neighborhood) has orthogonal columns
    with norms sqrt((j+1)(j+2)); dividing by those norms makes elevation an
    isometry, so scaling inputs by ``alpha`` scales embedded distances by
    ``alpha``;
  * one lattice step along any of the ``d+1`` blur directions has embedded
    length ``sqrt(d(d+1))``; choosing ``alpha = sqrt(d(d+1)) / s`` makes a
    lattice step correspond to distance ``s`` in the (lengthscale-normalized)
    input space, which is how the §4.1 stencil spacing is realized.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.hash import ops as hash_ops

Array = jax.Array

INT_SENTINEL_A = jnp.iinfo(jnp.int32).max // 2  # padding keys for invalid table rows
INT_SENTINEL_B = jnp.iinfo(jnp.int32).min // 2  # padding keys for invalid queries


def step_scale(d: int, spacing: float) -> float:
    """Input-space scaling so one lattice step == ``spacing`` (DESIGN.md §2)."""
    return math.sqrt(d * (d + 1.0)) / spacing


def elevation_scales(d: int, spacing: float) -> jnp.ndarray:
    """Per-dimension scale factors folded into the triangular elevation."""
    j = jnp.arange(d, dtype=jnp.float32)
    return step_scale(d, spacing) / jnp.sqrt((j + 1.0) * (j + 2.0))


def elevate(z: Array, spacing: float) -> Array:
    """Embed (n, d) inputs into the hyperplane H_d in R^{d+1}.

    Triangular-basis elevation (paper §3.2 "Splat"): O(d) per point via
    suffix sums, equivalent to multiplying by the orthogonal-column basis E.
    """
    n, d = z.shape
    c = z * elevation_scales(d, spacing)[None, :]  # (n, d)
    # elevated[0] = sum_j c_j ; elevated[i] = sum_{j>=i} c_j - i * c_{i-1}
    suffix = jnp.cumsum(c[:, ::-1], axis=1)[:, ::-1]  # suffix[:, i] = sum_{j>=i} c_j
    suffix_full = jnp.concatenate([suffix, jnp.zeros((n, 1), c.dtype)], axis=1)
    i = jnp.arange(1, d + 1, dtype=c.dtype)
    elevated_rest = suffix_full[:, 1:] - i[None, :] * c  # rows i=1..d
    return jnp.concatenate([suffix_full[:, :1], elevated_rest], axis=1)


def descending_rank(diff: Array) -> Array:
    """Stable descending rank of the rounding differential (the tie-break).

    ``rank[i] = #{j : diff_j > diff_i} + #{j < i : diff_j == diff_i}`` — an
    O(d^2)-per-point pairwise comparison count instead of an argsort.
    Bit-identical to the stable argsort it replaces, but keeps the whole
    embed (and hence the hash build and the frozen serving path,
    DESIGN.md §12) free of `lax.sort`.

    THE deterministic tie-break of the lattice (DESIGN.md §15): when a
    query sits exactly on a simplex boundary, two or more differentials
    tie and the enclosing simplex is ambiguous. Ties are broken
    POSITIONALLY — among equal differentials the LOWER coordinate index
    takes the smaller (earlier) rank — so every backend (XLA reference,
    Pallas kernel, and the tangent/Jacobian helpers below) selects the
    SAME cell and hence the same one-sided subgradient. The integer
    lattice structure carries no gradient — stop_gradient keeps autodiff
    (which differentiates the piecewise-linear barycentric weights) from
    tracing through the comparisons.
    """
    d = diff.shape[1] - 1
    nd_ = jax.lax.stop_gradient(diff)
    pos = jnp.tril(jnp.ones((d + 1, d + 1), bool), k=-1)  # [a, b]: b < a
    bigger = nd_[:, None, :] > nd_[:, :, None]  # [n, a, b]: diff_b > diff_a
    ties = (nd_[:, None, :] == nd_[:, :, None]) & pos[None]
    return jnp.sum(bigger | ties, axis=2).astype(jnp.int32)


def _rank_scatter(rank: Array, vals: Array, affine: bool = False) -> Array:
    """Scatter per-coordinate contributions into barycentric vertex order.

    ``vals`` is (n, d+1[, ...]) in COORDINATE order; each coordinate i
    contributes ``+vals[:, i]`` to canonical vertex ``d - rank[:, i]`` and
    ``-vals[:, i]`` to vertex ``d + 1 - rank[:, i]``, with the overflow
    column d+1 folded into vertex 0 (the rounding algorithm's telescoping
    weight recurrence, vectorized). ``affine=True`` adds the constant 1 to
    vertex 0 — the primal barycentric weights; without it the result is
    the LINEAR part only, i.e. exactly the map tangents/Jacobians of the
    weights flow through (DESIGN.md §15).
    """
    n, dp1 = rank.shape
    d = dp1 - 1
    out = jnp.zeros((n, d + 2) + vals.shape[2:], dtype=vals.dtype)
    rows = jnp.arange(n)[:, None]
    out = out.at[rows, d - rank].add(vals)
    out = out.at[rows, d + 1 - rank].add(-vals)
    fold = 1.0 + out[:, d + 1] if affine else out[:, d + 1]
    out = out.at[:, 0].add(fold)
    return out[:, : d + 1]


# --- embed instrumentation ---------------------------------------------------
# ``simplex_embed`` increments this on every Python-level call (trace-level
# under jit) — the serving analogue of ``build_count()``. The multi-output
# serving path (gp/serve.predict_multi) is pinned to ONE embed per query
# batch regardless of the number of output channels (DESIGN.md §15).

_EMBED_STATS = {"embeds": 0}


def embed_count() -> int:
    """Total ``simplex_embed`` invocations (trace-level under jit)."""
    return _EMBED_STATS["embeds"]


def simplex_embed_ranked(z: Array, spacing: float):
    """``simplex_embed`` that also returns the coordinate ranks.

    The ranks identify the enclosing simplex cell; the analytic weight
    derivative helpers (``embed_weight_tangent``/``embed_weight_jacobian``)
    consume them so gradient callers pay the embed ONCE and reuse its
    scratch for the tangent scatter (DESIGN.md §15).

    Returns:
      keys:    (n, d+1, d+1) int32 — lattice coordinates of the d+1 vertices.
      weights: (n, d+1) float32 — barycentric weights (sum to 1).
      rank:    (n, d+1) int32 — fixed-up descending rank per coordinate.
    """
    _EMBED_STATS["embeds"] += 1
    n, d = z.shape
    el = elevate(z, spacing)  # (n, d+1)

    # Round to the nearest remainder-0 point (multiples of d+1).
    v = el / (d + 1.0)
    rem0 = jnp.round(v) * (d + 1.0)  # (n, d+1) float
    rank = descending_rank(el - rem0)

    # Fix up so coordinates sum to zero on the lattice plane.
    coordsum = jnp.round(jnp.sum(rem0, axis=1) / (d + 1.0)).astype(jnp.int32)
    rank = rank + coordsum[:, None]
    under = rank < 0
    over = rank > d
    rank = jnp.where(under, rank + (d + 1), jnp.where(over, rank - (d + 1), rank))
    rem0 = jnp.where(under, rem0 + (d + 1.0), jnp.where(over, rem0 - (d + 1.0), rem0))

    # Barycentric weights from the (fixed-up) differential, sorted by rank.
    delta = (el - rem0) / (d + 1.0)  # (n, d+1)
    weights = _rank_scatter(rank, delta, affine=True)  # (n, d+1)

    # Vertex keys: rem0 + canonical_k[rank] with
    # canonical_k[r] = k - (d+1) * (r + k > d).
    rem0_i = jnp.round(rem0).astype(jnp.int32)  # exact multiples of d+1
    k = jnp.arange(d + 1, dtype=jnp.int32)[None, :, None]  # (1, d+1, 1) vertex idx
    rk = rank[:, None, :]  # (1 -> n, 1, d+1) coordinate ranks
    canon = k - (d + 1) * ((rk + k) > d).astype(jnp.int32)  # (n, d+1, d+1)
    keys = rem0_i[:, None, :] + canon
    return keys, weights.astype(jnp.float32), rank


def simplex_embed(z: Array, spacing: float):
    """Find enclosing-simplex vertices + barycentric weights for each input.

    Vectorized port of the rounding algorithm of Adams et al. (2010) §3.
    Returns:
      keys:    (n, d+1, d+1) int32 — lattice coordinates of the d+1 vertices.
      weights: (n, d+1) float32 — barycentric interpolation weights (sum to 1).
    """
    keys, weights, _ = simplex_embed_ranked(z, spacing)
    return keys, weights


def embed_weight_tangent(rank: Array, z_dot: Array, spacing: float) -> Array:
    """Directional derivative of the barycentric weights (DESIGN.md §15).

    Within a simplex cell the weights are AFFINE in the query: the round
    and the ranks are locally constant, so the tangent is just the linear
    ``elevate`` of the direction pushed through the same rank scatter —
    O(d^2) per point, no rounding, no probes. On a cell boundary this is
    the one-sided derivative of the cell ``descending_rank`` selected.
    Each row sums to zero (the weights always sum to 1).

    Args: rank (n, d+1) from ``simplex_embed_ranked``; z_dot (n, d) the
    input-space direction. Returns dw (n, d+1).
    """
    d = z_dot.shape[1]
    ddelta = elevate(z_dot, spacing) / (d + 1.0)
    return _rank_scatter(rank, ddelta)


def embed_weight_jacobian(rank: Array, spacing: float,
                          dtype=jnp.float32) -> Array:
    """Full Jacobian dW/dz of the barycentric weights: (n, d+1, d).

    ``embed_weight_tangent`` evaluated on the d basis directions at once:
    the constant per-coordinate differential Jacobian ``d delta / d z``
    (elevation is linear, so it is rank-independent) scattered per point
    by the cell's ranks. Row k of each point's Jacobian is the gradient
    of weight w_k; columns sum to zero over k.
    """
    n, dp1 = rank.shape
    d = dp1 - 1
    ej = elevate(jnp.eye(d, dtype=dtype), spacing)  # (d, d+1): row j = del/dz_j
    dd = jnp.transpose(ej) / (d + 1.0)  # (d+1, d): dd[i, j] = ddelta_i/dz_j
    return _rank_scatter(rank, jnp.broadcast_to(dd[None], (n, dp1, d)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Lattice:
    """Static-shape lattice structure; a pytree safe to pass through jit.

    Rows ``0..cap-1`` of every per-lattice-point array are (potentially)
    valid slots; row ``cap`` is the dump/sentinel row, kept at zero so that
    out-of-range gathers contribute nothing.
    """

    coords: Array  # (cap+1, d+1) int32: lattice point coordinates
    valid: Array  # (cap+1,) bool
    m: Array  # () int32: number of unique lattice points (may exceed cap!)
    seg_ids: Array  # (n*(d+1),) int32 in [0, cap]: slot per (input, vertex)
    weights: Array  # (n, d+1) f32 barycentric
    nbr: Array  # (d+1, cap+1, 2r) int32 in [0, cap]: blur gather table
    overflow: Array  # () bool: results invalid (capacity OR pack overflow)
    pack_overflow: Array  # () bool: |coord| > 2^15 — growing cap CANNOT fix
    # --- sorted splat plan (DESIGN.md §8): the dedup sort already places all
    # (input, vertex) contributions of one lattice point contiguously; these
    # four arrays let splat run as gather + segmented prefix scan + gather,
    # with no scatter/atomics — the hot-path trick of the fused MVM kernel.
    sort_row: Array  # (n*(d+1),) int32: input row of the k-th sorted contribution
    sort_w: Array  # (n*(d+1),) f32: its barycentric weight
    seg_head: Array  # (n*(d+1),) bool: True at the first member of each slot
    row_last: Array  # (cap+1,) int32: sorted index of each slot's last member
    d: int = dataclasses.field(metadata=dict(static=True))
    r: int = dataclasses.field(metadata=dict(static=True))
    cap: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    # which build path produced this lattice ("sort" / "hash_xla" /
    # "hash_pallas"). Slot NUMBERING differs between paths (lex order vs
    # hash placement) while the operator is equivalent; caches must key on
    # it so lattices from different paths never alias.
    build_backend: str = dataclasses.field(default="sort",
                                           metadata=dict(static=True))


def _lex_sort(cols: Sequence[Array], payloads: Sequence[Array]):
    out = jax.lax.sort(tuple(cols) + tuple(payloads), num_keys=len(cols))
    return out[: len(cols)], out[len(cols):]


# --- build instrumentation --------------------------------------------------
# ``build_lattice`` increments this on every Python-level call. Inside a jit
# trace that is once per *traced* build, i.e. exactly the number of lattice
# constructions baked into the compiled program — what the shared-lattice
# pipeline (DESIGN.md §9) drives to 1 per training step / posterior.

_BUILD_STATS = {"builds": 0}


def build_count() -> int:
    """Total ``build_lattice`` invocations (trace-level under jit)."""
    return _BUILD_STATS["builds"]


# --- packed sort keys (§Perf iteration C1/C2) -------------------------------
# Lattice coordinates sum to zero, so the last one is redundant; the first
# d are packed two-per-int32 (16-bit biased fields, wrap-tolerant: sorts
# need only group equal keys adjacently, not order them meaningfully).
# This halves (+1/(d+1)) the lex-sort key traffic of both the dedup sort
# and the neighbor-table merge sort — the dominant cost of the lattice
# build at houseelectric scale. C2 goes further: packing is lossless, so
# the dedup sort carries NO coordinate payload columns (coords are
# reconstructed by ``_unpack_key_cols`` after the sort), and the neighbor
# sort folds its tag and payload into a single column. Coordinates beyond
# +/-2^15 would corrupt the packing; they instead raise the existing
# ``overflow`` flag (the same grow-and-retry contract as capacity overflow).

_PACK_BIAS = 1 << 15
_PACK_LIMIT = (1 << 15) - 2
_TAG_SHIFT = 30  # neighbor-sort tag bit position (payload ids < 2^30)


def _pack_key_cols(keys: Array) -> list[Array]:
    """(N, d+1) int32 coords -> ceil(d/2) int32 sort columns."""
    n, c = keys.shape
    use = keys[:, : c - 1]  # last coord = -(sum of others)
    cols = []
    for start in range(0, c - 1, 2):
        hi = use[:, start].astype(jnp.int32) + _PACK_BIAS
        if start + 1 < c - 1:
            lo = use[:, start + 1].astype(jnp.int32) + _PACK_BIAS
        else:
            lo = jnp.zeros_like(hi)
        cols.append((hi << 16) | lo)
    return cols


def _unpack_key_cols(packed: Array, c: int) -> Array:
    """(N, ceil((c-1)/2)) packed sort columns -> (N, c) int32 coords.

    Exact inverse of ``_pack_key_cols`` within the +/-_PACK_LIMIT range;
    the dropped last coordinate is recovered from the zero-sum constraint.
    """
    fields = []
    for j in range(c - 1):
        word = packed[:, j // 2]
        f = (word >> 16) if j % 2 == 0 else word
        fields.append((f & 0xFFFF).astype(jnp.int32) - _PACK_BIAS)
    rest = jnp.stack(fields, axis=1)
    last = -jnp.sum(rest, axis=1, keepdims=True)
    return jnp.concatenate([rest, last], axis=1)


def _pack_overflow(keys: Array) -> Array:
    return jnp.any(jnp.abs(keys) > _PACK_LIMIT)


def default_capacity(n: int, d: int) -> int:
    """Worst case m = n (d+1) (paper Table 3's L)."""
    return n * (d + 1)


@functools.partial(jax.jit, static_argnames=("hcap",))
def _distinct_keys(packed: Array, hcap: int) -> Array:
    owner, _, _ = hash_ops.hash_insert(packed, hcap, backend="hash_xla")
    return jnp.sum((owner < packed.shape[0]).astype(jnp.int32))


def estimate_m(z: Array, spacing: float, *, sample: int = 4096) -> int:
    """Estimate the deduplicated lattice size m by hash-inserting a subsample.

    ``suggest_capacity``'s constant-occupancy guess knows nothing about the
    data; this inserts the vertex keys of an evenly-strided subsample at
    THREE scales (s/4, s/2, s) and extrapolates with the fitted power law
    ``m(n) ~ n^gamma`` (gamma in [0, 1]: 0 = the subsample already saturated
    the lattice, 1 = every point contributes fresh vertices). Exact when
    ``sample >= n``. Eager-only (returns a concrete int); cost is one
    O(sample * d) insert per scale — trivial next to a full build.

    Why three points: on MULTI-SCALE data (tight clusters on a sparse
    background) the growth curve is convex in log-log — small subsamples
    saturate the within-cluster vertices, so the s/2 -> s slope is steeper
    than the s/4 -> s/2 one, and the old 2-point fit (which only saw the
    coarser average slope through the saturated regime, or worse,
    underestimated via a lucky flat segment) produced caps that overflow
    and pay the grow-and-retry rebuild. The estimator fits gamma by
    least squares over the three log-log points, then applies a
    MONOTONICITY SANITY CHECK on the segment slopes: the nested prefixes
    guarantee m(s/4) <= m(s/2) <= m(s), so if the tail slope exceeds the
    head slope (convex growth — the multi-scale signature), the tail
    slope is the better predictor of what extrapolation will meet and
    wins over the least-squares average. Underestimates only cost a
    grow-and-retry rebuild (the overflow flag catches them), so the
    check deliberately resolves ambiguity upward.
    """
    n, d = z.shape
    s = min(n, max(int(sample), 64))
    stride = max(1, n // s)
    zs = z[::stride][:s]
    s = int(zs.shape[0])

    def distinct(zz) -> int:
        keys, _ = simplex_embed(zz, spacing)
        packed = jnp.stack(_pack_key_cols(
            keys.reshape(zz.shape[0] * (d + 1), d + 1)), axis=1)
        return int(_distinct_keys(
            packed, hash_ops.hash_capacity(zz.shape[0] * (d + 1))))

    m_s = distinct(zs)
    if s >= n:
        return m_s  # the "subsample" was the whole set: exact
    half = max(s // 2, 32)
    quarter = max(half // 2, 16)
    m_h = distinct(zs[:half])
    m_q = distinct(zs[:quarter]) if quarter < half else m_h
    # log-log samples; prefixes nest, so counts are non-decreasing by
    # construction — max() below only guards degenerate tiny samples
    pts = [(math.log(quarter), math.log(max(m_q, 1))),
           (math.log(half), math.log(max(min(m_h, m_s), m_q, 1))),
           (math.log(s), math.log(max(m_s, 1)))]
    xm = sum(p[0] for p in pts) / 3
    ym = sum(p[1] for p in pts) / 3
    den = sum((p[0] - xm) ** 2 for p in pts)
    gamma_lsq = sum((p[0] - xm) * (p[1] - ym) for p in pts) / max(den, 1e-12)
    g_head = (pts[1][1] - pts[0][1]) / max(pts[1][0] - pts[0][0], 1e-12)
    g_tail = (pts[2][1] - pts[1][1]) / max(pts[2][0] - pts[1][0], 1e-12)
    # monotonicity sanity check: convex growth (tail steeper than head)
    # means the least-squares slope is dragged down by the saturated
    # small-sample regime — trust the tail, the regime extrapolation
    # actually enters
    gamma = g_tail if g_tail > g_head else gamma_lsq
    gamma = min(max(gamma, 0.0), 1.0)
    return int(math.ceil(m_s * (n / s) ** gamma))


def suggest_capacity(n: int, d: int, spacing: float, *, r: int = 1,
                     c: int = 1, vmem_aware: bool = True,
                     z: Array | None = None, sample: int = 4096) -> int:
    """Heuristic starting capacity for grow-and-retry builds.

    The worst case m = n (d+1) is wildly pessimistic for real data (paper
    Table 3: m/L between 0.02 and 0.4), and every per-lattice-point array —
    the neighbor table above all — scales with cap, so over-allocating is
    the dominant build cost AND what keeps the fused kernel's table out of
    VMEM. Start from a constant-occupancy guess (wider stencil spacing means
    coarser cells, hence fewer of them), round up to a power of two, and let
    ``build_lattice_auto`` grow on overflow.

    ``z`` (the lengthscale-normalized points about to be embedded) switches
    to the data-aware guess: ``estimate_m`` hash-inserts a subsample and the
    cap starts at the estimate plus modest headroom, instead of the blind
    constant-occupancy formula — on clustered data this shrinks the
    neighbor table, the fused-MVM VMEM plan, and the frozen serving tables
    (DESIGN.md §12) by the m/guess ratio. Underestimates are safe: the
    grow-and-retry contract catches them via the overflow flag.

    ``vmem_aware`` guards the power-of-two rounding against silently
    defeating ``kernels.blur.ops.fits_vmem``: when the raw guess fits the
    fused MVM's VMEM plan (for ``r`` and ``c`` channels) but the rounded
    cap does not, the suggestion is clamped to the largest fitting cap
    instead of spilling the fusion. A guess that does not fit even
    unrounded is returned as-is — occupancy beats fusion (the blocked/XLA
    tiers handle oversized tables; under-capacity would corrupt results).
    """
    if z is not None and not isinstance(z, jax.core.Tracer):
        guess = max(1024, int(1.25 * estimate_m(z, spacing, sample=sample)))
    else:
        guess = max(1024, int(n * (d + 1) / (8.0 * max(spacing, 0.25))))
    # round up to a power of two, but never past the provable worst case
    cap = min(1 << (guess - 1).bit_length(), default_capacity(n, d))
    if vmem_aware:
        from repro.kernels.blur import ops as blur_ops  # cycle-safe: lazy
        if blur_ops.fits_vmem(n, d, r, guess + 1, c) and \
                not blur_ops.fits_vmem(n, d, r, cap + 1, c):
            cap = max(guess, min(cap, blur_ops.max_cap_for_vmem(n, d, r, c)))
    return min(cap, default_capacity(n, d))


def build_lattice_auto(z: Array, *, spacing: float, r: int = 1,
                       cap: int | None = None, growth: int = 4,
                       max_tries: int = 6,
                       backend: str = "auto") -> "Lattice":
    """Grow-and-retry wrapper: start at ``suggest_capacity`` and multiply by
    ``growth`` until the table fits (overflow flag clear).

    Syncs on the overflow flag, so call it OUTSIDE jit (amortized: once per
    hyperparameter setting). Inside jit, use ``build_lattice`` with a static
    cap as before. ``backend`` selects the build path (see
    ``build_lattice``); the overflow/grow contract is identical across
    paths.
    """
    n, d = z.shape
    worst = default_capacity(n, d)
    if cap is None:
        cap = suggest_capacity(n, d, spacing, r=r, z=z)
    for _ in range(max_tries):
        lat = build_lattice(z, spacing=spacing, r=r, cap=min(cap, worst),
                            backend=backend)
        if bool(lat.pack_overflow):
            # coordinate range, not capacity: growth cannot help — return
            # with the overflow flag set so the caller sees invalid results
            return lat
        if not bool(lat.overflow) or cap >= worst:
            return lat
        cap *= growth
    return lat  # pragma: no cover - max_tries exhausts only past worst case


def build_lattice(z: Array, *, spacing: float, r: int = 1,
                  cap: int | None = None, backend: str = "auto") -> Lattice:
    """Construct the lattice for (already lengthscale-normalized) inputs.

    Args:
      z: (n, d) float32 — inputs in the normalized metric of the kernel.
      spacing: §4.1 stencil spacing s (input-space distance of a lattice step).
      r: stencil radius (paper's blur order; Appendix A uses r=1).
      cap: static table capacity; defaults to the worst case n*(d+1).
        Prefer an auto-sized cap (``build_lattice_auto`` outside jit) — every
        per-lattice-point array scales with it.
      backend: build path (kernels/hash/ops.py policy). "auto" resolves to
        the hash build (hash_pallas on TPU when the table fits VMEM,
        hash_xla elsewhere); "sort" keeps the original two-pass
        lexicographic-sort build as the bit-exact lex-ordered oracle. All
        paths produce operator-equivalent lattices (same splat->blur->slice
        results up to slot permutation + f32 accumulation noise) with
        identical overflow/pack_overflow semantics.
    """
    n, d = z.shape
    if cap is None:
        cap = default_capacity(n, d)
    _BUILD_STATS["builds"] += 1
    resolved = hash_ops.resolve_build_backend(
        backend, hcap=hash_ops.hash_capacity(cap), npk=max(1, (d + 1) // 2))
    if resolved == "sort":
        return _build_lattice_impl(z, spacing=spacing, r=r, cap=cap)
    return _build_lattice_hash_impl(z, spacing=spacing, r=r, cap=cap,
                                    backend=resolved)


@functools.partial(jax.jit, static_argnames=("r", "cap"))
def _build_lattice_impl(z: Array, *, spacing: float, r: int,
                        cap: int) -> Lattice:
    n, d = z.shape
    keys, weights = simplex_embed(z, spacing)  # (n, d+1, d+1), (n, d+1)
    flat = keys.reshape(n * (d + 1), d + 1)
    big = n * (d + 1)

    # ---- exact dedup via lexicographic sort over PACKED keys ---------------
    # Packing is lossless (C2), so the only payload is the permutation; the
    # sorted coordinates come back out of the packed key columns themselves.
    cols = _pack_key_cols(flat)
    payload = jnp.arange(big, dtype=jnp.int32)
    sorted_cols, (perm,) = _lex_sort(cols, [payload])
    spacked = jnp.stack(sorted_cols, axis=1)
    skeys = _unpack_key_cols(spacked, d + 1)  # (big, d+1) sorted coords
    new_group = jnp.concatenate([
        jnp.ones((1,), bool),
        jnp.any(spacked[1:] != spacked[:-1], axis=1),
    ])
    uid_sorted = jnp.cumsum(new_group.astype(jnp.int32)) - 1  # (big,)
    m = uid_sorted[-1] + 1
    pack_ovf = _pack_overflow(flat)
    overflow = (m > cap) | pack_ovf
    slot_sorted = jnp.minimum(uid_sorted, cap)  # overflowed uniques -> dump row

    # lattice point coords (every member of a group writes the same value)
    coords = jnp.zeros((cap + 1, d + 1), jnp.int32).at[slot_sorted].set(skeys)
    valid = jnp.zeros((cap + 1,), bool).at[slot_sorted].set(True)
    valid = valid.at[cap].set(False)

    # per-(input, vertex) slot ids, back in original order
    seg_ids = jnp.zeros((big,), jnp.int32).at[perm].set(slot_sorted)

    # ---- sorted splat plan (DESIGN.md §8) ----------------------------------
    # Contributions in sorted order: original flat index f = i*(d+1) + k, so
    # the input row is f // (d+1); segment boundaries are the dedup groups;
    # the last member per slot indexes the segmented prefix scan's result.
    sort_row = perm // (d + 1)
    sort_w = weights.reshape(big)[perm]
    idx = jnp.arange(big, dtype=jnp.int32)
    row_last = jnp.zeros((cap + 1,), jnp.int32).at[slot_sorted].max(idx)

    # ---- blur neighbor table via merge-sort lookup -------------------------
    nbr = _neighbor_table(coords, valid, d=d, r=r, cap=cap)

    return Lattice(coords=coords, valid=valid, m=m, seg_ids=seg_ids,
                   weights=weights, nbr=nbr, overflow=overflow,
                   pack_overflow=pack_ovf, sort_row=sort_row, sort_w=sort_w,
                   seg_head=new_group, row_last=row_last, d=d, r=r, cap=cap,
                   n=n)


def _neighbor_queries(coords: Array, valid: Array, *, d: int, r: int,
                      cap: int):
    """Packed ``±1..±r`` neighbor-query keys for every (direction, slot).

    Shared by BOTH build paths (sort merge-lookup and hash lookup) and by
    the build benchmark's phase breakdown, so the query grid — offsets,
    flattening order, validity masking — can never desynchronize between
    the oracle and the fast path. Returns:
      q_packed:  ((d+1)(cap+1)(2r), npk) int32 packed query keys;
      src_valid: same leading shape, bool — whether the SOURCE slot of
        each query is a valid lattice point (invalid sources must miss).
    """
    # offsets along direction a: -1 everywhere, +d at coordinate a
    eye = jnp.eye(d + 1, dtype=jnp.int32)
    dirs = (d + 1) * eye - 1  # (d+1, d+1): dirs[a] = offset of +1 step along a

    steps = jnp.concatenate([jnp.arange(-r, 0), jnp.arange(1, r + 1)])  # (2r,)
    # queries[a, p, s] = coords[p] + steps[s] * dirs[a]
    table = coords[: cap + 1]  # includes dump row; masked via src_valid
    q = (table[None, :, None, :]
         + steps[None, None, :, None] * dirs[:, None, None, :])  # (d+1, cap+1, 2r, d+1)
    nq = (d + 1) * (cap + 1) * (2 * r)
    q_packed = jnp.stack(_pack_key_cols(q.reshape(nq, d + 1)), axis=1)
    src_valid = jnp.repeat(valid[: cap + 1], 2 * r)  # reshape order per a
    src_valid = jnp.tile(src_valid, d + 1)
    return q_packed, src_valid


def _neighbor_table(coords: Array, valid: Array, *, d: int, r: int,
                    cap: int) -> Array:
    """Resolve, for each lattice point and direction, the slots of its
    ``±1..±r`` neighbors. Returns (d+1, cap+1, 2r) int32 with misses -> cap.

    Strategy: concat [table entries (tag 0), neighbor queries (tag 1)],
    lex-sort by (coords..., tag); every query's match, if present, is the
    closest preceding tag-0 entry with identical coordinates.
    """
    q_packed, src_valid = _neighbor_queries(coords, valid, d=d, r=r, cap=cap)
    nq = q_packed.shape[0]
    t_packed = jnp.stack(_pack_key_cols(coords[: cap + 1]), axis=1)
    # invalid sources/entries get out-of-band packed cols
    q_packed = jnp.where(src_valid[:, None], q_packed, INT_SENTINEL_B)
    t_packed = jnp.where(valid[:, None], t_packed, INT_SENTINEL_A)

    all_keys = jnp.concatenate([t_packed, q_packed], axis=0)
    npk = all_keys.shape[1]
    # C2: tag and payload share one sort column — tag in the top bits so
    # table entries (tag 0) still sort before queries within a coordinate
    # group, payload in the low 30. One fewer comparator/payload column.
    assert nq < (1 << _TAG_SHIFT), "query id would overflow the tag packing"
    comb = jnp.concatenate([
        jnp.arange(cap + 1, dtype=jnp.int32),  # tag 0 | table slot
        (1 << _TAG_SHIFT) + jnp.arange(nq, dtype=jnp.int32),  # tag 1 | qid
    ])
    key_cols = [all_keys[:, j] for j in range(npk)] + [comb]
    sorted_cols, _ = _lex_sort(key_cols, [])
    scoords = jnp.stack(sorted_cols[: npk], axis=1)  # (N, npk) packed
    stag = sorted_cols[npk] >> _TAG_SHIFT
    spayload = sorted_cols[npk] & ((1 << _TAG_SHIFT) - 1)

    nfull = scoords.shape[0]
    pos = jnp.arange(nfull, dtype=jnp.int32)
    # forward-fill the position of the most recent table entry; a query
    # matches iff that entry has identical coordinates (tag 0 sorts first
    # within a coordinate group, and table entries are unique).
    last_a_pos = jax.lax.cummax(jnp.where(stag == 0, pos, -1))
    cand = jnp.maximum(last_a_pos, 0)
    same = jnp.all(scoords[cand] == scoords, axis=1) & (last_a_pos >= 0)
    matched_slot = jnp.where(same & (stag == 1), spayload[cand], cap)

    # scatter back: query id -> matched slot (non-queries dropped via OOB)
    is_q = stag == 1
    out = jnp.full((nq,), cap, jnp.int32).at[
        jnp.where(is_q, spayload, nq)
    ].set(matched_slot, mode="drop")
    return out.reshape(d + 1, cap + 1, 2 * r)


# ---------------------------------------------------------------------------
# Hash-based build (DESIGN.md §11): same Lattice, no lexicographic sorts.
# ---------------------------------------------------------------------------


def _counting_plan_shape(dom: int) -> tuple[int, int]:
    """(block, unroll) for ``_splat_plan_counting``, tuned on this host:
    small count states amortize the scan with deep unrolling; large ones
    are carry-copy bound and prefer fewer, wider steps."""
    return (64, 32) if dom <= (1 << 15) + 2 else (128, 8)


def _splat_plan_counting(seg_ids: Array, *, big: int, cap: int):
    """Group contributions by slot for the §8 splat plan — NO ``lax.sort``.

    A stable counting/partition construction over the already-known slot
    ids (ROADMAP item; replaces the single-column ``(slot << bits) | row``
    sort AND its two-array fallback): each contribution's destination is
    ``start[slot] + rank``, where ``start`` is the exclusive cumsum of the
    per-slot counts and ``rank`` is the contribution's stable index among
    same-slot predecessors. The rank — the only genuinely hard part of a
    sort-free counting sort — splits across ``B``-element blocks:

      * within a block: a lower-triangular pairwise equality count
        (``big * B`` comparisons, fully vectorized);
      * across blocks: ONE ``lax.scan`` over blocks carrying the running
        per-slot count table — gather-before-update yields each element's
        count over strictly earlier blocks, and the carry aliases in
        place, so the sweep is O(big) work + O(big / B) sequential steps
        (``K`` blocks unrolled per step to amortize loop overhead) with
        no (blocks x domain) histogram ever materialized.

    The resulting order is bit-identical to the stable sort it replaces
    (ascending slot, original row order within a slot), so the splat plan
    — and the fused kernel's segmented scan — are unchanged. All
    primitives are gathers, scatters, and cumsums; the hash build's jaxpr
    is asserted sort-free in tests/test_lattice_hash.py.
    """
    dom = cap + 2  # slots 0..cap, plus a padding value colliding with nothing
    bsz, unroll = _counting_plan_shape(dom)
    chunk = bsz * unroll
    padded = -(-big // chunk) * chunk
    seg_p = seg_ids if padded == big else jnp.concatenate(
        [seg_ids, jnp.full((padded - big,), cap + 1, jnp.int32)])
    blocks = seg_p.reshape(padded // bsz, bsz)

    # stable rank within each block: #{j < i in block : seg_j == seg_i}
    tri = jnp.tril(jnp.ones((bsz, bsz), bool), k=-1)  # [i, j]: j < i
    eq = blocks[:, :, None] == blocks[:, None, :]  # [b, i, j]
    local = jnp.sum(eq & tri[None], axis=2).astype(jnp.int32).reshape(padded)

    # cross-block prefix: count of each slot over all EARLIER blocks,
    # carried through the scan (read the count, then add the block)
    def body(cnt, bs):  # bs: (unroll, bsz)
        crosses = []
        for k in range(unroll):
            crosses.append(cnt[bs[k]])
            cnt = cnt.at[bs[k]].add(1)
        return cnt, jnp.stack(crosses)

    cnt, cross = jax.lax.scan(body, jnp.zeros((dom,), jnp.int32),
                              seg_p.reshape(padded // chunk, unroll, bsz))
    rank = (cross.reshape(padded) + local)[:big]

    # destination = slot's exclusive start + stable rank; a bijection on
    # [0, big), so one permutation scatter materializes the plan
    starts = jnp.concatenate([
        jnp.zeros((1,), jnp.int32),
        jnp.cumsum(cnt[: cap + 1])[:-1].astype(jnp.int32)])
    dest = starts[seg_ids] + rank
    perm = jnp.zeros((big,), jnp.int32).at[dest].set(
        jnp.arange(big, dtype=jnp.int32))
    seg_sorted = jnp.zeros((big,), jnp.int32).at[dest].set(seg_ids)
    return seg_sorted, perm


@functools.partial(jax.jit, static_argnames=("r", "cap", "backend"))
def _build_lattice_hash_impl(z: Array, *, spacing: float, r: int, cap: int,
                             backend: str) -> Lattice:
    """Open-addressing build: insert for dedup, lookup for neighbors.

    Replaces both ``_lex_sort`` passes of ``_build_lattice_impl`` with the
    kernels/hash table — O(n d · probes) with near-constant probes at
    <= 0.5 occupancy — and derives the sorted splat plan from the
    counting/partition construction (``_splat_plan_counting``), making the
    whole hash build — embed, dedup, neighbors, plan — free of
    ``lax.sort``. Produces an operator-equivalent ``Lattice``: identical
    deduplicated point set, seg structure, neighbor graph, and
    overflow/pack_overflow semantics; only the slot NUMBERING (hash
    placement vs lex order) differs.
    """
    n, d = z.shape
    keys, weights = simplex_embed(z, spacing)  # (n, d+1, d+1), (n, d+1)
    big = n * (d + 1)
    flat = keys.reshape(big, d + 1)
    packed = jnp.stack(_pack_key_cols(flat), axis=1)  # (big, npk)
    hcap = hash_ops.hash_capacity(cap)

    # ---- dedup via hash insert --------------------------------------------
    owner, slot_row, row_ok = hash_ops.hash_insert(packed, hcap,
                                                   backend=backend)
    occ = owner < big  # occupied hash slots (owner row id < N, EMPTY == N)
    m = jnp.sum(occ.astype(jnp.int32))
    dense = jnp.cumsum(occ.astype(jnp.int32)) - 1  # hash slot -> dense id
    dense_of = jnp.where(occ, jnp.minimum(dense, cap), cap)
    tkeys = hash_ops.table_keys(owner, packed)  # (hcap, npk), empty -> SENT
    pack_ovf = _pack_overflow(flat)
    overflow = (m > cap) | ~jnp.all(row_ok) | pack_ovf

    # per-(input, vertex) slot ids, already in original order (no perm)
    seg_ids = jnp.where(row_ok, dense_of[slot_row], cap)

    # dense lattice-point table (scatter over hcap rows only — cheap)
    dense_clip = jnp.where(occ & (dense < cap), dense, cap)
    coords = jnp.zeros((cap + 1, d + 1), jnp.int32).at[dense_clip].set(
        jnp.where(occ[:, None], _unpack_key_cols(tkeys, d + 1), 0))
    valid = jnp.zeros((cap + 1,), bool).at[dense_clip].set(occ)
    valid = valid.at[cap].set(False)

    # ---- sorted splat plan (DESIGN.md §8) ----------------------------------
    seg_sorted, perm = _splat_plan_counting(seg_ids, big=big, cap=cap)
    sort_row = perm // (d + 1)
    sort_w = weights.reshape(big)[perm]
    seg_head = jnp.concatenate([jnp.ones((1,), bool),
                                seg_sorted[1:] != seg_sorted[:-1]])
    # last sorted index per slot via binary search (no scatter): seg_sorted
    # is sorted, so right-boundary - 1 is each slot's last member
    row_last = jnp.clip(
        jnp.searchsorted(seg_sorted, jnp.arange(cap + 1, dtype=jnp.int32),
                         side="right").astype(jnp.int32) - 1, 0, big - 1)

    # ---- blur neighbor table via hash lookup -------------------------------
    q_packed, src_valid = _neighbor_queries(coords, valid, d=d, r=r, cap=cap)
    hres = hash_ops.hash_lookup(tkeys, q_packed, src_valid, hcap,
                                backend=backend)
    nbr = jnp.where(src_valid & (hres >= 0),
                    dense_of[jnp.clip(hres, 0, hcap - 1)],
                    cap).reshape(d + 1, cap + 1, 2 * r)

    return Lattice(coords=coords, valid=valid, m=m, seg_ids=seg_ids,
                   weights=weights, nbr=nbr, overflow=overflow,
                   pack_overflow=pack_ovf, sort_row=sort_row, sort_w=sort_w,
                   seg_head=seg_head, row_last=row_last, d=d, r=r, cap=cap,
                   n=n, build_backend=backend)


# ---------------------------------------------------------------------------
# Splat / Blur / Slice (paper §3.2) — the three SKI factors W^T, K_UU, W.
# ---------------------------------------------------------------------------


def splat(lat: Lattice, v: Array) -> Array:
    """W^T v: scatter barycentric-weighted values onto lattice points.

    v: (n, c) -> (cap+1, c); dump row forced to zero.
    """
    n, c = v.shape
    contrib = (lat.weights[:, :, None] * v[:, None, :]).reshape(
        n * (lat.d + 1), c)
    out = jax.ops.segment_sum(contrib, lat.seg_ids, num_segments=lat.cap + 1)
    return out.at[lat.cap].set(0.0)


def splat_sorted(lat: Lattice, v: Array) -> Array:
    """W^T v without any scatter: the fused-backend splat (DESIGN.md §8).

    Uses the build-time sorted plan: gather each sorted contribution's input
    row, run a segmented inclusive prefix scan (log-depth, pure vector ops —
    the XLA analogue of the fused Pallas kernel's in-VMEM Hillis-Steele
    sweep), and read each slot's total at its last member. Equivalent to
    ``splat`` as a linear map; summation order differs, so results agree to
    f32 accumulation noise only.
    """
    c = v.shape[1]
    contrib = lat.sort_w[:, None] * jnp.take(v, lat.sort_row, axis=0)
    carry = jnp.where(lat.seg_head, 0.0, 1.0)[:, None].astype(v.dtype)

    def comb(a, b):
        g1, v1 = a
        g2, v2 = b
        return g1 * g2, v2 + g2 * v1

    _, scanned = jax.lax.associative_scan(comb, (carry, contrib), axis=0)
    out = jnp.take(scanned, lat.row_last, axis=0)
    out = jnp.where(lat.valid[:, None], out, jnp.zeros((1, c), v.dtype))
    return out.at[lat.cap].set(0.0)


def blur_one_direction(lat: Lattice, vals: Array, stencil: Array,
                       direction: Array) -> Array:
    """Convolve lattice values with the stencil along one lattice direction."""
    nb = lat.nbr[direction]  # (cap+1, 2r)
    r = lat.r
    out = vals * stencil[r]
    gathered = vals[nb]  # (cap+1, 2r, c) ; dump row is zero
    w = jnp.concatenate([stencil[:r], stencil[r + 1:]])  # (2r,)
    out = out + jnp.einsum("prc,r->pc", gathered, w)
    return out.at[lat.cap].set(0.0)


def blur(lat: Lattice, vals: Array, stencil: Array, *,
         reverse: bool = False) -> Array:
    """Sequential separable blur along the d+1 lattice directions.

    ``reverse=True`` runs directions in the opposite order, which is exactly
    the transpose of the forward blur (each directional blur is symmetric) —
    used for the adjoint in lattice_filter's custom VJP and for the
    symmetrized operator 0.5 (F + F^T).
    """
    order = jnp.arange(lat.d + 1)
    if reverse:
        order = order[::-1]

    def body(carry, a):
        return blur_one_direction(lat, carry, stencil, a), None

    out, _ = jax.lax.scan(body, vals, order)
    return out


def slice_(lat: Lattice, vals: Array) -> Array:
    """W u: barycentric resampling back at the input locations. -> (n, c)"""
    per_vertex = vals[lat.seg_ids]  # (n*(d+1), c)
    per_vertex = per_vertex.reshape(lat.n, lat.d + 1, -1)
    return jnp.einsum("nkc,nk->nc", per_vertex, lat.weights)


# ---------------------------------------------------------------------------
# Frozen lattice index (DESIGN.md §12): slice-only queries at NEW points.
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LatticeIndex:
    """Hash index over a built lattice's occupied points.

    The serving-path complement of ``Lattice``: where the build resolves
    blur neighbors once for the training points, the index lets FROZEN
    per-lattice-point tables be sliced at arbitrary new points — embed the
    query, probe ``tkeys`` for each of its d+1 enclosing vertices, map hits
    to dense rows via ``row_of_slot``. Vertices absent from the index map
    to the zero row ``m`` and contribute nothing (the standard
    permutohedral slicing semantics); their barycentric mass is the
    query's "slice miss" diagnostic. Build-path agnostic: constructed from
    the deduplicated coords, so sort- and hash-built lattices index
    identically (up to the dense row permutation, which the compacted
    tables absorb).
    """

    tkeys: Array  # (hcap, npk) int32 packed keys; empty -> ref.KEY_SENTINEL
    row_of_slot: Array  # (hcap,) int32: hash slot -> dense row in [0, m]
    slots: Array  # (m,) int32: lattice slot of each dense row (for compact)
    d: int = dataclasses.field(metadata=dict(static=True))
    hcap: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))


def lattice_index(lat: Lattice) -> LatticeIndex:
    """Build the frozen query index for ``lat``. Eager-only: the dense
    table size is the CONCRETE occupied count m (not the static cap), so
    frozen tables shrink from (cap+1) to (m+1) rows — the right-sizing
    that keeps serving tables VMEM-resident."""
    valid = np.asarray(lat.valid)
    slots = np.nonzero(valid)[0].astype(np.int32)
    m = int(slots.shape[0])
    if m == 0:
        raise ValueError("cannot index an empty lattice")
    coords = jnp.asarray(np.asarray(lat.coords)[slots])
    packed = jnp.stack(_pack_key_cols(coords), axis=1)
    hcap = hash_ops.hash_capacity(m)
    owner, _, ok = hash_ops.hash_insert(packed, hcap, backend="hash_xla")
    if not bool(jnp.all(ok)):  # pragma: no cover - unique keys, occ <= 0.5
        raise RuntimeError("lattice_index insert failed on unique keys")
    occ = owner < m
    # keys are unique, so each occupied slot's owner IS its dense row id
    row_of_slot = jnp.where(occ, owner, m).astype(jnp.int32)
    return LatticeIndex(tkeys=hash_ops.table_keys(owner, packed),
                        row_of_slot=row_of_slot, slots=jnp.asarray(slots),
                        d=lat.d, hcap=hcap, m=m)


def compact_table(index: LatticeIndex, table: Array) -> Array:
    """(cap+1, c) per-lattice-point values -> (m+1, c) dense serving table.

    Row ``m`` is the zero miss row every absent-vertex lookup lands on.
    """
    vals = jnp.take(table, index.slots, axis=0)
    return jnp.concatenate(
        [vals, jnp.zeros((1, table.shape[1]), table.dtype)], axis=0)
