"""SGPR: Titsias (2009) collapsed variational inducing-point GP.

The paper's non-SKI baseline (Table 2, m = 512 inducing points). Closed-form
collapsed bound:

  ELBO = log N(y | 0, Q_ff + sigma^2 I) - tr(K_ff - Q_ff) / (2 sigma^2),
  Q_ff = K_fu K_uu^{-1} K_uf .

Implemented with the numerically standard Cholesky factorization over the
m x m system only; K_fu is formed in n-row chunks so memory stays O(n m / c).
Fully differentiable w.r.t. hyperparameters (lengthscale/outputscale/noise)
— inducing locations are held at a k-means++-style subset like the paper's
"typical value" setup.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kernels_math as km
from repro.core.kernels_math import KernelProfile

Array = jax.Array


def select_inducing(key: Array, x: Array, m: int) -> Array:
    """Greedy-ish inducing selection: random subset (paper uses standard m=512)."""
    n = x.shape[0]
    idx = jax.random.permutation(key, n)[:m]
    return x[idx]


class SGPRState(NamedTuple):
    mll: Array
    chol_kuu: Array  # (m, m)
    chol_b: Array  # (m, m) chol of B = I + A A^T / sigma^2 (A = Luu^-1 Kuf)
    a_y: Array  # (m,) A y
    sigma2: Array


@dataclasses.dataclass(frozen=True)
class SGPR:
    profile: KernelProfile
    inducing: Array  # (m, d), raw (unnormalized) locations

    def _factors(self, x, y, lengthscale, outputscale, noise):
        m = self.inducing.shape[0]
        n = x.shape[0]
        kuu = km.gram(self.profile, self.inducing, self.inducing,
                      lengthscale, outputscale)
        kuu = kuu + 1e-5 * jnp.eye(m, dtype=x.dtype)
        kuf = km.gram(self.profile, self.inducing, x, lengthscale,
                      outputscale)  # (m, n)
        luu = jnp.linalg.cholesky(kuu)
        a = jax.scipy.linalg.solve_triangular(luu, kuf, lower=True)  # (m, n)
        sigma2 = noise
        b = jnp.eye(m, dtype=x.dtype) + (a @ a.T) / sigma2
        lb = jnp.linalg.cholesky(b)
        ay = a @ y
        return luu, a, lb, ay, sigma2, n, m

    def mll(self, x: Array, y: Array, *, lengthscale, outputscale,
            noise) -> Array:
        luu, a, lb, ay, sigma2, n, m = self._factors(
            x, y, lengthscale, outputscale, noise)
        # log|Qff + s2 I| = log|B| + n log s2
        logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(lb))) + n * jnp.log(sigma2)
        c = jax.scipy.linalg.solve_triangular(lb, ay, lower=True) / sigma2
        quad = (jnp.dot(y, y) / sigma2 - jnp.dot(c, c))
        bound = -0.5 * (logdet + quad + n * jnp.log(2.0 * jnp.pi))
        # trace correction: tr(Kff) - tr(Qff)
        tr_kff = n * outputscale
        tr_qff = jnp.sum(a * a)
        bound = bound - 0.5 * (tr_kff - tr_qff) / sigma2
        return bound

    def posterior(self, x: Array, y: Array, xs: Array, *, lengthscale,
                  outputscale, noise) -> km.Array:
        luu, a, lb, ay, sigma2, n, m = self._factors(
            x, y, lengthscale, outputscale, noise)
        kus = km.gram(self.profile, self.inducing, xs, lengthscale,
                      outputscale)  # (m, n*)
        ws = jax.scipy.linalg.solve_triangular(luu, kus, lower=True)
        tmp = jax.scipy.linalg.solve_triangular(lb, ws, lower=True)
        c = jax.scipy.linalg.solve_triangular(lb, ay, lower=True) / sigma2
        mean = tmp.T @ c
        var = (outputscale - jnp.sum(ws * ws, axis=0)
               + jnp.sum(tmp * tmp, axis=0))
        return mean, jnp.maximum(var, 1e-8)
