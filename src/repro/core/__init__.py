"""The paper's primary contribution: Simplex-GP on the permutohedral lattice.

Submodules:
  lattice       — TPU-native permutohedral lattice (splat/blur/slice, §3.2)
  stencil       — generic stationary-kernel discretization (§4.1, Eq. 9)
  filtering     — the Simplex-GP MVM with §4.2 custom gradients
  kernels_math  — stationary profiles + dense oracles
  exact         — exact-GP baseline (KeOps role)
  ski_grid      — KISS-GP cubic-grid SKI baseline
  skip          — SKIP product-kernel low-rank baseline
  sgpr          — Titsias variational baseline
"""
from repro.core import kernels_math
from repro.core.filtering import (FilterSpec, LatticeCache, filter_mvm,
                                  lattice_filter, lattice_filter_with,
                                  mvm_operator, spec_for)
from repro.core.lattice import (Lattice, build_count, build_lattice,
                                build_lattice_auto, default_capacity,
                                suggest_capacity)
from repro.core.stencil import Stencil, make_stencil

__all__ = [
    "kernels_math", "FilterSpec", "LatticeCache", "filter_mvm",
    "lattice_filter", "lattice_filter_with", "mvm_operator", "spec_for",
    "Lattice", "build_count", "build_lattice", "build_lattice_auto",
    "default_capacity", "suggest_capacity", "Stencil", "make_stencil",
]
