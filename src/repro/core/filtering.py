"""Lattice filtering = the Simplex-GP MVM (paper §4) with efficient gradients.

``lattice_filter`` evaluates ``u ≈ K(z) v`` for a stationary kernel whose
§4.1 stencil is supplied, via Splat -> Blur -> Slice on the permutohedral
lattice (= the SKI decomposition W K_UU W^T of paper Eq. 8). It rebuilds
the lattice per call; ``lattice_filter_with`` is the shared-lattice variant
(same values, same §4.2 VJP) closed over a prebuilt ``Lattice``, and
``LatticeCache`` memoizes builds across eager calls — together they are the
one-build-per-step pipeline of DESIGN.md §9.

Gradients follow the paper exactly:
  * w.r.t. ``v``: the transpose filter (reverse-order blur); with
    ``symmetrize=True`` the operator is 0.5 (F + F^T) and self-adjoint.
  * w.r.t. ``z`` (and hence lengthscales, by the chain rule outside): the
    §4.2 identity (Eqs. 11-13) — ONE extra filtering call with the
    derivative stencil ``k'`` applied to Concat([z⊙g, -g, z⊙v, -v]).

Note the §4.2 gradient is an approximation of the gradient of the *exact*
MVM (like the paper's), not the exact gradient of the approximation; it
deliberately does not differentiate through the integer lattice rounding.

``symmetrize`` is a beyond-paper robustness option (default on for GP
inference): the raw sequential blur B_d ... B_0 is very slightly
non-symmetric because directional blurs do not commute; averaging with the
reversed order restores exact symmetry so CG operates on a symmetric
operator. Cost: 2x blur (splat/slice shared).
"""
from __future__ import annotations

import collections
import functools
import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lattice as lat_mod
from repro.core.lattice import Lattice
from repro.core.stencil import Stencil

Array = jax.Array


class FilterSpec(NamedTuple):
    """Static configuration of a lattice filter (hashable; jit-friendly).

    ``taps``/``dtaps`` carry the CONCRETE stencil values so Pallas/fused
    backends can bake them into the kernel even when the ``weights`` array
    reaching ``filter_mvm`` is traced under jit (converting a tracer with
    ``float()`` crashes — the seed's ``use_pallas`` bug).
    """

    spacing: float
    r: int
    cap: int | None
    symmetrize: bool
    dscale: float = 1.0  # amplitude of the derivative kernel k'(0)
    taps: tuple[float, ...] | None = None  # concrete forward stencil
    dtaps: tuple[float, ...] | None = None  # concrete derivative stencil
    backend: str = "auto"  # kernels/blur/ops.py backend policy
    build_backend: str = "auto"  # kernels/hash/ops.py build-path policy


def spec_for(stencil: Stencil, cap: int | None = None,
             symmetrize: bool = True, backend: str = "auto",
             build_backend: str = "auto") -> FilterSpec:
    return FilterSpec(spacing=stencil.spacing, r=stencil.r, cap=cap,
                      symmetrize=symmetrize, dscale=stencil.dscale,
                      taps=tuple(stencil.weights),
                      dtaps=tuple(stencil.dweights), backend=backend,
                      build_backend=build_backend)


def filter_mvm(lat: Lattice, v: Array, weights: Array | None = None, *,
               symmetrize: bool = True, backend: str = "auto",
               taps: tuple[float, ...] | None = None,
               use_pallas: bool = False, mesh=None,
               axis_name: str = "data") -> Array:
    """Apply the lattice operator W B W^T to (n, c) values, lattice given.

    This is the fast path for CG loops: build the lattice once per
    hyperparameter setting, then call this per iteration — the (n, c)
    block contract means a whole mBCG/LOVE RHS block rides ONE call.
    ``backend`` selects the kernels/blur/ops.py tier ("auto" = policy
    choice); ``use_pallas`` is the seed-compatible alias for the
    per-direction tier. Concrete ``taps`` enable the Pallas/fused tiers
    under jit. ``mesh`` engages the sharded data-parallel tier
    (one psum per MVM — DESIGN.md §10).
    """
    from repro.kernels.blur.ops import lattice_mvm
    if use_pallas:
        backend = "per_direction_pallas"
    return lattice_mvm(lat, v, weights, taps=taps, symmetrize=symmetrize,
                       backend=backend, mesh=mesh, axis_name=axis_name)


def filter_mvm_t(lat: Lattice, v: Array, weights: Array | None = None, *,
                 symmetrize: bool = True, backend: str = "auto",
                 taps: tuple[float, ...] | None = None, mesh=None,
                 axis_name: str = "data") -> Array:
    """Transpose operator F^T (== F when symmetrized).

    The fused backends give the transpose for free: it is the same kernel
    with the sweep order flipped.
    """
    from repro.kernels.blur.ops import lattice_mvm
    return lattice_mvm(lat, v, weights, taps=taps, symmetrize=symmetrize,
                       transpose=True, backend=backend, mesh=mesh,
                       axis_name=axis_name)


# ---------------------------------------------------------------------------
# Differentiable entry point.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def lattice_filter(z: Array, v: Array, weights: Array, dweights: Array,
                   spec: FilterSpec) -> Array:
    """u ≈ K(z) v with custom VJPs per paper §4.2.

    Args:
      z: (n, d) lengthscale-normalized inputs.
      v: (n, c) values to filter.
      weights: (2r+1,) §4.1 stencil of the kernel profile.
      dweights: (2r+1,) §4.1 stencil of k' (derivative wrt squared distance).
      spec: static filter configuration.
    """
    lat = lat_mod.build_lattice(z, spacing=spec.spacing, r=spec.r,
                                cap=spec.cap, backend=spec.build_backend)
    return filter_mvm(lat, v, weights, symmetrize=spec.symmetrize,
                      backend=spec.backend, taps=spec.taps)


def _filter_fwd(z, v, weights, dweights, spec):
    lat = lat_mod.build_lattice(z, spacing=spec.spacing, r=spec.r,
                                cap=spec.cap, backend=spec.build_backend)
    u = filter_mvm(lat, v, weights, symmetrize=spec.symmetrize,
                   backend=spec.backend, taps=spec.taps)
    return u, (z, v, weights, dweights, lat)


def _filter_bwd(spec, res, g):
    z, v, weights, dweights, lat = res
    return _filter_bwd_core(spec, lat, z, v, weights, dweights, g)


def _filter_bwd_core(spec, lat, z, v, weights, dweights, g):
    """Shared §4.2 backward pass for both filter entry points."""
    n, d = z.shape
    c = v.shape[1]

    # dL/dv = F^T g — reuse the already-built lattice; the fused backends
    # run the transpose as the same kernel with the sweep order flipped.
    dv = filter_mvm_t(lat, g, weights, symmetrize=spec.symmetrize,
                      backend=spec.backend, taps=spec.taps)

    # dL/dz via Eq. 12/13: one filter call with the k' stencil on
    # Concat([z ⊙ g, g, z ⊙ v, v]) (signs folded into the combination).
    zg = (z[:, :, None] * g[:, None, :]).reshape(n, d * c)
    zv = (z[:, :, None] * v[:, None, :]).reshape(n, d * c)
    big = jnp.concatenate([zg, g, zv, v], axis=1)
    out = filter_mvm(lat, big, dweights, symmetrize=spec.symmetrize,
                     backend=spec.backend, taps=spec.dtaps)
    A = out[:, : d * c].reshape(n, d, c)  # F'(z ⊙ g)
    B = out[:, d * c: d * c + c]  # F' g
    C = out[:, d * c + c: 2 * d * c + c].reshape(n, d, c)  # F'(z ⊙ v)
    D = out[:, 2 * d * c + c:]  # F' v

    # NOTE: expanding Eq. 11 (verified against autodiff of the dense MVM in
    # tests/test_filtering.py) gives the OPPOSITE overall sign of the paper's
    # printed Eq. 12; we follow Eq. 11.
    dz = (2.0 * spec.dscale) * (
        z * jnp.sum(v * B, axis=1, keepdims=True)
        - jnp.einsum("nc,ndc->nd", v, A)
        + z * jnp.sum(g * D, axis=1, keepdims=True)
        - jnp.einsum("nc,ndc->nd", g, C)
    )
    zero_w = jnp.zeros_like(weights)
    zero_dw = jnp.zeros_like(dweights)
    return dz.astype(z.dtype), dv.astype(v.dtype), zero_w, zero_dw


lattice_filter.defvjp(_filter_fwd, _filter_bwd)


# ---------------------------------------------------------------------------
# Prebuilt-lattice entry point (DESIGN.md §9): same operator + same §4.2
# custom VJP, but closed over an existing Lattice instead of rebuilding one
# per call. This is what lets a training step / posterior run on exactly ONE
# lattice build: the solve path, the surrogate quad forms, and the §4.2
# backward pass all share the ``lat`` the caller built.
# ---------------------------------------------------------------------------


def _lattice_zero_cotangent(lat: Lattice):
    """Zero cotangent for the Lattice pytree (float0 for int/bool leaves).

    The lattice's integer structure is non-differentiable by construction
    (the §4.2 gradient deliberately ignores the rounding), so its cotangent
    is symbolically zero.
    """
    def zero(leaf):
        if jnp.issubdtype(jnp.result_type(leaf), jnp.inexact):
            return jnp.zeros_like(leaf)
        return np.zeros(jnp.shape(leaf), jax.dtypes.float0)

    return jax.tree.map(zero, lat)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def lattice_filter_with(lat: Lattice, z: Array, v: Array, weights: Array,
                        dweights: Array, spec: FilterSpec) -> Array:
    """u ≈ K(z) v on a PREBUILT lattice, with the §4.2 custom VJP.

    Identical to ``lattice_filter`` except the lattice is supplied instead
    of rebuilt, so repeated quad forms within one step cost zero builds.
    The caller must guarantee ``lat`` was built from these ``z`` (same
    spacing/r); gradients w.r.t. ``z`` flow through the derivative-stencil
    identity exactly as in ``lattice_filter`` — the lattice itself gets a
    symbolic-zero cotangent, matching the §4.2 convention of not
    differentiating the integer rounding.
    """
    return filter_mvm(lat, v, weights, symmetrize=spec.symmetrize,
                      backend=spec.backend, taps=spec.taps)


def _filter_with_fwd(lat, z, v, weights, dweights, spec):
    u = filter_mvm(lat, v, weights, symmetrize=spec.symmetrize,
                   backend=spec.backend, taps=spec.taps)
    return u, (lat, z, v, weights, dweights)


def _filter_with_bwd(spec, res, g):
    lat, z, v, weights, dweights = res
    dz, dv, zero_w, zero_dw = _filter_bwd_core(spec, lat, z, v, weights,
                                               dweights, g)
    return _lattice_zero_cotangent(lat), dz, dv, zero_w, zero_dw


lattice_filter_with.defvjp(_filter_with_fwd, _filter_with_bwd)


# ---------------------------------------------------------------------------
# Cross-call lattice reuse (DESIGN.md §9).
# ---------------------------------------------------------------------------


def concrete_ls_key(ls) -> tuple | None:
    """Hashable cache key from a concrete lengthscale; None while traced.

    A rebuild is only *required* when the integer rounding of ``z = x / ls``
    changes, but detecting that is as expensive as rebuilding — so the cache
    keys conservatively on the exact concrete lengthscale values.
    """
    try:
        arr = np.asarray(ls, dtype=np.float64)
    except (jax.errors.ConcretizationTypeError, jax.errors.TracerArrayConversionError):
        return None
    return tuple(arr.reshape(-1).tolist())


class LatticeCache:
    """Small LRU memo of built lattices, keyed on the concrete geometry.

    Keys combine a caller-chosen point-set tag (which arrays were embedded),
    the concrete lengthscale values, and the static build parameters
    ``(spacing, r, cap)`` — the full determinants of the integer lattice.
    Under jit (traced lengthscales) the cache is transparently bypassed:
    within one traced step, reuse is instead structural (build once, pass the
    ``Lattice`` through ``operator(lat=...)`` / ``lattice_filter_with``).
    """

    def __init__(self, maxsize: int = 8):
        self._store: collections.OrderedDict = collections.OrderedDict()
        self._maxsize = maxsize
        self.hits = 0
        self.misses = 0

    @staticmethod
    def point_set_tag(*arrays: Array) -> tuple | None:
        """Content fingerprint of the (concrete) embedded point sets.

        Hashes the raw bytes, so it is ROW-ORDER SENSITIVE — the lattice's
        seg_ids/weights/splat plan depend on input order, so a reordered
        point set must miss the cache. Returns None for traced inputs
        (``get`` then bypasses the memo). Cost: one host transfer + hash,
        trivial next to a build.
        """
        parts = []
        for a in arrays:
            if isinstance(a, jax.core.Tracer):
                return None
            arr = np.asarray(a)
            parts.append((arr.shape, str(arr.dtype),
                          hashlib.blake2b(arr.tobytes(),
                                          digest_size=16).hexdigest()))
        return tuple(parts)

    @staticmethod
    def layout_key(z: Array) -> str:
        """Device/sharding fingerprint of the array the build starts from.

        The built lattice's arrays inherit ``z``'s placement and sharding
        (a shard_map/GSPMD consumer sees committed shardings), so a lattice
        built from an unsharded ``z`` must NOT be served to a request whose
        ``z`` is sharded over a mesh (or lives on different devices) — the
        MVM would silently reshard or, worse, mix layouts. str(sharding)
        covers both the device set and the partition spec.
        """
        sharding = getattr(z, "sharding", None)
        return "" if sharding is None else str(sharding)

    def get(self, tag, z: Array, *, spacing: float, r: int,
            cap: int | None, ls=None,
            build_backend: str = "auto", mesh=None) -> Lattice:
        """Return a cached lattice for this key, building on miss.

        ``tag`` identifies the point set(s) behind ``z`` (use
        ``point_set_tag``); ``ls`` is the concrete lengthscale the embedding
        divided by (traced -> bypass). The key also includes ``z``'s
        device/sharding layout so a sharded build never aliases an
        unsharded one, the build path (sort vs hash slot numbering
        differs, so lattices from different backends must never alias
        either — consumers may hold slot-indexed state), and the CONSUMER
        MESH the MVMs will run on (``mesh``): after an elastic mesh
        resize (DESIGN.md §16) a resumed run must never be served a
        lattice produced for the old device layout — downstream holds
        mesh-shaped compiled/sharded state keyed on these arrays, so the
        resume path misses here and rebuilds.
        """
        ls_key = concrete_ls_key(ls) if ls is not None else ()
        if tag is None or ls_key is None or isinstance(z, jax.core.Tracer):
            return lat_mod.build_lattice(z, spacing=spacing, r=r, cap=cap,
                                         backend=build_backend)
        # key on the RESOLVED backend (what build_lattice will actually
        # run), so "auto" and its explicit resolution share one entry —
        # and the key matches the stored Lattice.build_backend provenance
        from repro.kernels.hash import ops as hash_ops
        from repro.sharding.simplex import mesh_fingerprint
        n, d = z.shape
        cap_val = cap if cap is not None else lat_mod.default_capacity(n, d)
        resolved = hash_ops.resolve_build_backend(
            build_backend, hcap=hash_ops.hash_capacity(cap_val),
            npk=max(1, (d + 1) // 2))
        key = (tag, ls_key, float(spacing), int(r),
               None if cap is None else int(cap), self.layout_key(z),
               resolved, mesh_fingerprint(mesh))
        hit = self._store.get(key)
        if hit is not None:
            self._store.move_to_end(key)
            self.hits += 1
            return hit
        self.misses += 1
        lat = lat_mod.build_lattice(z, spacing=spacing, r=r, cap=cap,
                                    backend=resolved)
        self._store[key] = lat
        while len(self._store) > self._maxsize:
            self._store.popitem(last=False)
        return lat


def _embed_queries(zq: Array, spacing: float, dtype):
    """Embed + pack + pack-overflow mask — the shared front half of every
    frozen-slice entry point. Returns (q_packed, weights, rank, active);
    ONE ``simplex_embed`` per call, which is what the multi-output predict
    path's one-embed-per-batch pin (``lattice.embed_count``) counts."""
    b, d = zq.shape
    keys, w, rank = lat_mod.simplex_embed_ranked(zq, spacing)
    q_packed = jnp.stack(
        lat_mod._pack_key_cols(keys.reshape(b * (d + 1), d + 1)), axis=1)
    # queries whose coordinates overflow the 16-bit packing could alias
    # real keys — force all their vertices to miss (reported as mass 1)
    ok = jnp.all(jnp.abs(keys) <= lat_mod._PACK_LIMIT, axis=(1, 2))
    active = jnp.repeat(ok, d + 1)
    return q_packed, w.astype(dtype), rank, active


def _slice_only_xla(index: "lat_mod.LatticeIndex", tables: Array, zq: Array,
                    spacing: float) -> tuple[Array, Array]:
    """Pure-XLA frozen slice — every op is differentiable/transposable.

    The body the custom JVP below traces: ``simplex_embed_ranked`` is
    JVP-exact w.r.t. ``zq`` by construction (rounding and ranks are
    piecewise constant with zero/stopped tangents; the weights are affine
    per cell), and gather + einsum are linear in ``tables``/``weights``.
    Keeping this path free of ``pallas_call`` (which has no transpose
    rule) is what makes reverse-mode ``jax.grad`` work through serving.
    """
    from repro.kernels.slice.ref import slice_query_xla
    q_packed, w, _, active = _embed_queries(zq, spacing, tables.dtype)
    return slice_query_xla(index.tkeys, index.row_of_slot, tables,
                           q_packed, w, active, index.hcap)


@functools.partial(jax.custom_jvp, nondiff_argnums=(3, 4, 5))
def _slice_only_prim(index, tables, zq, spacing, backend, interpret):
    from repro.kernels.slice.ops import slice_query
    q_packed, w, _, active = _embed_queries(zq, spacing, tables.dtype)
    return slice_query(index, tables, q_packed, w, active,
                       backend=backend, interpret=interpret)


@_slice_only_prim.defjvp
def _slice_only_jvp(spacing, backend, interpret, primals, tangents):
    """Query-space (and table-space) JVP of the frozen slice (§15).

    Differentiation re-traces the pure-XLA body — the weights are
    piecewise-linear in the query, so the tangent is the existing slice
    contraction against the analytic weight derivative (no new probes),
    and linearizing this rule gives reverse-mode for free. The fast
    serving tiers (Pallas fused probe) stay primal-only; forward-only
    consumers that want the fused primal+tangent kernel use
    ``slice_only_tangent`` instead. The index tangent (int leaves) is
    ignored; ``miss`` gets the traced body's true tangent (zero when the
    query's simplex fully hits, the tangent weight mass on the missing
    vertices otherwise — see the boundary semantics in DESIGN.md §15).
    """
    index, tables, zq = primals
    _, tables_dot, zq_dot = tangents
    return jax.jvp(lambda t, q: _slice_only_xla(index, t, q, spacing),
                   (tables, zq), (tables_dot, zq_dot))


def slice_only(index: "lat_mod.LatticeIndex", tables: Array, zq: Array, *,
               spacing: float, backend: str = "auto",
               interpret: bool | None = None) -> tuple[Array, Array]:
    """Slice FROZEN per-lattice-point tables at new points — no build, no
    solve (the serving entry point, DESIGN.md §12).

    Embeds ``zq`` ((b, d) lengthscale-normalized queries; O(d^2) per
    point, sort-free), probes the lattice hash index for each enclosing
    vertex, and barycentrically contracts the frozen ``tables`` rows.
    Lookup-miss semantics: vertices absent from the index contribute ZERO
    (the standard permutohedral slicing convention — the frozen lattice
    simply has no mass there), and each query's barycentric mass on
    absent vertices is returned as ``miss`` — the per-batch fidelity
    diagnostic (0 = the query's simplex is entirely inside the frozen
    lattice; 1 = completely off-lattice, prediction falls back to the
    prior). ``backend`` selects the kernels/slice/ops.py tier.

    DIFFERENTIABLE in ``zq`` and ``tables`` (DESIGN.md §15): a custom JVP
    reuses the piecewise-linearity of the barycentric weights, so both
    ``jax.jvp`` and ``jax.grad`` flow through serving; gradients are only
    meaningful where ``miss == 0`` (gate on it — absent vertices clamp
    their mass's contribution to zero).
    """
    return _slice_only_prim(index, tables, zq, float(spacing), backend,
                            interpret)


def slice_only_tangent(index: "lat_mod.LatticeIndex", tables: Array,
                       zq: Array, zq_dot: Array, *, spacing: float,
                       backend: str = "auto",
                       interpret: bool | None = None
                       ) -> tuple[Array, Array, Array]:
    """Fused primal + directional query-space tangent of the frozen slice.

    The forward-mode fast path (DESIGN.md §15): one embed, one analytic
    weight tangent (``lattice.embed_weight_tangent``), then the fused
    primal+tangent contraction tier (``kernels/slice/ops.py``'s
    ``slice_query_tangent`` — Pallas on TPU, XLA elsewhere: probe once,
    gather once, contract twice). Returns ``(out, out_dot, miss)``;
    ``out_dot`` is d(out)/d(zq) . zq_dot, valid where ``miss == 0``.
    """
    from repro.kernels.slice.ops import slice_query_tangent
    q_packed, w, rank, active = _embed_queries(zq, spacing, tables.dtype)
    w_dot = lat_mod.embed_weight_tangent(rank, zq_dot, spacing)
    return slice_query_tangent(index, tables, q_packed, w,
                               w_dot.astype(tables.dtype), active,
                               backend=backend, interpret=interpret)


def slice_only_grad(index: "lat_mod.LatticeIndex", tables: Array,
                    zq: Array, *, spacing: float
                    ) -> tuple[Array, Array, Array]:
    """One-pass primal + FULL query-space Jacobian of the frozen slice.

    Returns ``(out (b, c), jac (b, c, d), miss (b,))`` with
    ``jac[q, :, j] = d out[q] / d zq[q, j]`` — the d directional tangents
    share one embed/probe/gather (``kernels/slice/ops.py``'s
    ``slice_query_jacobian``). What ``gp/serve.predict_grad`` builds its
    analytic d(mean, var)/dx* from; valid where ``miss == 0``.
    """
    from repro.kernels.slice.ops import slice_query_jacobian
    q_packed, w, rank, active = _embed_queries(zq, spacing, tables.dtype)
    wjac = lat_mod.embed_weight_jacobian(rank, spacing, w.dtype)
    return slice_query_jacobian(index, tables, q_packed, w,
                                wjac.astype(tables.dtype), active)


def mvm_operator(z: Array, stencil: Stencil, *, cap: int | None = None,
                 symmetrize: bool = True, backend: str = "auto",
                 build_backend: str = "auto",
                 auto_cap: bool = False, mesh=None,
                 axis_name: str = "data"):
    """Build the lattice once and return (matvec, lattice).

    ``matvec`` maps (n, k) -> (n, k) — the multi-RHS block contract: CG,
    mBCG, Lanczos, and LOVE hand it their whole RHS block so each solver
    iteration costs exactly ONE lattice MVM regardless of k. It is NOT
    differentiable w.r.t. hyperparameters (use ``lattice_filter`` for the
    surrogate-loss terms). ``auto_cap`` right-sizes the table with
    grow-and-retry (syncs on the overflow flag, so only valid outside
    jit) — a much smaller table is what keeps the fused backend's VMEM
    plan viable at real scales. ``mesh`` makes every MVM data-parallel
    over its ``axis_name`` axis (sharding/simplex.py; one psum per call).
    """
    if auto_cap and cap is None:
        lat = lat_mod.build_lattice_auto(z, spacing=stencil.spacing,
                                         r=stencil.r, backend=build_backend)
    else:
        lat = lat_mod.build_lattice(z, spacing=stencil.spacing, r=stencil.r,
                                    cap=cap, backend=build_backend)
    w = jnp.asarray(stencil.weights, dtype=z.dtype)
    taps = tuple(stencil.weights)

    def matvec(v: Array) -> Array:
        return filter_mvm(lat, v, w, symmetrize=symmetrize, backend=backend,
                          taps=taps, mesh=mesh, axis_name=axis_name)

    return matvec, lat
