"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

cost_analysis() on the SPMD-partitioned executable reports PER-DEVICE
flops/bytes (the partitioned module has per-device shapes), so the
per-chip division is already done — we divide by per-chip peaks directly.
MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) over HLO_FLOPs measures how
much compiled compute is "useful" (catches remat/dispatch waste).
"""
from __future__ import annotations

import dataclasses
from typing import Any

# TPU v5e, per chip
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link (usable, one direction)


@dataclasses.dataclass
class Roofline:
    name: str
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device HLO bytes accessed
    collective_bytes: float  # per-device collective traffic
    model_flops: float  # analytic useful flops (global)
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / (chips * HLO_FLOPs): 1.0 = no wasted compute."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline (upper bound on MFU)."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS_BF16 * t)

    def row(self) -> dict:
        return dict(name=self.name, t_compute=self.t_compute,
                    t_memory=self.t_memory, t_collective=self.t_collective,
                    bottleneck=self.bottleneck,
                    useful=self.useful_fraction, mfu_bound=self.mfu_bound,
                    step_time=self.step_time)


def model_flops_train(cfg, seq_len: int, global_batch: int) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) + attention flops."""
    n_active = active_params(cfg)
    tokens = seq_len * global_batch
    base = 6.0 * n_active * tokens
    # causal attention: 6·b·s²·d_attn (qk + av, fwd+bwd) per layer
    attn = attention_flops(cfg, seq_len, global_batch, train=True)
    return base + attn


def model_flops_decode(cfg, context: int, global_batch: int) -> float:
    n_active = active_params(cfg)
    base = 2.0 * n_active * global_batch  # one token, fwd only
    attn = attention_flops(cfg, context, global_batch, train=False,
                           decode=True)
    return base + attn


def model_flops_prefill(cfg, seq_len: int, global_batch: int) -> float:
    n_active = active_params(cfg)
    tokens = seq_len * global_batch
    return 2.0 * n_active * tokens + attention_flops(
        cfg, seq_len, global_batch, train=False)


def active_params(cfg) -> float:
    """Parameters touched per token (MoE: top-k + shared only)."""
    n = cfg.num_params()
    if not cfg.moe:
        return float(n)
    d = cfg.d_model
    ff = 3 * d * cfg.moe_d_ff
    routed_all = cfg.num_experts * ff
    routed_active = cfg.moe_top_k * ff
    per_layer_delta = routed_all - routed_active
    n_moe_layers = cfg.num_layers - cfg.first_k_dense
    return float(n - n_moe_layers * per_layer_delta)


def attention_flops(cfg, seq_len: int, global_batch: int, *,
                    train: bool, decode: bool = False) -> float:
    if cfg.family == "ssm":
        # linear attention: O(s·d·hk) per layer, no quadratic term
        hk = cfg.rwkv_head_dim
        per_tok = 4.0 * cfg.d_model * hk * cfg.num_layers
        toks = global_batch * (1 if decode else seq_len)
        return (3.0 if train else 1.0) * per_tok * toks
    hd = cfg.resolved_head_dim if not cfg.mla else (
        cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    h = cfg.num_heads
    n_attn_layers = cfg.num_layers
    if cfg.family == "hybrid":
        n_attn_layers = cfg.num_layers // 3  # one attn per period
        window = cfg.local_window
        if decode:
            per = 4.0 * h * hd * min(window, seq_len) * global_batch
        else:
            per = (4.0 * h * hd * min(window, seq_len)
                   * seq_len * global_batch / 2)
        return (3.0 if train else 1.0) * per * n_attn_layers
    if decode:
        per = 4.0 * h * hd * seq_len * global_batch
    else:
        per = 2.0 * h * hd * seq_len * seq_len * global_batch  # causal ~ /2 *qk+av=4 -> 2
    return (3.0 if train else 1.0) * per * n_attn_layers


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'cell':42s} {'t_comp(s)':>10s} {'t_mem(s)':>10s} "
           f"{'t_coll(s)':>10s} {'bound':>10s} {'useful':>7s} "
           f"{'MFU≤':>6s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['name']:42s} {r['t_compute']:10.4f} {r['t_memory']:10.4f} "
            f"{r['t_collective']:10.4f} {r['bottleneck']:>10s} "
            f"{r['useful']:7.3f} {r['mfu_bound']:6.3f}")
    return "\n".join(lines)
