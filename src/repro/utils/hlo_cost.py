"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 60 layers contributes one layer body of FLOPs
(verified empirically: llama-3B train reported logits + 1 layer). All our
models scan layers, CG iterations, and microbatches, so the §Roofline
terms must scale loop bodies by their trip counts.

This module parses the optimized (SPMD-partitioned) HLO text:

  * builds a symbol table (op name -> shape) per computation,
  * counts dot FLOPs (2*M*N*K from output shape x contraction dims),
  * counts bytes accessed (operands + outputs at fusion boundaries),
  * counts collective bytes by kind,
  * resolves while-loop trip counts from the loop-condition constant
    (scan emits ``compare(iter, constant(N)), direction=LT``) and builds
    the computation call graph (while bodies, fusion calls, conditional
    branches) to multiply nested loops through.

Shapes in the partitioned module are per-device, so all results are
per-device per-step quantities — exactly what the roofline needs.
"""
from __future__ import annotations

import collections
import dataclasses
import re
from typing import NamedTuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "u64_2": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")

_SHAPE_ONE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
# tuple shapes may contain /*index=N*/ comments -> allow anything but parens
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:\S+))\s+"
    r"([\w\-]+)\(([^)]*(?:\([^)]*\))?[^)]*)\)(.*)$")
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?"
                       r"\s*->.*{\s*$|^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s+{")


def _shape_info(shape_str: str):
    """-> list of (dtype, dims) for one shape or tuple-shape string."""
    out = []
    for m in _SHAPE_ONE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dd = [int(x) for x in dims.split(",")] if dims else []
        out.append((dt, dd))
    return out


def _nbytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_info(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    kind: str
    operands: list
    attrs: str


class HloCost(NamedTuple):
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collective_by_kind: dict
    trip_counts: dict  # while computation -> resolved trip count
    flash_bytes: float = 0.0  # bytes inside flash-attention fallback loops


def _parse(text: str):
    """-> (computations: name -> [ops], op_shapes: per-comp symbol table)."""
    comps: dict = collections.OrderedDict()
    current = None
    for raw in text.splitlines():
        # strip /*index=N*/ tuple comments: they contain '=' and break
        # both header detection and shape parsing
        line = re.sub(r"/\*.*?\*/", "", raw).rstrip()
        if line.endswith("{") and "=" not in line.split("{")[0]:
            hdr = line.strip()
            name = hdr.split("(")[0].replace("ENTRY", "").strip()
            name = name.lstrip("%").strip()
            if name and not name.startswith("//"):
                current = name
                comps[current] = []
            continue
        if line.strip() == "}":
            continue
        m = _OP_LINE.match(line)
        if m and current is not None:
            name, shape, kind, operands, attrs = m.groups()
            opnds = [o.strip().lstrip("%") for o in operands.split(",")
                     if o.strip()]
            comps[current].append(_Op(name=name, shape=shape, kind=kind,
                                      operands=opnds, attrs=attrs))
    tables = {c: {op.name: op.shape for op in ops}
              for c, ops in comps.items()}
    return comps, tables


def _dot_flops(op: _Op, table: dict) -> float:
    """2 * numel(output) * contraction_size (+batch handled via output)."""
    out_elems = 1
    info = _shape_info(op.shape)
    if not info:
        return 0.0
    for d in info[0][1]:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    csize = 1
    if m and op.operands:
        # operand fragments are comma-split (typed shapes contain commas);
        # the lhs NAME is the first token across fragments that resolves
        # in the symbol table
        names = [t.lstrip("%") for frag in op.operands
                 for t in frag.split()]
        named = [t for t in names if t in table]
        lhs_shape = table.get(named[0], "") if named else ""
        linfo = _shape_info(lhs_shape)
        if linfo:
            dims = linfo[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    csize *= dims[idx]
    return 2.0 * out_elems * csize


def _op_bytes(op: _Op, table: dict) -> float:
    total = _nbytes(op.shape)
    for o in op.operands:
        nm = o.split(" ")[-1].lstrip("%")
        if nm in table:
            total += _nbytes(table[nm])
    return float(total)


def analyze(text: str) -> HloCost:
    comps, tables = _parse(text)

    # ---- call graph edges: (parent, child, multiplier-kind) -----------------
    calls: dict = collections.defaultdict(list)
    while_of_body: dict = {}
    trip_hint: dict = {}
    for cname, ops in comps.items():
        for op in ops:
            if op.kind == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                mt = re.search(r"known_trip_count\\?\":\s*{\\?\"n\\?\":"
                               r"\s*\\?\"(\d+)", op.attrs)
                if mb:
                    calls[cname].append((mb.group(1), "while"))
                    while_of_body[mb.group(1)] = (cname, mc.group(1)
                                                  if mc else None)
                    if mt:
                        trip_hint[mb.group(1)] = int(mt.group(1))
                if mc:
                    calls[cname].append((mc.group(1), "cond"))
            elif op.kind == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if m:
                    calls[cname].append((m.group(1), "call"))
            elif op.kind in ("call", "custom-call", "conditional"):
                for m in re.finditer(
                        r"(?:to_apply|branch_computations=\{|called_computations=\{|true_computation|false_computation)=?%?([\w.\-]+)",
                        op.attrs):
                    calls[cname].append((m.group(1), "call"))

    # ---- trip counts from loop-condition constants ---------------------------
    trip: dict = {}
    for body, (parent, cond) in while_of_body.items():
        if body in trip_hint:  # XLA's own known_trip_count wins
            trip[body] = trip_hint[body]
            continue
        count = None
        if cond and cond in comps:
            consts = []
            for op in comps[cond]:
                # `%c = s32[] constant(28)` parses with operands=['28']
                if op.kind == "constant" and op.operands \
                        and op.operands[0].isdigit() \
                        and op.shape.startswith(("s32", "s64", "u32")):
                    consts.append(int(op.operands[0]))
            # scan lowers to `lt(iter, N)`; take the largest plausible bound
            if consts:
                count = max(consts)
        trip[body] = count if count and count > 0 else 1

    # ---- per-computation local costs -----------------------------------------
    local = {}
    flash_comp = set()  # computations containing flash-attention ops
    for cname, ops in comps.items():
        table = tables[cname]
        fl = 0.0
        by = 0.0
        coll = collections.Counter()
        fused_bodies = {re.search(r"calls=%?([\w.\-]+)", op.attrs).group(1)
                        for op in ops if op.kind == "fusion"
                        and re.search(r"calls=%?([\w.\-]+)", op.attrs)}
        for op in ops:
            if op.kind in ("dot", "convolution"):
                fl += _dot_flops(op, table)
            if op.kind not in ("parameter", "constant", "tuple",
                               "get-tuple-element", "bitcast"):
                by += _op_bytes(op, table)
            if "flash_attention" in op.attrs:
                flash_comp.add(cname)
            for c in _COLLECTIVES:
                if op.kind == c or op.kind.startswith(c + "-"):
                    coll[c] += _nbytes(op.shape)
        local[cname] = (fl, by, coll, fused_bodies)

    # fusion bodies: dots inside fusions must still count as flops, but
    # their intermediate bytes are fused away (only boundary bytes count)
    # -> add fusion-body dot flops into the fusion's parent computation.

    # ---- accumulate through the call graph with multipliers ------------------
    import functools

    @functools.lru_cache(maxsize=None)
    def total(cname: str) -> tuple:
        if cname not in comps:
            return (0.0, 0.0, (), 0.0)
        fl, by, coll, fused = local[cname]
        fb = by if cname in flash_comp else 0.0
        coll = collections.Counter(dict(coll))
        for child, kind in calls.get(cname, ()):
            cf, cb, cc, cfb = total(child)
            mult = trip.get(child, 1) if kind == "while" else 1
            # fusion bodies: count dot flops, not bytes (fused)
            if child in fused:
                cb = 0.0
                cfb = 0.0
            fl += mult * cf
            by += mult * cb
            fb += mult * cfb
            for k, v in cc:
                coll[k] += mult * v
        return (fl, by, tuple(sorted(coll.items())), fb)

    # find the entry computation: the one nobody calls
    called = {child for kids in calls.values() for child, _ in kids}
    entries = [c for c in comps if c not in called]
    fl = by = fb = 0.0
    coll = collections.Counter()
    roots = entries or list(comps)[:1]
    # prefer a computation whose name marks it as entry/main
    mains = [c for c in roots if "main" in c or "entry" in c.lower()]
    for c in (mains or roots):
        cf, cb, cc, cfb = total(c)
        fl += cf
        by += cb
        fb += cfb
        for k, v in cc:
            coll[k] += v

    return HloCost(flops=fl, bytes_accessed=by,
                   collective_bytes=float(sum(coll.values())),
                   collective_by_kind=dict(coll),
                   trip_counts={b: trip[b] for b in trip},
                   flash_bytes=fb)
