"""HLO text analysis: collective bytes + op census for the roofline.

``cost_analysis()`` does not expose collective traffic, so we parse the
(optimized, partitioned) HLO: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute contributes its operand
bytes. Shapes in the partitioned module are PER-DEVICE shapes, so summed
bytes are per-device traffic per step — exactly the numerator of the
collective roofline term.
"""
from __future__ import annotations

import collections
import re
from typing import NamedTuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """bytes of 'f32[128,256]' / tuple '(f32[2], s32[3])' fragments."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


class CollectiveStats(NamedTuple):
    total_bytes: int
    by_kind: dict  # kind -> (count, bytes)
    in_loops: int  # collectives appearing inside while-loop bodies


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum output bytes of every collective op (per-device traffic).

    While-loop bodies (scanned layers) execute trip-count times; the
    caller scales loop-resident collectives by the layer count — we report
    them separately so utils/roofline.py can do that.
    """
    by_kind: dict = collections.defaultdict(lambda: [0, 0])
    total = 0
    in_loops = 0
    current_computation = ""
    loop_computations = set()
    # identify while-body computations to attribute loop-resident traffic
    for m in re.finditer(r"while\(.*?\).*?body=([%\w.\-]+)", hlo_text):
        loop_computations.add(m.group(1).lstrip("%"))

    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:%)?([\w.\-]+)\s*(?:\([^)]*\))?\s*{", ls)
        if m and ("{" in ls) and ("=" not in ls):
            current_computation = m.group(1)
        opm = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|\S+)\s+"
                       r"([\w\-]+)\(", ls)
        if not opm:
            continue
        shape_str, op = opm.group(1), opm.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):
                kind = c
                break
        if kind is None:
            continue
        b = shape_bytes(shape_str)
        by_kind[kind][0] += 1
        by_kind[kind][1] += b
        total += b
        if current_computation in loop_computations:
            in_loops += b
    return CollectiveStats(total_bytes=total,
                           by_kind={k: tuple(v) for k, v in by_kind.items()},
                           in_loops=in_loops)


def op_census(hlo_text: str, ops: tuple[str, ...] = ("fusion", "dot",
                                                     "custom-call",
                                                     "while", "reshape",
                                                     "transpose")) -> dict:
    census: dict = collections.Counter()
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\S+\s+([\w\-]+)\(",
                     line)
        if m:
            op = m.group(1)
            for want in ops:
                if op == want:
                    census[op] += 1
    return dict(census)
