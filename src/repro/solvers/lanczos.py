"""Lanczos tridiagonalization + stochastic Lanczos quadrature (SLQ).

Used for the log-determinant term of the MLL (paper Eq. 4) exactly as in
BBMM (Gardner et al. 2018a): with Rademacher probes ``z_i``,

    log|A| ~= (1/p) sum_i ||z_i||^2 * e_1^T log(T_i) e_1 ,

where ``T_i`` is the Lanczos tridiagonalization of ``A`` started at
``z_i/||z_i||``. Appendix A caps Lanczos at 100 iterations.

Everything is static-shape (``lax.scan``) and batched over probes with
``vmap`` so it lowers to a single While op — dry-run friendly.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
MatVec = Callable[[Array], Array]  # (n, k) -> (n, k)


class LanczosResult(NamedTuple):
    alphas: Array  # (iters, k) tridiagonal diagonal
    betas: Array  # (iters, k) sub/super-diagonal; betas[j] couples j, j+1
    q: Array  # (iters, n, k) Lanczos basis (needed by LOVE-style variance)
    valid: Array  # (iters, k) True while the recurrence was healthy


def lanczos(matvec: MatVec, q0: Array, num_iters: int,
            *, reorthogonalize: bool = True) -> LanczosResult:
    """Block-of-columns Lanczos with optional full reorthogonalization.

    q0: (n, k) start vectors (normalized internally). ``num_iters`` is small
    (<= 100 per Appendix A) so full reorthogonalization is O(iters^2 n k) but
    cheap, and necessary in float32.
    """
    n, k = q0.shape
    dt = q0.dtype
    q = q0 / jnp.maximum(jnp.linalg.norm(q0, axis=0), 1e-30)

    def body(carry, i):
        q_prev, q_cur, beta_prev, basis, alive = carry
        w = matvec(q_cur)
        alpha = jnp.sum(q_cur * w, axis=0)
        w = w - alpha * q_cur - beta_prev * q_prev
        if reorthogonalize:
            # w -= Q (Q^T w), Q = collected basis (rows masked by step < i)
            proj = jnp.einsum("jnk,nk->jk", basis, w)
            step_mask = (jnp.arange(num_iters) <= i)[:, None]
            proj = proj * step_mask
            w = w - jnp.einsum("jnk,jk->nk", basis, proj)
        beta = jnp.linalg.norm(w, axis=0)
        healthy = alive & (beta > 1e-10)
        q_next = jnp.where(healthy, w / jnp.maximum(beta, 1e-30), q_cur)
        basis = basis.at[i].set(q_cur)
        out = (alpha, jnp.where(healthy, beta, 0.0), alive)
        return (q_cur, q_next, jnp.where(healthy, beta, 0.0), basis, healthy), out

    basis0 = jnp.zeros((num_iters, n, k), dt)
    init = (jnp.zeros_like(q), q, jnp.zeros((k,), dt), basis0,
            jnp.ones((k,), bool))
    (_, _, _, basis, _), (alphas, betas, valid) = jax.lax.scan(
        body, init, jnp.arange(num_iters))
    return LanczosResult(alphas=alphas, betas=betas, q=basis, valid=valid)


def _tridiag_to_dense(alpha: Array, beta: Array, valid: Array) -> Array:
    """(iters,) coeffs -> (iters, iters) dense symmetric tridiagonal.

    Rows past breakdown are replaced by identity so eigenvalues contribute
    log(1) = 0.
    """
    t = jnp.diag(jnp.where(valid, alpha, 1.0))
    off = beta[:-1] * valid[:-1] * valid[1:]
    t = t + jnp.diag(off, 1) + jnp.diag(off, -1)
    return t


def slq_quadrature(alphas: Array, betas: Array, valid: Array,
                   f: Callable[[Array], Array]) -> Array:
    """e_1^T f(T) e_1 for each column's tridiagonal. -> (k,)"""

    def one(alpha, beta, v):
        t = _tridiag_to_dense(alpha, beta, v)
        evals, evecs = jnp.linalg.eigh(t)
        w = evecs[0, :] ** 2
        return jnp.sum(w * f(evals))

    return jax.vmap(one, in_axes=(1, 1, 1))(alphas, betas, valid)


def slq_logdet(matvec: MatVec, n: int, *, key: Array, num_probes: int = 10,
               num_iters: int = 100, dtype=jnp.float32) -> Array:
    """SLQ estimate of log|A| for SPD A of size n."""
    z = jax.random.rademacher(key, (n, num_probes), dtype=dtype)
    res = lanczos(matvec, z, num_iters)
    safe_log = lambda lam: jnp.log(jnp.maximum(lam, 1e-30))
    quad = slq_quadrature(res.alphas, res.betas, res.valid, safe_log)
    znorm2 = jnp.sum(z * z, axis=0)
    return jnp.mean(znorm2 * quad)


def slq_logdet_from_cg(alphas: Array, betas: Array, valid: Array,
                       probe_norms2: Array) -> Array:
    """Log-det estimate reusing mBCG's tridiagonal coefficients.

    alphas/betas/valid: as returned by solvers.cg (per probe column);
    probe_norms2: (p,) squared norms of the Rademacher probes (= n).
    """
    from repro.solvers.cg import CGInfo, lanczos_tridiag_from_cg

    info = CGInfo(iterations=None, residual_norms=None, converged=None,
                  alphas=alphas, betas=betas, valid=valid)
    diag, off = lanczos_tridiag_from_cg(info)
    pad_off = jnp.concatenate([off, jnp.zeros((1, off.shape[1]), off.dtype)])
    safe_log = lambda lam: jnp.log(jnp.maximum(lam, 1e-30))
    quad = slq_quadrature(diag, pad_off, jnp.ones_like(diag, bool), safe_log)
    return jnp.mean(probe_norms2 * quad)
