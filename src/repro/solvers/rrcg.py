"""RR-CG: Russian-roulette randomized-truncation CG (paper §5.4, Table 4).

Potapczynski et al. (2021): plain CG truncated at J iterations is a *biased*
solver; reweighting the per-iteration increments by inverse survival
probabilities makes it unbiased in expectation:

    x_RR = sum_{j <= J} dx_j / P(J >= j),   J ~ truncation distribution.

We run the standard CG scan to ``max_iters`` (static shape), sample J once,
and combine the recorded increments — so a *single* compiled program serves
every sampled truncation. The truncation distribution follows the reference
implementation: geometric over [min_iters, max_iters], which concentrates
compute near the typical convergence point while keeping heavy tails for
unbiasedness. Table 4's observation (RR-CG ~ tol-1e-2 runtime with tol-1e-8
stability) comes from sampling mostly-short truncations.

Note: in this static-shape formulation the *compute* cost is max_iters
MVMs per solve regardless of J (TPU scans cannot early-exit); the paper's
wall-clock gains appear on dynamic-dispatch backends. We therefore also
expose ``expected_iters`` so benchmarks (table4) can report the *effective*
MVM count a dynamic runtime would execute — that is the honest cross-backend
comparison.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
MatVec = Callable[[Array], Array]


class RRCGResult(NamedTuple):
    x: Array  # (n, k) unbiased solve estimate
    j: Array  # () sampled truncation
    weights: Array  # (max_iters,) 1/P(J >= j) reweighting actually applied


def survival_probs(min_iters: int, max_iters: int, q: float = 0.95) -> jnp.ndarray:
    """P(J >= j) for j = 1..max_iters under the truncated-geometric law."""
    j = jnp.arange(1, max_iters + 1)
    # deterministic up to min_iters, geometric tail afterwards
    tail = q ** jnp.maximum(j - min_iters, 0).astype(jnp.float32)
    return jnp.clip(tail, 1e-12, 1.0)


def sample_truncation(key: Array, min_iters: int, max_iters: int,
                      q: float = 0.95) -> Array:
    """Sample J: min_iters + Geometric(1-q), clipped to max_iters."""
    u = jax.random.uniform(key, ())
    geo = jnp.floor(jnp.log(u) / jnp.log(q)).astype(jnp.int32)
    return jnp.clip(min_iters + geo, min_iters, max_iters)


def rrcg(matvec: MatVec, b: Array, *, key: Array,
         precond: MatVec | None = None, min_iters: int = 20,
         max_iters: int = 200, q: float = 0.95) -> RRCGResult:
    """Unbiased randomized-truncation CG solve of ``A x = b``."""
    n, k = b.shape
    dt = b.dtype
    minv = precond or (lambda v: v)

    j_trunc = sample_truncation(key, min_iters, max_iters, q)
    surv = survival_probs(min_iters, max_iters, q).astype(dt)

    def body(carry, j):
        x, r, z, p, rz = carry
        ap = matvec(p)
        pap = jnp.sum(p * ap, axis=0)
        alpha = jnp.where(pap > 0, rz / jnp.where(pap > 0, pap, 1.0), 0.0)
        dx = alpha * p
        x = x + dx
        r = r - alpha * ap
        z = minv(r)
        rz_new = jnp.sum(r * z, axis=0)
        beta = rz_new / jnp.where(rz != 0, rz, 1.0)
        p = z + beta * p
        return (x, r, z, p, rz_new), dx

    r0 = b
    z0 = minv(r0)
    init = (jnp.zeros_like(b), r0, z0, z0, jnp.sum(r0 * z0, axis=0))
    _, dxs = jax.lax.scan(body, init, jnp.arange(max_iters))

    jidx = jnp.arange(1, max_iters + 1)
    w = jnp.where(jidx <= j_trunc, 1.0 / surv, 0.0)  # (max_iters,)
    x = jnp.einsum("j,jnk->nk", w, dxs)
    return RRCGResult(x=x, j=j_trunc, weights=w)


def expected_iters(min_iters: int, max_iters: int, q: float = 0.95) -> float:
    """E[J]: the effective MVM count a dynamic backend would run (Table 4)."""
    surv = survival_probs(min_iters, max_iters, q)
    return float(jnp.sum(surv))
