"""Rank-k pivoted Cholesky preconditioner (Appendix A: rank 100).

Greedy partial Cholesky of the *exact* kernel matrix: at each step pick the
pivot with the largest residual diagonal, append the corresponding scaled
residual column. The preconditioner for CG on ``K + sigma^2 I`` is then

    P = L L^T + sigma^2 I ,     P^{-1} via Woodbury:
    P^{-1} v = (v - L (sigma^2 I_k + L^T L)^{-1} L^T v) / sigma^2 .

Only ``rank`` exact kernel *rows* are ever formed (O(rank * n * d) total),
so the preconditioner never materializes K — the same trick GPyTorch uses.
The whole build is a ``lax.scan`` with static rank: jittable, TPU-safe.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class PivotedCholesky(NamedTuple):
    l: Array  # (n, rank)
    pivots: Array  # (rank,) int32
    error: Array  # () trace of the residual diagonal


def pivoted_cholesky(row_fn: Callable[[Array], Array], diag: Array,
                     rank: int) -> PivotedCholesky:
    """Greedy rank-`rank` Cholesky. row_fn(i) -> K[i, :] (length n)."""
    n = diag.shape[0]
    dt = diag.dtype

    def body(carry, j):
        d, l = carry
        piv = jnp.argmax(d).astype(jnp.int32)
        dp = jnp.maximum(d[piv], 1e-30)
        row = row_fn(piv)  # (n,)
        # residual column: row - L[:, :j] @ L[piv, :j], mask cols >= j
        mask = (jnp.arange(rank) < j).astype(dt)
        corr = l @ (l[piv] * mask)
        col = (row - corr) / jnp.sqrt(dp)
        d_new = jnp.maximum(d - col * col, 0.0)
        d_new = d_new.at[piv].set(0.0)
        l = l.at[:, j].set(col)
        return (d_new, l), piv

    init = (diag, jnp.zeros((n, rank), dt))
    (d_final, l), pivots = jax.lax.scan(body, init, jnp.arange(rank))
    return PivotedCholesky(l=l, pivots=pivots, error=jnp.sum(d_final))


def woodbury_precond(l: Array, sigma2: Array) -> Callable[[Array], Array]:
    """Return ``v -> (L L^T + sigma^2 I)^{-1} v`` via the Woodbury identity."""
    rank = l.shape[1]
    inner = sigma2 * jnp.eye(rank, dtype=l.dtype) + l.T @ l
    chol = jnp.linalg.cholesky(inner)

    def apply(v: Array) -> Array:
        lt_v = l.T @ v  # (rank, k)
        sol = jax.scipy.linalg.cho_solve((chol, True), lt_v)
        return (v - l @ sol) / sigma2

    return apply


def precond_logdet(l: Array, sigma2: Array, n: int) -> Array:
    """log|L L^T + sigma^2 I| (matrix determinant lemma)."""
    rank = l.shape[1]
    inner = jnp.eye(rank, dtype=l.dtype) + (l.T @ l) / sigma2
    sign, ld = jnp.linalg.slogdet(inner)
    return ld + n * jnp.log(sigma2)
