"""Batched (preconditioned) conjugate gradients — the BBMM workhorse (§2, §5.4).

The paper's inference loop (GPyTorch-style BBMM, Gardner et al. 2018a) needs
only MVMs ``v -> K_hat v``. We implement *mBCG*: CG over a block of
right-hand-sides ``B = [y | z_1 .. z_p]`` that simultaneously

  * solves ``K_hat X = B``,
  * collects the Lanczos tridiagonal coefficients (alpha, beta) per column,
    which SLQ (solvers/lanczos.py) turns into a log-det estimate "for free".

TPU notes: the loop is a ``lax.scan`` over a *static* ``max_iters`` with a
convergence mask that freezes finished columns — dynamic trip counts do not
exist on TPU, and a scan keeps the HLO a single While op so the 40-cell
dry-run stays compilable. The mask also reproduces the paper's "CG error
tolerance" semantics (Appendix A: tol 1.0 train / 0.01 eval): a column stops
updating once ``||r|| <= tol * ||b||``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
MatVec = Callable[[Array], Array]  # (n, k) -> (n, k)


class CGInfo(NamedTuple):
    iterations: Array  # () int32: iterations actually used (max over columns)
    residual_norms: Array  # (k,) final ||r_j|| / ||b_j||
    converged: Array  # (k,) bool
    alphas: Array  # (max_iters, k) Lanczos-from-CG coefficients
    betas: Array  # (max_iters, k)
    valid: Array  # (max_iters, k) bool: True where the iterate was active


def _identity_precond(v: Array) -> Array:
    return v


def _seed_state(matvec: MatVec, b: Array,
                x0: Array | None) -> tuple[Array, Array]:
    """Initial (x, r) with warm-start hygiene (DESIGN.md §14).

    Warm-start seeds come from durable state — a previous Predictor's
    alpha, possibly restored from a checkpoint written under DIFFERENT
    hyperparameters or data — so they are sanitized, never trusted:
    non-finite entries are zeroed (one NaN would poison the whole Krylov
    basis), and any column whose seed residual is WORSE than the zero
    start (``||b - A x0|| > ||b||``) is reset to the cold start for that
    column. A stale seed can therefore only help or be ignored; it can
    never make the solve slower to converge than a cold one.
    """
    if x0 is None:
        return jnp.zeros_like(b), b
    x = jnp.where(jnp.isfinite(x0), x0, 0.0).astype(b.dtype)
    r = b - matvec(x)
    worse = (jnp.linalg.norm(r, axis=0)
             > jnp.linalg.norm(b, axis=0))  # (k,) regressive seeds
    x = jnp.where(worse[None, :], 0.0, x)
    r = jnp.where(worse[None, :], b, r)
    return x, r


def cg(
    matvec: MatVec,
    b: Array,
    *,
    precond: MatVec | None = None,
    tol: float | Array = 1e-2,
    max_iters: int = 500,
    min_iters: int = 10,
    x0: Array | None = None,
) -> tuple[Array, CGInfo]:
    """Preconditioned CG on SPD ``A`` for a block of RHS columns.

    Multi-RHS contract: the whole block advances together — each
    iteration issues exactly ONE ``matvec`` on the full (n, k) block
    (never one per column), so with a lattice operator every iteration
    costs one batched lattice MVM regardless of how many probes ride
    along. ``kernels.blur.ops.mvm_count``/``mvm_cols`` instrument this
    (tests/test_solvers.py pins it); sharded operators (DESIGN.md §10)
    then also pay one psum per iteration, not k.

    Args:
      matvec: ``v -> A v`` over (n, k) blocks.
      b: (n, k) right-hand sides.
      precond: ``v -> P^{-1} v`` (SPD); None = identity.
      tol: relative residual tolerance (paper Appendix A: 1.0 train / 0.01 eval).
      max_iters: static scan length (paper Appendix A: 500).
      min_iters: iterations always run before the tolerance may stop a
        column (GPyTorch semantics — at the paper's train tolerance 1.0 the
        *initial* relative residual is exactly 1, so without a floor CG
        would do nothing; GPyTorch's 10-iteration floor is what actually
        does the work at tol=1).
      x0: optional initial guess.

    Returns:
      x: (n, k) approximate solves.
      info: CGInfo, including the (alpha, beta) tridiagonal coefficients of
        the *preconditioned* operator, for SLQ.
    """
    if b.ndim == 1:
        raise ValueError("cg expects (n, k) column-blocked RHS; got 1-D")
    minv = precond or _identity_precond
    n, k = b.shape
    dt = b.dtype

    x, r = _seed_state(matvec, b, x0)
    z = minv(r)
    p = z
    rz = jnp.sum(r * z, axis=0)  # (k,)
    bnorm = jnp.maximum(jnp.linalg.norm(b, axis=0), 1e-30)
    tol_arr = jnp.asarray(tol, dt)
    min_iters = min(min_iters, max_iters)

    def body(carry, j):
        x, r, z, p, rz, active = carry
        ap = matvec(p)
        pap = jnp.sum(p * ap, axis=0)
        # guard: inactive / degenerate columns get alpha = 0 (no update)
        safe_pap = jnp.where(pap > 0, pap, 1.0)
        alpha = jnp.where(active & (pap > 0), rz / safe_pap, 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        z = minv(r)
        rz_new = jnp.sum(r * z, axis=0)
        safe_rz = jnp.where(rz != 0, rz, 1.0)
        beta = jnp.where(active, rz_new / safe_rz, 0.0)
        p = z + beta * p
        res = jnp.linalg.norm(r, axis=0) / bnorm
        still = active & ((res > tol_arr) | (j + 1 < min_iters))
        out = (alpha, beta, active)
        return (x, r, z, p, rz_new, still), out

    active0 = jnp.ones((k,), bool)
    init = (x, r, z, p, rz, active0)
    (x, r, *_rest), (alphas, betas, valids) = jax.lax.scan(
        body, init, jnp.arange(max_iters))

    res = jnp.linalg.norm(r, axis=0) / bnorm
    iters = jnp.sum(jnp.any(valids, axis=1).astype(jnp.int32))
    info = CGInfo(
        iterations=iters,
        residual_norms=res,
        converged=res <= tol_arr,
        alphas=alphas,
        betas=betas,
        valid=valids,
    )
    return x, info


def cg_while(
    matvec: MatVec,
    b: Array,
    *,
    precond: MatVec | None = None,
    tol: float | Array = 1e-2,
    max_iters: int = 500,
    min_iters: int = 10,
    x0: Array | None = None,
) -> tuple[Array, CGInfo]:
    """Early-exit CG twin of ``cg`` for WARM-STARTED solves (no SLQ).

    The scan-based ``cg`` runs its static ``max_iters`` trip count even
    after every column converges (frozen columns just stop updating) —
    the right trade when the Lanczos coefficients are wanted for SLQ and
    the solve is cold. A warm-started solve (gp/serve.refreeze seeding
    from the previous Predictor's alpha) converges in a handful of
    iterations, so here the loop is a ``lax.while_loop`` that exits as
    soon as every column is done — the wall-clock win warm starting is
    for. Columns whose ``x0`` residual is already within ``tol`` start
    INACTIVE (zero iterations), so a perfect seed costs one matvec.
    Seeds pass through ``_seed_state`` hygiene first: non-finite entries
    are zeroed and regressive columns fall back to the cold start, so an
    alpha restored from an old checkpoint (the warm-boot path) can only
    help, never hurt.

    Same operator/stopping semantics as ``cg`` (identical iterates while
    active, same ``min_iters`` refinement floor for active columns); the
    returned ``CGInfo`` carries real iteration/residual/convergence
    diagnostics but EMPTY (0, k) Lanczos coefficient arrays — use ``cg``
    when SLQ needs them.
    """
    if b.ndim == 1:
        raise ValueError("cg_while expects (n, k) column-blocked RHS; "
                         "got 1-D")
    minv = precond or _identity_precond
    n, k = b.shape
    dt = b.dtype

    x, r = _seed_state(matvec, b, x0)
    z = minv(r)
    p = z
    rz = jnp.sum(r * z, axis=0)
    bnorm = jnp.maximum(jnp.linalg.norm(b, axis=0), 1e-30)
    tol_arr = jnp.asarray(tol, dt)
    min_iters = min(min_iters, max_iters)
    # a cold start must enter the loop even at tol >= 1 (the min_iters
    # contract); a warm start may skip columns its seed already solved
    if x0 is None:
        active0 = jnp.ones((k,), bool)
    else:
        active0 = jnp.linalg.norm(r, axis=0) / bnorm > tol_arr

    def cond(state):
        j, *_rest, active = state
        return (j < max_iters) & jnp.any(active)

    def body(state):
        j, x, r, z, p, rz, active = state
        ap = matvec(p)
        pap = jnp.sum(p * ap, axis=0)
        safe_pap = jnp.where(pap > 0, pap, 1.0)
        alpha = jnp.where(active & (pap > 0), rz / safe_pap, 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        z = minv(r)
        rz_new = jnp.sum(r * z, axis=0)
        safe_rz = jnp.where(rz != 0, rz, 1.0)
        beta = jnp.where(active, rz_new / safe_rz, 0.0)
        p = z + beta * p
        res = jnp.linalg.norm(r, axis=0) / bnorm
        still = active & ((res > tol_arr) | (j + 1 < min_iters))
        return (j + 1, x, r, z, p, rz_new, still)

    state = (jnp.zeros((), jnp.int32), x, r, z, p, rz, active0)
    j, x, r, *_rest = jax.lax.while_loop(cond, body, state)

    res = jnp.linalg.norm(r, axis=0) / bnorm
    empty = jnp.zeros((0, k), dt)
    info = CGInfo(
        iterations=j,
        residual_norms=res,
        converged=res <= tol_arr,
        alphas=empty,
        betas=empty,
        valid=jnp.zeros((0, k), bool),
    )
    return x, info


def lanczos_tridiag_from_cg(info: CGInfo) -> tuple[Array, Array]:
    """Recover symmetric-tridiagonal (diag, offdiag) per column from CG.

    Standard CG<->Lanczos identity (Golub & Van Loan §10):
      T[0,0]   = 1/alpha_0
      T[j,j]   = 1/alpha_j + beta_{j-1}/alpha_{j-1}
      T[j,j-1] = sqrt(beta_{j-1}) / alpha_{j-1}

    Returns (diag, offdiag) with shapes (max_iters, k), (max_iters-1, k);
    entries past a column's convergence are padded so that eigenvalues appear
    as exact 1.0 (harmless for log-dets of unit-free operators we use this
    with — SLQ masks them out via ``valid`` anyway).
    """
    a, b, valid = info.alphas, info.betas, info.valid
    safe_a = jnp.where(valid & (a != 0), a, 1.0)
    inv_a = 1.0 / safe_a
    diag0 = inv_a[:1]
    diag_rest = inv_a[1:] + jnp.where(valid[:-1], b[:-1] / safe_a[:-1], 0.0)
    diag = jnp.concatenate([diag0, diag_rest], axis=0)
    off = jnp.where(valid[:-1] & (b[:-1] >= 0),
                    jnp.sqrt(jnp.maximum(b[:-1], 0.0)) / safe_a[:-1], 0.0)
    # freeze rows after convergence to identity
    diag = jnp.where(valid, diag, 1.0)
    return diag, off
