"""Krylov solvers for MVM-based GP inference (BBMM)."""
from repro.solvers.cg import CGInfo, cg, cg_while, lanczos_tridiag_from_cg
from repro.solvers.lanczos import (LanczosResult, lanczos, slq_logdet,
                                   slq_logdet_from_cg, slq_quadrature)
from repro.solvers.pivoted_cholesky import (PivotedCholesky, pivoted_cholesky,
                                            precond_logdet, woodbury_precond)
from repro.solvers.rrcg import RRCGResult, expected_iters, rrcg

__all__ = [
    "CGInfo", "cg", "cg_while", "lanczos_tridiag_from_cg",
    "LanczosResult", "lanczos", "slq_logdet", "slq_logdet_from_cg",
    "slq_quadrature",
    "PivotedCholesky", "pivoted_cholesky", "precond_logdet",
    "woodbury_precond",
    "RRCGResult", "expected_iters", "rrcg",
]
