"""LM training launcher: data pipeline + checkpoints + fault tolerance.

End-to-end host loop wiring every runtime substrate together:

  * deterministic step-indexed data (data/tokens.py) behind a prefetch
    thread (data/pipeline.py),
  * jit'd train step with the partition specs when a mesh is requested,
  * CheckpointManager (atomic/async/keep-k) with resume-from-latest —
    restart this script after a kill and it continues from the last save,
  * StepWatchdog straggler detection -> deterministic skip of slow steps,
  * optional int8 error-feedback gradient compression (--compress-grads)
    through a shard_map'd DP all-reduce.

CPU-reduced example: examples/lm_train.py drives this for a ~100M model.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get as get_config, smoke as smoke_config
from repro.data.pipeline import Prefetcher
from repro.data.tokens import TokenStream
from repro.models import build
from repro.optim import Adam, schedules
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.straggler import StepTimer, StepWatchdog


@dataclasses.dataclass
class TrainConfig:
    arch: str = "llama3.2-3b"
    smoke: bool = True
    steps: int = 200
    global_batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    warmup: int = 20
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    resume: bool = True
    seed: int = 0
    log_every: int = 10


def make_model_and_step(tc: TrainConfig):
    cfg = smoke_config(tc.arch) if tc.smoke else get_config(tc.arch)
    lm = build(cfg)
    opt = Adam(learning_rate=schedules.warmup_cosine(
        tc.lr, tc.warmup, tc.steps), clip_global_norm=1.0)
    train_step, _ = lm.make_train_step(opt)
    return cfg, lm, opt, jax.jit(train_step)


def run(tc: TrainConfig, *, log=print) -> dict:
    cfg, lm, opt, train_step = make_model_and_step(tc)
    params = lm.init_params(jax.random.PRNGKey(tc.seed))
    opt_state = opt.init(params)
    start_step = 0

    manager = None
    if tc.ckpt_dir:
        manager = CheckpointManager(tc.ckpt_dir, keep_last=2, keep_best=1)
        if tc.resume and manager.latest_step() is not None:
            start_step = manager.latest_step()
            tree = manager.restore(start_step,
                                   {"params": params, "opt": opt_state})
            params, opt_state = tree["params"], tree["opt"]
            log(f"resumed from step {start_step}")

    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=tc.seq_len,
                         global_batch=tc.global_batch, seed=tc.seed)

    def make_batch(step: int) -> dict:
        raw = stream.batch(step)
        batch = {
            "tokens": jnp.asarray(raw["tokens"]),
            "labels": jnp.asarray(raw["labels"]),
            "loss_mask": jnp.ones(raw["labels"].shape, jnp.float32),
        }
        if cfg.is_encdec:  # stub frame embeddings, deterministic per step
            rng = np.random.default_rng(tc.seed * 7919 + step)
            batch["frames"] = jnp.asarray(rng.normal(size=(
                tc.global_batch, cfg.encoder_frames,
                cfg.d_model)).astype(np.float32))
        return batch

    pf = Prefetcher(make_batch, start_step=start_step, depth=2)
    watchdog = StepWatchdog(
        multiplier=5.0, min_deadline=30.0,
        on_breach=lambda s, d: (log(f"WATCHDOG step {s} > {d:.0f}s; "
                                    f"skipping {s + 1}"), pf.skip(s + 1)))

    losses = []
    t_start = time.perf_counter()
    try:
        for step, batch in pf:
            if step >= tc.steps:
                break
            with StepTimer(watchdog, step):
                params, opt_state, metrics = train_step(params, opt_state,
                                                        batch)
            if step % tc.log_every == 0 or step == tc.steps - 1:
                loss = float(metrics["loss"])
                losses.append((step, loss))
                log(f"step {step:5d}  loss {loss:.4f}  "
                    f"({time.perf_counter() - t_start:.1f}s)")
            if manager and step and step % tc.ckpt_every == 0:
                manager.save(step, {"params": params, "opt": opt_state},
                             metric=float(metrics["loss"]))
    finally:
        pf.close()
        if manager:
            manager.wait()
    if manager:
        manager.save(tc.steps, {"params": params, "opt": opt_state},
                     metric=losses[-1][1] if losses else None)
        manager.wait()
    return {"params": params, "losses": losses,
            "breaches": watchdog.breaches}


def main():
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(TrainConfig):
        kind = f.type if isinstance(f.type, type) else str
        if f.type in ("bool", bool):
            ap.add_argument(f"--{f.name.replace('_', '-')}",
                            type=lambda v: v.lower() in ("1", "true"),
                            default=f.default)
        else:
            typ = {"str": str, "int": int, "float": float,
                   "str | None": str}.get(str(f.type), str)
            ap.add_argument(f"--{f.name.replace('_', '-')}", type=typ,
                            default=f.default)
    args = ap.parse_args()
    run(TrainConfig(**vars(args)))


if __name__ == "__main__":
    main()
