"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — dryrun.py sets XLA_FLAGS for 512 placeholder
devices before any jax import; smoke tests see the 1 real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(model: int = 2, data: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for unit tests (uses however many devices exist)."""
    if multi_pod:
        return jax.make_mesh((2, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
