import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))
# ^ MUST precede every other import (jax locks device count on first init).
# (No `from __future__ import annotations` here for the same reason — the
#  env var assignment must be the first statements in the file.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds abstract params / optimizer state / batch
(ShapeDtypeStruct only — nothing is allocated), jits the step with the
partition specs from sharding/partition.py, and compiles for the
production mesh. Success proves the distribution config is coherent;
memory_analysis shows it fits; cost_analysis + HLO collective parsing feed
EXPERIMENTS.md §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get as get_config
from repro.launch.mesh import make_production_mesh
from repro.models import SHAPES, build
from repro.models.config import ModelConfig
from repro.optim import Adam
from repro.sharding import partition
from repro.sharding.constraints import activation_mesh
from repro.utils import hlo as hlo_mod
from repro.utils import hlo_cost as hlo_cost_mod
from repro.utils import roofline as roof_mod

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results"

# Full-attention archs skip long_500k (O(L^2) attention; DESIGN.md §4).
FULL_ATTN = {"glm4-9b", "llama3.2-3b", "minitron-4b", "phi3-medium-14b",
             "moonshot-v1-16b-a3b", "deepseek-v2-236b", "qwen2-vl-7b",
             "whisper-tiny"}

# Gradient accumulation for cells whose activations exceed HBM otherwise.
MICROBATCHES = {"deepseek-v2-236b": 4, "moonshot-v1-16b-a3b": 2}


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch in FULL_ATTN:
        return False, "SKIP(full-attn): O(L^2) attention at 500k"
    return True, ""


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float))}


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (jitted_fn, abstract_args) for one cell."""
    cfg = get_config(arch)
    lm = build(cfg)
    shape = SHAPES[shape_name]
    params_abs = lm.abstract_params()
    pspecs = partition.param_specs(cfg, mesh, params_abs)
    psharding = partition.named(mesh, pspecs)

    batch_abs = lm.input_specs(shape)
    bspecs = partition.batch_specs(cfg, mesh, batch_abs)
    bsharding = jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s) if not isinstance(
            s, jax.NamedSharding) else s,
        bspecs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    if shape.kind == "train":
        opt = Adam(learning_rate=1e-4, clip_global_norm=1.0)
        train_step, _ = lm.make_train_step(
            opt, microbatches=MICROBATCHES.get(arch, 1))
        opt_abs = jax.eval_shape(opt.init, params_abs)
        ospecs = partition.opt_state_specs(pspecs, mesh)
        osharding = partition.named(mesh, ospecs)
        fn = jax.jit(train_step,
                     in_shardings=(psharding, osharding, bsharding),
                     donate_argnums=(0, 1))
        args = (params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        fn = jax.jit(lm.prefill, in_shardings=(psharding, bsharding))
        args = (params_abs, batch_abs)
    else:  # decode
        state_abs = batch_abs["state"]
        ssharding = bsharding["state"]
        tok_sh = bsharding["tokens"]
        pos_sh = bsharding["position"]
        fn = jax.jit(lm.serve_step,
                     in_shardings=(psharding, ssharding, tok_sh, pos_sh),
                     donate_argnums=(1,))
        args = (params_abs, state_abs, batch_abs["tokens"],
                batch_abs["position"])
    return cfg, shape, fn, args


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             *, save: bool = True, verbose: bool = True) -> dict:
    ok, reason = cell_supported(arch, shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        result["status"] = reason
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_kind}] {reason}")
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        cfg, shape, fn, args = build_cell(arch, shape_name, mesh)
        shp = SHAPES[shape_name]
        dp = partition.mesh_axis_size(mesh, partition.dp_axes(mesh))
        seq_shard = (shp.global_batch % dp) != 0
        with mesh, activation_mesh(mesh, seq_shard=seq_shard):
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        cost = _cost_dict(compiled)
        memory = _memory_dict(compiled)
        text = compiled.as_text()
        # trip-count-aware analysis (XLA cost_analysis counts loop bodies
        # once; our models scan layers/microbatches/CG — see hlo_cost.py)
        tc = hlo_cost_mod.analyze(text)
        coll = hlo_mod.collective_stats(text)
        census = hlo_mod.op_census(text)

        chips = mesh.devices.size
        if shape.kind == "train":
            mflops = roof_mod.model_flops_train(cfg, shape.seq_len,
                                                shape.global_batch)
        elif shape.kind == "prefill":
            mflops = roof_mod.model_flops_prefill(cfg, shape.seq_len,
                                                  shape.global_batch)
        else:
            mflops = roof_mod.model_flops_decode(cfg, shape.seq_len,
                                                 shape.global_batch)
        rl = roof_mod.Roofline(
            name=f"{arch}x{shape_name}x{mesh_kind}",
            flops=tc.flops,
            hbm_bytes=tc.bytes_accessed,
            collective_bytes=tc.collective_bytes,
            model_flops=mflops, chips=chips)

        result.update(
            status="OK", seconds_lower=round(t_lower, 1),
            seconds_compile=round(t_compile, 1), cost=cost, memory=memory,
            trip_aware={"flops": tc.flops, "bytes": tc.bytes_accessed,
                        "collective_bytes": tc.collective_bytes,
                        "by_kind": tc.collective_by_kind},
            collectives={"total_bytes": coll.total_bytes,
                         "by_kind": coll.by_kind,
                         "in_loop_bytes": coll.in_loops},
            census=census, roofline=rl.row(), chips=chips,
            model_flops=mflops,
        )
        if verbose:
            mem_gb = memory.get("argument_size_in_bytes", 0) / 2 ** 30
            tmp_gb = memory.get("temp_size_in_bytes", 0) / 2 ** 30
            print(f"[{arch} x {shape_name} x {mesh_kind}] OK "
                  f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
                  f"args {mem_gb:.2f}GiB temp {tmp_gb:.2f}GiB/dev | "
                  f"flops/dev {tc.flops:.3g} | "
                  f"coll {tc.collective_bytes/2**30:.2f}GiB | "
                  f"bound={rl.bottleneck} | useful "
                  f"{rl.useful_fraction:.2f}")
    except Exception as e:
        result["status"] = f"FAIL: {type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_kind}] FAIL: {e}")

    if save:
        RESULTS_DIR.mkdir(exist_ok=True)
        fname = f"dryrun_{arch}_{shape_name}_{mesh_kind}.json"
        (RESULTS_DIR / fname).write_text(json.dumps(result, indent=2,
                                                    default=str))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = 0
    for a, s in cells:
        for m in meshes:
            r = run_cell(a, s, m, save=not args.no_save)
            if str(r.get("status", "")).startswith("FAIL"):
                failures += 1
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
