"""Elastic fault-tolerant GP training (DESIGN.md §16).

Two layers, matching the two ways a sharded GP run dies:

``ElasticGPTrainer``
    The in-process supervisor. Runs ``gp/train.fit`` in segments over a
    mesh built from the devices currently considered healthy. When a
    segment is interrupted — a ``StepWatchdog`` breach (slow/hung step:
    fit checkpoints the valid state and returns early) or an injected
    crash (``runtime/faults.is_injected``: fit's last durable checkpoint
    is the fallback) — the trainer picks a surviving data-axis size via
    ``runtime/elastic.choose_mesh_shape(allow_uneven=True)``, rebuilds
    the 1-D ``("data",)`` mesh over the remaining devices, and resumes
    from the newest valid checkpoint. Ghost padding in
    ``sharding/simplex.py`` means ANY device count works for ANY n, so
    shrinking never has to round below the surviving count.

``run_worker_segment`` / ``python -m repro.launch.elastic_gp --worker``
    One training *process* life, for harnesses that simulate true device
    loss: the driver (benchmarks/fig_elastic.py, tests/test_elastic.py)
    kills the worker (``os._exit(17)`` via an armed ``kill`` fault) and
    restarts it under a different ``--xla_force_host_platform_device_count``
    — from the checkpoint layer's point of view exactly what losing half
    the mesh looks like. The worker builds its problem deterministically
    from the spec's seed, runs one resumable ``fit`` segment on all
    visible devices, and prints a JSON report as its last stdout line
    (the fig_recovery protocol).

This module must stay import-light and must NOT set XLA_FLAGS at import
time (the driver sets the device count in the child's environment).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import sys
from typing import Callable

from repro.runtime import elastic
from repro.runtime import faults as faults_mod
from repro.runtime.straggler import StepWatchdog

KILL_EXIT = 17  # runtime/faults.kill_if_armed's scripted exit code


@dataclasses.dataclass
class ElasticRunReport:
    """What an elastic run survived, and what it produced."""

    result: object  # gp/train.TrainResult of the final (completed) segment
    events: list  # one entry per mesh change: {kind, devices, survivors}
    device_counts: list  # data-axis size of every segment, in order
    restarts: int


class ElasticGPTrainer:
    """Watchdog-driven elastic supervisor around ``gp/train.fit``.

    ``faults`` is threaded into every segment: ``"fit"``/``"fit_step"``
    site events fire inside the loop (transient retries are absorbed by
    fit itself; crashes and watchdog breaches surface here and trigger a
    mesh change). ``lost_per_event`` is the device-loss model: how many
    devices a breach/crash is assumed to have taken with it.
    """

    def __init__(self, model, x, y, *, x_val, y_val, ckpt_dir: str,
                 epochs: int = 40, ckpt_every: int = 5, lr: float = 0.1,
                 seed: int = 0, faults=None, max_restarts: int = 6,
                 lost_per_event: int | None = None,
                 watchdog_window: int = 8, watchdog_multiplier: float = 3.0,
                 watchdog_min_deadline: float = 10.0,
                 log_fn: Callable[[str], None] | None = None):
        self.model, self.x, self.y = model, x, y
        self.x_val, self.y_val = x_val, y_val
        self.ckpt_dir = ckpt_dir
        self.epochs, self.ckpt_every = epochs, ckpt_every
        self.lr, self.seed = lr, seed
        self.faults = faults
        self.max_restarts = max_restarts
        self.lost_per_event = lost_per_event
        self.watchdog_window = watchdog_window
        self.watchdog_multiplier = watchdog_multiplier
        self.watchdog_min_deadline = watchdog_min_deadline
        self.log_fn = log_fn

    def _survivors(self, devices: int) -> int:
        """Data-axis size after an event took devices with it."""
        lost = (self.lost_per_event if self.lost_per_event is not None
                else max(1, devices // 2))
        surviving = max(1, devices - lost)
        dp, _ = elastic.choose_mesh_shape(
            surviving, model_parallel=1, global_batch=self.x.shape[0],
            prev_dp=devices, allow_uneven=True)
        return dp

    def run(self, device_count: int | None = None) -> ElasticRunReport:
        import jax

        from repro.gp import train as train_mod

        devices = jax.devices()
        k = min(device_count or len(devices), len(devices))
        events, counts, restarts = [], [], 0
        while True:
            counts.append(k)
            mesh = elastic.gp_mesh(devices[:k])
            wd = StepWatchdog(window=self.watchdog_window,
                              multiplier=self.watchdog_multiplier,
                              min_deadline=self.watchdog_min_deadline)
            if self.log_fn:
                self.log_fn(f"elastic segment {len(counts)}: "
                            f"{k} device(s), resume from "
                            f"{self.ckpt_dir}")
            try:
                res = train_mod.fit(
                    self.model, self.x, self.y,
                    x_val=self.x_val, y_val=self.y_val,
                    epochs=self.epochs, lr=self.lr, seed=self.seed,
                    mesh=mesh, ckpt_dir=self.ckpt_dir,
                    ckpt_every=self.ckpt_every, resume=True,
                    faults=self.faults, watchdog=wd, watchdog_abort=True,
                    log_fn=self.log_fn)
            except Exception as err:  # noqa: BLE001 — non-injected re-raised
                if (faults_mod.is_injected(err)
                        and restarts < self.max_restarts):
                    # scripted crash: the last durable checkpoint is the
                    # fallback — resume=True picks it up next segment
                    survivors = self._survivors(k)
                    events.append(dict(
                        kind="crash", devices=k, survivors=survivors,
                        error=str(err).splitlines()[0][:200]))
                    k = survivors
                    restarts += 1
                    continue
                raise
            if (res.report.interrupted == "watchdog_breach"
                    and restarts < self.max_restarts):
                # fit already checkpointed the slow-but-valid epoch; drop
                # the straggler's devices and resume from that state
                survivors = self._survivors(k)
                events.append(dict(kind="watchdog_breach", devices=k,
                                   survivors=survivors,
                                   breaches=list(
                                       res.report.watchdog_breaches)))
                k = survivors
                restarts += 1
                continue
            return ElasticRunReport(result=res, events=events,
                                    device_counts=counts,
                                    restarts=restarts)


# -- subprocess worker (true device loss: the PROCESS is the casualty) -------

def make_problem(seed: int, n: int, d: int, n_val: int):
    """Deterministic synthetic regression problem shared by the elastic
    tests and benchmarks — both sides of a kill/restart must rebuild the
    identical data from the spec alone."""
    import numpy as np
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = (jnp.sin(2 * x[:, 0]) + 0.4 * x[:, 1 % d]
         + 0.05 * jnp.asarray(rng.normal(size=n), jnp.float32))
    xv = jnp.asarray(rng.normal(size=(n_val, d)), jnp.float32)
    yv = jnp.sin(2 * xv[:, 0]) + 0.4 * xv[:, 1 % d]
    return x, y, xv, yv


def params_digest(params) -> str:
    """Order-stable byte digest of a GPParams pytree — the bit-compat
    witness the same-mesh resume contract is asserted on."""
    import jax
    import numpy as np
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(params):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()


def run_worker_segment(spec: dict) -> dict:
    """One training-process life; returns the JSON-able segment report.

    ``spec`` keys (all optional but ``ckpt_dir``):
      seed/n/d/n_val      problem (rebuilt deterministically)
      epochs/ckpt_every/lr  fit knobs (resume=True always)
      patience            early-stop patience (default: never — the
                          harness's steps-lost arithmetic needs lives of
                          deterministic length)
      kernel/max_cg_iters/num_probes  model config
      devices             use only the first k visible devices (None=all)
      faults              list of FaultEvent dicts to arm
      watchdog            {window, multiplier, min_deadline} or None
      watchdog_abort      return early on breach (default True)
    """
    import jax

    from repro.gp import SimplexGP, SimplexGPConfig
    from repro.gp import train as train_mod
    from repro.runtime.faults import FaultInjector

    seed = int(spec.get("seed", 0))
    n, d = int(spec.get("n", 300)), int(spec.get("d", 2))
    n_val = int(spec.get("n_val", 64))
    x, y, xv, yv = make_problem(seed, n, d, n_val)
    model = SimplexGP(SimplexGPConfig(
        kernel=spec.get("kernel", "matern32"),
        max_cg_iters=int(spec.get("max_cg_iters", 50)),
        num_probes=int(spec.get("num_probes", 2))))

    devices = jax.devices()
    k = min(int(spec["devices"]), len(devices)) if spec.get("devices") \
        else len(devices)
    mesh = elastic.gp_mesh(devices[:k])

    fi = None
    if spec.get("faults"):
        fi = FaultInjector()
        for ev in spec["faults"]:
            fi.arm(**ev)
    wd = None
    if spec.get("watchdog"):
        wd = StepWatchdog(**{str(kk): vv
                             for kk, vv in spec["watchdog"].items()})

    res = train_mod.fit(
        model, x, y, x_val=xv, y_val=yv,
        epochs=int(spec.get("epochs", 20)), lr=float(spec.get("lr", 0.1)),
        patience=int(spec.get("patience", 10 ** 9)),
        seed=seed, mesh=mesh, ckpt_dir=spec["ckpt_dir"],
        ckpt_every=int(spec.get("ckpt_every", 4)), resume=True,
        faults=fi, watchdog=wd,
        watchdog_abort=bool(spec.get("watchdog_abort", True)))
    r = res.report
    return {
        "devices": k,
        "visible_devices": len(devices),
        "resumed_from_epoch": r.resumed_from_epoch,
        "completed_epochs": r.completed_epochs,
        "last_epoch": res.history[-1]["epoch"] if res.history else None,
        "interrupted": r.interrupted,
        "checkpoints_written": r.checkpoints_written,
        "retries": list(r.retries),
        "watchdog_breaches": list(r.watchdog_breaches),
        "rollbacks": list(r.rollbacks),
        "fired": fi.summary() if fi is not None else [],
        "mll_history": [(h["epoch"], h["mll"]) for h in res.history],
        "final_mll": res.history[-1]["mll"] if res.history else None,
        "best_val_rmse": res.best_val_rmse,
        "params_digest": params_digest(res.params),
    }


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--worker":
        out = run_worker_segment(json.loads(sys.argv[2]))
        print(json.dumps(out))  # last line: the report the driver parses
    else:
        raise SystemExit("usage: python -m repro.launch.elastic_gp "
                         "--worker '<json spec>'")
