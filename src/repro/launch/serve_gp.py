"""Fault-tolerant GP serving engine: hot-swap Predictors under live traffic.

The frozen serving path (gp/serve.py, DESIGN.md §12) made a query cheap;
this module makes it OPERABLE (DESIGN.md §13). The design exploits what
the SKI lineage gives us for free: a Predictor is an immutable pytree of
precomputed tables, so "updating the model" is building a NEW pytree off
the query path, validating it, and atomically publishing it — queries
never lock against refreshes, and a broken candidate is refused before
any query can observe it.

Architecture (one class, three lanes):

  query lane    ``query(xs)`` reads the current Predictor (a single
                Python reference — atomic under the GIL), serves through
                ``gp.serve.predict``, and applies per-request robustness:
                bounded retry with a wall-clock deadline on transient
                failures, an explicit prior-fallback lane for full-miss
                queries, a final finiteness check (the zero-invalid-
                responses guarantee), and rolling miss_mass staleness
                tracking with an alert threshold.

  refresh lane  ``submit_refresh(...)`` records new data; the refresh
                (inline via ``refresh_now`` or on the background worker
                thread) re-freezes via ``gp.serve.refreeze`` — CG warm-
                started from the old alpha, hash index reused when the
                lattice is unchanged — validates the candidate with
                ``serve.validate_predictor``, and only then swaps it into
                the double-buffered registry. Every refresh runs in its
                own guarded thread with a deadline derived from a
                ``runtime/straggler.StepWatchdog`` over past refresh
                durations: a wedged freeze is abandoned (its result can
                never publish), the last-good Predictor keeps serving,
                and health degrades instead of crashing. A capacity-
                overflow refusal from ``freeze`` retries with grown cap.

  health lane   ``health()`` snapshots status/version/staleness/counters
                so an operator (or the soak harness) can watch the engine
                degrade and recover.

Fault injection: pass a ``runtime/faults.FaultInjector`` and the engine
probes it at its sites ("refresh" exceptions, "freeze" slow/NaN/cg-stall/
overflow, "query" transients) — benchmarks/fig_soak.py scripts a failure
schedule through a live engine and asserts zero invalid responses.

Durability (DESIGN.md §14): a ``PredictorStore`` makes the engine's
published state survive the process. The store is a named multi-model
registry on disk (``<root>/<model>/gen_<k>/``, each generation one
atomic ``gp.serve.save_predictor`` directory, keep-last-k plus keep-best
retention). An engine constructed with a store WARM-BOOTS: it serves the
newest generation that passes the full load gate (integrity checksums +
``validate_predictor`` + self-probe), falling back generation by
generation past corrupt ones, and only cold-freezes from the constructor
data when no valid generation exists. Every published Predictor is
persisted POST-publish on a background thread — queries never wait on
disk; a persist failure degrades health, never serving.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import pathlib
import threading
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filtering
from repro.gp.models import GPParams, SimplexGP
from repro.gp.serve import (Predictor, PredictorLoadError, load_predictor,
                            predict, refreeze, freeze, save_predictor,
                            validate_predictor)
from repro.runtime.faults import FaultInjector
from repro.runtime.straggler import StepWatchdog

Array = jax.Array


class ServeUnavailable(RuntimeError):
    """Raised when a query exhausts its retry/deadline budget."""


class RefreshRejected(RuntimeError):
    """A candidate Predictor failed the validation gate (never published)."""


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Serving-engine knobs (all host-side; nothing affects frozen math)."""

    variance_rank: int = 16
    require_converged: bool = True  # validation gate refuses stalled solves
    max_retries: int = 2  # per-query transient-failure retries
    query_timeout_s: float = 10.0  # per-query wall-clock budget
    staleness_window: int = 64  # rolling batches in the miss_mass window
    staleness_alert: float = 0.25  # alert when rolling mean miss exceeds
    fallback_miss: float = 0.999  # per-query prior-fallback threshold
    refresh_min_deadline_s: float = 30.0  # wedge deadline floor
    refresh_deadline_multiplier: float = 5.0  # x median refresh duration
    refresh_max_deadline_s: float | None = None  # cap (tests force wedges)
    cap_growth: int = 4  # lattice-cap growth per overflow retry
    max_cap_retries: int = 3
    registry_size: int = 2  # double-buffered: current + previous


class QueryResult(NamedTuple):
    mean: Array  # (b,)
    var: Array  # (b,) latent-f variance
    miss_mass: Array  # (b,)
    fallback: Array  # (b,) bool: full-miss queries served from the prior
    version: int  # Predictor version that served this batch
    stale: bool  # True when data newer than this version is pending/failed


@dataclasses.dataclass(frozen=True)
class HealthStatus:
    """Point-in-time engine health (all counters monotone since start)."""

    status: str  # "ok" | "degraded"
    version: int
    n_train: int
    refreshes_ok: int
    refreshes_failed: int  # worker exceptions (incl. injected)
    refreshes_rejected: int  # validation-gate refusals
    refreshes_wedged: int  # deadline-abandoned refreshes
    overflow_recoveries: int  # capacity overflows recovered by regrowth
    queries_served: int
    queries_retried: int
    queries_refused: int
    fallback_queries: int  # individual full-miss queries -> prior lane
    staleness: float  # rolling mean miss_mass over the window
    staleness_alert: bool
    last_refresh_s: float | None  # duration of the last completed refresh
    last_failure: str | None
    pending_refresh: bool
    # durability lane (DESIGN.md §14) — defaults keep old constructors valid
    boot_mode: str = "cold"  # "warm" = served from the store at startup
    boot_generation: int | None = None  # store generation served at boot
    boot_skipped: int = 0  # corrupt generations walked past during boot
    persists_ok: int = 0
    persists_failed: int = 0
    persisted_version: int = 0  # newest engine version durable on disk


class PredictorStore:
    """Durable, multi-model Predictor registry on disk (DESIGN.md §14).

    Layout: ``<root>/<model>/gen_<k>/`` — one atomic
    ``gp.serve.save_predictor`` directory per generation, so every
    generation is independently loadable and independently corruptible
    (the warm-boot fallback walks them newest-first). Retention keeps the
    newest ``keep_last`` generations PLUS the single best by the saved
    metric (default: the alpha solve's final CG residual — lower is
    better), so a regression in later generations never deletes the best
    model the store has seen.
    """

    def __init__(self, root: str | pathlib.Path, *, keep_last: int = 3,
                 keep_best: int = 1):
        self.root = pathlib.Path(root)
        self.keep_last = max(keep_last, 1)
        self.keep_best = max(keep_best, 0)
        self._lock = threading.Lock()

    def model_dir(self, name: str) -> pathlib.Path:
        if "/" in name or name in ("", ".", ".."):
            raise ValueError(f"invalid model name {name!r}")
        return self.root / name

    def path(self, name: str, gen: int) -> pathlib.Path:
        return self.model_dir(name) / f"gen_{gen:08d}"

    def models(self) -> list[str]:
        if not self.root.is_dir():
            return []
        return sorted(p.name for p in self.root.iterdir() if p.is_dir())

    def generations(self, name: str) -> list[int]:
        """Generation numbers on disk, ascending (published dirs only —
        a dead ``.tmp`` from a mid-write crash is invisible here)."""
        mdir = self.model_dir(name)
        if not mdir.is_dir():
            return []
        gens = []
        for p in mdir.iterdir():
            if p.is_dir() and p.name.startswith("gen_") \
                    and not p.name.endswith(".tmp"):
                try:
                    gens.append(int(p.name[4:]))
                except ValueError:
                    continue
        return sorted(gens)

    def _metric(self, name: str, gen: int) -> float:
        try:
            man = json.loads(
                (self.path(name, gen) / "manifest.json").read_text())
            return float(man["extra"]["metric"])
        except Exception:
            return float("inf")  # unreadable = never retention-best

    def save(self, name: str, pred: Predictor, *, gen: int | None = None,
             metric: float | None = None, extra: dict | None = None,
             faults: FaultInjector | None = None) -> int:
        """Persist ``pred`` as the next (or given) generation; prune.

        Returns the generation written. ``metric`` feeds keep-best
        retention (lower is better; defaults to the Predictor's CG
        residual). The write itself is ``save_predictor``'s atomic
        tmp+rename; retention runs after publish, so a crash during
        pruning leaves extra generations, never fewer.
        """
        with self._lock:
            if gen is None:
                gens = self.generations(name)
                gen = (gens[-1] + 1) if gens else 1
            if metric is None:
                metric = float(np.asarray(pred.cg_residual))
            save_predictor(pred, self.path(name, gen),
                           extra=dict(extra or {}, metric=metric, gen=gen),
                           faults=faults)
            self._prune(name)
            return gen

    def _prune(self, name: str):
        import shutil
        gens = self.generations(name)
        keep = set(gens[-self.keep_last:])
        if self.keep_best and gens:
            by_metric = sorted(gens, key=lambda g: self._metric(name, g))
            keep.update(by_metric[:self.keep_best])
        for g in gens:
            if g not in keep:
                shutil.rmtree(self.path(name, g), ignore_errors=True)

    def load_newest_valid(self, name: str, *,
                          require_converged: bool = True
                          ) -> tuple[Predictor, int, list[dict]]:
        """Newest generation passing the FULL load gate, falling back
        generation by generation past corrupt/invalid ones.

        Returns ``(pred, gen, skipped)`` where ``skipped`` records every
        newer generation that was rejected (gen + reason) — the warm-boot
        audit trail. Raises ``FileNotFoundError`` when no generation
        loads (the caller cold-freezes instead).
        """
        skipped: list[dict] = []
        for gen in reversed(self.generations(name)):
            try:
                pred = load_predictor(
                    self.path(name, gen),
                    require_converged=require_converged)
                return pred, gen, skipped
            except PredictorLoadError as e:
                skipped.append({"gen": gen, "reason": str(e)})
        err = FileNotFoundError(
            f"{self.model_dir(name)}: no valid predictor generation "
            f"({len(skipped)} rejected)")
        err.skipped = skipped  # cold-boot callers keep the audit trail
        raise err


@dataclasses.dataclass
class _RefreshJob:
    x: Array | None  # None = inputs unchanged (y-only refresh)
    y: Array
    params: GPParams | None  # None = hyperparameters unchanged
    gen: int  # data generation this job carries


class GPServeEngine:
    """Double-buffered Predictor registry + background refresh + health.

    Thread model: ``query`` may be called from any thread; the published
    Predictor is swapped by a single reference assignment under
    ``_lock`` (readers take one reference — pytrees are immutable, so an
    in-flight batch keeps serving its version through a swap; the §10
    replicated-swap contract in sharding/simplex.py covers the mesh
    case). At most one refresh executes at a time; with
    ``background=True`` a worker thread drains the LATEST submitted job
    (intermediate submissions are coalesced — the newest data wins).
    """

    def __init__(self, model: SimplexGP, params: GPParams, x: Array,
                 y: Array, *, key: Array, config: EngineConfig | None = None,
                 faults: FaultInjector | None = None, mesh=None,
                 axis_name: str = "data", background: bool = False,
                 cap: int | None = None, store: PredictorStore | None = None,
                 model_name: str = "default"):
        self.model = model
        self._cfg = config or EngineConfig()
        self._faults = faults
        self._mesh = mesh
        self._axis_name = axis_name
        self._key = key
        self._cap = cap
        self._cache = filtering.LatticeCache()
        self._lock = threading.Lock()
        self._store = store
        self._model_name = model_name
        self._persisted_version = 0
        self._persist_threads: list[threading.Thread] = []
        self._boot = {"mode": "cold", "generation": None, "skipped": 0}

        # counters (guarded by _lock)
        self._c = collections.Counter()
        self._last_failure: str | None = None
        self._last_refresh_s: float | None = None
        self._miss_window: collections.deque = collections.deque(
            maxlen=self._cfg.staleness_window)

        # double-buffered registry: version -> Predictor (last 2 kept)
        self._registry: collections.OrderedDict[int, Predictor] = \
            collections.OrderedDict()
        self._version = 0
        self._data_gen = 0  # bumped per submit_refresh
        self._served_gen = 0  # data generation of the published Predictor

        self._watchdog = StepWatchdog(
            window=16, multiplier=self._cfg.refresh_deadline_multiplier,
            min_deadline=self._cfg.refresh_min_deadline_s)

        # boot: prefer the durable store (warm boot — serve the newest
        # generation that passes the full load gate, walking past corrupt
        # ones); cold-freeze from the constructor data only when the
        # store has nothing valid. The engine refuses to START without a
        # valid Predictor either way (no last-good to degrade to yet).
        self._params = params
        self._x, self._y = x, y
        pred = None
        if store is not None:
            try:
                pred, gen, skipped = store.load_newest_valid(
                    model_name,
                    require_converged=self._cfg.require_converged)
                self._boot = {"mode": "warm", "generation": gen,
                              "skipped": len(skipped)}
                if skipped:
                    self._last_failure = (
                        f"boot: skipped {len(skipped)} corrupt "
                        f"generation(s), newest {skipped[0]['gen']}")
            except FileNotFoundError as e:
                pred = None
                self._boot["skipped"] = len(getattr(e, "skipped", ()))
        if pred is None:
            t0 = time.perf_counter()
            pred = freeze(model, params, x, y, key=self._next_key(),
                          variance_rank=self._cfg.variance_rank, cap=cap,
                          cache=self._cache)
            rep = validate_predictor(
                pred, require_converged=self._cfg.require_converged)
            if not rep.ok:
                raise RefreshRejected(
                    "initial freeze failed validation: "
                    + "; ".join(rep.failures))
            dt = time.perf_counter() - t0
            self._watchdog.end_step(dt)
            self._last_refresh_s = dt
        ver = self._publish(pred, gen=0)
        if self._boot["mode"] == "cold":
            # make the boot Predictor durable too (a crash before the
            # first refresh must still warm-boot); warm boot skips this
            # — its generation is already on disk
            self._persist_async(pred, ver)

        # background refresh worker
        self._abandoned: list[threading.Thread] = []
        self._pending: _RefreshJob | None = None
        self._refresh_idle = True
        self._attempted_gen = 0
        self._cond = threading.Condition(self._lock)
        self._stop = threading.Event()
        self._worker: threading.Thread | None = None
        if background:
            self._worker = threading.Thread(
                target=self._worker_loop, name="gp-refresh", daemon=True)
            self._worker.start()

    # -- registry ------------------------------------------------------------

    def _next_key(self) -> Array:
        # locked: an abandoned (wedged) attempt thread may still be
        # splitting keys when the next refresh attempt starts
        with self._lock:
            self._key, sub = jax.random.split(self._key)
            return sub

    def _publish(self, pred: Predictor, *, gen: int) -> int:
        """Atomic hot swap: validate-before-call is the caller's job."""
        if self._mesh is not None:
            from repro.sharding.simplex import replicate_pytree
            pred = replicate_pytree(pred, self._mesh)
        with self._lock:
            self._version += 1
            self._registry[self._version] = pred
            while len(self._registry) > self._cfg.registry_size:
                self._registry.popitem(last=False)
            self._served_gen = max(self._served_gen, gen)
            return self._version

    def _persist_async(self, pred: Predictor, version: int):
        """Persist a just-published Predictor WITHOUT blocking queries.

        Runs on a daemon thread: the query lane never waits on disk, and
        a kill injected at the persistence sites dies off the serving
        path (the published in-memory Predictor already served). Persist
        failures count and degrade health — they never unpublish.
        """
        if self._store is None:
            return

        def work():
            try:
                self._store.save(self._model_name, pred,
                                 extra={"engine_version": version},
                                 faults=self._faults)
                with self._lock:
                    self._c["persists_ok"] += 1
                    self._persisted_version = max(self._persisted_version,
                                                  version)
            except Exception as e:  # noqa: BLE001 — degrade, never crash
                with self._lock:
                    self._c["persists_failed"] += 1
                    self._last_failure = f"persist: {e}"

        t = threading.Thread(target=work, name="gp-persist", daemon=True)
        with self._lock:
            self._persist_threads.append(t)
        t.start()

    def wait_persisted(self, version: int | None = None, *,
                       timeout_s: float = 30.0) -> bool:
        """Block until engine ``version`` (default: current) is durable
        on disk, a persist for it has FAILED, or the timeout expires.
        True iff it is durable."""
        if self._store is None:
            return False
        with self._lock:
            want = self._version if version is None else version
            fails0 = self._c["persists_failed"]
        t1 = time.monotonic() + timeout_s
        while time.monotonic() < t1:
            with self._lock:
                if self._persisted_version >= want:
                    return True
                done = not any(t.is_alive() for t in self._persist_threads)
                failed = self._c["persists_failed"] > fails0
            if done and failed:
                return False
            time.sleep(0.005)
        return False

    def predictor(self, version: int | None = None) -> Predictor:
        with self._lock:
            if version is None:
                version = self._version
            return self._registry[version]

    @property
    def version(self) -> int:
        return self._version

    # -- query lane ----------------------------------------------------------

    def query(self, xs: Array, *, timeout_s: float | None = None,
              backend: str | None = None) -> QueryResult:
        """Serve one batch with bounded retry + deadline + fallback lane."""
        cfg = self._cfg
        deadline = time.monotonic() + (cfg.query_timeout_s
                                       if timeout_s is None else timeout_s)
        attempts = 0
        while True:
            with self._lock:
                version = self._version
                pred = self._registry[version]
                stale = self._served_gen < self._data_gen
            try:
                if self._faults is not None:
                    self._faults.maybe_raise("query")
                sr = predict(pred, xs, backend=backend, mesh=self._mesh,
                             axis_name=self._axis_name)
                mean = np.asarray(sr.mean).astype(np.float32)
                var = np.asarray(sr.var).astype(np.float32)
                miss = np.asarray(sr.miss_mass)
                # prior-fallback lane: a full-miss query's prediction IS
                # the prior by the slicing math; make the contract
                # explicit so a fallback response is prior-exact even if
                # a future table format violates it
                fb = miss >= cfg.fallback_miss
                if fb.any():
                    mean[fb] = 0.0
                    var[fb] = float(pred.outputscale)
                # zero-invalid-responses guarantee: the LAST line of
                # defense behind the validation gate
                if not (np.isfinite(mean).all() and np.isfinite(var).all()):
                    raise RuntimeError(
                        "non-finite response from a validated Predictor")
                with self._lock:
                    self._c["queries_served"] += 1
                    self._c["fallback_queries"] += int(fb.sum())
                    if miss.size:  # empty batch would push NaN into the window
                        self._miss_window.append(float(miss.mean()))
                return QueryResult(mean=jnp.asarray(mean),
                                   var=jnp.asarray(var),
                                   miss_mass=sr.miss_mass,
                                   fallback=jnp.asarray(fb),
                                   version=version, stale=stale)
            except Exception as e:
                attempts += 1
                with self._lock:
                    self._c["queries_retried"] += 1
                if attempts > cfg.max_retries or time.monotonic() > deadline:
                    with self._lock:
                        self._c["queries_refused"] += 1
                        self._last_failure = f"query: {e}"
                    raise ServeUnavailable(
                        f"query failed after {attempts} attempt(s)") from e

    # -- refresh lane --------------------------------------------------------

    def submit_refresh(self, *, y: Array, x: Array | None = None,
                       params: GPParams | None = None) -> int:
        """Record new data for the next refresh; returns its generation.

        ``x=None`` means the inputs are unchanged (a y-only refresh —
        the cheap path: cached lattice, reused index, warm-started CG).
        Coalescing: a newer submission replaces an unstarted older one.
        """
        with self._lock:
            self._data_gen += 1
            self._pending = _RefreshJob(x=x, y=y, params=params,
                                        gen=self._data_gen)
            self._cond.notify_all()
            return self._data_gen

    def refresh_now(self, *, wait: bool = True) -> bool:
        """Run the pending refresh inline (sync mode); True on publish.

        With a background worker, prefer ``submit_refresh`` +
        ``wait_refreshed``; this entry point exists for deterministic
        tests and single-threaded deployments.
        """
        with self._lock:
            job, self._pending = self._pending, None
            if job is not None:
                self._refresh_idle = False
        if job is None:
            return False
        return self._run_guarded(job)

    def wait_refreshed(self, gen: int, *, timeout_s: float = 60.0) -> bool:
        """Block until data generation ``gen`` is serving, a refresh for a
        generation >= gen has FAILED (last-good keeps serving), or the
        timeout expires. True iff gen is serving."""
        t1 = time.monotonic() + timeout_s
        while time.monotonic() < t1:
            with self._lock:
                if self._served_gen >= gen:
                    return True
                settled = (self._pending is None
                           and self._refresh_idle
                           and self._attempted_gen >= gen)
            if settled:
                return False
            time.sleep(0.005)
        return False

    def _worker_loop(self):
        while True:
            with self._cond:
                while self._pending is None and not self._stop.is_set():
                    self._cond.wait(timeout=0.1)
                if self._stop.is_set():
                    return
                job, self._pending = self._pending, None
                # mark busy while still holding the lock: wait_refreshed
                # must never observe the gap between dequeue and run
                self._refresh_idle = False
            self._run_guarded(job)

    def _run_guarded(self, job: _RefreshJob) -> bool:
        """One refresh attempt under the wedge deadline; never raises."""
        self._refresh_idle = False
        result: dict = {}

        def work():
            try:
                result["pred"] = self._do_refresh(job)
            except BaseException as e:  # noqa: BLE001 — the guard reports
                result["err"] = e

        deadline = self._watchdog.deadline
        if self._cfg.refresh_max_deadline_s is not None:
            deadline = min(deadline, self._cfg.refresh_max_deadline_s)
        t0 = time.perf_counter()
        t = threading.Thread(target=work, name="gp-refresh-attempt",
                             daemon=True)
        t.start()
        t.join(None if deadline == float("inf") else deadline)
        try:
            if t.is_alive():
                # wedged: abandon — the attempt thread's result dict is
                # never read again, so a late finish can never publish
                with self._lock:
                    self._c["refreshes_wedged"] += 1
                    self._abandoned.append(t)
                    self._last_failure = (
                        f"refresh wedged (> {deadline:.2f}s deadline), "
                        "last-good predictor kept")
                return False
            dt = time.perf_counter() - t0
            if "err" in result:
                with self._lock:
                    if isinstance(result["err"], RefreshRejected):
                        self._c["refreshes_rejected"] += 1
                    self._c["refreshes_failed"] += 1
                    self._last_failure = f"refresh: {result['err']}"
                return False
            self._watchdog.end_step(dt)
            ver = self._publish(result["pred"], gen=job.gen)
            self._persist_async(result["pred"], ver)
            with self._lock:
                # accepted: advance the engine's notion of train data HERE
                # (not in _do_refresh) so an abandoned wedged attempt that
                # finishes late can never mutate engine state
                if job.x is not None:
                    self._x = job.x
                self._y = job.y
                if job.params is not None:
                    self._params = job.params
                self._c["refreshes_ok"] += 1
                self._last_refresh_s = dt
            return True
        finally:
            with self._lock:
                self._attempted_gen = max(self._attempted_gen, job.gen)
                self._refresh_idle = True

    def _do_refresh(self, job: _RefreshJob) -> Predictor:
        """Build + validate one candidate (runs on the attempt thread)."""
        cfg = self._cfg
        faults = self._faults
        if faults is not None:
            faults.maybe_raise("refresh")
            faults.sleep_if_armed("freeze")

        x = self._x if job.x is None else job.x
        params = self._params if job.params is None else job.params
        model = self.model
        if faults is not None and faults.cg_stall("freeze"):
            # force a genuinely non-converged solve (not a faked flag):
            # a tolerance no f32 solve reaches in 2 iterations
            model = SimplexGP(dataclasses.replace(
                model.config, cg_tol_eval=1e-12, max_cg_iters=2))

        cap = self._cap
        if faults is not None:
            forced = faults.forced_cap("freeze")
            if forced is not None:
                cap = forced
        old = self.predictor()
        cand = None
        for attempt in range(cfg.max_cap_retries + 1):
            try:
                cand = refreeze(model, params, x, job.y,
                                key=self._next_key(), old=old,
                                cache=self._cache, cap=cap,
                                variance_rank=cfg.variance_rank)
                break
            except RuntimeError as e:
                if ("capacity overflow" not in str(e)
                        or attempt == cfg.max_cap_retries):
                    raise
                # grown-cap recovery; final retry escalates to the
                # worst-case auto sizing, which cannot capacity-overflow
                cap = (None if cap is None or attempt >= 1
                       else cap * cfg.cap_growth)
                with self._lock:
                    self._c["overflow_recoveries"] += 1

        if faults is not None:
            cand = dataclasses.replace(
                cand, tables=faults.corrupt_tables("freeze", cand.tables))

        rep = validate_predictor(cand,
                                 require_converged=cfg.require_converged)
        if not rep.ok:
            raise RefreshRejected("candidate refused: "
                                  + "; ".join(rep.failures))
        return cand

    # -- health lane ---------------------------------------------------------

    @property
    def staleness(self) -> float:
        with self._lock:
            if not self._miss_window:
                return 0.0
            return float(sum(self._miss_window) / len(self._miss_window))

    def health(self) -> HealthStatus:
        stal = self.staleness
        with self._lock:
            c = self._c
            degraded = (self._served_gen < self._data_gen
                        and self._pending is None and self._refresh_idle)
            ok = not degraded and not (
                stal > self._cfg.staleness_alert)
            return HealthStatus(
                status="ok" if ok else "degraded",
                version=self._version,
                n_train=self._registry[self._version].n_train,
                refreshes_ok=c["refreshes_ok"],
                refreshes_failed=c["refreshes_failed"],
                refreshes_rejected=c["refreshes_rejected"],
                refreshes_wedged=c["refreshes_wedged"],
                overflow_recoveries=c["overflow_recoveries"],
                queries_served=c["queries_served"],
                queries_retried=c["queries_retried"],
                queries_refused=c["queries_refused"],
                fallback_queries=c["fallback_queries"],
                staleness=stal,
                staleness_alert=stal > self._cfg.staleness_alert,
                last_refresh_s=self._last_refresh_s,
                last_failure=self._last_failure,
                pending_refresh=self._pending is not None
                or not self._refresh_idle,
                boot_mode=self._boot["mode"],
                boot_generation=self._boot["generation"],
                boot_skipped=self._boot["skipped"],
                persists_ok=c["persists_ok"],
                persists_failed=c["persists_failed"],
                persisted_version=self._persisted_version,
            )

    # -- lifecycle -----------------------------------------------------------

    def close(self, *, timeout_s: float = 30.0):
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        if self._worker is not None:
            self._worker.join(timeout_s)
        # abandoned (wedged) attempt threads may still be inside device
        # work; give them a bounded chance to drain so interpreter
        # teardown never kills a thread mid-XLA-call
        with self._lock:
            abandoned = list(self._abandoned)
            persisting = list(self._persist_threads)
        for t in abandoned:
            t.join(timeout_s)
        for t in persisting:  # drain in-flight persists (bounded)
            t.join(timeout_s)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
