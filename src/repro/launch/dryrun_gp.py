import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))
# ^ MUST precede every other import (jax locks device count on first init).

"""Dry-run for the PAPER'S OWN workload: Simplex-GP training at scale.

One full BBMM hyperparameter step (lattice build + mBCG solves + SLQ
logdet + §4.2 gradient filtering) on a houseelectric-sized problem
(n ~ 2M, d = 11), lowered for the production mesh:

  * data points sharded over ("pod","data") — splat becomes a local
    segment-sum followed by an all-reduce of the lattice table (the
    paper's communication pattern: O(m·c) per CG iteration),
  * the lattice table replicated over "model" (it is the shared inducing
    structure); CG dot products are global psums.

This is cell #41 — beyond the 40 assigned LM cells — proving the paper's
technique itself distributes on the mesh.

    PYTHONPATH=src python -m repro.launch.dryrun_gp --mesh both
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.gp import GPParams, SimplexGP, SimplexGPConfig
from repro.gp.mll import mll_value_and_grad
from repro.launch.mesh import make_production_mesh
from repro.sharding import partition
from repro.utils import hlo as hlo_mod
from repro.utils import roofline as roof_mod

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results"


def gp_cell(mesh_kind: str, *, n: int = 2_048_000, d: int = 11,
            cap: int = 2_097_152, cg_iters: int = 20, probes: int = 8,
            save: bool = True) -> dict:
    # cap: 2x the paper's measured m for houseelectric (Table 3: 1.0M) —
    # §Perf iteration C2 (was 4.2M; right-sizing halved the build cost)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    dp = partition.dp_axes(mesh)
    result = {"cell": "simplexgp-houseelectric", "mesh": mesh_kind,
              "n": n, "d": d, "cap": cap}
    t0 = time.time()
    try:
        cfg = SimplexGPConfig(kernel="matern32", order=1,
                              max_cg_iters=cg_iters, num_probes=probes,
                              max_lanczos_iters=20,
                              cap_factor=cap / (n * (d + 1)))
        model = SimplexGP(cfg)
        params = GPParams.init(d)

        x_abs = jax.ShapeDtypeStruct((n, d), jnp.float32)
        y_abs = jax.ShapeDtypeStruct((n,), jnp.float32)
        key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
        p_abs = jax.eval_shape(lambda: params)

        xsh = jax.NamedSharding(mesh, jax.P(*( (dp, None) )))
        ysh = jax.NamedSharding(mesh, jax.P(dp))
        rep = jax.NamedSharding(mesh, jax.P())
        psh = jax.tree.map(lambda _: rep, p_abs)

        def step(p, x, y, key):
            res = mll_value_and_grad(model, p, x, y, key, tol=1e-2)
            return res.mll, res.grads

        fn = jax.jit(step, in_shardings=(psh, xsh, ysh, rep))
        with mesh:
            lowered = fn.lower(p_abs, x_abs, y_abs, key_abs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in dict(ca).items()
                if isinstance(v, (int, float))}
        ma = compiled.memory_analysis()
        text = compiled.as_text()
        coll = hlo_mod.collective_stats(text)
        chips = mesh.devices.size
        # model flops: (cg_iters+probes-solve) filter MVMs, each
        # O(d^2 (n+m) c) useful work (paper Table 1)
        c_channels = 1 + probes
        mvms = cg_iters * c_channels + cfg.max_lanczos_iters * probes
        mflops = 4.0 * (d ** 2) * (n + cap) * mvms
        rl = roof_mod.Roofline(
            name=f"simplexgp x {mesh_kind}", flops=cost.get("flops", 0.0),
            hbm_bytes=cost.get("bytes accessed", 0.0),
            collective_bytes=float(coll.total_bytes),
            model_flops=mflops, chips=chips)
        result.update(
            status="OK", seconds_lower=round(t_lower, 1),
            seconds_compile=round(t_compile, 1), cost=cost,
            memory={"argument_size_in_bytes": ma.argument_size_in_bytes,
                    "temp_size_in_bytes": ma.temp_size_in_bytes},
            collectives={"total_bytes": coll.total_bytes,
                         "by_kind": coll.by_kind},
            roofline=rl.row(), chips=chips)
        print(f"[simplexgp x {mesh_kind}] OK lower {t_lower:.0f}s "
              f"compile {t_compile:.0f}s | "
              f"args {ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp {ma.temp_size_in_bytes/2**30:.2f}GiB/dev | "
              f"flops/dev {cost.get('flops', 0):.3g} | "
              f"coll {coll.total_bytes/2**20:.1f}MiB | "
              f"bound={rl.bottleneck}")
    except Exception as e:
        result["status"] = f"FAIL: {type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()
        print(f"[simplexgp x {mesh_kind}] FAIL: {e}")
    if save:
        RESULTS_DIR.mkdir(exist_ok=True)
        (RESULTS_DIR / f"dryrun_simplexgp_{mesh_kind}.json").write_text(
            json.dumps(result, indent=2, default=str))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--n", type=int, default=2_048_000)
    ap.add_argument("--cg-iters", type=int, default=20)
    args = ap.parse_args()
    kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    fails = 0
    for k in kinds:
        r = gp_cell(k, n=args.n, cg_iters=args.cg_iters)
        if str(r.get("status", "")).startswith("FAIL"):
            fails += 1
    if fails:
        raise SystemExit(f"{fails} gp cells failed")


if __name__ == "__main__":
    main()
