"""Batched serving loop: prefill once, decode many, continuous batching.

Minimal-but-real serving semantics for the decode shapes:

  * requests arrive with prompts of different lengths; the engine packs a
    fixed-size batch, left-pads positions, prefills via serve_step token
    feeding (smoke scale) and then decodes greedily/top-k per step,
  * finished sequences (EOS or max_len) are retired and their slots
    refilled from the queue — classic continuous batching,
  * the KV cache / recurrent state is allocated once at max context and
    reused across slot refills (position-based masking makes stale
    entries invisible).

examples/lm_serve.py drives this on a reduced config.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (len,) int32
    max_new: int = 16


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list


class RunReport(list):
    """``ServeEngine.run``'s return value: the finished completions (it IS
    the ``done`` list, so existing callers keep working), plus what a
    step-budget exhaustion left behind — in-flight completions with their
    partial tokens and still-queued requests. ``exhausted`` is True iff
    the loop stopped on ``max_steps`` with work remaining; a caller that
    ignores it sees exactly the old (silently-truncating) behavior, a
    caller that checks it can re-run or surface the loss."""

    def __init__(self, done, *, in_flight=(), queued=(), exhausted=False):
        super().__init__(done)
        self.in_flight: list = list(in_flight)
        self.queued: list = list(queued)
        self.exhausted: bool = exhausted

    @property
    def unfinished(self) -> int:
        return len(self.in_flight) + len(self.queued)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch: int,
                 max_seq: int, temperature: float = 0.0, seed: int = 0):
        assert not cfg.is_encdec, "use WhisperEngine for enc-dec"
        self.cfg = cfg
        self.lm = build(cfg)
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.state = self.lm.init_decode_state(batch, max_seq)
        self._step = jax.jit(self.lm.serve_step)
        # slot bookkeeping (host side)
        self.slot_req: list = [None] * batch
        self.slot_pos = np.zeros(batch, np.int64)
        self.slot_remaining = np.zeros(batch, np.int64)
        self.slot_pending: list = [None] * batch  # prompt tokens to feed
        self.queue: list = []
        self.done: list = []

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, *, max_steps: int = 10_000) -> "RunReport":
        """Drive the batch until the queue drains or ``max_steps`` runs out.

        Returns a ``RunReport`` — a list of finished ``Completion``s that
        ADDITIONALLY reports work stranded by an exhausted step budget
        (``in_flight`` partial completions, ``queued`` requests,
        ``exhausted`` flag) instead of silently dropping it. Stranded
        state stays on the engine, so a follow-up ``run()`` resumes it.
        """
        exhausted = True
        for _ in range(max_steps):
            if not self._refill() and all(
                    r is None for r in self.slot_req):
                exhausted = False
                break
            self._one_step()
        in_flight = [r for r in self.slot_req if r is not None]
        exhausted = exhausted and bool(in_flight or self.queue)
        if exhausted:
            warnings.warn(
                f"ServeEngine.run: step budget ({max_steps}) exhausted with "
                f"{len(in_flight)} in-flight and {len(self.queue)} queued "
                "request(s) unfinished — see RunReport.in_flight/.queued",
                RuntimeWarning, stacklevel=2)
        return RunReport(self.done, in_flight=in_flight,
                         queued=list(self.queue), exhausted=exhausted)

    # -- internals ---------------------------------------------------------
    def _refill(self) -> bool:
        any_active = False
        for i in range(self.batch):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = Completion(rid=req.rid, tokens=[])
                self.slot_pending[i] = list(req.prompt)
                self.slot_pos[i] = 0
                self.slot_remaining[i] = req.max_new
            if self.slot_req[i] is not None:
                any_active = True
        return any_active

    def _one_step(self):
        toks = np.zeros((self.batch, 1), np.int32)
        pos = np.zeros((self.batch,), np.int32)
        feeding = np.zeros(self.batch, bool)
        for i in range(self.batch):
            if self.slot_req[i] is None:
                continue
            pos[i] = self.slot_pos[i]
            if self.slot_pending[i]:
                toks[i, 0] = self.slot_pending[i].pop(0)
                feeding[i] = True
            else:
                toks[i, 0] = (self.slot_req[i].tokens[-1]
                              if self.slot_req[i].tokens else 0)
        logits, self.state = self._step(self.params, self.state,
                                        jnp.asarray(toks),
                                        jnp.asarray(pos))
        logits = np.asarray(logits[:, 0])  # (batch, vocab)
        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            g = np.asarray(jax.random.gumbel(sub, logits.shape))
            nxt = np.argmax(logits / self.temperature + g, axis=-1)
        else:
            nxt = np.argmax(logits, axis=-1)
        for i in range(self.batch):
            if self.slot_req[i] is None:
                continue
            self.slot_pos[i] += 1
            if feeding[i] and self.slot_pending[i]:
                continue  # still prefilling
            self.slot_req[i].tokens.append(int(nxt[i]))
            self.slot_remaining[i] -= 1
            if (self.slot_remaining[i] <= 0
                    or self.slot_pos[i] >= self.max_seq - 1):
                self.done.append(self.slot_req[i])
                self.slot_req[i] = None
                self.slot_pending[i] = None
