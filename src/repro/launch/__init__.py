# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time.
from repro.launch.elastic_gp import ElasticGPTrainer, ElasticRunReport
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.serve_gp import (EngineConfig, GPServeEngine,
                                   HealthStatus, PredictorStore,
                                   QueryResult, RefreshRejected,
                                   ServeUnavailable)

__all__ = ["make_debug_mesh", "make_production_mesh", "EngineConfig",
           "ElasticGPTrainer", "ElasticRunReport",
           "GPServeEngine", "HealthStatus", "PredictorStore",
           "QueryResult", "RefreshRejected", "ServeUnavailable"]
