"""Learning-rate schedules (plain callables: step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_fraction: float = 0.1):
    """Linear warmup -> cosine decay to final_fraction * peak (MaxText-style)."""

    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_fraction + (1 - final_fraction) * 0.5 * (
            1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return sched


def step_decay(lr: float, decay: float, every: int):
    """Paper-style: Adam lr 0.1 with optional halving for GP hyperparams."""

    def sched(step):
        k = (step // every).astype(jnp.float32)
        return jnp.asarray(lr, jnp.float32) * (decay ** k)

    return sched
