"""Adam optimizer over arbitrary pytrees (no optax in this container).

Used by both the GP hyperparameter loop (paper Appendix A: Adam, lr 0.1)
and the LM train steps. Stateless-functional: ``init`` builds the moment
pytree, ``update`` returns (new_params, new_state). Supports global-norm
gradient clipping and decoupled weight decay (AdamW) for the LM path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class AdamState(NamedTuple):
    step: Array  # () int32
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class Adam:
    learning_rate: float | Callable[[Array], Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_global_norm: float | None = None
    # moments kept in f32 even for bf16 params (mixed-precision training)
    moment_dtype: Any = jnp.float32

    def init(self, params: PyTree) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params))

    def _lr(self, step: Array) -> Array:
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads: PyTree, state: AdamState,
               params: PyTree) -> tuple[PyTree, AdamState]:
        step = state.step + 1
        if self.clip_global_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_global_norm /
                                jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(self.moment_dtype),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) *
            jnp.square(g.astype(self.moment_dtype)),
            state.nu, grads)
        t = step.astype(jnp.float32)
        mhat_scale = 1.0 / (1.0 - b1 ** t)
        vhat_scale = 1.0 / (1.0 - b2 ** t)
        lr = self._lr(step)

        def upd(p, m, v):
            u = (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(self.moment_dtype)
            return (p.astype(self.moment_dtype) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(step=step, mu=mu, nu=nu)


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
