"""RecurrentGemma-2B [hybrid]: 26L, d=2560, 10H local-MQA kv=1, ff=7680,
vocab=256000. RG-LRU + local attention (window 2048) in a (rec, rec,
attn) pattern — 8 scanned periods + 2 trailing recurrent layers.
(arXiv:2402.19427)"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    head_dim=256, d_ff=7680, vocab_size=256000,
    block_pattern=("rec", "rec", "attn"), local_window=2048,
    lru_width=2560, rglru_conv_width=4,
    mlp_kind="swiglu", tie_embeddings=True,
)
