"""GLM-4-9B [dense]: 40L, d=4096, 32H GQA kv=2, ff=13696, vocab=151552.

RoPE + GQA + SwiGLU decoder-only LM. [hf:THUDM/glm-4-9b; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=151552, rope_theta=10_000.0,
    mlp_kind="swiglu", tie_embeddings=True,
)
