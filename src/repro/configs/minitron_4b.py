"""Minitron-4B [dense]: 32L, d=3072, 24H GQA kv=8, ff=9216, vocab=256000.

Pruned Nemotron (arXiv:2407.14679): squared-ReLU non-gated MLP, RoPE.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=9216, vocab_size=256000, rope_theta=10_000.0,
    mlp_kind="relu2", tie_embeddings=True,
)
