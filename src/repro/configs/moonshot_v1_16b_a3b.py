"""Moonlight-16B-A3B [moe]: 48L, d=2048, 16H MHA, expert ff=1408,
vocab=163840, 64 experts top-6. [hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163840, rope_theta=50_000.0,
    moe=True, num_experts=64, moe_top_k=6, moe_d_ff=1408,
    num_shared_experts=0, first_k_dense=0,
    mlp_kind="swiglu", tie_embeddings=True,
)
