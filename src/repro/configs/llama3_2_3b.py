"""Llama-3.2-3B [dense]: 28L, d=3072, 24H GQA kv=8, ff=8192, vocab=128256.

Small llama3: RoPE (theta 5e5), SwiGLU, tied embeddings.
[hf:meta-llama/Llama-3.2-1B; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, rope_theta=500_000.0,
    mlp_kind="swiglu", tie_embeddings=True,
)
