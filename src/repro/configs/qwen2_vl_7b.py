"""Qwen2-VL-7B [vlm]: 28L, d=3584, 28H GQA kv=4, ff=18944, vocab=152064.

M-RoPE with (t, h, w) sections (16, 24, 24) over head_dim/2 = 64; dynamic-
resolution vision frontend is a STUB — input_specs provides precomputed
patch embeddings. (arXiv:2409.12191)"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, rope_theta=1_000_000.0,
    m_rope_sections=(16, 24, 24), num_vision_tokens=1024,
    mlp_kind="swiglu", tie_embeddings=True,
)
