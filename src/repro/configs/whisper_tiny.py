"""Whisper-tiny [audio]: enc-dec 4L+4L, d=384, 6H MHA, ff=1536,
vocab=51865. Conv/mel frontend is a STUB (input_specs feeds frame
embeddings). Sinusoidal positions both sides (adaptation: the real 448-
entry learned decoder table cannot index the assigned 32k decode shape).
(arXiv:2212.04356)"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    encoder_layers=4, encoder_frames=1500, cross_attention=True,
    mlp_kind="gelu", tie_embeddings=True,
)
