"""RWKV-6 "Finch" 7B [ssm]: 32L, d=4096 (attn-free), ff=14336,
vocab=65536. Data-dependent decay, 64 heads of dim 64, chunked-parallel
time mixing (arXiv:2404.05892)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
    rwkv_head_dim=64, rwkv_lora_rank=64, ssm_chunk=64,
    mlp_kind="relu2", tie_embeddings=True,
)
