"""Architecture registry: ``--arch <id>`` lookup + reduced smoke configs.

``get(name)`` returns the full published config; ``smoke(name)`` returns a
reduced config of the same family (small widths, few layers/experts, tiny
vocab) that runs a forward/train step on CPU in seconds — the full configs
are only ever lowered via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib

import jax.numpy as jnp

from repro.models.config import ModelConfig

_MODULES = {
    "glm4-9b": "glm4_9b",
    "llama3.2-3b": "llama3_2_3b",
    "minitron-4b": "minitron_4b",
    "phi3-medium-14b": "phi3_medium_14b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-tiny": "whisper_tiny",
    "rwkv6-7b": "rwkv6_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

ARCH_IDS = tuple(_MODULES)


def get(name: str) -> ModelConfig:
    try:
        module = _MODULES[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{module}").CONFIG


def smoke(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get(name)
    kv = min(cfg.num_kv_heads, 2)
    heads = max(4, kv)
    upd: dict = dict(
        num_layers=3 if cfg.family == "hybrid" else 2,
        d_model=128, num_heads=heads, num_kv_heads=kv,
        d_ff=256, vocab_size=512, vocab_pad_multiple=64,
        dtype=jnp.float32, remat=False,
        head_dim=32,
    )
    if cfg.moe:
        # generous capacity so smoke tests are drop-free deterministic
        # (the full configs keep the paper-typical 1.25)
        upd.update(num_experts=8, moe_top_k=2, moe_d_ff=64,
                   num_shared_experts=min(cfg.num_shared_experts, 1),
                   first_k_dense=min(cfg.first_k_dense, 1),
                   capacity_factor=8.0)
    if cfg.mla:
        upd.update(kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=32,
                   qk_rope_head_dim=16, v_head_dim=32, head_dim=48)
    if cfg.family == "vlm":
        upd.update(m_rope_sections=(4, 6, 6), num_vision_tokens=8)
    if cfg.is_encdec:
        upd.update(encoder_layers=2, encoder_frames=16)
    if cfg.family == "ssm":
        upd.update(rwkv_head_dim=32, rwkv_lora_rank=16, ssm_chunk=8,
                   num_heads=4, num_kv_heads=4)
    if cfg.family == "hybrid":
        upd.update(local_window=16, lru_width=128, head_dim=32)
    return dataclasses.replace(cfg, **upd)
