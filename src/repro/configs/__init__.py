from repro.configs.registry import ARCH_IDS, get, smoke

__all__ = ["ARCH_IDS", "get", "smoke"]
