"""DeepSeek-V2-236B [moe]: 60L, d=5120, 128H MLA (kv_lora=512),
expert ff=1536, vocab=102400, 2 shared + 160 routed top-6.
(arXiv:2405.04434). First layer dense (ff=12288) per the paper."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288,  # leading dense layer(s)
    vocab_size=102400, rope_theta=10_000.0,
    moe=True, num_experts=160, moe_top_k=6, moe_d_ff=1536,
    num_shared_experts=2, first_k_dense=1,
    mla=True, kv_lora_rank=512, q_lora_rank=1536,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    head_dim=192,  # qk_nope + qk_rope
    mlp_kind="swiglu", tie_embeddings=True,
)
