"""XLA reference/fallback for the open-addressing lattice hash table.

The paper's CUDA implementation deduplicates lattice keys and resolves
blur neighbors with a GPU hash table (linear probing + atomicCAS). XLA
has no atomics, so the insert re-derives the same table with the
primitives that are actually cheap on an accelerator-less host too
(measured on this image's CPU backend: gathers ~0.1 ms for 144k rows,
scatters ~5 ms, `lax.sort` ~33 ms):

  * **insert** runs in *epochs*: one ``scatter-min`` of row ids claims the
    slots each unresolved row observed empty (deterministic winner = min
    row id), then a scatter-free inner probe loop advances every row
    through the table (gather + compare only) until it either finds its
    key or pauses at a fresh empty slot for the next epoch's claim.
    Benign loads (occupancy <= 0.5) settle in a handful of epochs, so the
    whole dedup costs a few scatters instead of an O(N log N) multi-
    column lexicographic sort.
  * **lookup** is pure gather + compare: probe until the key or an empty
    slot appears. Empty slots never un-fill (no deletions), so hitting
    one proves absence.

The table stores no keys of its own: ``owner[slot]`` is the row id whose
key occupies the slot (``EMPTY = N`` when free), and key comparisons
gather the owner's packed row. ``table_keys`` materializes the
(hcap, npk) key table afterwards for the lookup phase, with
``KEY_SENTINEL`` marking empty slots — a value unreachable by any packed
key within the documented |coord| <= 2^15 - 2 range.

Determinism: given the same inputs, insert is fully deterministic.
Permuting input rows may permute *which slot* each key lands in (claim
races resolve by row id) but never the deduplicated key set — the lattice
build's contract is operator equivalence up to slot permutation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

# empty-slot marker in the materialized key table. Packed lattice keys
# bias 16-bit fields by 2^15 and reject |coord| > 2^15 - 2 (pack_overflow),
# so no valid packed word ever has a low half-word of 0xFFFF.
KEY_SENTINEL = jnp.int32((0x3FFF << 16) | 0xFFFF)

# per-row insert states
_PROBE = 0  # advancing through occupied slots
_WAIT = 1  # observed an empty slot; claim it at the next epoch boundary
_DONE = 2  # slot holding this row's key found
_FAIL = 3  # advanced past every slot without key or space: table full

DEFAULT_INNER_ROUNDS = 16


def hash32(packed: Array) -> Array:
    """FNV-1a fold of the packed key words + murmur3 finalizer. -> uint32."""
    h = jnp.full((packed.shape[0],), 0x811C9DC5, jnp.uint32)
    for j in range(packed.shape[1]):
        h = (h ^ packed[:, j].astype(jnp.uint32)) * jnp.uint32(0x01000193)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def initial_slots(packed: Array, hcap: int) -> Array:
    """Each key's home slot h(key) mod hcap (hcap must be a power of two)."""
    return (hash32(packed) & jnp.uint32(hcap - 1)).astype(jnp.int32)


def hash_insert_xla(packed: Array, hcap: int, *,
                    inner_rounds: int = DEFAULT_INNER_ROUNDS):
    """Insert all N packed keys; dedup falls out of slot sharing.

    Args:
      packed: (N, npk) int32 packed key rows (duplicates expected).
      hcap: power-of-two table capacity; keep occupancy <= 0.5 for
        near-constant probe counts.
      inner_rounds: probe steps between claim scatters. Exhausting them
        just rolls the row into the next epoch (no correctness impact).

    Returns:
      owner: (hcap,) int32 — row id whose key occupies each slot; N = empty.
      slot: (N,) int32 — the slot holding each row's key (valid where ok).
      ok: (N,) bool — False ONLY when the table genuinely ran out of
        space: a row fails after it has ADVANCED through hcap slots
        (visited the whole table) without finding its key or an empty
        slot. Claims serialize one-per-epoch on a shared cluster
        frontier, so epochs are NOT bounded by probes/inner_rounds —
        the loop instead runs while any row is alive; liveness holds
        because an epoch with a WAIT row always claims a slot and a
        PROBE row always advances, so advance counters grow every
        epoch until resolution or provable fullness. A row's advances
        never exceed its final displacement <= cluster length <= m, so
        with m <= cap <= hcap/2 no benign insert can spuriously fail.
    """
    n_rows, _ = packed.shape
    empty = jnp.int32(n_rows)
    mask = hcap - 1
    ids = jnp.arange(n_rows, dtype=jnp.int32)
    # pure safety net: state liveness terminates the loop long before this
    max_epochs = 2 * hcap + 8

    def inner_cond(st):
        _, status, _, k = st
        return jnp.logical_and(k < inner_rounds, jnp.any(status == _PROBE))

    def epoch_cond(st):
        _, _, status, _, ep = st
        alive = (status == _PROBE) | (status == _WAIT)
        return jnp.logical_and(ep < max_epochs, jnp.any(alive))

    def epoch_body(st):
        owner, slot, status, probes, ep = st
        # claim observed-empty slots; min row id wins. Safe against
        # clobbering occupied slots: WAIT rows observed emptiness after
        # the previous epoch's claims, and claims are the only writes.
        cslot = jnp.where(status == _WAIT, slot, hcap)
        owner = owner.at[cslot].min(ids, mode="drop")
        status = jnp.where(status == _WAIT, _PROBE, status)

        def inner_body(st_):  # owner is loop-invariant: probe scatter-free
            slot_, status_, probes_, k = st_
            probing = status_ == _PROBE
            own = owner[slot_]
            is_empty = own == empty
            okey = packed[jnp.clip(own, 0, n_rows - 1)]
            hit = probing & ~is_empty & jnp.all(okey == packed, axis=1)
            status_ = jnp.where(hit, _DONE,
                                jnp.where(probing & is_empty, _WAIT, status_))
            # advancing rows have visited one more distinct slot; a row
            # that advanced hcap times saw the full table: provably no
            # key match and no space left
            advance = status_ == _PROBE
            probes_ = probes_ + advance.astype(jnp.int32)
            status_ = jnp.where(advance & (probes_ >= hcap), _FAIL, status_)
            slot_ = jnp.where(advance, (slot_ + 1) & mask, slot_)
            return slot_, status_, probes_, k + 1

        slot, status, probes, _ = jax.lax.while_loop(
            inner_cond, inner_body, (slot, status, probes, jnp.int32(0)))
        return owner, slot, status, probes, ep + 1

    owner0 = jnp.full((hcap,), empty, jnp.int32)
    status0 = jnp.full((n_rows,), _WAIT, jnp.int32)
    probes0 = jnp.zeros((n_rows,), jnp.int32)
    owner, slot, status, _, _ = jax.lax.while_loop(
        epoch_cond, epoch_body,
        (owner0, initial_slots(packed, hcap), status0, probes0,
         jnp.int32(0)))
    return owner, slot, status == _DONE


def table_keys(owner: Array, packed: Array) -> Array:
    """Materialize the (hcap, npk) key table; empty slots -> KEY_SENTINEL."""
    n_rows = packed.shape[0]
    occ = owner < n_rows
    rows = packed[jnp.clip(owner, 0, n_rows - 1)]
    return jnp.where(occ[:, None], rows, KEY_SENTINEL)


def hash_lookup_xla(tkeys: Array, queries: Array, active: Array,
                    hcap: int) -> Array:
    """Find each query key's slot, or -1 (absent / inactive query).

    Pure gather + compare: probe from the home slot until the key or an
    empty slot (KEY_SENTINEL) appears. No deletions ever happen, so an
    empty slot proves the key was never inserted.
    """
    mask = hcap - 1

    def cond(st):
        _, _, done, k = st
        return jnp.logical_and(k < hcap, ~jnp.all(done))

    def body(st):
        slot, res, done, k = st
        row = tkeys[slot]
        hit = ~done & jnp.all(row == queries, axis=1)
        miss = ~done & (row[:, 0] == KEY_SENTINEL)
        res = jnp.where(hit, slot, res)
        done = done | hit | miss
        slot = jnp.where(done, slot, (slot + 1) & mask)
        return slot, res, done, k + 1

    res0 = jnp.full((queries.shape[0],), -1, jnp.int32)
    _, res, _, _ = jax.lax.while_loop(
        cond, body,
        (initial_slots(queries, hcap), res0, ~active, jnp.int32(0)))
    return res
