"""Build-backend policy + dispatch for the lattice hash table.

Mirrors the blur MVM policy (kernels/blur/ops.py): ``auto`` resolves to a
concrete backend from the platform and the table's VMEM footprint, every
tier stays explicitly reachable, and off-TPU the Pallas kernels dispatch
to the XLA fallback unless the interpreter is requested.

Backend tiers (DESIGN.md §11):

  hash_pallas  accelerator-resident table: sequential-core insert +
               vectorized resident-table lookup (kernel.py). Engaged on
               TPU when the key table fits the VMEM budget.
  hash_xla     epoch-based scatter-min insert + while-loop probe lookup
               (ref.py) — the fast path everywhere else, and the TPU
               fallback for oversized tables.
  sort         the original two-pass lexicographic-sort build
               (core/lattice._build_lattice_impl). Bit-exact oracle: the
               hash backends must match it up to slot permutation.

``auto`` NEVER resolves to "sort": the hash build is the production
default (2.5-5x faster cold/warm on the host backend — BENCH_build.json);
the sort path is kept for verification and as the deterministic
lex-ordered reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hash import ref
from repro.kernels.hash.kernel import hash_insert_pallas, hash_lookup_pallas
from repro.kernels.hash.ref import (hash_insert_xla, hash_lookup_xla,
                                    table_keys)

Array = jax.Array

BUILD_BACKENDS = ("auto", "hash_pallas", "hash_xla", "sort")

# VMEM budget for keeping the key table resident in the lookup kernel
# (same ceiling discipline as kernels/blur/ops.py).
TABLE_BUDGET_BYTES = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def hash_capacity(cap: int) -> int:
    """Power-of-two table size >= 2*cap: occupancy <= 0.5 whenever the
    deduplicated point count fits the lattice capacity at all."""
    cap = max(int(cap), 8)
    return 2 * (1 << (cap - 1).bit_length())


def table_vmem_bytes(hcap: int, npk: int, itemsize: int = 4) -> int:
    return hcap * npk * itemsize


def choose_build_backend(*, hcap: int, npk: int,
                         platform: str | None = None) -> str:
    """Resolve ``auto`` to a concrete build backend for this problem/host."""
    platform = platform or jax.default_backend()
    if platform == "tpu" and \
            table_vmem_bytes(hcap, npk) <= TABLE_BUDGET_BYTES:
        return "hash_pallas"
    return "hash_xla"


def resolve_build_backend(backend: str, *, hcap: int = 0,
                          npk: int = 1) -> str:
    if backend not in BUILD_BACKENDS:
        raise ValueError(f"unknown build backend {backend!r}; want one of "
                         f"{BUILD_BACKENDS}")
    if backend == "auto":
        return choose_build_backend(hcap=hcap, npk=npk)
    return backend


def hash_insert(packed: Array, hcap: int, *, backend: str = "hash_xla",
                interpret: bool | None = None):
    """Dedup-insert all packed key rows -> (owner, slot_of_row, ok).

    ``backend`` must be a concrete hash tier. Off-TPU, "hash_pallas"
    dispatches to the XLA fallback unless ``interpret=True`` explicitly
    asks for the Pallas interpreter (the blur-ops convention).
    """
    if backend == "hash_pallas":
        run_interp = interpret if interpret is not None else False
        if _on_tpu() or run_interp:
            return hash_insert_pallas(packed, hcap, interpret=run_interp)
    return hash_insert_xla(packed, hcap)


def hash_lookup(tkeys: Array, queries: Array, active: Array, hcap: int, *,
                backend: str = "hash_xla",
                interpret: bool | None = None) -> Array:
    """Slot of each query key, or -1 (absent / inactive)."""
    if backend == "hash_pallas":
        run_interp = interpret if interpret is not None else False
        if _on_tpu() or run_interp:
            return hash_lookup_pallas(tkeys, queries, active,
                                      interpret=run_interp)
    return hash_lookup_xla(tkeys, queries, active, hcap)


__all__ = ["BUILD_BACKENDS", "choose_build_backend", "resolve_build_backend",
           "hash_capacity", "hash_insert", "hash_lookup", "table_keys",
           "table_vmem_bytes", "ref"]
