"""Open-addressing hash table for the lattice build (DESIGN.md §11).

Replaces the two O(N log N) lexicographic sorts of the lattice build —
dedup over the n(d+1) vertex keys and the neighbor-table merge-sort —
with insert/lookup on a static-capacity linear-probe hash table, the
same design the paper's CUDA implementation uses. ops.py carries the
backend policy (hash_pallas / hash_xla, with "sort" as the oracle tier
kept in core/lattice.py).
"""
from repro.kernels.hash.ops import (BUILD_BACKENDS, choose_build_backend,
                                    hash_capacity, hash_insert, hash_lookup,
                                    resolve_build_backend, table_keys)

__all__ = ["BUILD_BACKENDS", "choose_build_backend", "hash_capacity",
           "hash_insert", "hash_lookup", "resolve_build_backend",
           "table_keys"]
