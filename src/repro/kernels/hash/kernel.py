"""Pallas TPU kernels for the open-addressing lattice hash table.

Two kernels, mirroring the CUDA hash table of the paper's implementation
(Adams et al. 2010 / Simplex-GP) under TPU constraints:

  * ``hash_lookup_pallas`` — the neighbor-resolution hot path. Fully
    vectorized: the materialized key table stays VMEM-resident across the
    whole grid (constant index_map, like the blur kernels' gather
    source), queries stream in blocks, and each probe round is one
    vectorized gather + compare over the block. Probing stops per lane
    at a key match or an empty slot (KEY_SENTINEL: no deletions, so an
    empty slot proves absence).

  * ``hash_insert_pallas`` — the dedup phase. TPUs have no atomics, but a
    Pallas grid runs *sequentially* on a core, so insertion needs no CAS
    at all: a single program walks the rows in order, probing the
    VMEM-resident ``owner`` table and claiming the first empty slot with
    a plain store. This is scalar-throughput bound (one row at a time)
    and is honest about it — the XLA fallback (ref.py) stays the default
    where the epoch-vectorized insert wins; this kernel exists for
    TPU-resident builds where keeping the table in VMEM and avoiding
    HBM scatter round-trips dominates.

Both kernels take PACKED key rows (int32 words) and are agnostic to the
lattice geometry; hashing runs outside (ref.hash32) so the two backends
share one hash function bit-for-bit. Off-TPU the interpreter is opt-in
(interpret=True), matching kernels/blur's convention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.hash.ref import KEY_SENTINEL, initial_slots

Array = jax.Array

DEFAULT_BLOCK_Q = 1024

# jax renamed TPUCompilerParams -> CompilerParams across versions
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


# ---------------------------------------------------------------------------
# Vectorized lookup.
# ---------------------------------------------------------------------------


def _lookup_kernel(tk_ref, q_ref, h_ref, act_ref, out_ref, *, hcap: int,
                   sentinel: int):
    """One block of queries against the resident key table."""
    tk = tk_ref[...]  # (hcap, npk) — resident gather source
    q = q_ref[...]  # (block_q, npk)
    slot = h_ref[...][:, 0]  # (block_q,) precomputed home slots
    active = act_ref[...][:, 0] != 0
    mask = hcap - 1

    def cond(st):
        _, _, done, k = st
        return jnp.logical_and(k < hcap, ~jnp.all(done))

    def body(st):
        slot_, res, done, k = st
        row = jnp.take(tk, slot_, axis=0)  # (block_q, npk)
        hit = ~done & jnp.all(row == q, axis=1)
        miss = ~done & (row[:, 0] == sentinel)
        res = jnp.where(hit, slot_, res)
        done = done | hit | miss
        slot_ = jnp.where(done, slot_, (slot_ + 1) & mask)
        return slot_, res, done, k + 1

    res0 = jnp.full(slot.shape, -1, jnp.int32)
    _, res, _, _ = jax.lax.while_loop(
        cond, body, (slot, res0, ~active, jnp.int32(0)))
    out_ref[...] = res[:, None]


def hash_lookup_pallas(tkeys: Array, queries: Array, active: Array, *,
                       block_q: int = DEFAULT_BLOCK_Q,
                       interpret: bool = False) -> Array:
    """Slot of each query key, or -1 (absent / inactive). tkeys resident."""
    hcap, npk = tkeys.shape
    nq = queries.shape[0]
    h0 = initial_slots(queries, hcap)[:, None]
    act = active.astype(jnp.int32)[:, None]
    pad = (-nq) % block_q
    if pad:
        queries = jnp.concatenate(
            [queries, jnp.zeros((pad, npk), queries.dtype)], axis=0)
        h0 = jnp.concatenate([h0, jnp.zeros((pad, 1), h0.dtype)], axis=0)
        act = jnp.concatenate([act, jnp.zeros((pad, 1), act.dtype)], axis=0)
    padded = nq + pad

    kernel = functools.partial(_lookup_kernel, hcap=hcap,
                               sentinel=int(KEY_SENTINEL))
    out = pl.pallas_call(
        kernel,
        grid=(padded // block_q,),
        in_specs=[
            pl.BlockSpec((hcap, npk), lambda i: (0, 0)),  # resident table
            pl.BlockSpec((block_q, npk), lambda i: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, 1), jnp.int32),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(tkeys, queries, h0, act)
    return out[:nq, 0]


# ---------------------------------------------------------------------------
# Sequential-core insert.
# ---------------------------------------------------------------------------

# per-row probe outcomes inside the insert kernel
_CONTINUE = 0
_FOUND = 1
_CLAIM = 2
_FULL = 3


def _insert_kernel(pk_ref, h_ref, owner_ref, slot_ref, ok_ref, *,
                   hcap: int, n_rows: int):
    """Serial open-addressing insert; the grid is one sequential program."""
    empty = jnp.int32(n_rows)
    mask = hcap - 1
    owner_ref[...] = jnp.full((hcap, 1), empty, jnp.int32)

    def row_body(i, carry):
        key = pk_ref[pl.dslice(i, 1), :]  # (1, npk)
        h = h_ref[i, 0]

        def cond(st):
            _, state, _ = st
            return state == _CONTINUE

        def body(st):
            slot, state, k = st
            own = owner_ref[slot, 0]
            is_empty = own == empty
            okey = pk_ref[pl.dslice(jnp.where(is_empty, 0, own), 1), :]
            match = jnp.logical_and(~is_empty, jnp.all(okey == key))
            state = jnp.where(match, _FOUND,
                              jnp.where(is_empty, _CLAIM,
                                        jnp.where(k + 1 >= hcap, _FULL,
                                                  _CONTINUE)))
            slot = jnp.where(state == _CONTINUE, (slot + 1) & mask, slot)
            return slot, state, k + 1

        slot, state, _ = jax.lax.while_loop(
            cond, body, (h, jnp.int32(_CONTINUE), jnp.int32(0)))

        # claim-after-probe: execution is sequential, so the store cannot
        # race with any other row's probe
        @pl.when(state == _CLAIM)
        def _claim():
            owner_ref[slot, 0] = i

        slot_ref[i, 0] = slot
        ok_ref[i, 0] = jnp.where(state == _FULL, 0, 1)
        return carry

    jax.lax.fori_loop(0, n_rows, row_body, jnp.int32(0))


def hash_insert_pallas(packed: Array, hcap: int, *,
                       interpret: bool = False):
    """Serial insert of all N packed key rows. Same contract as
    ``ref.hash_insert_xla`` (owner, slot, ok); slot assignment may differ
    (first-come claims instead of min-row-id epoch claims) — the build's
    equivalence is up to slot permutation either way."""
    n_rows, npk = packed.shape
    h0 = initial_slots(packed, hcap)[:, None]
    owner, slot, ok = pl.pallas_call(
        functools.partial(_insert_kernel, hcap=hcap, n_rows=n_rows),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_specs=(
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((hcap, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_rows, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_rows, 1), jnp.int32),
        ),
        interpret=interpret,
    )(packed, h0)
    return owner[:, 0], slot[:, 0], ok[:, 0] != 0
