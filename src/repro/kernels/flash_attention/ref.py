"""Pure-jnp oracle for (GQA, causal) scaled-dot-product attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                  scale: float | None = None) -> Array:
    """q: (b, hq, sq, d); k: (b, hkv, sk, d); v: (b, hkv, sk, dv).

    hq % hkv == 0; dv may differ from d (MLA). Softmax in float32
    regardless of input dtype (the kernel matches this).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    qg = q.reshape(b, hkv, group, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        sk = k.shape[2]
        # query position i attends to keys <= i + (sk - sq) (decode offset)
        qpos = jnp.arange(sq)[:, None] + (sk - sq)
        kpos = jnp.arange(sk)[None, :]
        mask = kpos <= qpos
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, v.shape[-1]).astype(q.dtype)
