"""Public flash-attention op: padding, backend dispatch, GQA contract."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (DEFAULT_BLOCK_K,
                                                  DEFAULT_BLOCK_Q,
                                                  flash_attention_pallas)
from repro.kernels.flash_attention.ref import attention_ref

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def blockwise_attention_xla(q: Array, k: Array, v: Array, *,
                            causal: bool = True, block_q: int = 512,
                            block_k: int = 1024) -> Array:
    """Flash-style online-softmax attention in pure XLA (no Pallas).

    Same math as the Pallas kernel but expressed as a lax.scan over kv
    blocks nested in a lax.map over q blocks, so peak memory is
    O(b·h·block_q·block_k) instead of O(b·h·s²). This is the long-sequence
    path for CPU dry-runs and the fallback on backends without Pallas; on
    identical inputs it matches attention_ref to float32 roundoff
    (asserted in tests/test_kernels_flash.py).
    """
    b, hq, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    vd = v.shape[-1]  # may differ from hd (MLA)
    group = hq // hkv
    scale = hd ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    offset = sk - sq
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk = (sq + pq) // block_q, (sk + pk) // block_k
    # keep operands in their input dtype (bf16 on the dry-run path) and
    # accumulate in f32 via preferred_element_type — materializing f32
    # copies of q/k/v doubled the measured HBM traffic
    qg = qp.reshape(b, hkv, group, nq, block_q, hd)
    kb = kp.reshape(b, hkv, nk, block_k, hd)
    vb = vp.reshape(b, hkv, nk, block_k, vd)

    kpos = (jnp.arange(nk)[:, None] * block_k
            + jnp.arange(block_k)[None, :])  # (nk, bk)
    kb_t = kb.transpose(2, 0, 1, 3, 4)
    vb_t = vb.transpose(2, 0, 1, 3, 4)

    @functools.partial(jax.checkpoint, static_argnums=(1,))
    def q_block(qi, nk_i):
        """One q block against its first nk_i kv blocks (causal skip)."""
        qblk = jax.lax.dynamic_index_in_dim(qg, qi, axis=3,
                                            keepdims=False)
        qpos = qi * block_q + jnp.arange(block_q) + offset  # (bq,)

        @jax.checkpoint
        def kv_step(carry, inp):
            m, l, acc = carry
            kblk, vblk, kp_blk = inp  # (b,hkv,bk,hd) x2, (bk,)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            valid = kp_blk[None, :] < sk
            if causal:
                valid = valid & (kp_blk[None, :] <= qpos[:, None])
            s = jnp.where(valid[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = (acc * alpha[..., None]
                       + jnp.einsum("bhgqk,bhkd->bhgqd",
                                    p.astype(vblk.dtype), vblk,
                                    preferred_element_type=jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, group, block_q), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, group, block_q), jnp.float32)
        a0 = jnp.zeros((b, hkv, group, block_q, vd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb_t[:nk_i], vb_t[:nk_i], kpos[:nk_i]))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    if causal:
        # static unroll over q blocks: block qi only sees keys up to
        # (qi+1)*block_q + offset, so its kv scan is statically shorter —
        # ~2x fewer attention FLOPs than scanning all nk masked blocks
        # (EXPERIMENTS.md §Perf iteration L1).
        blocks = []
        for qi in range(nq):
            hi = qi * block_q + (block_q - 1) + offset
            nk_i = min(nk, max(1, hi // block_k + 1))
            blocks.append(q_block(jnp.int32(qi), nk_i))
        out = jnp.stack(blocks)  # (nq, b, hkv, g, bq, vd)
    else:
        out = jax.lax.map(lambda qi: q_block(qi, nk), jnp.arange(nq))
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, sq + pq, vd)
    return out[:, :, :sq].astype(q.dtype)


# sequences at or above this length avoid the O(s^2) reference
_BLOCKWISE_THRESHOLD = 2048


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    use_pallas: bool | None = None) -> Array:
    """Dispatching wrapper: Pallas kernel on TPU, XLA elsewhere.

    The model code (models/attention paths) calls this everywhere, so the
    same model definition runs the Pallas kernel on hardware, the compact
    O(s²) reference on short CPU shapes, and the blockwise XLA form on
    long sequences (32k prefill / 4k train dry-runs would otherwise
    materialize s² logits).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        if max(q.shape[2], k.shape[2]) >= _BLOCKWISE_THRESHOLD:
            return blockwise_attention_xla(q, k, v, causal=causal)
        return attention_ref(q, k, v, causal=causal)
    if v.shape[-1] != q.shape[-1]:  # MLA: pad v for the same-dim kernel
        vd = v.shape[-1]
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                        (0, q.shape[-1] - vd)))
        out = flash_attention(q, k, v, causal=causal, use_pallas=True)
        return out[..., :vd]

    b, hq, sq, hd = q.shape
    sk = k.shape[2]
    # pad head_dim to 128 multiples, seq to block multiples
    pd = (-hd) % 128
    pq = (-sq) % min(DEFAULT_BLOCK_Q, max(sq, 8))
    pk = (-sk) % min(DEFAULT_BLOCK_K, max(sk, 8))
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, pd)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, pd)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, pd)))
    out = flash_attention_pallas(qp, kp, vp, causal=causal,
                                 scale=hd ** -0.5,
                                 offset=sk - sq, k_valid=sk,
                                 interpret=not _on_tpu())
    return out[:, :, :sq, :hd]
