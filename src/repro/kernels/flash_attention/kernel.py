"""Block-wise online-softmax (flash) attention for the LM architectures.

Standard flash tiling adapted to the TPU memory hierarchy:

  grid = (batch * q_heads, sq / block_q, sk / block_k)
  dims = (parallel, parallel, arbitrary)  — kv dimension is sequential so
  the running (m, l, acc) state lives in VMEM scratch across kv steps.

GQA is handled with *index maps*, not materialized head repetition: the
k/v BlockSpecs map q-head h to kv-head h // group, so kv tiles for a group
of q heads are re-streamed from HBM but never duplicated there.

Causal masking compares global q/k positions (with the sk - sq decode
offset); fully-masked kv blocks are skipped cheaply via @pl.when on the
block-level causal bound, which halves work for the training shapes.

MXU alignment: block_q/block_k default to 128/256; head_dim is padded to a
multiple of 128 by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

# jax renamed TPUCompilerParams -> CompilerParams across versions
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  offset: int, k_valid: int):
    """offset = sk_orig - sq_orig (decode); k_valid = sk before padding."""
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level skip: first key of this block vs last query of the q
    # block (with decode offset), and key-padding bound
    q_last = qi * block_q + (block_q - 1) + offset
    k_first = kj * block_k
    live = k_first < k_valid
    if causal:
        live = live & (k_first <= q_last)

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot(q, k.T,
                        precision=jax.lax.Precision.HIGHEST) * scale
        kpos = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        valid = kpos < k_valid
        if causal:
            qpos = (qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)) + offset
            valid = valid & (kpos <= qpos)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]  # (bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=1))[:, None]
        p = jnp.exp(s - m_cur)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_cur)  # (bq, 1)
        l_cur = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, precision=jax.lax.Precision.HIGHEST)
        m_scr[...] = m_cur
        l_scr[...] = l_cur

    @pl.when(kj == nk - 1)
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q: Array, k: Array, v: Array, *,
                           causal: bool = True,
                           scale: float | None = None,
                           offset: int | None = None,
                           k_valid: int | None = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = True) -> Array:
    """q: (b, hq, sq, hd); k/v: (b, hkv, sk, hd). Shapes pre-padded.

    offset: original (sk - sq) BEFORE padding (decode alignment);
    k_valid: original sk BEFORE padding (padded keys are masked out).
    """
    b, hq, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    scale = (hd ** -0.5) if scale is None else scale
    offset = (sk - sq) if offset is None else offset
    k_valid = sk if k_valid is None else k_valid

    qf = q.reshape(b * hq, sq, hd)
    kf = k.reshape(b * hkv, sk, hd)
    vf = v.reshape(b * hkv, sk, hd)
    grid = (b * hq, sq // block_q, sk // block_k)

    def kv_index(h, qi, kj):
        # q-head h lives in batch h // hq; its kv head is (h % hq) // group
        return ((h // hq) * hkv + (h % hq) // group, kj, 0)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k,
                               offset=offset, k_valid=k_valid)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, qi, kj: (h, qi, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda h, qi, kj: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, hd)
