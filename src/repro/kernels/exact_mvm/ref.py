"""Pure-jnp oracle for the exact stationary-kernel MVM."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kernels_math import KernelProfile, pairwise_sqdist

Array = jax.Array


def exact_mvm_ref(profile: KernelProfile, x: Array, v: Array,
                  *, outputscale: float | Array = 1.0) -> Array:
    """u = outputscale * K(X, X) v, dense. x: (n, d), v: (n, c)."""
    tau = jnp.sqrt(pairwise_sqdist(x, x) + 1e-30)
    return outputscale * (profile.k(tau) @ v)
