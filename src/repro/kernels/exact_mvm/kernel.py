"""Tiled exact stationary-kernel MVM (the paper's KeOps baseline, §5.1/Fig 6).

TPU mapping of the "never materialize K" trick: the (n x n) kernel matrix is
produced tile-by-tile in VMEM and immediately contracted against v.

Grid: (n/bn row-tiles, n/bm col-tiles), row-parallel, cols sequential
(accumulation). Per step the kernel holds
    x_i (bn, d) + x_j (bm, d) + v_j (bm, c) + out (bn, c) + K-tile (bn, bm)
in VMEM; with bn = bm = 256, d,c <= 128 that is ~0.5 MB — far under the
16 MB/core budget, and the (bn x bm) distance matmul x_i @ x_j^T runs on the
MXU with 128-aligned tiles.

Arithmetic intensity: the K-tile costs O(bn bm d) FLOPs for O((bn+bm) d)
bytes — compute-bound for n >> bn, exactly why the paper's exact baseline
saturates GPU FLOPs and why Fig 6's crossover sits at ~1e5 points.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.kernels_math import KernelProfile

Array = jax.Array

# jax renamed TPUCompilerParams -> CompilerParams across versions
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_M = 256


def _mvm_kernel(x_i_ref, x_j_ref, v_j_ref, o_ref, *, profile: KernelProfile,
                num_col_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xi = x_i_ref[...]  # (bn, d)
    xj = x_j_ref[...]  # (bm, d)
    vj = v_j_ref[...]  # (bm, c)
    # pairwise squared distances via the MXU: |xi|^2 + |xj|^2 - 2 xi xj^T
    ni = jnp.sum(xi * xi, axis=1)[:, None]
    nj = jnp.sum(xj * xj, axis=1)[None, :]
    sq = jnp.maximum(ni + nj - 2.0 * jax.lax.dot(
        xi, xj.T, precision=jax.lax.Precision.HIGHEST), 0.0)
    tau = jnp.sqrt(sq + 1e-30)
    k_tile = profile.k(tau)  # (bn, bm), fused elementwise on the VPU
    o_ref[...] += jax.lax.dot(k_tile, vj,
                              precision=jax.lax.Precision.HIGHEST)


def exact_mvm_pallas(profile: KernelProfile, x: Array, v: Array, *,
                     block_n: int = DEFAULT_BLOCK_N,
                     block_m: int = DEFAULT_BLOCK_M,
                     interpret: bool = True) -> Array:
    """u = K(X,X) v with K produced tile-wise in VMEM.

    x: (n, d) lengthscale-normalized inputs; v: (n, c). n must be padded to
    a multiple of the block sizes by the caller (ops.py handles it).
    """
    n, d = x.shape
    c = v.shape[1]
    assert n % block_n == 0 and n % block_m == 0, (n, block_n, block_m)
    grid = (n // block_n, n // block_m)

    kernel = functools.partial(_mvm_kernel, profile=profile,
                               num_col_blocks=grid[1])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),  # x rows
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),  # x cols
            pl.BlockSpec((block_m, c), lambda i, j: (j, 0)),  # v cols
        ],
        out_specs=pl.BlockSpec((block_n, c), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), v.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, x, v)
