"""Jit'd public wrapper for the exact-MVM kernel: padding + backend choice."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.kernels_math import KernelProfile, get_profile
from repro.kernels.exact_mvm.kernel import (DEFAULT_BLOCK_M, DEFAULT_BLOCK_N,
                                            exact_mvm_pallas)

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("profile_name", "block_n",
                                             "block_m"))
def exact_mvm(profile_name: str, x: Array, v: Array, *,
              outputscale: Array | float = 1.0,
              block_n: int = DEFAULT_BLOCK_N,
              block_m: int = DEFAULT_BLOCK_M) -> Array:
    """u = outputscale * K(X,X) v via the tiled Pallas kernel.

    Pads n to the block size (padded rows sit at +inf distance -> k = 0 for
    all decaying profiles, so they contribute nothing).
    """
    profile = get_profile(profile_name)
    n, d = x.shape
    block_n = min(block_n, max(8, 1 << (n - 1).bit_length()))
    block_m = min(block_m, block_n)
    pad = (-n) % max(block_n, block_m)
    if pad:
        # padded points are pushed far away; exp-decaying kernels vanish
        far = jnp.full((pad, d), 1e6, x.dtype)
        x = jnp.concatenate([x, far], axis=0)
        v = jnp.concatenate([v, jnp.zeros((pad, v.shape[1]), v.dtype)],
                            axis=0)
    out = exact_mvm_pallas(profile, x, v, block_n=block_n, block_m=block_m,
                           interpret=not _on_tpu())
    return outputscale * out[:n]
