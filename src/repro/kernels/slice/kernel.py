"""Fused Pallas TPU kernel for frozen-table slice queries (DESIGN.md §12).

One ``pallas_call`` runs the whole per-query pipeline — hash probe of the
d+1 enclosing vertices, dense-row translation, table gather, barycentric
contraction, miss accumulation — with ALL frozen state resident in VMEM
for the whole grid (the blur kernels' constant-index-map pattern):

  resident   tkeys (hcap, npk), row_of_slot (hcap, 1), tables (m+1, c)
  streamed   per query block: packed vertex keys + precomputed home
             slots + active mask ((block_b*(d+1), .) rows, query-major)
             and barycentric weights (block_b, d+1)
  out        (block_b, c) sliced values + (block_b, 1) miss mass

The probe loop is the vectorized lookup of ``kernels/hash/kernel.py``:
each round is one gather + compare over the block's (d+1)-vertex rows,
stopping per lane at a key match or an empty slot (KEY_SENTINEL — no
deletions, so emptiness proves absence). A serving batch therefore costs
zero HBM round-trips between lookup and slice, versus 2 kernel dispatches
plus an (b*(d+1), c) HBM intermediate on the unfused path.

Off-TPU the interpreter is opt-in (interpret=True), matching the blur and
hash kernels' convention; ops.py dispatches to the XLA reference instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.hash.ref import KEY_SENTINEL, initial_slots

Array = jax.Array

DEFAULT_BLOCK_B = 256

# jax renamed TPUCompilerParams -> CompilerParams across versions
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _slice_kernel(tk_ref, s2r_ref, tab_ref, q_ref, h_ref, act_ref, w_ref,
                  out_ref, miss_ref, *, hcap: int, m: int, dp1: int,
                  sentinel: int):
    """One block of queries against the resident index + tables."""
    tk = tk_ref[...]  # (hcap, npk) — resident gather source
    q = q_ref[...]  # (block_b*dp1, npk)
    slot = h_ref[...][:, 0]
    active = act_ref[...][:, 0] != 0
    mask = hcap - 1

    def cond(st):
        _, _, done, k = st
        return jnp.logical_and(k < hcap, ~jnp.all(done))

    def body(st):
        slot_, res, done, k = st
        row = jnp.take(tk, slot_, axis=0)  # (block_b*dp1, npk)
        hit = ~done & jnp.all(row == q, axis=1)
        empty = ~done & (row[:, 0] == sentinel)
        res = jnp.where(hit, slot_, res)
        done = done | hit | empty
        slot_ = jnp.where(done, slot_, (slot_ + 1) & mask)
        return slot_, res, done, k + 1

    res0 = jnp.full(slot.shape, -1, jnp.int32)
    _, res, _, _ = jax.lax.while_loop(
        cond, body, (slot, res0, ~active, jnp.int32(0)))

    s2r = s2r_ref[...][:, 0]  # (hcap,)
    row = jnp.where(res >= 0, jnp.take(s2r, jnp.clip(res, 0, hcap - 1)), m)
    tab = tab_ref[...]  # (m+1, c)
    vals = jnp.take(tab, row, axis=0)  # (block_b*dp1, c)
    w = w_ref[...].astype(tab.dtype)  # (block_b, dp1)
    bb = w.shape[0]
    absent = (row == m).astype(tab.dtype)

    # query-major rows: vertex k of query i sits at i*dp1 + k
    base = jax.lax.broadcasted_iota(jnp.int32, (bb, 1), 0)[:, 0] * dp1
    out = jnp.zeros((bb, tab.shape[1]), tab.dtype)
    miss = jnp.zeros((bb,), tab.dtype)
    for k in range(dp1):
        out = out + w[:, k][:, None] * jnp.take(vals, base + k, axis=0)
        miss = miss + w[:, k] * jnp.take(absent, base + k)
    out_ref[...] = out
    # clip to the documented [0, 1] contract (f32 weight sums are 1 +/- eps)
    miss_ref[...] = jnp.clip(miss, 0.0, 1.0)[:, None]


def _slice_tangent_kernel(tk_ref, s2r_ref, tab_ref, q_ref, h_ref, act_ref,
                          w_ref, wd_ref, out_ref, outd_ref, miss_ref, *,
                          hcap: int, m: int, dp1: int, sentinel: int):
    """Primal + directional-tangent slice block (DESIGN.md §15).

    Identical probe to ``_slice_kernel``; the gathered table rows feed TWO
    barycentric contractions — against the weights and against their
    directional derivative — so the query-space JVP costs zero extra
    probes or gathers over the primal.
    """
    tk = tk_ref[...]
    q = q_ref[...]
    slot = h_ref[...][:, 0]
    active = act_ref[...][:, 0] != 0
    mask = hcap - 1

    def cond(st):
        _, _, done, k = st
        return jnp.logical_and(k < hcap, ~jnp.all(done))

    def body(st):
        slot_, res, done, k = st
        row = jnp.take(tk, slot_, axis=0)
        hit = ~done & jnp.all(row == q, axis=1)
        empty = ~done & (row[:, 0] == sentinel)
        res = jnp.where(hit, slot_, res)
        done = done | hit | empty
        slot_ = jnp.where(done, slot_, (slot_ + 1) & mask)
        return slot_, res, done, k + 1

    res0 = jnp.full(slot.shape, -1, jnp.int32)
    _, res, _, _ = jax.lax.while_loop(
        cond, body, (slot, res0, ~active, jnp.int32(0)))

    s2r = s2r_ref[...][:, 0]
    row = jnp.where(res >= 0, jnp.take(s2r, jnp.clip(res, 0, hcap - 1)), m)
    tab = tab_ref[...]
    vals = jnp.take(tab, row, axis=0)
    w = w_ref[...].astype(tab.dtype)
    wd = wd_ref[...].astype(tab.dtype)
    bb = w.shape[0]
    absent = (row == m).astype(tab.dtype)

    base = jax.lax.broadcasted_iota(jnp.int32, (bb, 1), 0)[:, 0] * dp1
    out = jnp.zeros((bb, tab.shape[1]), tab.dtype)
    out_d = jnp.zeros((bb, tab.shape[1]), tab.dtype)
    miss = jnp.zeros((bb,), tab.dtype)
    for k in range(dp1):
        v = jnp.take(vals, base + k, axis=0)
        out = out + w[:, k][:, None] * v
        out_d = out_d + wd[:, k][:, None] * v
        miss = miss + w[:, k] * jnp.take(absent, base + k)
    out_ref[...] = out
    outd_ref[...] = out_d
    miss_ref[...] = jnp.clip(miss, 0.0, 1.0)[:, None]


def slice_query_tangent_pallas(tkeys: Array, row_of_slot: Array,
                               tables: Array, q_packed: Array,
                               weights: Array, weights_dot: Array,
                               active: Array, *,
                               block_b: int = DEFAULT_BLOCK_B,
                               interpret: bool = False
                               ) -> tuple[Array, Array, Array]:
    """Fused lookup + primal/tangent slice; contract of
    ``ref.slice_query_tangent_xla``."""
    hcap, npk = tkeys.shape
    b, dp1 = weights.shape
    m1, c = tables.shape
    h0 = initial_slots(q_packed, hcap)[:, None]
    act = active.astype(jnp.int32)[:, None]
    pad = (-b) % block_b
    if pad:
        q_packed = jnp.concatenate(
            [q_packed, jnp.zeros((pad * dp1, npk), q_packed.dtype)], axis=0)
        h0 = jnp.concatenate([h0, jnp.zeros((pad * dp1, 1), h0.dtype)])
        act = jnp.concatenate([act, jnp.zeros((pad * dp1, 1), act.dtype)])
        weights = jnp.concatenate(
            [weights, jnp.zeros((pad, dp1), weights.dtype)], axis=0)
        weights_dot = jnp.concatenate(
            [weights_dot, jnp.zeros((pad, dp1), weights_dot.dtype)], axis=0)
    padded = b + pad

    kernel = functools.partial(_slice_tangent_kernel, hcap=hcap, m=m1 - 1,
                               dp1=dp1, sentinel=int(KEY_SENTINEL))
    resident = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))  # noqa: E731
    out, out_d, miss = pl.pallas_call(
        kernel,
        grid=(padded // block_b,),
        in_specs=[
            resident((hcap, npk)),  # tkeys
            resident((hcap, 1)),  # row_of_slot
            resident((m1, c)),  # tables
            pl.BlockSpec((block_b * dp1, npk), lambda i: (i, 0)),
            pl.BlockSpec((block_b * dp1, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b * dp1, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, dp1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, dp1), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_b, c), lambda i: (i, 0)),
            pl.BlockSpec((block_b, c), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((padded, c), tables.dtype),
            jax.ShapeDtypeStruct((padded, c), tables.dtype),
            jax.ShapeDtypeStruct((padded, 1), tables.dtype),
        ),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(tkeys, row_of_slot.reshape(-1, 1), tables, q_packed, h0, act,
      weights, weights_dot)
    return out[:b], out_d[:b], miss[:b, 0]


def slice_query_pallas(tkeys: Array, row_of_slot: Array, tables: Array,
                       q_packed: Array, weights: Array, active: Array, *,
                       block_b: int = DEFAULT_BLOCK_B,
                       interpret: bool = False) -> tuple[Array, Array]:
    """Fused lookup+slice; same contract as ``ref.slice_query_xla``."""
    hcap, npk = tkeys.shape
    b, dp1 = weights.shape
    m1, c = tables.shape
    h0 = initial_slots(q_packed, hcap)[:, None]
    act = active.astype(jnp.int32)[:, None]
    pad = (-b) % block_b
    if pad:
        q_packed = jnp.concatenate(
            [q_packed, jnp.zeros((pad * dp1, npk), q_packed.dtype)], axis=0)
        h0 = jnp.concatenate([h0, jnp.zeros((pad * dp1, 1), h0.dtype)])
        act = jnp.concatenate([act, jnp.zeros((pad * dp1, 1), act.dtype)])
        weights = jnp.concatenate(
            [weights, jnp.zeros((pad, dp1), weights.dtype)], axis=0)
    padded = b + pad

    kernel = functools.partial(_slice_kernel, hcap=hcap, m=m1 - 1, dp1=dp1,
                               sentinel=int(KEY_SENTINEL))
    resident = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))  # noqa: E731
    out, miss = pl.pallas_call(
        kernel,
        grid=(padded // block_b,),
        in_specs=[
            resident((hcap, npk)),  # tkeys
            resident((hcap, 1)),  # row_of_slot
            resident((m1, c)),  # tables
            pl.BlockSpec((block_b * dp1, npk), lambda i: (i, 0)),
            pl.BlockSpec((block_b * dp1, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b * dp1, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, dp1), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_b, c), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((padded, c), tables.dtype),
            jax.ShapeDtypeStruct((padded, 1), tables.dtype),
        ),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(tkeys, row_of_slot.reshape(-1, 1), tables, q_packed, h0, act,
      weights)
    return out[:b], miss[:b, 0]
