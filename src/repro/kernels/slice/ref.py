"""XLA reference/fallback for frozen-table slice queries (DESIGN.md §12).

The serving hot path: each query point was embedded into its enclosing
simplex (d+1 packed vertex keys + barycentric weights) and now needs the
barycentric contraction of FROZEN per-lattice-point tables at those
vertices. Per query that is

  * d+1 hash probes against the lattice index (``kernels/hash``'s
    gather-only lookup — an empty slot proves absence),
  * d+1 gathers from the dense (m+1, c) table,
  * one (d+1) x c contraction,

with NO build, NO solve, and NO collective — the whole point of the
frozen serving path. Vertices absent from the index land on the zero row
``m`` and contribute nothing (standard permutohedral slicing semantics);
their barycentric mass is returned per query as the slice-miss fidelity
diagnostic (0 = the query's simplex is fully inside the frozen lattice).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hash.ref import hash_lookup_xla

Array = jax.Array


def slice_query_xla(tkeys: Array, row_of_slot: Array, tables: Array,
                    q_packed: Array, weights: Array, active: Array,
                    hcap: int) -> tuple[Array, Array]:
    """Slice frozen ``tables`` at embedded queries.

    Args:
      tkeys: (hcap, npk) int32 materialized key table (empty -> sentinel).
      row_of_slot: (hcap,) int32 hash slot -> dense row; misses use ``m``.
      tables: (m+1, c) frozen values; row m is the zero miss row.
      q_packed: (b*(d+1), npk) packed vertex keys, query-major.
      weights: (b, d+1) barycentric weights (nonnegative, sum to 1).
      active: (b*(d+1),) bool — False vertices are forced misses (used
        for padding rows and pack-overflowed queries).

    Returns:
      out: (b, c) sliced table values.
      miss: (b,) barycentric mass on absent/inactive vertices, in [0, 1].
    """
    b, dp1 = weights.shape
    m = tables.shape[0] - 1
    hres = hash_lookup_xla(tkeys, q_packed, active, hcap)
    row = jnp.where(hres >= 0,
                    jnp.take(row_of_slot, jnp.clip(hres, 0, hcap - 1)),
                    m)
    vals = jnp.take(tables, row, axis=0).reshape(b, dp1, -1)
    out = jnp.einsum("bkc,bk->bc", vals, weights.astype(tables.dtype))
    missed = (row == m).reshape(b, dp1)
    # clip: f32 barycentric weights sum to 1 +/- eps, and the documented
    # contract (and the fully-in-lattice miss == 0 exactness) is [0, 1]
    miss = jnp.clip(
        jnp.sum(weights * missed.astype(weights.dtype), axis=1), 0.0, 1.0)
    return out, miss


def slice_query_tangent_xla(tkeys: Array, row_of_slot: Array, tables: Array,
                            q_packed: Array, weights: Array,
                            weights_dot: Array, active: Array,
                            hcap: int) -> tuple[Array, Array, Array]:
    """Fused primal + directional tangent slice (DESIGN.md §15).

    The frozen tables are constants and the table rows are piecewise
    constant in the query, so the query-space JVP of the slice is the
    SAME contraction against the tangent weights: probe once, gather
    once, contract twice. ``weights_dot`` is the (b, d+1) directional
    derivative of the barycentric weights
    (``lattice.embed_weight_tangent``); rows missing from the index sit
    on the zero row m and contribute zero to both contractions — the
    subgradient convention for off-lattice mass.

    Returns (out (b, c), out_dot (b, c), miss (b,)).
    """
    b, dp1 = weights.shape
    m = tables.shape[0] - 1
    hres = hash_lookup_xla(tkeys, q_packed, active, hcap)
    row = jnp.where(hres >= 0,
                    jnp.take(row_of_slot, jnp.clip(hres, 0, hcap - 1)),
                    m)
    vals = jnp.take(tables, row, axis=0).reshape(b, dp1, -1)
    out = jnp.einsum("bkc,bk->bc", vals, weights.astype(tables.dtype))
    out_dot = jnp.einsum("bkc,bk->bc", vals, weights_dot.astype(tables.dtype))
    missed = (row == m).reshape(b, dp1)
    miss = jnp.clip(
        jnp.sum(weights * missed.astype(weights.dtype), axis=1), 0.0, 1.0)
    return out, out_dot, miss


def slice_query_jacobian_xla(tkeys: Array, row_of_slot: Array, tables: Array,
                             q_packed: Array, weights: Array, wjac: Array,
                             active: Array,
                             hcap: int) -> tuple[Array, Array, Array]:
    """Primal + FULL query-space Jacobian slice in one probe.

    ``wjac`` is the (b, d+1, d) barycentric-weight Jacobian
    (``lattice.embed_weight_jacobian``); the d directional tangents share
    the single gather: jac[b, c, j] = sum_k vals[b, k, c] wjac[b, k, j].
    O(d^2 c) per query on top of the primal's O(d c) — still no solve, no
    extra probes. Returns (out (b, c), jac (b, c, d), miss (b,)).
    """
    b, dp1 = weights.shape
    m = tables.shape[0] - 1
    hres = hash_lookup_xla(tkeys, q_packed, active, hcap)
    row = jnp.where(hres >= 0,
                    jnp.take(row_of_slot, jnp.clip(hres, 0, hcap - 1)),
                    m)
    vals = jnp.take(tables, row, axis=0).reshape(b, dp1, -1)
    out = jnp.einsum("bkc,bk->bc", vals, weights.astype(tables.dtype))
    jac = jnp.einsum("bkc,bkj->bcj", vals, wjac.astype(tables.dtype))
    missed = (row == m).reshape(b, dp1)
    miss = jnp.clip(
        jnp.sum(weights * missed.astype(weights.dtype), axis=1), 0.0, 1.0)
    return out, jac, miss
