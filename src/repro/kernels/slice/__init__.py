"""Frozen-table slice queries (DESIGN.md §12): the serving-path kernel."""
