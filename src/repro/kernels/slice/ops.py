"""Serving-slice policy + dispatch for frozen-table queries (DESIGN.md §12).

Mirrors the blur (kernels/blur/ops.py) and build (kernels/hash/ops.py)
policies: ``auto`` resolves from the platform and the frozen state's VMEM
footprint, every tier stays explicitly reachable, and off-TPU the Pallas
kernel dispatches to the XLA fallback unless the interpreter is
requested.

Backend tiers:

  slice_pallas  one fused pallas_call per query batch: hash probe +
                dense-row translation + table gather + barycentric
                contraction with tkeys/row_of_slot/tables VMEM-resident
                (kernel.py). Engaged on TPU when the frozen state fits
                the VMEM budget.
  slice_xla     hash lookup (kernels/hash/ref.py) + gather + einsum —
                the fallback everywhere else and for oversized tables.
"""
from __future__ import annotations

import jax

from repro.core.lattice import LatticeIndex
from repro.kernels.slice.kernel import (slice_query_pallas,
                                        slice_query_tangent_pallas)
from repro.kernels.slice.ref import (slice_query_jacobian_xla,
                                     slice_query_tangent_xla,
                                     slice_query_xla)

Array = jax.Array

SLICE_BACKENDS = ("auto", "slice_pallas", "slice_xla")

# VMEM budget for the resident frozen state (key table + row map + value
# tables), same ceiling discipline as the other kernel policies.
SERVE_BUDGET_BYTES = 10 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def frozen_vmem_bytes(hcap: int, npk: int, m1: int, c: int,
                      itemsize: int = 4) -> int:
    """Resident bytes of the fused query kernel's frozen state."""
    return itemsize * (hcap * npk + hcap + m1 * c)


def choose_slice_backend(*, hcap: int, npk: int, m1: int, c: int,
                         platform: str | None = None) -> str:
    """Resolve ``auto`` to a concrete serving backend for this host."""
    platform = platform or jax.default_backend()
    if platform == "tpu" and \
            frozen_vmem_bytes(hcap, npk, m1, c) <= SERVE_BUDGET_BYTES:
        return "slice_pallas"
    return "slice_xla"


def resolve_slice_backend(backend: str, *, hcap: int = 0, npk: int = 1,
                          m1: int = 1, c: int = 1) -> str:
    if backend not in SLICE_BACKENDS:
        raise ValueError(f"unknown slice backend {backend!r}; want one of "
                         f"{SLICE_BACKENDS}")
    if backend == "auto":
        return choose_slice_backend(hcap=hcap, npk=npk, m1=m1, c=c)
    return backend


def slice_query(index: LatticeIndex, tables: Array, q_packed: Array,
                weights: Array, active: Array, *, backend: str = "auto",
                interpret: bool | None = None) -> tuple[Array, Array]:
    """Slice frozen ``tables`` at embedded queries -> (out (b, c), miss (b,)).

    ``q_packed`` is query-major ((b*(d+1), npk) packed vertex keys),
    ``weights`` the (b, d+1) barycentric weights, ``active`` a per-vertex
    validity mask (False forces a miss — padding rows, pack-overflowed
    queries). Misses contribute zero and their barycentric mass comes
    back as the per-query slice-miss diagnostic.
    """
    m1, c = tables.shape
    resolved = resolve_slice_backend(backend, hcap=index.hcap,
                                     npk=index.tkeys.shape[1], m1=m1, c=c)
    if resolved == "slice_pallas":
        run_interp = interpret if interpret is not None else False
        if _on_tpu() or run_interp:
            return slice_query_pallas(index.tkeys, index.row_of_slot,
                                      tables, q_packed, weights, active,
                                      interpret=run_interp)
    return slice_query_xla(index.tkeys, index.row_of_slot, tables,
                           q_packed, weights, active, index.hcap)


def slice_query_tangent(index: LatticeIndex, tables: Array, q_packed: Array,
                        weights: Array, weights_dot: Array, active: Array, *,
                        backend: str = "auto",
                        interpret: bool | None = None
                        ) -> tuple[Array, Array, Array]:
    """Primal + directional-tangent slice -> (out, out_dot, miss).

    The query-space JVP of the frozen slice (DESIGN.md §15): the tables
    and probed rows are constant along the tangent, so the JVP is the
    SAME barycentric contraction against ``weights_dot`` (the directional
    derivative of the weights, ``lattice.embed_weight_tangent``) — fused
    with the primal so the pair costs one probe + one gather. Backend
    policy is identical to ``slice_query``: the Pallas tier runs the
    probe loop once and both contractions in-register; everywhere else
    the XLA reference gathers once and einsums twice.
    """
    m1, c = tables.shape
    resolved = resolve_slice_backend(backend, hcap=index.hcap,
                                     npk=index.tkeys.shape[1], m1=m1, c=c)
    if resolved == "slice_pallas":
        run_interp = interpret if interpret is not None else False
        if _on_tpu() or run_interp:
            return slice_query_tangent_pallas(
                index.tkeys, index.row_of_slot, tables, q_packed, weights,
                weights_dot, active, interpret=run_interp)
    return slice_query_tangent_xla(index.tkeys, index.row_of_slot, tables,
                                   q_packed, weights, weights_dot, active,
                                   index.hcap)


def slice_query_jacobian(index: LatticeIndex, tables: Array, q_packed: Array,
                         weights: Array, wjac: Array, active: Array
                         ) -> tuple[Array, Array, Array]:
    """Primal + full query-space Jacobian -> (out, jac (b, c, d), miss).

    The d-directional generalization of ``slice_query_tangent`` (one
    probe, one gather, d+1 contractions); XLA-only — the serving
    gradient consumers (gp/serve.predict_grad) run it on the host, and
    its output is d+1 times the primal's so the VMEM-residency argument
    for a fused kernel does not transfer.
    """
    return slice_query_jacobian_xla(index.tkeys, index.row_of_slot, tables,
                                    q_packed, weights, wjac, active,
                                    index.hcap)


__all__ = ["SLICE_BACKENDS", "SERVE_BUDGET_BYTES", "choose_slice_backend",
           "resolve_slice_backend", "frozen_vmem_bytes", "slice_query",
           "slice_query_tangent", "slice_query_jacobian"]
