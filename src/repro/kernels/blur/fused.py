"""Fused lattice MVM: splat -> (d+1)-blur -> slice in ONE pallas_call.

This is the TPU analogue of the paper's fused CUDA filter (§4): the whole
symmetrized operator W 0.5(B + B^T) W^T runs with the lattice value table
resident in VMEM scratch the entire time, instead of round-tripping HBM
once per directional blur plus separate splat/slice dispatches (~2d+4
kernels on the old path).

Memory plan (DESIGN.md §8) for the fits-VMEM variant:

  grid = (T,),  T = 2(d+1) sweeps when symmetrized else d+1
  persistent VMEM scratch:
    table  (cap+1, c)  splat result, kept for the reverse sweep's restart
    work   (cap+1, c)  current sweep state
    accum  (cap+1, c)  forward-sweep result while the reverse sweep runs
  streamed per grid step (auto double-buffered by the Pallas pipeline):
    nbr    (1, cap+1, 2r) — the step's directional gather tile; the sweep
           order is palindromic (0..d, d..0) so the middle tile is reused
           across the fwd->rev boundary without a re-fetch, and the
           forward and reverse sweeps share the single resident table load.
  resident inputs: v (n, c), the sorted splat plan (3 x (n(d+1), 1)),
    row_last/valid (cap+1, 1), seg_ids/weights (n, d+1) for the slice.

Stage schedule on grid step t:
  t == 0        splat: gather sorted contributions, segmented Hillis-Steele
                prefix scan in VMEM (no scatter, no atomics — build-time
                sorting makes every lattice point's members contiguous),
                boundary-gather into `table`; start the forward sweep.
  every t       one directional stencil sweep on `work`.
  t == d+1      (symmetrized) park forward result in `accum`, restart the
                reverse sweep from `table`.
  t == T-1      combine 0.5(accum + work), barycentric slice, write (n, c).

ops.py gates this kernel on a VMEM budget over ALL residents (not just the
table) and picks the per-direction or XLA tiers otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

# jax renamed TPUCompilerParams -> CompilerParams across versions
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _shift_down(x: Array, s: int) -> Array:
    """Shift rows down by s, zero-filling the top (static s)."""
    return jnp.concatenate(
        [jnp.zeros((s, x.shape[1]), x.dtype), x[:-s]], axis=0)


def _fused_kernel(v_ref, srow_ref, sw_ref, head_ref, rlast_ref, valid_ref,
                  seg_ref, wts_ref, nbr_ref, out_ref,
                  table_ref, work_ref, accum_ref, *,
                  taps: tuple[float, ...], d: int, n: int, c: int,
                  cap1: int, big: int, symmetrize: bool):
    t = pl.program_id(0)
    num_steps = 2 * (d + 1) if symmetrize else d + 1
    dump_row = cap1 - 1
    r = len(taps) // 2

    @pl.when(t == 0)
    def _splat():
        # gather + segmented Hillis-Steele scan over sorted contributions
        contrib = sw_ref[...] * jnp.take(v_ref[...], srow_ref[...][:, 0],
                                         axis=0)  # (big, c)
        carry = 1.0 - head_ref[...]  # (big, 1): 0 at segment heads
        shift = 1
        while shift < big:
            contrib = contrib + carry * _shift_down(contrib, shift)
            carry = carry * _shift_down(carry, shift)
            shift *= 2
        table = jnp.take(contrib, rlast_ref[...][:, 0], axis=0)  # (cap1, c)
        table = table * valid_ref[...]  # empty slots and dump row -> 0
        table_ref[...] = table
        work_ref[...] = table

    if symmetrize:
        @pl.when(t == d + 1)
        def _restart_reverse():
            accum_ref[...] = work_ref[...]
            work_ref[...] = table_ref[...]

    # one directional stencil sweep (the step's nbr tile picks the direction)
    vals = work_ref[...]
    nbr = nbr_ref[...][0]  # (cap1, 2r)
    swept = vals * taps[r]
    side = list(taps[:r]) + list(taps[r + 1:])
    for s, w in enumerate(side):
        swept = swept + w * jnp.take(vals, nbr[:, s], axis=0)
    rows = jax.lax.broadcasted_iota(jnp.int32, (cap1, 1), 0)
    work_ref[...] = jnp.where(rows == dump_row, 0.0, swept)

    @pl.when(t == num_steps - 1)
    def _slice():
        if symmetrize:
            final = 0.5 * (accum_ref[...] + work_ref[...])
        else:
            final = work_ref[...]
        out = jnp.zeros((n, c), out_ref.dtype)
        for k in range(d + 1):
            out = out + (wts_ref[...][:, k][:, None]
                         * jnp.take(final, seg_ref[...][:, k], axis=0))
        out_ref[...] = out


def fused_filter_pallas(lat, v: Array, taps: tuple[float, ...], *,
                        symmetrize: bool = True, transpose: bool = False,
                        interpret: bool = False) -> Array:
    """Run the whole lattice MVM as one Pallas kernel.

    ``transpose`` flips the sweep order (F^T); with ``symmetrize`` the
    operator is self-adjoint and the flag is a no-op by construction.
    Requires concrete (non-traced) ``taps``.
    """
    n, c = v.shape
    d, cap1 = lat.d, lat.cap + 1
    big = n * (d + 1)
    num_steps = 2 * (d + 1) if symmetrize else d + 1
    two_r = lat.nbr.shape[-1]

    # palindromic sweep order: fwd 0..d then rev d..0 (swapped on transpose)
    if symmetrize:
        def dir_map(t):
            a = jnp.where(t <= d, t, 2 * d + 1 - t)
            return (a, 0, 0)
    elif transpose:
        def dir_map(t):
            return (d - t, 0, 0)
    else:
        def dir_map(t):
            return (t, 0, 0)

    kernel = functools.partial(
        _fused_kernel, taps=tuple(taps), d=d, n=n, c=c, cap1=cap1, big=big,
        symmetrize=symmetrize)

    col = lambda a, dt: a.reshape(-1, 1).astype(dt)  # noqa: E731
    resident = lambda shape: pl.BlockSpec(shape, lambda t: (0,) * len(shape))  # noqa: E731
    out = pl.pallas_call(
        kernel,
        grid=(num_steps,),
        in_specs=[
            resident((n, c)),          # v
            resident((big, 1)),        # sort_row
            resident((big, 1)),        # sort_w
            resident((big, 1)),        # seg_head (f32)
            resident((cap1, 1)),       # row_last
            resident((cap1, 1)),       # valid (f32)
            resident((n, d + 1)),      # seg_ids
            resident((n, d + 1)),      # weights
            pl.BlockSpec((1, cap1, two_r), dir_map),  # streamed nbr tile
        ],
        out_specs=resident((n, c)),
        out_shape=jax.ShapeDtypeStruct((n, c), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((cap1, c), v.dtype),  # table
            pltpu.VMEM((cap1, c), v.dtype),  # work
            pltpu.VMEM((cap1, c), v.dtype),  # accum
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(
        v,
        col(lat.sort_row, jnp.int32),
        col(lat.sort_w, v.dtype),
        col(lat.seg_head, v.dtype),
        col(lat.row_last, jnp.int32),
        col(lat.valid, v.dtype),
        lat.seg_ids.reshape(n, d + 1),
        lat.weights.astype(v.dtype),
        lat.nbr,
    )
    return out
