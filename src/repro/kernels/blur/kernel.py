"""Pallas lattice-blur kernel — the hot spot the paper's CUDA kernel targets.

The CUDA blur probes a hash table per (point, neighbor); the TPU-native
reformulation (DESIGN.md §2) precomputes the neighbor table, so blur is a
*gather + stencil reduction*. This kernel blocks over lattice points:

  grid = (ceil(cap+1 / block_p),)
  per step VMEM holds: the full value table (cap+1, c) [gather source],
  one (block_p, 2r) index tile, and one (block_p, c) output tile.

The gather source stays resident across grid steps (its index_map is
constant, so Mosaic keeps it in VMEM rather than re-streaming it), which is
the right trade for c-small GP filtering: the value table for m = 500k
lattice points x 4 channels is 8 MB < 16 MB VMEM. ops.py falls back to the
XLA path when the table cannot fit.

Why one direction per pallas_call: the d+1 directional blurs are strictly
sequential (each consumes the previous output). This file is the
PER-DIRECTION tier of the backend policy (ops.py): the fully fused
splat->blur->slice kernel lives in fused.py and keeps the table resident
across all sweeps; this tier re-streams it once per direction but tolerates
a larger table. Two variants:

  * ``blur_direction_pallas`` — gather source resident in VMEM (table fits).
  * ``blur_direction_blocked_pallas`` — grid-blocked fallback for tables
    past the VMEM budget: lattice points tiled over the output grid axis,
    the gather source streamed tile-by-tile over a second (arbitrary) grid
    axis with contributions masked to the resident source tile. Traffic is
    O(num_src_tiles) x the table, so ops.py only engages it for moderately
    oversized tables and otherwise falls back to XLA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_P = 1024

# jax renamed TPUCompilerParams -> CompilerParams across versions
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _blur_kernel(vals_ref, nbr_ref, out_ref, *, taps: tuple[float, ...],
                 dump_row: int, block_p: int):
    """One direction, one block of lattice points."""
    i = pl.program_id(0)
    vals = vals_ref[...]  # (cap1, c) — resident gather source
    nbr = nbr_ref[...]  # (block_p, 2r)
    r = len(taps) // 2
    base = vals_ref[pl.dslice(i * block_p, block_p), :]  # this block's rows
    acc = base * taps[r]
    side = list(taps[:r]) + list(taps[r + 1:])
    for s, w in enumerate(side):
        acc = acc + w * jnp.take(vals, nbr[:, s], axis=0)
    # zero the dump row if it falls inside this block
    rows = i * block_p + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_p, 1), 0)
    acc = jnp.where(rows == dump_row, 0.0, acc)
    out_ref[...] = acc


def blur_direction_pallas(vals: Array, nbr_dir: Array,
                          stencil: tuple[float, ...], *,
                          block_p: int = DEFAULT_BLOCK_P,
                          interpret: bool = False) -> Array:
    """One directional blur. vals: (cap+1, c); nbr_dir: (cap+1, 2r)."""
    cap1, c = vals.shape
    dump_row = cap1 - 1
    pad = (-cap1) % block_p
    if pad:
        vals = jnp.concatenate(
            [vals, jnp.zeros((pad, c), vals.dtype)], axis=0)
        nbr_dir = jnp.concatenate(
            [nbr_dir, jnp.full((pad, nbr_dir.shape[1]), dump_row,
                               nbr_dir.dtype)], axis=0)
    padded = cap1 + pad
    grid = (padded // block_p,)

    kernel = functools.partial(_blur_kernel, taps=tuple(stencil),
                               dump_row=dump_row, block_p=block_p)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # full table resident (constant index_map -> loaded once)
            pl.BlockSpec((padded, c), lambda i: (0, 0)),
            pl.BlockSpec((block_p, nbr_dir.shape[1]), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_p, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, c), vals.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(vals, nbr_dir)
    return out[:cap1]


# ---------------------------------------------------------------------------
# Grid-blocked fallback: gather source streamed, never fully resident.
# ---------------------------------------------------------------------------


def _blur_blocked_kernel(src_ref, nbr_ref, out_ref, *,
                         taps: tuple[float, ...], dump_row: int,
                         block_p: int):
    """(out tile i, src tile j): accumulate the taps whose gather index
    lands inside src tile j. The out tile stays resident across the j axis
    (constant index_map), so `out_ref` accumulates read-modify-write."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    src = src_ref[...]  # (block_p, c) — rows [j*block_p, (j+1)*block_p)
    nbr = nbr_ref[...]  # (block_p, 2r) — for out rows of tile i
    r = len(taps) // 2
    acc = out_ref[...]

    # center tap: an out row's own value lives in src tile j == i
    acc = acc + jnp.where(j == i, taps[r], 0.0) * src

    base = j * block_p
    side = list(taps[:r]) + list(taps[r + 1:])
    for s, w in enumerate(side):
        loc = nbr[:, s] - base
        in_tile = (loc >= 0) & (loc < block_p)
        gathered = jnp.take(src, jnp.clip(loc, 0, block_p - 1), axis=0)
        acc = acc + w * jnp.where(in_tile[:, None], gathered, 0.0)

    # the dump row only ever accumulates zeros (its nbr entries all miss),
    # so unconditional zeroing on every pass is safe and keeps one store
    rows = i * block_p + jax.lax.broadcasted_iota(jnp.int32, (block_p, 1), 0)
    out_ref[...] = jnp.where(rows == dump_row, 0.0, acc)


def blur_direction_blocked_pallas(vals: Array, nbr_dir: Array,
                                  stencil: tuple[float, ...], *,
                                  block_p: int = DEFAULT_BLOCK_P,
                                  interpret: bool = False) -> Array:
    """Streaming-source directional blur for tables past the VMEM budget."""
    cap1, c = vals.shape
    dump_row = cap1 - 1
    pad = (-cap1) % block_p
    if pad:
        vals = jnp.concatenate(
            [vals, jnp.zeros((pad, c), vals.dtype)], axis=0)
        nbr_dir = jnp.concatenate(
            [nbr_dir, jnp.full((pad, nbr_dir.shape[1]), dump_row,
                               nbr_dir.dtype)], axis=0)
    padded = cap1 + pad
    num_src = padded // block_p
    grid = (num_src, num_src)

    kernel = functools.partial(_blur_blocked_kernel, taps=tuple(stencil),
                               dump_row=dump_row, block_p=block_p)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, c), lambda i, j: (j, 0)),  # src stream
            pl.BlockSpec((block_p, nbr_dir.shape[1]), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_p, c), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, c), vals.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(vals, nbr_dir)
    return out[:cap1]
