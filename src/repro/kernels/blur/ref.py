"""Pure-jnp oracles for the lattice MVM: blur sweeps and the full
splat -> (d+1)-blur -> slice operator the fused kernel implements.

Two splat oracles are provided because the fused backends sum each lattice
point's contributions in sorted-segment order (scatter-free), not in the
input order ``jax.ops.segment_sum`` uses; at large n the two orders differ
by f32 accumulation noise (~1e-4 at n=64k), far above kernel-parity
tolerances. Parity checks therefore compare against the oracle that shares
the backend's summation structure:

  * ``splat_sorted_ref``  — segmented associative scan (== fused_xla).
  * ``splat_sorted_hs_ref`` — Hillis-Steele sweep (== the Pallas kernel's
    in-VMEM loop, step for step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def blur_direction_ref(vals: Array, nbr_dir: Array, stencil: Array,
                       dump_row: int) -> Array:
    """One direction of the separable lattice blur.

    vals: (cap+1, c) lattice values, dump row zeroed.
    nbr_dir: (cap+1, 2r) neighbor slots (misses -> dump row).
    stencil: (2r+1,) taps; center at index r.
    """
    r = stencil.shape[0] // 2
    out = vals * stencil[r]
    gathered = vals[nbr_dir]  # (cap+1, 2r, c)
    w = jnp.concatenate([stencil[:r], stencil[r + 1:]])
    out = out + jnp.einsum("prc,r->pc", gathered, w)
    return out.at[dump_row].set(0.0)


def blur_ref(vals: Array, nbr: Array, stencil: Array, *,
             reverse: bool = False) -> Array:
    """Full (d+1)-direction sequential blur. nbr: (d+1, cap+1, 2r)."""
    dump = vals.shape[0] - 1
    dirs = range(nbr.shape[0])
    if reverse:
        dirs = reversed(list(dirs))
    for a in dirs:
        vals = blur_direction_ref(vals, nbr[a], stencil, dump)
    return vals


# ---------------------------------------------------------------------------
# Full-operator oracle (splat -> blur -> slice), mirroring the fused kernel.
# ---------------------------------------------------------------------------


def splat_sorted_ref(lat, v: Array) -> Array:
    """Scatter-free splat oracle: segmented associative scan over the
    build-time sorted contributions (same order as lattice.splat_sorted)."""
    contrib = lat.sort_w[:, None] * v[lat.sort_row]
    carry = jnp.where(lat.seg_head, 0.0, 1.0)[:, None].astype(v.dtype)

    def comb(a, b):
        (g1, v1), (g2, v2) = a, b
        return g1 * g2, v2 + g2 * v1

    _, scanned = jax.lax.associative_scan(comb, (carry, contrib), axis=0)
    out = jnp.where(lat.valid[:, None], scanned[lat.row_last], 0.0)
    return out.at[lat.cap].set(0.0)


def splat_sorted_hs_ref(lat, v: Array) -> Array:
    """Same linear map via an explicit Hillis-Steele sweep — the exact
    op-for-op order of the fused Pallas kernel's in-VMEM splat stage."""
    big, c = lat.sort_row.shape[0], v.shape[1]
    contrib = lat.sort_w[:, None] * v[lat.sort_row]
    carry = jnp.where(lat.seg_head, 0.0, 1.0)[:, None].astype(v.dtype)
    shift = 1
    while shift < big:
        zed = jnp.zeros((shift, 1), v.dtype)
        contrib = contrib + carry * jnp.concatenate(
            [jnp.zeros((shift, c), v.dtype), contrib[:-shift]], axis=0)
        carry = carry * jnp.concatenate([zed, carry[:-shift]], axis=0)
        shift *= 2
    out = jnp.where(lat.valid[:, None], contrib[lat.row_last], 0.0)
    return out.at[lat.cap].set(0.0)


def slice_ref(lat, vals: Array) -> Array:
    per_vertex = vals[lat.seg_ids].reshape(lat.n, lat.d + 1, -1)
    return jnp.einsum("nkc,nk->nc", per_vertex, lat.weights)


def filter_ref(lat, v: Array, stencil: Array, *, symmetrize: bool = True,
               transpose: bool = False, splat_algo: str = "scan") -> Array:
    """Full lattice MVM oracle: W [0.5(B + B^T)] W^T v (or unsymmetrized).

    ``splat_algo`` selects which sorted-splat ordering to mirror ("scan" for
    the XLA fused backend, "hs" for the Pallas kernel).
    """
    splat = splat_sorted_hs_ref if splat_algo == "hs" else splat_sorted_ref
    table = splat(lat, v)
    blurred = blur_ref(table, lat.nbr, stencil, reverse=transpose)
    if symmetrize:
        blurred_r = blur_ref(table, lat.nbr, stencil, reverse=not transpose)
        blurred = 0.5 * (blurred + blurred_r)
    return slice_ref(lat, blurred)
