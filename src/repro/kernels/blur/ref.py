"""Pure-jnp oracle for the lattice blur (one direction and full sweep)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def blur_direction_ref(vals: Array, nbr_dir: Array, stencil: Array,
                       dump_row: int) -> Array:
    """One direction of the separable lattice blur.

    vals: (cap+1, c) lattice values, dump row zeroed.
    nbr_dir: (cap+1, 2r) neighbor slots (misses -> dump row).
    stencil: (2r+1,) taps; center at index r.
    """
    r = stencil.shape[0] // 2
    out = vals * stencil[r]
    gathered = vals[nbr_dir]  # (cap+1, 2r, c)
    w = jnp.concatenate([stencil[:r], stencil[r + 1:]])
    out = out + jnp.einsum("prc,r->pc", gathered, w)
    return out.at[dump_row].set(0.0)


def blur_ref(vals: Array, nbr: Array, stencil: Array, *,
             reverse: bool = False) -> Array:
    """Full (d+1)-direction sequential blur. nbr: (d+1, cap+1, 2r)."""
    dump = vals.shape[0] - 1
    dirs = range(nbr.shape[0])
    if reverse:
        dirs = reversed(list(dirs))
    for a in dirs:
        vals = blur_direction_ref(vals, nbr[a], stencil, dump)
    return vals
