"""Public blur op: full (d+1)-direction sweep with backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.lattice import Lattice
from repro.kernels.blur.kernel import DEFAULT_BLOCK_P, blur_direction_pallas

Array = jax.Array

# VMEM budget for keeping the value table resident (see kernel.py docstring)
_VMEM_TABLE_BYTES = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fits_vmem(cap1: int, c: int, itemsize: int = 4) -> bool:
    return cap1 * c * itemsize <= _VMEM_TABLE_BYTES


def blur_pallas(lat: Lattice, vals: Array, stencil: tuple[float, ...], *,
                reverse: bool = False,
                block_p: int = DEFAULT_BLOCK_P) -> Array:
    """Sequential separable blur via the Pallas kernel (one call/direction).

    Drop-in replacement for repro.core.lattice.blur when the value table
    fits VMEM; callers (core/filtering.py) choose via ``use_pallas_blur``.
    """
    order = range(lat.d + 1)
    if reverse:
        order = reversed(list(order))
    interpret = not _on_tpu()
    for a in order:
        vals = blur_direction_pallas(vals, lat.nbr[a], stencil,
                                     block_p=block_p, interpret=interpret)
    return vals
