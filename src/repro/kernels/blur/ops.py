"""Public lattice-MVM ops: backend policy + dispatch for the full operator.

Backend tiers (DESIGN.md §8), chosen from (n, cap, d, r, c) and platform:

  fused_pallas         one pallas_call for splat -> 2(d+1) sweeps -> slice;
                       the value table lives in VMEM scratch throughout
                       (fused.py). Engaged on TPU when every resident
                       buffer fits the VMEM budget.
  per_direction_pallas one pallas_call per directional sweep (kernel.py),
                       XLA splat/slice around them. Resident gather source
                       when the table fits; grid-blocked streaming variant
                       for moderately oversized tables.
  fused_xla            single-jit XLA composition with the scatter-free
                       sorted-segment splat (lattice.splat_sorted) — the
                       fast path on hosts without a TPU, and the same
                       algorithm the fused kernel runs in VMEM.
  xla                  the legacy reference composition (segment_sum splat
                       + scan blur + slice). Keeps the seed semantics;
                       always available, any table size, traced weights OK.

``auto`` resolves per the table above. Pallas tiers need CONCRETE stencil
taps (they are baked into the kernel); pass them via ``taps=`` (e.g. from
``FilterSpec`` / ``Stencil.weights``) — ``auto`` falls back to the XLA tier
when only traced weights are available rather than crash under jit.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core import lattice as lat_mod
from repro.core.lattice import Lattice
from repro.kernels.blur.fused import fused_filter_pallas
from repro.kernels.blur.kernel import (DEFAULT_BLOCK_P,
                                       blur_direction_blocked_pallas,
                                       blur_direction_pallas)

Array = jax.Array

BACKENDS = ("auto", "fused_pallas", "per_direction_pallas", "fused_xla",
            "xla")

# VMEM budget for Pallas residency decisions. 16 MB/core physical; leave
# headroom for the pipeline's double buffers and compiler spill.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024
# resident-source per-direction tier: the table is the only large resident
_TABLE_BUDGET_BYTES = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_vmem_bytes(n: int, d: int, r: int, cap1: int, c: int,
                     itemsize: int = 4) -> int:
    """Total resident bytes of the fused kernel's memory plan (fused.py)."""
    big = n * (d + 1)
    table = 3 * cap1 * c            # table + work + accum scratch
    splat_plan = big * (c + 3)      # contrib scan + sort_row/sort_w/head
    slice_plan = 2 * n * (d + 1)    # seg_ids + weights
    io = 2 * n * c                  # v + out
    nbr_tiles = 2 * cap1 * 2 * r    # double-buffered direction tiles
    misc = 2 * cap1                 # row_last + valid
    return itemsize * (table + splat_plan + slice_plan + io + nbr_tiles
                       + misc)


def fits_vmem(n: int, d: int, r: int, cap1: int, c: int, *,
              budget: int = VMEM_BUDGET_BYTES) -> bool:
    """Gate for the fused kernel: ALL residents (not just the table) fit.

    Callers should size ``cap`` realistically (lattice.suggest_capacity +
    build_lattice_auto), not at the worst case n(d+1) — paper Table 3 shows
    m is usually a small fraction of it, and this gate is exactly where
    over-allocation turns into a lost fusion.
    """
    return fused_vmem_bytes(n, d, r, cap1, c) <= budget


def table_fits_vmem(cap1: int, c: int, itemsize: int = 4) -> bool:
    return cap1 * c * itemsize <= _TABLE_BUDGET_BYTES


def max_cap_for_vmem(n: int, d: int, r: int, c: int, *,
                     budget: int = VMEM_BUDGET_BYTES,
                     itemsize: int = 4) -> int:
    """Largest table capacity whose fused-kernel memory plan fits ``budget``.

    Inverts ``fused_vmem_bytes`` (linear in cap1). 0 when even an empty
    table spills — the fixed per-point residents alone exceed the budget.
    Used by ``lattice.suggest_capacity`` to keep its power-of-two rounding
    from silently defeating ``fits_vmem``.
    """
    big = n * (d + 1)
    fixed_words = big * (c + 3) + 2 * big + 2 * n * c
    per_cap1_words = 3 * c + 4 * r + 2
    cap1 = (budget // itemsize - fixed_words) // per_cap1_words
    return max(int(cap1) - 1, 0)


def pick_block_p(cap1: int, c: int = 1) -> int:
    """Heuristic block_p: large enough to amortize per-step overhead, small
    enough that a handful of tiles fit next to the resident table. Override
    with REPRO_BLUR_BLOCK_P; ``autotune_block_p`` measures candidates."""
    env = os.environ.get("REPRO_BLUR_BLOCK_P")
    if env:
        return int(env)
    best = 256
    for cand in (512, 1024, 2048, 4096):
        if cand <= max(256, cap1 // 4) and cand * (c + 8) * 4 <= 1 << 20:
            best = cand
    return best


_AUTOTUNE_CACHE: dict[tuple, int] = {}


def autotune_block_p(lat: Lattice, c: int, taps: tuple[float, ...], *,
                     candidates: tuple[int, ...] = (256, 512, 1024, 2048),
                     iters: int = 3) -> int:
    """Measure the per-direction kernel across block sizes on this device.

    Only meaningful where the kernel compiles (TPU); elsewhere returns the
    heuristic (timing the interpreter would autotune the wrong thing).
    Cached per (platform, table-size bucket, c, r).
    """
    cap1 = lat.cap + 1
    key = (jax.default_backend(), cap1.bit_length(), c, lat.r)
    if key in _AUTOTUNE_CACHE:
        return _AUTOTUNE_CACHE[key]
    if not _on_tpu():
        best = pick_block_p(cap1, c)
        _AUTOTUNE_CACHE[key] = best
        return best
    import time
    vals = jnp.zeros((cap1, c), jnp.float32)
    best, best_t = None, float("inf")
    for bp in candidates:
        fn = jax.jit(functools.partial(blur_direction_pallas,
                                       stencil=taps, block_p=bp))
        jax.block_until_ready(fn(vals, lat.nbr[0]))  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(vals, lat.nbr[0]))
        dt = time.perf_counter() - t0
        if dt < best_t:
            best, best_t = bp, dt
    _AUTOTUNE_CACHE[key] = best
    return best


def choose_backend(*, n: int, d: int, r: int, cap1: int, c: int,
                   taps_available: bool = True,
                   platform: str | None = None) -> str:
    """Resolve ``auto`` to a concrete backend for this problem + host."""
    platform = platform or jax.default_backend()
    if not taps_available:
        # only the Pallas tiers bake taps into the kernel; the fused XLA
        # tier (scatter-free sorted splat) takes traced weights fine
        return "fused_xla"
    if platform == "tpu":
        if fits_vmem(n, d, r, cap1, c):
            return "fused_pallas"
        if table_fits_vmem(cap1, c):
            return "per_direction_pallas"
        # past the resident budget the blocked streaming kernel re-reads
        # the table once per block_p-row source tile — traffic that always
        # loses to the XLA gather at these sizes — so the policy prefers
        # fused_xla; the blocked variant stays reachable explicitly via
        # backend="per_direction_pallas" for strictly-VMEM-bound runs.
        return "fused_xla"
    # CPU/GPU hosts: the fused idea lands as one jitted XLA program with
    # the scatter-free splat; Pallas runs only under explicit interpret.
    return "fused_xla"


# ---------------------------------------------------------------------------
# Blur-only entry point (kept for kernel tests and the per-direction tier).
# ---------------------------------------------------------------------------


def blur_pallas(lat: Lattice, vals: Array, stencil: tuple[float, ...], *,
                reverse: bool = False, block_p: int | None = None,
                interpret: bool | None = None) -> Array:
    """Sequential separable blur via the Pallas kernels (one call/direction).

    Off-TPU this dispatches to the XLA blur — running the Pallas
    interpreter by default was orders of magnitude slower than XLA; set
    ``interpret=True`` explicitly to exercise the kernels in tests.
    """
    if interpret is None:
        if not _on_tpu():
            w = jnp.asarray(stencil, vals.dtype)
            return lat_mod.blur(lat, vals, w, reverse=reverse)
        interpret = False
    cap1, c = vals.shape
    if block_p is None:
        # measured on-device where the kernel compiles (cached per shape
        # bucket); interpret mode gets the cheap heuristic
        block_p = (pick_block_p(cap1, c) if interpret
                   else autotune_block_p(lat, c, tuple(stencil)))
    blocked = not table_fits_vmem(cap1, c)
    fn = blur_direction_blocked_pallas if blocked else blur_direction_pallas
    order = range(lat.d + 1)
    if reverse:
        order = reversed(list(order))
    for a in order:
        vals = fn(vals, lat.nbr[a], stencil, block_p=block_p,
                  interpret=interpret)
    return vals


# ---------------------------------------------------------------------------
# Full-operator dispatch.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("symmetrize", "transpose"))
def _fused_xla(lat: Lattice, v: Array, weights: Array, *,
               symmetrize: bool, transpose: bool) -> Array:
    table = lat_mod.splat_sorted(lat, v)
    blurred = lat_mod.blur(lat, table, weights, reverse=transpose)
    if symmetrize:
        blurred_r = lat_mod.blur(lat, table, weights, reverse=not transpose)
        blurred = 0.5 * (blurred + blurred_r)
    return lat_mod.slice_(lat, blurred)


def _xla_reference(lat: Lattice, v: Array, weights: Array, *,
                   symmetrize: bool, transpose: bool) -> Array:
    splatted = lat_mod.splat(lat, v)
    blurred = lat_mod.blur(lat, splatted, weights, reverse=transpose)
    if symmetrize:
        blurred_r = lat_mod.blur(lat, splatted, weights,
                                 reverse=not transpose)
        blurred = 0.5 * (blurred + blurred_r)
    return lat_mod.slice_(lat, blurred)


# --- MVM instrumentation ----------------------------------------------------
# ``lattice_mvm`` bumps these on every Python-level call (trace-level under
# jit/scan — the number of lattice MVMs baked into the compiled program,
# exactly like ``lattice.build_count``). ``cols`` accumulates the channel
# width of each call, so a solver that batches k RHS into ONE (n, k) MVM per
# iteration shows up as calls=1, cols=k — while a per-column loop would show
# calls=k. tests/test_solvers.py pins the mBCG contract with this.

_MVM_STATS = {"calls": 0, "cols": 0}


def mvm_count() -> int:
    """Total ``lattice_mvm`` invocations (trace-level under jit)."""
    return _MVM_STATS["calls"]


def mvm_cols() -> int:
    """Total RHS columns across all ``lattice_mvm`` invocations."""
    return _MVM_STATS["cols"]


def _concrete_taps(weights, taps):
    """Concrete stencil taps, or None when only traced values exist."""
    if taps is not None:
        return tuple(float(t) for t in taps)
    if weights is None:
        return None
    try:
        return tuple(float(w) for w in jax.core.concrete_or_error(
            None, weights, "lattice_mvm taps"))
    except jax.errors.ConcretizationTypeError:
        return None


def lattice_mvm(lat: Lattice, v: Array, weights: Array | None = None, *,
                taps: tuple[float, ...] | None = None,
                symmetrize: bool = True, transpose: bool = False,
                backend: str = "auto", block_p: int | None = None,
                interpret: bool | None = None, mesh=None,
                axis_name: str = "data") -> Array:
    """Apply W B W^T (or its transpose / symmetrization) with one of the
    policy backends. ``weights`` (traced OK) and/or concrete ``taps`` must
    describe the same (2r+1) stencil.

    ``mesh`` selects the data-parallel tier (sharding/simplex.py): rows of
    ``v`` shard over the mesh's ``axis_name`` axis, the blur table is
    replicated, and the whole MVM costs ONE psum. The per-device compute is
    plain XLA, so ``backend`` is ignored on that path.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of "
                         f"{BACKENDS}")
    if weights is None and taps is None:
        raise ValueError("lattice_mvm needs a stencil: pass weights= "
                         "(array) and/or taps= (concrete tuple)")
    _MVM_STATS["calls"] += 1
    _MVM_STATS["cols"] += int(v.shape[1])
    if mesh is not None:
        from repro.sharding.simplex import sharded_lattice_mvm
        return sharded_lattice_mvm(lat, v, weights, taps=taps,
                                   mesh=mesh, axis_name=axis_name,
                                   symmetrize=symmetrize,
                                   transpose=transpose)
    concrete = _concrete_taps(weights, taps)
    if backend == "auto":
        backend = choose_backend(n=lat.n, d=lat.d, r=lat.r, cap1=lat.cap + 1,
                                 c=v.shape[1],
                                 taps_available=concrete is not None)
    if backend in ("fused_pallas", "per_direction_pallas") and concrete is None:
        raise ValueError(
            f"backend {backend!r} needs concrete stencil taps; pass taps= "
            "(e.g. Stencil.weights / FilterSpec.taps) instead of traced "
            "weights")
    if weights is None:
        weights = jnp.asarray(concrete, v.dtype)

    if backend == "fused_pallas":
        run_interp = (not _on_tpu()) if interpret is None else interpret
        return fused_filter_pallas(lat, v, concrete, symmetrize=symmetrize,
                                   transpose=transpose, interpret=run_interp)
    if backend == "per_direction_pallas":
        splatted = lat_mod.splat(lat, v)
        blurred = blur_pallas(lat, splatted, concrete, reverse=transpose,
                              block_p=block_p, interpret=interpret)
        if symmetrize:
            blurred_r = blur_pallas(lat, splatted, concrete,
                                    reverse=not transpose, block_p=block_p,
                                    interpret=interpret)
            blurred = 0.5 * (blurred + blurred_r)
        return lat_mod.slice_(lat, blurred)
    if backend == "fused_xla":
        return _fused_xla(lat, v, weights, symmetrize=symmetrize,
                          transpose=transpose)
    return _xla_reference(lat, v, weights, symmetrize=symmetrize,
                          transpose=transpose)
