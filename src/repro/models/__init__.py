from repro.models.config import SHAPES, ModelConfig, ShapeSpec
from repro.models.lm import LM, build

__all__ = ["SHAPES", "ModelConfig", "ShapeSpec", "LM", "build"]
