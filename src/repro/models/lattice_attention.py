"""Lattice attention: the paper's technique as a sub-quadratic LM layer.

Beyond-paper integration (DESIGN.md §4): RBF-kernel attention

    y_i = sum_j exp(-|phi(q_i) - phi(k_j)|^2 / 2) v_j / (normalizer)

is exactly the bilateral-filter MVM of paper Eq. 1, so the permutohedral
splat/blur/slice pipeline evaluates it in O((s + m) d_lat^2) instead of
O(s^2) — the queries/keys are projected to a low-dim lattice space
phi: R^hd -> R^d_lat (learned), and the cross-covariance trick of
gp/predict.py (splat values at key rows, slice at query rows) produces the
kernel-weighted sum; filtering an extra ones-channel yields the softmax-
style normalizer.

This is what lets *full-attention* architectures run the long_500k cell:
swap ``attention_kind="lattice"`` into any dense config and decode cost
becomes linear in context length. Accuracy is an approximation (same
cosine-error regime as Fig 4) — offered as an ablation, not a claim of
parity with softmax attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import filtering
from repro.core.lattice import build_lattice
from repro.core.stencil import make_stencil
from repro.models import modules as nn
from repro.models.config import ModelConfig

Array = jax.Array


def lattice_attn_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": nn.dense_init(ks[0], (d, cfg.num_heads * hd), dtype),
        "wk": nn.dense_init(ks[1], (d, cfg.num_heads * hd), dtype),
        "wv": nn.dense_init(ks[2], (d, cfg.num_heads * hd), dtype),
        "wo": nn.dense_init(ks[3], (cfg.num_heads * hd, d), dtype),
        # learned projection into the lattice space
        "phi": nn.dense_init(ks[4], (hd, cfg.lattice_qk_dim), dtype),
    }


def _kernel_attend(zq: Array, zk: Array, v: Array, stencil,
                   cap_factor: float = 1.0) -> Array:
    """One (head x batch) slice: keys (n,dl), queries (m,dl), values (n,c).

    Joint lattice over [keys; queries]; splat values (+ones) from key rows,
    slice at query rows, normalize. ``cap_factor`` scales the lattice
    capacity below the n(d+1) worst case (long-context: projected q/k are
    bounded by the tanh, so vertex sharing is heavy and the Table-3-style
    sparsity prior applies).
    """
    n = zk.shape[0]
    m = zq.shape[0]
    joint = jnp.concatenate([zk, zq], axis=0).astype(jnp.float32)
    d_l = joint.shape[1]
    cap = max(1024, int(cap_factor * (n + m) * (d_l + 1)))
    lat = build_lattice(joint, spacing=stencil.spacing, r=stencil.r,
                        cap=cap)
    ones = jnp.ones((n, 1), v.dtype)
    vj = jnp.concatenate([
        jnp.concatenate([v, ones], axis=1),
        jnp.zeros((m, v.shape[1] + 1), v.dtype)], axis=0)
    w = jnp.asarray(stencil.weights, jnp.float32)
    out = filtering.filter_mvm(lat, vj, w, symmetrize=False)[n:]
    num, den = out[:, :-1], out[:, -1:]
    return num / jnp.maximum(den, 1e-6)


def lattice_attention(params: dict, x: Array, cfg: ModelConfig,
                      *, kv_x: Array | None = None) -> Array:
    """Bidirectional kernel attention via the permutohedral lattice.

    x: (b, s, d) queries; kv_x: key/value source (defaults to x).
    NOTE: kernel attention is not causal — the normalized filter attends
    to the whole window, which is the right semantic for the encode /
    long-context-read settings it is offered for.
    """
    b, s, d = x.shape
    src = x if kv_x is None else kv_x
    hd = cfg.resolved_head_dim
    h = cfg.num_heads
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (src @ params["wk"]).reshape(b, src.shape[1], h, hd)
    v = (src @ params["wv"]).reshape(b, src.shape[1], h, hd)
    zq = jnp.tanh(q @ params["phi"]) * 3.0  # bounded lattice coords
    zk = jnp.tanh(k @ params["phi"]) * 3.0

    st = make_stencil("rbf", 1)
    cf = getattr(cfg, "lattice_cap_factor", 1.0)

    def per_bh(zq1, zk1, v1):
        return _kernel_attend(zq1, zk1, v1, st, cap_factor=cf)

    flat = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, -1, t.shape[-1])
    out = jax.vmap(per_bh)(flat(zq), flat(zk), flat(v))
    out = out.reshape(b, h, s, hd).transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out.astype(x.dtype) @ params["wo"]
