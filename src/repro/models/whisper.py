"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment the conv/mel frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (b, frames, d_model) directly to the encoder.
Encoder layers are bidirectional self-attention; decoder layers are causal
self-attention + cross-attention to the encoder output. Positions are
sinusoidal on both sides (the real model's learned 448-entry decoder table
cannot cover the assigned 32k decode shape — adaptation noted in
DESIGN.md §4).

Decode state = stacked self-attn KV caches + cross-attn K/V precomputed
once from the encoder output ("encode once, decode many").
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.models import attention as attn_mod
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.sharding.constraints import constrain

Array = jax.Array


def _enc_layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn_mod.attn_init(ks[0], cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": nn.mlp_init(ks[1], cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def _dec_layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "self_attn": attn_mod.attn_init(ks[0], cfg, dtype),
        "ln_x": jnp.zeros((cfg.d_model,), dtype),
        "cross_attn": attn_mod.attn_init(ks[1], cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": nn.mlp_init(ks[2], cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def init_params(cfg: ModelConfig, key: Array) -> dict:
    dtype = cfg.dtype
    ke, kd, kemb = jax.random.split(key, 3)
    stack = lambda fn, k, n: jax.vmap(fn)(jax.random.split(k, n))
    return {
        "embed": nn.embed_init(kemb, (cfg.padded_vocab, cfg.d_model),
                               dtype),
        "enc_layers": stack(lambda k: _enc_layer_init(k, cfg, dtype), ke,
                            cfg.encoder_layers),
        "enc_ln_f": jnp.zeros((cfg.d_model,), dtype),
        "dec_layers": stack(lambda k: _dec_layer_init(k, cfg, dtype), kd,
                            cfg.num_layers),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
    }


def _cross_attention(params: dict, x: Array, enc_k: Array,
                     enc_v: Array, cfg: ModelConfig) -> Array:
    """q from decoder hidden; k/v precomputed from encoder output."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(b, s, cfg.num_heads, hd)
    q = q.transpose(0, 2, 1, 3)
    out = flash_attention(q, enc_k, enc_v, causal=False)
    return out.transpose(0, 2, 1, 3).reshape(b, s, -1) @ params["wo"]


def _enc_kv(params: dict, enc_out: Array, cfg: ModelConfig):
    b, f, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ params["wk"]).reshape(b, f, cfg.num_kv_heads, hd)
    v = (enc_out @ params["wv"]).reshape(b, f, cfg.num_kv_heads, hd)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def encode(cfg: ModelConfig, params: dict, frames: Array) -> Array:
    """frames: (b, F, d_model) stub embeddings -> encoder states."""
    b, f, d = frames.shape
    x = frames + nn.sinusoidal_positions(f, d).astype(frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[None],
                                 (b, f))

    def body(x, layer):
        h = nn.rms_norm(x, layer["ln1"], cfg.norm_eps)
        # bidirectional self-attention, no rope (sinusoidal already added)
        hd = cfg.resolved_head_dim
        q = (h @ layer["attn"]["wq"]).reshape(b, f, cfg.num_heads, hd)
        k = (h @ layer["attn"]["wk"]).reshape(b, f, cfg.num_kv_heads, hd)
        v = (h @ layer["attn"]["wv"]).reshape(b, f, cfg.num_kv_heads, hd)
        a = flash_attention(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=False)
        a = a.transpose(0, 2, 1, 3).reshape(b, f, -1) @ layer["attn"]["wo"]
        x = x + a
        h = nn.rms_norm(x, layer["ln2"], cfg.norm_eps)
        return x + nn.mlp_apply(layer["mlp"], h, "gelu"), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return nn.rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: dict, frames: Array,
            tokens: Array):
    """Teacher-forced decoder logits given stub frames + token ids."""
    enc = encode(cfg, params, frames)
    b, s = tokens.shape
    d = cfg.d_model
    x = nn.embed_lookup(params["embed"], tokens)
    x = x + nn.sinusoidal_positions(s, d).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (b, s))

    def body(x, layer):
        h = nn.rms_norm(x, layer["ln1"], cfg.norm_eps)
        hd = cfg.resolved_head_dim
        q = (h @ layer["self_attn"]["wq"]).reshape(b, s, cfg.num_heads, hd)
        k = (h @ layer["self_attn"]["wk"]).reshape(b, s, cfg.num_kv_heads,
                                                   hd)
        v = (h @ layer["self_attn"]["wv"]).reshape(b, s, cfg.num_kv_heads,
                                                   hd)
        a = flash_attention(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=True)
        a = (a.transpose(0, 2, 1, 3).reshape(b, s, -1)
             @ layer["self_attn"]["wo"])
        x = x + a
        h = nn.rms_norm(x, layer["ln_x"], cfg.norm_eps)
        ek, ev = _enc_kv(layer["cross_attn"], enc, cfg)
        x = x + _cross_attention(layer["cross_attn"], h, ek, ev, cfg)
        h = nn.rms_norm(x, layer["ln2"], cfg.norm_eps)
        return x + nn.mlp_apply(layer["mlp"], h, "gelu"), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = nn.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = nn.logits_from_hidden(x, params["embed"], None,
                                   cfg.vocab_size)
    return constrain(logits, "batch", "seq", "model")


class WhisperState(NamedTuple):
    self_cache: Any  # stacked attn_mod.KVCache over decoder layers
    cross_k: Array  # (L, b, hkv, F, hd)
    cross_v: Array  # (L, b, hkv, F, hd)


def init_state(cfg: ModelConfig, params: dict, enc_out: Array,
               max_seq: int) -> WhisperState:
    """Precompute cross K/V once (encode-once, decode-many)."""
    b = enc_out.shape[0]
    kv = attn_mod.init_kv_cache(cfg, b, max_seq)
    stacked = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (cfg.num_layers,) + l.shape),
        kv)

    def per_layer(layer):
        return _enc_kv(layer["cross_attn"], enc_out, cfg)

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])
    return WhisperState(self_cache=stacked, cross_k=ck, cross_v=cv)


def serve_step(cfg: ModelConfig, params: dict, state: WhisperState,
               tokens: Array, position: Array):
    """One decoder token against self cache + fixed cross K/V."""
    b = tokens.shape[0]
    d = cfg.d_model
    x = nn.embed_lookup(params["embed"], tokens)
    # sinusoidal position of the current step
    pos_table = nn.sinusoidal_positions(state.self_cache.k.shape[3] + 1, d)
    x = x + pos_table[position][:, None].astype(x.dtype)

    def body(x, inp):
        layer, cache, ck, cv = inp
        h = nn.rms_norm(x, layer["ln1"], cfg.norm_eps)
        a, cache = attn_mod.decode_attention(layer["self_attn"], h, cache,
                                             position, cfg,
                                             use_rope=False)
        x = x + a
        h = nn.rms_norm(x, layer["ln_x"], cfg.norm_eps)
        x = x + _cross_attention(layer["cross_attn"], h, ck, cv, cfg)
        h = nn.rms_norm(x, layer["ln2"], cfg.norm_eps)
        return x + nn.mlp_apply(layer["mlp"], h, "gelu"), cache

    x, new_cache = jax.lax.scan(
        body, x, (params["dec_layers"], state.self_cache, state.cross_k,
                  state.cross_v))
    x = nn.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = nn.logits_from_hidden(x, params["embed"], None,
                                   cfg.vocab_size)
    logits = constrain(logits, "batch", None, "model")
    return logits, state._replace(self_cache=new_cache)
