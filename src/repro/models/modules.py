"""Shared neural-net primitives for the architecture pool.

Everything is a pure function over explicit parameter dicts (no framework
modules): params are nested dicts of jax.Arrays, so the sharding layer
(sharding/partition.py) can mirror the tree with PartitionSpecs and the
checkpoint layer can treat it as a flat list of named tensors.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(key: Array, shape: tuple[int, ...], dtype,
               scale: float | None = None) -> Array:
    """Truncated-normal fan-in init (MaxText-style)."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    if len(shape) == 3:  # (experts, in, out)
        fan_in = shape[1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (std * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def embed_init(key: Array, shape: tuple[int, ...], dtype) -> Array:
    return (jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rms_norm(x: Array, weight: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: Array, weight: Array, bias: Array,
               eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight + bias).astype(x.dtype)


def group_norm(x: Array, weight: Array, bias: Array, num_groups: int,
               eps: float = 1e-5) -> Array:
    """Per-head norm used by RWKV6 time-mix output. x: (..., H*K)."""
    shape = x.shape
    xf = x.astype(jnp.float32).reshape(*shape[:-1], num_groups, -1)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(shape)
    return (out * weight + bias).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings (half-rotate / NeoX convention)
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 1e4) -> Array:
    """x: (b, h, s, hd); positions: (b, s) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # b1sf
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(x: Array, positions_3d: Array, sections: tuple[int, ...],
                 theta: float = 1e4) -> Array:
    """Qwen2-VL M-RoPE. x: (b, h, s, hd); positions_3d: (3, b, s).

    The hd/2 frequency slots are partitioned into `sections` (t, h, w);
    each section rotates by its own positional stream. Text tokens carry
    identical (t,h,w) positions, recovering 1-D RoPE exactly.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    assert sum(sections) == hd // 2, (sections, hd)
    # build per-slot positions: (b, s, hd/2)
    parts = []
    for i, sec in enumerate(sections):
        parts.append(jnp.broadcast_to(
            positions_3d[i][:, :, None],
            positions_3d.shape[1:] + (sec,)))
    pos = jnp.concatenate(parts, axis=-1).astype(jnp.float32)
    angles = pos[:, None, :, :] * freqs  # (b, 1, s, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> Array:
    """Whisper-style fixed sinusoidal embeddings. -> (length, dim)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-math.log(10_000.0)
                  * jnp.arange(dim // 2, dtype=jnp.float32) / (dim // 2 - 1))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def mlp_init(key: Array, d_model: int, d_ff: int, kind: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "wi_gate": dense_init(ks[0], (d_model, d_ff), dtype),
            "wi_up": dense_init(ks[1], (d_model, d_ff), dtype),
            "wo": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    # relu2 (Minitron squared-ReLU) and gelu (Whisper) are non-gated
    return {
        "wi": dense_init(ks[0], (d_model, d_ff), dtype),
        "wo": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def mlp_apply(params: dict, x: Array, kind: str) -> Array:
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["wi_gate"]) * (x @ params["wi_up"])
        return h @ params["wo"]
    if kind == "gelu":
        return jax.nn.gelu(x @ params["wi"]) @ params["wo"]
    h = jax.nn.relu(x @ params["wi"])
    return (h * h) @ params["wo"]


# --------------------------------------------------------------------------
# embedding / unembedding with Megatron-style padded vocab
# --------------------------------------------------------------------------


def embed_lookup(embedding: Array, tokens: Array) -> Array:
    return jnp.take(embedding, tokens, axis=0)


def logits_from_hidden(x: Array, embedding: Array, head: Array | None,
                       vocab_size: int) -> Array:
    """x: (..., d) -> (..., padded_vocab); padded columns masked to -inf."""
    table = embedding if head is None else head
    logits = (x.astype(jnp.float32)
              @ table.T.astype(jnp.float32)) if head is None else (
        x.astype(jnp.float32) @ table.astype(jnp.float32))
    padded = logits.shape[-1]
    if padded > vocab_size:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < vocab_size, logits, -1e30)
    return logits


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Mean next-token CE in f32. logits: (b, s, v); labels: (b, s)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    return jnp.mean(logz - gold)
