"""Top-level model API: one object per architecture.

``LM`` wraps config + family dispatch behind the five entry points the
rest of the framework uses:

    init_params(key)                     concrete params (smoke/examples)
    abstract_params()                    ShapeDtypeStruct tree (dry-run)
    loss_fn(params, batch)               CE (+ MoE aux), masked
    serve_step(params, state, tok, pos)  one-token decode
    input_specs(shape)                   ShapeDtypeStruct batch for dry-run

Batches are dicts: tokens/labels/loss_mask (+frames for audio,
vision_embeds/positions_3d for VLM, decode state + position for decode).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.models import transformer, whisper
from repro.models.config import SHAPES, ModelConfig, ShapeSpec
from repro.optim import Adam

Array = jax.Array


def masked_ce(logits: Array, labels: Array, mask: Array) -> Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    per = (logz - gold) * mask
    return jnp.sum(per) / jnp.maximum(jnp.sum(mask), 1.0)


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig

    # ---- params ----------------------------------------------------------
    def init_params(self, key: Array) -> dict:
        if self.cfg.is_encdec:
            return whisper.init_params(self.cfg, key)
        return transformer.init_params(self.cfg, key)

    def abstract_params(self) -> dict:
        return jax.eval_shape(
            lambda k: self.init_params(k), jax.random.PRNGKey(0))

    # ---- training --------------------------------------------------------
    def loss_fn(self, params: dict, batch: dict) -> tuple[Array, dict]:
        cfg = self.cfg
        if cfg.is_encdec:
            logits = whisper.forward(cfg, params, batch["frames"],
                                     batch["tokens"])
            aux = jnp.zeros((), jnp.float32)
        else:
            out = transformer.forward(
                cfg, params, batch["tokens"],
                vision_embeds=batch.get("vision_embeds"),
                positions_3d=batch.get("positions_3d"))
            logits, aux = out.logits, out.aux_loss
        ce = masked_ce(logits, batch["labels"], batch["loss_mask"])
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    def make_train_step(self, optimizer: Adam | None = None,
                        microbatches: int = 1):
        """Build the jittable train step.

        ``microbatches > 1`` enables gradient accumulation: the global
        batch is split on dim 0 and scanned, cutting activation memory
        ~k-fold at the cost of k sequential passes — how the 236B MoE
        train cells fit HBM (EXPERIMENTS.md §Dry-run).
        """
        opt = optimizer or Adam(learning_rate=3e-4, clip_global_norm=1.0)

        def train_step(params, opt_state, batch):
            if microbatches == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    self.loss_fn, has_aux=True)(params, batch)
            else:
                def split(leaf):
                    b = leaf.shape[0]
                    assert b % microbatches == 0, (b, microbatches)
                    return leaf.reshape((microbatches, b // microbatches)
                                        + leaf.shape[1:])

                mb = {k: (jnp.moveaxis(split(v), 0, 0) if k != "positions_3d"
                          else jnp.moveaxis(
                              v.reshape((3, microbatches,
                                         v.shape[1] // microbatches)
                                        + v.shape[2:]), 1, 0))
                      for k, v in batch.items()}

                def body(acc, one):
                    (l, m), g = jax.value_and_grad(
                        self.loss_fn, has_aux=True)(params, one)
                    acc_g, acc_l = acc
                    acc_g = jax.tree.map(jnp.add, acc_g, g)
                    return (acc_g, acc_l + l), m

                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum), ms = jax.lax.scan(
                    body, (zero, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / microbatches, gsum)
                loss = lsum / microbatches
                metrics = jax.tree.map(lambda m: jnp.mean(m), ms)
            params, opt_state = opt.update(grads, opt_state, params)
            metrics = dict(metrics, loss=loss)
            return params, opt_state, metrics

        return train_step, opt

    # ---- prefill / decode ---------------------------------------------------
    def prefill(self, params: dict, batch: dict) -> Array:
        cfg = self.cfg
        if cfg.is_encdec:
            enc = whisper.encode(cfg, params, batch["frames"])
            return enc
        out = transformer.forward(
            cfg, params, batch["tokens"],
            vision_embeds=batch.get("vision_embeds"),
            positions_3d=batch.get("positions_3d"))
        return out.logits

    def init_decode_state(self, batch: int, max_seq: int,
                          params: dict | None = None,
                          enc_out: Array | None = None) -> Any:
        cfg = self.cfg
        if cfg.is_encdec:
            assert params is not None and enc_out is not None
            return whisper.init_state(cfg, params, enc_out, max_seq)
        return transformer.init_decode_state(cfg, batch, max_seq)

    def abstract_decode_state(self, batch: int, max_seq: int) -> Any:
        cfg = self.cfg
        if cfg.is_encdec:
            frames = jax.ShapeDtypeStruct(
                (batch, cfg.encoder_frames, cfg.d_model), cfg.dtype)
            return jax.eval_shape(
                lambda p, f: whisper.init_state(
                    cfg, p, f, max_seq), self.abstract_params(), frames)
        return jax.eval_shape(
            lambda: transformer.init_decode_state(cfg, batch, max_seq))

    def serve_step(self, params: dict, state: Any, tokens: Array,
                   position: Array):
        cfg = self.cfg
        if cfg.is_encdec:
            return whisper.serve_step(cfg, params, state, tokens, position)
        return transformer.serve_step(cfg, params, state, tokens, position)

    # ---- dry-run input specs -------------------------------------------------
    def input_specs(self, shape: ShapeSpec | str,
                    global_batch: int | None = None) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        cfg = self.cfg
        if isinstance(shape, str):
            shape = SHAPES[shape]
        b = global_batch or shape.global_batch
        s = shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct

        if shape.kind in ("train", "prefill"):
            if cfg.is_encdec:
                # encoder consumes stub frames; decoder sees s tokens
                # (prefill_32k = 32k-frame encode + 1 decoder token)
                dec_s = 1 if shape.kind == "prefill" else min(s, 4096)
                frames = min(s, 32_768) if shape.kind == "prefill" \
                    else cfg.encoder_frames
                batch = {
                    "frames": sds((b, frames, cfg.d_model), cfg.dtype),
                    "tokens": sds((b, dec_s), i32),
                }
            elif cfg.family == "vlm" and cfg.num_vision_tokens:
                nv = min(cfg.num_vision_tokens, s // 4)
                st = s - nv
                batch = {
                    "tokens": sds((b, st), i32),
                    "vision_embeds": sds((b, nv, cfg.d_model), cfg.dtype),
                    "positions_3d": sds((3, b, s), i32),
                }
            else:
                batch = {"tokens": sds((b, s), i32)}
            if shape.kind == "train":
                ls = (batch["tokens"].shape[1] if cfg.is_encdec
                      else s if cfg.family != "vlm" else s)
                batch["labels"] = sds((b, ls), i32)
                batch["loss_mask"] = sds((b, ls), jnp.float32)
            return batch

        # decode: one new token against a seq_len-deep state
        return {
            "tokens": sds((b, 1), i32),
            "position": sds((b,), i32),
            "state": self.abstract_decode_state(b, s),
        }


def build(cfg: ModelConfig) -> LM:
    return LM(cfg=cfg)
