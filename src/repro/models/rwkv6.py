"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free, data-dependent decay.

Time-mixing recurrence per head (state S in R^{K x V}):

    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t,     w_t = exp(-exp(ww_t))

Training uses the **chunked-parallel** form (the TPU adaptation — the
reference CUDA kernel is a serial per-token loop; a serial scan would
starve the MXU). Within a chunk of length C, with P_t = prod_{i<=t} w_i:

    scores[t,s] = <r_t . P_{t-1}, k_s / P_s>   (strictly causal s < t)
    y = scores @ V + (r . P_shift) @ S_in + diag(<r_t . u, k_t>) v_t
    S_out = diag(P_C) S_in + (K . P_C/P)^T V

so a 4096-token sequence becomes 4096/C batched (C x C)(C x V) matmuls —
MXU-shaped — plus a short scan over chunks carrying S. Decode is the O(1)
recurrence on the cached state.

Token-shift / ddlerp and the channel-mix block follow the Finch paper
(LoRA-modulated interpolation between x_t and x_{t-1}).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.models.config import ModelConfig

Array = jax.Array

_MIX_NAMES = ("w", "k", "v", "r", "g")


def tmix_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    rank = cfg.rwkv_lora_rank
    hk = cfg.rwkv_head_dim
    h = d // hk
    ks = jax.random.split(key, 12)
    return {
        "mu_base": jnp.zeros((d,), dtype),
        "mu": jnp.zeros((5, d), dtype),
        "lora_a": nn.dense_init(ks[0], (d, 5 * rank), dtype),
        "lora_b": nn.dense_init(ks[1], (5, rank, d), dtype, scale=0.01),
        "wr": nn.dense_init(ks[2], (d, d), dtype),
        "wk": nn.dense_init(ks[3], (d, d), dtype),
        "wv": nn.dense_init(ks[4], (d, d), dtype),
        "wg": nn.dense_init(ks[5], (d, d), dtype),
        "wo": nn.dense_init(ks[6], (d, d), dtype),
        "w0": jnp.full((d,), -6.0, dtype),  # decay bias: w ~ exp(-exp(-6))
        "wd_a": nn.dense_init(ks[7], (d, rank), dtype),
        "wd_b": nn.dense_init(ks[8], (rank, d), dtype, scale=0.01),
        "u": jnp.zeros((h, hk), dtype),  # "bonus" for the current token
        "gn_w": jnp.ones((d,), dtype),
        "gn_b": jnp.zeros((d,), dtype),
    }


def cmix_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), dtype),
        "mu_r": jnp.zeros((d,), dtype),
        "wk": nn.dense_init(ks[0], (d, f), dtype),
        "wv": nn.dense_init(ks[1], (f, d), dtype),
        "wr": nn.dense_init(ks[2], (d, d), dtype),
    }


class RWKVState(NamedTuple):
    s: Array  # (b, h, K, V) wkv state
    shift_t: Array  # (b, d) last token for time-mix shift
    shift_c: Array  # (b, d) last token for channel-mix shift


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RWKVState:
    d = cfg.d_model
    hk = cfg.rwkv_head_dim
    h = d // hk
    return RWKVState(
        s=jnp.zeros((batch, h, hk, hk), jnp.float32),
        shift_t=jnp.zeros((batch, d), dtype),
        shift_c=jnp.zeros((batch, d), dtype),
    )


def _ddlerp(params: dict, x: Array, x_prev: Array):
    """Finch data-dependent token-shift. Returns dict name -> mixed input."""
    dx = x_prev - x
    xxx = x + dx * params["mu_base"]
    rank = params["lora_a"].shape[1] // 5
    lora = jnp.tanh(xxx @ params["lora_a"])
    lora = lora.reshape(*lora.shape[:-1], 5, rank)
    mods = jnp.einsum("...nr,nrd->...nd", lora, params["lora_b"])
    out = {}
    for i, name in enumerate(_MIX_NAMES):
        mix = params["mu"][i] + mods[..., i, :]
        out[name] = x + dx * mix
    return out


def _rkvgw(params: dict, x: Array, x_prev: Array, cfg: ModelConfig):
    b = x.shape[0]
    s = x.shape[1] if x.ndim == 3 else 1
    d = cfg.d_model
    hk = cfg.rwkv_head_dim
    h = d // hk
    mixed = _ddlerp(params, x, x_prev)
    r = mixed["r"] @ params["wr"]
    k = mixed["k"] @ params["wk"]
    v = mixed["v"] @ params["wv"]
    g = jax.nn.silu(mixed["g"] @ params["wg"])
    ww = params["w0"] + jnp.tanh(mixed["w"] @ params["wd_a"]) @ params["wd_b"]
    logw = -jnp.exp(ww.astype(jnp.float32))  # log decay in (-inf, 0)
    hd = lambda t: t.reshape(b, s, h, hk).astype(jnp.float32)
    return hd(r), hd(k), hd(v), g, logw.reshape(b, s, h, hk)


def tmix_chunked(params: dict, x: Array, state: RWKVState,
                 cfg: ModelConfig) -> tuple[Array, RWKVState]:
    """Chunked-parallel time mixing over a full sequence. x: (b, s, d)."""
    b, s, d = x.shape
    hk = cfg.rwkv_head_dim
    h = d // hk
    c = min(cfg.ssm_chunk, s)
    assert s % c == 0, (s, c)
    x_prev = jnp.concatenate(
        [state.shift_t[:, None, :], x[:, :-1]], axis=1)
    r, k, v, g, logw = _rkvgw(params, x, x_prev, cfg)
    u = params["u"].astype(jnp.float32)

    nc = s // c
    resh = lambda t: t.reshape(b, nc, c, h, hk).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, lwc = resh(r), resh(k), resh(v), resh(logw)  # (nc,b,h,c,K)

    mask = jnp.tril(jnp.ones((c, c), jnp.float32), -1)  # strictly causal

    def chunk_step(s_in, inp):
        rr, kk, vv, lw = inp  # (b, h, c, K)
        lp = jnp.cumsum(lw, axis=2)  # log P_t
        p_shift = jnp.exp(jnp.concatenate(
            [jnp.zeros_like(lp[:, :, :1]), lp[:, :, :-1]], axis=2))
        r_dec = rr * p_shift  # r_t . P_{t-1}
        k_dec = kk * jnp.exp(-lp)  # k_s / P_s
        scores = jnp.einsum("bhtk,bhsk->bhts", r_dec, k_dec) * mask
        bonus = jnp.einsum("bhtk,bhtk->bht", rr * u[None, :, None, :], kk)
        y = (jnp.einsum("bhts,bhsv->bhtv", scores, vv)
             + jnp.einsum("bhtk,bhkv->bhtv", r_dec, s_in)
             + bonus[..., None] * vv)
        p_total = jnp.exp(lp[:, :, -1])  # (b, h, K)
        k_tail = kk * jnp.exp(lp[:, :, -1:, :] - lp)  # k_s . P_C/P_s
        s_out = (p_total[..., None] * s_in
                 + jnp.einsum("bhsk,bhsv->bhkv", k_tail, vv))
        return s_out, y

    s_fin, ys = jax.lax.scan(chunk_step, state.s, (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, d)
    y = nn.group_norm(y.astype(x.dtype), params["gn_w"], params["gn_b"], h)
    out = (y * g) @ params["wo"]
    return out, RWKVState(s=s_fin, shift_t=x[:, -1], shift_c=state.shift_c)


def tmix_decode(params: dict, x: Array, state: RWKVState,
                cfg: ModelConfig) -> tuple[Array, RWKVState]:
    """One-token recurrence. x: (b, 1, d)."""
    b, _, d = x.shape
    hk = cfg.rwkv_head_dim
    h = d // hk
    r, k, v, g, logw = _rkvgw(params, x, state.shift_t[:, None], cfg)
    r, k, v, logw = (t[:, 0] for t in (r, k, v, logw))  # (b, h, K)
    u = params["u"].astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    y = jnp.einsum("bhk,bhkv->bhv", r, state.s + u[None, :, :, None] * kv)
    s_new = jnp.exp(logw)[..., None] * state.s + kv
    y = y.reshape(b, 1, d)
    y = nn.group_norm(y.astype(x.dtype), params["gn_w"], params["gn_b"], h)
    out = (y * g) @ params["wo"]
    return out, RWKVState(s=s_new, shift_t=x[:, 0], shift_c=state.shift_c)


def cmix(params: dict, x: Array, state: RWKVState, cfg: ModelConfig,
         *, decode: bool) -> tuple[Array, RWKVState]:
    """Channel mixing (squared-ReLU gated MLP with token shift)."""
    if decode:
        x_prev = state.shift_c[:, None]
    else:
        x_prev = jnp.concatenate(
            [state.shift_c[:, None, :], x[:, :-1]], axis=1)
    dx = x_prev - x
    xk = x + dx * params["mu_k"]
    xr = x + dx * params["mu_r"]
    kk = jax.nn.relu(xk @ params["wk"])
    out = jax.nn.sigmoid(xr @ params["wr"]) * ((kk * kk) @ params["wv"])
    return out, state._replace(shift_c=x[:, -1])
