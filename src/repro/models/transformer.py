"""Unified decoder-only LM covering dense / MoE / MLA / VLM / SSM / hybrid.

One parameter tree + forward/serve pair per family, assembled from the
block libraries (attention.py, moe.py, mla.py, rwkv6.py, griffin.py,
lattice_attention.py). Layers are **stacked and scanned** (`lax.scan` over
a leading L axis on every layer parameter) so the lowered HLO contains one
layer body regardless of depth — this keeps the 80-cell dry-run
compile-able and is what MaxText does in production. Heterogeneous stacks
(DeepSeek's leading dense layers, Griffin's (rec, rec, attn) period) are
split into one scan per homogeneous segment.

All functions are pure; params are nested dicts mirrored 1:1 by
sharding/partition.py's PartitionSpec trees.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import griffin as griffin_mod
from repro.models import lattice_attention as lattn_mod
from repro.models import mla as mla_mod
from repro.models import modules as nn
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.config import ModelConfig
from repro.sharding.constraints import constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# layer init by family
# ---------------------------------------------------------------------------


def _dense_layer_init(key, cfg: ModelConfig, dtype, *, use_moe: bool):
    ks = jax.random.split(key, 2)
    if cfg.mla:
        attn = mla_mod.mla_init(ks[0], cfg, dtype)
    elif cfg.attention_kind == "lattice":
        attn = lattn_mod.lattice_attn_init(ks[0], cfg, dtype)
    else:
        attn = attn_mod.attn_init(ks[0], cfg, dtype)
    if use_moe:
        mlp = moe_mod.moe_init(ks[1], cfg, dtype)
    else:
        mlp = nn.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn,
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": mlp,
    }


def _rwkv_layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1_w": jnp.ones((cfg.d_model,), dtype),
        "ln1_b": jnp.zeros((cfg.d_model,), dtype),
        "tmix": rwkv_mod.tmix_init(ks[0], cfg, dtype),
        "ln2_w": jnp.ones((cfg.d_model,), dtype),
        "ln2_b": jnp.zeros((cfg.d_model,), dtype),
        "cmix": rwkv_mod.cmix_init(ks[1], cfg, dtype),
    }


def _griffin_sub_init(key, cfg: ModelConfig, dtype, kind: str):
    ks = jax.random.split(key, 2)
    if kind == "rec":
        inner = griffin_mod.rglru_block_init(ks[0], cfg, dtype)
    else:
        inner = attn_mod.attn_init(ks[0], cfg, dtype)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "inner": inner,
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": nn.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                           dtype),
    }


def _griffin_period_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    return {
        "rec1": _griffin_sub_init(ks[0], cfg, dtype, "rec"),
        "rec2": _griffin_sub_init(ks[1], cfg, dtype, "rec"),
        "attn": _griffin_sub_init(ks[2], cfg, dtype, "attn"),
    }


def _stack(init_fn, key, n: int):
    if n == 0:
        return None
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_params(cfg: ModelConfig, key: Array) -> dict:
    dtype = cfg.dtype
    k_embed, k_layers, k_head, k_extra = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": nn.embed_init(k_embed, (cfg.padded_vocab, cfg.d_model),
                               dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = nn.dense_init(k_head,
                                       (cfg.d_model, cfg.padded_vocab),
                                       dtype)
    if cfg.family == "ssm":
        params["embed_ln_w"] = jnp.ones((cfg.d_model,), dtype)
        params["embed_ln_b"] = jnp.zeros((cfg.d_model,), dtype)
        params["layers"] = _stack(
            lambda k: _rwkv_layer_init(k, cfg, dtype), k_layers,
            cfg.num_layers)
    elif cfg.family == "hybrid":
        periods = cfg.num_layers // 3
        tail = cfg.num_layers - periods * 3
        params["periods"] = _stack(
            lambda k: _griffin_period_init(k, cfg, dtype), k_layers,
            periods)
        tails = {}
        tk = jax.random.split(k_extra, max(tail, 1))
        for i in range(tail):
            tails[f"rec{i}"] = _griffin_sub_init(tk[i], cfg, dtype, "rec")
        params["tail"] = tails
    else:  # dense / moe / vlm backbones
        n_dense = cfg.first_k_dense if cfg.moe else cfg.num_layers
        n_moe = cfg.num_layers - n_dense if cfg.moe else 0
        if cfg.moe and n_dense:
            params["dense_layers"] = _stack(
                lambda k: _dense_layer_init(k, cfg, dtype, use_moe=False),
                k_extra, n_dense)
        params["layers"] = _stack(
            lambda k: _dense_layer_init(k, cfg, dtype,
                                        use_moe=cfg.moe),
            k_layers, n_moe if cfg.moe else cfg.num_layers)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


class ForwardOut(NamedTuple):
    logits: Array
    aux_loss: Array


def _dense_block(layer, x, positions, cfg: ModelConfig, *, use_moe: bool,
                 positions_3d=None):
    h = nn.rms_norm(x, layer["ln1"], cfg.norm_eps)
    if cfg.mla:
        a = mla_mod.mla_attention(layer["attn"], h, positions, cfg)
    elif cfg.attention_kind == "lattice":
        a = lattn_mod.lattice_attention(layer["attn"], h, cfg)
    elif cfg.sliding_window:
        a = attn_mod.windowed_attention(layer["attn"], h, positions, cfg,
                                        cfg.sliding_window)
    else:
        a = attn_mod.full_attention(layer["attn"], h, positions, cfg,
                                    positions_3d=positions_3d)
    sp = a.shape[1] > 1  # train/prefill: Megatron-SP on block outputs so
    # the row-parallel TP psum lowers as reduce-scatter, not all-reduce
    # (§Perf B8: measured all-reduce was the dominant collective)
    if sp:
        a = constrain(a, "batch", "seq_tp", None)
    x = x + a
    h = nn.rms_norm(x, layer["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        out = moe_mod.moe_apply(layer["mlp"], h, cfg)
        m, aux = out.y, out.aux_loss
    else:
        m = nn.mlp_apply(layer["mlp"], h, cfg.mlp_kind)
    if sp:
        m = constrain(m, "batch", "seq_tp", None)
    out_x = x + m
    if sp:
        out_x = constrain(out_x, "batch", "seq_tp", None)
    return out_x, aux


def _griffin_sub(layer, x, positions, state, cfg: ModelConfig, kind: str,
                 *, decode: bool):
    h = nn.rms_norm(x, layer["ln1"], cfg.norm_eps)
    if kind == "rec":
        inner, state = griffin_mod.recurrent_block(
            layer["inner"], h, state, cfg, decode=decode)
    else:
        if decode:
            inner, state = attn_mod.decode_attention(
                layer["inner"], h, state, positions, cfg,
                window=cfg.local_window)
        else:
            inner = attn_mod.windowed_attention(
                layer["inner"], h, positions, cfg, cfg.local_window)
    x = x + inner
    h = nn.rms_norm(x, layer["ln2"], cfg.norm_eps)
    out_x = x + nn.mlp_apply(layer["mlp"], h, cfg.mlp_kind)
    if not decode:
        out_x = constrain(out_x, "batch", "seq_tp", None)
    return out_x, state


def _rwkv_block(layer, x, state, cfg: ModelConfig, *, decode: bool):
    h = nn.layer_norm(x, layer["ln1_w"], layer["ln1_b"], cfg.norm_eps)
    if decode:
        t, state = rwkv_mod.tmix_decode(layer["tmix"], h, state, cfg)
    else:
        t, state = rwkv_mod.tmix_chunked(layer["tmix"], h, state, cfg)
    x = x + t
    h = nn.layer_norm(x, layer["ln2_w"], layer["ln2_b"], cfg.norm_eps)
    c, state = rwkv_mod.cmix(layer["cmix"], h, state, cfg, decode=decode)
    out_x = x + c
    if not decode:
        out_x = constrain(out_x, "batch", "seq_tp", None)
    return out_x, state


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def forward(cfg: ModelConfig, params: dict, tokens: Array, *,
            vision_embeds: Array | None = None,
            positions_3d: Array | None = None) -> ForwardOut:
    """Full-sequence forward. tokens: (b, s_text) int32.

    VLM: `vision_embeds` (b, nv, d) are prepended (stub frontend);
    positions_3d (3, b, s_total) provides M-RoPE streams.
    """
    x = nn.embed_lookup(params["embed"], tokens)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    x = constrain(x, "batch", "seq", None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "ssm":
        x = nn.layer_norm(x, params["embed_ln_w"], params["embed_ln_b"],
                          cfg.norm_eps)
        state0 = rwkv_mod.init_state(cfg, b, dtype=x.dtype)

        def body(x, layer):
            out, _ = _rwkv_block(layer, x, state0, cfg, decode=False)
            return out, None

        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
    elif cfg.family == "hybrid":
        rec0 = griffin_mod.init_rec_state(cfg, b, dtype=x.dtype)

        def body(x, period):
            x, _ = _griffin_sub(period["rec1"], x, positions, rec0, cfg,
                                "rec", decode=False)
            x, _ = _griffin_sub(period["rec2"], x, positions, rec0, cfg,
                                "rec", decode=False)
            x, _ = _griffin_sub(period["attn"], x, positions, None, cfg,
                                "attn", decode=False)
            return x, None

        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["periods"])
        for name in sorted(params.get("tail", {})):
            x, _ = _griffin_sub(params["tail"][name], x, positions, rec0,
                                cfg, "rec", decode=False)
    else:
        if cfg.moe and params.get("dense_layers") is not None:
            def dbody(x, layer):
                out, _ = _dense_block(layer, x, positions, cfg,
                                      use_moe=False)
                return out, None

            x, _ = jax.lax.scan(_maybe_remat(dbody, cfg), x,
                                params["dense_layers"])

        def body(carry, layer):
            x, aux = carry
            out, a = _dense_block(layer, x, positions, cfg,
                                  use_moe=cfg.moe,
                                  positions_3d=positions_3d)
            return (out, aux + a), None

        (x, aux_total), _ = jax.lax.scan(_maybe_remat(body, cfg),
                                         (x, aux_total), params["layers"])

    x = nn.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = nn.logits_from_hidden(x, params["embed"],
                                   params.get("head"), cfg.vocab_size)
    logits = constrain(logits, "batch", "seq", "model")
    return ForwardOut(logits=logits, aux_loss=aux_total)


# ---------------------------------------------------------------------------
# decode: state init + one-token step
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> Any:
    """Per-layer stacked decode state (KV caches / recurrent states)."""
    if cfg.family == "ssm":
        one = rwkv_mod.init_state(cfg, batch, dtype=cfg.dtype)
        return jax.tree.map(
            lambda leaf: jnp.broadcast_to(
                leaf[None], (cfg.num_layers,) + leaf.shape), one)
    if cfg.family == "hybrid":
        periods = cfg.num_layers // 3
        tail = cfg.num_layers - periods * 3
        rec = griffin_mod.init_rec_state(cfg, batch, dtype=cfg.dtype)
        kv = attn_mod.init_kv_cache(cfg, batch, max_seq,
                                    window=cfg.local_window)
        period_state = {
            "rec1": jax.tree.map(
                lambda l: jnp.broadcast_to(l[None],
                                           (periods,) + l.shape), rec),
            "rec2": jax.tree.map(
                lambda l: jnp.broadcast_to(l[None],
                                           (periods,) + l.shape), rec),
            "attn": jax.tree.map(
                lambda l: jnp.broadcast_to(l[None],
                                           (periods,) + l.shape), kv),
        }
        return {"periods": period_state,
                "tail": {f"rec{i}": rec for i in range(tail)}}
    if cfg.mla:
        one = mla_mod.init_mla_cache(cfg, batch, max_seq)
        n_moe = cfg.num_layers - cfg.first_k_dense
        out = {"layers": jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n_moe,) + l.shape), one)}
        if cfg.first_k_dense:
            out["dense_layers"] = jax.tree.map(
                lambda l: jnp.broadcast_to(
                    l[None], (cfg.first_k_dense,) + l.shape), one)
        return out
    one = attn_mod.init_kv_cache(cfg, batch, max_seq)
    n_scan = cfg.num_layers - (cfg.first_k_dense if cfg.moe else 0)
    out = {"layers": jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (n_scan,) + l.shape), one)}
    if cfg.moe and cfg.first_k_dense:
        out["dense_layers"] = jax.tree.map(
            lambda l: jnp.broadcast_to(
                l[None], (cfg.first_k_dense,) + l.shape), one)
    return out


def _decode_dense_block(layer, x, cache, position, cfg: ModelConfig, *,
                        use_moe: bool):
    h = nn.rms_norm(x, layer["ln1"], cfg.norm_eps)
    if cfg.mla:
        a, cache = mla_mod.mla_decode(layer["attn"], h, cache, position,
                                      cfg)
    else:
        a, cache = attn_mod.decode_attention(layer["attn"], h, cache,
                                             position, cfg)
    x = x + a
    h = nn.rms_norm(x, layer["ln2"], cfg.norm_eps)
    if use_moe:
        out = moe_mod.moe_apply(layer["mlp"], h, cfg)
        m = out.y
    else:
        m = nn.mlp_apply(layer["mlp"], h, cfg.mlp_kind)
    return x + m, cache


def serve_step(cfg: ModelConfig, params: dict, state: Any, tokens: Array,
               position: Array) -> tuple[Array, Any]:
    """One decode step. tokens: (b, 1); position: (b,) absolute index."""
    x = nn.embed_lookup(params["embed"], tokens)
    b = x.shape[0]

    if cfg.family == "ssm":
        x = nn.layer_norm(x, params["embed_ln_w"], params["embed_ln_b"],
                          cfg.norm_eps)

        def body(x, inp):
            layer, st = inp
            out, st = _rwkv_block(layer, x, st, cfg, decode=True)
            return out, st

        x, new_state = jax.lax.scan(body, x, (params["layers"], state))
    elif cfg.family == "hybrid":
        def body(x, inp):
            period, st = inp
            x, s1 = _griffin_sub(period["rec1"], x, position, st["rec1"],
                                 cfg, "rec", decode=True)
            x, s2 = _griffin_sub(period["rec2"], x, position, st["rec2"],
                                 cfg, "rec", decode=True)
            x, sa = _griffin_sub(period["attn"], x, position, st["attn"],
                                 cfg, "attn", decode=True)
            return x, {"rec1": s1, "rec2": s2, "attn": sa}

        x, new_periods = jax.lax.scan(
            body, x, (params["periods"], state["periods"]))
        new_tail = {}
        for name in sorted(params.get("tail", {})):
            x, st = _griffin_sub(params["tail"][name], x, position,
                                 state["tail"][name], cfg, "rec",
                                 decode=True)
            new_tail[name] = st
        new_state = {"periods": new_periods, "tail": new_tail}
    else:
        new_state = dict(state)
        if cfg.moe and params.get("dense_layers") is not None:
            def dbody(x, inp):
                layer, st = inp
                out, st = _decode_dense_block(layer, x, st, position, cfg,
                                              use_moe=False)
                return out, st

            x, nd = jax.lax.scan(dbody, x, (params["dense_layers"],
                                            state["dense_layers"]))
            new_state["dense_layers"] = nd

        def body(x, inp):
            layer, st = inp
            out, st = _decode_dense_block(layer, x, st, position, cfg,
                                          use_moe=cfg.moe)
            return out, st

        x, nl = jax.lax.scan(body, x, (params["layers"], state["layers"]))
        new_state["layers"] = nl

    x = nn.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = nn.logits_from_hidden(x, params["embed"],
                                   params.get("head"), cfg.vocab_size)
    logits = constrain(logits, "batch", None, "model")
    return logits, new_state
