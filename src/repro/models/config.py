"""Model configuration shared by all 10 assigned architectures.

One frozen dataclass covers the whole pool; family-specific switches select
blocks (MoE, MLA, RWKV6 time-mix, RG-LRU, enc-dec). Exact published numbers
live in src/repro/configs/<arch>.py; this module only defines the schema
and the input-shape descriptors (train_4k / prefill_32k / decode_32k /
long_500k).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---------------------------------------------------------------
    moe: bool = False
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden
    first_k_dense: int = 0  # leading dense layers (DeepSeek-V2 style)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # --- MLA (DeepSeek-V2) ---------------------------------------------------
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MLP / misc ----------------------------------------------------------
    mlp_kind: str = "swiglu"  # swiglu | relu2
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    norm_eps: float = 1e-5

    # --- VLM (Qwen2-VL): M-RoPE sections over head_dim/2 ---------------------
    m_rope_sections: tuple[int, ...] | None = None
    num_vision_tokens: int = 0  # stub patch embeddings prepended to the seq

    # --- hybrid (RecurrentGemma) / ssm (RWKV6) -------------------------------
    block_pattern: tuple[str, ...] | None = None  # e.g. ("rec","rec","attn")
    local_window: int = 2048
    rglru_conv_width: int = 4
    lru_width: int = 0  # 0 -> d_model
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 64
    ssm_chunk: int = 64  # chunked-parallel scan length

    # --- enc-dec (Whisper) ----------------------------------------------------
    encoder_layers: int = 0
    encoder_frames: int = 0  # stub conv frontend output length
    cross_attention: bool = False
    learned_positions: bool = False
    max_position: int = 0  # learned-positional table size (0 -> unused)

    # --- attention implementation ---------------------------------------------
    attention_kind: str = "softmax"  # softmax | lattice (beyond-paper)
    lattice_qk_dim: int = 4  # projected q/k dim for lattice attention
    lattice_cap_factor: float = 1.0  # lattice capacity vs n(d+1) worst case
    sliding_window: int = 0  # 0 = full attention

    # --- numerics ---------------------------------------------------------------
    dtype: Any = jnp.bfloat16  # activation/param dtype for dry-run/TPU
    vocab_pad_multiple: int = 256  # Megatron-style vocab padding for TP
    remat: bool = True
    # "full": recompute the whole layer in backward (min memory);
    # "dots": save matmul outputs (jax dots_saveable policy) — kills the
    # remat recompute FLOPs at ~linear activation-memory cost (§Perf L2)
    remat_policy: str = "full"
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_recurrent(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def num_params(self) -> int:
        """Approximate parameter count (documented per arch in configs/)."""
        d, v = self.d_model, self.padded_vocab
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":  # rwkv6
            per_layer = d * d * 4 + d * self.d_ff * 2 + d * d  # rkvg+out+cmix
        else:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            if self.mla:
                q = (d * self.q_lora_rank + self.q_lora_rank
                     * self.num_heads * (self.qk_nope_head_dim
                                         + self.qk_rope_head_dim))
                kv = (d * (self.kv_lora_rank + self.qk_rope_head_dim)
                      + self.kv_lora_rank * self.num_heads
                      * (self.qk_nope_head_dim + self.v_head_dim))
                o = self.num_heads * self.v_head_dim * d
            per_layer = q + kv + o
            if self.moe:
                ff = 3 * d * self.moe_d_ff
                per_layer += (self.num_experts + self.num_shared_experts) * ff
                per_layer += d * self.num_experts  # router
            else:
                mult = 3 if self.mlp_kind == "swiglu" else 2
                per_layer += mult * d * self.d_ff
        return emb + self.num_layers * per_layer


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
