"""Mixture-of-Experts block with capacity-based sort dispatch (EP-ready).

Dispatch is the static-shape "dropping" formulation (MaxText-style):
tokens' top-k expert choices are sorted by expert id, each expert keeps at
most C = ceil(T*k/E * capacity_factor) slots, overflow tokens are dropped
(contributing zero — their residual path still carries them). The expert
FFN is a single batched einsum over the expert axis, which partition.py
shards over the "model" mesh axis — the all-to-all pattern GSPMD derives
from scatter(gather) across the (tokens->slots) permutation is exactly the
expert-parallel dispatch collective.

Router aux loss is the standard Switch load-balance term.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.models.config import ModelConfig

Array = jax.Array


def moe_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.moe_d_ff
    e = cfg.num_experts
    ks = jax.random.split(key, 5)
    params = {
        "router": nn.dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "wi_gate": nn.dense_init(ks[1], (e, d, f), dtype),
        "wi_up": nn.dense_init(ks[2], (e, d, f), dtype),
        "wo": nn.dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        params["shared"] = nn.mlp_init(ks[4], d, fs, "swiglu", dtype)
    return params


class MoEOut(NamedTuple):
    y: Array
    aux_loss: Array


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(tokens * cfg.moe_top_k / cfg.num_experts * cfg.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)  # 8-aligned for TPU tiling


def _route_indices(router: Array, xg: Array, cfg: ModelConfig, c: int):
    """Routing plan for ONE token group (= one sequence). xg: (t, d).

    Returns GATHER indices only — the (tokens x d) data path never goes
    through a scatter. GSPMD's scatter partitioning falls back to
    replicate-and-masked-all-reduce (measured 100s of GiB/step on the MoE
    dry-run cells); batched gathers partition cleanly. The only scatters
    left are on (t*k,) int32 index vectors — kilobytes.
    """
    t, d = xg.shape
    e, k = cfg.num_experts, cfg.moe_top_k

    logits = xg.astype(jnp.float32) @ router  # (t, e)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (t, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # Switch aux-loss statistics (combined across groups by the caller)
    fexp = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (t * k))
    pexp = jnp.mean(probs, axis=0)

    flat_e = top_e.reshape(-1)  # (t*k,)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    stok = flat_t[order]
    idx = jnp.arange(t * k, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    group_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    pos_in_e = idx - group_start
    keep = pos_in_e < c
    slot_sorted = jnp.where(keep, se * c + pos_in_e, e * c)

    # tiny int32 scatters: slot per (token, choice) and token per slot
    slot_tk = jnp.zeros((t * k,), jnp.int32).at[order].set(slot_sorted)
    token_of_slot = jnp.full((e * c + 1,), t, jnp.int32).at[
        slot_sorted].set(stok, mode="drop")
    return (slot_tk.reshape(t, k), token_of_slot[: e * c],
            top_p.astype(xg.dtype), fexp, pexp)


def _dispatch_local(router: Array, x: Array, cfg: ModelConfig, c: int):
    """Routing + dispatch gather on LOCAL batch rows. x: (b_loc, s, d)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    slot_tk, token_of_slot, top_p, fexp, pexp = jax.vmap(
        lambda xg: _route_indices(router, xg, cfg, c))(x)
    xpad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    buf = jnp.take_along_axis(
        xpad, token_of_slot[:, :, None], axis=1,
        mode="clip").reshape(b, e, c, d)
    return buf, slot_tk, top_p, fexp, pexp


def _combine_local(out_e: Array, slot_tk: Array, top_p: Array,
                   cfg: ModelConfig):
    """Weighted combine gather on LOCAL rows. out_e: (b_loc, e, c, d)."""
    b, e, c, d = out_e.shape
    s, k = slot_tk.shape[1], slot_tk.shape[2]
    out_pad = jnp.concatenate(
        [out_e.reshape(b, e * c, d),
         jnp.zeros((b, 1, d), out_e.dtype)], axis=1)
    picked = jnp.take_along_axis(
        out_pad, slot_tk.reshape(b, s * k)[:, :, None],
        axis=1, mode="clip").reshape(b, s, k, d)
    return jnp.einsum("bskd,bsk->bsd", picked, top_p)


def _expert_ffn(params: dict, buf: Array) -> Array:
    """(b, e, c, d) -> (b, e, c, d); e sharded (EP), contractions TP."""
    gate = jax.nn.silu(jnp.einsum("becd,edf->becf", buf,
                                  params["wi_gate"]))
    up = jnp.einsum("becd,edf->becf", buf, params["wi_up"])
    return jnp.einsum("becf,efd->becd", gate * up, params["wo"])


def moe_apply(params: dict, x: Array, cfg: ModelConfig) -> MoEOut:
    """x: (b, s, d) -> same; per-sequence top-k capacity routing.

    Data path: dispatch gather -> expert einsum -> combine gather. The
    gathers (and their backward scatter-adds) run inside a shard_map over
    the DP axes, because GSPMD's fallback for batched scatters is
    replicate-and-mask — measured at 100+ GiB/step on the 236B cells.
    Inside the manual region everything is local; the expert einsum stays
    in auto (GSPMD) land, so the buf reshard between batch-sharded and
    expert-sharded layouts is the EP all-to-all.
    """
    from repro.sharding import constraints as cst
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    c = capacity(cfg, s)
    mesh, _ = cst._current()

    dp = None
    if mesh is not None:
        dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        if b % dp_size != 0:
            dp = None  # batch not shardable (long-context cells)

    if dp is None:
        buf, slot_tk, top_p, fexp, pexp = _dispatch_local(
            params["router"], x, cfg, c)
        out_e = _expert_ffn(params, buf)
        y = _combine_local(out_e, slot_tk, top_p, cfg)
    else:
        from jax.sharding import PartitionSpec as P
        manual = frozenset(dp)  # "model" stays auto (GSPMD) inside
        mdl = "model" if d % mesh.shape["model"] == 0 else None
        disp = jax.shard_map(
            lambda r, xx: _dispatch_local(r, xx, cfg, c),
            mesh=mesh, in_specs=(P(), P(dp)),
            out_specs=(P(dp), P(dp), P(dp), P(dp), P(dp)),
            axis_names=manual, check_vma=False)
        buf, slot_tk, top_p, fexp, pexp = disp(params["router"], x)
        # Reshard the dispatch buffer into the EXPERT layout (e over
        # "data", d over "model") — this is the EP all-to-all. Without
        # it GSPMD all-gathers the expert weights per layer instead
        # (7.5 GiB/layer on the 236B config).
        edata = "data" if e % mesh.shape["data"] == 0 else None
        buf = jax.lax.with_sharding_constraint(
            buf, jax.NamedSharding(mesh, P(None, edata, None, mdl)))
        out_e = _expert_ffn(params, buf)
        out_e = jax.lax.with_sharding_constraint(
            out_e, jax.NamedSharding(mesh, P(None, edata, None, mdl)))
        comb = jax.shard_map(
            lambda o, sl, tp: _combine_local(o, sl, tp, cfg),
            mesh=mesh, in_specs=(P(dp), P(dp), P(dp)),
            out_specs=P(dp), axis_names=manual, check_vma=False)
        y = comb(out_e, slot_tk, top_p)
        fexp = fexp.reshape(-1, e)
        pexp = pexp.reshape(-1, e)

    aux = (e * jnp.sum(jnp.mean(fexp.reshape(-1, e), 0)
                       * jnp.mean(pexp.reshape(-1, e), 0))
           * cfg.router_aux_coef)
    y = y.astype(x.dtype)
    if cfg.num_shared_experts:
        y = y + nn.mlp_apply(params["shared"], x.reshape(b * s, d),
                             "swiglu").reshape(b, s, d)
    return MoEOut(y=y, aux_loss=aux)
