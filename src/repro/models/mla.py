"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Faithful structure: queries go through a LoRA bottleneck (q_lora_rank);
keys/values share one compressed latent c_kv (kv_lora_rank) plus a single
shared RoPE key head (qk_rope_head_dim). Per head, q/k split into a no-RoPE
part (qk_nope_head_dim, up-projected from the latent) and the RoPE part.

The decode cache stores ONLY (c_kv, k_rope): (kv_lora + rope_dim) floats
per token — 576 for the assigned config vs 2*128*128 for vanilla MHA; this
compression is the architecture's entire point and what makes the
decode_32k dry-run cell fit memory.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.sharding.constraints import constrain

Array = jax.Array


def mla_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    qk = cfg.qk_nope_head_dim
    qr = cfg.qk_rope_head_dim
    vh = cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_a": nn.dense_init(ks[0], (d, cfg.q_lora_rank), dtype),
        "q_norm": jnp.zeros((cfg.q_lora_rank,), dtype),
        "wq_b": nn.dense_init(ks[1], (cfg.q_lora_rank, h * (qk + qr)),
                              dtype),
        "wkv_a": nn.dense_init(ks[2], (d, cfg.kv_lora_rank + qr), dtype),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), dtype),
        "wk_b": nn.dense_init(ks[3], (cfg.kv_lora_rank, h * qk), dtype),
        "wv_b": nn.dense_init(ks[4], (cfg.kv_lora_rank, h * vh), dtype),
        "wo": nn.dense_init(ks[5], (h * vh, d), dtype),
    }


class MLACache(NamedTuple):
    c_kv: Array  # (b, S, kv_lora_rank)
    k_rope: Array  # (b, S, qk_rope_head_dim)


def init_mla_cache(cfg: ModelConfig, batch: int, seq_len: int,
                   dtype=None) -> MLACache:
    dt = dtype or cfg.dtype
    return MLACache(
        c_kv=jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dt),
        k_rope=jnp.zeros((batch, seq_len, cfg.qk_rope_head_dim), dt),
    )


def _project_q(params, x, cfg, positions):
    b, s, _ = x.shape
    h, qk, qr = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = nn.rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = (cq @ params["wq_b"]).reshape(b, s, h, qk + qr)
    q_nope, q_rope = q[..., :qk], q[..., qk:]
    q_rope = nn.apply_rope(q_rope.transpose(0, 2, 1, 3), positions,
                           cfg.rope_theta).transpose(0, 2, 1, 3)
    return q_nope, q_rope  # (b, s, h, *)


def _latents(params, x, cfg, positions):
    ckv_kr = x @ params["wkv_a"]  # (b, s, lora + qr)
    c_kv = nn.rms_norm(ckv_kr[..., :cfg.kv_lora_rank], params["kv_norm"],
                       cfg.norm_eps)
    k_rope = ckv_kr[..., cfg.kv_lora_rank:]  # single shared rope head
    k_rope = nn.apply_rope(k_rope[:, None], positions,
                           cfg.rope_theta)[:, 0]
    return c_kv, k_rope


def mla_attention(params: dict, x: Array, positions: Array,
                  cfg: ModelConfig) -> Array:
    """Training/prefill MLA (full causal)."""
    b, s, d = x.shape
    h, qk, qr, vh = (cfg.num_heads, cfg.qk_nope_head_dim,
                     cfg.qk_rope_head_dim, cfg.v_head_dim)
    q_nope, q_rope = _project_q(params, x, cfg, positions)
    c_kv, k_rope = _latents(params, x, cfg, positions)
    k_nope = (c_kv @ params["wk_b"]).reshape(b, s, h, qk)
    v = (c_kv @ params["wv_b"]).reshape(b, s, h, vh)

    # assemble full q/k (nope ++ rope, rope shared across heads) and run
    # the blockwise flash path — never materializes the (s, s) logits
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)  # (b,s,h,qk+qr)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, qr))], axis=-1)
    q_sh = constrain(q_full.transpose(0, 2, 1, 3),
                     "batch", "model", None, None)
    k_sh = constrain(k_full.transpose(0, 2, 1, 3),
                     "batch", "model", None, None)
    v_sh = constrain(v.transpose(0, 2, 1, 3),
                     "batch", "model", None, None)
    out = flash_attention(q_sh, k_sh, v_sh, causal=True)
    out = out.transpose(0, 2, 1, 3).astype(x.dtype)
    return out.reshape(b, s, h * vh) @ params["wo"]


def mla_decode(params: dict, x: Array, cache: MLACache, position: Array,
               cfg: ModelConfig) -> tuple[Array, MLACache]:
    """One-token decode against the latent cache.

    Uses the absorbed-matmul trick: q_nope is pushed through wk_b^T once
    (q_latent = q_nope @ wk_b per head) so attention scores are computed
    directly against the cached c_kv — no per-step K up-projection.
    """
    b, _, d = x.shape
    h, qk, qr, vh = (cfg.num_heads, cfg.qk_nope_head_dim,
                     cfg.qk_rope_head_dim, cfg.v_head_dim)
    r = cfg.kv_lora_rank
    q_nope, q_rope = _project_q(params, x, cfg, position[:, None])
    c_new, kr_new = _latents(params, x, cfg, position[:, None])

    bidx = jnp.arange(b)
    c_kv = cache.c_kv.at[bidx, position].set(
        c_new[:, 0].astype(cache.c_kv.dtype))
    k_rope = cache.k_rope.at[bidx, position].set(
        kr_new[:, 0].astype(cache.k_rope.dtype))

    wk_b = params["wk_b"].reshape(r, h, qk)
    # absorb: q_lat[b,h,r] = sum_d q_nope[b,h,d] wk_b[r,h,d]
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    scale = (qk + qr) ** -0.5
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat,
                         c_kv.astype(jnp.float32))
              + jnp.einsum("bhd,bsd->bhs",
                           q_rope[:, 0].astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
    valid = jnp.arange(c_kv.shape[1])[None, :] <= position[:, None]
    logits = jnp.where(valid[:, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    # attend in latent space then up-project once: out_lat (b, h, r)
    out_lat = jnp.einsum("bhs,bsr->bhr", p, c_kv.astype(jnp.float32))
    wv_b = params["wv_b"].reshape(r, h, vh)
    out = jnp.einsum("bhr,rhd->bhd", out_lat, wv_b.astype(jnp.float32))
    out = out.reshape(b, 1, h * vh).astype(x.dtype)
    return out @ params["wo"], MLACache(c_kv=c_kv, k_rope=k_rope)
