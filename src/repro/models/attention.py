"""Attention blocks: GQA softmax (full / sliding-window) with KV caching.

Training/prefill use the flash-attention op (Pallas on TPU, XLA ref on CPU).
Sliding-window attention is computed *blocked* — queries in window-sized
blocks attend to (previous, self) key blocks only — so FLOPs are O(s·w),
not O(s²) masked, which is what makes recurrentgemma's local layers honest
in the roofline accounting.

Decode keeps either a full KV cache (b, hkv, S, hd) or, for windowed
layers, a rolling cache of the last `window` positions.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.sharding.constraints import constrain

Array = jax.Array


def attn_init(key: Array, cfg: ModelConfig, dtype,
              d_model: int | None = None) -> dict:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": nn.dense_init(ks[0], (d, cfg.num_heads * hd), dtype),
        "wk": nn.dense_init(ks[1], (d, cfg.num_kv_heads * hd), dtype),
        "wv": nn.dense_init(ks[2], (d, cfg.num_kv_heads * hd), dtype),
        "wo": nn.dense_init(ks[3], (cfg.num_heads * hd, d), dtype),
    }


class KVCache(NamedTuple):
    k: Array  # (b, hkv, S, hd)   (S = window size for windowed layers)
    v: Array  # (b, hkv, S, hd)
    pos: Array  # (b, S) int32 absolute positions (-1 = empty), windowed only


def init_kv_cache(cfg: ModelConfig, batch: int, seq_len: int,
                  *, window: int = 0, dtype=None) -> KVCache:
    hd = cfg.resolved_head_dim
    s = window or seq_len
    dt = dtype or cfg.dtype
    return KVCache(
        k=jnp.zeros((batch, cfg.num_kv_heads, s, hd), dt),
        v=jnp.zeros((batch, cfg.num_kv_heads, s, hd), dt),
        pos=jnp.full((batch, s), -1, jnp.int32),
    )


def _qkv(params: dict, x: Array, cfg: ModelConfig):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = (x @ params["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (x @ params["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    # -> (b, h, s, hd); heads sharded over TP when they divide
    q = constrain(q.transpose(0, 2, 1, 3), "batch", "model", None, None)
    k = constrain(k.transpose(0, 2, 1, 3), "batch", "model", None, None)
    v = constrain(v.transpose(0, 2, 1, 3), "batch", "model", None, None)
    return q, k, v


def _rope(cfg: ModelConfig, q, k, positions, positions_3d=None):
    if cfg.m_rope_sections is not None and positions_3d is not None:
        q = nn.apply_m_rope(q, positions_3d, cfg.m_rope_sections,
                            cfg.rope_theta)
        k = nn.apply_m_rope(k, positions_3d, cfg.m_rope_sections,
                            cfg.rope_theta)
    else:
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k = nn.apply_rope(k, positions, cfg.rope_theta)
    return q, k


def full_attention(params: dict, x: Array, positions: Array,
                   cfg: ModelConfig, *, positions_3d=None,
                   causal: bool = True) -> Array:
    """Training / prefill path, full causal attention."""
    b, s, d = x.shape
    q, k, v = _qkv(params, x, cfg)
    q, k = _rope(cfg, q, k, positions, positions_3d)
    out = flash_attention(q, k, v, causal=causal)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out @ params["wo"]


def windowed_attention(params: dict, x: Array, positions: Array,
                       cfg: ModelConfig, window: int) -> Array:
    """Blocked sliding-window attention, O(s·w) exact.

    Queries in block i attend keys in blocks (i-1, i) with the causal +
    age < window mask. Requires s % window == 0 (models pad internally).
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = _qkv(params, x, cfg)
    q, k = _rope(cfg, q, k, positions)
    pad = (-s) % window
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nb = sp // window
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    group = hq // hkv

    qb = q.reshape(b, hq, nb, window, hd)
    kb = k.reshape(b, hkv, nb, window, hd)
    vb = v.reshape(b, hkv, nb, window, hd)
    # keys for block i = concat(block i-1, block i)
    k_prev = jnp.concatenate(
        [jnp.zeros_like(kb[:, :, :1]), kb[:, :, :-1]], axis=2)
    v_prev = jnp.concatenate(
        [jnp.zeros_like(vb[:, :, :1]), vb[:, :, :-1]], axis=2)
    k2 = jnp.concatenate([k_prev, kb], axis=3)  # (b,hkv,nb,2w,hd)
    v2 = jnp.concatenate([v_prev, vb], axis=3)

    qg = qb.reshape(b, hkv, group, nb, window, hd).astype(jnp.float32)
    logits = jnp.einsum("bhgnqd,bhnkd->bhgnqk", qg,
                        k2.astype(jnp.float32)) * (hd ** -0.5)
    qpos = jnp.arange(window)[:, None] + window  # position inside 2w axis
    kpos = jnp.arange(2 * window)[None, :]
    age = qpos - kpos
    mask = (age >= 0) & (age < window)
    first = jnp.arange(nb) == 0  # block 0 has no previous block
    mask_nb = mask[None, :, :] & ((~first[:, None, None])
                                  | (kpos[None] >= window))
    logits = jnp.where(mask_nb[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgnqk,bhnkd->bhgnqd", p, v2.astype(jnp.float32))
    out = out.reshape(b, hq, sp, hd)[:, :, :s].astype(x.dtype)
    return out.transpose(0, 2, 1, 3).reshape(b, s, -1) @ params["wo"]


def decode_attention(params: dict, x: Array, cache: KVCache,
                     position: Array, cfg: ModelConfig, *,
                     window: int = 0,
                     use_rope: bool = True) -> tuple[Array, KVCache]:
    """One-token decode. x: (b, 1, d); position: (b,) int32 absolute.

    Full caches write at `position`; rolling (windowed) caches write at
    ``position % window`` and mask by age via stored absolute positions.
    ``use_rope=False`` for additive-positional models (Whisper).
    """
    b, _, d = x.shape
    hd = cfg.resolved_head_dim
    q, k_new, v_new = _qkv(params, x, cfg)  # (b, h, 1, hd)
    if use_rope:
        q = nn.apply_rope(q, position[:, None], cfg.rope_theta)
        k_new = nn.apply_rope(k_new, position[:, None], cfg.rope_theta)

    s_cache = cache.k.shape[2]
    slot = position % window if window else position
    bidx = jnp.arange(b)
    k = cache.k.at[bidx, :, slot].set(k_new[:, :, 0].astype(cache.k.dtype))
    v = cache.v.at[bidx, :, slot].set(v_new[:, :, 0].astype(cache.v.dtype))
    pos = cache.pos.at[bidx, slot].set(position)

    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    group = hq // hkv
    qg = q.reshape(b, hkv, group, hd).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhsd->bhgs", qg,
                        k.astype(jnp.float32)) * (hd ** -0.5)
    age = position[:, None] - pos  # (b, s_cache)
    valid = (pos >= 0) & (age >= 0)
    if window:
        valid = valid & (age < window)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    out = out.reshape(b, 1, hq * hd).astype(x.dtype)
    return out @ params["wo"], KVCache(k=k, v=v, pos=pos)
