"""RecurrentGemma / Griffin blocks (arXiv:2402.19427): RG-LRU + local attn.

Recurrent block: x -> { silu(W_gate x) } * { conv1d_4(W_in x) -> RG-LRU }
-> W_out. The RG-LRU is a *diagonal* gated linear recurrence

    r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)            (c = 8)
    h_t = a_t . h_{t-1} + sqrt(1 - a_t^2) . (i_t . x_t)

computed with ``jax.lax.associative_scan`` over (a, b) pairs — O(log s)
depth, fully parallel, the TPU-native replacement for the paper's fused
GPU scan kernel. Decode is the O(1) recurrence plus a width-4 conv state.

Block pattern is (rec, rec, attn) repeating — attention is local MQA
(window 2048, kv_heads = 1) via models/attention.py's blocked form.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.models.config import ModelConfig

Array = jax.Array

_LRU_C = 8.0


def rglru_block_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        "w_gate": nn.dense_init(ks[0], (d, w), dtype),
        "w_in": nn.dense_init(ks[1], (d, w), dtype),
        "conv_w": nn.dense_init(ks[2], (cfg.rglru_conv_width, w), dtype,
                                scale=0.1),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": nn.dense_init(ks[3], (w, w), dtype),
        "w_x": nn.dense_init(ks[4], (w, w), dtype),
        # Lambda init so a^c in [0.9, 0.999] at r=1 (paper's init range)
        "lam": jnp.linspace(2.0, 5.5, w).astype(dtype),
        "w_out": nn.dense_init(ks[5], (w, d), dtype),
    }


class RecState(NamedTuple):
    h: Array  # (b, w) RG-LRU hidden
    conv: Array  # (b, conv_width - 1, w) trailing conv inputs


def init_rec_state(cfg: ModelConfig, batch: int,
                   dtype=jnp.float32) -> RecState:
    w = cfg.lru_width or cfg.d_model
    return RecState(
        h=jnp.zeros((batch, w), jnp.float32),
        conv=jnp.zeros((batch, cfg.rglru_conv_width - 1, w), dtype),
    )


def _causal_conv(params: dict, x: Array, prev: Array) -> Array:
    """Depthwise causal conv, width cw. x: (b, s, w); prev: (b, cw-1, w)."""
    cw = params["conv_w"].shape[0]
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * params["conv_w"][i]
              for i in range(cw))
    return out + params["conv_b"]


def _lru_gates(params: dict, u: Array):
    r = jax.nn.sigmoid((u @ params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ params["w_x"]).astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(
        params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * (i * u.astype(jnp.float32))
    return a, gated


def rglru_scan(params: dict, u: Array, h0: Array) -> tuple[Array, Array]:
    """Parallel linear recurrence over the sequence. u: (b, s, w)."""
    a, b = _lru_gates(params, u)  # (b, s, w) each
    # fold the initial state into the first step: h_1 = a_1 h0 + b_1
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def recurrent_block(params: dict, x: Array, state: RecState,
                    cfg: ModelConfig, *, decode: bool
                    ) -> tuple[Array, RecState]:
    """x: (b, s, d) (s=1 for decode). Returns (out, new_state)."""
    gate = jax.nn.silu(x @ params["w_gate"])
    u = x @ params["w_in"]
    cw = cfg.rglru_conv_width
    if decode:
        conv_in = jnp.concatenate([state.conv, u], axis=1)
        u = _causal_conv(params, u, state.conv)
        a, b = _lru_gates(params, u[:, 0])
        h_last = a * state.h + b
        h = h_last[:, None]
        new_conv = conv_in[:, -(cw - 1):]
    else:
        conv_in = u
        u = _causal_conv(params, u, state.conv.astype(u.dtype))
        h, h_last = rglru_scan(params, u, state.h)
        new_conv = conv_in[:, -(cw - 1):]
    out = (h.astype(x.dtype) * gate) @ params["w_out"]
    return out, RecState(h=h_last, conv=new_conv.astype(state.conv.dtype))
