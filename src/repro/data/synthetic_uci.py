"""Synthetic stand-ins for the paper's UCI regression datasets (§5.3).

The container is offline, so the five UCI sets are replaced by generators
matched on (n, d) and on the *geometry* that drives the paper's results:
the lattice sparsity ratio m/L (Table 3) is controlled by how clustered the
inputs are, so each generator plants a cluster/manifold structure tuned to
land near the published ratio. Targets are a smooth random function
(random-feature GP sample) plus noise, standardized like the paper
(train-fit z-scoring, 4/9-2/9-3/9 split).

Benchmarks therefore reproduce the paper's *relationships* (sparsity <<1,
Simplex-GP ~ Exact >> SKIP, speedups growing with n) rather than the
published decimal values; see DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

# name -> (n, d, n_clusters per unit volume proxy, cluster spread)
# spread tuned so m/L (Table 3) is qualitatively matched:
#   precipitation 0.003 (grid-like), protein 0.03, houseelectric 0.04,
#   keggdirected 0.12, elevators 0.69.
SPECS: dict[str, dict] = {
    "houseelectric": dict(n=2_049_280, d=11, structure="clustered",
                          clusters=64, spread=0.05, table3_m=1_000_190),
    "precipitation": dict(n=628_474, d=3, structure="grid",
                          grid=8, jitter=0.02, table3_m=480),
    "keggdirected": dict(n=48_827, d=20, structure="clustered",
                         clusters=256, spread=0.045, table3_m=122_755),
    "protein": dict(n=45_730, d=9, structure="clustered",
                    clusters=48, spread=0.08, table3_m=14_715),
    "elevators": dict(n=16_599, d=17, structure="lowrank",
                      intrinsic=6, noise=0.12, table3_m=204_761),
}


class Dataset(NamedTuple):
    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    x_val: np.ndarray
    y_val: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n(self) -> int:
        return self.x_train.shape[0]

    @property
    def d(self) -> int:
        return self.x_train.shape[1]


def _inputs(rng: np.random.Generator, n: int, d: int, spec: dict) -> np.ndarray:
    kind = spec["structure"]
    if kind == "grid":
        # lat/lon/time-like gridded data -> extremely sparse lattice
        g = spec["grid"]
        cells = rng.integers(0, g, size=(n, d)).astype(np.float64)
        return cells / g + spec["jitter"] * rng.normal(size=(n, d))
    if kind == "clustered":
        k = spec["clusters"]
        centers = rng.normal(size=(k, d))
        assign = rng.integers(0, k, size=n)
        return centers[assign] + spec["spread"] * rng.normal(size=(n, d))
    # "lowrank": sensor-style data on a low-dim manifold in ambient d
    # (real elevators has correlated dims; m/L = 0.69 needs SOME vertex
    # sharing, which i.i.d. 17-D points never produce)
    z = rng.standard_t(df=4, size=(n, spec["intrinsic"]))
    mix = rng.normal(size=(spec["intrinsic"], d))
    return z @ mix + spec["noise"] * rng.normal(size=(n, d))


def _targets(rng: np.random.Generator, x: np.ndarray,
             num_features: int = 256, noise: float = 0.1) -> np.ndarray:
    """Sample from an RBF random-feature GP prior: smooth ground truth."""
    n, d = x.shape
    w = rng.normal(size=(d, num_features))
    b = rng.uniform(0, 2 * np.pi, size=num_features)
    amp = rng.normal(size=num_features) / np.sqrt(num_features)
    f = np.cos(x @ w + b) @ amp
    return f + noise * rng.normal(size=n)


def load(name: str, *, scale: float = 1.0, seed: int = 0) -> Dataset:
    """Generate the named dataset. ``scale`` subsamples n for CPU benches."""
    spec = SPECS[name]
    n = max(int(spec["n"] * scale), 64)
    d = spec["d"]
    rng = np.random.default_rng(seed + hash(name) % (2 ** 31))
    x = _inputs(rng, n, d, spec)
    y = _targets(rng, x)

    perm = rng.permutation(n)
    x, y = x[perm], y[perm]
    n_train = (4 * n) // 9
    n_val = (2 * n) // 9
    sl_train = slice(0, n_train)
    sl_val = slice(n_train, n_train + n_val)
    sl_test = slice(n_train + n_val, None)

    # standardize with train statistics (paper §5.3)
    mu_x, sd_x = x[sl_train].mean(0), x[sl_train].std(0) + 1e-8
    mu_y, sd_y = y[sl_train].mean(), y[sl_train].std() + 1e-8
    xs = (x - mu_x) / sd_x
    ys = (y - mu_y) / sd_y
    f32 = lambda a: np.ascontiguousarray(a, np.float32)
    return Dataset(name=name,
                   x_train=f32(xs[sl_train]), y_train=f32(ys[sl_train]),
                   x_val=f32(xs[sl_val]), y_val=f32(ys[sl_val]),
                   x_test=f32(xs[sl_test]), y_test=f32(ys[sl_test]))


def all_names() -> list[str]:
    return list(SPECS)
