"""Host data pipeline: background prefetch + deterministic resume.

A thin, dependency-free analogue of the tf.data/grain input pipelines the
big frameworks use:

  * ``Prefetcher`` — a daemon thread keeps ``depth`` batches ahead of the
    training loop so host data generation overlaps device compute.
  * step-indexed determinism — the underlying sources (data/tokens.py,
    data/synthetic_uci.py) are pure functions of the step, so resuming
    from a checkpoint is just "start at step k"; no iterator state files.
  * ``skip_steps`` — the straggler-mitigation hook (runtime/straggler.py)
    can ask the pipeline to skip a step on all hosts deterministically.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

Batch = dict


class Prefetcher:
    def __init__(self, make_batch: Callable[[int], Batch], start_step: int,
                 depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = start_step
        self._skips: set[int] = set()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            with self._lock:
                step = self._next
                if step in self._skips:
                    self._skips.discard(step)
                    self._next += 1
                    continue
                self._next += 1
            try:
                batch = self._make(step)
            except Exception as e:  # surface in consumer thread
                self._q.put((step, e))
                return
            self._q.put((step, batch))

    def skip(self, step: int):
        """Deterministically drop `step` (straggler recovery)."""
        with self._lock:
            self._skips.add(step)

    def __iter__(self) -> Iterator[tuple[int, Batch]]:
        return self

    def __next__(self) -> tuple[int, Batch]:
        while True:
            step, item = self._q.get()
            if isinstance(item, Exception):
                raise item
            with self._lock:
                if step in self._skips:  # was already prefetched when skipped
                    self._skips.discard(step)
                    continue
            return step, item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
