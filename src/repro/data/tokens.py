"""Deterministic synthetic token stream for the LM architectures.

Stateless-indexable: token value is a pure function of (stream seed,
sequence id, position), so any host can materialize exactly its own data
shard for any step without coordination — the property that makes
deterministic restart/elastic-resharding trivial (runtime/elastic.py).

A light Zipf-ish skew is layered on top of a counter-mode hash so the
batches are not uniform noise (MoE routing then exercises imbalanced
paths, like real text would).
"""
from __future__ import annotations

import dataclasses

import numpy as np

_MUL = np.uint64(6364136223846793005)
_INC = np.uint64(1442695040888963407)


def _hash64(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64)
    x = (x * _MUL + _INC)
    x ^= x >> np.uint64(33)
    x = x * np.uint64(0xFF51AFD7ED558CCD)
    x ^= x >> np.uint64(33)
    return x


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1

    def seq_ids(self, step: int) -> np.ndarray:
        """Global sequence ids consumed at `step` (deterministic order)."""
        start = step * self.global_batch
        return np.arange(start, start + self.global_batch, dtype=np.int64)

    def batch(self, step: int, *, shard: int = 0,
              num_shards: int = 1) -> dict[str, np.ndarray]:
        """Materialize this host's shard of the step's global batch.

        Returns tokens (b_local, seq) and next-token labels (b_local, seq).
        """
        ids = self.seq_ids(step)
        local = ids[shard::num_shards] if num_shards > 1 else ids
        b = local.shape[0]
        pos = np.arange(self.seq_len + 1, dtype=np.int64)
        key = (local[:, None] << np.int64(20)) + pos[None, :] \
            + np.int64(self.seed) * np.int64(1_000_003)
        u = (_hash64(key) >> np.uint64(11)).astype(np.float64) / float(2 ** 53)
        # inverse-CDF of a truncated zipf: rank ~ u^(-1/(alpha-1)) style skew
        ranks = np.floor(
            (self.vocab_size ** (1.0 - u)) - 1.0).astype(np.int64)
        toks = np.clip(ranks, 0, self.vocab_size - 1)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
