from repro.data import pipeline, synthetic_uci, tokens

__all__ = ["pipeline", "synthetic_uci", "tokens"]
