"""Elastic restart: resume a run on a different mesh shape.

Checkpoints are logical (runtime/checkpoint.py), so elasticity reduces to:

  1. pick the new mesh from the devices that are actually healthy,
  2. rebuild partition specs for that mesh,
  3. restore + re-shard (device_put against the new NamedShardings),
  4. resume the data pipeline at the saved step (sources are pure
     functions of the step — data/tokens.py — so no iterator state).

``choose_mesh_shape`` implements the policy: keep the model axis as large
as TP requires, fold every remaining healthy device into the data axis —
shrinking DP changes only throughput, never correctness, because the
global batch is re-sharded (gradient accumulation covers the remainder).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models.config import ModelConfig
from repro.runtime.checkpoint import CheckpointCorruptError, CheckpointManager
from repro.sharding import partition


@dataclasses.dataclass
class ElasticPlan:
    mesh: Mesh
    dp_size: int
    accum_steps: int  # gradient-accumulation factor to keep global batch


def choose_mesh_shape(num_devices: int, *, model_parallel: int,
                      global_batch: int, prev_dp: int,
                      allow_uneven: bool = False) -> tuple[int, int]:
    """(data, accum): largest dp <= devices/model that divides batch.

    ``allow_uneven=True`` drops the divisibility walk and takes every
    healthy device: consumers whose sharded kernels pad uneven rows
    (the GP lattice MVM's ghost padding, sharding/simplex.py) don't need
    the batch to divide the data axis, so shrinking 8 -> 5 devices keeps
    all 5 instead of falling back to 4.
    """
    assert num_devices % model_parallel == 0, (num_devices, model_parallel)
    dp = num_devices // model_parallel
    if not allow_uneven:
        while dp > 1 and global_batch % dp != 0:
            dp -= 1
    accum = max(1, prev_dp // dp)
    return dp, accum


def make_elastic_mesh(devices, *, model_parallel: int) -> Mesh:
    devices = np.asarray(devices)
    dp = devices.size // model_parallel
    grid = devices[: dp * model_parallel].reshape(dp, model_parallel)
    return Mesh(grid, ("data", "model"))


def resume(cfg: ModelConfig, manager: CheckpointManager, template: Any,
           devices=None, *, model_parallel: int = 16,
           global_batch: int = 256) -> tuple[Any, int, ElasticPlan]:
    """Restore the latest checkpoint onto whatever devices remain."""
    devices = list(devices if devices is not None else jax.devices())
    mesh = make_elastic_mesh(devices, model_parallel=min(
        model_parallel, len(devices)))
    dp = mesh.shape["data"]
    specs = partition.param_specs(cfg, mesh, template)
    shardings = partition.named(mesh, specs)
    # generation-by-generation fallback: a node that died mid-write (or a
    # bit-flipped blob) costs one checkpoint, not the restart — the newest
    # generation that passes the integrity verify wins (DESIGN.md §14).
    step = manager.latest_valid_step()
    if step is None:
        raise FileNotFoundError("no valid checkpoint to resume from")
    try:
        tree = manager.restore(step, template, shardings)
    except CheckpointCorruptError as e:  # pragma: no cover - verify raced
        raise FileNotFoundError(
            f"checkpoint step {step} corrupted between verify and restore: "
            f"{e}") from e
    plan = ElasticPlan(mesh=mesh, dp_size=dp,
                       accum_steps=max(1, global_batch // max(dp, 1)
                                       // max(global_batch // dp, 1)))
    return tree, step, plan


# -- GP trainer elasticity (DESIGN.md §16) ----------------------------------

def gp_mesh(devices=None) -> Mesh:
    """1-D ``("data",)`` mesh over whatever devices remain.

    The GP trainer has no model axis: every healthy device joins the data
    axis (ghost padding in sharding/simplex.py absorbs uneven n), so the
    surviving-mesh policy is simply "all of them".
    """
    devs = np.asarray(list(devices if devices is not None else jax.devices()))
    return Mesh(devs, ("data",))


def resume_gp(manager: CheckpointManager, template: Any,
              devices=None) -> tuple[Any, int, dict, Mesh]:
    """Restore the newest valid GP checkpoint onto the surviving mesh.

    GP loop state — hyperparams, Adam moments, the rng key — is tiny and
    logically REPLICATED: the data axis shards the per-point MVM operands
    inside the step, never the checkpointed state. So mesh-shape
    elasticity for the GP is a broadcast: restore the logical arrays and
    ``device_put`` them fully-replicated onto the new mesh, whatever its
    size (8 -> 4 -> 1 -> 8 all land bit-identical, asserted by the
    hypothesis round-trip test). Returns ``(tree, step, extra, mesh)``
    with ``extra`` the non-array loop state ``gp/train.fit`` saved.

    Same newest-valid-generation fallback as ``resume``: a generation
    that died mid-write costs one checkpoint, not the restart.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    mesh = gp_mesh(devices)
    repl = NamedSharding(mesh, PartitionSpec())
    shardings = jax.tree.map(lambda _: repl, template)
    step = manager.latest_valid_step()
    if step is None:
        raise FileNotFoundError("no valid checkpoint to resume from")
    try:
        tree = manager.restore(step, template, shardings)
    except CheckpointCorruptError as e:  # pragma: no cover - verify raced
        raise FileNotFoundError(
            f"checkpoint step {step} corrupted between verify and restore: "
            f"{e}") from e
    extra = dict(manager.manifest(step).get("extra", {}))
    return tree, step, extra, mesh
