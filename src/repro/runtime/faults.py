"""Deterministic fault injection for the serving runtime (DESIGN.md §13).

The fault-tolerance claims of ``launch/serve_gp.GPServeEngine`` — a bad
candidate is never published, a wedged refresh never blocks queries, an
overflow refusal recovers with grown capacity — are only claims until a
harness can *force* each failure on cue and watch the engine degrade
gracefully. This module is that harness: a scripted schedule of
``FaultEvent``s that the engine probes at named sites, each firing
exactly when its per-site occurrence counter matches, so a soak run
(benchmarks/fig_soak.py) replays the identical failure sequence every
time and its availability/validity stats are reproducible.

Sites are engine-defined strings (``"refresh"``, ``"freeze"``,
``"query"``); kinds are the failure modes the serving stack must survive:

  exception    the probe raises ``InjectedFault`` (a refresh worker crash,
               a transient query-path error)
  slow         the probe sleeps ``seconds`` (a wedged/straggling freeze —
               trips the refresh deadline, StepWatchdog-style)
  nan_tables   candidate Predictor tables poisoned with NaN (a diverged
               solve / corrupt device buffer) — must be refused by the
               ``serve.validate_predictor`` gate
  inf_tables   same, with +inf
  cg_stall     the refresh solves under a config that cannot converge
               (forced CG non-convergence) — refused by the gate
  overflow     the refresh freezes with a deliberately tiny lattice cap,
               forcing the capacity-overflow refusal the engine must
               recover from by re-freezing with grown capacity
  kill         the probe terminates the PROCESS via ``os._exit`` — no
               cleanup, no atexit, no flushing: a crash, as far as every
               durability layer can tell. Probed at the persistence
               sites (``"persist_before_publish"`` /
               ``"persist_after_publish"`` around the atomic rename) by
               the recovery harness (benchmarks/fig_recovery.py), which
               restarts the process and asserts warm boot loses at most
               one generation.

Training sites (DESIGN.md §16) reuse the same schedule: ``gp/train.fit``
probes ``"fit"`` between steps (kill / nan_params / spike_params, PR 7)
and — new here — ``"fit_step"`` *inside* the jitted step via the
``plan_step``/``exec_step_fault`` pair: the host consumes the schedule
once per step DISPATCH and passes the decision into the compiled step
as a fault-code operand, where a ``jax.pure_callback`` sleeps (``slow``
models a wedged collective — the whole step stalls on the straggling
host callback) and echoes a poison flag back as a step OUTPUT; the host
raises ``InjectedFault`` after ``block_until_ready`` when the flag is
set, so a transient in-step ``exception`` surfaces as a retried event
in ``FitReport``, not an abort. The callback itself NEVER raises: an
exception thrown from a host callback on one device thread of a
sharded program leaves the other threads parked in the collective —
a real deadlock, observed, not hypothetical. Simulated device loss is not
a probe at all: the elastic harness (launch/elastic_gp.py,
benchmarks/fig_elastic.py) kills the training subprocess and restarts it
with a smaller ``--xla_force_host_platform_device_count``, which is what
losing devices looks like from the checkpoint layer's point of view.

Durability corruption (DESIGN.md §14) is injected on DISK rather than
through a probe: ``corrupt_checkpoint(dir, kind)`` damages an
already-published checkpoint/Predictor directory the way real storage
does — ``truncate`` (partial write), ``bitflip`` (silent media
corruption), ``missing_blob`` (lost file), ``stale_manifest`` (manifest
and blobs out of sync). Every kind must be DETECTED at load by the
integrity layer (runtime/checkpoint.py checksums + the
``validate_predictor``/self-probe gate) — the corruption tests assert a
damaged generation is rejected and never served.

Every fired event is appended to ``injector.fired`` so benchmarks can
report the schedule actually exercised. The injector is thread-safe: the
engine probes it from both the query (caller) thread and the refresh
worker thread.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import threading
import time


class InjectedFault(RuntimeError):
    """Raised by an armed ``exception`` event (and nothing else)."""


def is_injected(exc: BaseException | None) -> bool:
    """True if ``exc`` is — or wraps — an ``InjectedFault``.

    The in-step protocol raises ``InjectedFault`` directly on the host
    (see ``FaultInjector.plan_step``), but any fault that does cross the
    XLA boundary — e.g. a future callback-site failure — arrives wrapped
    in the backend's runtime error (``XlaRuntimeError``), sometimes with
    the original only present in the message text rather than the
    ``__cause__`` chain. This walks both the cause/context chain and the
    message so the trainer can distinguish a scripted transient (retry)
    from a genuine failure (abort) regardless of how many layers XLA
    wrapped it in.
    """
    seen: set[int] = set()
    stack: list[BaseException | None] = [exc]
    while stack:
        e = stack.pop()
        if e is None or id(e) in seen:
            continue
        seen.add(id(e))
        if isinstance(e, InjectedFault):
            return True
        if "InjectedFault" in str(e) or "injected exception" in str(e):
            return True
        stack.extend((e.__cause__, e.__context__))
    return False


def exec_step_fault(code):
    """Act on a ``plan_step`` fault code from inside a jitted step.

    The ``jax.pure_callback`` body for the ``"fit_step"`` site: sleeps
    ``code[0]`` seconds (a wedged collective — the compiled step cannot
    complete until the callback returns) and echoes the poison flag
    ``code[1]`` back as a float32 scalar the step returns as an output
    (an output cannot be dead-code-eliminated, so the callback always
    executes). Deliberately a pure function of its operand — no injector
    state, no raising — so it is safe to run once per device thread.
    """
    import numpy as np
    seconds = float(code[0])
    if seconds > 0.0:
        time.sleep(seconds)
    return np.float32(code[1])


@dataclasses.dataclass
class FaultEvent:
    """One scripted failure.

    ``at`` is the 1-based occurrence of the (site, kind) probe the event
    fires on — e.g. ``at=3`` on site "refresh" fires on the third refresh
    — with ``count`` consecutive firings (``count=2`` makes the next
    probe fail too, which is how a *persistent* failure is scripted vs a
    transient one). ``at=None`` fires on the very next probe.
    """

    site: str
    kind: str  # exception | slow | nan_tables | inf_tables | cg_stall | overflow
    at: int | None = None
    count: int = 1
    seconds: float = 0.0  # for kind="slow"
    cap: int = 8  # for kind="overflow": the forced (too-small) lattice cap
    note: str = ""

    _remaining: int = dataclasses.field(default=-1, repr=False)


class FaultInjector:
    """Scripted, thread-safe fault schedule probed by the serving engine.

    The engine calls the ``take``/``maybe_raise``/``sleep_if_armed``/...
    probes at its sites; an event fires when the site's probe counter for
    its kind reaches ``at``. A ``None`` injector (the production default)
    means every probe is a no-op — the engine guards each call site with
    ``if self._faults is not None``.
    """

    def __init__(self, events: list[FaultEvent] | tuple[FaultEvent, ...] = ()):
        self._lock = threading.Lock()
        self._events: list[FaultEvent] = []
        self._counts: dict[tuple[str, str], int] = {}
        self.fired: list[FaultEvent] = []
        for ev in events:
            self.arm(ev)

    def arm(self, event: FaultEvent | None = None, /, **kw) -> FaultEvent:
        """Add an event to the schedule (``arm(FaultEvent(...))`` or
        ``arm(site="refresh", kind="exception", at=2)``)."""
        ev = event if event is not None else FaultEvent(**kw)
        with self._lock:
            ev._remaining = ev.count
            self._events.append(ev)
        return ev

    # -- probes (engine-facing) ---------------------------------------------

    def take(self, site: str, kind: str) -> FaultEvent | None:
        """Consume one firing of an armed (site, kind) event, if due.

        Increments the (site, kind) probe counter regardless of outcome —
        scheduling is by how many times the engine ASKED, which is what
        makes "fail refresh #3" scriptable.
        """
        with self._lock:
            key = (site, kind)
            self._counts[key] = self._counts.get(key, 0) + 1
            tick = self._counts[key]
            for ev in self._events:
                if ev.site != site or ev.kind != kind or ev._remaining <= 0:
                    continue
                if ev.at is None or ev.at <= tick < ev.at + ev.count:
                    ev._remaining -= 1
                    self.fired.append(ev)
                    return ev
        return None

    def maybe_raise(self, site: str) -> None:
        ev = self.take(site, "exception")
        if ev is not None:
            raise InjectedFault(f"injected exception at {site!r}"
                                + (f" ({ev.note})" if ev.note else ""))

    def sleep_if_armed(self, site: str) -> float:
        """Stall the calling thread (a wedged freeze); returns seconds slept."""
        ev = self.take(site, "slow")
        if ev is None:
            return 0.0
        time.sleep(ev.seconds)
        return ev.seconds

    def corrupt_tables(self, site: str, tables):
        """Poison a candidate's value tables with NaN/Inf if armed."""
        ev = self.take(site, "nan_tables")
        bad = float("nan")
        if ev is None:
            ev = self.take(site, "inf_tables")
            bad = float("inf")
        if ev is None:
            return tables
        return tables.at[0, 0].set(bad)

    def cg_stall(self, site: str) -> bool:
        """True if this refresh's CG solve should be forced to stall."""
        return self.take(site, "cg_stall") is not None

    def forced_cap(self, site: str) -> int | None:
        """A deliberately undersized lattice cap for this freeze, or None."""
        ev = self.take(site, "overflow")
        return None if ev is None else ev.cap

    def plan_step(self, site: str):
        """Consume the in-step schedule for ONE step dispatch (host side).

        Returns a float32 ``[sleep_seconds, poison]`` fault code the
        caller passes INTO the jitted step as an operand, where
        ``exec_step_fault`` acts on it from a ``jax.pure_callback``.
        Consuming on dispatch (not inside the callback) keeps the
        ``at`` arithmetic device-count-independent: XLA may run a host
        callback once per participating device, and a retried step is a
        new dispatch — one tick either way, same as the single-device
        probe counting the tests pin.

        A nonzero poison flag means an ``exception`` event is due; the
        caller must raise ``InjectedFault`` on the HOST after
        ``block_until_ready``, never from the callback — an exception
        thrown from a host callback on one device thread of a sharded
        program leaves the other threads parked in the collective
        (deadlock), which is why the flag travels as a step output.
        """
        import numpy as np
        ev_slow = self.take(site, "slow")
        ev_exc = self.take(site, "exception")
        return np.asarray([ev_slow.seconds if ev_slow is not None else 0.0,
                           1.0 if ev_exc is not None else 0.0],
                          dtype=np.float32)

    def kill_if_armed(self, site: str) -> None:
        """Terminate the process like a crash (``os._exit``) if armed.

        ``os._exit`` skips every Python-level cleanup — daemon threads,
        atexit, buffered writes — which is exactly what a SIGKILL/power
        loss looks like to the durability layer. Exit code 17 marks the
        death as scripted so the recovery harness can tell an injected
        kill from a genuine crash.
        """
        if self.take(site, "kill") is not None:
            os._exit(17)

    # -- reporting ----------------------------------------------------------

    def summary(self) -> list[dict]:
        """JSON-able log of every fired event, in firing order."""
        with self._lock:
            return [{"site": ev.site, "kind": ev.kind, "at": ev.at,
                     "note": ev.note} for ev in self.fired]


# -- on-disk durability faults (no probe: damage published state) -----------

CORRUPTION_KINDS = ("truncate", "bitflip", "missing_blob", "stale_manifest")


def corrupt_checkpoint(directory: str | pathlib.Path, kind: str,
                       *, blob_index: int = 0) -> str:
    """Damage a published checkpoint/Predictor directory like storage does.

    ``directory`` is a blob+manifest directory (runtime/checkpoint.py's
    ``step_*`` or gp/serve.py's Predictor layout). Returns a description
    of what was damaged. Kinds:

      truncate        cut the ``blob_index``-th .npy blob to half its
                      bytes (a write that died mid-flight past the
                      atomic-rename boundary, or a torn copy)
      bitflip         flip one bit in the middle of a blob (silent media
                      corruption — only the CRC can see it)
      missing_blob    delete a blob the manifest still references
      stale_manifest  rewrite the manifest to reference a blob file that
                      does not exist (manifest and blobs out of sync —
                      e.g. a restored-from-backup manifest over newer
                      blobs)

    Every kind must be detected at load (CheckpointCorruptError or the
    Predictor validation gate) — the corruption tests and
    benchmarks/fig_recovery.py assert detection, never silent serving.
    """
    directory = pathlib.Path(directory)
    blobs = sorted(directory.glob("*.npy"))
    if not blobs:
        raise FileNotFoundError(f"{directory}: no .npy blobs to corrupt")
    blob = blobs[blob_index % len(blobs)]
    if kind == "truncate":
        size = blob.stat().st_size
        with open(blob, "r+b") as f:
            f.truncate(max(size // 2, 1))
        return f"truncated {blob.name} {size} -> {max(size // 2, 1)} bytes"
    if kind == "bitflip":
        data = bytearray(blob.read_bytes())
        pos = len(data) // 2
        data[pos] ^= 0x10
        blob.write_bytes(bytes(data))
        return f"flipped bit 4 of byte {pos} in {blob.name}"
    if kind == "missing_blob":
        blob.unlink()
        return f"deleted {blob.name}"
    if kind == "stale_manifest":
        mpath = directory / "manifest.json"
        man = json.loads(mpath.read_text())
        leaves = man.get("leaves", {})
        if not leaves:
            raise ValueError(f"{directory}: manifest has no leaves")
        name = sorted(leaves)[blob_index % len(leaves)]
        leaves[name] = dict(leaves[name], file="__gone__.npy")
        mpath.write_text(json.dumps(man))
        return f"manifest leaf {name!r} now references __gone__.npy"
    raise ValueError(f"unknown corruption kind {kind!r}; "
                     f"expected one of {CORRUPTION_KINDS}")
