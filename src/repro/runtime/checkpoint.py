"""Sharding-agnostic checkpointing: atomic, async, keep-k, integrity-checked.

Design (the orbax pattern, dependency-free):

  * params/opt-state are flattened to named leaves ("layers/attn/wq", ...)
    and written as raw .npy blobs + a JSON manifest with step metadata.
  * arrays are host-gathered to their LOGICAL (unsharded) shape, so a
    checkpoint written on one mesh restores onto ANY mesh — elastic
    restarts (runtime/elastic.py) just re-shard at load.
  * writes go to ``<dir>/step_<k>.tmp`` then ``os.replace`` to the final
    name — a crash mid-write never corrupts the latest checkpoint.
  * an async writer thread overlaps serialization with training; ``wait``
    joins before the next save (single-buffered, like orbax's async).
  * keep-last-k + keep-best (by a metric the caller passes) retention.

Integrity contract (DESIGN.md §14): the manifest records a schema
version plus, per blob, its byte size and CRC32. ``restore``/``verify``
check every blob BEFORE ``np.load`` touches it, so a truncated, missing,
or bit-flipped blob raises ``CheckpointCorruptError`` with a precise
message instead of crashing mid-parse — callers (gp/train resume,
runtime/elastic, the serving warm boot) treat that error as "this
generation is dead, fall back to the previous one". The blob read/write
helpers (``save_blobs``/``load_blobs``) are shared with the Predictor
persistence layer (gp/serve.py) so both durability formats enforce the
same checks.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

PyTree = Any

SCHEMA_VERSION = 2  # manifest schema this writer emits


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed an integrity check (missing / truncated /
    checksum-mismatched blob, unreadable or future-schema manifest).

    The durability contract: callers must treat this as "generation
    unusable — fall back", never as a crash. It is deliberately NOT a
    subclass of ``OSError``/``ValueError`` so integrity failures cannot
    be accidentally swallowed by broad IO handling.
    """


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)  # lossless; .npy can't store bf16
        flat[name] = arr
    return flat


def _unflatten_like(template: PyTree, flat: dict[str, np.ndarray]) -> PyTree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if name not in flat:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = flat[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs "
                f"model {leaf.shape}")
        import jax.numpy as jnp
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


# -- blob IO (shared with gp/serve.py Predictor persistence) -----------------


def _crc32(path: pathlib.Path) -> int:
    crc = 0
    with open(path, "rb") as f:
        while chunk := f.read(1 << 20):
            crc = zlib.crc32(chunk, crc)
    return crc


def save_blobs(directory: pathlib.Path,
               flat: dict[str, np.ndarray]) -> dict[str, dict]:
    """Write every array as a .npy blob; return the manifest leaf metadata
    (file name, shape, dtype, byte size, CRC32 of the on-disk bytes)."""
    leaves: dict[str, dict] = {}
    for name, arr in flat.items():
        fname = name.replace("/", "__") + ".npy"
        path = directory / fname
        np.save(path, arr)
        leaves[name] = {
            "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "nbytes": path.stat().st_size,
            "crc32": _crc32(path)}
    return leaves


def load_blobs(directory: pathlib.Path,
               leaves: dict[str, dict]) -> dict[str, np.ndarray]:
    """Load manifest-listed blobs with integrity checks BEFORE np.load.

    Order of checks per blob: exists -> recorded byte size (catches
    truncation without reading content) -> CRC32 (catches bit flips) ->
    parseable .npy with the recorded shape/dtype. Any failure raises
    ``CheckpointCorruptError`` naming the blob and the check that failed.
    Pre-schema-2 manifests (no nbytes/crc32) still get the existence and
    parse checks.
    """
    flat: dict[str, np.ndarray] = {}
    for name, meta in leaves.items():
        path = directory / meta["file"]
        if not path.exists():
            raise CheckpointCorruptError(
                f"{directory}: blob {meta['file']!r} (leaf {name!r}) is "
                "missing")
        if "nbytes" in meta and path.stat().st_size != meta["nbytes"]:
            raise CheckpointCorruptError(
                f"{directory}: blob {meta['file']!r} is truncated/resized "
                f"({path.stat().st_size} bytes, manifest records "
                f"{meta['nbytes']})")
        if "crc32" in meta and _crc32(path) != meta["crc32"]:
            raise CheckpointCorruptError(
                f"{directory}: blob {meta['file']!r} failed its CRC32 "
                "check (bit corruption)")
        try:
            arr = np.load(path)
        except Exception as e:
            raise CheckpointCorruptError(
                f"{directory}: blob {meta['file']!r} is not a readable "
                f".npy file ({type(e).__name__}: {e})") from e
        if (list(arr.shape) != list(meta["shape"])
                or str(arr.dtype) != meta["dtype"]):
            raise CheckpointCorruptError(
                f"{directory}: blob {meta['file']!r} decodes to "
                f"{arr.dtype}{arr.shape}, manifest records "
                f"{meta['dtype']}{tuple(meta['shape'])} — stale manifest "
                "or swapped blob")
        flat[name] = arr
    return flat


def read_manifest(path: pathlib.Path, *,
                  expect_format: str | None = None) -> dict:
    """Read + sanity-check a manifest.json; integrity failures raise
    ``CheckpointCorruptError`` (missing file, bad JSON, future schema,
    wrong format tag)."""
    if not path.exists():
        raise CheckpointCorruptError(f"{path.parent}: manifest.json missing")
    try:
        man = json.loads(path.read_text())
    except (json.JSONDecodeError, OSError) as e:
        raise CheckpointCorruptError(
            f"{path.parent}: manifest.json unreadable "
            f"({type(e).__name__}: {e})") from e
    schema = man.get("schema", 1)
    if not isinstance(schema, int) or schema > SCHEMA_VERSION:
        raise CheckpointCorruptError(
            f"{path.parent}: manifest schema {schema!r} is newer than this "
            f"reader ({SCHEMA_VERSION}) — refusing to guess")
    if expect_format is not None and man.get("format", expect_format) \
            != expect_format:
        raise CheckpointCorruptError(
            f"{path.parent}: manifest format {man.get('format')!r} != "
            f"expected {expect_format!r}")
    if not isinstance(man.get("leaves"), dict):
        raise CheckpointCorruptError(
            f"{path.parent}: manifest has no 'leaves' table")
    return man


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, *, keep_last: int = 3,
                 keep_best: int = 1, async_write: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.keep_best = keep_best
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, metric: float | None = None,
             extra: dict | None = None):
        flat = _flatten(tree)  # host-gather on the caller thread (cheap)
        self.wait()

        def write():
            try:
                self._write(step, flat, metric, extra or {})
            except Exception as e:  # surfaced on next wait()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def _write(self, step: int, flat: dict, metric, extra):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        manifest = {"schema": SCHEMA_VERSION, "step": step, "metric": metric,
                    "extra": extra, "leaves": save_blobs(tmp, flat)}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore -----------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if p.is_dir() and not p.name.endswith(".tmp"))

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def manifest(self, step: int) -> dict:
        return read_manifest(
            self.dir / f"step_{step:08d}" / "manifest.json")

    def verify(self, step: int) -> dict:
        """Full integrity pass over one checkpoint WITHOUT unflattening.

        Returns the manifest on success; raises ``CheckpointCorruptError``
        naming the failed check otherwise. This is the generation-by-
        generation fallback probe the warm-boot/resume paths run before
        trusting a checkpoint.
        """
        man = self.manifest(step)
        load_blobs(self.dir / f"step_{step:08d}", man["leaves"])
        return man

    def latest_valid_step(self) -> int | None:
        """Newest step that passes ``verify`` — the resume entry point.

        Corrupt generations are skipped (newest first), never raised on:
        a half-written or bit-flipped checkpoint costs one generation of
        progress, not the run.
        """
        for step in reversed(self.steps()):
            try:
                self.verify(step)
                return step
            except CheckpointCorruptError:
                continue
        return None

    def restore(self, step: int, template: PyTree,
                shardings: PyTree | None = None) -> PyTree:
        """Load logical arrays and (optionally) place them sharded.

        ``shardings`` may target a DIFFERENT mesh than the one the
        checkpoint was saved under — this is the elastic-restart path.
        Integrity failures raise ``CheckpointCorruptError`` before any
        array is materialized.
        """
        d = self.dir / f"step_{step:08d}"
        man = self.manifest(step)
        flat = load_blobs(d, man["leaves"])
        tree = _unflatten_like(template, flat)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree

    # -- retention ------------------------------------------------------------
    def _gc(self):
        steps = self.steps()
        if len(steps) <= self.keep_last:
            return
        # collect best-k by metric (None metrics never counted as best)
        metrics = {}
        for s in steps:
            try:
                metrics[s] = self.manifest(s).get("metric")
            except Exception:
                metrics[s] = None
        scored = [s for s in steps if metrics[s] is not None]
        best = set(sorted(scored, key=lambda s: metrics[s])
                   [: self.keep_best])
        keep = set(steps[-self.keep_last:]) | best
        for s in steps:
            if s not in keep:
                shutil.rmtree(self.dir / f"step_{s:08d}",
                              ignore_errors=True)
